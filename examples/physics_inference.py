"""The paper's end-to-end physics workflow on one model (default: GW).

Reproduces the Sec. V-C + Sec. VI-A protocol: train the gravitational-wave
classifier, post-training-quantize at the paper's chosen precision
(ap_fixed<12,6>), run quantization-aware training at the same precision,
and report the AUC ratio (quantized vs float) plus the latency estimates
(FPGA cycle model per Tables II-IV and the TPU roofline).

Both quantization passes run through the PrecisionPolicy grid
(``--policy`` overrides the paper-optimal parametric presets).

    PYTHONPATH=src python examples/physics_inference.py [gw|engine_anomaly|btagging]
        [--policy qat_fixed<10,5>]
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core import fixed_point as fxp
from repro.core import latency_model as lat
from repro.core import precision as precision_lib
from repro.data import physics as pdata
from repro.models import physics as pmodel
from repro.optim import AdamW


def train(cfg, x, y, steps, params=None, lr=3e-3, seed=0):
    if params is None:
        params = pmodel.init_params(cfg, jax.random.PRNGKey(seed))
    opt = AdamW(schedule=lambda s: lr, weight_decay=0.0)
    state = opt.init(params)
    batch = {"x": jnp.asarray(x), "y": jnp.asarray(y)}

    @jax.jit
    def step(params, state):
        (l, _), g = jax.value_and_grad(pmodel.loss_fn, has_aux=True)(
            params, cfg, batch
        )
        params, state, _ = opt.update(g, state, params)
        return params, state, l

    for i in range(steps):
        params, state, l = step(params, state)
    return params, float(l)


def auc_of(cfg, params, x, y):
    proba = np.asarray(pmodel.predict_proba(params, cfg, jnp.asarray(x)))
    if cfg.n_classes == 1:
        return pdata.auc_score(y, proba)
    if cfg.n_classes == 2:
        return pdata.auc_score(y, proba[:, 1])
    return pdata.multiclass_auc(y, proba)


def main(name: str = "gw", policy: str | None = None):
    import dataclasses

    cfg = configs.get_config(name)
    fp = fxp.PAPER_OPTIMAL[name]["qat"]
    if policy == "auto":
        policy = cfg.serve_policy
    if policy is None:
        ptq_policy = precision_lib.get_policy(
            f"ptq_fixed<{fp.total_bits},{fp.int_bits}>"
        )
        qat_policy = precision_lib.get_policy(
            f"qat_fixed<{fp.total_bits},{fp.int_bits}>"
        )
    else:
        ptq_policy = qat_policy = precision_lib.get_policy(policy)
    print(f"== {name}: seq {cfg.seq_len} x {cfg.input_vec_size}, "
          f"{cfg.n_layers} blocks, d={cfg.d_model}, "
          f"policies {ptq_policy.name}/{qat_policy.name} ==")
    x, y = pdata.GENERATORS[name](1024, seed=0)
    xt, yt = pdata.GENERATORS[name](1024, seed=77)

    params, loss = train(cfg, x, y, 150)
    auc_float = auc_of(cfg, params, xt, yt)
    print(f"float model:       loss {loss:.4f}  AUC {auc_float:.4f}")

    ptq = precision_lib.apply_plan_to_params(
        params, ptq_policy.resolve(cfg.n_layers)
    )
    auc_ptq = auc_of(cfg, ptq, xt, yt)
    print(f"PTQ {ptq_policy.name}:   AUC {auc_ptq:.4f}  "
          f"(ratio {auc_ptq/auc_float:.4f})")

    cfg_q = dataclasses.replace(cfg, precision=qat_policy)
    qat_params, _ = train(cfg_q, x, y, 60, params=params, lr=1e-3)
    qat_eval = precision_lib.apply_plan_to_params(
        qat_params, qat_policy.resolve(cfg.n_layers)
    )
    auc_qat = auc_of(cfg_q, qat_eval, xt, yt)
    print(f"QAT {qat_policy.name}:   AUC {auc_qat:.4f}  "
          f"(ratio {auc_qat/auc_float:.4f})")

    for r in (1, 2, 4):
        est = lat.fpga_style_estimate(
            seq_len=cfg.seq_len, d_model=cfg.d_model,
            n_blocks=cfg.n_layers, reuse=r,
        )
        print(f"latency model R{r}: clk {est.clock_ns:.2f}ns  "
              f"II {est.interval_cycles}  latency {est.latency_us:.2f}us")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("model", nargs="?", default="gw",
                    choices=["gw", "engine_anomaly", "btagging"])
    ap.add_argument("--policy", default=None,
                    help="precision policy overriding the paper-optimal "
                         "presets (e.g. qat_fixed<10,5>, paper_vu13p, or "
                         "'auto' for the model's recommended serve_policy)")
    args = ap.parse_args()
    main(args.model, policy=args.policy)

"""End-to-end serving driver (the paper's kind is inference): serve a
small LM with batched requests through the continuous-batching engine,
with the paper's quantized datapath enabled.

    PYTHONPATH=src python examples/serve_lm.py --arch granite-8b --requests 12

``--stream`` consumes two interleaved ``Engine.stream`` iterators (the
rest batch behind them) and prints per-token events with
time-to-first-token — the client-facing side of the
Scheduler / Executor / Engine split.
"""

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.models import lm
from repro.serve import Engine
from repro.serve.cli import add_serving_args, config_from_args


def stream_demo(eng, handles):
    """Interleave the first two streams token-by-token (proving both
    make progress on shared engine pumps), then drain the rest."""
    first_ts = {}
    live = [eng.stream(h) for h in handles[:2]]
    while live:
        for it in list(live):
            ev = next(it, None)
            if ev is None:
                live.remove(it)
            else:
                first_ts.setdefault(ev.uid, ev.ts)
                print(f"  [stream] req {ev.uid} token#{ev.index} = {ev.token}"
                      f"{'  <done:' + ev.finish_reason + '>' if ev.finished else ''}")
    for h in handles[2:]:
        for ev in eng.stream(h):
            first_ts.setdefault(ev.uid, ev.ts)
    for h in handles[:3]:
        req = eng.result(h)
        if h.uid not in first_ts:  # zero-token finish (sequence cap)
            print(f"  req {h.uid}: no tokens -> {req.generated}")
            continue
        ttft_ms = (first_ts[h.uid] - req.created_at) * 1e3
        print(f"  req {h.uid}: ttft {ttft_ms:.1f} ms -> {req.generated}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--requests", type=int, default=12)
    add_serving_args(ap, max_batch=4, max_seq=128, max_new=16,
                     temperature=0.7)
    args = ap.parse_args()

    cfg = configs.get_config(args.arch, reduced=True)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, config_from_args(args, cfg))
    print(f"serving {cfg.name} ({lm.count_params(cfg):,} params), "
          f"max_batch={args.max_batch}, policy={eng.executor.policy.name}, "
          f"kv_layout={eng.executor.kv_layout}, "
          f"buckets={eng.executor.buckets or 'exact'}, "
          f"decode_steps={eng.serve_cfg.decode_steps}"
          + (f", prefill_chunk={args.prefill_chunk}"
             if args.prefill_chunk else ""))

    rng = np.random.default_rng(0)
    preamble = list(rng.integers(0, cfg.vocab_size, args.shared_prefix))
    handles = []
    for _ in range(args.requests):
        prompt = preamble + list(
            rng.integers(0, cfg.vocab_size, rng.integers(3, 12))
        )
        handles.append(eng.submit(prompt, max_new_tokens=args.max_new))

    t0 = time.perf_counter()
    if args.stream:
        stream_demo(eng, handles)
        results = {h.uid: eng.result(h) for h in handles}
    else:
        steps = 0
        while eng.has_work:
            stats = eng.step()
            steps += 1
            if steps % 8 == 0:
                active = sum(s.active for s in eng.executor.slots)
                print(f"  step {steps}: active={active} "
                      f"queued={len(eng.scheduler.queue)} "
                      f"prefilled={stats['prefilled']} "
                      f"decoded={stats['decoded']}")
        results = {h.uid: eng.result(h) for h in handles}
    dt = time.perf_counter() - t0

    total_tokens = sum(len(r.generated) for r in results.values())
    print(f"\ncompleted {len(results)} requests / {total_tokens} tokens "
          f"in {dt:.2f}s ({total_tokens/dt:.1f} tok/s on CPU host)")
    tel = eng.telemetry
    print(f"telemetry: queue wait mean {tel['queue_wait_s_total']/max(tel['prompts_admitted'],1)*1e3:.1f} ms | "
          f"{tel['prefill_compiles']} prefill programs, "
          f"{tel['decode_compiles']} decode program | "
          f"prefill {tel['prefill_time_s']:.2f}s / decode {tel['decode_time_s']:.2f}s")
    print(f"kv cache: layout={tel['kv_layout']} "
          f"{tel['kv_bytes'] / 2**20:.2f} MiB | "
          f"pages peak {tel['pages_in_use_peak']}/{tel['pages_capacity']} "
          f"(page_size={tel['kv_page_size']})")
    if args.speculative:
        acc = tel["draft_tokens_accepted"] / max(
            tel["draft_tokens_proposed"], 1
        )
        print(f"speculative: draft={args.draft or 'self'} "
              f"k={args.spec_tokens} | "
              f"proposed {tel['draft_tokens_proposed']} / "
              f"accepted {tel['draft_tokens_accepted']} "
              f"(rate {acc:.2f}) | "
              f"{tel['spec_dispatches']} verify dispatches")
    if args.kv_prefix_cache or args.kv_preemption:
        print(f"prefix cache: hit rate {tel['prefix_hit_rate']:.2f} | "
              f"prefill tokens saved {tel['prefill_tokens_saved']} "
              f"(+{tel['prefix_tokens_shared']} shared-storage) | "
              f"{tel['cow_copies']} CoW copies | "
              f"{tel['preemptions']} preemptions")
    if getattr(args, "kv_host_pages", 0):
        print(f"victim tier: {tel['swap_outs']} spills / "
              f"{tel['swap_ins']} swap-ins | "
              f"host pages {tel['host_pages_used']}/"
              f"{tel['host_pages_capacity']} "
              f"({tel['host_evictions']} tier evictions) | "
              f"swap time {tel['swap_latency_s']*1e3:.1f} ms")
    if args.scheduler == "edf" or args.deadline_ms is not None:
        print(f"slo: scheduler={args.scheduler} | "
              f"{tel['deadline_requests']} deadlined requests, "
              f"{tel['deadline_missed']} missed "
              f"({tel['deadline_dropped']} dropped)")
    if tel["phases"]:
        print("phases (ms):")
        for name, s in tel["phases"].items():
            if not isinstance(s, dict):
                continue
            print(f"  {name:>10}: p50 {s['p50_ms']:7.2f} | "
                  f"p95 {s['p95_ms']:7.2f} | p99 {s['p99_ms']:7.2f} | "
                  f"total {s['total_s']:.2f}s over {s['n']} steps")
    if not args.stream:
        for h in handles[:3]:
            r = results[h.uid]
            print(f"  req {h.uid}: prompt {r.prompt[:6]}... -> {r.generated}")


if __name__ == "__main__":
    main()

"""End-to-end serving driver (the paper's kind is inference): serve a
small LM with batched requests through the continuous-batching engine,
with the paper's quantized datapath enabled.

    PYTHONPATH=src python examples/serve_lm.py --arch granite-8b --requests 12
"""

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.configs.base import ServeConfig
from repro.launch.serve import resolve_policy_arg
from repro.models import lm
from repro.serve import ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.7)
    ap.add_argument("--policy", default=None,
                    help="precision policy preset (float, int8_serve, "
                         "paper_vu13p, ptq_fixed<W,I>, qat_fixed<W,I>) or "
                         "'auto' for the arch's recommended serve_policy")
    ap.add_argument("--quantized", action="store_true",
                    help="deprecated alias for --policy int8_serve")
    ap.add_argument("--prefill-buckets", type=int, nargs="*", default=None,
                    help="prompt-length buckets (default: powers of two; "
                         "pass with no values for exact-length v1 prefill)")
    ap.add_argument("--decode-steps", type=int, default=4,
                    help="decode tokens per host dispatch (lax.scan)")
    ap.add_argument("--max-prefill-per-step", type=int, default=0,
                    help="cap on prompts admitted per step (0 = all free slots)")
    ap.add_argument("--kv-layout", default="dense",
                    choices=("dense", "paged"),
                    help="KV-cache storage layout: dense per-slot slabs or "
                         "block-table pages (serve/kv_cache.py)")
    ap.add_argument("--kv-page-size", type=int, default=16,
                    help="tokens per page (paged layout)")
    ap.add_argument("--kv-prefix-cache", action="store_true",
                    help="share full prompt pages across same-prefix "
                         "requests (paged layout; copy-on-write)")
    ap.add_argument("--kv-preemption", action="store_true",
                    help="preempt the youngest resident instead of "
                         "head-of-line blocking when the page pool is "
                         "exhausted (paged layout, bit-exact datapath)")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="prepend a fixed preamble of this many tokens to "
                         "every request (prefix-cache exercise)")
    args = ap.parse_args()

    cfg = configs.get_config(args.arch, reduced=True)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    serve_cfg = ServeConfig(
        max_batch=args.max_batch,
        max_seq_len=128,
        temperature=args.temperature,
        policy=resolve_policy_arg(args.policy, args.quantized, cfg),
        prefill_buckets=(
            None if args.prefill_buckets is None
            else tuple(args.prefill_buckets)
        ),
        decode_steps=args.decode_steps,
        max_prefill_per_step=args.max_prefill_per_step,
        kv_layout=args.kv_layout,
        kv_page_size=args.kv_page_size,
        kv_prefix_cache=args.kv_prefix_cache,
        kv_preemption=args.kv_preemption,
    )
    eng = ServingEngine(cfg, params, serve_cfg)
    print(f"serving {cfg.name} ({lm.count_params(cfg):,} params), "
          f"max_batch={args.max_batch}, policy={eng.policy.name}, "
          f"kv_layout={eng.kv_layout}, "
          f"buckets={eng.prefill_buckets or 'exact'}, "
          f"decode_steps={serve_cfg.decode_steps}")

    rng = np.random.default_rng(0)
    preamble = list(rng.integers(0, cfg.vocab_size, args.shared_prefix))
    uids = []
    for i in range(args.requests):
        prompt = preamble + list(
            rng.integers(0, cfg.vocab_size, rng.integers(3, 12))
        )
        uids.append(eng.submit(prompt, max_new_tokens=args.max_new))

    t0 = time.perf_counter()
    steps = 0
    while eng.has_work:
        stats = eng.step()
        steps += 1
        if steps % 8 == 0:
            active = sum(s.active for s in eng.slots)
            print(f"  step {steps}: active={active} queued={len(eng._queue)} "
                  f"prefilled={stats['prefilled']} decoded={stats['decoded']}")
    dt = time.perf_counter() - t0

    results = {u: eng.result(u) for u in uids}
    total_tokens = sum(len(r.generated) for r in results.values())
    print(f"\ncompleted {len(results)} requests / {total_tokens} tokens "
          f"in {dt:.2f}s ({total_tokens/dt:.1f} tok/s on CPU host)")
    tel = eng.telemetry
    print(f"telemetry: queue wait mean {tel['queue_wait_s_total']/max(tel['prompts_admitted'],1)*1e3:.1f} ms | "
          f"{tel['prefill_compiles']} prefill programs, "
          f"{tel['decode_compiles']} decode program | "
          f"prefill {tel['prefill_time_s']:.2f}s / decode {tel['decode_time_s']:.2f}s")
    print(f"kv cache: layout={tel['kv_layout']} "
          f"{tel['kv_bytes'] / 2**20:.2f} MiB | "
          f"pages peak {tel['pages_in_use_peak']}/{tel['pages_capacity']} "
          f"(page_size={tel['kv_page_size']})")
    if args.kv_prefix_cache or args.kv_preemption:
        print(f"prefix cache: hit rate {tel['prefix_hit_rate']:.2f} | "
              f"prefill tokens saved {tel['prefill_tokens_saved']} "
              f"(+{tel['prefix_tokens_shared']} shared-storage) | "
              f"{tel['cow_copies']} CoW copies | "
              f"{tel['preemptions']} preemptions")
    for u in uids[:3]:
        r = results[u]
        print(f"  req {u}: prompt {r.prompt[:6]}... -> {r.generated}")


if __name__ == "__main__":
    main()

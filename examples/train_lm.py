"""Training driver: train an LM on the synthetic pipeline with the full
fault-tolerant loop (checkpoint/resume, heartbeat, straggler detection).

Default trains a ~100M-param llama-style model for a few hundred steps on
a single host; any assigned arch runs with --arch (reduced) or
--full-config (the real dimensions — needs accelerators).

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""

import argparse
import dataclasses

from repro import configs
from repro.configs.base import ModelConfig, TrainConfig
from repro.data.synthetic import SyntheticLM, SyntheticLMConfig
from repro.models import lm
from repro.train import run_training


def model_100m() -> ModelConfig:
    """~100M params: 12L x d768, llama-style."""
    return ModelConfig(
        name="repro-100m",
        family="dense",
        n_layers=12,
        d_model=768,
        n_heads=12,
        n_kv_heads=4,
        d_ff=2048,
        vocab_size=32000,
        attn_kind="gqa",
        norm_kind="rmsnorm",
        act="silu",
        gated_mlp=True,
        tie_embeddings=True,
        dtype="float32",
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="assigned arch id (reduced)")
    ap.add_argument("--full-config", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--schedule", default="cosine", choices=["cosine", "wsd", "linear"])
    ap.add_argument("--workdir", default="/tmp/repro_train")
    args = ap.parse_args()

    if args.arch:
        cfg = configs.get_config(args.arch, reduced=not args.full_config)
        if cfg.name.startswith("minicpm"):
            args.schedule = "wsd"  # the paper-faithful schedule for MiniCPM
    else:
        cfg = model_100m()
    print(f"training {cfg.name}: {lm.count_params(cfg):,} params, "
          f"{args.steps} steps @ batch {args.batch} x seq {args.seq}")

    ds = SyntheticLM(SyntheticLMConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq, global_batch=args.batch
    ))
    tc = TrainConfig(
        learning_rate=args.lr,
        warmup_steps=max(10, args.steps // 20),
        total_steps=args.steps,
        schedule=args.schedule,
        checkpoint_every=max(50, args.steps // 4),
    )
    result = run_training(cfg, tc, ds.batch, workdir=args.workdir, log_every=10)
    print(f"\nfinal step {result.final_step}; "
          f"loss {result.metrics_history[0]['loss']:.3f} -> "
          f"{result.metrics_history[-1]['loss']:.3f}; "
          f"stragglers flagged: {len(result.stragglers)}")


if __name__ == "__main__":
    main()

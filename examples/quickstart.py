"""Quickstart: the paper's pipeline in 60 lines.

Builds a small transformer, trains briefly, then runs the paper's
quantized low-latency inference path (int8 weights + LUT softmax +
streaming attention) and compares it against the float model.

    PYTHONPATH=src python examples/quickstart.py
"""

import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.configs.base import ServeConfig, TrainConfig
from repro.core import latency_model as lat
from repro.data.synthetic import SyntheticLM, SyntheticLMConfig
from repro.models import lm
from repro.serve import Engine
from repro.train import run_training


def main():
    cfg = configs.get_config("granite-8b", reduced=True)
    print(f"model: {cfg.name}  params={lm.count_params(cfg):,}")

    # 1. train briefly on the synthetic token stream
    ds = SyntheticLM(SyntheticLMConfig(
        vocab_size=cfg.vocab_size, seq_len=32, global_batch=8
    ))
    # fresh workdir per run: a stale checkpoint at total_steps would make
    # run_training resume-and-return with an empty metrics history
    workdir = tempfile.mkdtemp(prefix="repro_quickstart_")
    result = run_training(
        cfg,
        TrainConfig(learning_rate=1e-2, warmup_steps=5, total_steps=50,
                    checkpoint_every=25),
        ds.batch,
        workdir=workdir,
    )
    print(f"trained {result.final_step} steps; "
          f"loss {result.metrics_history[0]['loss']:.3f} -> "
          f"{result.metrics_history[-1]['loss']:.3f}")

    # 2. reload the trained params and serve, float vs paper-quantized
    from repro.checkpoint import Checkpointer
    from repro.optim import AdamW
    from repro.train import step as step_lib

    opt = AdamW(schedule=lambda s: 1e-2)
    template = step_lib.make_train_state(cfg, opt, jax.random.PRNGKey(0))
    state = Checkpointer(f"{workdir}/checkpoints").restore(template)
    params = state["params"]

    prompt = list(np.asarray(ds.batch(999)["tokens"][0, :8]))
    float_eng = Engine(cfg, params, ServeConfig(max_batch=1, max_seq_len=64))
    h = float_eng.submit(prompt, max_new_tokens=12)
    float_out = float_eng.generate()[h.uid].generated

    quant_eng = Engine(
        cfg, params,
        ServeConfig(max_batch=1, max_seq_len=64, policy="int8_serve"),
    )
    h = quant_eng.submit(prompt, max_new_tokens=12)
    quant_out = quant_eng.generate()[h.uid].generated

    agree = sum(a == b for a, b in zip(float_out, quant_out))
    print(f"float   continuation: {float_out}")
    print(f"int8+LUT continuation: {quant_out}  (agreement {agree}/12)")

    # 3. the roofline latency estimate for this model's decode step
    n = lm.count_params(cfg)
    terms = lat.roofline(2 * n, 2 * n, 0, int8=True)
    print(f"single-chip decode-step roofline: "
          f"{lat.tpu_latency_us(terms)[0]:.2f}-{lat.tpu_latency_us(terms)[1]:.2f} us")


if __name__ == "__main__":
    main()

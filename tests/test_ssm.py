"""Mamba2 SSD: chunked scan vs naive recurrence oracle; decode-step
consistency; full block prefill->decode continuity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import ssm


def _inputs(b=2, l=32, h=3, p=8, n=16, seed=0):
    rng = np.random.default_rng(seed)
    xdt = jnp.asarray(rng.normal(size=(b, l, h, p)) * 0.5, jnp.float32)
    a = jnp.asarray(-np.abs(rng.normal(size=(b, l, h))) * 0.3, jnp.float32)
    bmat = jnp.asarray(rng.normal(size=(b, l, h, n)) * 0.5, jnp.float32)
    cmat = jnp.asarray(rng.normal(size=(b, l, h, n)) * 0.5, jnp.float32)
    return xdt, a, bmat, cmat


@pytest.mark.parametrize("chunk", [4, 8, 16, 32])
def test_chunked_matches_naive(chunk):
    xdt, a, bmat, cmat = _inputs()
    y_ref, s_ref = ssm.ssd_naive_ref(xdt, a, bmat, cmat)
    y, s = ssm.ssd_chunked(xdt, a, bmat, cmat, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref), atol=1e-4)


def test_initial_state_threading():
    xdt, a, bmat, cmat = _inputs(seed=3)
    # run halves with state handoff == full run
    y_full, s_full = ssm.ssd_chunked(xdt, a, bmat, cmat, chunk=8)
    y1, s1 = ssm.ssd_chunked(
        xdt[:, :16], a[:, :16], bmat[:, :16], cmat[:, :16], chunk=8
    )
    y2, s2 = ssm.ssd_chunked(
        xdt[:, 16:], a[:, 16:], bmat[:, 16:], cmat[:, 16:],
        chunk=8, initial_state=s1,
    )
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate([y1, y2], 1)), np.asarray(y_full), atol=1e-4
    )
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full), atol=1e-4)


def test_step_matches_scan():
    xdt, a, bmat, cmat = _inputs(b=1, l=8, seed=5)
    y_ref, s_ref = ssm.ssd_naive_ref(xdt, a, bmat, cmat)
    state = jnp.zeros_like(s_ref)
    ys = []
    for t in range(8):
        # ssd_step takes raw x and dt separately; fold dt=1, x=xdt
        y, state = ssm.ssd_step(
            state,
            xdt[:, t],
            jnp.ones((xdt.shape[0], xdt.shape[2]), jnp.float32),  # dt = 1
            a[:, t],
            bmat[:, t],
            cmat[:, t],
        )
        ys.append(y)
    ys = jnp.stack(ys, 1)
    np.testing.assert_allclose(np.asarray(ys), np.asarray(y_ref), atol=1e-4)
    np.testing.assert_allclose(np.asarray(state), np.asarray(s_ref), atol=1e-4)


def test_mamba_block_prefill_decode_continuity():
    """Full mamba block: prefill a prompt, then decode tokens; must match
    the same sequence run in one pass."""
    cfg = configs.get_config("mamba2-130m", reduced=True)
    from repro.models import lm

    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    b, s, extra = 2, 12, 3
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s + extra), 0, cfg.vocab_size)
    full_logits, _, _ = lm.forward(params, cfg, {"tokens": toks}, mode="train")
    caches = lm.init_caches(cfg, b, s + extra, dtype=jnp.float32)
    last, caches = lm.prefill(params, cfg, {"tokens": toks[:, :s]}, caches)
    np.testing.assert_allclose(
        np.asarray(last), np.asarray(full_logits[:, s - 1]), atol=2e-4
    )
    for i in range(extra):
        pos = jnp.full((b,), s + i, jnp.int32)
        last, caches = lm.decode_step(
            params, cfg, toks[:, s + i : s + i + 1], pos, caches
        )
        np.testing.assert_allclose(
            np.asarray(last), np.asarray(full_logits[:, s + i]), atol=2e-4
        )


def test_decay_monotonicity():
    """More negative a (stronger decay) -> state forgets faster."""
    xdt, a, bmat, cmat = _inputs(seed=7)
    _, s_weak = ssm.ssd_chunked(xdt, a * 0.1, bmat, cmat, chunk=8)
    _, s_strong = ssm.ssd_chunked(xdt, a * 10.0, bmat, cmat, chunk=8)
    # strong decay: final state dominated by recent inputs only; compare
    # sensitivity to the first token by zeroing it
    xdt0 = xdt.at[:, 0].set(0.0)
    _, s_weak0 = ssm.ssd_chunked(xdt0, a * 0.1, bmat, cmat, chunk=8)
    _, s_strong0 = ssm.ssd_chunked(xdt0, a * 10.0, bmat, cmat, chunk=8)
    weak_sens = float(jnp.linalg.norm(s_weak - s_weak0))
    strong_sens = float(jnp.linalg.norm(s_strong - s_strong0))
    assert strong_sens < weak_sens

"""Property tests for the int8 quantization engine (performance path) and
the PTQ calibrator."""

import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # minimal images: deterministic fallback shim
    from _hypothesis_shim import given, settings, st

from repro.core import fixed_point as fxp
from repro.core import quant


@settings(max_examples=50, deadline=None)
@given(
    st.lists(st.floats(-50, 50), min_size=4, max_size=64).filter(
        lambda l: len(l) % 2 == 0
    ),
    st.sampled_from([None, 0]),
)
def test_int8_roundtrip_error_bounded(xs, axis):
    x = jnp.asarray(np.asarray(xs, np.float32).reshape(-1, 2))
    q = quant.quantize_int8(x, axis=axis)
    deq = q.dequantize()
    # error <= scale/2 per element
    if axis is None:
        bound = float(q.scale) / 2 + 1e-6
        assert float(jnp.max(jnp.abs(deq - x))) <= bound
    else:
        scales = np.asarray(q.scale)
        err = np.abs(np.asarray(deq - x))
        assert (err <= scales[:, None] / 2 + 1e-6).all()


@settings(max_examples=50, deadline=None)
@given(st.lists(st.floats(-50, 50), min_size=4, max_size=64))
def test_int8_codes_in_range(xs):
    x = jnp.asarray(np.asarray(xs, np.float32))
    q = quant.quantize_int8(x)
    codes = np.asarray(q.values)
    assert codes.dtype == np.int8
    assert codes.min() >= -128 and codes.max() <= 127


def test_fake_quant_preserves_gradient_flow():
    x = jnp.asarray([[0.5, -1.0], [2.0, 0.1]], jnp.float32)
    g = jax.grad(lambda t: jnp.sum(quant.fake_quant_int8(t) * 3.0))(x)
    np.testing.assert_allclose(np.asarray(g), 3.0)


def test_ptq_calibrator_tracks_range():
    calib = quant.PTQCalibrator(frac_bits=8)
    calib.observe("layer0", jnp.asarray([-3.0, 5.0]))
    calib.observe("layer0", jnp.asarray([-7.5, 2.0]))
    cfgs = calib.configs()
    c = cfgs["layer0"]
    # needs ceil(log2(7.5)) + sign = 4 integer bits
    assert c.int_bits == 4
    assert c.frac_bits == 8


def test_quantize_pytree_fixed_only_touches_floats():
    params = {
        "w": jnp.asarray([[0.123456]], jnp.float32),
        "idx": jnp.asarray([3], jnp.int32),
    }
    out = quant.quantize_pytree_fixed(params, fxp.ap_fixed(8, 4))
    assert out["idx"].dtype == jnp.int32
    assert float(out["w"][0, 0]) != 0.123456  # snapped to the grid
    step = fxp.ap_fixed(8, 4).step
    assert abs(float(out["w"][0, 0]) / step - round(float(out["w"][0, 0]) / step)) < 1e-6


def test_int8_pytree_quantizes_matrices_only():
    params = {
        "w": jnp.ones((4, 4), jnp.float32),
        "b": jnp.ones((4,), jnp.float32),
    }
    out = quant.quantize_pytree_int8(params)
    assert isinstance(out["w"], quant.QTensor)
    assert isinstance(out["b"], jax.Array)  # 1-D left in float


def test_sweep_frac_bits_improves_with_bits():
    """More fractional bits -> better fidelity (paper Figs. 9-11 trend)."""
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=(16, 16)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(4, 16)), jnp.float32)
    exact = x @ w
    errs = []
    for fb in (1, 3, 5, 8):
        cfg = fxp.ap_fixed(6 + fb, 6)
        qw = fxp.quantize(w, cfg)
        errs.append(float(jnp.max(jnp.abs(x @ qw - exact))))
    assert errs == sorted(errs, reverse=True) or errs[-1] < errs[0]

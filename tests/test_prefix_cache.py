"""Prefix-cache page sharing + page-aware preemption test layer.

Three tiers, mirroring how the subsystem can fail:

* **Property-based CacheManager traces** (hypothesis, with the
  deterministic shim fallback): random admit / ensure / register / free /
  CoW / preempt-style op sequences against the raw manager, asserting the
  pool invariants after every operation — refcount conservation, no page
  leaked or double-freed, trash page 0 never allocated or mapped,
  ``pages_in_use`` == distinct live table entries, index/page-key
  consistency.
* **Randomized scheduler stress**: random admission order, prompt
  lengths, generation budgets, and pool sizes — the paged engine with
  prefix caching AND preemption enabled must stay token-identical to the
  dense engine for every request, across GQA / MLA / int8-KV.
* **Targeted scenarios**: prefill-skip savings, copy-on-write on
  full-coverage hits, page retention after the first tenant finishes,
  LRU eviction under pressure, preemption-resume equality + telemetry,
  and the zero-capacity ``page_utilization`` guard.

The tiered-KV-cache (host victim tier) section runs all three shapes
against the two-tier manager: property traces with spill/swap/flush
ops, swap-back token identity across GQA / MLA / int8-KV, and the
warm-prefix tenant-cycling scenario where the tier must convert
spilled prefixes into prefill skips at identical token output.
"""

import dataclasses

import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # pragma: no cover - minimal images use the shim
    from _hypothesis_shim import given, settings, st

from repro import configs
from repro.configs.base import ServeConfig
from repro.core import precision as P
from repro.models import lm
from repro.serve import CacheManager, ServingEngine
from repro.serve import kv_cache as kvc

KEY = jax.random.PRNGKey(11)

KV8 = P.PrecisionPolicy(
    "kv8", (P.Rule("kv_cache", P.int8(per_channel=False)),)
)

PAGE = 8  # page size used throughout; one full page = one shareable unit
PREAMBLE = [7, 1, 3, 9, 2, 8, 4, 6]  # exactly one page of shared prefix


def _params(cfg):
    return lm.init_params(cfg, KEY)


def _serve(layout, **kw):
    base = dict(max_batch=2, max_seq_len=64, kv_layout=layout,
                kv_page_size=PAGE, decode_steps=3)
    base.update(kw)
    return ServeConfig(**base)


def _generate(cfg, params, serve_cfg, prompts, n_new=6, seed=0):
    eng = ServingEngine(cfg, params, serve_cfg, seed=seed)
    uids = [eng.submit(list(p), n_new) for p in prompts]
    res = eng.run()
    return eng, [res[u].generated for u in uids]


# =========================================================================
# Tier 1: property-based CacheManager traces
# =========================================================================


def _trace_manager(pool_pages, page_size, seed, host_pages=0):
    """Drive one random op trace against a raw paged CacheManager with the
    prefix cache on, mimicking the engine's calling discipline (reserve
    check before admit, ensure-with-write-range before decode writes,
    free on finish/preempt), and assert the pool invariants after every
    single operation.  With ``host_pages`` > 0 the victim tier is live:
    evictions spill to the host ring, matches can resolve from either
    tier, and the flush op drains queued spill/swap-in copies against
    real device caches — the invariants then also audit the host tier
    (no chain key served by both tiers, no host slot leaked or
    double-booked)."""
    cfg = configs.get_config("granite-8b", reduced=True)
    max_seq = page_size * 8
    sc = ServeConfig(
        max_batch=4, max_seq_len=max_seq, kv_layout="paged",
        kv_page_size=page_size, kv_pages=pool_pages, kv_prefix_cache=True,
        kv_host_pages=host_pages,
    )
    mgr = CacheManager(cfg, sc)
    caches = mgr.init_device_caches() if host_pages else None
    rng = np.random.default_rng(seed)
    live: dict[int, dict] = {}  # slot -> {"tokens": [...], "pos": int}
    vocab = 5  # tiny vocab makes shared prefixes common
    for _ in range(40):
        op = rng.integers(0, 5)
        if op == 0 and len(live) < sc.max_batch:  # admit (maybe prefix hit)
            slot = next(i for i in range(sc.max_batch) if i not in live)
            n = int(rng.integers(1, max_seq // 2))
            if live and rng.integers(0, 2):  # borrow a resident's prefix
                donor = live[list(live)[0]]["tokens"]
                tokens = donor[: max(1, n // 2)] + list(
                    rng.integers(0, vocab, max(1, n // 2))
                )
            else:
                tokens = list(rng.integers(0, vocab, n))
            reserve = min(len(tokens) + int(rng.integers(1, 16)), max_seq)
            match = mgr.match_prefix(tokens)
            lazy = bool(match) and len(tokens) > 1 and rng.integers(0, 2)
            wf = (
                min(match.tokens, len(tokens) - 1)
                if lazy else len(tokens)
            )
            need = mgr.admission_need(match, reserve, wf)
            if mgr.can_reserve(need):
                mgr.admit(slot, tokens, reserve, match=match,
                          lazy_tail=lazy, write_from=wf)
                live[slot] = {"tokens": list(tokens), "pos": wf,
                              "reserve": reserve}
        elif op == 1 and live:  # decode growth (+ CoW when range overlaps)
            slot = int(rng.choice(list(live)))
            state = live[slot]
            upto = min(state["pos"] + int(rng.integers(1, 4)),
                       state["reserve"])
            if upto > state["pos"]:
                mgr.ensure(slot, upto, write_from=state["pos"])
                # decode "writes" random generated tokens
                grow = max(upto - len(state["tokens"]), 0)
                state["tokens"] += list(rng.integers(0, vocab, grow))
                state["pos"] = upto
        elif op == 2 and live:  # register decode-completed pages
            slot = int(rng.choice(list(live)))
            state = live[slot]
            mgr.register_filled(slot, state["tokens"], state["pos"])
        elif op == 3 and live:  # finish or preempt: both just free
            slot = int(rng.choice(list(live)))
            mgr.free(slot)
            del live[slot]
        else:  # flush pending device work, as a dispatch host_prep would
            if caches is not None:
                caches = mgr.flush_swaps(caches)
                caches = mgr.flush_copies(caches)
            else:  # no tier: device side is exercised by the engine
                # tests; here we only keep the queue bounded
                mgr._pending_copies.clear()
        mgr.check_invariants()
    for slot in list(live):
        mgr.free(slot)
    mgr.check_invariants()
    # every request finished: nothing live, nothing lost
    assert mgr.pages_in_use == 0
    st_ = mgr.stats()
    assert st_.pages_cached + len(mgr._free) == st_.pages_capacity


@settings(max_examples=20, deadline=None)
@given(
    st.integers(6, 24),   # pool pages (incl. trash)
    st.sampled_from([2, 4, 8]),  # page size
    st.integers(0, 10_000),      # trace seed
)
def test_manager_invariants_under_random_traces(pool, page_size, seed):
    _trace_manager(pool, page_size, seed)


@settings(max_examples=10, deadline=None)
@given(
    st.integers(6, 16),          # pool pages (incl. trash) — tight, so
    st.sampled_from([2, 4]),     # evictions (hence spills) are common
    st.integers(0, 10_000),      # trace seed
    st.sampled_from([2, 6, 12]),  # host victim-tier capacity
)
def test_manager_invariants_with_victim_tier(pool, page_size, seed, host):
    """The random-trace property, with the host victim tier live: every
    eviction spills, matches resolve from either tier, flushes move real
    rows, and the two-tier invariants hold after every operation."""
    _trace_manager(pool, page_size, seed, host_pages=host)


def test_invariant_checker_catches_corruption():
    """The checker itself must fail loudly on a corrupted pool (otherwise
    the property test above proves nothing)."""
    cfg = configs.get_config("granite-8b", reduced=True)
    sc = ServeConfig(max_batch=2, max_seq_len=32, kv_layout="paged",
                     kv_page_size=8, kv_pages=8, kv_prefix_cache=True)
    mgr = CacheManager(cfg, sc)
    mgr.admit(0, [1, 2, 3], 10)
    mgr.check_invariants()
    page = mgr._slot_pages[0][0]
    mgr._free.append(page)  # double-book: live AND free
    with pytest.raises(AssertionError, match="free list"):
        mgr.check_invariants()


# =========================================================================
# Tier 2: randomized scheduler stress — paged+prefix+preemption == dense
# =========================================================================


def _stress_case(arch, policy, seed):
    cfg = configs.get_config(arch, reduced=True)
    params = _params(cfg)
    rng = np.random.default_rng(seed)
    preamble = list(rng.integers(0, cfg.vocab_size, PAGE))
    prompts, budgets = [], []
    for _ in range(5):
        kind = rng.integers(0, 3)
        if kind == 0:  # full preamble + payload (page-aligned hit)
            p = preamble + list(
                rng.integers(0, cfg.vocab_size, rng.integers(1, 8))
            )
        elif kind == 1:  # exact repeat (full-coverage hit -> CoW)
            p = list(preamble)
        else:  # unrelated prompt
            p = list(rng.integers(0, cfg.vocab_size, rng.integers(2, 12)))
        prompts.append(p)
        budgets.append(int(rng.integers(2, 10)))
    kv_pages = int(rng.integers(8, 17))  # oversubscribed pool -> preemption

    def run(layout, **kw):
        eng = ServingEngine(
            cfg, params,
            _serve(layout, max_seq_len=32, policy=policy, **kw),
            seed=0,
        )
        uids = [eng.submit(p, b) for p, b in zip(prompts, budgets)]
        res = eng.run()
        assert sorted(res) == sorted(uids), "a request was lost"
        return eng, [res[u].generated for u in uids]

    _, dense = run("dense")
    eng, paged = run("paged", kv_pages=kv_pages, kv_prefix_cache=True,
                     kv_preemption=True)
    assert paged == dense, (
        f"paged+prefix+preemption diverged from dense for {arch}/{policy}"
    )
    eng.cache_mgr.check_invariants()
    assert eng.cache_mgr.stats().pages_in_use == 0


@settings(max_examples=4, deadline=None)
@given(st.integers(0, 10_000))
def test_scheduler_stress_gqa(seed):
    _stress_case("granite-8b", None, seed)


@settings(max_examples=2, deadline=None)
@given(st.integers(0, 10_000))
def test_scheduler_stress_mla(seed):
    _stress_case("minicpm3-4b", None, seed)


@settings(max_examples=2, deadline=None)
@given(st.integers(0, 10_000))
def test_scheduler_stress_int8_kv(seed):
    _stress_case("granite-8b", KV8, seed)


# =========================================================================
# Tier 3: targeted scenarios
# =========================================================================


def test_prefix_skip_saves_prefill_and_matches_dense():
    """Same-preamble admissions on the bit-exact datapath skip the prefill
    dispatch for the shared pages (tail rides the decode scan teacher-
    forced) and still reproduce the dense token streams exactly."""
    cfg = configs.get_config("granite-8b", reduced=True)
    params = _params(cfg)
    prompts = [PREAMBLE + [5, 5], PREAMBLE + [5, 5], PREAMBLE + [2, 4, 1],
               PREAMBLE[:4]]  # last one: no full page -> miss
    _, dense = _generate(cfg, params, _serve("dense"), prompts, n_new=8)
    eng, paged = _generate(
        cfg, params, _serve("paged", kv_prefix_cache=True), prompts, n_new=8
    )
    assert paged == dense
    assert eng._prefix_skip  # float GQA: the skip path is live
    st_ = eng.cache_mgr.stats()
    assert st_.prefix_hits == 2 and st_.prefix_queries == 4
    assert 0 < st_.prefix_hit_rate < 1
    # both hits covered the full 8-token preamble page without recompute
    assert eng.telemetry["prefill_tokens_saved"] == 2 * len(PREAMBLE)
    eng.cache_mgr.check_invariants()


def test_full_coverage_hit_triggers_copy_on_write():
    """An exact-repeat prompt maps every page shared; its first decode
    write lands inside the last shared page and must CoW a private copy —
    the original tenant's stream and the repeat's stream both stay
    identical to dense."""
    cfg = configs.get_config("granite-8b", reduced=True)
    params = _params(cfg)
    prompts = [list(PREAMBLE)] * 3
    _, dense = _generate(cfg, params, _serve("dense"), prompts, n_new=8)
    eng, paged = _generate(
        cfg, params, _serve("paged", kv_prefix_cache=True), prompts, n_new=8
    )
    assert paged == dense
    assert eng.cache_mgr.stats().cow_copies >= 1
    eng.cache_mgr.check_invariants()


def test_retained_pages_hit_after_owner_finishes():
    """The amortization that matters for repeated-prompt physics
    workloads: wave 2 must hit pages whose tenants finished in wave 1
    (refcount-0 retention), not just co-resident sharing."""
    cfg = configs.get_config("granite-8b", reduced=True)
    params = _params(cfg)
    eng = ServingEngine(cfg, params, _serve("paged", kv_prefix_cache=True))
    u1 = [eng.submit(PREAMBLE + [5, 5], 6)]
    r1 = eng.run()
    assert eng.cache_mgr.stats().pages_cached > 0  # retained, not wiped
    u2 = [eng.submit(PREAMBLE + [9, 9, 9], 6)]
    r2 = eng.run()
    st_ = eng.cache_mgr.stats()
    assert st_.prefix_hits >= 1 and eng.telemetry["prefill_tokens_saved"] > 0
    # parity for both waves against a dense engine run the same way
    eng_d = ServingEngine(cfg, params, _serve("dense"))
    ud1 = [eng_d.submit(PREAMBLE + [5, 5], 6)]
    rd1 = eng_d.run()
    ud2 = [eng_d.submit(PREAMBLE + [9, 9, 9], 6)]
    rd2 = eng_d.run()
    assert [r1[u].generated for u in u1] == [rd1[u].generated for u in ud1]
    assert [r2[u].generated for u in u2] == [rd2[u].generated for u in ud2]
    eng.cache_mgr.check_invariants()


def test_lru_eviction_under_pool_pressure():
    """Retained pages are evictable: a stream of distinct prompts through
    a small pool must recycle cached pages (evictions > 0) without ever
    corrupting later requests."""
    cfg = configs.get_config("granite-8b", reduced=True)
    params = _params(cfg)
    sc = _serve("paged", max_seq_len=32, kv_pages=5, kv_prefix_cache=True)
    eng = ServingEngine(cfg, params, sc)
    eng_d = ServingEngine(cfg, params, _serve("dense", max_seq_len=32))
    rng = np.random.default_rng(3)
    for _ in range(6):
        # distinct full-page prompts: each wave retains its prompt page,
        # so the 4-page pool must start evicting LRU retained pages
        prompt = list(rng.integers(0, cfg.vocab_size, 10))
        u = eng.submit(prompt, 6)
        ud = eng_d.submit(prompt, 6)
        res, res_d = eng.run(), eng_d.run()
        assert res[u].generated == res_d[ud].generated
        eng.cache_mgr.check_invariants()
    assert eng.cache_mgr.stats().page_evictions > 0
    assert eng.cache_mgr.stats().pages_in_use == 0


def test_preemption_resumes_token_identical_with_telemetry():
    """A pool that cannot hold two growing residents + preemption: the
    youngest is evicted and resumed, its final stream equals both the
    dense run and the FIFO (never-preempted) paged run, and the
    preemption is recorded on the request and in engine telemetry."""
    cfg = configs.get_config("granite-8b", reduced=True)
    params = _params(cfg)
    prompts = ([7, 8, 9], [1, 2, 3])
    kw = dict(max_seq_len=32, kv_pages=5)  # 4 usable pages; each wants 3
    _, dense = _generate(cfg, params, _serve("dense", max_seq_len=32),
                         prompts, n_new=20)
    fifo_eng, fifo = _generate(cfg, params, _serve("paged", **kw),
                               prompts, n_new=20)
    pre_eng, pre = _generate(
        cfg, params, _serve("paged", kv_preemption=True, **kw),
        prompts, n_new=20,
    )
    assert dense == fifo == pre
    assert fifo_eng.telemetry["preemptions"] == 0
    assert pre_eng.telemetry["preemptions"] >= 1
    preempted = [r for r in pre_eng._finished.values() if r.preemptions]
    assert preempted, "no request recorded its preemption"
    # a re-admission must not double-count the prompt
    assert pre_eng.telemetry["prompts_admitted"] == len(prompts)
    assert all(len(g) == 20 for g in pre)
    pre_eng.cache_mgr.check_invariants()


def test_preemption_live_on_non_bit_exact_datapaths():
    """MLA / int8-KV decode datapaths are not bitwise the prefill
    datapath, so preempt-resume used to be silently gated off.  The
    cache-extend program replays the prompt with prefill math and the
    generated tail with decode math, so these engines now preempt for
    real — and every resumed stream stays dense-exact."""
    for arch, policy in (("minicpm3-4b", None), ("granite-8b", KV8)):
        cfg = configs.get_config(arch, reduced=True)
        params = _params(cfg)
        prompts = ([7, 8, 9], [1, 2, 3])
        kw = dict(max_seq_len=32, kv_pages=5, policy=policy)
        eng, paged = _generate(
            cfg, params, _serve("paged", kv_preemption=True, **kw),
            prompts, n_new=20,
        )
        assert eng._preempt_enabled, f"{arch}/{policy}: preemption gated off"
        assert eng.telemetry["preemptions"] >= 1
        _, dense = _generate(
            cfg, params, _serve("dense", max_seq_len=32, policy=policy),
            prompts, n_new=20,
        )
        assert paged == dense


def test_prefix_cache_inert_for_dense_layout():
    cfg = configs.get_config("granite-8b", reduced=True)
    params = _params(cfg)
    prompts = [PREAMBLE + [5], PREAMBLE + [5]]
    eng, out = _generate(
        cfg, params,
        _serve("dense", kv_prefix_cache=True, kv_preemption=True), prompts,
    )
    assert not eng.cache_mgr.prefix_cache and not eng._preempt_enabled
    st_ = eng.cache_mgr.stats()
    assert st_.prefix_queries == 0 and st_.prefix_hits == 0
    _, ref = _generate(cfg, params, _serve("dense"), prompts)
    assert out == ref


# =========================================================================
# Victim tier (tiered KV cache): spill on eviction, swap-back on hit
# =========================================================================

_TIER_KW = dict(kv_pages=13, kv_prefix_cache=True, kv_preemption=True)


def _tenant_waves(cfg, params, serve_cfg, *, seed=5, waves=6, n_new=6):
    """Cycle four tenants' 3-page preambles through an engine in waves
    of two.  With ``kv_pages=13`` (12 usable) two residents fill the
    pool, so each wave evicts the previous tenants' preamble pages and
    the next visit must either swap them back (tier on) or recompute
    (tier off).  Returns (engine, per-request generated streams)."""
    rng = np.random.default_rng(seed)
    preambles = [
        list(rng.integers(0, cfg.vocab_size, 3 * PAGE)) for _ in range(4)
    ]
    eng = ServingEngine(cfg, params, serve_cfg, seed=0)
    outs = []
    for wave in range(waves):
        uids = []
        for j in range(2):
            tenant = (wave * 2 + j) % 4
            prompt = preambles[tenant] + list(
                rng.integers(0, cfg.vocab_size, 4)
            )
            uids.append(eng.submit(prompt, n_new))
        res = eng.run()
        outs.extend(res[u].generated for u in uids)
        if serve_cfg.kv_layout == "paged":
            eng.cache_mgr.check_invariants()
    return eng, outs


def test_victim_tier_swap_back_restores_prefix_hits():
    """The tier's reason to exist: on a device pool below the warm
    working set, tier-off loses every tenant prefix between visits
    (zero savings) while tier-on swaps them back — majority of spills
    return (swap_hit_rate > 0.5), strictly more prefill tokens saved,
    and the greedy streams stay bit-identical."""
    cfg = configs.get_config("granite-8b", reduced=True)
    params = _params(cfg)
    off, off_out = _tenant_waves(cfg, params, _serve("paged", **_TIER_KW))
    on, on_out = _tenant_waves(
        cfg, params, _serve("paged", kv_host_pages=32, **_TIER_KW)
    )
    assert on_out == off_out  # identical token output, tier on or off
    t_on, t_off = on.telemetry, off.telemetry
    assert t_off["swap_outs"] == 0 and t_off["swap_ins"] == 0
    assert t_on["swap_outs"] > 0 and t_on["swap_ins"] > 0
    assert t_on["swap_ins"] / t_on["swap_outs"] > 0.5
    assert (
        t_on["prefill_tokens_saved"] > t_off["prefill_tokens_saved"]
    ), "the tier failed to convert spilled prefixes into prefill skips"
    assert t_on["host_pages_used"] > 0
    assert t_on["swap_latency_s"] >= 0.0
    on.cache_mgr.check_invariants()


def test_victim_tier_token_identity_across_datapaths():
    """Swap-back must restore byte-identical cache rows on every
    datapath the cache serves: GQA float, MLA latent pools, and the
    int8-KV pools with their per-page scales — each engine's streams
    must equal the dense reference."""
    for arch, policy in (
        ("granite-8b", None), ("minicpm3-4b", None), ("granite-8b", KV8)
    ):
        cfg = configs.get_config(arch, reduced=True)
        params = _params(cfg)
        eng, paged = _tenant_waves(
            cfg, params,
            _serve("paged", policy=policy, kv_host_pages=32, **_TIER_KW),
            waves=4,
        )
        _, dense = _tenant_waves(
            cfg, params, _serve("dense", policy=policy), waves=4
        )
        assert paged == dense, f"swap-back diverged for {arch}/{policy}"
        assert eng.telemetry["swap_ins"] > 0, (
            f"tier never exercised for {arch}/{policy}"
        )
        eng.cache_mgr.check_invariants()


def test_jit_budget_with_victim_tier():
    """All tier movement is host bookkeeping plus eager device copies
    outside jit: with spills and swap-backs live, the program set must
    stay at len(prefill_buckets) prefill + 1 decode."""
    cfg = configs.get_config("granite-8b", reduced=True)
    params = _params(cfg)
    sc = _serve("paged", prefill_buckets=(8, 16, 32), kv_host_pages=32,
                **_TIER_KW)
    eng, _ = _tenant_waves(cfg, params, sc)
    assert eng.telemetry["swap_ins"] > 0  # the tier was live
    assert eng.telemetry["prefill_compiles"] <= 3
    assert eng.telemetry["decode_compiles"] == 1


def test_invariant_checker_catches_two_tier_booking():
    """The two-tier checker must fail loudly on a chain key served by
    both tiers at once (otherwise the tier property test proves
    nothing about the corruption swap-back exists to prevent)."""
    cfg = configs.get_config("granite-8b", reduced=True)
    sc = ServeConfig(max_batch=2, max_seq_len=32, kv_layout="paged",
                     kv_page_size=8, kv_pages=8, kv_prefix_cache=True,
                     kv_host_pages=4)
    mgr = CacheManager(cfg, sc)
    mgr.admit(0, list(range(8)), 16)
    mgr.register_filled(0, list(range(8)), 8)
    page = mgr._slot_pages[0][0]
    key = mgr._page_key[page]
    mgr.check_invariants()
    host = mgr._host_free.pop()  # double-book the key onto the host ring
    mgr._host_index[key] = host
    mgr._host_key[host] = key
    with pytest.raises(AssertionError, match="both tiers"):
        mgr.check_invariants()


# =========================================================================
# Regression guards
# =========================================================================


def test_free_purges_pending_cow_copies():
    """Regression (satellite fix): a tenant finishing between its CoW
    ensure and the next dispatch used to leave the queued copy aimed at
    a freed page — the next tenant to reuse that page got stale rows
    scattered over its freshly prefilled content, because the prefill
    dispatch flushes pending copies after its own writes.  free() must
    purge pending copies whose destination returns to the free list."""
    cfg = configs.get_config("granite-8b", reduced=True)
    sc = ServeConfig(max_batch=2, max_seq_len=32, kv_layout="paged",
                     kv_page_size=8, kv_pages=8, kv_prefix_cache=True)
    mgr = CacheManager(cfg, sc)
    first = list(range(8))
    mgr.admit(0, first, 16)
    mgr.register_filled(0, first, 8)
    match = mgr.match_prefix(first)
    assert match.tokens == 8  # full-coverage hit: write lands in-page
    mgr.admit(1, first, 16, match=match, lazy_tail=True, write_from=7)
    mgr.ensure(1, 9, write_from=7)  # write inside the shared page -> CoW
    assert mgr._pending_copies, "scenario failed to queue a CoW copy"
    mgr.free(1)  # finish before any dispatch flushed the copy
    freed = set(mgr._free)
    assert not any(dst in freed for _, dst in mgr._pending_copies), (
        "pending CoW copy still targets a freed page"
    )
    mgr.check_invariants()


def test_admission_counts_revived_cached_pages():
    """Regression: reviving cached matched pages removes them from the
    evictable pool, so the admission check must charge for them — the old
    accounting over-promised the pool and crashed mid-decode with 'pool
    exhausted' despite the reservation discipline."""
    cfg = configs.get_config("granite-8b", reduced=True)
    sc = ServeConfig(max_batch=3, max_seq_len=40, kv_layout="paged",
                     kv_page_size=8, kv_pages=6, kv_prefix_cache=True)
    mgr = CacheManager(cfg, sc)
    first = list(range(16))
    mgr.admit(0, first, 16)
    mgr.free(0)  # both pages retained on the cached LRU
    mgr.admit(1, [1, 2, 3, 4, 5, 6, 7, 8], 24)  # 1 page live, 3 reserved
    match = mgr.match_prefix(first)
    assert len(match.pages) == 2
    # full-coverage hit: tail needs 1 + CoW headroom 1, plus 2 revivals;
    # the pool (2 free + 2 cached - 2 promised) cannot cover that
    need = mgr.admission_need(match, 24, 15)
    assert need == 4
    assert not mgr.can_reserve(need)
    with pytest.raises(RuntimeError, match="cannot reserve"):
        mgr.admit(2, first, 24, match=match, lazy_tail=True, write_from=15)
    mgr.check_invariants()
    # once the resident's reservation is gone, the same admission fits
    # and both residents can grow to their full reservations
    mgr.free(1)
    match = mgr.match_prefix(first)
    mgr.admit(2, first, 24, match=match, lazy_tail=True, write_from=15)
    mgr.ensure(2, 24, write_from=15)
    mgr.check_invariants()


def test_prefix_plus_preemption_tight_pool_terminates():
    """Regression (livelock): with the prefix cache AND preemption on a
    pool that holds only one resident, a skip-resumed victim used to
    spend its whole residency teacher-forcing its replay tail — emitting
    nothing — and was preempted again every step, forever.  A slot must
    emit at least one token per residency before it is preemptable, so
    every preemption cycle nets progress and the run terminates."""
    cfg = configs.get_config("granite-8b", reduced=True)
    params = _params(cfg)
    rng = np.random.default_rng(0)
    prompts = [list(rng.integers(0, cfg.vocab_size, rng.integers(4, 16)))
               for _ in range(4)]
    kw = dict(max_seq_len=32, kv_pages=5, decode_steps=4)
    # _generate raises KeyError if any request never finishes (livelock)
    eng, paged = _generate(
        cfg, params,
        _serve("paged", kv_prefix_cache=True, kv_preemption=True, **kw),
        prompts, n_new=20,
    )
    assert eng.telemetry["preemptions"] >= 1
    _, dense = _generate(
        cfg, params, _serve("dense", max_seq_len=32), prompts, n_new=20
    )
    # every request ran to its budget or the sequence cap — exactly as
    # far as the dense engine took it — and emitted identical tokens
    assert paged == dense
    assert all(g for g in paged)
    eng.cache_mgr.check_invariants()


def test_chain_key_intern_table_is_garbage_collected():
    """Regression (host-memory leak): every full page ever served interns
    a chain key; without the mark-sweep the table grows monotonically on
    a long-running engine.  After churning many distinct prompts through
    a small pool, the table must stay bounded by the reachable set — and
    retained prefixes must still match afterwards (fresh ids, no reuse)."""
    cfg = configs.get_config("granite-8b", reduced=True)
    sc = ServeConfig(max_batch=2, max_seq_len=32, kv_layout="paged",
                     kv_page_size=4, kv_pages=5, kv_prefix_cache=True)
    mgr = CacheManager(cfg, sc)
    mgr._intern_gc_floor = mgr._intern_gc_at = 8  # frequent sweeps at test scale
    keep = list(range(100, 108))  # 2 full pages we want to keep hitting
    mgr.admit(0, keep, 12)
    mgr.free(0)  # retained on the cached LRU
    for i in range(40):  # 40 distinct 1-page prompts churn the pool
        tokens = [200 + i] * 4
        match = mgr.match_prefix(tokens)
        if not mgr.can_reserve(mgr.admission_need(match, 8, len(tokens))):
            break
        mgr.admit(1, tokens, 8, match=match)
        mgr.free(1)
        mgr.check_invariants()
    assert len(mgr._key_intern) <= max(
        16, 4 * (len(mgr._prefix_index) + 1)
    ), "intern table grew without bound"
    # ids were never reused: the retained prefix still matches exactly
    match = mgr.match_prefix(keep + [1, 2])
    kept_pages = [p for p in mgr._cached if mgr._page_key.get(p)]
    if kept_pages:  # unless churn evicted it (pool pressure)
        assert match.tokens in (0, 8)


def test_preemption_never_outgrows_prefill_buckets():
    """Regression: a preempted request resumes with prompt + generated as
    its new prompt; if that outgrows the largest configured bucket the
    re-prefill would mint an exact-length jit program.  Such slots must
    not be preempted (FIFO fallback) so the program budget holds."""
    cfg = configs.get_config("granite-8b", reduced=True)
    params = _params(cfg)
    prompts = ([7, 8, 9], [1, 2, 3])
    kw = dict(max_seq_len=32, kv_pages=5, prefill_buckets=(4, 8),
              decode_steps=2)
    eng, paged = _generate(
        cfg, params, _serve("paged", kv_preemption=True, **kw),
        prompts, n_new=20,
    )
    assert all(len(g) == 20 for g in paged)
    # early preemptions (short resumes) happen; oversized resumes don't
    assert eng.telemetry["preemptions"] >= 1
    assert all(
        len(s.request.resume_tokens) <= 8
        for s in eng.slots if s.active
    )
    assert eng.telemetry["prefill_compiles"] <= 2
    assert eng.telemetry["decode_compiles"] == 1
    _, dense = _generate(
        cfg, params,
        _serve("dense", max_seq_len=32, prefill_buckets=(4, 8),
               decode_steps=2),
        prompts, n_new=20,
    )
    assert paged == dense


def test_page_utilization_guards_zero_capacity():
    """Regression (satellite): a zero-capacity stats row (max_batch=0
    dense probe, or a hand-rolled row) must report 0.0 utilization, not
    divide by zero."""
    row = kvc.CacheStats(
        layout="dense", kv_bytes=0, page_size=0, pages_in_use=0,
        pages_capacity=0, page_allocs_total=0, pages_in_use_peak=0,
    )
    assert row.page_utilization == 0.0
    assert row.prefix_hit_rate == 0.0
    assert row.as_dict()["page_utilization"] == 0.0
    cfg = configs.get_config("granite-8b", reduced=True)
    mgr = CacheManager(cfg, ServeConfig(max_batch=0, max_seq_len=32))
    assert mgr.stats().page_utilization == 0.0

    from benchmarks.serving_throughput import _page_util_peak

    assert _page_util_peak({}) == 0.0
    assert _page_util_peak({"pages_capacity": 0, "pages_in_use_peak": 3}) == 0.0
    assert _page_util_peak({"pages_capacity": 4, "pages_in_use_peak": 2}) == 0.5


def test_prefix_benchmark_reports_savings():
    """The serving benchmark's prefix-heavy mode must show a real hit
    rate and nonzero prefill-token savings (acceptance criterion)."""
    from benchmarks import serving_throughput as bench

    cfg = bench.physics_scale_lm()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    row = bench._sweep_one(
        "physics_scale", cfg, params, max_batch=2, buckets=(8, 16, 32),
        decode_steps=4, kv_layout="paged", workload="prefix", n_requests=4,
    )
    derived = row.rsplit(",", 1)[1]
    fields = dict(f.split("=") for f in derived.split(";"))
    assert float(fields["prefix_hit_rate"]) > 0
    assert int(fields["prefill_tokens_saved"]) > 0
    assert "preemptions" in fields


def test_jit_budget_with_prefix_and_preemption():
    """Sharing and preemption are host-side block-table operations: the
    jit cache must stay at len(prefill_buckets) prefill + 1 decode
    programs with both knobs on (CI enforces this alongside the per-
    layout budget test)."""
    cfg = configs.get_config("granite-8b", reduced=True)
    params = _params(cfg)
    rng = np.random.default_rng(0)
    prompts = [PREAMBLE + list(rng.integers(0, cfg.vocab_size, n))
               for n in (1, 2, 3, 5, 7)]
    prompts += [list(rng.integers(0, cfg.vocab_size, n)) for n in (3, 9, 13)]
    sc = _serve("paged", max_batch=4, prefill_buckets=(4, 8, 16),
                kv_prefix_cache=True, kv_preemption=True)
    eng, _ = _generate(cfg, params, sc, prompts)
    assert eng.cache_mgr.stats().prefix_hits > 0  # the knobs were live
    assert eng.telemetry["prefill_compiles"] <= len(eng.prefill_buckets)
    assert eng.telemetry["decode_compiles"] == 1

    def programs(fn):
        size = getattr(fn, "_cache_size", None)
        return size() if callable(size) else 1

    assert sum(programs(f) for f in eng._prefill_fn.values()) <= len(
        eng.prefill_buckets
    )
    assert programs(eng._decode_fn) == 1

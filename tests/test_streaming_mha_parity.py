"""Paper-datapath parity: the int8 + LUT-softmax streaming MHA pipeline
(core/streaming_mha, the paper's Sec. IV-A 4-stage design) must track the
float oracle within quantization tolerance across head counts and
causal/windowed masks."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.streaming_mha import (
    quantize_mha_params,
    streaming_mha,
    streaming_mha_float_ref,
)

KEY = jax.random.PRNGKey(42)


def _weights(d, seed=0):
    rng = np.random.default_rng(seed)
    mk = lambda *s: jnp.asarray(rng.normal(size=s) / np.sqrt(s[0]), jnp.float32)
    return mk(d, d), mk(d, d), mk(d, d), mk(d, d)


@pytest.mark.parametrize("n_heads,d_model", [(2, 16), (4, 32), (8, 64)])
@pytest.mark.parametrize(
    "causal,window", [(False, None), (True, None), (True, 4)]
)
def test_int8_lut_pipeline_tracks_float_ref(n_heads, d_model, causal, window):
    wq, wk, wv, wo = _weights(d_model, seed=n_heads)
    x = jax.random.normal(KEY, (2, 12, d_model))
    qparams = quantize_mha_params(wq, wk, wv, wo)
    out_q = streaming_mha(
        x, qparams, n_heads=n_heads, causal=causal, window=window,
        softmax_mode="lut",
    )
    out_f = streaming_mha_float_ref(
        x, wq, wk, wv, wo, n_heads=n_heads, causal=causal, window=window
    )
    assert out_q.shape == out_f.shape == x.shape
    rel = float(jnp.linalg.norm(out_q - out_f) / jnp.linalg.norm(out_f))
    # int8 stage-1/4 GEMMs + LUT softmax: ~1-2% relative error at these
    # widths; 10% is the generous ceiling also used by the AUC benchmarks
    assert rel < 0.1, (n_heads, causal, window, rel)
    assert np.isfinite(np.asarray(out_q)).all()


@pytest.mark.parametrize("n_heads", [2, 4])
def test_lut_vs_safe_softmax_agree_in_pipeline(n_heads):
    """The LUT softmax inside the fused kernel must not drift from the
    exact softmax beyond table-resolution error."""
    d = 8 * n_heads
    wq, wk, wv, wo = _weights(d, seed=7)
    x = jax.random.normal(KEY, (1, 10, d))
    qparams = quantize_mha_params(wq, wk, wv, wo)
    out_lut = streaming_mha(
        x, qparams, n_heads=n_heads, causal=True, softmax_mode="lut"
    )
    out_safe = streaming_mha(
        x, qparams, n_heads=n_heads, causal=True, softmax_mode="safe"
    )
    rel = float(
        jnp.linalg.norm(out_lut - out_safe) / jnp.linalg.norm(out_safe)
    )
    assert rel < 0.05, rel

"""Engine v2 tests: bucketed prefill (selection, masking, parity),
compile-count boundedness, mid-scan slot retirement (eos / max tokens),
greedy determinism vs the v1 one-token path, and queue admission."""

import jax
import numpy as np
import pytest

from repro import configs
from repro.configs.base import ServeConfig
from repro.models import lm
from repro.serve import ServingEngine

KEY = jax.random.PRNGKey(3)


@pytest.fixture(scope="module")
def cfg():
    return configs.get_config("granite-8b", reduced=True)


@pytest.fixture(scope="module")
def params(cfg):
    return lm.init_params(cfg, KEY)


def _v1_cfg(**kw):
    """Engine v1 semantics: exact-length prefill, one token per step."""
    return ServeConfig(prefill_buckets=(), decode_steps=1, **kw)


def _greedy(cfg, params, prompts, serve_cfg, n_new=6):
    eng = ServingEngine(cfg, params, serve_cfg)
    uids = [eng.submit(p, n_new) for p in prompts]
    res = eng.run()
    return eng, [res[u].generated for u in uids]


# ----------------------------------------------------------- bucketing --


def test_bucket_selection(cfg, params):
    eng = ServingEngine(
        cfg, params,
        ServeConfig(max_batch=1, max_seq_len=64, prefill_buckets=(8, 16, 32)),
    )
    assert eng.bucket_for(1) == 8
    assert eng.bucket_for(8) == 8
    assert eng.bucket_for(9) == 16
    assert eng.bucket_for(17) == 32
    assert eng.bucket_for(33) == 33  # beyond the largest bucket: exact


def test_auto_buckets_are_powers_of_two_capped_at_max_seq():
    sc = ServeConfig(max_seq_len=100)
    buckets = sc.resolved_buckets()
    assert buckets[0] == 8 and buckets[-1] == 100
    assert all(b <= 100 for b in buckets)
    assert list(buckets) == sorted(buckets)


def test_exact_fallback_for_unbucketable_families(params):
    # sliding-window rolling buffer: right-padding would evict real tokens
    win_cfg = configs.get_config("starcoder2-7b", reduced=True)
    win_params = lm.init_params(win_cfg, KEY)
    eng = ServingEngine(
        win_cfg, win_params, ServeConfig(max_batch=1, max_seq_len=64)
    )
    assert not eng._bucketable
    assert eng.bucket_for(5) == 5


def test_bucketed_prefill_logits_match_unpadded(cfg, params):
    """The padded program's masked last-token logits must equal the exact
    program's — the bucket length mask in action."""
    import jax.numpy as jnp

    sc = ServeConfig(max_batch=1, max_seq_len=64, prefill_buckets=(16,))
    eng = ServingEngine(cfg, params, sc)
    prompt = [5, 9, 3, 7, 11]
    n = len(prompt)
    caches = lm.init_caches(cfg, 1, 64, dtype=jnp.float32)
    padded = np.zeros((1, 16), np.int32)
    padded[0, :n] = prompt
    lengths = jnp.asarray([n], jnp.int32)
    slots = jnp.asarray([0], jnp.int32)
    lp, _ = eng._prefill_batch(
        eng.params, jnp.asarray(padded), lengths, caches, slots
    )
    le, _ = eng._prefill_batch(
        eng.params, jnp.asarray([prompt], jnp.int32), lengths, caches, slots
    )
    np.testing.assert_allclose(np.asarray(lp), np.asarray(le), atol=1e-5)


def test_bucketed_prefill_masks_cache_tail(cfg, params):
    """Pad positions of the inserted slot cache must be exactly zero."""
    eng = ServingEngine(
        cfg, params,
        ServeConfig(max_batch=2, max_seq_len=64, prefill_buckets=(16,),
                    decode_steps=1),
    )
    prompt = [5, 9, 3]
    eng.submit(prompt, 1)
    eng.step()
    k = np.asarray(eng.caches["layers"]["k"])  # (L, B, Hkv, S, D)
    # decode wrote position len(prompt); everything past it must be zero
    assert np.all(k[:, 0, :, len(prompt) + 1:, :] == 0)
    assert np.any(k[:, 0, :, :len(prompt), :] != 0)


# ------------------------------------------------- compile boundedness --


def test_prefill_compile_count_bounded_by_buckets(cfg, params):
    """>= 8 distinct prompt lengths, <= len(buckets) compiled prefill
    programs, tokens identical (greedy) to the v1 per-length path."""
    rng = np.random.default_rng(0)
    lengths = [3, 4, 5, 6, 7, 8, 9, 10]  # 8 distinct lengths
    prompts = [
        list(rng.integers(0, cfg.vocab_size, n)) for n in lengths
    ]
    buckets = (4, 8, 16)
    eng, got = _greedy(
        cfg, params, prompts,
        ServeConfig(max_batch=4, max_seq_len=64, prefill_buckets=buckets,
                    decode_steps=4),
    )
    v1_eng, ref = _greedy(
        cfg, params, prompts, _v1_cfg(max_batch=4, max_seq_len=64)
    )
    assert got == ref
    assert eng.telemetry["prefill_compiles"] <= len(buckets)
    assert len(eng._prefill_fn) <= len(buckets)
    # the v1 path really does compile per distinct length
    assert v1_eng.telemetry["prefill_compiles"] == len(set(lengths))


# ------------------------------------------------- batched prefill -----


def test_batched_same_bucket_prefill_fills_slots_in_one_dispatch(cfg, params):
    """>= 2 prompts sharing a bucket must ride ONE prefill dispatch."""
    eng = ServingEngine(
        cfg, params,
        ServeConfig(max_batch=4, max_seq_len=64, prefill_buckets=(8,),
                    decode_steps=2),
    )
    for _ in range(4):
        eng.submit([1, 2, 3], 4)
    stats = eng.step()
    assert stats["prefilled"] == 4
    assert eng.telemetry["prefill_dispatches"] == 1
    res = eng.run()
    assert all(len(r.generated) == 4 for r in res.values())


def test_mixed_bucket_step_dispatches_once_per_bucket(cfg, params):
    """One engine step, two buckets -> exactly two prefill dispatches,
    each batching its same-bucket prompts."""
    eng = ServingEngine(
        cfg, params,
        ServeConfig(max_batch=4, max_seq_len=64, prefill_buckets=(4, 16),
                    decode_steps=2),
    )
    eng.submit([1, 2], 3)
    eng.submit([3, 4, 5], 3)  # bucket 4
    eng.submit([1] * 10, 3)
    eng.submit([2] * 12, 3)  # bucket 16
    stats = eng.step()
    assert stats["prefilled"] == 4
    assert eng.telemetry["prefill_dispatches"] == 2
    assert eng.telemetry["prefill_compiles"] == 2


# ------------------------------------------------- mid-scan retirement --


def test_eos_retires_slot_mid_scan(cfg, params):
    prompt = [4, 8, 15, 16]
    _, (free,) = _greedy(
        cfg, params, [prompt],
        ServeConfig(max_batch=1, max_seq_len=64, decode_steps=8),
        n_new=8,
    )
    # pick the 3rd generated token as eos: the scan must stop right there
    eos = free[2]
    eng = ServingEngine(
        cfg, params, ServeConfig(max_batch=1, max_seq_len=64, decode_steps=8)
    )
    uid = eng.submit(prompt, 8, eos_id=eos)
    res = eng.run()
    got = res[uid].generated
    assert got == free[: free.index(eos) + 1]
    assert got[-1] == eos


def test_max_tokens_retires_slot_mid_scan(cfg, params):
    prompt = [1, 2, 3, 4, 5]
    _, (free,) = _greedy(
        cfg, params, [prompt],
        ServeConfig(max_batch=1, max_seq_len=64, decode_steps=8),
        n_new=8,
    )
    _, (capped,) = _greedy(
        cfg, params, [prompt],
        ServeConfig(max_batch=1, max_seq_len=64, decode_steps=8),
        n_new=3,
    )
    assert capped == free[:3]


# ------------------------------------------------------- v1 parity -----


def test_greedy_determinism_vs_v1_path(cfg, params):
    prompts = [[5, 9, 3, 7], [11, 2, 6], [1, 2, 3, 4, 5, 6, 7, 8, 9]]
    _, v2 = _greedy(
        cfg, params, prompts,
        ServeConfig(max_batch=2, max_seq_len=64, decode_steps=4),
    )
    _, v1 = _greedy(cfg, params, prompts, _v1_cfg(max_batch=2, max_seq_len=64))
    assert v2 == v1
    # and stable across runs
    _, v2b = _greedy(
        cfg, params, prompts,
        ServeConfig(max_batch=2, max_seq_len=64, decode_steps=4),
    )
    assert v2 == v2b


# ------------------------------------------------------- admission -----


def test_queue_admission_when_all_slots_full(cfg, params):
    eng = ServingEngine(
        cfg, params,
        ServeConfig(max_batch=2, max_seq_len=64, decode_steps=2),
    )
    uids = [eng.submit([3, 1, 4, 1, 5], 4) for _ in range(5)]
    stats = eng.step()
    assert stats["prefilled"] == 2  # both slots filled
    assert sum(s.active for s in eng.slots) <= 2
    assert len(eng._queue) == 3  # the rest wait
    res = eng.run()
    assert set(res) == set(uids)
    assert all(len(res[u].generated) == 4 for u in uids)


def test_max_prefill_per_step_caps_admission(cfg, params):
    eng = ServingEngine(
        cfg, params,
        ServeConfig(max_batch=4, max_seq_len=64, max_prefill_per_step=1),
    )
    for _ in range(3):
        eng.submit([7, 7, 7], 2)
    stats = eng.step()
    assert stats["prefilled"] == 1
    res = eng.run()
    assert len(res) == 3


# ------------------------------------------------------- telemetry -----


def test_telemetry_counters(cfg, params):
    eng = ServingEngine(
        cfg, params,
        ServeConfig(max_batch=2, max_seq_len=64, decode_steps=4),
    )
    for _ in range(3):
        eng.submit([2, 7, 1, 8], 5)
    eng.run()
    tel = eng.telemetry
    assert tel["tokens_generated"] == 15
    assert tel["prompts_admitted"] == 3
    assert tel["decode_compiles"] == 1
    assert tel["tokens_per_s"] > 0
    assert tel["queue_wait_s_mean"] >= 0
    assert tel["prefill_time_s"] > 0 and tel["decode_time_s"] > 0


def test_temperature_sampling_still_runs(cfg, params):
    eng = ServingEngine(
        cfg, params,
        ServeConfig(max_batch=2, max_seq_len=64, temperature=0.8,
                    decode_steps=4),
    )
    uid = eng.submit([3, 1, 4], 6)
    res = eng.run()
    assert len(res[uid].generated) == 6

"""PR-9 per-request sampling / speculative decoding / n-best tests:
the per-slot token-identity matrix (a greedy row in a mixed-temperature
batch bit-identical to the same request running alone, and a *seeded*
sampled stream identical across batch composition and engine seed —
dense/paged x GQA/MLA/int8-KV), the tie-inclusive dtype-aware top-k
mask, speculative decoding (greedy bitwise-identical to the plain
engine with a self-draft, seeded sampled streams identical too because
the correction token is the target's own position-keyed sample, gating
to cache-extend datapaths), n-best generation-page sharing (fork
telemetry, ``check_invariants`` over shared generation pages, seeded
sibling divergence + determinism), and the replica salt (unseeded
sampled streams diverge across replicas; seeded streams don't)."""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.base import ServeConfig
from repro.core import precision as P
from repro.models import lm
from repro.serve import Engine, ReplicaRouter, SamplingParams
from repro.serve.sampling import _mask_top_k, sample

KEY = jax.random.PRNGKey(3)

KV8 = P.PrecisionPolicy(
    "kv8", (P.Rule("kv_cache", P.int8(per_channel=False)),)
)


@pytest.fixture(scope="module")
def cfg():
    return configs.get_config("granite-8b", reduced=True)


@pytest.fixture(scope="module")
def params(cfg):
    return lm.init_params(cfg, KEY)


def _serve(**kw):
    base = dict(
        max_batch=2, max_seq_len=64, prefill_buckets=(8, 16),
        decode_steps=3, temperature=0.0,
    )
    base.update(kw)
    return ServeConfig(**base)


GREEDY_PROMPT = [5, 9, 3]
SAMPLED_PROMPT = [2, 4, 6, 8, 1]
SAMPLED = SamplingParams(
    max_new_tokens=6, temperature=0.9, top_k=12, top_p=0.95, seed=7
)


# ------------------------------------------------------- top-k masking --


def test_top_k_mask_is_tie_inclusive_and_dtype_aware():
    """Values tied with the k-th largest all survive, and masked slots
    carry the dtype minimum (a hardcoded -1e30 would overflow to -inf
    under float16 and corrupt the masked softmax)."""
    scaled = jnp.asarray([[1.0, 3.0, 3.0, 2.0, 0.0]], jnp.float16)
    out = _mask_top_k(scaled, jnp.asarray([2]))
    lo = jnp.finfo(jnp.float16).min
    np.testing.assert_array_equal(
        out[0], jnp.asarray([lo, 3.0, 3.0, lo, lo], jnp.float16)
    )
    assert jnp.isfinite(out).any() and not jnp.isinf(out).any()
    # top_k <= 0 disables the mask entirely
    np.testing.assert_array_equal(
        _mask_top_k(scaled, jnp.asarray([0])), scaled
    )


def test_scalar_sample_top_k_ties_and_finfo_min():
    """The scalar path (serve-independent callers): top_k=1 with a tied
    maximum keeps *both* argmaxes in support, everything else never
    appears, and float16 logits don't produce inf/nan."""
    logits = jnp.asarray([[0.0, 5.0, 5.0, 1.0]], jnp.float16)
    seen = set()
    for i in range(64):
        tok = sample(
            logits, jax.random.PRNGKey(i), temperature=1.0, top_k=1
        )
        seen.add(int(tok[0]))
    assert seen <= {1, 2}
    assert 1 in seen and 2 in seen  # ties genuinely reachable


# ------------------------------------- per-slot token-identity matrix --


@pytest.mark.parametrize(
    "arch,policy",
    [
        ("granite-8b", None),   # GQA float (bit-exact datapath)
        ("minicpm3-4b", None),  # MLA float
        ("granite-8b", KV8),    # GQA int8 KV
    ],
)
@pytest.mark.parametrize("layout", ["dense", "paged"])
def test_per_slot_sampling_identity_matrix(arch, policy, layout):
    """The tentpole acceptance bar: a mixed-temperature batch emits, per
    request, exactly the stream that request would emit running alone —
    the greedy row is unperturbed by its sampled neighbor, and the
    seeded sampled row is schedule- and engine-seed-independent (its
    PRNG keys depend only on (seed, position))."""
    acfg = configs.get_config(arch, reduced=True)
    aparams = lm.init_params(acfg, KEY)
    sc = _serve(kv_layout=layout, kv_page_size=8, policy=policy)

    solo_g = Engine(acfg, aparams, sc, seed=0)
    hg = solo_g.submit(GREEDY_PROMPT, SamplingParams(max_new_tokens=6))
    want_g = solo_g.generate()[hg.uid].generated

    solo_s = Engine(acfg, aparams, sc, seed=123)  # different engine seed
    hs = solo_s.submit(SAMPLED_PROMPT, SAMPLED)
    want_s = solo_s.generate()[hs.uid].generated

    mixed = Engine(acfg, aparams, sc, seed=77)
    hg2 = mixed.submit(GREEDY_PROMPT, SamplingParams(max_new_tokens=6))
    hs2 = mixed.submit(SAMPLED_PROMPT, SAMPLED)
    fin = mixed.generate()
    assert fin[hg2.uid].generated == want_g
    assert fin[hs2.uid].generated == want_s


def test_unseeded_sampling_is_engine_keyed(cfg, params):
    """Without a per-request seed the stream comes from the engine's
    dispatch key: same engine seed reproduces, different diverges."""
    sp = SamplingParams(max_new_tokens=8, temperature=1.0)

    def run(seed):
        eng = Engine(cfg, params, _serve(), seed=seed)
        h = eng.submit(SAMPLED_PROMPT, sp)
        return eng.generate()[h.uid].generated

    assert run(0) == run(0)
    assert run(0) != run(1)


# ------------------------------------------------- speculative decode --


@pytest.mark.parametrize("layout", ["dense", "paged"])
def test_spec_greedy_bitwise_identical(cfg, params, layout):
    """Greedy speculative output is bitwise the plain engine's on the
    same datapath; the self-draft accepts everything, so the measured
    acceptance rate is the upper bound, not merely > 0."""
    kw = dict(kv_layout=layout, kv_page_size=8)
    base = Engine(cfg, params, _serve(**kw))
    hs = [
        base.submit(list(p), max_new_tokens=6)
        for p in (GREEDY_PROMPT, SAMPLED_PROMPT, [7, 7, 1, 2])
    ]
    fin = base.generate()
    want = [fin[h.uid].generated for h in hs]

    spec = Engine(
        cfg, params, _serve(speculative=True, spec_tokens=4, **kw)
    )
    hs2 = [
        spec.submit(list(p), max_new_tokens=6)
        for p in (GREEDY_PROMPT, SAMPLED_PROMPT, [7, 7, 1, 2])
    ]
    fin2 = spec.generate()
    assert [fin2[h.uid].generated for h in hs2] == want
    tel = spec.telemetry
    assert tel["spec_dispatches"] > 0
    assert tel["draft_tokens_proposed"] > 0
    assert tel["draft_tokens_accepted"] == tel["draft_tokens_proposed"]
    # per-request counters mirror the engine totals
    reqs = [fin2[h.uid] for h in hs2]
    assert sum(r.draft_proposed for r in reqs) == tel["draft_tokens_proposed"]
    assert sum(r.draft_accepted for r in reqs) == tel["draft_tokens_accepted"]


def test_spec_sampled_stream_matches_plain_engine(cfg, params):
    """Under sampling the greedy draft is rarely accepted — but the
    correction token is the target's own position-keyed sample, so a
    seeded request's stream through the speculative engine is exactly
    the plain engine's."""
    plain = Engine(cfg, params, _serve())
    h = plain.submit(SAMPLED_PROMPT, SAMPLED)
    want = plain.generate()[h.uid].generated

    spec = Engine(cfg, params, _serve(speculative=True, spec_tokens=4))
    h2 = spec.submit(SAMPLED_PROMPT, SAMPLED)
    got = spec.generate()[h2.uid].generated
    assert got == want
    assert spec.telemetry["spec_dispatches"] > 0


def test_spec_requires_cache_extend(cfg, params):
    """Speculation rides the extend-window program; without it the
    engine warns once and disables rather than silently degrading."""
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        eng = Engine(
            cfg, params,
            _serve(speculative=True, cache_extend=False),
        )
    assert any(
        issubclass(w.category, RuntimeWarning)
        and "speculative" in str(w.message).lower()
        for w in caught
    )
    assert eng.executor.draft is None
    h = eng.submit(GREEDY_PROMPT, max_new_tokens=4)
    assert len(eng.generate()[h.uid].generated) == 4  # plain decode works


def test_spec_draft_vocab_mismatch_is_an_error(cfg, params):
    import dataclasses

    bad = dataclasses.replace(
        cfg, name="bad-vocab", vocab_size=cfg.vocab_size + 1
    )
    bad_params = lm.init_params(bad, KEY)
    with pytest.raises(ValueError, match="vocab"):
        Engine(
            cfg, params, _serve(speculative=True),
            draft=(bad, bad_params),
        )


# ----------------------------------------------------- n-best fan-out --


def _nbest_cfg(**kw):
    return _serve(
        max_batch=4, kv_layout="paged", kv_page_size=8, **kw
    )


def test_n_best_siblings_share_generation_pages(cfg, params):
    """submit(n=3) admits one prefill plus two forks whose block tables
    map the primary's pages CoW; the pool invariants must hold with
    generation pages shared, and seeded siblings must diverge."""
    eng = Engine(cfg, params, _nbest_cfg())
    hh = eng.submit(
        SAMPLED_PROMPT,
        SamplingParams(max_new_tokens=6, temperature=0.8, seed=11),
        n=3,
    )
    assert isinstance(hh, list) and len(hh) == 3
    fin = eng.generate()
    outs = [fin[h.uid].generated for h in hh]
    assert all(len(o) == 6 for o in outs)
    assert len({tuple(o) for o in outs}) == 3  # seed+i per sibling
    eng.executor.cache_mgr.check_invariants()
    tel = eng.telemetry
    assert tel["forks"] == 2
    assert tel["gen_pages_shared"] > 0
    assert tel["prefill_dispatches"] == 1  # one prefill serves all three


def test_n_best_is_deterministic(cfg, params):
    def run():
        eng = Engine(cfg, params, _nbest_cfg())
        hh = eng.submit(
            SAMPLED_PROMPT,
            SamplingParams(max_new_tokens=6, temperature=0.8, seed=11),
            n=3,
        )
        fin = eng.generate()
        return [fin[h.uid].generated for h in hh]

    assert run() == run()


def test_n_best_falls_back_without_pages(cfg, params):
    """Dense layout cannot refcount pages: siblings admit as plain
    prefills (n results, zero forks) instead of failing."""
    eng = Engine(cfg, params, _serve(max_batch=4))
    hh = eng.submit(
        SAMPLED_PROMPT,
        SamplingParams(max_new_tokens=5, temperature=0.8, seed=11),
        n=2,
    )
    fin = eng.generate()
    assert len({tuple(fin[h.uid].generated) for h in hh}) == 2
    assert eng.telemetry["forks"] == 0


def test_submit_validates_sampling_and_n(cfg, params):
    eng = Engine(cfg, params, _serve())
    with pytest.raises(ValueError):
        eng.submit([1, 2], SamplingParams(temperature=-0.5))
    with pytest.raises(ValueError):
        eng.submit([1, 2], SamplingParams(top_p=0.0))
    with pytest.raises(ValueError):
        eng.submit([1, 2], SamplingParams(top_k=-1))
    with pytest.raises(ValueError):
        eng.submit([1, 2], SamplingParams(seed=-3))
    with pytest.raises(ValueError):
        eng.submit([1, 2], n=0)


# -------------------------------------------------------- replica salt --


def test_replicas_draw_distinct_unseeded_streams(cfg, params):
    """The fold_in replica salt: the same unseeded sampled prompt routed
    to different replicas draws different streams, while the whole fleet
    stays deterministic per (router seed, submission order)."""
    sp = SamplingParams(max_new_tokens=8, temperature=1.0)

    def run():
        router = ReplicaRouter(cfg, params, _serve(replicas=2), seed=5)
        h0 = router.submit(list(SAMPLED_PROMPT), sp)
        h1 = router.submit(list(SAMPLED_PROMPT), sp)
        assert {router.replica_of(h0), router.replica_of(h1)} == {0, 1}
        fin = router.generate()
        return fin[h0.uid].generated, fin[h1.uid].generated

    a = run()
    assert a[0] != a[1]  # replica salt diverges the streams
    assert run() == a    # ...deterministically


def test_seeded_stream_is_replica_independent(cfg, params):
    """A per-request seed pins the stream by (seed, position) — the
    replica salt only touches the engine dispatch key, so the same
    seeded request emits identically on any replica."""
    outs = []
    for replica in (0, 5):
        eng = Engine(cfg, params, _serve(), seed=9, replica=replica)
        h = eng.submit(SAMPLED_PROMPT, SAMPLED)
        outs.append(eng.generate()[h.uid].generated)
    assert outs[0] == outs[1]

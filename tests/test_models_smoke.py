"""Per-arch smoke tests (assignment requirement): instantiate a REDUCED
config of each family and run one forward/train step on CPU, asserting
output shapes and no NaNs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import lm

KEY = jax.random.PRNGKey(0)


def _batch(cfg, b=2, s=16):
    if cfg.frontend == "audio":
        return {
            "frames": jax.random.normal(KEY, (b, s, cfg.frontend_dim)),
            "labels": jnp.zeros((b, s), jnp.int32),
        }, b, s
    if cfg.frontend == "patch":
        return {
            "patches": jax.random.normal(
                KEY, (b, cfg.n_frontend_tokens, cfg.frontend_dim)
            ),
            "tokens": jnp.ones((b, s), jnp.int32),
        }, b, s + cfg.n_frontend_tokens
    return {"tokens": jnp.ones((b, s), jnp.int32)}, b, s


@pytest.mark.parametrize("arch", configs.ARCH_NAMES)
def test_forward_shapes_and_no_nans(arch):
    cfg = configs.get_config(arch, reduced=True)
    params = lm.init_params(cfg, KEY)
    batch, b, total_s = _batch(cfg)
    logits, _, _ = lm.forward(params, cfg, batch, mode="train")
    assert logits.shape == (b, total_s, cfg.padded_vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits)))


@pytest.mark.parametrize("arch", configs.ARCH_NAMES)
def test_one_train_step_no_nans(arch):
    from repro.optim import AdamW
    from repro.train import step as step_lib

    cfg = configs.get_config(arch, reduced=True)
    opt = AdamW(schedule=lambda s: 1e-3)
    state = step_lib.make_train_state(cfg, opt, KEY)
    batch, _, _ = _batch(cfg)
    new_state, metrics = step_lib.train_step(
        state, batch, cfg=cfg, optimizer=opt
    )
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed
    delta = jax.tree.reduce(
        lambda a, x: a + float(jnp.sum(jnp.abs(x))),
        jax.tree.map(lambda a, b: a - b, new_state["params"], state["params"]),
        0.0,
    )
    assert delta > 0


@pytest.mark.parametrize("arch", ["minicpm3-4b", "granite-8b", "zamba2-1.2b"])
def test_full_config_param_counts(arch):
    """Full (non-reduced) configs match their published parameter scale."""
    cfg = configs.get_config(arch)
    n = cfg.param_count_estimate()
    expected = {
        "minicpm3-4b": 4.0e9,
        "granite-8b": 8.0e9,
        "zamba2-1.2b": 1.2e9,
    }[arch]
    assert 0.5 * expected < n < 1.8 * expected, (arch, n)


def test_dbrx_moe_param_count():
    cfg = configs.get_config("dbrx-132b")
    n = cfg.param_count_estimate()
    assert 1.0e11 < n < 1.7e11, n  # ~132B total
    na = cfg.active_param_count_estimate()
    assert 2.0e10 < na < 4.5e10, na  # ~36B active


def test_physics_model_param_counts_near_paper():
    """Paper Table I: engine 3244, btagging 9135, gw 3394 trainable params.
    Head count/ffn width are unspecified in the paper, so we require the
    same order of magnitude."""
    from repro.models import physics
    from repro.models.params import count_params

    for name, target in [("engine_anomaly", 3244), ("btagging", 9135), ("gw", 3394)]:
        cfg = configs.get_config(name)
        n = count_params(physics.param_spec(cfg))
        # head-count / FFN width are under-specified in the paper — require
        # the same order of magnitude rather than an exact match
        assert 0.2 * target < n < 15 * target, (name, n, target)


def test_tp_safe_cross_entropy_equivalent():
    """kernel['tp_loss'] switches the label gather to a one-hot einsum;
    the loss must be bit-comparable to the take_along_axis form."""
    import jax.numpy as jnp

    cfg = configs.get_config("granite-8b", reduced=True)
    params = lm.init_params(cfg, KEY)
    batch = {"tokens": jnp.ones((2, 16), jnp.int32)}
    l1, m1 = lm.loss_fn(params, cfg, batch)
    l2, m2 = lm.loss_fn(params, cfg, batch, kernel={"tp_loss": True})
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)
    np.testing.assert_allclose(
        float(m1["accuracy"]), float(m2["accuracy"]), rtol=1e-6
    )


def test_mla_absorb_decode_equivalent():
    """The beyond-paper absorbed MLA decode (§Perf Cell A) must produce
    the same logits as the paper-faithful materialized form."""
    import jax.numpy as jnp

    cfg = configs.get_config("minicpm3-4b", reduced=True)
    params = lm.init_params(cfg, KEY)
    b, s = 2, 10
    toks = jax.random.randint(KEY, (b, s + 2), 0, cfg.vocab_size)
    outs = {}
    for absorb in (False, True):
        caches = lm.init_caches(cfg, b, s + 2, dtype=jnp.float32)
        kernel = {"mla_absorb": absorb}
        last, caches = lm.prefill(
            params, cfg, {"tokens": toks[:, :s]}, caches, kernel=kernel
        )
        pos = jnp.full((b,), s, jnp.int32)
        last, _ = lm.decode_step(
            params, cfg, toks[:, s : s + 1], pos, caches, kernel=kernel
        )
        outs[absorb] = np.asarray(last)
    np.testing.assert_allclose(outs[False], outs[True], atol=2e-4)

"""KV-cache subsystem tests: dense-vs-paged token parity across GQA / MLA /
int8-KV configs, slot churn (admit/finish/re-admit) with page reuse and no
cross-request leakage, the jit program budget (len(prefill_buckets) prefill
+ 1 decode, both layouts), CacheManager allocation bookkeeping, and
sharding composition for paged pools."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.base import ServeConfig
from repro.core import precision as P
from repro.models import lm
from repro.serve import CacheManager, ServingEngine
from repro.serve import kv_cache as kvc

KEY = jax.random.PRNGKey(11)

KV8 = P.PrecisionPolicy(
    "kv8", (P.Rule("kv_cache", P.int8(per_channel=False)),)
)


def _params(cfg):
    return lm.init_params(cfg, KEY)


def _serve(layout, **kw):
    base = dict(max_batch=2, max_seq_len=64, kv_layout=layout,
                kv_page_size=8, decode_steps=3)
    base.update(kw)
    return ServeConfig(**base)


def _generate(cfg, params, serve_cfg, prompts, n_new=6, seed=0):
    eng = ServingEngine(cfg, params, serve_cfg, seed=seed)
    uids = [eng.submit(list(p), n_new) for p in prompts]
    res = eng.run()
    return eng, [res[u].generated for u in uids]


PROMPTS = ([5, 9, 3, 7], [11, 2, 6], [1, 2, 3, 4, 5, 6, 7, 8, 9], [4, 4])


# ----------------------------------------------------- dense/paged parity --


@pytest.mark.parametrize(
    "arch,policy",
    [
        ("granite-8b", None),         # GQA float
        ("minicpm3-4b", None),        # MLA float
        ("granite-8b", KV8),          # GQA int8 KV (per-page scales)
        ("minicpm3-4b", KV8),         # MLA int8 latent (per-page scales)
        ("granite-8b", "int8_serve"), # full serving performance path
    ],
)
@pytest.mark.parametrize("temperature", [0.0, 0.7])
def test_dense_paged_token_identical(arch, policy, temperature):
    """Same prompts, same sampling key -> identical tokens across layouts.

    The paged gather reconstructs the exact dense logical view (same
    shape, same values at every valid position, masked elsewhere), so
    even stochastic sampling must agree token-for-token."""
    cfg = configs.get_config(arch, reduced=True)
    params = _params(cfg)
    outs = {}
    for layout in ("dense", "paged"):
        eng, outs[layout] = _generate(
            cfg, params,
            _serve(layout, policy=policy, temperature=temperature,
                   max_batch=2),
            PROMPTS,
        )
        assert eng.kv_layout == layout
    assert outs["dense"] == outs["paged"]


def test_unpageable_families_fall_back_to_dense():
    for arch in ("mamba2-130m", "starcoder2-7b", "zamba2-1.2b"):
        cfg = configs.get_config(arch, reduced=True)
        params = _params(cfg)
        eng, out_p = _generate(cfg, params, _serve("paged"), PROMPTS[:2])
        assert eng.kv_layout == "dense"  # silent, documented fallback
        _, out_d = _generate(cfg, params, _serve("dense"), PROMPTS[:2])
        assert out_p == out_d


# --------------------------------------------------------- slot churn -----


def test_slot_churn_reuses_pages_without_leakage():
    """Admit / finish / re-admit waves through a pool smaller than the
    total page demand: freed pages must be recycled, and every wave's
    tokens must match the dense engine run through the identical
    admission sequence (no cross-request contamination from reused
    pages)."""
    cfg = configs.get_config("granite-8b", reduced=True)
    params = _params(cfg)
    waves = [PROMPTS[:2], PROMPTS[2:], ([7, 7, 7], [9, 1, 9, 1])]

    def churn(layout):
        sc = _serve(layout, max_batch=2, max_seq_len=32, kv_page_size=8)
        eng = ServingEngine(cfg, params, sc)
        outs = []
        for wave in waves:
            uids = [eng.submit(list(p), 8) for p in wave]
            res = eng.run()
            outs.append([res[u].generated for u in uids])
        return eng, outs

    eng_d, outs_d = churn("dense")
    eng_p, outs_p = churn("paged")
    assert outs_d == outs_p
    st = eng_p.cache_mgr.stats()
    # all six requests finished -> every page is back in the free list
    assert st.pages_in_use == 0
    # the pool (2 slots x 4 pages) is smaller than total demand
    # (6 requests x >=2 pages each): allocations must have recycled pages
    assert st.page_allocs_total > st.pages_capacity >= st.pages_in_use_peak


def test_oversubscribed_pool_serves_fifo_without_leakage():
    """A pool too small for two concurrently-growing sequences must
    serialize them (admission reserves prompt + generation budget, so
    decode growth never exhausts the pool mid-run) and still match the
    dense engine's output."""
    cfg = configs.get_config("granite-8b", reduced=True)
    params = _params(cfg)
    prompts = (PROMPTS[0], PROMPTS[1], [8, 6, 7], [5, 3, 0, 9])
    # 3 usable pages of 8 tokens; each request reserves
    # ceil((4 + 12) / 8) = 2 pages -> only one sequence resident at a time
    sc_p = _serve("paged", max_batch=2, max_seq_len=32, kv_page_size=8,
                  kv_pages=4)
    eng, out_p = _generate(cfg, params, sc_p, prompts, n_new=12)
    assert eng.kv_layout == "paged"
    assert eng.cache_mgr.stats().pages_in_use_peak <= 3  # never overflows
    _, out_d = _generate(
        cfg, params, _serve("dense", max_batch=2, max_seq_len=32), prompts,
        n_new=12,
    )
    # dense runs both slots concurrently, paged serializes; greedy decode
    # makes per-request tokens independent of co-residency
    assert out_p == out_d


def test_decode_growth_never_exhausts_pool():
    """Regression: short prompts with long generation budgets on a tight
    pool used to crash mid-decode with 'pool exhausted'; reservation at
    admission now serializes them instead."""
    cfg = configs.get_config("granite-8b", reduced=True)
    params = _params(cfg)
    sc = _serve("paged", max_batch=2, max_seq_len=64, kv_page_size=16,
                kv_pages=5)  # 4 usable pages; each request reserves 4
    eng = ServingEngine(cfg, params, sc)
    uids = [eng.submit([7, 8, 9], 56), eng.submit([1, 2, 3], 56)]
    res = eng.run()
    assert sorted(res) == sorted(uids)
    assert all(len(res[u].generated) == 56 for u in uids)


# ------------------------------------------------------ program budget ----


def _program_count(fn):
    size = getattr(fn, "_cache_size", None)
    return size() if callable(size) else 1


@pytest.mark.parametrize(
    "layout,features",
    [
        ("dense", {}),
        ("paged", {}),
        ("paged", {"kv_prefix_cache": True, "kv_preemption": True}),
    ],
)
def test_jit_program_budget(layout, features):
    """len(prefill_buckets) prefill programs + 1 decode program, enforced
    on the actual jit caches — for both layouts, and with the prefix
    cache + preemption knobs on (sharing/preemption are host-side
    block-table operations and must not grow the program set)."""
    cfg = configs.get_config("granite-8b", reduced=True)
    params = _params(cfg)
    rng = np.random.default_rng(0)
    prompts = [
        list(rng.integers(0, cfg.vocab_size, n))
        for n in (3, 4, 5, 6, 9, 11, 13, 15)
    ]
    sc = _serve(layout, max_batch=4, prefill_buckets=(4, 8, 16), **features)
    eng, _ = _generate(cfg, params, sc, prompts)
    assert eng.telemetry["prefill_compiles"] <= len(eng.prefill_buckets)
    assert eng.telemetry["decode_compiles"] == 1
    total_prefill = sum(
        _program_count(fn) for fn in eng._prefill_fn.values()
    )
    assert total_prefill <= len(eng.prefill_buckets)
    assert _program_count(eng._decode_fn) == 1


# ------------------------------------------------------- CacheManager -----


def test_manager_page_bookkeeping():
    cfg = configs.get_config("granite-8b", reduced=True)
    sc = ServeConfig(max_batch=2, max_seq_len=32, kv_layout="paged",
                     kv_page_size=8, kv_pages=8)
    mgr = CacheManager(cfg, sc)
    assert mgr.layout == "paged"
    assert mgr.pages_per_slot == 4 and mgr.pages_capacity == 7
    assert mgr.pages_for(1) == 1 and mgr.pages_for(8) == 1
    assert mgr.pages_for(9) == 2 and mgr.pages_for(32) == 4
    mgr.alloc(0, 9)
    assert mgr.pages_in_use == 2
    assert np.all(mgr._table[0, :2] > 0)  # page 0 is the trash page
    mgr.ensure(0, 17)
    assert mgr.pages_in_use == 3
    mgr.ensure(0, 17)  # idempotent
    assert mgr.pages_in_use == 3
    mgr.alloc(1, 30)
    assert mgr.pages_in_use == 7
    used = set(mgr._table[mgr._table > 0].tolist())
    assert len(used) == 7  # no page is shared between slots
    mgr.free(0)
    assert mgr.pages_in_use == 4
    assert np.all(mgr._table[0] == kvc.TRASH_PAGE)
    mgr.alloc(0, 24)  # freed pages are reusable
    assert mgr.pages_in_use == 7
    with pytest.raises(RuntimeError, match="exhausted"):
        mgr.ensure(0, 32)  # would need a 4th page with the pool drained


def test_manager_validates_page_size_and_pool():
    cfg = configs.get_config("granite-8b", reduced=True)
    with pytest.raises(ValueError, match="divide"):
        CacheManager(cfg, ServeConfig(max_seq_len=100, kv_layout="paged",
                                      kv_page_size=16))
    with pytest.raises(ValueError, match="kv_pages"):
        CacheManager(cfg, ServeConfig(max_seq_len=64, kv_layout="paged",
                                      kv_page_size=16, kv_pages=1))
    with pytest.raises(ValueError, match="kv_layout"):
        CacheManager(cfg, ServeConfig(kv_layout="interleaved"))


def test_engine_rejects_prompt_larger_than_pool():
    cfg = configs.get_config("granite-8b", reduced=True)
    params = _params(cfg)
    sc = _serve("paged", max_batch=2, max_seq_len=32, kv_page_size=8,
                kv_pages=3)  # 2 usable pages = 16 tokens
    eng = ServingEngine(cfg, params, sc)
    with pytest.raises(ValueError, match="pool"):
        eng.submit(list(range(1, 20)), 2)


def test_paged_cache_bytes_shrink_with_pool():
    """The point of paging: device bytes scale with the page pool, not
    with max_batch x max_seq_len."""
    cfg = configs.get_config("granite-8b", reduced=True)
    dense = CacheManager(cfg, ServeConfig(max_batch=8, max_seq_len=512))
    paged = CacheManager(
        cfg, ServeConfig(max_batch=8, max_seq_len=512, kv_layout="paged",
                         kv_page_size=32, kv_pages=33),  # 1/4 of dense
    )
    assert paged.kv_bytes < dense.kv_bytes / 3
    st = paged.stats().as_dict()
    assert st["kv_layout"] == "paged" and st["pages_capacity"] == 32


# ----------------------------------------------------------- specs --------


def test_paged_spec_shapes_gqa_and_mla():
    gqa = configs.get_config("granite-8b", reduced=True)
    spec = kvc.attention_cache_spec(
        gqa, batch=4, max_len=64, quantized=True, layout="paged",
        page_size=16, num_pages=9,
    )
    hd = gqa.resolved_head_dim
    assert spec["k"].shape == (9, gqa.n_kv_heads, 16, hd)
    assert spec["k"].dtype == jnp.int8
    assert spec["k_scale"].shape == (9, gqa.n_kv_heads, 16)
    assert spec["page_table"].shape == (4, 4)
    mla = configs.get_config("minicpm3-4b", reduced=True)
    spec = kvc.attention_cache_spec(
        mla, batch=2, max_len=64, layout="paged", page_size=16, num_pages=9
    )
    width = mla.mla.kv_lora_rank + mla.mla.qk_rope_head_dim
    assert spec["latent"].shape == (9, 16, width)
    assert spec["page_table"].shape == (2, 4)


def test_paged_spec_rejects_unpageable():
    win = configs.get_config("starcoder2-7b", reduced=True)
    with pytest.raises(ValueError, match="sliding-window"):
        kvc.attention_cache_spec(
            win, 2, 64, layout="paged", page_size=16, num_pages=9
        )
    ssm = configs.get_config("mamba2-130m", reduced=True)
    with pytest.raises(ValueError, match="position-addressed"):
        kvc.attention_cache_spec(
            ssm, 2, 64, layout="paged", page_size=16, num_pages=9
        )


def test_paged_roundtrip_write_view():
    """paged_decode_write then paged_decode_view reads back exactly what
    was written at each slot's logical position."""
    cfg = configs.get_config("granite-8b", reduced=True)
    cache = kvc.init_attention_cache(
        cfg, batch=2, max_len=32, dtype=jnp.float32, layout="paged",
        page_size=8, num_pages=9,
    )
    # slot 0 -> pages 1,2; slot 1 -> pages 3,4
    cache["page_table"] = jnp.asarray([[1, 2, 0, 0], [3, 4, 0, 0]], jnp.int32)
    rng = np.random.default_rng(0)
    hd = cfg.resolved_head_dim
    k_new = jnp.asarray(
        rng.normal(size=(2, cfg.n_kv_heads, hd)), jnp.float32
    )
    v_new = jnp.asarray(
        rng.normal(size=(2, cfg.n_kv_heads, hd)), jnp.float32
    )
    positions = jnp.asarray([3, 11], jnp.int32)  # page 0 off 3 / page 1 off 3
    cache = kvc.paged_decode_write(
        cache, {"k": k_new, "v": v_new}, positions
    )
    view = kvc.paged_decode_view(cache)
    assert view["k"].shape == (2, cfg.n_kv_heads, 32, hd)
    np.testing.assert_array_equal(np.asarray(view["k"][0, :, 3]), k_new[0])
    np.testing.assert_array_equal(np.asarray(view["k"][1, :, 11]), k_new[1])
    np.testing.assert_array_equal(np.asarray(view["v"][1, :, 11]), v_new[1])
    # everything else is still zero
    assert float(jnp.abs(view["k"][0, :, 4:]).max()) == 0.0
    assert float(jnp.abs(view["k"][1, :, :11]).max()) == 0.0


# --------------------------------------------------------- sharding -------


def test_cache_shardings_compose_for_both_layouts():
    from repro.distributed.sharding import ShardingRules, cache_shardings
    from repro.launch.mesh import make_mesh

    cfg = configs.get_config("granite-8b", reduced=True)
    mesh = make_mesh((1, 1), ("data", "model"))
    rules = ShardingRules(mesh)
    dense = cache_shardings(rules, cfg, batch=2, max_len=64)
    assert set(dense["layers"]) == {"k", "v"}
    paged = cache_shardings(
        rules, cfg, batch=2, max_len=64, layout="paged",
        page_size=16, num_pages=9,
    )
    assert set(paged["layers"]) == {"k", "v", "page_table"}
    # every leaf got a NamedSharding (composition holds for pool shapes)
    for leaf in jax.tree.leaves(paged):
        assert leaf is not None


def test_model_serve_policy_untouched_by_layout():
    """kv_layout is orthogonal to precision: the engine's resolved plan is
    identical across layouts."""
    cfg = configs.get_config("granite-8b", reduced=True)
    cfg = dataclasses.replace(cfg, precision="int8_serve")
    params = _params(cfg)
    eng_d = ServingEngine(cfg, params, _serve("dense"))
    eng_p = ServingEngine(cfg, params, _serve("paged"))
    assert eng_d.plan == eng_p.plan
    assert eng_d.quant_cache and eng_p.quant_cache

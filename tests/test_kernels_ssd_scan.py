"""SSD chunked-scan Pallas kernel vs the jnp chunked oracle and the naive
step-by-step recurrence, across shape/chunk sweeps."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ssd_scan import ssd, ssd_scan_naive


def _inputs(b=2, l=64, h=3, p=16, n=24, seed=0):
    rng = np.random.default_rng(seed)
    xdt = jnp.asarray(rng.normal(size=(b, l, h, p)) * 0.5, jnp.float32)
    a = jnp.asarray(-np.abs(rng.normal(size=(b, l, h))) * 0.3, jnp.float32)
    bm = jnp.asarray(rng.normal(size=(b, l, h, n)) * 0.5, jnp.float32)
    cm = jnp.asarray(rng.normal(size=(b, l, h, n)) * 0.5, jnp.float32)
    return xdt, a, bm, cm


def _naive(xdt, a, bm, cm):
    b, l, h, p = xdt.shape

    def fold(t):
        return t.transpose(0, 2, 1, 3).reshape(b * h, l, t.shape[-1])

    out = ssd_scan_naive(fold(xdt), fold(a[..., None]), fold(bm), fold(cm))
    return out.reshape(b, h, l, p).transpose(0, 2, 1, 3)


@pytest.mark.parametrize("chunk", [8, 16, 32, 64])
def test_kernel_matches_naive(chunk):
    xdt, a, bm, cm = _inputs(seed=chunk)
    out = ssd(xdt, a, bm, cm, chunk=chunk, use_pallas=True, interpret=True)
    ref = _naive(xdt, a, bm, cm)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


@pytest.mark.parametrize(
    "b,l,h,p,n", [(1, 32, 1, 8, 8), (2, 128, 2, 32, 16), (1, 64, 4, 64, 64)]
)
def test_shape_sweep(b, l, h, p, n):
    xdt, a, bm, cm = _inputs(b, l, h, p, n, seed=l + p)
    out = ssd(xdt, a, bm, cm, chunk=32, use_pallas=True, interpret=True)
    ref = ssd(xdt, a, bm, cm, chunk=32, use_pallas=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


def test_chunk_size_invariance():
    """Chunking is a schedule, not math — outputs must match across
    chunk sizes (same invariant as the attention FIFO depth)."""
    xdt, a, bm, cm = _inputs(seed=9)
    outs = [
        ssd(xdt, a, bm, cm, chunk=c, use_pallas=True, interpret=True)
        for c in (8, 16, 64)
    ]
    for o in outs[1:]:
        np.testing.assert_allclose(
            np.asarray(outs[0]), np.asarray(o), atol=1e-4
        )


def test_strong_decay_truncates_history():
    xdt, a, bm, cm = _inputs(seed=11)
    out1 = ssd(xdt, a * 50.0, bm, cm, chunk=16, use_pallas=True, interpret=True)
    xdt0 = xdt.at[:, 0].set(0.0)
    out2 = ssd(xdt0, a * 50.0, bm, cm, chunk=16, use_pallas=True, interpret=True)
    # with near-total decay, zeroing token 0 must not affect late tokens
    np.testing.assert_allclose(
        np.asarray(out1[:, -1]), np.asarray(out2[:, -1]), atol=1e-5
    )

"""Property-based tests (hypothesis) for ap_fixed semantics — the paper's
numeric foundation."""

import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # minimal images: deterministic fallback shim
    from _hypothesis_shim import given, settings, st

from repro.core import fixed_point as fxp

cfg_strategy = (
    st.tuples(st.integers(4, 18), st.integers(2, 8))
    .filter(lambda t: t[1] <= t[0])
    .map(lambda t: fxp.ap_fixed(t[0], t[1]))
)


@settings(max_examples=60, deadline=None)
@given(cfg_strategy, st.lists(st.floats(-100, 100), min_size=1, max_size=32))
def test_quantize_is_idempotent(cfg, xs):
    x = jnp.asarray(xs, jnp.float32)
    q1 = fxp.quantize(x, cfg)
    q2 = fxp.quantize(q1, cfg)
    np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2))


@settings(max_examples=60, deadline=None)
@given(cfg_strategy, st.lists(st.floats(-100, 100), min_size=1, max_size=32))
def test_quantize_saturates_to_range(cfg, xs):
    x = jnp.asarray(xs, jnp.float32)
    q = np.asarray(fxp.quantize(x, cfg))
    assert (q <= cfg.max_value + 1e-9).all()
    assert (q >= cfg.min_value - 1e-9).all()


@settings(max_examples=60, deadline=None)
@given(cfg_strategy)
def test_in_range_error_bounded_by_half_step(cfg):
    rng = np.random.default_rng(cfg.total_bits)
    x = rng.uniform(cfg.min_value, cfg.max_value, 64).astype(np.float32)
    q = np.asarray(fxp.quantize(jnp.asarray(x), cfg))
    bound = fxp.quantization_error_bound(cfg) + 1e-6
    assert np.max(np.abs(q - x)) <= bound


@settings(max_examples=40, deadline=None)
@given(cfg_strategy)
def test_grid_values_roundtrip_through_ints(cfg):
    """float carrier <-> integer codes must be lossless on the grid."""
    lo = int(cfg.min_value / cfg.step)
    hi = int(cfg.max_value / cfg.step)
    codes = np.arange(lo, hi + 1, max(1, (hi - lo) // 256), dtype=np.int64)
    x = jnp.asarray(codes * cfg.step, jnp.float32)
    back = fxp.from_int(fxp.to_int(x, cfg), cfg)
    np.testing.assert_allclose(np.asarray(back), np.asarray(x), atol=1e-6)


@settings(max_examples=40, deadline=None)
@given(cfg_strategy, st.lists(st.floats(-4, 4), min_size=2, max_size=16))
def test_quantize_is_monotone(cfg, xs):
    x = np.sort(np.asarray(xs, np.float32))
    q = np.asarray(fxp.quantize(jnp.asarray(x), cfg))
    assert (np.diff(q) >= -1e-9).all()


def test_ste_gradient_is_identity_in_range():
    import jax

    cfg = fxp.ap_fixed(12, 6)
    g = jax.grad(lambda x: jnp.sum(fxp.quantize_ste(x, cfg) * 2.0))(
        jnp.asarray([0.5, -1.25, 3.0], jnp.float32)
    )
    np.testing.assert_allclose(np.asarray(g), 2.0)


def test_ste_gradient_zero_outside_range():
    import jax

    cfg = fxp.ap_fixed(8, 4)  # range ~ [-8, 7.94]
    g = jax.grad(lambda x: jnp.sum(fxp.quantize_ste(x, cfg)))(
        jnp.asarray([100.0, -100.0], jnp.float32)
    )
    np.testing.assert_allclose(np.asarray(g), 0.0)


def test_paper_accumulator_width():
    """Paper Sec. VI-A: accumulator has 10 integer bits incl. sign."""
    assert fxp.ACCUM_INT_BITS == 10
    assert fxp.ACCUM_CONFIG.int_bits == 10

"""Deterministic fallback for ``hypothesis`` when it is not installed.

Implements the tiny slice of the API the property tests use — ``given``,
``settings`` and the ``strategies`` namespace (``integers``, ``floats``,
``lists``, ``tuples``, ``sampled_from`` plus ``.map``/``.filter``) — by
drawing a fixed number of seeded pseudo-random examples per test.  Far
weaker than real shrinking-based hypothesis, but it keeps the property
suite meaningful (and green) on minimal images; installing ``hypothesis``
upgrades these tests transparently.
"""

from __future__ import annotations

import functools
import random
from types import SimpleNamespace

_DEFAULT_EXAMPLES = 25
_FILTER_RETRIES = 200


class Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng: random.Random):
        return self._draw(rng)

    def map(self, fn):
        return Strategy(lambda rng: fn(self._draw(rng)))

    def filter(self, pred):
        def draw(rng):
            for _ in range(_FILTER_RETRIES):
                x = self._draw(rng)
                if pred(x):
                    return x
            raise RuntimeError("filter predicate too restrictive for shim")

        return Strategy(draw)


def integers(min_value, max_value):
    return Strategy(lambda rng: rng.randint(min_value, max_value))


def floats(min_value, max_value):
    return Strategy(lambda rng: rng.uniform(min_value, max_value))


def lists(elements: Strategy, *, min_size=0, max_size=10):
    def draw(rng):
        n = rng.randint(min_size, max_size)
        return [elements.example(rng) for _ in range(n)]

    return Strategy(draw)


def tuples(*strategies):
    return Strategy(lambda rng: tuple(s.example(rng) for s in strategies))


def sampled_from(seq):
    seq = list(seq)
    return Strategy(lambda rng: seq[rng.randrange(len(seq))])


st = SimpleNamespace(
    integers=integers,
    floats=floats,
    lists=lists,
    tuples=tuples,
    sampled_from=sampled_from,
)


def settings(max_examples=_DEFAULT_EXAMPLES, deadline=None, **_ignored):
    def deco(fn):
        fn._shim_max_examples = max_examples
        return fn

    return deco


def given(*strategies):
    def deco(fn):
        # NOTE: the wrapper takes no parameters (and deliberately does not
        # expose fn's signature via functools.wraps) so pytest does not
        # mistake the strategy-drawn parameters for fixtures.
        def wrapper():
            n = getattr(wrapper, "_shim_max_examples", None) or getattr(
                fn, "_shim_max_examples", _DEFAULT_EXAMPLES
            )
            # seed on the test name so runs are reproducible
            rng = random.Random(fn.__name__)
            for i in range(n):
                drawn = [s.example(rng) for s in strategies]
                try:
                    fn(*drawn)
                except Exception as e:  # noqa: BLE001 - re-raise with context
                    raise AssertionError(
                        f"{fn.__name__} failed on shim example #{i}: "
                        f"{drawn!r}"
                    ) from e

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper

    return deco

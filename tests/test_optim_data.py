"""Optimizer + schedules + data pipeline tests."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.physics import (
    auc_score,
    btagging_data,
    engine_anomaly_data,
    gw_data,
    multiclass_auc,
)
from repro.data.synthetic import SyntheticLM, SyntheticLMConfig
from repro.optim import AdamW, cosine_schedule, make_schedule, wsd_schedule


def test_adamw_converges_on_quadratic():
    opt = AdamW(schedule=lambda s: 0.1, weight_decay=0.0, grad_clip=None)
    params = {"x": jnp.asarray([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = {"x": 2 * params["x"]}  # d/dx x^2
        params, state, _ = opt.update(grads, state, params)
    assert float(jnp.max(jnp.abs(params["x"]))) < 1e-2


def test_grad_clip_bounds_update():
    opt = AdamW(schedule=lambda s: 1.0, grad_clip=1.0, weight_decay=0.0)
    params = {"x": jnp.zeros(4)}
    state = opt.init(params)
    _, _, metrics = opt.update({"x": jnp.full(4, 1e6)}, state, params)
    assert float(metrics["grad_norm"]) > 1e5  # raw norm reported


def test_schedules_monotone_warmup():
    for fn in (cosine_schedule, wsd_schedule):
        lrs = [
            float(fn(s, base_lr=1.0, warmup_steps=10, total_steps=100))
            for s in range(10)
        ]
        assert lrs == sorted(lrs)


def test_make_schedule_dispatch():
    from repro.configs.base import TrainConfig

    for kind in ("cosine", "wsd", "linear"):
        sched = make_schedule(TrainConfig(schedule=kind))
        assert np.isfinite(float(sched(jnp.asarray(5))))


# --------------------------------------------------------------------- data


def test_synthetic_lm_deterministic_and_restart_exact():
    cfg = SyntheticLMConfig(vocab_size=64, seq_len=8, global_batch=4, seed=3)
    a, b = SyntheticLM(cfg), SyntheticLM(cfg)
    np.testing.assert_array_equal(
        a.batch(5)["tokens"], b.batch(5)["tokens"]
    )
    # sharding partitions the batch deterministically
    full = a.batch(2, 0, 1)["tokens"]
    assert full.shape == (4, 8)
    s0 = a.batch(2, 0, 2)["tokens"]
    s1 = a.batch(2, 1, 2)["tokens"]
    assert s0.shape == (2, 8) and s1.shape == (2, 8)
    assert not np.array_equal(s0, s1)


def test_synthetic_lm_is_learnable_structure():
    cfg = SyntheticLMConfig(vocab_size=32, seq_len=64, global_batch=16, seed=0)
    ds = SyntheticLM(cfg)
    toks = ds.batch(0)["tokens"]
    succ = ds.successor[toks[:, :-1]]
    frac = float(np.mean(succ == toks[:, 1:]))
    assert frac > 0.6  # structure dominates noise


def test_physics_generators_shapes_match_paper_table1():
    x, y = engine_anomaly_data(32)
    assert x.shape == (32, 50, 1) and set(np.unique(y)) <= {0, 1}
    x, y = btagging_data(32)
    assert x.shape == (32, 15, 6) and set(np.unique(y)) <= {0, 1, 2}
    x, y = gw_data(32)
    assert x.shape == (32, 100, 2)


def test_physics_classes_are_separable():
    """A trivial hand-built statistic must already get AUC > 0.6 — the
    datasets carry real signal for the QAT/PTQ benchmarks."""
    x, y = engine_anomaly_data(400, seed=1)
    score = np.abs(np.diff(x[..., 0], axis=1)).max(axis=1)
    assert auc_score(y, score) > 0.6

    x, y = gw_data(400, seed=1)
    score = np.abs(x).max(axis=(1, 2))
    assert auc_score(y, score) > 0.6

    x, y = btagging_data(400, seed=1)
    score = x[..., 3].max(axis=1)
    assert auc_score((y == 2).astype(int), score) > 0.65


def test_auc_score_sane():
    y = np.array([0, 0, 1, 1])
    assert auc_score(y, np.array([0.1, 0.2, 0.8, 0.9])) == 1.0
    assert auc_score(y, np.array([0.9, 0.8, 0.2, 0.1])) == 0.0
    assert abs(auc_score(y, np.array([0.5, 0.5, 0.5, 0.5])) - 0.5) < 1e-9


def test_prefetch_loader():
    from repro.data import PrefetchLoader

    cfg = SyntheticLMConfig(vocab_size=16, seq_len=4, global_batch=2, seed=0)
    ds = SyntheticLM(cfg)
    loader = PrefetchLoader(ds.batch, prefetch=2)
    b0 = next(loader)
    b1 = next(loader)
    loader.close()
    np.testing.assert_array_equal(b0["tokens"], ds.batch(0)["tokens"])
    np.testing.assert_array_equal(b1["tokens"], ds.batch(1)["tokens"])

"""core/streaming_mha (the paper's 4-stage pipeline) + reuse/latency
models + physics models' trainability."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import latency_model as lat
from repro.core import reuse
from repro.core.streaming_mha import (
    quantize_mha_params,
    streaming_mha,
    streaming_mha_float_ref,
)

KEY = jax.random.PRNGKey(0)


def _weights(d=32, heads=4, seed=0):
    rng = np.random.default_rng(seed)
    mk = lambda *s: jnp.asarray(rng.normal(size=s) / np.sqrt(s[0]), jnp.float32)
    return mk(d, d), mk(d, d), mk(d, d), mk(d, d)


def test_streaming_mha_quantized_close_to_float():
    wq, wk, wv, wo = _weights()
    x = jax.random.normal(KEY, (2, 10, 32))
    qparams = quantize_mha_params(wq, wk, wv, wo)
    out_q = streaming_mha(x, qparams, n_heads=4, softmax_mode="lut")
    out_f = streaming_mha_float_ref(x, wq, wk, wv, wo, n_heads=4)
    rel = float(jnp.linalg.norm(out_q - out_f) / jnp.linalg.norm(out_f))
    assert rel < 0.1, rel


def test_streaming_mha_causal():
    wq, wk, wv, wo = _weights(seed=2)
    x = jax.random.normal(KEY, (1, 8, 32))
    qparams = quantize_mha_params(wq, wk, wv, wo)
    full = streaming_mha(x, qparams, n_heads=4, causal=True)
    # causal: output at position t must not depend on later inputs
    x2 = x.at[:, -1].set(99.0)
    full2 = streaming_mha(x2, qparams, n_heads=4, causal=True)
    np.testing.assert_allclose(
        np.asarray(full[:, :-1]), np.asarray(full2[:, :-1]), atol=1e-5
    )


# ------------------------------------------------------ latency model ----


def test_fpga_latency_model_matches_paper_trends():
    """Tables II-IV: II and latency grow with R; latency_us near paper's
    magnitude for the engine model (R1: 257 cycles / 1.9us)."""
    ests = [
        lat.fpga_style_estimate(seq_len=50, d_model=16, n_blocks=3, reuse=r)
        for r in (1, 2, 4)
    ]
    assert ests[0].interval_cycles < ests[1].interval_cycles < ests[2].interval_cycles
    assert ests[0].latency_cycles < ests[1].latency_cycles < ests[2].latency_cycles
    assert 0.5 < ests[0].latency_us < 5.0  # paper: 1.9us


def test_roofline_terms_and_bounds():
    t = lat.roofline(1e12, 1e11, 1e9)
    assert t.dominant in ("compute", "memory", "collective")
    assert t.overlap_s <= t.serial_s
    assert t.compute_s == pytest.approx(1e12 / lat.TPU_V5E.peak_flops)


def test_reuse_resource_estimate_total_macs_invariant():
    """R changes the schedule, never the arithmetic work."""
    base = None
    for r in (1, 2, 4):
        plan = reuse.plan_matmul(256, 1024, 512, reuse_factor=r)
        est = reuse.resource_estimate(plan)
        if base is None:
            base = est.macs
        assert est.macs == base


# ------------------------------------------------------ physics models ---


@pytest.mark.parametrize("name", ["engine_anomaly", "btagging", "gw"])
def test_physics_forward_shapes(name):
    from repro.models import physics

    cfg = configs.get_config(name)
    params = physics.init_params(cfg, KEY)
    x = jax.random.normal(KEY, (4, cfg.seq_len, cfg.input_vec_size))
    logits = physics.forward(params, cfg, x)
    assert logits.shape == (4, cfg.n_classes)
    assert not bool(jnp.any(jnp.isnan(logits)))


def test_engine_model_trains_and_beats_chance():
    """Quick-train the paper's engine model on synthetic FordA-like data;
    AUC must clearly beat chance (paper reports 98% accuracy on real data)."""
    from repro.data.physics import auc_score, engine_anomaly_data
    from repro.models import physics
    from repro.optim import AdamW

    cfg = configs.get_config("engine_anomaly")
    params = physics.init_params(cfg, KEY)
    opt = AdamW(schedule=lambda s: 3e-3, weight_decay=0.0)
    state = opt.init(params)
    x, y = engine_anomaly_data(512, seed=0)
    xb, yb = jnp.asarray(x), jnp.asarray(y)

    @jax.jit
    def step(params, state):
        (l, m), g = jax.value_and_grad(physics.loss_fn, has_aux=True)(
            params, cfg, {"x": xb, "y": yb}
        )
        params, state, _ = opt.update(g, state, params)
        return params, state, l

    for _ in range(60):
        params, state, l = step(params, state)
    xt, yt = engine_anomaly_data(512, seed=99)
    proba = physics.predict_proba(params, cfg, jnp.asarray(xt))
    auc = auc_score(yt, np.asarray(proba[:, 1]))
    assert auc > 0.75, auc

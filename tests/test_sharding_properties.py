"""Property-based tests (hypothesis) for the sharding rules — the
invariants a 1000+-node deployment depends on:

  * every emitted PartitionSpec is valid for its shape (each sharded dim
    divisible by its mesh-axes product),
  * no mesh axis is used twice in one spec,
  * divisibility fallback never crashes, it replicates,
  * batch specs respect explicit shapes (global_batch=1 decode cells).
"""

import jax
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # minimal images: deterministic fallback shim
    from _hypothesis_shim import given, settings, st
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import make_mesh as make_compat_mesh
from repro.configs.base import ParallelismConfig
from repro.distributed.sharding import ShardingRules

LOGICALS = [
    None, "batch", "embed", "heads", "kv_heads", "mlp", "vocab",
    "experts", "layers", "cache_len", "q_lora", "inner", "ssm_heads",
]


@pytest.fixture(scope="module")
def mesh():
    # 8 forced host devices are NOT available under the normal test
    # process (1 device) — use a 1x1 mesh for structural properties and
    # rely on tests/test_distributed.py subprocesses for multi-device.
    return make_compat_mesh((1, 1), ("data", "model"))


@settings(max_examples=100, deadline=None)
@given(
    st.lists(st.sampled_from(LOGICALS), min_size=1, max_size=4),
    st.lists(st.integers(1, 512), min_size=1, max_size=4),
)
def test_spec_is_always_valid(axes, dims):
    mesh = make_compat_mesh((1, 1), ("data", "model"))
    n = min(len(axes), len(dims))
    axes, dims = tuple(axes[:n]), tuple(dims[:n])
    rules = ShardingRules(mesh=mesh, plan=ParallelismConfig())
    spec = rules.spec_for(axes, dims)
    assert isinstance(spec, P)
    assert len(spec) == n
    used = []
    for part, size in zip(spec, dims):
        if part is None:
            continue
        names = part if isinstance(part, tuple) else (part,)
        total = int(np.prod([mesh.shape[a] for a in names]))
        assert size % total == 0
        used.extend(names)
    assert len(used) == len(set(used))  # no axis reused


def test_fallback_records_unshardable_axes():
    """40 experts on a 16-way model axis must replicate AND be recorded
    (the granite-moe §Perf finding)."""
    import os
    import subprocess
    import sys

    code = """
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=16'
import jax
from repro.configs.base import ParallelismConfig
from repro.distributed.sharding import ShardingRules
from repro.launch.mesh import make_mesh as make_compat_mesh
mesh = make_compat_mesh((1, 16), ("data", "model"))
rules = ShardingRules(mesh=mesh, plan=ParallelismConfig())
spec = rules.spec_for(("experts", "embed", "mlp"), (40, 64, 512))
assert spec[0] is None, spec           # 40 % 16 != 0 -> replicated
assert ("experts", 40) in rules.fallbacks
spec2 = rules.spec_for(("experts",), (48,))
assert spec2[0] == "model"             # 48 % 16 == 0 -> sharded
print("OK")
"""
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    out = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True, timeout=300,
        env=dict(os.environ, PYTHONPATH=os.path.join(repo, "src")),
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout


@settings(max_examples=50, deadline=None)
@given(st.integers(1, 4), st.integers(1, 1024))
def test_batch_spec_shape_fallback(ndim, batch):
    mesh = make_compat_mesh((1, 1), ("data", "model"))
    rules = ShardingRules(mesh=mesh, plan=ParallelismConfig())
    shape = (batch,) + (8,) * (ndim - 1)
    spec = rules.batch_spec(ndim, shape=shape)
    assert len(spec) == ndim
    for part, size in zip(spec, shape):
        if part is None:
            continue
        names = part if isinstance(part, tuple) else (part,)
        total = int(np.prod([mesh.shape[a] for a in names]))
        assert size % total == 0

"""PrecisionPolicy API tests: preset round-trips across the zoo, pattern
resolution, legacy-flag lowering, numerical parity of int8/LUT policies on
a physics model, heterogeneous per-layer plans, and the bounded-compile
discipline (a quantized policy adds no jit programs in the engine)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.base import ServeConfig
from repro.core import fixed_point as fxp
from repro.core import precision as P
from repro.core import quant
from repro.models import lm
from repro.models import physics as pmodel
from repro.serve import ServingEngine

KEY = jax.random.PRNGKey(3)

PRESET_NAMES = [
    "float", "int8_serve", "paper_vu13p",
    "ptq_fixed<12,6>", "qat_fixed<12,6>", "qat_fixed<8,4>",
]
ALL_CONFIG_NAMES = configs.ARCH_NAMES + configs.PHYSICS_NAMES


# ---------------------------------------------------------------------------
# Serialization round-trips
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("preset", PRESET_NAMES)
def test_preset_dict_roundtrip(preset):
    policy = P.get_policy(preset)
    assert P.PrecisionPolicy.from_dict(policy.to_dict()) == policy


@pytest.mark.parametrize("preset", PRESET_NAMES)
@pytest.mark.parametrize("name", ALL_CONFIG_NAMES)
def test_preset_resolution_roundtrips_across_zoo(preset, name):
    """Every preset x every zoo/physics config: the resolved plan survives
    a to_dict/from_dict round-trip of its policy unchanged."""
    cfg = configs.get_config(name, reduced=name in configs.ARCH_NAMES)
    policy = P.get_policy(preset)
    plan = policy.resolve(cfg)
    plan2 = P.PrecisionPolicy.from_dict(policy.to_dict()).resolve(cfg)
    assert plan.layers == plan2.layers
    assert plan.kv_cache == plan2.kv_cache
    assert plan.embed == plan2.embed
    assert plan.logits == plan2.logits
    assert plan.accum == plan2.accum
    assert len(plan.layers) == cfg.n_layers


def test_precision_literal_parsing():
    assert P.parse_precision("float") == P.FLOAT
    assert P.parse_precision("int8") == P.int8(per_channel=True)
    assert P.parse_precision("int8_pertensor") == P.int8(per_channel=False)
    assert P.parse_precision("lut8") == P.lut8()
    fp = P.parse_precision("qat_fixed<12,6>")
    assert fp.kind == "fixed" and fp.method == "qat"
    assert fp.fixed_cfg() == fxp.ap_fixed(12, 6)
    with pytest.raises(ValueError):
        P.parse_precision("int4_nonsense")


# ---------------------------------------------------------------------------
# Pattern resolution
# ---------------------------------------------------------------------------


def test_rule_order_last_match_wins():
    policy = P.PrecisionPolicy("t", (
        P.Rule("*.weights", P.fixed(12, 6)),
        P.Rule("layers.0.weights", P.FLOAT),
        P.Rule("layers.1.weights", P.int8()),
    ))
    plan = policy.resolve(3)
    assert plan.layers[0].weights == P.FLOAT
    assert plan.layers[1].weights == P.int8()
    assert plan.layers[2].weights == P.fixed(12, 6)
    assert plan.logits.weights == P.fixed(12, 6)
    assert plan.embed.weights == P.fixed(12, 6)
    assert plan.uniform_layer_quant() is None  # heterogeneous


def test_softmax_and_kv_patterns():
    policy = P.PrecisionPolicy("t", (
        P.Rule("layers.*.attn.softmax", P.lut8()),
        P.Rule("kv_cache", P.int8(per_channel=False)),
    ))
    plan = policy.resolve(2)
    assert plan.lut_softmax and plan.softmax_mode() == "lut"
    assert plan.int8_kv_cache
    assert not plan.int8_weights
    assert not plan.transforms_params


def test_mixed_per_layer_softmax_rejected():
    policy = P.PrecisionPolicy("t", (
        P.Rule("layers.0.attn.softmax", P.lut8()),
    ))
    plan = policy.resolve(2)
    with pytest.raises(ValueError, match="uniform softmax"):
        plan.softmax_mode()


def test_invalid_slot_kind_rejected():
    policy = P.PrecisionPolicy("t", (P.Rule("kv_cache", P.lut8()),))
    with pytest.raises(ValueError, match="not valid"):
        policy.resolve(1)


def test_unknown_policy_name():
    with pytest.raises(KeyError):
        P.get_policy("no_such_policy")


# ---------------------------------------------------------------------------
# Legacy lowering (model-level QuantConfig -> policy, single source of truth)
# ---------------------------------------------------------------------------


def test_serve_config_has_no_legacy_flags():
    """The PR-2 deprecation shim is gone: ServeConfig carries `policy`
    only (the old boolean triple would now be a TypeError)."""
    with pytest.raises(TypeError):
        ServeConfig(int8_kv_cache=True)
    assert ServeConfig().policy is None


def test_quant_config_lowers_through_policy_engine():
    """Model-level QuantConfig flags flow through the one policy engine
    (core.precision.from_quant_config)."""
    qc = quant.QuantConfig(lut_softmax=True, int8_kv_cache=True)
    policy = P.from_quant_config(qc)
    plan = policy.resolve(2)
    assert plan.lut_softmax and plan.int8_kv_cache
    fp = fxp.ap_fixed(12, 6)
    qc2 = quant.QuantConfig(mode="qat", weight_cfg=fp, act_cfg=fp)
    plan2 = P.from_quant_config(qc2).resolve(3)
    assert plan2.uniform_layer_quant() == qc2
    assert P.from_quant_config(quant.QuantConfig()) is None


def test_model_policy_precedence():
    cfg = configs.get_config("granite-8b", reduced=True)
    assert P.model_policy(cfg).name == "float"
    cfg_q = dataclasses.replace(
        cfg, quant=quant.QuantConfig(int8_weights=True)
    )
    assert P.model_policy(cfg_q).name == "legacy_quant_config"
    cfg_p = dataclasses.replace(cfg_q, precision="paper_vu13p")
    assert P.model_policy(cfg_p).name == "paper_vu13p"  # explicit wins


# ---------------------------------------------------------------------------
# Parameter transforms
# ---------------------------------------------------------------------------


def test_apply_plan_matches_quantize_pytree_fixed():
    """The ptq_fixed<W,I> policy grid reproduces the legacy whole-tree
    snap exactly (the Figs. 9-11 sweep protocol)."""
    cfg = configs.get_config("btagging")
    params = pmodel.init_params(cfg, KEY)
    fp = fxp.ap_fixed(12, 6)
    legacy = quant.quantize_pytree_fixed(params, fp)
    plan = P.get_policy("ptq_fixed<12,6>").resolve(cfg.n_layers)
    new = P.apply_plan_to_params(params, plan)
    for a, b in zip(jax.tree.leaves(legacy), jax.tree.leaves(new)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_apply_plan_int8_skips_vectors():
    plan = P.get_policy("int8_serve").resolve(2)
    rng = np.random.default_rng(5)
    w = jnp.asarray(rng.normal(size=(2, 4, 4)), jnp.float32)
    # a bias stacked over layers is (n_layers, d): per-layer it is 1-D and
    # must stay float even though the stacked leaf has ndim >= 2
    b = jnp.asarray(rng.normal(size=(2, 4)), jnp.float32)
    scale = jnp.asarray(rng.normal(size=(4,)), jnp.float32)
    params = {"blocks": {"w": w, "b": b}, "final_norm": {"scale": scale}}
    out = P.apply_plan_to_params(params, plan)
    assert not np.array_equal(np.asarray(out["blocks"]["w"]), np.asarray(w))
    np.testing.assert_array_equal(np.asarray(out["blocks"]["b"]), np.asarray(b))
    np.testing.assert_array_equal(
        np.asarray(out["final_norm"]["scale"]), np.asarray(scale)
    )


def test_apply_plan_heterogeneous_blocks():
    """Per-layer weight rules hit only their layer of the stacked tree."""
    policy = P.PrecisionPolicy("t", (
        P.Rule("layers.0.weights", P.fixed(6, 3)),
    ))
    plan = policy.resolve(2)
    leaf = jnp.asarray(np.random.default_rng(0).normal(size=(2, 8, 8)),
                       jnp.float32)
    out = P.apply_plan_to_params({"blocks": {"w": leaf}}, plan)["blocks"]["w"]
    snapped = fxp.quantize(leaf[0], fxp.ap_fixed(6, 3))
    np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(snapped))
    np.testing.assert_array_equal(np.asarray(out[1]), np.asarray(leaf[1]))


# ---------------------------------------------------------------------------
# Numerical parity on a physics model
# ---------------------------------------------------------------------------


def _physics_setup(name="gw", n=64):
    cfg = configs.get_config(name)
    params = pmodel.init_params(cfg, KEY)
    x = jnp.asarray(
        np.random.default_rng(0).normal(size=(n, cfg.seq_len,
                                              cfg.input_vec_size)),
        jnp.float32,
    )
    return cfg, params, x


@pytest.mark.parametrize("preset", ["int8_serve", "paper_vu13p"])
def test_policy_numerical_parity_physics(preset):
    """int8 / LUT / fixed policies track the float reference closely on
    the paper's GW model (probabilities within a few percent)."""
    cfg, params, x = _physics_setup()
    ref = np.asarray(pmodel.predict_proba(params, cfg, x))
    policy = P.get_policy(preset)
    cfg_q = dataclasses.replace(cfg, precision=policy)
    params_q = P.apply_plan_to_params(params, policy.resolve(cfg.n_layers))
    out = np.asarray(pmodel.predict_proba(params_q, cfg_q, x))
    assert np.isfinite(out).all()
    assert float(np.max(np.abs(out - ref))) < 0.1
    assert float(np.mean(np.abs(out - ref))) < 0.03


def test_norm_lut_rule_engages_staged_datapath():
    """A layers.*.norm lut rule actually switches the norm onto the
    staged 1/sqrt-LUT path (not a silent no-op)."""
    cfg, params, x = _physics_setup("gw", n=8)  # gw uses layernorm
    pol = P.PrecisionPolicy("nl", (P.Rule("layers.*.norm", P.lut8()),))
    assert pol.resolve(cfg.n_layers).norm_mode() == "lut"
    out_f = np.asarray(pmodel.forward(params, cfg, x))
    out_l = np.asarray(pmodel.forward(
        params, dataclasses.replace(cfg, precision=pol), x
    ))
    assert np.isfinite(out_l).all()
    assert not np.array_equal(out_f, out_l)
    assert float(np.max(np.abs(out_f - out_l))) < 0.5  # approximation, not garbage


def test_mixed_per_layer_norm_rejected():
    pol = P.PrecisionPolicy("t", (P.Rule("layers.0.norm", P.lut8()),))
    with pytest.raises(ValueError, match="uniform norm"):
        pol.resolve(2).norm_mode()


def test_engine_rejects_unsupported_kv_bits():
    cfg = configs.get_config("granite-8b", reduced=True)
    params = lm.init_params(cfg, KEY)
    pol = P.PrecisionPolicy("kv4", (P.Rule("kv_cache", P.int8(bits=4)),))
    with pytest.raises(NotImplementedError, match="8-bit"):
        ServingEngine(cfg, params, ServeConfig(max_batch=1, max_seq_len=32,
                                               policy=pol))


def test_heterogeneous_layer_policy_forward():
    """A per-layer mixed fixed/float policy runs through the single scan
    body and actually changes layer-0 numerics only."""
    cfg, params, x = _physics_setup("btagging", n=8)
    coarse0 = P.PrecisionPolicy("h0", (
        P.Rule("layers.0.weights", P.fixed(6, 3, method="qat")),
        P.Rule("layers.0.activations", P.fixed(6, 3)),
    ))
    uniform = P.PrecisionPolicy("hu", (
        P.Rule("layers.*.weights", P.fixed(6, 3, method="qat")),
        P.Rule("layers.*.activations", P.fixed(6, 3)),
    ))
    out_f = np.asarray(pmodel.forward(params, cfg, x))
    out_h = np.asarray(pmodel.forward(
        params, dataclasses.replace(cfg, precision=coarse0), x
    ))
    out_u = np.asarray(pmodel.forward(
        params, dataclasses.replace(cfg, precision=uniform), x
    ))
    assert not np.array_equal(out_f, out_h)  # layer-0 quant bites
    assert not np.array_equal(out_h, out_u)  # but layers 1-2 stay float


def test_heterogeneous_fake_quant_matches_scalar_path():
    """The traced (array-step) fake-quant matches fixed_point.quantize_ste
    whenever the step is active, and is the identity when step == 0."""
    cfg6 = fxp.ap_fixed(6, 3)
    x = jnp.asarray(np.random.default_rng(1).normal(size=(64,)) * 5,
                    jnp.float32)
    arr = P._fake_quant_traced(
        x, jnp.float32(cfg6.step), jnp.float32(cfg6.min_value),
        jnp.float32(cfg6.max_value),
    )
    np.testing.assert_allclose(
        np.asarray(arr), np.asarray(fxp.quantize_ste(x, cfg6)), rtol=1e-6
    )
    ident = P._fake_quant_traced(
        x, jnp.float32(0.0), jnp.float32(0.0), jnp.float32(0.0)
    )
    np.testing.assert_array_equal(np.asarray(ident), np.asarray(x))


# ---------------------------------------------------------------------------
# Serving engine integration
# ---------------------------------------------------------------------------


def _run_engine(cfg, params, serve_cfg, prompts=((5, 9, 3), (1, 2, 3, 4))):
    eng = ServingEngine(cfg, params, serve_cfg)
    uids = [eng.submit(list(p), 5) for p in prompts]
    res = eng.run()
    return eng, [res[u].generated for u in uids]


def test_engine_policy_adds_no_jit_programs():
    """Bounded-compile discipline: an int8/LUT policy leaves the prefill/
    decode compile counters exactly where the float baseline has them."""
    cfg = configs.get_config("granite-8b", reduced=True)
    params = lm.init_params(cfg, KEY)
    base = ServeConfig(max_batch=2, max_seq_len=64)
    eng_f, _ = _run_engine(cfg, params, base)
    eng_q, _ = _run_engine(
        cfg, params, dataclasses.replace(base, policy="int8_serve")
    )
    assert (
        eng_q.telemetry["prefill_compiles"]
        == eng_f.telemetry["prefill_compiles"]
    )
    assert (
        eng_q.telemetry["decode_compiles"]
        == eng_f.telemetry["decode_compiles"]
    )


def test_engine_explicit_rules_match_int8_serve_preset():
    """An explicitly constructed rule set equivalent to the old boolean
    triple generates exactly what the int8_serve preset generates."""
    cfg = configs.get_config("granite-8b", reduced=True)
    params = lm.init_params(cfg, KEY)
    explicit = P.PrecisionPolicy("explicit", (
        P.Rule("*.weights", P.int8(per_channel=True)),
        P.Rule("kv_cache", P.int8(per_channel=False)),
        P.Rule("*.softmax", P.lut8()),
    ))
    _, a = _run_engine(
        cfg, params, ServeConfig(max_batch=2, max_seq_len=64, policy=explicit)
    )
    _, b = _run_engine(
        cfg, params,
        ServeConfig(max_batch=2, max_seq_len=64, policy="int8_serve"),
    )
    assert a == b


def test_engine_auto_policy_from_model_config():
    """With no serving policy, the model's own precision governs (the
    engine no longer ignores cfg-level quantization selections)."""
    cfg = configs.get_config("granite-8b", reduced=True)
    params = lm.init_params(cfg, KEY)
    cfg_q = dataclasses.replace(cfg, precision="int8_serve")
    eng, outs = _run_engine(cfg_q, params, ServeConfig(max_batch=2,
                                                       max_seq_len=64))
    assert eng.plan.int8_kv_cache and eng.quant_cache
    assert all(len(o) == 5 for o in outs)


def test_engine_qat_policy_runs_and_matches_compile_budget():
    """A fixed-point (runtime fake-quant) serving policy also keeps the
    compiled-program set at the float baseline."""
    cfg = configs.get_config("granite-8b", reduced=True)
    params = lm.init_params(cfg, KEY)
    base = ServeConfig(max_batch=1, max_seq_len=64)
    eng_f, _ = _run_engine(cfg, params, base, prompts=((5, 9, 3),))
    eng_q, outs = _run_engine(
        cfg, params, dataclasses.replace(base, policy="qat_fixed<12,6>"),
        prompts=((5, 9, 3),),
    )
    assert len(outs[0]) == 5
    assert (
        eng_q.telemetry["prefill_compiles"]
        == eng_f.telemetry["prefill_compiles"]
    )

"""Fault tolerance: straggler detection (fake clock), failure-inject ->
restart-resume bit-exactness, preemption checkpointing, heartbeat."""

import os

import numpy as np
import pytest

from repro import configs
from repro.configs.base import TrainConfig
from repro.data.synthetic import SyntheticLM, SyntheticLMConfig
from repro.train import (
    FailureInjector,
    Heartbeat,
    PreemptionHandler,
    StepTimer,
    run_training,
)


def test_step_timer_flags_stragglers():
    t = {"now": 0.0}

    def clock():
        return t["now"]

    timer = StepTimer(window=16, threshold=2.0, clock=clock)
    for i in range(10):
        timer.start()
        t["now"] += 1.0
        _, s = timer.stop()
        assert not s
    timer.start()
    t["now"] += 5.0  # 5x median
    _, s = timer.stop()
    assert s
    assert len(timer.straggler_events) == 1


def test_heartbeat_liveness(tmp_path):
    path = os.path.join(str(tmp_path), "hb")
    hb = Heartbeat(path, interval=0.05).start()
    import time

    time.sleep(0.15)
    assert Heartbeat.is_alive(path, timeout=5.0)
    hb.stop()
    assert not os.path.exists(path)


def test_preemption_checkpoint_and_resume(tmp_path):
    cfg = configs.get_config("granite-8b", reduced=True)
    ds = SyntheticLM(
        SyntheticLMConfig(vocab_size=cfg.vocab_size, seq_len=16, global_batch=4)
    )
    tc = TrainConfig(total_steps=30, warmup_steps=2, checkpoint_every=10,
                     learning_rate=1e-3)
    pre = PreemptionHandler(signals=())
    # stop after ~12 steps by injecting the stop flag via a wrapper batch_fn
    calls = {"n": 0}

    def batch_fn(step, shard, n_shards):
        calls["n"] += 1
        if calls["n"] == 13:
            pre.request_stop()
        return ds.batch(step, shard, n_shards)

    res1 = run_training(
        cfg, tc, batch_fn, workdir=str(tmp_path), preemption=pre, log_every=1
    )
    assert res1.stopped_early
    stopped_at = res1.final_step

    # resume: must start from the preemption checkpoint, not step 0
    res2 = run_training(
        cfg, tc, ds.batch, workdir=str(tmp_path), log_every=1
    )
    assert not res2.stopped_early
    assert res2.final_step == 30
    first_logged = res2.metrics_history[0]["step"]
    assert first_logged > stopped_at


def test_failure_injection_then_restart_is_exact(tmp_path):
    """Train 20 steps straight vs (fail at 12 -> restart): identical loss."""
    cfg = configs.get_config("granite-8b", reduced=True)
    ds = SyntheticLM(
        SyntheticLMConfig(vocab_size=cfg.vocab_size, seq_len=16, global_batch=4)
    )
    tc = TrainConfig(total_steps=20, warmup_steps=2, checkpoint_every=5,
                     learning_rate=1e-3)

    w1 = os.path.join(str(tmp_path), "straight")
    res_a = run_training(cfg, tc, ds.batch, workdir=w1, log_every=1)

    w2 = os.path.join(str(tmp_path), "faulty")
    with pytest.raises(RuntimeError, match="injected failure"):
        run_training(
            cfg, tc, ds.batch, workdir=w2, log_every=1,
            failure_injector=FailureInjector(fail_at_step=12),
        )
    res_b = run_training(cfg, tc, ds.batch, workdir=w2, log_every=1)

    la = {m["step"]: m["loss"] for m in res_a.metrics_history}
    lb = {m["step"]: m["loss"] for m in res_b.metrics_history}
    # compare the final step's loss: restart path must reproduce it
    assert 20 in la and 20 in lb
    np.testing.assert_allclose(la[20], lb[20], rtol=1e-5)

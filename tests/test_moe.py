"""MoE dispatch: capacity semantics, combine-weight correctness vs a dense
(all-experts) oracle, EP-shape invariants, aux losses."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import layers, moe

KEY = jax.random.PRNGKey(0)


def _setup(t=32, d=16, e=4, k=2, cf=4.0):
    cfg = configs.get_config("dbrx-132b", reduced=True)
    cfg = dataclasses.replace(
        cfg,
        d_model=d,
        moe=dataclasses.replace(
            cfg.moe, n_experts=e, top_k=k, d_expert=24, capacity_factor=cf
        ),
    )
    spec = moe.moe_spec(cfg)
    from repro.models.params import init_params

    params = init_params(spec, KEY)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, t // 2, d))
    return cfg, params, x


def _dense_oracle(cfg, params, x):
    """Compute every expert for every token, combine with router weights."""
    from repro.core import softmax as sm

    b, s, d = x.shape
    flat = x.reshape(-1, d)
    logits = layers.dense(params["router"], flat.astype(jnp.float32), None)
    probs = sm.softmax_paper_exact(logits, axis=-1)
    gate, ids = jax.lax.top_k(probs, cfg.moe.top_k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
    outs = []
    for e in range(cfg.moe.n_experts):
        up = flat @ params["w_up"][e]
        g = flat @ params["w_gate"][e]
        h = jax.nn.silu(g) * up
        outs.append(h @ params["w_down"][e])
    outs = jnp.stack(outs, 1)  # (t, e, d)
    mask = jax.nn.one_hot(ids, cfg.moe.n_experts) * gate[..., None]
    w = mask.sum(1)  # (t, e)
    return jnp.einsum("te,ted->td", w, outs).reshape(b, s, d)


def test_dispatch_matches_dense_oracle_with_ample_capacity():
    cfg, params, x = _setup(cf=8.0)  # no drops
    out, aux = moe.moe_apply(params, cfg, x)
    ref = _dense_oracle(cfg, params, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)
    assert float(aux["moe_dropped_frac"]) == 0.0


def test_capacity_drops_tokens():
    cfg, params, x = _setup(cf=0.25)
    out, aux = moe.moe_apply(params, cfg, x)
    assert float(aux["moe_dropped_frac"]) > 0.0
    assert np.isfinite(np.asarray(out)).all()


def test_aux_losses_finite_and_scaled():
    cfg, params, x = _setup()
    _, aux = moe.moe_apply(params, cfg, x)
    assert float(aux["moe_aux_loss"]) > 0.0
    assert float(aux["moe_z_loss"]) >= 0.0


def test_gradients_flow_through_dispatch():
    cfg, params, x = _setup(cf=8.0)

    def loss(p):
        out, _ = moe.moe_apply(p, cfg, x)
        return jnp.sum(out ** 2)

    g = jax.grad(loss)(params)
    gn = float(
        jax.tree.reduce(lambda a, t: a + jnp.sum(jnp.abs(t)), g, 0.0)
    )
    assert np.isfinite(gn) and gn > 0


def test_balanced_router_has_low_aux_loss():
    """Uniform routing minimizes the load-balance loss (≈ aux_weight)."""
    cfg, params, x = _setup(t=256, e=4, k=1, cf=8.0)
    # force uniform logits -> balanced
    params = dict(params)
    params["router"] = {"kernel": jnp.zeros_like(params["router"]["kernel"])}
    _, aux = moe.moe_apply(params, cfg, x)
    # E * sum(me*ce) == 1 when perfectly balanced -> loss == weight
    assert abs(float(aux["moe_aux_loss"]) - cfg.moe.router_aux_weight) < 0.01

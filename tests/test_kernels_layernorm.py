"""Staged LayerNorm kernel vs oracle across modes (LN/RMS x exact/LUT)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.layernorm import layernorm_paper, rmsnorm
from repro.kernels.layernorm import layernorm, layernorm_ref


def _rand(shape, seed=0, scale=3.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape) * scale, jnp.float32)


@pytest.mark.parametrize("rows,feat", [(64, 96), (128, 48), (1, 16), (33, 200)])
@pytest.mark.parametrize("use_lut", [False, True])
@pytest.mark.parametrize("rms", [False, True])
def test_kernel_matches_ref(rows, feat, use_lut, rms):
    x = _rand((rows, feat), rows + feat)
    g = _rand((feat,), 1, 1.0)
    b = _rand((feat,), 2, 1.0)
    out = layernorm(
        x, g, b, use_lut=use_lut, rms=rms, use_pallas=True, interpret=True
    )
    ref = layernorm_ref(
        x, g.reshape(1, -1), b.reshape(1, -1), use_lut=use_lut, rms=rms
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


def test_five_stage_decomposition_is_layernorm():
    """Paper Sec. IV-C staged dataflow == standard layernorm."""
    x = _rand((32, 64), 5)
    g = _rand((64,), 6, 1.0)
    b = _rand((64,), 7, 1.0)
    ours = layernorm_paper(x, g, b, eps=1e-5)
    mean = jnp.mean(x, -1, keepdims=True)
    var = jnp.mean((x - mean) ** 2, -1, keepdims=True)
    std = jnp.sqrt(var + 1e-5)
    ref = (x - mean) / std * g + b
    np.testing.assert_allclose(np.asarray(ours), np.asarray(ref), atol=1e-5)


def test_lut_rsqrt_accuracy():
    x = _rand((64, 128), 8)
    g = jnp.ones((128,), jnp.float32)
    b = jnp.zeros((128,), jnp.float32)
    exact = layernorm(x, g, b, use_lut=False, use_pallas=False)
    approx = layernorm(x, g, b, use_lut=True, use_pallas=False)
    rel = float(
        jnp.linalg.norm(exact - approx) / jnp.linalg.norm(exact)
    )
    assert rel < 0.02, rel


def test_rmsnorm_zero_mean_equivalence():
    """For zero-mean rows, LN(x; eps=0) == RMSNorm(x; eps=0)."""
    x = _rand((16, 32), 9)
    x = x - jnp.mean(x, -1, keepdims=True)
    g = _rand((32,), 10, 1.0)
    ln = layernorm_paper(x, g, jnp.zeros((32,)), eps=0.0)
    rms = rmsnorm(x, g, eps=0.0)
    np.testing.assert_allclose(np.asarray(ln), np.asarray(rms), atol=1e-5)

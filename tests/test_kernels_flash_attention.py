"""Fused streaming-attention kernel: sweep shapes x masks x softmax modes
against the jnp oracle; GQA broadcasting; block-size invariance."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import attention_ref, mha


def _qkv(b, hq, hkv, lq, lkv, d, seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(b, hq, lq, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, hkv, lkv, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, hkv, lkv, d)), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("mode", ["safe", "lut"])
@pytest.mark.parametrize("causal", [False, True])
@pytest.mark.parametrize("window", [None, 24])
def test_kernel_vs_ref(mode, causal, window):
    q, k, v = _qkv(2, 4, 2, 100, 100, 32, seed=1)
    out = mha(q, k, v, causal=causal, window=window, mode=mode,
              use_pallas=True, interpret=True, block_q=32, block_kv=32)
    ref = mha(q, k, v, causal=causal, window=window, mode=mode,
              use_pallas=False)
    # lut mode: the kernel accumulates the denominator blockwise while the
    # ref sums whole rows — float ordering can flip a nearest-table-entry
    # at bin boundaries (observed <=4e-5 on ~0.01% of elements)
    atol = 1e-4 if mode == "lut" else 2e-5
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=atol)


@pytest.mark.parametrize(
    "b,hq,hkv,l,d", [(1, 1, 1, 16, 8), (2, 8, 1, 64, 16), (1, 6, 3, 128, 64)]
)
def test_shape_sweep(b, hq, hkv, l, d):
    q, k, v = _qkv(b, hq, hkv, l, l, d, seed=l + d)
    out = mha(q, k, v, causal=True, use_pallas=True, interpret=True,
              block_q=32, block_kv=32)
    ref = mha(q, k, v, causal=True, use_pallas=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_block_size_invariance():
    """Streaming block decomposition must not change the math — the FIFO
    depth never changes the answer on the FPGA either."""
    q, k, v = _qkv(1, 2, 2, 96, 96, 32, seed=3)
    outs = [
        mha(q, k, v, causal=True, use_pallas=True, interpret=True,
            block_q=bq, block_kv=bkv)
        for bq, bkv in [(96, 96), (32, 96), (96, 32), (16, 48)]
    ]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o), atol=2e-5)


def test_cross_attention_lengths():
    q, k, v = _qkv(2, 4, 4, 32, 80, 16, seed=5)
    out = mha(q, k, v, use_pallas=True, interpret=True, block_q=16, block_kv=16)
    ref = mha(q, k, v, use_pallas=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_bf16_inputs():
    q, k, v = _qkv(1, 2, 2, 64, 64, 32, seed=7)
    qb, kb, vb = (t.astype(jnp.bfloat16) for t in (q, k, v))
    out = mha(qb, kb, vb, causal=True, use_pallas=True, interpret=True,
              block_q=32, block_kv=32)
    ref = mha(q, k, v, causal=True, use_pallas=False)
    assert out.dtype == jnp.bfloat16
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref), atol=0.03
    )


def test_lut_mode_matches_safe_in_bounded_domain():
    """With scores inside the exp-LUT domain, the paper's no-max-sub
    softmax must agree closely with the safe variant."""
    q, k, v = _qkv(1, 2, 2, 48, 48, 16, seed=9)
    q = q * 0.3  # keep scores well inside [-8, 8]
    lut_out = mha(q, k, v, causal=True, mode="lut", use_pallas=True,
                  interpret=True, block_q=16, block_kv=16)
    safe = mha(q, k, v, causal=True, mode="safe", use_pallas=False)
    assert float(jnp.max(jnp.abs(lut_out - safe))) < 0.02

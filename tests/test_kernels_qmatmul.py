"""Per-kernel validation: int8 GEMM vs pure-jnp oracle, shape/dtype sweep,
reuse-factor invariance (paper Sec. VI-B: R changes schedule, not math)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quant, reuse
from repro.kernels.qmatmul import qmatmul, qmatmul_pallas, qmatmul_ref


def _rand(shape, seed=0, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape) * scale, jnp.float32)


@pytest.mark.parametrize(
    "m,k,n",
    [(8, 16, 8), (100, 300, 200), (128, 128, 128), (7, 130, 65), (1, 256, 512)],
)
def test_qmatmul_matches_ref(m, k, n):
    x, w = _rand((m, k), 1), _rand((k, n), 2)
    out = qmatmul(x, w, use_pallas=True, interpret=True)
    xq = quant.quantize_int8(x, axis=0)
    wq = quant.quantize_int8(w, axis=1)
    ref = qmatmul_ref(
        xq.values, wq.values, xq.scale.reshape(-1, 1), wq.scale.reshape(1, -1)
    )
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("r", [1, 2, 4, 8])
def test_reuse_factor_does_not_change_result(r):
    x, w = _rand((64, 512), 3), _rand((512, 96), 4)
    base = qmatmul(x, w, reuse_factor=1, interpret=True)
    out = qmatmul(x, w, reuse_factor=r, interpret=True)
    np.testing.assert_allclose(out, base, rtol=1e-5, atol=1e-4)


def test_reuse_factor_shrinks_vmem_and_grows_interval():
    """The paper's R trade-off: resources (VMEM) down, interval up."""
    plans = [
        reuse.plan_matmul(512, 2048, 512, reuse_factor=r) for r in (1, 2, 4, 8)
    ]
    vmem = [p.vmem_bytes for p in plans]
    intervals = [p.interval for p in plans]
    assert intervals == sorted(intervals)
    assert intervals[-1] > intervals[0]
    assert vmem[-1] < vmem[0]


def test_quantization_error_bounded():
    x, w = _rand((32, 64), 5), _rand((64, 32), 6)
    out = qmatmul(x, w, interpret=True)
    exact = x @ w
    rel = float(
        jnp.linalg.norm(out - exact) / jnp.linalg.norm(exact)
    )
    assert rel < 0.05, rel


def test_int8_accumulation_is_int32_exact():
    """Products of int8 codes must accumulate exactly (no float rounding):
    compare kernel int32 path against numpy int64."""
    rng = np.random.default_rng(7)
    xq = rng.integers(-127, 128, (64, 256), dtype=np.int8)
    wq = rng.integers(-127, 128, (256, 64), dtype=np.int8)
    ones_m = jnp.ones((64, 1), jnp.float32)
    ones_n = jnp.ones((1, 64), jnp.float32)
    out = qmatmul_pallas(
        jnp.asarray(xq), jnp.asarray(wq), ones_m, ones_n,
        block_m=64, block_n=64, block_k=128, interpret=True,
    )
    expected = xq.astype(np.int64) @ wq.astype(np.int64)
    np.testing.assert_array_equal(np.asarray(out), expected.astype(np.float32))

"""LUT softmax kernel vs oracle; the paper's k-vs-k^2 restructure; LUT
error bounds vs exact softmax."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import lut
from repro.core import softmax as sm
from repro.kernels.lut_softmax import (
    lut_softmax,
    lut_softmax_ref,
    softmax_exact_ref,
)


def _rand(shape, seed=0, scale=2.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape) * scale, jnp.float32)


@pytest.mark.parametrize(
    "shape", [(64, 64), (2, 4, 48, 48), (1, 16), (128, 100), (3, 5, 7)]
)
def test_kernel_matches_ref_bit_exact(shape):
    x = _rand(shape, seed=hash(shape) % 97)
    out = lut_softmax(x, use_pallas=True, interpret=True)
    ref = lut_softmax_ref(x)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_lut_close_to_exact_softmax():
    x = _rand((64, 64), 1)
    approx = lut_softmax_ref(x)
    exact = softmax_exact_ref(x)
    assert float(jnp.max(jnp.abs(approx - exact))) < 0.02


def test_rows_sum_to_one():
    x = _rand((32, 50), 2)
    out = lut_softmax(x, use_pallas=True, interpret=True)
    np.testing.assert_allclose(np.asarray(jnp.sum(out, -1)), 1.0, atol=0.02)


def test_restructured_matches_legacy_hls4ml():
    """Paper Sec. IV-B: S_i = e^{z_i} (sum e^{z_j})^{-1} must equal the
    original S_i = (sum e^{z_j - z_i})^{-1} exactly (in exact arithmetic)."""
    x = _rand((8, 24), 3, scale=1.0)
    new = sm.softmax_paper_exact(x)
    legacy = sm.softmax_legacy_hls4ml(x)
    np.testing.assert_allclose(np.asarray(new), np.asarray(legacy), rtol=2e-5)


def test_op_count_k_vs_k_squared():
    """The whole point of the restructure: k exponentials, not k^2."""
    assert sm.op_count(128, "paper") == 128
    assert sm.op_count(128, "legacy") == 128 * 128


def test_saturation_matches_ap_fixed_semantics():
    """Out-of-domain scores saturate (AP_SAT) instead of overflowing."""
    x = jnp.asarray([[100.0, 0.0, -100.0]], jnp.float32)
    out = lut_softmax(x, use_pallas=True, interpret=True)
    assert np.isfinite(np.asarray(out)).all()
    assert float(out[0, 0]) > float(out[0, 1]) > float(out[0, 2])


def test_lut_interpolation_error_bound():
    err = lut.lut_max_abs_error(lut.EXP_SPEC, np.exp)
    # nearest-entry error <= step/2 * max|f'| on the domain
    bound = lut.EXP_SPEC.step / 2 * np.exp(lut.EXP_SPEC.hi) * 1.01
    assert err <= bound

"""HLO cost-model parser: shapes/bytes, dot flops, while trip counts —
validated against hand-built HLO snippets and a real compiled module."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.roofline import hlo_parser as hp

SNIPPET = """
HloModule test

%cond (arg: (s32[], f32[4,8])) -> pred[] {
  %arg = (s32[], f32[4,8]) parameter(0)
  %i = s32[] get-tuple-element(%arg), index=0
  %n = s32[] constant(6)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

%body (arg: (s32[], f32[4,8])) -> (s32[], f32[4,8]) {
  %arg = (s32[], f32[4,8]) parameter(0)
  %i = s32[] get-tuple-element(%arg), index=0
  %x = f32[4,8] get-tuple-element(%arg), index=1
  %w = f32[8,8]{1,0} constant({...})
  %dot.1 = f32[4,8]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %one = s32[] constant(1)
  %ip = s32[] add(%i, %one)
  ROOT %out = (s32[], f32[4,8]) tuple(%ip, %dot.1)
}

ENTRY %main (p0: f32[4,8]) -> f32[4,8] {
  %p0 = f32[4,8]{1,0} parameter(0)
  %zero = s32[] constant(0)
  %t = (s32[], f32[4,8]) tuple(%zero, %p0)
  %loop = (s32[], f32[4,8]) while(%t), condition=%cond, body=%body
  ROOT %res = f32[4,8]{1,0} get-tuple-element(%loop), index=1
}
"""


def test_type_bytes():
    assert hp.type_bytes("f32[4,8]{1,0}") == 4 * 8 * 4
    assert hp.type_bytes("bf16[2,3]") == 12
    assert hp.type_bytes("(f32[2], s8[4])") == 12
    assert hp.type_bytes("pred[]") == 1
    assert hp.type_bytes("s32[]") == 4


def test_snippet_trip_count_and_flops():
    mc = hp.total_cost(SNIPPET, default_trip_count=1)
    # dot: 2 * (4*8) * 8 = 512 flops per iteration, 6 iterations
    assert mc.flops == 512 * 6
    assert mc.trip_counts == [6]


def test_real_module_flops_accuracy():
    """Scan over 5 layers of (32x64)@(64x64): parser must recover the
    analytic flop count exactly (fwd only)."""

    def f(params, x):
        def body(h, w):
            return h @ w, ()
        h, _ = jax.lax.scan(body, x, params)
        return h

    params = jax.ShapeDtypeStruct((5, 64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    compiled = jax.jit(f).lower(params, x).compile()
    mc = hp.total_cost(compiled.as_text(), default_trip_count=5)
    expected = 5 * 2 * 32 * 64 * 64
    assert abs(mc.flops - expected) / expected < 0.01
    assert 5 in mc.trip_counts


def test_xla_cost_analysis_undercounts_scans():
    """Regression documentation: XLA's own cost_analysis counts while
    bodies once — the reason hlo_parser exists."""

    def f(params, x):
        def body(h, w):
            return h @ w, ()
        h, _ = jax.lax.scan(body, x, params)
        return h

    params = jax.ShapeDtypeStruct((8, 64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    compiled = jax.jit(f).lower(params, x).compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, list):  # older JAX: one properties dict per device
        ca = ca[0]
    xla_flops = ca.get("flops", 0)
    ours = hp.total_cost(compiled.as_text(), default_trip_count=8).flops
    assert ours > 4 * xla_flops  # XLA misses the ~8x trip multiplier


def test_attnvol_tagging_separates_attention():
    from repro import configs
    from repro.models import lm

    cfg = configs.get_config("granite-8b", reduced=True)
    params = lm.abstract_params(cfg)
    batch = {"tokens": jax.ShapeDtypeStruct((2, 32), jnp.int32)}

    def fwd(p, b):
        logits, _, _ = lm.forward(p, cfg, b, mode="train")
        return logits

    compiled = jax.jit(fwd).lower(params, batch).compile()
    mc = hp.total_cost(compiled.as_text(), default_trip_count=cfg.n_layers)
    assert mc.attn_flops > 0
    assert mc.attn_flops < mc.flops
    # attention score volume: 2 dots of 2*b*h*l^2*hd flops each per layer
    b, l, h, hd = 2, 32, cfg.n_heads, cfg.resolved_head_dim
    expected = cfg.n_layers * 2 * (2 * b * h * l * l * hd)
    assert 0.5 * expected < mc.attn_flops < 2.0 * expected

"""Cache-extending prefill program: the parity matrix that retires the
silent quantized-datapath fallbacks.

Chunked prefill, prefix-cache prefill-skip, and preemption-resume used
to be gated on ``caps.bit_exact`` (float GQA + safe softmax only) and
silently fell back to whole-prompt prefill / FIFO blocking everywhere
else.  The cache-extend program replays any token window through the
*prefill* math against the populated cache, so every datapath — GQA,
MLA latent caches, int8-KV, LUT softmax — now runs all three features
for real.  This layer pins that down:

* **Chunked parity**: chunk-admitted engines token-identical to the
  whole-prompt engine on every datapath x {dense, paged}, with the
  extend program actually dispatching.
* **Prefix-skip parity**: a full-coverage hit skips the prompt-prefill
  dispatch entirely (0 new prefill dispatches) and still reproduces the
  cold stream, on MLA and int8-KV.
* **Preemption-resume parity**: an oversubscribed pool preempts and
  resumes on MLA / int8-KV with streams equal to the dense engine.
* **Program budget**: with every knob on, the jit cache holds exactly
  ``len(prefill_buckets)`` prefill + 1 decode + 1 extend programs
  (CI runs this next to the other budget tests).
* **Loud fallbacks** (satellites): engines that *cannot* honor a
  requested feature say so — ``disabled_features`` telemetry + a
  one-shot RuntimeWarning — and ``prefill_chunk`` on a non-bucketable
  (SSM / rolling-window) engine is a configuration error, not a silent
  no-op.
"""

import warnings

import jax
import numpy as np
import pytest

from repro import configs
from repro.configs.base import ServeConfig
from repro.core import precision as P
from repro.models import lm
from repro.serve import Engine

KEY = jax.random.PRNGKey(7)

KV8 = P.PrecisionPolicy(
    "kv8", (P.Rule("kv_cache", P.int8(per_channel=False)),)
)
LUT_KV8 = P.PrecisionPolicy("lut_kv8", (
    P.Rule("layers.*.attn.softmax", P.lut8()),
    P.Rule("kv_cache", P.int8(per_channel=False)),
))

# the datapaths the old gate silently excluded
DATAPATHS = [
    ("minicpm3-4b", None),      # MLA latent cache
    ("granite-8b", KV8),        # int8-KV GQA
    ("granite-8b", LUT_KV8),    # LUT softmax + int8-KV
]


def _setup(arch):
    cfg = configs.get_config(arch, reduced=True)
    return cfg, lm.init_params(cfg, KEY)


def _serve(policy, **kw):
    base = dict(max_batch=2, max_seq_len=64, decode_steps=3,
                prefill_buckets=(8, 16, 32), policy=policy)
    base.update(kw)
    return ServeConfig(**base)


def _gen(cfg, params, sc, prompts, n_new=6):
    eng = Engine(cfg, params, sc)
    handles = [eng.submit(list(p), max_new_tokens=n_new) for p in prompts]
    res = eng.generate()
    return eng, [res[h.uid].generated for h in handles]


def _prompts(cfg, lengths=(20, 11), seed=0):
    rng = np.random.default_rng(seed)
    return [list(rng.integers(1, cfg.vocab_size - 1, n)) for n in lengths]


@pytest.mark.parametrize("arch,policy", DATAPATHS,
                         ids=["mla", "int8kv", "lut_int8kv"])
@pytest.mark.parametrize("layout", ["dense", "paged"])
def test_chunked_prefill_parity(arch, policy, layout):
    cfg, params = _setup(arch)
    prompts = _prompts(cfg)
    kw = dict(kv_layout=layout, kv_page_size=8) if layout == "paged" else {}
    _, ref = _gen(cfg, params, _serve(policy, cache_extend=False, **kw),
                  prompts)
    eng, got = _gen(cfg, params, _serve(policy, prefill_chunk=8, **kw),
                    prompts)
    assert got == ref, f"{arch}/{layout}: chunked diverged from whole-prompt"
    assert eng.scheduler.chunk_len == 8
    assert eng.telemetry["extend_dispatches"] >= 1
    assert eng.telemetry["disabled_features"] == []
    # the 20-token prompt never minted its whole-length bucket program
    assert 32 not in eng.executor._prefill_fn


@pytest.mark.parametrize("arch,policy", DATAPATHS,
                         ids=["mla", "int8kv", "lut_int8kv"])
def test_prefix_skip_full_coverage_skips_prefill(arch, policy):
    """A warm full-coverage hit must not dispatch prompt prefill at all:
    the shared pages are mapped and only the unwritten tail rides the
    extend program — with the stream identical to the cold run."""
    cfg, params = _setup(arch)
    prompt = _prompts(cfg, lengths=(16,))[0]  # exactly 2 full pages
    sc = _serve(policy, kv_layout="paged", kv_page_size=8,
                kv_prefix_cache=True)
    eng = Engine(cfg, params, sc)
    h1 = eng.submit(list(prompt), max_new_tokens=6)
    cold = eng.generate()[h1.uid].generated
    dispatches_before = eng.telemetry["prefill_dispatches"]
    h2 = eng.submit(list(prompt), max_new_tokens=6)
    warm = eng.generate()[h2.uid].generated
    assert warm == cold, f"{arch}: prefix-skip resume diverged"
    assert eng.telemetry["prefill_dispatches"] == dispatches_before, (
        f"{arch}: full-coverage hit still dispatched prompt prefill"
    )
    assert eng.telemetry["prefill_tokens_saved"] > 0
    eng.executor.cache_mgr.check_invariants()


@pytest.mark.parametrize("arch,policy", DATAPATHS,
                         ids=["mla", "int8kv", "lut_int8kv"])
def test_preemption_resume_parity(arch, policy):
    """An oversubscribed pool preempts the youngest resident; its resume
    replays the prompt with prefill math and the generated tail with
    decode math — byte-for-byte the cache the dense engine would hold."""
    cfg, params = _setup(arch)
    prompts = ([7, 8, 9], [1, 2, 3])
    kw = dict(max_seq_len=32,)
    _, dense = _gen(cfg, params, _serve(policy, **kw), prompts, n_new=20)
    eng, paged = _gen(
        cfg, params,
        _serve(policy, kv_layout="paged", kv_page_size=8, kv_pages=5,
               kv_preemption=True, **kw),
        prompts, n_new=20,
    )
    assert paged == dense, f"{arch}: preempt-resume diverged from dense"
    assert eng.telemetry["preemptions"] >= 1, f"{arch}: pool never preempted"
    assert eng.telemetry["disabled_features"] == []
    eng.executor.cache_mgr.check_invariants()


def test_jit_program_budget_with_extend():
    """The one new program is ONE program: with chunking, prefix sharing
    and preemption all on, on an extend datapath (MLA), the jit caches
    hold exactly len(prefill_buckets) prefill + 1 decode + 1 extend."""
    cfg, params = _setup("minicpm3-4b")
    rng = np.random.default_rng(0)
    prompts = [list(rng.integers(1, cfg.vocab_size - 1, n))
               for n in (3, 5, 9, 12, 17, 23, 30)]
    prompts += [list(prompts[0])]  # one full-coverage repeat
    sc = ServeConfig(
        max_batch=4, max_seq_len=64, decode_steps=3,
        prefill_buckets=(8, 16), prefill_chunk=8,
        kv_layout="paged", kv_page_size=8,
        kv_prefix_cache=True, kv_preemption=True,
    )
    eng, streams = _gen(cfg, params, sc, prompts, n_new=5)
    assert all(len(s) == 5 for s in streams)

    def programs(fn):
        size = getattr(fn, "_cache_size", None)
        return size() if callable(size) else 1

    buckets = eng.executor.buckets
    prefill = sum(programs(f) for f in eng.executor._prefill_fn.values())
    assert prefill <= len(buckets)
    assert programs(eng.executor._decode_fn) == 1
    assert programs(eng.executor._extend_fn) == 1
    assert prefill + programs(eng.executor._decode_fn) + programs(
        eng.executor._extend_fn
    ) <= len(buckets) + 2
    assert eng.telemetry["extend_compiles"] == 1
    assert eng.telemetry["extend_dispatches"] >= 1
    assert eng.telemetry["decode_compiles"] == 1


def test_unhonorable_features_warn_and_report():
    """With the extend program disabled, an MLA engine cannot honor
    chunking / prefill-skip / preemption: it must say so once via
    RuntimeWarning and permanently in ``disabled_features`` telemetry —
    never silently."""
    cfg, params = _setup("minicpm3-4b")
    sc = _serve(None, cache_extend=False, prefill_chunk=8,
                kv_layout="paged", kv_page_size=8,
                kv_prefix_cache=True, kv_preemption=True)
    with pytest.warns(RuntimeWarning):
        eng = Engine(cfg, params, sc)
    disabled = eng.telemetry["disabled_features"]
    joined = " ".join(disabled)
    assert "prefill_chunk" in joined
    assert "kv_preemption" in joined
    assert "prefill-skip" in joined
    # the engine still serves correctly, just without the features
    h = eng.submit(list(range(1, 20)), max_new_tokens=4)
    assert len(eng.generate()[h.uid].generated) == 4
    assert eng.scheduler.chunk_len is None
    assert eng.telemetry["extend_dispatches"] == 0


def test_fully_honored_engine_reports_nothing_disabled():
    cfg, params = _setup("granite-8b")
    sc = _serve(KV8, prefill_chunk=8, kv_layout="paged", kv_page_size=8,
                kv_prefix_cache=True, kv_preemption=True)
    with warnings.catch_warnings():
        warnings.simplefilter("error", RuntimeWarning)
        eng = Engine(cfg, params, sc)
    assert eng.telemetry["disabled_features"] == []


def test_prefill_chunk_rejected_on_non_bucketable_engines():
    """SSM / hybrid state caches admit exact-length prompts only; a
    chunk request there is a configuration error, not a silent no-op."""
    cfg = configs.get_config("mamba2-130m", reduced=True)
    params = lm.init_params(cfg, KEY)
    with pytest.raises(ValueError, match="bucketable"):
        Engine(cfg, params,
               ServeConfig(max_batch=2, max_seq_len=64, prefill_chunk=8))

"""Serving-path tests: prefill/decode equivalence across attention
families, continuous batching vs reference greedy decode, int8 KV cache,
rolling-buffer (sliding window) correctness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.base import ServeConfig
from repro.core import precision as P
from repro.models import lm
from repro.serve import ServingEngine

KEY = jax.random.PRNGKey(7)

# int8 per-token KV cache only (what the removed int8_kv_cache flag selected)
KV8 = P.PrecisionPolicy(
    "kv8", (P.Rule("kv_cache", P.int8(per_channel=False)),)
)


@pytest.mark.parametrize(
    "arch",
    ["granite-8b", "minicpm3-4b", "starcoder2-7b", "mamba2-130m",
     "zamba2-1.2b", "internvl2-1b"],
)
def test_prefill_decode_matches_full_forward(arch):
    cfg = configs.get_config(arch, reduced=True)
    params = lm.init_params(cfg, KEY)
    b, s, extra = 2, 12, 4
    toks = jax.random.randint(KEY, (b, s + extra), 0, cfg.vocab_size)
    batch = {"tokens": toks}
    if cfg.frontend == "patch":
        patches = jax.random.normal(
            KEY, (b, cfg.n_frontend_tokens, cfg.frontend_dim)
        )
        batch = {"patches": patches, "tokens": toks}
    full_logits, _, _ = lm.forward(params, cfg, batch, mode="train")
    off = cfg.n_frontend_tokens if cfg.frontend == "patch" else 0
    caches = lm.init_caches(cfg, b, off + s + extra, dtype=jnp.float32)
    pre_batch = dict(batch)
    pre_batch["tokens"] = toks[:, :s]
    last, caches = lm.prefill(params, cfg, pre_batch, caches)
    np.testing.assert_allclose(
        np.asarray(last), np.asarray(full_logits[:, off + s - 1]), atol=5e-4
    )
    for i in range(extra):
        pos = jnp.full((b,), off + s + i, jnp.int32)
        last, caches = lm.decode_step(
            params, cfg, toks[:, s + i : s + i + 1], pos, caches
        )
        np.testing.assert_allclose(
            np.asarray(last),
            np.asarray(full_logits[:, off + s + i]),
            atol=5e-4,
        )


def test_sliding_window_rolling_buffer_long_decode():
    """Decode far past the window: rolling buffer must agree with a full
    forward whose attention uses the same window."""
    cfg = configs.get_config("starcoder2-7b", reduced=True)  # window 8
    params = lm.init_params(cfg, KEY)
    b, total = 1, 24
    toks = jax.random.randint(KEY, (b, total), 0, cfg.vocab_size)
    full_logits, _, _ = lm.forward(params, cfg, {"tokens": toks}, mode="train")
    s = 6
    caches = lm.init_caches(cfg, b, total, dtype=jnp.float32)
    last, caches = lm.prefill(params, cfg, {"tokens": toks[:, :s]}, caches)
    for i in range(total - s):
        pos = jnp.full((b,), s + i, jnp.int32)
        last, caches = lm.decode_step(
            params, cfg, toks[:, s + i : s + i + 1], pos, caches
        )
        np.testing.assert_allclose(
            np.asarray(last), np.asarray(full_logits[:, s + i]), atol=1e-3
        )


def _greedy_ref(cfg, params, prompt, n_new, max_len=64):
    toks = list(prompt)
    caches = lm.init_caches(cfg, 1, max_len, dtype=jnp.float32)
    last, caches = lm.prefill(
        params, cfg, {"tokens": jnp.asarray([toks], jnp.int32)}, caches
    )
    out, pos = [], len(toks)
    nxt = int(jnp.argmax(last[0]))
    out.append(nxt)
    for _ in range(n_new - 1):
        last, caches = lm.decode_step(
            params, cfg, jnp.asarray([[nxt]], jnp.int32),
            jnp.asarray([pos], jnp.int32), caches,
        )
        nxt = int(jnp.argmax(last[0]))
        out.append(nxt)
        pos += 1
    return out


def test_continuous_batching_matches_reference():
    cfg = configs.get_config("granite-8b", reduced=True)
    params = lm.init_params(cfg, KEY)
    prompts = [[5, 9, 3, 7], [11, 2, 6], [1, 2, 3, 4, 5, 6]]
    refs = [_greedy_ref(cfg, params, p, 6) for p in prompts]
    eng = ServingEngine(cfg, params, ServeConfig(max_batch=2, max_seq_len=64))
    uids = [eng.submit(p, 6) for p in prompts]
    res = eng.run()
    for uid, ref in zip(uids, refs):
        assert res[uid].generated == ref


def test_int8_kv_cache_quality():
    cfg = configs.get_config("granite-8b", reduced=True)
    params = lm.init_params(cfg, KEY)
    prompt = [4, 8, 15, 16, 23, 42]
    ref = _greedy_ref(cfg, params, prompt, 8)
    eng = ServingEngine(
        cfg, params,
        ServeConfig(max_batch=1, max_seq_len=64, policy=KV8),
    )
    uid = eng.submit(prompt, 8)
    res = eng.run()
    agree = sum(a == b for a, b in zip(res[uid].generated, ref))
    assert agree >= 6, (res[uid].generated, ref)


def test_lut_softmax_serving_runs():
    cfg = configs.get_config("granite-8b", reduced=True)
    params = lm.init_params(cfg, KEY)
    w8_lut = P.PrecisionPolicy("w8_lut", (
        P.Rule("*.weights", P.int8(per_channel=True)),
        P.Rule("*.softmax", P.lut8()),
    ))
    eng = ServingEngine(
        cfg, params,
        ServeConfig(max_batch=2, max_seq_len=48, policy=w8_lut),
    )
    uid = eng.submit([3, 1, 4], 4)
    res = eng.run()
    assert len(res[uid].generated) == 4


def test_quantized_cache_memory_is_4x_smaller():
    cfg = configs.get_config("granite-8b", reduced=True)
    fp = lm.abstract_caches(cfg, 4, 32, dtype=jnp.bfloat16)
    q = lm.abstract_caches(cfg, 4, 32, quantized=True)
    fp_kv = fp["layers"]["k"]
    q_kv = q["layers"]["k"]
    assert fp_kv.dtype == jnp.bfloat16 and q_kv.dtype == jnp.int8
    assert np.prod(fp_kv.shape) * 2 == 2 * np.prod(q_kv.shape) * 1
    assert "k_scale" in q["layers"]


def test_int8_mla_latent_cache_quality():
    """Beyond-paper §Perf A4: int8 latent cache for MLA decode must track
    the fp cache closely."""
    cfg = configs.get_config("minicpm3-4b", reduced=True)
    params = lm.init_params(cfg, KEY)
    prompt = [7, 3, 11, 2, 9]
    ref = _greedy_ref(cfg, params, prompt, 8)
    eng = ServingEngine(
        cfg, params,
        ServeConfig(max_batch=1, max_seq_len=64, policy=KV8),
    )
    assert eng.quant_cache
    uid = eng.submit(prompt, 8)
    res = eng.run()
    agree = sum(a == b for a, b in zip(res[uid].generated, ref))
    assert agree >= 6, (res[uid].generated, ref)

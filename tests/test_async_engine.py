"""PR-8 pipelined engine loop + mesh-sharded decode tests: the
token-identity matrix (async greedy streams bit-identical to the
synchronous loop across dense/paged x GQA/MLA/int8-KV, under forced
preemption, mid-flight cancel, and EDF deadline drops), virtual-clock
determinism one step late (seeded Poisson replay), the in-flight
dispatch protocol (``Slot.inflight`` marks + discard-at-collect on
preemption), the overlap tracer mode, the
data-parallel :class:`~repro.serve.router.ReplicaRouter`, and the jit
program budget with *everything* enabled at once (async + sharded +
overlap tracer + EDF + prefix cache + preemption + chunked prefill)."""

import dataclasses
import warnings

import jax
import numpy as np
import pytest
from jax.sharding import NamedSharding

from repro import configs
from repro.configs.base import ServeConfig
from repro.core import precision as P
from repro.models import lm
from repro.serve import (
    Engine,
    ReplicaRouter,
    SamplingParams,
    StepClock,
    workloads,
)
from repro.serve.phases import PHASES, OverlapTracer, make_tracer
from repro.serve.scheduler import Slot

KEY = jax.random.PRNGKey(17)

KV8 = P.PrecisionPolicy(
    "kv8", (P.Rule("kv_cache", P.int8(per_channel=False)),)
)


@pytest.fixture(scope="module")
def cfg():
    return configs.get_config("granite-8b", reduced=True)


@pytest.fixture(scope="module")
def params(cfg):
    return lm.init_params(cfg, KEY)


def _serve(**kw):
    base = dict(
        max_batch=2, max_seq_len=64, prefill_buckets=(8, 16, 32),
        decode_steps=3, temperature=0.0,
    )
    base.update(kw)
    return ServeConfig(**base)


PROMPTS = (
    [5, 9, 3, 7],
    [11, 2, 6],
    [1, 2, 3, 4, 5, 6, 7, 8, 9],
    [4, 4],
    [8, 1, 6, 2, 9],
)


def _generate_tokens(cfg, params, sc, prompts=PROMPTS, max_new=8, **ekw):
    eng = Engine(cfg, params, sc, **ekw)
    handles = [eng.submit(list(p), max_new_tokens=max_new) for p in prompts]
    fin = eng.generate()
    return [tuple(fin[h.uid].generated) for h in handles], eng


# ------------------------------------------------- token-identity matrix --


@pytest.mark.parametrize(
    "arch,policy",
    [
        ("granite-8b", None),   # GQA float (bit-exact datapath)
        ("minicpm3-4b", None),  # MLA float
        ("granite-8b", KV8),    # GQA int8 KV (per-page scales)
    ],
)
@pytest.mark.parametrize("layout", ["dense", "paged"])
def test_async_greedy_identical_to_sync(arch, policy, layout):
    """The acceptance bar for the pipelined loop: greedy token streams
    bit-identical to the synchronous loop on every datapath x layout."""
    acfg = configs.get_config(arch, reduced=True)
    aparams = lm.init_params(acfg, KEY)
    kw = dict(kv_layout=layout, kv_page_size=8, policy=policy)
    sync, _ = _generate_tokens(acfg, aparams, _serve(**kw))
    pipe, eng = _generate_tokens(
        acfg, aparams, _serve(async_loop=True, **kw)
    )
    assert pipe == sync
    assert eng.executor.async_loop


def test_async_identical_under_forced_preemption(cfg, params):
    """A page pool too small for two residents forces preemption cycles;
    a victim with an uncollected dispatch has its in-flight tokens
    discarded at collect and regenerates them after resume, so the
    streams stay bit-identical to the synchronous loop."""
    kw = dict(
        max_seq_len=32, decode_steps=2, kv_layout="paged",
        kv_page_size=8, kv_pages=5, kv_prefix_cache=True,
        kv_preemption=True,
    )
    prompts = [[3 + i, 1, 4] for i in range(4)]
    sync, sref = _generate_tokens(
        cfg, params, _serve(**kw), prompts=prompts, max_new=20
    )
    pipe, eng = _generate_tokens(
        cfg, params, _serve(async_loop=True, **kw),
        prompts=prompts, max_new=20,
    )
    assert pipe == sync
    # preemption actually happened in both runs or this test is inert
    assert sref.telemetry["preemptions"] > 0
    assert eng.telemetry["preemptions"] > 0
    eng.executor.cache_mgr.check_invariants()


def test_async_runs_are_deterministic(cfg, params):
    """Same seed, same prompts -> the pipelined loop reproduces itself
    exactly (no host/device race can leak into token streams)."""
    sc = _serve(async_loop=True, kv_layout="paged", kv_page_size=8)
    a, _ = _generate_tokens(cfg, params, sc)
    b, _ = _generate_tokens(cfg, params, sc)
    assert a == b


# ------------------------------------------------------ stale boundaries --


def test_mid_flight_cancel_discards_inflight_tokens(cfg, params):
    """Cancelling while a dispatch is in flight: the cancelled stream
    stops (at most the uncollected step's tokens are discarded — never
    routed), its pages free, and the surviving request is unharmed."""
    eng = Engine(cfg, params, _serve(
        async_loop=True, kv_layout="paged", kv_page_size=8,
    ))
    ha = eng.submit(list(PROMPTS[0]), max_new_tokens=12)
    hb = eng.submit(list(PROMPTS[1]), max_new_tokens=12)
    for _ in range(3):  # prefill + a couple of pipelined decode steps
        eng.step()
    gen_at_cancel = len(eng.request(ha).generated)
    assert eng.cancel(ha)
    assert eng.finish_reason(ha) == "cancelled"
    fin = eng.generate()
    # the cancelled request never grew past the in-flight boundary
    assert len(eng.request(ha).generated) <= gen_at_cancel + 1
    assert hb.uid in fin and len(fin[hb.uid].generated) == 12
    # pool is clean: the cancelled slot's pages went back
    eng.executor.cache_mgr.check_invariants()
    assert not eng.has_work


def test_edf_drops_identical_and_deterministic(cfg, params):
    """EDF deadline drops act on queued requests only, so the one-step-
    stale boundary cannot corrupt them: the same seeded Poisson workload
    on a virtual clock completes/drops identically across two async runs
    and matches the synchronous loop's totals."""
    def run(async_loop):
        clock = StepClock()
        eng = Engine(
            cfg, params,
            _serve(async_loop=async_loop, scheduler="edf"),
            clock=clock,
        )
        events = workloads.poisson(
            rate=100.0, n=24, vocab_size=cfg.vocab_size, seed=3,
            prompt_len=(3, 10), max_new_tokens=6, deadline_s=(0.05, 0.6),
        )
        rep = workloads.replay(eng, events, step_cost=0.02)
        return rep

    def virtual(rep):
        d = rep.as_dict()
        d.pop("host_wall_s")  # real seconds, legitimately run-dependent
        return d

    sync = run(False)
    async_a, async_b = run(True), run(True)
    # async is deterministic with itself, bit for bit (virtual-clock
    # accounting only; host wall seconds are physical measurements)
    assert virtual(async_a) == virtual(async_b)
    assert async_a.per_request == async_b.per_request
    # and agrees with sync on what was served vs dropped
    assert async_a.requests == sync.requests
    assert async_a.completed == sync.completed
    assert async_a.dropped == sync.dropped
    assert async_a.tokens == sync.tokens


def test_token_events_stamped_with_dispatch_clock(cfg, params):
    """Satellite contract: TokenEvents carry the engine clock of the
    step that *dispatched* them, so on a virtual clock the async loop's
    event timeline is reproducible (collect-time stamping would shift
    every event one step_cost late and wobble TTFT accounting)."""
    def run():
        clock = StepClock()
        eng = Engine(cfg, params, _serve(async_loop=True), clock=clock)
        h = eng.submit(list(PROMPTS[0]), max_new_tokens=6)
        events = []
        it = eng.stream(h)
        while True:
            ev = next(it, None)
            if ev is None:
                break
            events.append((ev.token, ev.index, ev.ts))
            clock.advance(0.01)
        return events

    assert run() == run()


def test_inflight_marks_track_uncollected_dispatch(cfg, params):
    """The executor marks decode slots in flight at dispatch and clears
    them at collect — but only when no newer dispatch re-marked the slot
    (the async loop dispatches N+1 before collecting N over the same
    slots).  The marks tell policies which residents carry an
    uncollected dispatch; preempting one is legal (discard-at-collect)
    but discards up to decode_steps tokens."""
    assert Slot().inflight is False
    eng = Engine(cfg, params, _serve(async_loop=True))
    eng.submit(list(PROMPTS[0]), max_new_tokens=8)
    eng.step()  # prefill dispatch + first decode dispatch in flight
    eng.step()
    marked = [i for i, s in enumerate(eng.executor.slots) if s.inflight]
    # a decode dispatch is pending -> its slots are marked
    assert marked == list(eng._inflight.decode_set)
    eng.generate()
    assert not any(s.inflight for s in eng.executor.slots)


def test_preempted_inflight_tokens_are_discarded_at_collect(cfg, params):
    """The admit_seq snapshot guard: a slot whose resident turned over
    between dispatch and collect — even back to the SAME request, whose
    identity check alone would pass — must not have the stale dispatch's
    tokens routed (the resume replay was planned from pre-dispatch
    ``generated``; routing them would duplicate tokens)."""
    eng = Engine(cfg, params, _serve(async_loop=True))
    h = eng.submit(list(PROMPTS[0]), max_new_tokens=12)
    eng.step()
    eng.step()  # a decode dispatch for h is now in flight
    inflight = eng._inflight
    assert inflight is not None and inflight.decode_set
    idx = inflight.decode_set[0]
    req = eng.executor.slots[idx].request
    before = len(req.generated)
    # simulate a mid-flight preempt + same-slot re-admission: the slot
    # record turns over but holds the same Request with a new admit stamp
    slot = eng.executor.slots[idx]
    slot.admit_seq += 1
    out = eng.executor.collect(inflight)
    eng._inflight = None
    assert len(req.generated) == before  # in-flight tokens discarded
    assert not any(t[0] == req.uid for t in out.tokens)


# ---------------------------------------------------------- overlap mode --


def test_overlap_tracer_never_fences_and_reports_overlap(cfg, params):
    eng = Engine(cfg, params, _serve(
        async_loop=True, trace_phases=True, phase_mode="overlap",
    ))
    assert isinstance(eng._tracer, OverlapTracer)
    eng.generate([list(p) for p in PROMPTS[:3]], max_new_tokens=6)
    assert eng._tracer.fences == 0  # never blocks the pipeline
    s = eng.telemetry["phases"]
    for key in ("device_overlap_s", "host_bubble_s", "overlap_efficiency"):
        assert key in s
    assert s["device_overlap_s"] > 0.0
    assert 0.0 <= s["overlap_efficiency"] <= 1.0
    # per-step records stay within the extended schema
    for rec in eng._tracer.records():
        assert set(rec) <= set(PHASES) | {"wall", "collect", "overlap"}


def test_make_tracer_mode_dispatch():
    assert isinstance(make_tracer(True, mode="overlap"), OverlapTracer)
    assert make_tracer(True, mode="fenced").collect_phase == "sample"
    assert make_tracer(False, mode="overlap").collect_phase == "sample"
    with pytest.raises(ValueError, match="phase_mode"):
        make_tracer(True, mode="bogus")


def test_fenced_tracer_with_async_loop_warns(cfg, params):
    with pytest.warns(UserWarning, match="serializing the async_loop"):
        Engine(cfg, params, _serve(
            async_loop=True, trace_phases=True, phase_mode="fenced",
        ))
    with warnings.catch_warnings():
        warnings.simplefilter("error")  # overlap mode must NOT warn
        Engine(cfg, params, _serve(
            async_loop=True, trace_phases=True, phase_mode="overlap",
        ))


# --------------------------------------------------- mesh-sharded decode --


def test_shard_decode_places_named_shardings(cfg, params):
    """shard_decode commits params and KV pools to NamedSharding over
    the host mesh; on a single-device mesh this must be a semantic
    no-op (identical tokens) while every cache leaf is mesh-placed."""
    kw = dict(kv_layout="paged", kv_page_size=8)
    sync, _ = _generate_tokens(cfg, params, _serve(**kw))
    sharded, eng = _generate_tokens(
        cfg, params, _serve(shard_decode=True, async_loop=True, **kw)
    )
    assert sharded == sync
    assert eng.executor.mesh is not None
    for leaf in jax.tree.leaves(eng.executor.caches):
        assert isinstance(leaf.sharding, NamedSharding)
    for leaf in jax.tree.leaves(eng.executor.params):
        assert isinstance(leaf.sharding, NamedSharding)
    # the page-table rebuild hook keeps the committed placement
    assert eng.executor.cache_mgr.table_sharding is not None


def test_jit_budget_with_everything_enabled(cfg, params):
    """THE budget gate for this PR (CI-enforced): async loop + sharded
    decode + overlap tracer + EDF + prefix cache + preemption + chunked
    prefill + speculative decoding + mixed per-request sampling + n-best
    forking together still mint at most len(prefill_buckets) prefill
    programs + 1 decode + 1 extend on the target — no feature may re-key
    a jit cache mid-run (the page-table re-placement hook is what this
    catches).  The draft worker owns its own bounded set (at most
    len(prefill_buckets) draft prefills + 1 propose scan)."""
    clock = StepClock()
    eng = Engine(cfg, params, _serve(
        async_loop=True, shard_decode=True, trace_phases=True,
        phase_mode="overlap", scheduler="edf", kv_layout="paged",
        kv_page_size=8, kv_prefix_cache=True, kv_preemption=True,
        prefill_chunk=8, speculative=True, spec_tokens=3,
    ), clock=clock)
    events = workloads.poisson(
        rate=50.0, n=12, vocab_size=cfg.vocab_size, seed=0,
        max_new_tokens=6, deadline_s=(0.5, 5.0), shared_prefix=8,
    )
    workloads.replay(eng, events, step_cost=0.1)
    # mixed per-request sampling + an n-best fork on the same engine:
    # knobs ride the dispatches as stacked arrays, never as new programs
    eng.submit([5, 9, 3], SamplingParams(max_new_tokens=4))
    eng.submit([2, 4, 6, 8], SamplingParams(
        max_new_tokens=4, temperature=0.9, top_k=12, top_p=0.95, seed=7))
    eng.submit([7, 7, 1], SamplingParams(
        max_new_tokens=4, temperature=0.7, seed=11), n=2)
    eng.generate()

    def programs(fn):
        size = getattr(fn, "_cache_size", None)
        return size() if callable(size) else 1

    ex = eng.executor
    buckets = ex.buckets
    assert sum(programs(f) for f in ex._prefill_fn.values()) <= len(buckets)
    # a fully-speculative steady state can retire every token through the
    # verify dispatch without ever compiling the decode scan — hence <= 1
    assert programs(ex._decode_fn) <= 1
    if ex._extend_fn is not None:
        assert programs(ex._extend_fn) <= 1
    assert ex.draft is not None
    assert programs(ex.draft._propose_fn) <= 1
    assert sum(
        programs(f) for f in ex.draft._prefill_fn.values()
    ) <= len(buckets)
    assert eng.telemetry["draft_tokens_proposed"] > 0
    assert eng._tracer.fences == 0


# ---------------------------------------------------------- replica router --


def test_router_greedy_identical_to_single_engine(cfg, params):
    single = Engine(cfg, params, _serve())
    hs = [single.submit(list(p), max_new_tokens=6) for p in PROMPTS]
    fin = single.generate()
    want = [fin[h.uid].generated for h in hs]

    router = ReplicaRouter(cfg, params, _serve(replicas=2, async_loop=True))
    rhs = [router.submit(list(p), max_new_tokens=6) for p in PROMPTS]
    rfin = router.generate()
    got = [rfin[h.uid].generated for h in rhs]
    assert got == want


def test_router_least_loaded_admission_balances(cfg, params):
    router = ReplicaRouter(cfg, params, _serve(replicas=3))
    handles = [
        router.submit(list(PROMPTS[i % len(PROMPTS)]), max_new_tokens=4)
        for i in range(9)
    ]
    placed = [router.replica_of(h) for h in handles]
    counts = [placed.count(i) for i in range(3)]
    assert counts == [3, 3, 3]  # round-robin falls out of least-loaded
    router.generate()
    assert not router.has_work


def test_router_stream_and_cancel_delegate(cfg, params):
    router = ReplicaRouter(cfg, params, _serve(replicas=2))
    ha = router.submit(list(PROMPTS[0]), max_new_tokens=5)
    hb = router.submit(list(PROMPTS[1]), max_new_tokens=5)
    events = list(router.stream(ha))
    # events re-stamped with the ROUTER uid, gapless and ordered
    assert [e.uid for e in events] == [ha.uid] * len(events)
    assert [e.index for e in events] == list(range(len(events)))
    assert events[-1].finished
    assert router.cancel(hb) or router.result(hb) is not None
    router.generate()
    tel = router.telemetry
    assert tel["replicas"] == 2
    assert len(tel["replica_telemetry"]) == 2
    assert tel["tokens_generated"] >= len(events)


def test_router_rejects_bad_replicas(cfg, params):
    with pytest.raises(ValueError, match="replicas"):
        ReplicaRouter(cfg, params, _serve(replicas=0))


# -------------------------------------------------------- sync unchanged --


def test_sync_loop_is_untouched_by_default(cfg, params):
    """async_loop defaults off and the sync path never creates carry
    state or in-flight steps — the legacy loop is byte-identical."""
    eng = Engine(cfg, params, _serve())
    eng.generate([list(p) for p in PROMPTS[:2]], max_new_tokens=5)
    assert not eng.executor.async_loop
    assert eng.executor._carry is None
    assert eng._inflight is None
    assert not eng.executor._carry_valid.any()

"""End-to-end system tests: train a tiny LM (loss decreases), checkpoint,
resume, and serve it with the continuous-batching engine."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.base import ServeConfig, TrainConfig
from repro.data.synthetic import SyntheticLM, SyntheticLMConfig
from repro.models import lm
from repro.serve import ServingEngine
from repro.train import run_training


@pytest.fixture(scope="module")
def tiny_cfg():
    return configs.get_config("granite-8b", reduced=True)


def test_train_loss_decreases(tiny_cfg, tmp_path_factory):
    workdir = str(tmp_path_factory.mktemp("train"))
    ds = SyntheticLM(SyntheticLMConfig(
        vocab_size=tiny_cfg.vocab_size, seq_len=32, global_batch=8, seed=0
    ))
    tc = TrainConfig(
        learning_rate=1e-2, warmup_steps=5, total_steps=60,
        checkpoint_every=30, schedule="cosine",
    )
    result = run_training(tiny_cfg, tc, ds.batch, workdir=workdir)
    assert result.final_step == 60
    losses = [m["ce_loss"] for m in result.metrics_history]
    assert losses[-1] < losses[0] * 0.85, losses
    assert not np.isnan(losses[-1])


def test_train_then_serve(tiny_cfg):
    params = lm.init_params(tiny_cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(
        tiny_cfg, params, ServeConfig(max_batch=2, max_seq_len=48)
    )
    uid = eng.submit([3, 1, 4, 1, 5], max_new_tokens=5)
    results = eng.run()
    assert len(results[uid].generated) == 5
    assert all(0 <= t < tiny_cfg.padded_vocab_size for t in results[uid].generated)


def test_wsd_schedule_used_for_minicpm():
    """The minicpm family trains with WSD (paper arXiv:2404.06395)."""
    from repro.optim import wsd_schedule

    lrs = [
        float(wsd_schedule(s, base_lr=1.0, warmup_steps=10, total_steps=100))
        for s in range(100)
    ]
    assert lrs[5] < 1.0  # warming up
    assert abs(lrs[50] - 1.0) < 1e-6  # stable phase
    assert lrs[99] < 0.05  # decayed

"""Serving API redesign tests: the Scheduler / Executor / Engine layering
contract (scheduler device-free, executor decision-driven), the request
lifecycle (submit -> stream -> cancel frees pages, cancel-before-prefill,
interleaved streams), `Engine.generate` vs legacy `ServingEngine.run`
token identity across dense/paged x GQA/MLA/int8-KV, chunked prefill
(greedy streams bit-identical to unchunked, jit budget unchanged), and
scheduler pluggability."""

import dataclasses
import inspect

import jax
import numpy as np
import pytest

from repro import configs
from repro.configs.base import ServeConfig
from repro.core import precision as P
from repro.models import lm
from repro.serve import (
    Engine,
    FifoScheduler,
    SamplingParams,
    ServingEngine,
)
from repro.serve import executor as executor_mod
from repro.serve import scheduler as scheduler_mod

KEY = jax.random.PRNGKey(17)

KV8 = P.PrecisionPolicy(
    "kv8", (P.Rule("kv_cache", P.int8(per_channel=False)),)
)


@pytest.fixture(scope="module")
def cfg():
    return configs.get_config("granite-8b", reduced=True)


@pytest.fixture(scope="module")
def params(cfg):
    return lm.init_params(cfg, KEY)


def _serve(**kw):
    base = dict(max_batch=2, max_seq_len=64, decode_steps=3)
    base.update(kw)
    return ServeConfig(**base)


PROMPTS = ([5, 9, 3, 7], [11, 2, 6], [1, 2, 3, 4, 5, 6, 7, 8, 9], [4, 4])


# ------------------------------------------------------ layering contract --


def test_scheduler_module_is_device_free():
    """The policy layer must stay importable and auditable without jax:
    no jax import, no jnp usage, no device dispatch can hide in it."""
    src = inspect.getsource(scheduler_mod)
    assert "import jax" not in src
    assert "jnp." not in src
    assert "jax." not in src


def test_executor_makes_no_policy_decisions():
    """The executor consumes explicit decisions: it never inspects the
    queue, never matches prefixes, never reserves or admits — those are
    scheduler verbs (it may free pages: retirement is mechanical)."""
    src = inspect.getsource(executor_mod)
    for policy_verb in (
        "FifoScheduler",
        ".queue",
        "match_prefix",
        "admission_need",
        "can_reserve",
        ".admit(",
        "_try_preempt",
    ):
        assert policy_verb not in src, f"executor performs policy: {policy_verb}"


def test_custom_scheduler_pluggable(cfg, params):
    """Engine accepts a scheduler_factory; a policy tweak (cap admissions
    at one per step) needs no executor or engine change."""

    class OneAtATime(FifoScheduler):
        def __init__(self, serve_cfg, caps, cache):
            super().__init__(
                dataclasses.replace(serve_cfg, max_prefill_per_step=1),
                caps, cache,
            )

    eng = Engine(cfg, params, _serve(max_batch=4),
                 scheduler_factory=OneAtATime)
    handles = [eng.submit(p, max_new_tokens=3) for p in PROMPTS[:3]]
    stats = eng.step()
    assert stats["prefilled"] == 1  # the policy capped admission
    res = eng.generate()
    assert all(len(res[h.uid].generated) == 3 for h in handles)


# ------------------------------------------------- legacy-parity (shim) ----


def test_servingengine_warns_deprecation(cfg, params):
    with pytest.warns(DeprecationWarning, match="ServingEngine is deprecated"):
        ServingEngine(cfg, params, _serve())


@pytest.mark.parametrize(
    "arch,policy",
    [
        ("granite-8b", None),   # GQA float (bit-exact datapath)
        ("minicpm3-4b", None),  # MLA float
        ("granite-8b", KV8),    # GQA int8 KV (per-page scales)
    ],
)
@pytest.mark.parametrize("layout", ["dense", "paged"])
def test_generate_token_identical_to_legacy_run(arch, policy, layout):
    """`Engine.generate` is the `ServingEngine.run` migration target:
    same prompts, same seed -> identical token streams through the shim,
    across layouts and datapaths."""
    acfg = configs.get_config(arch, reduced=True)
    aparams = lm.init_params(acfg, KEY)
    sc = _serve(kv_layout=layout, kv_page_size=8, policy=policy)
    eng = Engine(acfg, aparams, sc)
    handles = [eng.submit(list(p), max_new_tokens=5) for p in PROMPTS]
    new = [eng.generate()[h.uid].generated for h in handles]
    with pytest.warns(DeprecationWarning):
        old_eng = ServingEngine(acfg, aparams, sc)
    uids = [old_eng.submit(list(p), 5) for p in PROMPTS]
    old = [old_eng.run()[u].generated for u in uids]
    assert new == old


# ------------------------------------------------------ request lifecycle --


def test_stream_matches_generate_with_ordered_events(cfg, params):
    handles_cfg = _serve()
    ref_eng = Engine(cfg, params, handles_cfg)
    ref_handles = [ref_eng.submit(list(p), max_new_tokens=6) for p in PROMPTS[:2]]
    ref = [ref_eng.generate()[h.uid].generated for h in ref_handles]

    eng = Engine(cfg, params, handles_cfg)
    h0 = eng.submit(list(PROMPTS[0]), max_new_tokens=6)
    h1 = eng.submit(list(PROMPTS[1]), max_new_tokens=6)
    ev0 = list(eng.stream(h0))
    ev1 = list(eng.stream(h1))
    assert [e.token for e in ev0] == ref[0]
    assert [e.token for e in ev1] == ref[1]
    for evs in (ev0, ev1):
        assert [e.index for e in evs] == list(range(len(evs)))
        assert all(b.ts >= a.ts for a, b in zip(evs, evs[1:]))
        assert evs[-1].finished and evs[-1].finish_reason == "length"
        assert not any(e.finished for e in evs[:-1])
        # time-to-first-token is measurable from the event stream
        req = eng.request(evs[0].uid)
        assert evs[0].ts >= req.submitted_at >= 0.0


def test_two_interleaved_streams_make_progress(cfg, params):
    """Alternately pulling two streams: each pump advances the shared
    engine, and both consumers see their full ordered sequence."""
    eng = Engine(cfg, params, _serve())
    ha = eng.submit(list(PROMPTS[0]), max_new_tokens=6)
    hb = eng.submit(list(PROMPTS[1]), max_new_tokens=6)
    ita, itb = eng.stream(ha), eng.stream(hb)
    got_a, got_b = [], []
    while len(got_a) < 6 or len(got_b) < 6:
        for it, got in ((ita, got_a), (itb, got_b)):
            ev = next(it, None)
            if ev is not None:
                got.append(ev.token)
    assert got_a == eng.result(ha).generated
    assert got_b == eng.result(hb).generated


def test_eos_finish_reason_on_stream(cfg, params):
    probe = Engine(cfg, params, _serve())
    hp = probe.submit(list(PROMPTS[0]), max_new_tokens=8)
    free = probe.generate()[hp.uid].generated
    eos = free[2]
    eng = Engine(cfg, params, _serve())
    h = eng.submit(list(PROMPTS[0]), SamplingParams(max_new_tokens=8, eos_id=eos))
    events = list(eng.stream(h))
    assert [e.token for e in events] == free[: free.index(eos) + 1]
    assert events[-1].finished and events[-1].finish_reason == "eos"
    assert eng.finish_reason(h) == "eos"


def test_cancel_mid_generation_frees_pages(cfg, params):
    """Cancelling a resident request returns its pages to the pool at
    once (pool invariants clean), and concurrent requests are unharmed."""
    eng = Engine(cfg, params, _serve(
        kv_layout="paged", kv_page_size=8, decode_steps=2,
    ))
    mgr = eng.executor.cache_mgr
    h_long = eng.submit(list(PROMPTS[2]), max_new_tokens=40)
    h_short = eng.submit(list(PROMPTS[1]), max_new_tokens=5)
    stream = eng.stream(h_long)
    got = [next(stream), next(stream)]  # mid-generation
    pages_before = mgr.pages_in_use
    assert pages_before > 0
    assert eng.cancel(h_long)
    mgr.check_invariants()
    assert not any(
        s.active and s.request.uid == h_long.uid for s in eng.executor.slots
    )
    assert mgr.pages_in_use < pages_before
    assert eng.finish_reason(h_long) == "cancelled"
    assert eng.result(h_long).cancelled
    # the open stream drains its buffer and stops; no post-cancel tokens
    rest = list(stream)
    n_before_cancel = len(eng.result(h_long).generated)
    assert len(got) + len(rest) <= n_before_cancel
    res = eng.generate()
    assert len(res[h_short.uid].generated) == 5
    mgr.check_invariants()
    assert mgr.pages_in_use == 0
    # cancelling twice is a no-op
    assert not eng.cancel(h_long)


def test_cancel_before_prefill(cfg, params):
    """A queued request cancels without ever touching a slot or a page."""
    eng = Engine(cfg, params, _serve(
        max_batch=1, kv_layout="paged", kv_page_size=8,
    ))
    h_running = eng.submit(list(PROMPTS[0]), max_new_tokens=4)
    h_queued = eng.submit(list(PROMPTS[1]), max_new_tokens=4)
    eng.step()  # h_running occupies the only slot; h_queued waits
    assert len(eng.scheduler.queue) == 1
    assert eng.cancel(h_queued)
    assert not eng.scheduler.queue
    eng.executor.cache_mgr.check_invariants()
    res = eng.generate()
    assert len(res[h_running.uid].generated) == 4
    assert res[h_queued.uid].generated == []
    assert eng.finish_reason(h_queued) == "cancelled"
    assert list(eng.stream(h_queued)) == []
    assert eng.telemetry["prompts_admitted"] == 1


def test_sequence_cap_skip_admission_streams_final_token(cfg, params):
    """A prefix-skip admission with one token of sequence headroom: the
    forced tail replays to the cap and the stream delivers exactly the
    one sampled token, flagged final — identical to the unskipped run.
    (Zero-event finishes happen only via cancel, covered above; stream
    consumers still must not assume >= 1 event.)"""
    sc = ServeConfig(
        max_batch=1, max_seq_len=32, decode_steps=4,
        prefill_buckets=(8, 16, 32),
        kv_layout="paged", kv_page_size=8, kv_prefix_cache=True,
    )
    eng = Engine(cfg, params, sc)
    prompt = list(range(31))  # max_seq_len - 1: one-token headroom
    h1 = eng.submit(list(prompt), max_new_tokens=4)
    first = eng.generate()[h1.uid].generated
    assert len(first) == 1  # capped by the sequence limit
    # second identical prompt: full-page prefix hit -> skip admission
    h2 = eng.submit(list(prompt), max_new_tokens=4)
    events = list(eng.stream(h2))
    assert eng.telemetry["prefill_tokens_saved"] > 0  # it really skipped
    assert [e.token for e in events] == first
    assert events[-1].finished
    eng.executor.cache_mgr.check_invariants()


def test_created_at_survives_preemption_restamp(cfg, params):
    """Preemption restamps Request.submitted_at (queue-wait clock) but
    must never touch created_at — the TTFT anchor for TokenEvent
    consumers."""
    sc = ServeConfig(
        max_batch=2, max_seq_len=32, decode_steps=2,
        prefill_buckets=(8, 16, 32),
        kv_layout="paged", kv_page_size=8, kv_pages=5,
        kv_prefix_cache=True, kv_preemption=True,
    )
    eng = Engine(cfg, params, sc)
    handles = [eng.submit([3 + i, 1, 4], max_new_tokens=20) for i in range(4)]
    created = {h.uid: eng.request(h).created_at for h in handles}
    res = eng.generate()
    assert eng.telemetry["preemptions"] > 0  # the tight pool forced it
    preempted = [r for r in res.values() if r.preemptions]
    assert preempted
    for req in preempted:
        assert req.created_at == created[req.uid]
        assert req.submitted_at > req.created_at  # requeue restamped it
    for h in handles:
        assert len(res[h.uid].generated) == 20


def test_generate_releases_event_buffers(cfg, params):
    """The batch path must not accumulate per-token event state across
    waves (a long-lived engine would otherwise grow O(tokens ever
    generated)); a stream opened later on a finished request just ends."""
    eng = Engine(cfg, params, _serve())
    for _ in range(3):
        h = eng.submit(list(PROMPTS[0]), max_new_tokens=6)
        eng.generate()
    assert eng._events == {}
    assert list(eng.stream(h)) == []  # finished, buffer released


def test_submit_param_styles(cfg, params):
    eng = Engine(cfg, params, _serve())
    with pytest.raises(ValueError, match="not both"):
        eng.submit([1, 2], SamplingParams(max_new_tokens=3), max_new_tokens=4)
    with pytest.raises(ValueError, match="empty prompt"):
        eng.submit([])
    h = eng.submit([1, 2], SamplingParams(max_new_tokens=3))
    assert len(eng.generate()[h.uid].generated) == 3


# -------------------------------------------------------- chunked prefill --


LONG_PROMPTS = (
    list(range(1, 21)),           # 20 tokens: chunk 8 -> 12 forced
    list(range(3, 12)),           # 9 tokens: one chunk + 1 forced
    [7, 7, 7],                    # shorter than the chunk: plain prefill
    list(np.arange(2, 30) % 13),  # 28 tokens
)


@pytest.mark.parametrize("layout", ["dense", "paged"])
def test_chunked_prefill_greedy_token_identical(cfg, params, layout):
    """prefill_chunk admits long prompts chunk-first + teacher-forced
    tail; on the bit-exact datapath the greedy streams must be identical
    to unchunked while only chunk-sized buckets compile."""
    base = dict(
        max_batch=2, max_seq_len=64, decode_steps=4,
        prefill_buckets=(8, 16, 32), kv_layout=layout, kv_page_size=8,
    )
    ref_eng = Engine(cfg, params, ServeConfig(**base))
    href = [ref_eng.submit(list(p), max_new_tokens=6) for p in LONG_PROMPTS]
    ref = [ref_eng.generate()[h.uid].generated for h in href]

    eng = Engine(cfg, params, ServeConfig(**base, prefill_chunk=8))
    hc = [eng.submit(list(p), max_new_tokens=6) for h, p in zip(href, LONG_PROMPTS)]
    got = [eng.generate()[h.uid].generated for h in hc]
    assert got == ref
    # long prompts never dispatched their full length: every compiled
    # prefill program is at most the chunk's bucket
    assert max(eng.executor._prefill_fn) <= 8
    assert max(ref_eng.executor._prefill_fn) >= 32


def test_chunked_prefill_interleaves_with_resident_decode(cfg, params):
    """A long prompt admitted mid-run must not stall the resident: the
    resident keeps emitting while the newcomer's tail teacher-forces
    through the shared decode scans."""
    eng = Engine(cfg, params, _serve(
        max_batch=2, decode_steps=2, prefill_buckets=(4, 8, 16, 32),
        prefill_chunk=4,
    ))
    h_res = eng.submit(list(PROMPTS[0]), max_new_tokens=12)
    eng.step()
    resident_before = len(eng.request(h_res).generated)
    h_long = eng.submit(list(range(1, 20)), max_new_tokens=4)
    eng.step()  # chunk dispatch + shared decode scan
    # the newcomer is resident, still draining its forced tail...
    slot = next(
        s for s in eng.executor.slots
        if s.active and s.request.uid == h_long.uid
    )
    assert slot.pending, "tail should drain over multiple steps"
    assert not eng.request(h_long).generated
    # ...and the resident advanced on the very same step
    assert len(eng.request(h_res).generated) > resident_before
    res = eng.generate()
    assert len(res[h_long.uid].generated) == 4
    assert len(res[h_res.uid].generated) == 12


def test_chunked_prefill_jit_budget(cfg, params):
    """With prefill_chunk on (and the prefix cache + preemption knobs on
    top), the real jit caches still hold <= len(prefill_buckets) prefill
    programs + 1 decode program."""

    def programs(fn):
        size = getattr(fn, "_cache_size", None)
        return size() if callable(size) else 1

    rng = np.random.default_rng(0)
    prompts = [
        list(rng.integers(0, cfg.vocab_size, n))
        for n in (3, 4, 5, 9, 12, 17, 23, 30)
    ]
    sc = ServeConfig(
        max_batch=4, max_seq_len=64, decode_steps=3,
        prefill_buckets=(4, 8, 16), prefill_chunk=8,
        kv_layout="paged", kv_page_size=8,
        kv_prefix_cache=True, kv_preemption=True,
    )
    eng = Engine(cfg, params, sc)
    handles = [eng.submit(p, max_new_tokens=5) for p in prompts]
    res = eng.generate()
    assert all(len(res[h.uid].generated) == 5 for h in handles)
    buckets = eng.executor.buckets
    assert eng.telemetry["prefill_compiles"] <= len(buckets)
    assert sum(programs(f) for f in eng.executor._prefill_fn.values()) <= len(
        buckets
    )
    assert programs(eng.executor._decode_fn) == 1
    assert eng.telemetry["decode_compiles"] == 1


def test_chunked_live_on_non_bit_exact_datapaths():
    """MLA's decode path is ~1ulp off prefill, so chunking used to be
    silently gated off there.  The cache-extending prefill program runs
    later chunks through prefill math against the populated cache, so
    chunking now activates for real — and the tokens stay identical to
    the whole-prompt engine."""
    acfg = configs.get_config("minicpm3-4b", reduced=True)
    aparams = lm.init_params(acfg, KEY)
    base = dict(max_batch=2, max_seq_len=64, decode_steps=3,
                prefill_buckets=(8, 32))
    eng = Engine(acfg, aparams, ServeConfig(**base, prefill_chunk=8))
    assert eng.scheduler.chunk_len == 8
    h = eng.submit(list(range(1, 20)), max_new_tokens=5)
    got = eng.generate()[h.uid].generated
    ref_eng = Engine(acfg, aparams, ServeConfig(**base))
    hr = ref_eng.submit(list(range(1, 20)), max_new_tokens=5)
    assert ref_eng.generate()[hr.uid].generated == got
    # later chunks rode the extend program, not a whole-prompt bucket
    assert eng.telemetry["extend_dispatches"] >= 1
    assert 32 not in eng.executor._prefill_fn
    assert "prefill_chunk" not in " ".join(
        eng.telemetry["disabled_features"]
    )


def test_chunk_must_fit_a_bucket(cfg, params):
    with pytest.raises(ValueError, match="largest prefill bucket"):
        Engine(cfg, params, _serve(prefill_buckets=(8, 16), prefill_chunk=24))
    with pytest.raises(ValueError, match=">= 1"):
        Engine(cfg, params, _serve(prefill_buckets=(8,), prefill_chunk=0))


def test_chunked_with_prefix_cache_composes(cfg, params):
    """Prefix hits skip, unmatched long prompts chunk, and chunk pages
    registered by the first tenant are hittable by the second — all
    token-identical to the dense baseline."""
    prompts = [list(range(1, 25)), list(range(1, 25)) + [9, 9]]
    base = dict(max_batch=2, max_seq_len=64, decode_steps=3,
                prefill_buckets=(8, 16, 32))
    ref_eng = Engine(cfg, params, ServeConfig(**base))
    hr = [ref_eng.submit(list(p), max_new_tokens=5) for p in prompts]
    ref = [ref_eng.generate()[h.uid].generated for h in hr]
    eng = Engine(cfg, params, ServeConfig(
        **base, kv_layout="paged", kv_page_size=8,
        kv_prefix_cache=True, prefill_chunk=8,
        max_prefill_per_step=1,  # serialize so the second can hit
    ))
    h = [eng.submit(list(p), max_new_tokens=5) for p in prompts]
    got = [eng.generate()[x.uid].generated for x in h]
    assert got == ref
    tel = eng.telemetry
    assert tel["prefix_hits"] >= 1  # the chunk-registered pages hit
    assert tel["prefill_tokens_saved"] > 0
    eng.executor.cache_mgr.check_invariants()

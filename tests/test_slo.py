"""SLO-aware serving tests: the EDF DeadlineScheduler (miss-rate win
over FIFO on a seeded Poisson workload — the PR's acceptance
criterion), past-deadline drop/demote/ignore policies, deadline-aware
preemption victims, the deterministic workload/replay layer
(serve/workloads.py), the jit-budget invariant with the tracer + EDF
live, and the benchmark matrix regression gate."""

import inspect
import math
import types

import jax
import pytest

from repro import configs
from repro.configs.base import ServeConfig
from repro.models import lm
from repro.serve import (
    DeadlineScheduler,
    Engine,
    FifoScheduler,
    StepClock,
    workloads,
)
from repro.serve import slo as slo_mod
from repro.serve.api import FINISH_DEADLINE, NO_TOKEN
from repro.serve.scheduler import ExecutorCaps, Request, Slot

KEY = jax.random.PRNGKey(17)


@pytest.fixture(scope="module")
def cfg():
    return configs.get_config("granite-8b", reduced=True)


@pytest.fixture(scope="module")
def params(cfg):
    return lm.init_params(cfg, KEY)


def _engine(cfg, params, clock=None, **kw):
    base = dict(
        max_batch=2, max_seq_len=64, prefill_buckets=(8, 16, 32),
        decode_steps=2, temperature=0.0,
    )
    base.update(kw)
    return Engine(cfg, params, ServeConfig(**base), clock=clock)


# ----------------------------------------------------------- layering --


def test_slo_and_workloads_modules_are_device_free():
    """New policy-side modules obey the PR-5 contract: importable and
    auditable without jax (workloads uses numpy for seeded draws)."""
    for mod in (slo_mod, workloads):
        src = inspect.getsource(mod)
        assert "import jax" not in src
        assert "jnp." not in src
        assert "jax." not in src


def test_unknown_scheduler_name_rejected(cfg, params):
    with pytest.raises(ValueError, match="edf"):
        Engine(cfg, params, ServeConfig(
            max_batch=2, max_seq_len=64, scheduler="priority"
        ))


# ---------------------------------------------------- EDF vs FIFO win --


def _replay(cfg, params, scheduler, seed=0):
    clock = StepClock()
    eng = _engine(cfg, params, clock=clock, scheduler=scheduler,
                  overdue_policy="drop")
    events = workloads.poisson(
        rate=20.0, n=16, vocab_size=cfg.vocab_size, seed=seed,
        max_new_tokens=8, deadline_s=(1.0, 10.0),
    )
    rep = workloads.replay(eng, events, step_cost=0.2)
    return rep, eng


def test_edf_beats_fifo_on_seeded_poisson_deadlines(cfg, params):
    """Acceptance criterion: at the same offered load (identical seeded
    Poisson arrivals, virtual step cost) the EDF scheduler achieves a
    strictly lower deadline-miss rate than FIFO.  Deterministic by
    construction: StepClock time, temperature 0, fixed seed."""
    fifo, fifo_eng = _replay(cfg, params, "fifo")
    edf, edf_eng = _replay(cfg, params, "edf")
    assert fifo.requests == edf.requests == 16
    assert fifo.deadline_total == edf.deadline_total == 16
    assert fifo.deadline_missed > 0  # the load genuinely pressures FIFO
    assert edf.miss_rate < fifo.miss_rate
    # both engines completed every token's worth of feasible work and
    # the engine-level SLO telemetry agrees with the replay report
    assert fifo_eng.telemetry["deadline_missed"] == fifo.deadline_missed
    assert edf_eng.telemetry["deadline_missed"] == edf.deadline_missed


def test_replay_is_deterministic(cfg, params):
    a, _ = _replay(cfg, params, "edf", seed=3)
    b, _ = _replay(cfg, params, "edf", seed=3)
    da, db = a.as_dict(), b.as_dict()
    # host_wall_s is real elapsed host time — the one legitimately
    # non-deterministic field; everything else is simulation time
    da.pop("host_wall_s"), db.pop("host_wall_s")
    assert da == db
    assert a.per_request == b.per_request


# ------------------------------------------------------ overdue: drop --


def test_deadline_drop_streams_terminal_event(cfg, params):
    """A queued request whose deadline passes is dropped: it finishes
    with finish_reason='deadline' and its stream yields exactly one
    tokenless terminal event (a drop is an answer, not a hang)."""
    clock = StepClock()
    eng = _engine(cfg, params, clock=clock, max_batch=1,
                  scheduler="edf", overdue_policy="drop")
    blocker = eng.submit([1, 2, 3, 4], max_new_tokens=8)
    eng.step()  # blocker becomes resident (max_batch=1: queue blocks)
    victim = eng.submit([5, 6, 7], max_new_tokens=4, deadline_s=0.5)
    clock.advance(1.0)  # sail past the victim's deadline while queued
    eng.step()
    assert eng.finish_reason(victim) == FINISH_DEADLINE
    events = list(eng.stream(victim))
    assert len(events) == 1
    assert events[0].finished
    assert events[0].finish_reason == FINISH_DEADLINE
    assert events[0].token == NO_TOKEN
    assert eng.result(victim).generated == []
    tel = eng.telemetry
    assert tel["deadline_drops"] == 1
    assert tel["deadline_dropped"] == 1
    assert tel["deadline_missed"] >= 1
    # the blocker still completes untouched
    for _ in range(64):
        if not eng.has_work:
            break
        eng.step()
    assert len(eng.result(blocker).generated) == 8


def test_submit_deadline_validation(cfg, params):
    eng = _engine(cfg, params)
    with pytest.raises(ValueError, match="deadline_s"):
        eng.submit([1, 2], deadline_s=0.0)


def test_default_deadline_inherited_from_config(cfg, params):
    clock = StepClock(t0=5.0)
    eng = _engine(cfg, params, clock=clock, deadline_ms=250.0)
    h = eng.submit([1, 2, 3])
    assert eng.request(h).deadline_at == pytest.approx(5.25)
    # explicit per-request deadline overrides the config default
    h2 = eng.submit([1, 2, 3], deadline_s=2.0)
    assert eng.request(h2).deadline_at == pytest.approx(7.0)


# -------------------------------------------- overdue: demote / ignore --


@pytest.mark.parametrize("policy", ["demote", "ignore"])
def test_overdue_non_drop_policies_complete(cfg, params, policy):
    """Under demote/ignore an overdue queued request still runs to
    completion (counted as a miss, never dropped)."""
    clock = StepClock()
    eng = _engine(cfg, params, clock=clock, max_batch=1,
                  scheduler="edf", overdue_policy=policy)
    blocker = eng.submit([1, 2, 3, 4], max_new_tokens=4)
    eng.step()
    overdue = eng.submit([5, 6, 7], max_new_tokens=4, deadline_s=0.5)
    clock.advance(1.0)
    for _ in range(64):
        if not eng.has_work:
            break
        eng.step()
    assert eng.finish_reason(overdue) == "length"
    assert len(eng.result(overdue).generated) == 4
    tel = eng.telemetry
    assert tel["deadline_dropped"] == 0
    assert tel["deadline_missed"] == 1
    assert len(eng.result(blocker).generated) == 4


def _bare_sched(policy="drop", clock=None):
    """A DeadlineScheduler with no cache/slots behind it — scheduling
    against an empty slot list exercises only the queue-policy slice
    (drop/sort/demote happen before the admission loop ever runs)."""
    sc = ServeConfig(max_batch=2, max_seq_len=64, scheduler="edf",
                     overdue_policy=policy)
    caps = ExecutorCaps(
        max_batch=2, max_seq_len=64, decode_steps=1, buckets=(8,),
        bucketable=True, paged=False, bit_exact=True, prefix_cache=False,
    )
    return DeadlineScheduler(sc, caps, None, clock=clock)


def _queue_of(sched):
    for uid, dl in ((1, 1.0), (2, 5.0), (3, 9.0), (4, None)):
        sched.enqueue(Request(uid, [1], 1, None, deadline_at=dl))


def test_demote_orders_overdue_behind_feasible():
    """The demote reorder is pure queue policy: overdue requests land
    behind every still-feasible one, feasible stay EDF-sorted."""
    sched = _bare_sched("demote", clock=StepClock(t0=2.0))
    _queue_of(sched)
    decision = sched.schedule([])
    assert decision.dropped == []
    assert [r.uid for r in sched.queue] == [2, 3, 4, 1]


def test_ignore_keeps_pure_edf_order():
    sched = _bare_sched("ignore", clock=StepClock(t0=2.0))
    _queue_of(sched)
    decision = sched.schedule([])
    assert decision.dropped == []
    assert [r.uid for r in sched.queue] == [1, 2, 3, 4]


def test_drop_removes_only_overdue_from_queue():
    sched = _bare_sched("drop", clock=StepClock(t0=2.0))
    _queue_of(sched)
    decision = sched.schedule([])
    assert [r.uid for r in decision.dropped] == [1]
    assert [r.uid for r in sched.queue] == [2, 3, 4]
    assert sched.stats["deadline_drops"] == 1


def test_bad_overdue_policy_rejected():
    with pytest.raises(ValueError, match="overdue_policy"):
        _bare_sched("defer")


# -------------------------------------------- deadline-aware victims --


def _slot(uid, admit_seq, deadline_at):
    s = Slot(active=True)
    s.request = Request(uid, [1], 1, None, deadline_at=deadline_at)
    s.admit_seq = admit_seq
    return s


def test_edf_pick_victim_prefers_least_urgent():
    """Preemption under EDF evicts the least-urgent resident (deadline-
    less first, then latest deadline), not FIFO's youngest."""
    sched = DeadlineScheduler.__new__(DeadlineScheduler)
    slots = [
        _slot(1, admit_seq=3, deadline_at=1.0),   # most urgent, youngest
        _slot(2, admit_seq=1, deadline_at=9.0),
        _slot(3, admit_seq=2, deadline_at=None),  # deadline-less
    ]
    assert sched._pick_victim([0, 1, 2], slots) == 2
    assert sched._pick_victim([0, 1], slots) == 1
    assert sched._pick_victim([0], slots) == 0
    # FIFO's rule stays youngest-resident
    fifo = FifoScheduler.__new__(FifoScheduler)
    assert fifo._pick_victim([0, 1, 2], slots) == 0


def test_urgency_key():
    assert slo_mod._urgency(Request(1, [1], 1, None)) == math.inf
    assert slo_mod._urgency(Request(1, [1], 1, None, deadline_at=3.0)) == 3.0


# ------------------------------------------------- workloads / traces --


def test_poisson_workload_is_seeded_and_sorted():
    a = workloads.poisson(rate=5.0, n=20, vocab_size=64, seed=9,
                          deadline_s=(0.1, 2.0), shared_prefix=4)
    b = workloads.poisson(rate=5.0, n=20, vocab_size=64, seed=9,
                          deadline_s=(0.1, 2.0), shared_prefix=4)
    assert a == b
    assert all(x.at <= y.at for x, y in zip(a, a[1:]))
    assert all(0.1 <= ev.deadline_s <= 2.0 for ev in a)
    prefix = a[0].prompt[:4]
    assert all(ev.prompt[:4] == prefix for ev in a)
    c = workloads.poisson(rate=5.0, n=20, vocab_size=64, seed=10)
    assert c != a
    assert all(ev.deadline_s is None for ev in c)


def test_synchronous_workload_all_at_zero():
    evs = workloads.synchronous(n=5, vocab_size=32, seed=1)
    assert all(ev.at == 0.0 for ev in evs)


def test_poisson_rejects_bad_rate():
    with pytest.raises(ValueError, match="rate"):
        workloads.poisson(rate=0.0, n=1, vocab_size=8)


def test_trace_roundtrip(tmp_path):
    evs = workloads.poisson(rate=3.0, n=7, vocab_size=32, seed=2,
                            deadline_s=0.5, eos_id=1)
    path = str(tmp_path / "trace.jsonl")
    workloads.save_trace(evs, path)
    assert workloads.load_trace(path) == sorted(evs, key=lambda e: e.at)


def test_trace_bad_record_reports_line(tmp_path):
    path = tmp_path / "bad.jsonl"
    path.write_text('{"at": 0.0, "prompt": [1]}\n{"at": "x", "prompt": 3}\n')
    with pytest.raises(ValueError, match="bad.jsonl:2"):
        workloads.load_trace(str(path))


def test_step_clock_contract():
    clock = StepClock(t0=2.0)
    assert clock() == 2.0
    clock.advance(0.5)
    assert clock() == 2.5
    with pytest.raises(ValueError):
        clock.advance(-1.0)


def test_replay_rejects_step_cost_on_wall_clock(cfg, params):
    eng = _engine(cfg, params)  # default wall clock
    with pytest.raises(ValueError, match="step_cost"):
        workloads.replay(eng, [], step_cost=0.1)


class _NullEngine:
    """Submit-and-forget engine stub (never has work), isolating
    replay's idle-gap clock handling from the model entirely."""

    def __init__(self, clock):
        self.clock = clock
        self._reqs = {}
        self._uid = 0

    @property
    def has_work(self):
        return False

    def submit(self, prompt, *, max_new_tokens=16, eos_id=None,
               deadline_s=None):
        self._uid += 1
        now = self.clock()
        self._reqs[self._uid] = types.SimpleNamespace(
            uid=self._uid, generated=[], preemptions=0, finished_at=now,
            deadline_at=None if deadline_s is None else now + deadline_s,
        )
        return types.SimpleNamespace(uid=self._uid)

    def result(self, handle):
        return self._reqs[handle.uid]

    def finish_reason(self, handle):
        return "length"

    def step(self):  # pragma: no cover - has_work is always False
        raise AssertionError("stub engine has no work to step")


def test_replay_idle_gap_survives_float_cancellation():
    """Regression: on a reused clock far from zero (a second benchmark
    wave), ``at - (clock() - t_start)`` cancels catastrophically and the
    residual gap can round below one ulp of the clock value — advance()
    then no-ops and the idle loop used to spin forever.  With enough
    arrivals, some gap always lands in that window pre-fix."""
    t0 = 1.9861456435215117  # clock value from the wave that hung
    clock = StepClock(t0=t0)
    events = workloads.poisson(rate=200.0, n=256, vocab_size=64, seed=1)
    rep = workloads.replay(_NullEngine(clock), events)
    assert rep.requests == 256
    # the clock crossed every arrival (ulp-nudge error is invisible at
    # any realistic tolerance)
    assert clock() - t0 >= events[-1].at - 1e-9


# ------------------------------------------------ jit budget with SLO --


def test_jit_budget_with_tracer_and_edf(cfg, params):
    """The tracer fences and the EDF policy reorders — neither may mint
    programs: the jit caches stay at len(prefill_buckets) prefill + 1
    decode (+1 extend) exactly as without them (CI-enforced)."""
    clock = StepClock()
    eng = _engine(cfg, params, clock=clock, scheduler="edf",
                  trace_phases=True, kv_layout="paged",
                  kv_prefix_cache=True, kv_preemption=True)
    events = workloads.poisson(
        rate=50.0, n=10, vocab_size=cfg.vocab_size, seed=0,
        max_new_tokens=6, deadline_s=(0.5, 5.0), shared_prefix=8,
    )
    workloads.replay(eng, events, step_cost=0.1)
    assert eng._tracer.fences > 0  # the fenced path was actually live
    tel = eng.telemetry
    buckets = eng.executor.buckets
    assert tel["prefill_compiles"] <= len(buckets)
    assert tel["decode_compiles"] == 1

    def programs(fn):
        size = getattr(fn, "_cache_size", None)
        return size() if callable(size) else 1

    ex = eng.executor
    assert sum(programs(f) for f in ex._prefill_fn.values()) <= len(buckets)
    assert programs(ex._decode_fn) == 1
    if ex._extend_fn is not None:
        assert programs(ex._extend_fn) <= 1


# ------------------------------------------------------- matrix gate --


def test_matrix_check_flags_regressions():
    from benchmarks import matrix

    baseline = {"cells": [
        {"cell": "a/float/paged/none", "us_per_token": 100.0},
        {"cell": "b/float/paged/none", "us_per_token": 200.0},
        {"cell": "only/in/baseline", "us_per_token": 50.0},
    ]}
    fresh = [
        {"cell": "a/float/paged/none", "us_per_token": 115.0},  # +15%: ok
        {"cell": "b/float/paged/none", "us_per_token": 250.0},  # +25%: fail
        {"cell": "only/in/fresh", "us_per_token": 999.0},       # skipped
    ]
    failures = matrix.check(fresh, baseline, tolerance=0.2)
    assert len(failures) == 1
    assert "b/float/paged/none" in failures[0]
    assert matrix.check(fresh, baseline, tolerance=0.5) == []
    assert matrix.check([], baseline) == []


def test_matrix_cells_and_trajectory(tmp_path):
    from benchmarks import matrix

    # cell -> ServeConfig resolution honors each ablation
    full = matrix._serve_cfg(matrix.Cell(), None)
    assert full.kv_layout == "paged" and full.kv_prefix_cache
    assert full.prefill_chunk == 8 and full.cache_extend
    nop = matrix._serve_cfg(matrix.Cell(ablation="no-paging"), None)
    assert nop.kv_layout == "dense" and not nop.kv_prefix_cache
    noc = matrix._serve_cfg(matrix.Cell(ablation="no-chunk"), None)
    assert noc.prefill_chunk is None
    noe = matrix._serve_cfg(matrix.Cell(ablation="no-extend"), None)
    assert not noe.cache_extend
    nopfx = matrix._serve_cfg(matrix.Cell(ablation="no-prefix"), None)
    assert not nopfx.kv_prefix_cache and nopfx.kv_layout == "paged"
    with pytest.raises(ValueError, match="ablation"):
        matrix._serve_cfg(matrix.Cell(ablation="no-such"), None)

    # trajectory is append-only and legacy dicts are migrated
    path = str(tmp_path / "BENCH_matrix.json")
    results = [{"cell": "a/float/paged/none", "us_per_token": 1.0,
                "cached": False}]
    matrix.record(path, "smoke", results)
    matrix.record(path, "smoke", results)
    history = matrix.load_trajectory(path)
    assert len(history) == 2
    assert all(e["bench"] == "matrix" for e in history)
    assert all("date" in e and "git_rev" in e for e in history)
    assert "cached" not in history[0]["cells"][0]


def test_serving_trajectory_migrates_legacy_dict(tmp_path):
    import json

    from benchmarks import serving_throughput as bench

    path = tmp_path / "BENCH_serving.json"
    legacy = {"bench": "serving_throughput", "args": {}, "before": [],
              "after": []}
    path.write_text(json.dumps(legacy))
    history = bench.load_trajectory(str(path))
    assert history == [legacy]
    assert bench.load_trajectory(str(tmp_path / "missing.json")) == []

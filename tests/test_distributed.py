"""Distribution layer: sharding rules (single-device), multi-device
collectives + dry-run (subprocess with forced host device count)."""

import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.launch.mesh import make_mesh as make_compat_mesh
from repro.configs.base import ParallelismConfig
from repro.distributed.sharding import ShardingRules

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))


@pytest.fixture(scope="module")
def rules():
    mesh = make_compat_mesh((1, 1), ("data", "model"))
    return ShardingRules(mesh=mesh, plan=ParallelismConfig())


def test_spec_for_divisibility_fallback(rules):
    # heads=40 doesn't divide model=1? (1 divides everything) — use a fake
    # mesh-shape check through the public API instead:
    spec = rules.spec_for(("embed", "heads"), (64, 40))
    assert isinstance(spec, P)


def test_spec_rank_matches():
    mesh = make_compat_mesh((1, 1), ("data", "model"))
    r = ShardingRules(mesh=mesh)
    spec = r.spec_for(("layers", "embed", "mlp"), (4, 32, 64))
    assert len(spec) == 3


def test_param_tree_shardings_cover_all_leaves(rules):
    from repro.models import lm
    from repro.models import params as params_lib

    cfg = configs.get_config("granite-8b", reduced=True)
    spec = lm.param_spec(cfg)
    sh = rules.tree_shardings(
        params_lib.abstract_params(spec), params_lib.logical_axes(spec)
    )
    n_params = len(jax.tree.leaves(params_lib.abstract_params(spec)))
    n_shardings = len(jax.tree.leaves(sh, is_leaf=lambda x: hasattr(x, "spec")))
    assert n_params == n_shardings


def _run(script: str, devices: int = 8) -> str:
    code = (
        "import os\n"
        f"os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count={devices}'\n"
        + script
    )
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env=ENV, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_ring_collective_matmul_multidevice():
    out = _run(
        """
import jax, jax.numpy as jnp, numpy as np
from repro.distributed.collectives import ring_collective_matmul
from repro.launch.mesh import make_mesh as make_compat_mesh
mesh = make_compat_mesh((2, 4), ("data", "model"))
rng = np.random.default_rng(0)
x = jnp.asarray(rng.normal(size=(16, 32)), jnp.float32)
w = jnp.asarray(rng.normal(size=(32, 24)), jnp.float32)
with mesh:
    out = ring_collective_matmul(mesh, x, w, axis="model")
err = float(jnp.max(jnp.abs(out - x @ w)))
print("ERR", err)
assert err < 1e-4
"""
    )
    assert "ERR" in out


def test_compressed_allreduce_error_feedback_converges():
    out = _run(
        """
import jax, jax.numpy as jnp, numpy as np
from repro.distributed.collectives import make_compressed_grad_allreduce, init_error_buffers
from repro.launch.mesh import make_mesh as make_compat_mesh
mesh = make_compat_mesh((8,), ("data",))
g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(32, 32)), jnp.float32)}
err = init_error_buffers(g)
f = make_compressed_grad_allreduce(mesh, axis_name="data")
# accumulate compressed means over steps: error feedback keeps the
# long-run average unbiased
total_c, total_e = jnp.zeros((32, 32)), jnp.zeros((32, 32))
with mesh:
    for i in range(20):
        mean, err = f(g, err)
        total_c += mean["w"]
        total_e += g["w"]
bias = float(jnp.max(jnp.abs(total_c - total_e)) / jnp.max(jnp.abs(total_e)))
print("BIAS", bias)
assert bias < 0.01
"""
    )
    assert "BIAS" in out


@pytest.mark.slow
def test_dryrun_tiny_mesh_subprocess(tmp_path):
    """End-to-end dry-run (lower+compile+roofline) for one arch on a tiny
    mesh carved from 512 forced host devices."""
    out = subprocess.run(
        [
            sys.executable, "-m", "repro.launch.dryrun",
            "--arch", "granite-8b", "--shape", "decode_32k",
            "--mesh", "tiny", "--reduced", "--out", str(tmp_path), "--force",
        ],
        capture_output=True, text=True, env=ENV, timeout=900,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    path = tmp_path / "granite-8b__decode_32k__tiny.json"
    data = json.loads(path.read_text())
    assert data["status"] == "ok"
    assert data["terms"]["dominant"] in ("compute", "memory", "collective")
    assert data["flops"] > 0


def test_all_cells_accounted():
    cells = configs.dryrun_cells()
    assert len(cells) == 40
    runnable = [c for c in cells if c[2]]
    skipped = [c for c in cells if not c[2]]
    assert len(runnable) == 32
    assert len(skipped) == 8
    for _, _, _, reason in skipped:
        assert reason

"""Latency-observability tests: the per-step phase tracer (off costs
nothing — zero fences, no phase state; on — phases sum within step
wall time and land in Engine.telemetry), TokenEvent stream integrity
(monotone timestamps, gapless indices), merged-telemetry key-collision
protection, and the two-key queue-wait attribution for preempted
requests (restamped vs created_at-anchored)."""

import time

import jax
import numpy as np
import pytest

from repro import configs
from repro.configs.base import ServeConfig
from repro.models import lm
from repro.serve import Engine, StepClock
from repro.serve.phases import (
    NULL_TRACER,
    PHASES,
    NullTracer,
    PhaseTracer,
    _percentile,
    make_tracer,
)

KEY = jax.random.PRNGKey(17)


@pytest.fixture(scope="module")
def cfg():
    return configs.get_config("granite-8b", reduced=True)


@pytest.fixture(scope="module")
def params(cfg):
    return lm.init_params(cfg, KEY)


def _engine(cfg, params, **kw):
    base = dict(
        max_batch=2, max_seq_len=64, prefill_buckets=(8, 16, 32),
        decode_steps=2, temperature=0.0,
    )
    clock = kw.pop("clock", None)
    base.update(kw)
    return Engine(cfg, params, ServeConfig(**base), clock=clock)


PROMPTS = ([5, 9, 3, 7], [11, 2, 6], [1, 2, 3, 4, 5, 6, 7, 8], [4, 4])


# ------------------------------------------------------ tracer unit --


def test_null_tracer_is_inert():
    tr = make_tracer(False)
    assert tr is NULL_TRACER
    assert isinstance(tr, NullTracer)
    assert not tr.enabled
    # the fence is a pass-through that never imports jax / touches
    # device; phase() hands back one shared no-op context manager
    sentinel = object()
    assert tr.fence(sentinel) is sentinel
    assert tr.phase("device") is tr.phase("sample")
    with tr.phase("anything"):
        pass
    tr.begin_step(), tr.end_step()
    assert tr.records() == []
    assert tr.summary() == {}


def test_phase_tracer_accumulates_and_bounds_ring():
    tr = PhaseTracer(ring=3)
    for step in range(5):
        tr.begin_step()
        with tr.phase("schedule"):
            pass
        # re-entrant: two dispatch phases in one step sum
        with tr.phase("dispatch"):
            time.sleep(0.001)
        with tr.phase("dispatch"):
            time.sleep(0.001)
        tr.end_step()
    recs = tr.records()
    assert len(recs) == 3  # ring bounded
    for rec in recs:
        assert rec["dispatch"] >= 0.002
        assert rec["wall"] >= rec["dispatch"]
    s = tr.summary()
    assert s["steps"] == 3 and s["ring"] == 3
    assert s["dispatch"]["n"] == 3
    assert s["dispatch"]["p50_ms"] >= 2.0
    assert s["unattributed_s"] >= 0.0
    # phases absent from every step don't appear in the summary
    assert "device" not in s


def test_phase_tracer_validates_ring():
    with pytest.raises(ValueError, match="ring"):
        PhaseTracer(ring=0)


def test_percentile_nearest_rank():
    xs = [1.0, 2.0, 3.0, 4.0]
    assert _percentile([], 50) == 0.0
    assert _percentile(xs, 0) == 1.0
    assert _percentile(xs, 100) == 4.0
    assert _percentile(xs, 50) == 3.0  # nearest rank rounds up here
    assert _percentile([7.0], 99) == 7.0


# -------------------------------------------------- off costs nothing --


def test_tracer_off_by_default_no_fences_no_phases(cfg, params):
    """An untraced engine runs the shared NULL_TRACER: no fences, no
    phase records, empty 'phases' telemetry — the hot loop is the
    pre-tracer code path."""
    eng = _engine(cfg, params)
    assert eng.executor.tracer is NULL_TRACER
    for p in PROMPTS:
        eng.submit(list(p), max_new_tokens=6)
    eng.generate()
    assert eng.telemetry["phases"] == {}
    assert not hasattr(NULL_TRACER, "fences")  # nothing even counts


def test_tracer_off_throughput_guard(cfg, params):
    """The off path must not tax throughput: an untraced run of the same
    workload is not meaningfully slower than a traced (fenced) one.
    The traced run pays for fencing, so the generous bound only trips
    if the off path somehow grew overhead."""

    def wall(trace):
        eng = _engine(cfg, params, trace_phases=trace)
        for p in PROMPTS:
            eng.submit(list(p), max_new_tokens=8)
        eng.generate()  # warmup: compiles
        for p in PROMPTS:
            eng.submit(list(p), max_new_tokens=8)
        t0 = time.perf_counter()
        eng.generate()
        return time.perf_counter() - t0

    wall_on = wall(True)
    wall_off = wall(False)
    assert wall_off <= wall_on * 1.5 + 0.1


# ----------------------------------------------------- traced engine --


def test_traced_engine_phases_sum_within_wall(cfg, params):
    eng = _engine(cfg, params, trace_phases=True, phase_ring=64)
    for p in PROMPTS:
        eng.submit(list(p), max_new_tokens=6)
    eng.generate()
    tr = eng._tracer
    assert tr.enabled and tr.fences > 0
    recs = tr.records()
    assert recs
    for rec in recs:
        attributed = sum(v for k, v in rec.items() if k != "wall")
        # phases are disjoint spans inside the step: their sum can never
        # exceed the step's wall time (small epsilon for timer jitter)
        assert attributed <= rec["wall"] + 1e-4
        assert set(rec) - {"wall"} <= set(PHASES)
        assert "schedule" in rec
    ph = eng.telemetry["phases"]
    assert ph["steps"] == len(recs)
    for name in ("schedule", "device", "wall"):
        assert ph[name]["n"] > 0
        assert ph[name]["p50_ms"] <= ph[name]["p95_ms"] <= ph[name]["p99_ms"]
    # decode steps ran, so every phase of the model appeared somewhere
    assert {"host_prep", "dispatch", "device", "sample"} <= set(ph)


def test_phase_ring_knob_respected(cfg, params):
    eng = _engine(cfg, params, trace_phases=True, phase_ring=2)
    for p in PROMPTS:
        eng.submit(list(p), max_new_tokens=8)
    eng.generate()
    assert len(eng._tracer.records()) == 2
    assert eng.telemetry["phases"]["ring"] == 2


# ------------------------------------------------- stream integrity --


def test_token_events_monotone_and_gapless(cfg, params):
    """Per stream: timestamps never go backwards and indices count
    0,1,2,... with the final event flagged exactly once."""
    eng = _engine(cfg, params)
    handles = [eng.submit(list(p), max_new_tokens=7) for p in PROMPTS]
    for h in handles:
        events = list(eng.stream(h))
        assert events, "every request generates at least one token here"
        assert [ev.index for ev in events] == list(range(len(events)))
        assert all(a.ts <= b.ts for a, b in zip(events, events[1:]))
        assert [ev.finished for ev in events].count(True) == 1
        assert events[-1].finished
        assert events[-1].finish_reason == "length"
        assert all(ev.uid == h.uid for ev in events)


def test_token_event_ts_uses_engine_clock(cfg, params):
    clock = StepClock(t0=100.0)
    eng = _engine(cfg, params, clock=clock)
    h = eng.submit([1, 2, 3], max_new_tokens=4)
    events = list(eng.stream(h))
    assert all(ev.ts == 100.0 for ev in events)  # clock never advanced
    assert eng.result(h).finished_at == 100.0


# ------------------------------------------- telemetry key integrity --


@pytest.mark.parametrize("kv_host_pages", [0, 16])
def test_merged_telemetry_has_no_key_collisions(cfg, params, kv_host_pages):
    """Engine.telemetry merges four dicts + the SLO counters + the
    phases view; a key collision would silently shadow one layer's
    counter with another's.  Runs tier-off and tier-on: the victim
    tier's swap_outs/swap_ins/host_* keys live in the cache.stats
    layer and must stay disjoint from every other layer."""
    eng = _engine(cfg, params, kv_layout="paged", kv_prefix_cache=True,
                  kv_preemption=True, kv_host_pages=kv_host_pages)
    for p in PROMPTS:
        eng.submit(list(p), max_new_tokens=6)
    eng.generate()
    layers = {
        "executor.tel": set(eng.executor.tel),
        "scheduler.stats": set(eng.scheduler.stats),
        "cache.stats": set(eng.executor.cache_mgr.stats().as_dict()),
        "run_tel": set(eng._run_tel),
        "slo": set(eng._slo),
        "reserved": {"phases"},
    }
    names = sorted(layers)
    for i, a in enumerate(names):
        for b in names[i + 1:]:
            overlap = layers[a] & layers[b]
            assert not overlap, f"{a} and {b} both export {sorted(overlap)}"
    merged = eng.telemetry
    for keys in layers.values():
        assert keys <= set(merged)
    # the tier counters ride the cache.stats layer whether the tier is
    # configured or not (off: all-zero), so dashboards can always key on
    # them
    assert {"swap_outs", "swap_ins", "host_evictions", "host_pages_used",
            "host_pages_capacity", "swap_latency_s"} <= set(merged)
    assert merged["host_pages_capacity"] == kv_host_pages


# --------------------------------------------------- wait attribution --


def test_preemption_wait_attribution_two_keys(cfg, params):
    """Satellite: preempted requests report both waits — the restamped
    (submitted_at) wait that measures time-to-resume, and the
    created_at-anchored wait that keeps the full time-in-system
    (strictly larger once a preemption restamped the clock)."""
    clock = StepClock()
    eng = _engine(
        cfg, params, clock=clock, kv_layout="paged", kv_page_size=8,
        kv_pages=5, max_seq_len=32, kv_prefix_cache=True,
        kv_preemption=True,
    )
    rng = np.random.default_rng(0)
    handles = [
        eng.submit(list(rng.integers(0, cfg.vocab_size, 6)),
                   max_new_tokens=20)
        for _ in range(4)
    ]
    steps = 0
    while eng.has_work and steps < 400:
        eng.step()
        clock.advance(0.01)  # deterministic nonzero waits
        steps += 1
    assert not eng.has_work
    tel = eng.telemetry
    assert tel["preemptions"] > 0  # the pool genuinely thrashed
    preempted = [eng.result(h) for h in handles
                 if eng.result(h).preemptions > 0]
    assert preempted
    # restamping happened: the resumed admission's submitted_at moved
    # past the original created_at
    assert all(r.submitted_at > r.created_at for r in preempted)
    # the created-anchored total includes prior residencies, so it
    # strictly exceeds the restamped total once anything was preempted
    assert (tel["queue_wait_created_s_total"]
            > tel["queue_wait_s_total"])
    assert tel["queue_wait_created_s_total"] >= 0.0
    eng.generate()  # idle drain: stamps the run-level means
    assert (eng._run_tel["queue_wait_created_s_mean"]
            >= eng._run_tel["queue_wait_s_mean"])


def test_wait_keys_equal_without_preemption(cfg, params):
    clock = StepClock()
    eng = _engine(cfg, params, clock=clock)
    for p in PROMPTS:
        eng.submit(list(p), max_new_tokens=4)
    while eng.has_work:
        eng.step()
        clock.advance(0.01)
    tel = eng.telemetry
    assert tel["preemptions"] == 0
    assert tel["queue_wait_created_s_total"] == pytest.approx(
        tel["queue_wait_s_total"]
    )

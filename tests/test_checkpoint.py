"""Checkpointer: roundtrip, async, atomicity, keep-k GC, crc32 integrity,
elastic restore."""

import os
import zlib

import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.mesh import make_mesh as make_compat_mesh
from repro.checkpoint import Checkpointer


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "params": {
            "w": jnp.asarray(rng.normal(size=(8, 8)), jnp.float32),
            "b": jnp.asarray(rng.normal(size=(8,)), jnp.bfloat16),
        },
        "opt": {"step": jnp.asarray(7, jnp.int32)},
    }


def test_roundtrip(tmp_path):
    ckpt = Checkpointer(str(tmp_path), keep=2)
    tree = _tree()
    ckpt.save(7, tree, blocking=True)
    restored = ckpt.restore(tree)
    for a, b in zip(
        jnp.asarray(tree["params"]["w"]).flatten(),
        jnp.asarray(restored["params"]["w"]).flatten(),
    ):
        assert float(a) == float(b)
    assert restored["params"]["b"].dtype == jnp.bfloat16
    assert int(restored["opt"]["step"]) == 7


def test_async_save_then_wait(tmp_path):
    ckpt = Checkpointer(str(tmp_path), keep=2)
    ckpt.save(1, _tree())  # non-blocking
    ckpt.wait()
    assert ckpt.latest_step() == 1


def test_keep_k_garbage_collection(tmp_path):
    ckpt = Checkpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ckpt.save(s, _tree(s), blocking=True)
    assert ckpt.all_steps() == [3, 4]


def test_no_partial_checkpoints_visible(tmp_path):
    ckpt = Checkpointer(str(tmp_path), keep=3)
    ckpt.save(5, _tree(), blocking=True)
    names = os.listdir(str(tmp_path))
    assert not any(n.endswith(".tmp") for n in names)


def test_crc_corruption_detected(tmp_path):
    ckpt = Checkpointer(str(tmp_path), keep=2)
    tree = _tree()
    ckpt.save(3, tree, blocking=True)
    # corrupt the npz payload
    path = os.path.join(str(tmp_path), "step_00000003", "proc_00000.npz")
    data = np.load(path)
    arrs = {k: data[k].copy() for k in data.files}
    key = [k for k in arrs if k.endswith("w")][0]
    arrs[key][0, 0] += 1.0
    np.savez(path, **arrs)
    with pytest.raises(IOError, match="corruption"):
        ckpt.restore(tree)


def test_restore_latest_of_many(tmp_path):
    ckpt = Checkpointer(str(tmp_path), keep=5)
    for s in (10, 20, 30):
        ckpt.save(s, _tree(s), blocking=True)
    restored = ckpt.restore(_tree())
    expected = _tree(30)
    np.testing.assert_allclose(
        np.asarray(restored["params"]["w"]), np.asarray(expected["params"]["w"])
    )


def test_elastic_restore_with_shardings(tmp_path):
    """Restore with explicit (single-device) shardings — the elastic path."""
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    ckpt = Checkpointer(str(tmp_path), keep=2)
    tree = _tree()
    ckpt.save(1, tree, blocking=True)
    mesh = make_compat_mesh((1,), ("data",))
    sh = NamedSharding(mesh, P())
    shardings = {
        "params": {"w": sh, "b": sh},
        "opt": {"step": sh},
    }
    restored = ckpt.restore(tree, shardings=shardings)
    assert restored["params"]["w"].sharding == sh

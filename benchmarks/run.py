"""Benchmark orchestrator — one benchmark per paper table/figure.

    latency_tables      <-> paper Tables II-IV   (latency vs reuse factor)
    auc_vs_bits         <-> paper Figs. 9-11     (fidelity vs fractional bits)
    resources           <-> paper Figs. 12-14    (resources vs reuse factor)
    kernel_micro        <-> per-kernel validation
    roofline_table      <-> EXPERIMENTS.md §Roofline (from the dry-run cache)
    serving_throughput  <-> engine v2 tokens/s (batch x bucket x decode_steps)
    matrix              <-> configs x policies x layouts x ablations grid

Prints ``name,us_per_call,derived`` style CSV blocks per benchmark.
"""

from __future__ import annotations

import sys
import time


def main() -> None:
    from benchmarks import (
        auc_vs_bits,
        kernel_micro,
        latency_tables,
        matrix,
        resources,
        roofline_table,
        serving_throughput,
    )

    benches = [
        ("latency_tables", latency_tables.run),
        ("resources", resources.run),
        ("kernel_micro", kernel_micro.run),
        ("auc_vs_bits", auc_vs_bits.run),
        ("roofline_table", roofline_table.run),
        ("serving_throughput", serving_throughput.run),
        ("matrix", matrix.run),
    ]
    only = sys.argv[1] if len(sys.argv) > 1 else None
    failures = 0
    for name, fn in benches:
        if only and name != only:
            continue
        t0 = time.time()
        print(f"===== {name} =====")
        try:
            for row in fn():
                print(row)
            print(f"# {name}: OK in {time.time()-t0:.1f}s")
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"# {name}: FAILED {type(e).__name__}: {e}")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()

"""Serving-engine throughput: tokens/s across batch x bucket x decode_steps,
with KV-cache occupancy (bytes + page utilization) per sweep point.

The continuous-batching counterpart of the paper's latency tables — the
engine's hot loop (bucketed batched prefill + scan decode) swept over the
two knobs that bound its compiled-program set and host-dispatch overhead,
on a physics-scale LM (paper Table I dims as a causal LM) and the reduced
``minicpm-2b`` config.  ``--kv-layout paged`` runs the same sweep through
the block-table page pool (serve/kv_cache.py) instead of dense slabs.

``--api stream`` drives the measured wave through the client-facing
``Engine.stream`` API instead of the batch ``Engine.generate`` wrapper
and adds latency percentiles computed from ``TokenEvent`` timestamps:
``ttft_ms_p50/p95`` (submit -> first token) and ``itl_ms_p50/p95``
(inter-token gaps within a request; with ``decode_steps`` tokens
arriving per host dispatch, intra-dispatch gaps are ~0 and the p95
exposes the dispatch boundary).

``--workload prefix`` switches the request stream from uniform random
prompts to a prefix-heavy one — every prompt starts with the same long
preamble, the physics pattern of a fixed detector-geometry prefix ahead
of per-event payloads — with the prefix cache and page-aware preemption
enabled, and the derived column gains
``prefix_hit_rate=<hits/queries>;prefill_tokens_saved=<tokens never
recomputed>;preemptions=<count>``.

``--workload poisson`` replaces the submit-everything closed loop with a
seeded open-loop arrival process (serve/workloads.py): requests arrive
on a virtual engine clock paced by measured step wall time, optionally
carrying deadlines (``--deadline-ms``), and the derived column gains
``miss_rate``/``deadline_dropped``.  With ``--scheduler edf`` the sweep
measures the SLO policy instead of FIFO.

``--trace-phases`` turns on the per-step phase tracer
(serve/phases.py); the derived column gains ``ph_<phase>_p50``/``_p95``
millisecond columns for schedule / host_prep / dispatch / device /
sample.  Fencing serializes dispatch, so tok/s measured with tracing on
is an instrumented number — compare like with like.  With
``--phase-mode overlap`` the tracer never fences and the derived column
instead gains ``device_overlap_s`` / ``host_bubble_s`` /
``overlap_efficiency`` — how much of the host loop the device hid,
which is the number ``--async-loop`` exists to raise (and the matrix
``--check`` gate can guard).

``--workload multi_tenant`` replays the warm-prefix multi-tenant stream
(serve/workloads.py): Poisson arrivals cycling over four distinct
seeded tenant preambles, against a device page pool deliberately sized
*below* the warm working set, so tenant prefixes churn off the LRU
between visits.  With ``--kv-host-pages N`` the evicted prefixes spill
to the host-memory victim tier and swap back on re-arrival; the derived
column gains ``swap_outs``/``swap_ins``/``swap_hit_rate``/
``prefill_tokens_saved``/``host_pages_used``.  ``--record --ablation
victim_tier`` appends a tier-off vs tier-on before/after entry on that
workload — the number the tier exists to raise is
``prefill_tokens_saved`` at identical token output.

``--speculative`` turns on draft-propose/target-verify speculative
decoding (self-draft, ``--spec-tokens`` per verify step); the derived
column gains ``draft_tokens_proposed``/``draft_tokens_accepted``/
``acceptance_rate``/``spec_dispatches``.  ``--temperature-mix 0,0.7``
cycles per-request sampling temperatures across the wave (sampled rows
get deterministic per-request seeds, so the wave stays reproducible);
``--n-best N`` fans every prompt into N siblings that share generated
KV pages (forces the paged layout) and the derived column gains
``forks``/``gen_pages_shared``.  ``--record --ablation speculative``
appends a plain-decode vs speculative before/after entry (after
records carry ``acceptance_rate``; with ``--api stream`` both sides
carry ``itl_ms_p95``).

CSV rows: ``name,us_per_call,derived`` where ``us_per_call`` is mean
microseconds per generated token and ``derived`` packs
``tok_s=<tokens/s>;prefill_compiles=<n>;decode_compiles=<n>;``
``kv_layout=<dense|paged>;kv_mib=<cache MiB>;page_util_peak=<peak
pages-in-use / capacity>``.
"""

from __future__ import annotations

import jax
import numpy as np

from repro import configs
from repro.configs.base import ModelConfig, ServeConfig
from repro.models import lm
from repro.serve import Engine, SamplingParams, workloads


def physics_scale_lm() -> ModelConfig:
    """The paper's b-tagging-scale transformer (Table I: d=64, 3 blocks)
    recast as a tiny causal LM so it can drive the serving engine."""
    return ModelConfig(
        name="physics-scale-lm",
        family="dense",
        n_layers=3,
        d_model=64,
        n_heads=8,
        n_kv_heads=8,
        d_ff=128,
        vocab_size=256,
        dtype="float32",
    )


def _page_util_peak(tel: dict) -> float:
    """Peak page utilization; 0.0 for degenerate pools (a zero-capacity
    or dense-layout stats row must never divide by zero)."""
    capacity = tel.get("pages_capacity", 0)
    if capacity <= 0:
        return 0.0
    return tel.get("pages_in_use_peak", 0) / capacity


def _stream_wave(eng: Engine, handles) -> tuple[list[float], list[float]]:
    """Drain every handle's stream; return (per-request TTFT seconds,
    inter-token gaps in seconds).  Event timestamps are stamped when each
    dispatch's results reach the host, so gaps measure real host-loop
    latency regardless of which stream performed the pump."""
    ttfts: list[float] = []
    gaps: list[float] = []
    for h in handles:
        last_ts = None
        for ev in eng.stream(h):
            if last_ts is None:
                # created_at is never restamped; submitted_at is (the
                # preemption requeue resets the queue-wait clock)
                ttfts.append(ev.ts - eng.request(h).created_at)
            else:
                gaps.append(ev.ts - last_ts)
            last_ts = ev.ts
    return ttfts, gaps


def _sweep_one(name, cfg, params, *, max_batch, buckets, decode_steps,
               policy=None, kv_layout="dense", workload="uniform",
               api="batch", n_requests=8, max_new=16, seed=0,
               cache_extend=True, scheduler="fifo", deadline_ms=None,
               trace_phases=False, async_loop=False, phase_mode="fenced",
               repeats=1, speculative=False, spec_tokens=4,
               temperature_mix=None, n_best=1, kv_host_pages=0):
    prefix_mode = workload == "prefix"
    poisson_mode = workload == "poisson"
    mt_mode = workload == "multi_tenant"
    clock = workloads.StepClock() if (poisson_mode or mt_mode) else None
    eng = Engine(
        cfg, params,
        ServeConfig(
            max_batch=max_batch, max_seq_len=64,
            prefill_buckets=buckets, decode_steps=decode_steps,
            policy=policy, kv_layout=kv_layout, kv_page_size=16,
            # multi-tenant: one spare page above worst-case residency, so
            # the warm tenant prefixes (4 tenants x 2 pages) cannot all
            # stay device-resident — the victim tier is what keeps them
            kv_pages=(max_batch * 4 + 2) if mt_mode else None,
            kv_prefix_cache=prefix_mode or mt_mode,
            kv_preemption=prefix_mode or mt_mode,
            kv_host_pages=kv_host_pages,
            cache_extend=cache_extend, scheduler=scheduler,
            deadline_ms=deadline_ms, trace_phases=trace_phases,
            async_loop=async_loop, phase_mode=phase_mode,
            speculative=speculative, spec_tokens=spec_tokens,
        ),
        clock=clock,
    )
    # prefix-heavy workload: one fixed detector-geometry-style preamble
    # (a whole page of it) shared by every request in every wave
    preamble = list(
        np.random.default_rng(seed + 7).integers(0, cfg.vocab_size, 16)
    )

    def wave(wave_seed):
        import time
        if poisson_mode or mt_mode:
            if mt_mode:
                # 2x arrivals over 4 tenants: every tenant re-arrives,
                # so each wave exercises evict -> spill -> swap-back
                events = workloads.multi_tenant(
                    rate=200.0, n=2 * n_requests,
                    vocab_size=cfg.vocab_size, seed=wave_seed,
                    tenants=4, preamble_len=32, prompt_len=(3, 13),
                    max_new_tokens=max_new,
                    deadline_s=(
                        None if deadline_ms is None else deadline_ms / 1e3
                    ),
                )
            else:
                events = workloads.poisson(
                    rate=200.0, n=n_requests, vocab_size=cfg.vocab_size,
                    seed=wave_seed, prompt_len=(3, 13),
                    max_new_tokens=max_new,
                    deadline_s=(
                        None if deadline_ms is None else deadline_ms / 1e3
                    ),
                )
            rep = workloads.replay(eng, events)
            return rep.host_wall_s, [], [], rep
        rng = np.random.default_rng(wave_seed)
        handles = []
        for j in range(n_requests):
            payload = list(
                rng.integers(0, cfg.vocab_size, int(rng.integers(3, 14)))
            )
            prompt = preamble + payload if prefix_mode else payload
            # --temperature-mix cycles per-request temperatures through
            # the wave (sampled rows carry a per-request seed, so mixed
            # waves stay deterministic per wave_seed)
            if temperature_mix:
                t = float(temperature_mix[j % len(temperature_mix)])
                sp = SamplingParams(
                    max_new_tokens=max_new, temperature=t,
                    seed=(wave_seed * 1000 + j) if t > 0 else None,
                )
            else:
                sp = SamplingParams(max_new_tokens=max_new)
            h = eng.submit(prompt, sp, n=n_best)
            handles.extend(h if isinstance(h, list) else [h])
        t0 = time.perf_counter()
        if api == "stream":
            ttfts, gaps = _stream_wave(eng, handles)
        else:
            eng.generate()
            ttfts, gaps = [], []
        return time.perf_counter() - t0, ttfts, gaps, None

    # warmup wave: same length distribution, so it compiles the full
    # bucket/decode program set — the measured waves are steady-state.
    # With repeats > 1 the median-wall wave's measurements are reported
    # (one noisy wave on a shared runner would otherwise dominate a
    # recorded before/after comparison)
    wave(seed)
    measured = []
    for r in range(repeats):
        tokens_before = eng.telemetry["tokens_generated"]
        wall_s, ttfts, gaps, rep = wave(seed + 1 + r)
        toks = eng.telemetry["tokens_generated"] - tokens_before
        measured.append((wall_s, toks, ttfts, gaps, rep))
    measured.sort(key=lambda m: m[0] / max(m[1], 1))
    wall_s, toks, ttfts, gaps, rep = measured[len(measured) // 2]
    tel = eng.telemetry
    us_per_tok = wall_s / max(toks, 1) * 1e6
    tok_s = toks / max(wall_s, 1e-9)
    derived = (
        f"tok_s={tok_s:.1f};"
        f"prefill_compiles={tel['prefill_compiles']};"
        f"decode_compiles={tel['decode_compiles']};"
        f"kv_layout={tel['kv_layout']};"
        f"kv_mib={tel['kv_bytes'] / 2**20:.2f};"
        f"page_util_peak={_page_util_peak(tel):.2f}"
    )
    if api == "stream":
        derived += (
            f";ttft_ms_p50={np.percentile(ttfts, 50)*1e3:.1f}"
            f";ttft_ms_p95={np.percentile(ttfts, 95)*1e3:.1f}"
            f";itl_ms_p50={np.percentile(gaps, 50)*1e3:.2f}"
            f";itl_ms_p95={np.percentile(gaps, 95)*1e3:.2f}"
        )
    if prefix_mode:
        derived += (
            f";prefix_hit_rate={tel['prefix_hit_rate']:.2f}"
            f";prefill_tokens_saved={tel['prefill_tokens_saved']}"
            f";prefix_tokens_shared={tel['prefix_tokens_shared']}"
            f";preemptions={tel['preemptions']}"
            f";extend_dispatches={tel['extend_dispatches']}"
        )
    if mt_mode:
        derived += (
            f";swap_outs={tel['swap_outs']}"
            f";swap_ins={tel['swap_ins']}"
            f";swap_hit_rate={tel['swap_ins'] / max(tel['swap_outs'], 1):.2f}"
            f";prefill_tokens_saved={tel['prefill_tokens_saved']}"
            f";prefix_hit_rate={tel['prefix_hit_rate']:.2f}"
            f";host_pages_used={tel['host_pages_used']}"
            f";host_evictions={tel['host_evictions']}"
        )
    if rep is not None:
        derived += (
            f";completed={rep.completed}"
            f";deadline_dropped={rep.dropped}"
            f";miss_rate={rep.miss_rate:.2f}"
        )
    if speculative:
        prop = tel["draft_tokens_proposed"]
        acc = tel["draft_tokens_accepted"]
        derived += (
            f";draft_tokens_proposed={prop}"
            f";draft_tokens_accepted={acc}"
            f";acceptance_rate={acc / max(prop, 1):.3f}"
            f";spec_dispatches={tel['spec_dispatches']}"
        )
    if n_best > 1:
        derived += (
            f";forks={tel['forks']}"
            f";gen_pages_shared={tel['gen_pages_shared']}"
        )
    if trace_phases:
        for ph, s in tel["phases"].items():
            if isinstance(s, dict):
                derived += (
                    f";ph_{ph}_p50={s['p50_ms']:.2f}"
                    f";ph_{ph}_p95={s['p95_ms']:.2f}"
                )
        if "overlap_efficiency" in tel["phases"]:
            # overlap-mode tracer: how much host time the device hid
            # (matrix --check can gate on overlap_efficiency regressions)
            derived += (
                f";device_overlap_s={tel['phases']['device_overlap_s']:.4f}"
                f";host_bubble_s={tel['phases']['host_bubble_s']:.4f}"
                f";overlap_efficiency="
                f"{tel['phases']['overlap_efficiency']:.3f}"
            )
    derived += f";async_loop={int(async_loop)}"
    return (
        f"serving_throughput,{name},b{max_batch},ds{decode_steps},"
        f"{us_per_tok:.1f},{derived}"
    )


def run(policy: str | None = None, kv_layout: str = "dense",
        workload: str = "uniform", api: str = "batch",
        cache_extend: bool = True, scheduler: str = "fifo",
        deadline_ms: float | None = None,
        trace_phases: bool = False, async_loop: bool = False,
        phase_mode: str = "fenced", repeats: int = 1,
        speculative: bool = False, spec_tokens: int = 4,
        temperature_mix=None, n_best: int = 1,
        kv_host_pages: int = 0) -> list[str]:
    if workload in ("prefix", "multi_tenant") and kv_layout == "dense":
        kv_layout = "paged"  # sharing needs pages; dense would be inert
    if n_best > 1 and kv_layout == "dense":
        kv_layout = "paged"  # generation-page sharing needs refcounted pages
    rows = ["bench,config,batch,decode_steps,us_per_token,derived"]
    archs = [
        ("physics_scale", physics_scale_lm()),
        ("minicpm_2b", configs.get_config("minicpm-2b", reduced=True)),
    ]
    buckets = (8, 16, 32)
    for name, cfg in archs:
        arch_policy = cfg.serve_policy if policy == "auto" else policy
        params = lm.init_params(cfg, jax.random.PRNGKey(0))
        for max_batch in (2, 4):
            for decode_steps in (1, 4):
                rows.append(
                    _sweep_one(
                        name, cfg, params,
                        max_batch=max_batch, buckets=buckets,
                        decode_steps=decode_steps, policy=arch_policy,
                        kv_layout=kv_layout, workload=workload, api=api,
                        cache_extend=cache_extend, scheduler=scheduler,
                        deadline_ms=deadline_ms,
                        trace_phases=trace_phases, async_loop=async_loop,
                        phase_mode=phase_mode, repeats=repeats,
                        speculative=speculative, spec_tokens=spec_tokens,
                        temperature_mix=temperature_mix, n_best=n_best,
                        kv_host_pages=kv_host_pages,
                    )
                )
    return rows


def _rows_to_records(rows: list[str]) -> list[dict]:
    """CSV rows -> dicts, with the packed derived column exploded."""
    records = []
    for row in rows[1:]:
        head, derived = row.rsplit(",", 1)
        bench, config, batch, steps, us_tok = head.split(",")
        rec = {
            "bench": bench, "config": config, "batch": batch,
            "decode_steps": steps, "us_per_token": float(us_tok),
        }
        for field in derived.split(";"):
            key, _, val = field.partition("=")
            try:
                rec[key] = int(val)
            except ValueError:
                try:
                    rec[key] = float(val)
                except ValueError:
                    rec[key] = val
        records.append(rec)
    return records


def _git_rev() -> str:
    """Short hash of the checkout a record was taken at (best effort —
    a trajectory entry must stay writable outside a git checkout)."""
    import os
    import subprocess

    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10,
            cwd=os.path.dirname(os.path.abspath(__file__)),
        )
        return out.stdout.strip() or "unknown"
    except (OSError, subprocess.SubprocessError):
        return "unknown"


def load_trajectory(path: str) -> list[dict]:
    """Read a BENCH_serving.json trajectory: a list of run entries,
    oldest first.  A legacy single-dict artifact (the pre-trajectory
    before/after schema) is wrapped as the list's first entry so old
    baselines keep their place in the history."""
    import json
    import os

    if not os.path.exists(path):
        return []
    with open(path) as f:
        doc = json.load(f)
    return [doc] if isinstance(doc, dict) else list(doc)


def record_trajectory(path: str, ablation: str = "cache_extend",
                      **run_kw) -> dict:
    """Append one timestamped run entry to the BENCH_serving.json
    trajectory (never overwrites: the file is a list of runs, each
    stamped with git rev + UTC date + the sweep args, so the perf
    history accumulates across PRs).  ``ablation`` picks the
    within-entry before/after axis:

    * ``"cache_extend"`` — off vs on (the historical entry schema).
    * ``"async_loop"`` — synchronous vs pipelined engine loop, same
      seeded workload; with ``api="stream"`` the before/after records
      carry ``itl_ms_p95``, the overlap loop's acceptance metric.
    * ``"speculative"`` — plain decode vs draft-propose/target-verify
      speculative decoding (self-draft), same seeded greedy workload;
      the after records carry ``acceptance_rate`` and — with
      ``api="stream"`` — the before/after ``itl_ms_p95`` comparison
      speculation exists to win.
    * ``"victim_tier"`` — host-memory victim tier off vs on, on the
      warm-prefix ``multi_tenant`` workload (forced if the caller left
      the workload at its default); the after records carry
      ``swap_hit_rate`` and the strictly-higher ``prefill_tokens_saved``
      the tier exists to buy at identical token output.
    """
    import datetime
    import json

    if ablation == "cache_extend":
        before = run(cache_extend=False, **run_kw)
        after = run(cache_extend=True, **run_kw)
    elif ablation == "async_loop":
        before = run(async_loop=False, **run_kw)
        after = run(async_loop=True, **run_kw)
    elif ablation == "speculative":
        before = run(speculative=False, **run_kw)
        after = run(speculative=True, **run_kw)
    elif ablation == "victim_tier":
        if run_kw.get("workload", "uniform") in ("uniform", None):
            run_kw["workload"] = "multi_tenant"
        if not run_kw.get("kv_host_pages"):
            run_kw["kv_host_pages"] = 32
        before = run(**{**run_kw, "kv_host_pages": 0})
        after = run(**run_kw)
    else:
        raise ValueError(
            f"ablation must be 'cache_extend', 'async_loop', "
            f"'speculative', or 'victim_tier', got {ablation!r}"
        )
    entry = {
        "bench": "serving_throughput",
        "date": datetime.datetime.now(datetime.timezone.utc).isoformat(
            timespec="seconds"
        ),
        "git_rev": _git_rev(),
        "ablation": ablation,
        "args": {k: v for k, v in run_kw.items()},
        "before": _rows_to_records(before),
        "after": _rows_to_records(after),
    }
    history = load_trajectory(path)
    history.append(entry)
    with open(path, "w") as f:
        json.dump(history, f, indent=2)
        f.write("\n")
    return entry


def main():
    import argparse
    import time

    ap = argparse.ArgumentParser()
    ap.add_argument("--policy", default=None,
                    help="precision policy preset applied to every sweep "
                         "point (float, int8_serve, paper_vu13p, ...) or "
                         "'auto' for each arch's recommended serve_policy")
    ap.add_argument("--kv-layout", default="dense",
                    choices=("dense", "paged"),
                    help="KV-cache storage layout (serve/kv_cache.py)")
    ap.add_argument("--api", default="batch", choices=("batch", "stream"),
                    help="drive the measured wave through Engine.generate "
                         "(batch) or Engine.stream (per-token events; adds "
                         "ttft/itl p50/p95 columns)")
    ap.add_argument("--workload", default="uniform",
                    choices=("uniform", "prefix", "poisson",
                             "multi_tenant"),
                    help="request stream: uniform random prompts, "
                         "prefix-heavy (shared preamble; enables the "
                         "prefix cache + preemption and reports hit rate "
                         "/ prefill tokens saved / preemption count), "
                         "poisson (seeded open-loop arrivals on a virtual "
                         "engine clock via serve/workloads.py; --api is "
                         "ignored, the replay driver consumes results), "
                         "or multi_tenant (warm-prefix tenant cycling "
                         "against a device pool below the warm working "
                         "set — the victim-tier exercise; reports "
                         "swap_outs/swap_ins/swap_hit_rate)")
    ap.add_argument("--kv-host-pages", type=int, default=0,
                    help="host-memory victim tier capacity in pages for "
                         "every sweep point (0 = off); pairs with "
                         "--workload multi_tenant")
    ap.add_argument("--scheduler", default="fifo",
                    choices=("fifo", "edf"),
                    help="admission policy for the swept engines")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request completion budget; with "
                         "--workload poisson the derived column gains "
                         "miss_rate / deadline_dropped")
    ap.add_argument("--trace-phases", action="store_true",
                    help="per-step phase tracing; derived gains "
                         "ph_<phase>_p50/_p95 ms columns (fenced — an "
                         "instrumented number, compare like with like)")
    ap.add_argument("--phase-mode", default="fenced",
                    choices=("fenced", "overlap"),
                    help="tracer mode under --trace-phases: overlap never "
                         "fences and adds device_overlap_s / host_bubble_s "
                         "/ overlap_efficiency derived columns")
    ap.add_argument("--async-loop", action="store_true",
                    help="pipelined engine loop (ServeConfig.async_loop) "
                         "for every sweep point")
    ap.add_argument("--ablation", default="cache_extend",
                    choices=("cache_extend", "async_loop", "speculative",
                             "victim_tier"),
                    help="--record before/after axis: cache-extend off/on "
                         "(historical), sync/async engine loop (with "
                         "--api stream the records carry itl_ms_p95), "
                         "plain-decode vs speculative decoding (after "
                         "records carry acceptance_rate), or victim_tier "
                         "off/on over the multi_tenant workload (after "
                         "records carry swap_hit_rate and the higher "
                         "prefill_tokens_saved)")
    ap.add_argument("--speculative", action="store_true",
                    help="draft-propose/target-verify speculative decoding "
                         "(self-draft) for every sweep point; derived "
                         "gains draft_tokens_proposed/accepted, "
                         "acceptance_rate, spec_dispatches")
    ap.add_argument("--spec-tokens", type=int, default=4,
                    help="draft tokens proposed per verify step under "
                         "--speculative / --ablation speculative")
    ap.add_argument("--temperature-mix", default=None, metavar="T0,T1,...",
                    help="comma-separated per-request temperatures cycled "
                         "across the wave (e.g. '0,0.7,1.0'); sampled "
                         "rows get deterministic per-request seeds so "
                         "the wave stays reproducible")
    ap.add_argument("--n-best", type=int, default=1,
                    help="fan each prompt into N siblings that share "
                         "generated KV pages (forces --kv-layout paged); "
                         "derived gains forks / gen_pages_shared")
    ap.add_argument("--no-cache-extend", action="store_true",
                    help="disable the cache-extending prefill program "
                         "(pre-extend behavior: skip/chunk/preempt gated "
                         "to bit-exact datapaths)")
    ap.add_argument("--repeats", type=int, default=1,
                    help="measured waves per sweep point; the median-wall "
                         "wave is reported (use >1 when recording a "
                         "before/after entry on a noisy shared runner)")
    ap.add_argument("--record", default=None, metavar="PATH",
                    help="append a timestamped before/after (cache-extend "
                         "off/on) run entry to the JSON trajectory at "
                         "PATH instead of printing one CSV sweep")
    args = ap.parse_args()
    t0 = time.time()
    temperature_mix = None
    if args.temperature_mix:
        temperature_mix = [float(x) for x in args.temperature_mix.split(",")]
    if args.record:
        record_kw = dict(
            policy=args.policy, kv_layout=args.kv_layout,
            workload=args.workload, api=args.api,
            scheduler=args.scheduler, deadline_ms=args.deadline_ms,
            repeats=args.repeats,
        )
        if temperature_mix is not None:
            record_kw["temperature_mix"] = temperature_mix
        if args.n_best > 1:
            record_kw["n_best"] = args.n_best
        if args.ablation == "cache_extend" and args.async_loop:
            record_kw["async_loop"] = True
        if args.kv_host_pages:
            record_kw["kv_host_pages"] = args.kv_host_pages
        if args.ablation == "speculative":
            record_kw["spec_tokens"] = args.spec_tokens
        elif args.speculative:
            record_kw["speculative"] = True
            record_kw["spec_tokens"] = args.spec_tokens
        entry = record_trajectory(
            args.record, ablation=args.ablation, **record_kw
        )
        n = len(load_trajectory(args.record))
        if args.ablation == "speculative":
            acc = [a.get("acceptance_rate") for a in entry["after"]]
            itl = [
                (b.get("itl_ms_p95"), a.get("itl_ms_p95"))
                for b, a in zip(entry["before"], entry["after"])
            ] if args.api == "stream" else None
            print(f"# appended run {entry['git_rev']}@{entry['date']} to "
                  f"{args.record} ({n} entries); "
                  f"acceptance_rate per point: {acc}"
                  + (f"; itl_ms_p95 plain->spec per point: {itl}"
                     if itl is not None else ""))
        elif args.ablation == "victim_tier":
            saved = [
                (b.get("prefill_tokens_saved", 0),
                 a.get("prefill_tokens_saved", 0))
                for b, a in zip(entry["before"], entry["after"])
            ]
            hits = [a.get("swap_hit_rate") for a in entry["after"]]
            print(f"# appended run {entry['git_rev']}@{entry['date']} to "
                  f"{args.record} ({n} entries); "
                  f"prefill_tokens_saved tier-off->on per point: {saved}; "
                  f"swap_hit_rate per point: {hits}")
        elif args.ablation == "async_loop" and args.api == "stream":
            itl = [
                (b.get("itl_ms_p95"), a.get("itl_ms_p95"))
                for b, a in zip(entry["before"], entry["after"])
            ]
            print(f"# appended run {entry['git_rev']}@{entry['date']} to "
                  f"{args.record} ({n} entries); "
                  f"itl_ms_p95 sync->async per point: {itl}")
        else:
            saved = [
                r.get("prefill_tokens_saved", 0) for r in entry["after"]
            ]
            print(f"# appended run {entry['git_rev']}@{entry['date']} to "
                  f"{args.record} ({n} entries); "
                  f"after prefill_tokens_saved={saved}")
    else:
        rows = run(policy=args.policy, kv_layout=args.kv_layout,
                   workload=args.workload, api=args.api,
                   cache_extend=not args.no_cache_extend,
                   scheduler=args.scheduler, deadline_ms=args.deadline_ms,
                   trace_phases=args.trace_phases,
                   async_loop=args.async_loop, phase_mode=args.phase_mode,
                   repeats=args.repeats,
                   speculative=args.speculative,
                   spec_tokens=args.spec_tokens,
                   temperature_mix=temperature_mix,
                   n_best=args.n_best,
                   kv_host_pages=args.kv_host_pages)
        for row in rows:
            print(row)
    print(f"# serving_throughput done in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()

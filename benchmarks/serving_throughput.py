"""Serving-engine throughput: tokens/s across batch x bucket x decode_steps,
with KV-cache occupancy (bytes + page utilization) per sweep point.

The continuous-batching counterpart of the paper's latency tables — the
engine's hot loop (bucketed batched prefill + scan decode) swept over the
two knobs that bound its compiled-program set and host-dispatch overhead,
on a physics-scale LM (paper Table I dims as a causal LM) and the reduced
``minicpm-2b`` config.  ``--kv-layout paged`` runs the same sweep through
the block-table page pool (serve/kv_cache.py) instead of dense slabs.

``--api stream`` drives the measured wave through the client-facing
``Engine.stream`` API instead of the batch ``Engine.generate`` wrapper
and adds latency percentiles computed from ``TokenEvent`` timestamps:
``ttft_ms_p50/p95`` (submit -> first token) and ``itl_ms_p50/p95``
(inter-token gaps within a request; with ``decode_steps`` tokens
arriving per host dispatch, intra-dispatch gaps are ~0 and the p95
exposes the dispatch boundary).

``--workload prefix`` switches the request stream from uniform random
prompts to a prefix-heavy one — every prompt starts with the same long
preamble, the physics pattern of a fixed detector-geometry prefix ahead
of per-event payloads — with the prefix cache and page-aware preemption
enabled, and the derived column gains
``prefix_hit_rate=<hits/queries>;prefill_tokens_saved=<tokens never
recomputed>;preemptions=<count>``.

CSV rows: ``name,us_per_call,derived`` where ``us_per_call`` is mean
microseconds per generated token and ``derived`` packs
``tok_s=<tokens/s>;prefill_compiles=<n>;decode_compiles=<n>;``
``kv_layout=<dense|paged>;kv_mib=<cache MiB>;page_util_peak=<peak
pages-in-use / capacity>``.
"""

from __future__ import annotations

import jax
import numpy as np

from repro import configs
from repro.configs.base import ModelConfig, ServeConfig
from repro.models import lm
from repro.serve import Engine


def physics_scale_lm() -> ModelConfig:
    """The paper's b-tagging-scale transformer (Table I: d=64, 3 blocks)
    recast as a tiny causal LM so it can drive the serving engine."""
    return ModelConfig(
        name="physics-scale-lm",
        family="dense",
        n_layers=3,
        d_model=64,
        n_heads=8,
        n_kv_heads=8,
        d_ff=128,
        vocab_size=256,
        dtype="float32",
    )


def _page_util_peak(tel: dict) -> float:
    """Peak page utilization; 0.0 for degenerate pools (a zero-capacity
    or dense-layout stats row must never divide by zero)."""
    capacity = tel.get("pages_capacity", 0)
    if capacity <= 0:
        return 0.0
    return tel.get("pages_in_use_peak", 0) / capacity


def _stream_wave(eng: Engine, handles) -> tuple[list[float], list[float]]:
    """Drain every handle's stream; return (per-request TTFT seconds,
    inter-token gaps in seconds).  Event timestamps are stamped when each
    dispatch's results reach the host, so gaps measure real host-loop
    latency regardless of which stream performed the pump."""
    ttfts: list[float] = []
    gaps: list[float] = []
    for h in handles:
        last_ts = None
        for ev in eng.stream(h):
            if last_ts is None:
                # created_at is never restamped; submitted_at is (the
                # preemption requeue resets the queue-wait clock)
                ttfts.append(ev.ts - eng.request(h).created_at)
            else:
                gaps.append(ev.ts - last_ts)
            last_ts = ev.ts
    return ttfts, gaps


def _sweep_one(name, cfg, params, *, max_batch, buckets, decode_steps,
               policy=None, kv_layout="dense", workload="uniform",
               api="batch", n_requests=8, max_new=16, seed=0,
               cache_extend=True):
    prefix_mode = workload == "prefix"
    eng = Engine(
        cfg, params,
        ServeConfig(
            max_batch=max_batch, max_seq_len=64,
            prefill_buckets=buckets, decode_steps=decode_steps,
            policy=policy, kv_layout=kv_layout, kv_page_size=16,
            kv_prefix_cache=prefix_mode, kv_preemption=prefix_mode,
            cache_extend=cache_extend,
        ),
    )
    # prefix-heavy workload: one fixed detector-geometry-style preamble
    # (a whole page of it) shared by every request in every wave
    preamble = list(
        np.random.default_rng(seed + 7).integers(0, cfg.vocab_size, 16)
    )

    def wave(wave_seed):
        import time
        rng = np.random.default_rng(wave_seed)
        handles = []
        for _ in range(n_requests):
            payload = list(
                rng.integers(0, cfg.vocab_size, int(rng.integers(3, 14)))
            )
            prompt = preamble + payload if prefix_mode else payload
            handles.append(eng.submit(prompt, max_new_tokens=max_new))
        t0 = time.perf_counter()
        if api == "stream":
            ttfts, gaps = _stream_wave(eng, handles)
        else:
            eng.generate()
            ttfts, gaps = [], []
        return time.perf_counter() - t0, ttfts, gaps

    # warmup wave: same length distribution, so it compiles the full
    # bucket/decode program set — the measured wave is steady-state
    wave(seed)
    tokens_before = eng.telemetry["tokens_generated"]
    wall_s, ttfts, gaps = wave(seed + 1)
    tel = eng.telemetry
    toks = tel["tokens_generated"] - tokens_before
    us_per_tok = wall_s / max(toks, 1) * 1e6
    tok_s = toks / max(wall_s, 1e-9)
    derived = (
        f"tok_s={tok_s:.1f};"
        f"prefill_compiles={tel['prefill_compiles']};"
        f"decode_compiles={tel['decode_compiles']};"
        f"kv_layout={tel['kv_layout']};"
        f"kv_mib={tel['kv_bytes'] / 2**20:.2f};"
        f"page_util_peak={_page_util_peak(tel):.2f}"
    )
    if api == "stream":
        derived += (
            f";ttft_ms_p50={np.percentile(ttfts, 50)*1e3:.1f}"
            f";ttft_ms_p95={np.percentile(ttfts, 95)*1e3:.1f}"
            f";itl_ms_p50={np.percentile(gaps, 50)*1e3:.2f}"
            f";itl_ms_p95={np.percentile(gaps, 95)*1e3:.2f}"
        )
    if prefix_mode:
        derived += (
            f";prefix_hit_rate={tel['prefix_hit_rate']:.2f}"
            f";prefill_tokens_saved={tel['prefill_tokens_saved']}"
            f";prefix_tokens_shared={tel['prefix_tokens_shared']}"
            f";preemptions={tel['preemptions']}"
            f";extend_dispatches={tel['extend_dispatches']}"
        )
    return (
        f"serving_throughput,{name},b{max_batch},ds{decode_steps},"
        f"{us_per_tok:.1f},{derived}"
    )


def run(policy: str | None = None, kv_layout: str = "dense",
        workload: str = "uniform", api: str = "batch",
        cache_extend: bool = True) -> list[str]:
    if workload == "prefix" and kv_layout == "dense":
        kv_layout = "paged"  # sharing needs pages; dense would be inert
    rows = ["bench,config,batch,decode_steps,us_per_token,derived"]
    archs = [
        ("physics_scale", physics_scale_lm()),
        ("minicpm_2b", configs.get_config("minicpm-2b", reduced=True)),
    ]
    buckets = (8, 16, 32)
    for name, cfg in archs:
        arch_policy = cfg.serve_policy if policy == "auto" else policy
        params = lm.init_params(cfg, jax.random.PRNGKey(0))
        for max_batch in (2, 4):
            for decode_steps in (1, 4):
                rows.append(
                    _sweep_one(
                        name, cfg, params,
                        max_batch=max_batch, buckets=buckets,
                        decode_steps=decode_steps, policy=arch_policy,
                        kv_layout=kv_layout, workload=workload, api=api,
                        cache_extend=cache_extend,
                    )
                )
    return rows


def _rows_to_records(rows: list[str]) -> list[dict]:
    """CSV rows -> dicts, with the packed derived column exploded."""
    records = []
    for row in rows[1:]:
        head, derived = row.rsplit(",", 1)
        bench, config, batch, steps, us_tok = head.split(",")
        rec = {
            "bench": bench, "config": config, "batch": batch,
            "decode_steps": steps, "us_per_token": float(us_tok),
        }
        for field in derived.split(";"):
            key, _, val = field.partition("=")
            try:
                rec[key] = int(val)
            except ValueError:
                try:
                    rec[key] = float(val)
                except ValueError:
                    rec[key] = val
        records.append(rec)
    return records


def record_trajectory(path: str, **run_kw) -> dict:
    """Write a BENCH_serving.json trajectory artifact: the same sweep
    with the cache-extending prefill program off (``before`` — the old
    bit-exact-gated behavior) and on (``after``), so the trajectory
    shows chunked prefill / prefix-skip / preemption savings becoming
    real on quantized datapaths instead of storage-only dedup."""
    import json

    doc = {
        "bench": "serving_throughput",
        "args": {k: v for k, v in run_kw.items()},
        "before": _rows_to_records(run(cache_extend=False, **run_kw)),
        "after": _rows_to_records(run(cache_extend=True, **run_kw)),
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    return doc


def main():
    import argparse
    import time

    ap = argparse.ArgumentParser()
    ap.add_argument("--policy", default=None,
                    help="precision policy preset applied to every sweep "
                         "point (float, int8_serve, paper_vu13p, ...) or "
                         "'auto' for each arch's recommended serve_policy")
    ap.add_argument("--kv-layout", default="dense",
                    choices=("dense", "paged"),
                    help="KV-cache storage layout (serve/kv_cache.py)")
    ap.add_argument("--api", default="batch", choices=("batch", "stream"),
                    help="drive the measured wave through Engine.generate "
                         "(batch) or Engine.stream (per-token events; adds "
                         "ttft/itl p50/p95 columns)")
    ap.add_argument("--workload", default="uniform",
                    choices=("uniform", "prefix"),
                    help="request stream: uniform random prompts, or "
                         "prefix-heavy (shared preamble; enables the "
                         "prefix cache + preemption and reports hit rate "
                         "/ prefill tokens saved / preemption count)")
    ap.add_argument("--no-cache-extend", action="store_true",
                    help="disable the cache-extending prefill program "
                         "(pre-extend behavior: skip/chunk/preempt gated "
                         "to bit-exact datapaths)")
    ap.add_argument("--record", default=None, metavar="PATH",
                    help="write a before/after (cache-extend off/on) "
                         "trajectory artifact to PATH as JSON instead of "
                         "printing one CSV sweep")
    args = ap.parse_args()
    t0 = time.time()
    if args.record:
        doc = record_trajectory(
            args.record, policy=args.policy, kv_layout=args.kv_layout,
            workload=args.workload, api=args.api,
        )
        saved = [r.get("prefill_tokens_saved", 0) for r in doc["after"]]
        print(f"# wrote {args.record}; "
              f"after prefill_tokens_saved={saved}")
    else:
        rows = run(policy=args.policy, kv_layout=args.kv_layout,
                   workload=args.workload, api=args.api,
                   cache_extend=not args.no_cache_extend)
        for row in rows:
            print(row)
    print(f"# serving_throughput done in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()

"""Paper Figs. 12-14 analogue: resource usage vs reuse factor x precision.

FPGA resources (DSP/FF/LUT/BRAM) map to TPU analogues per DESIGN.md:
VMEM working set (register/BRAM), sequential MXU passes (latency), and
total MACs (DSP-ops).  Swept over R in {1,2,4,8} and weight precision
in {int8, bf16} for each physics model's dominant GEMM.
"""

from __future__ import annotations

import time

from repro import configs
from repro.core import reuse


def run() -> list[str]:
    rows = [
        "figure,model,gemm,reuse,precision,vmem_bytes,mxu_passes,interval,macs"
    ]
    # the paper's models (R degenerates on TPU: K < 128 lanes) AND LM-scale
    # GEMMs from the assigned archs, where the R trade-off is real.
    cases = []
    for name in ("engine_anomaly", "btagging", "gw"):
        cfg = configs.get_config(name)
        cases.append((name, "block_gemm", cfg.seq_len, cfg.d_model, cfg.d_model))
    g8 = configs.get_config("granite-8b")
    cases.append(("granite-8b", "mlp_up", 4096, g8.d_model, g8.d_ff))
    m3 = configs.get_config("minicpm3-4b")
    cases.append(("minicpm3-4b", "q_proj", 4096, m3.d_model, 2560))
    for name, gemm, m, k, n in cases:
        for prec, bpe in (("int8", 1), ("bf16", 2)):
            for r in (1, 2, 4, 8):
                plan = reuse.plan_matmul(
                    m, k, n, reuse_factor=r, bytes_per_elem=bpe
                )
                est = reuse.resource_estimate(plan)
                rows.append(
                    f"resources,{name},{gemm},R{r},{prec},{est.vmem_bytes},"
                    f"{est.passes},{est.interval},{est.macs}"
                )
    return rows


def main():
    t0 = time.time()
    for row in run():
        print(row)
    print(f"# resources done in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()

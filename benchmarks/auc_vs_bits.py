"""Paper Figs. 9-11 analogue: AUC ratio (quantized model vs float model)
vs fractional bit width, PTQ and QAT, for the three physics models.

Mirrors the paper's protocol: the metric compares the quantized model's
outputs against the FLOAT model (not ground truth) — "we are primarily
interested in the capability ... to replicate the output of the Keras
model".  Integer bits fixed at 6 (the paper's chosen setting).

The sweep is a **policy grid**: each (mode, frac_bits) point is the
parametric preset ``{ptq,qat}_fixed<6+fb,6>`` from ``core.precision``,
resolved and applied through the same PrecisionPolicy machinery the
serving engine uses — so per-layer heterogeneous sweeps are a one-line
policy change away.

    PYTHONPATH=src python -m benchmarks.auc_vs_bits [--smoke]
        [--models gw ...] [--frac-bits 2 6 ...]
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core import precision as precision_lib
from repro.data import physics as pdata
from repro.models import physics as pmodel
from repro.optim import AdamW

INT_BITS = 6
# paper sweeps 1..11 fractional bits; we sample the same range coarsely so
# the whole benchmark stays CPU-friendly (QAT fine-tunes per point)
FRAC_BITS = [1, 2, 3, 4, 6, 8, 10]
MODELS = ["engine_anomaly", "btagging", "gw"]
TRAIN_STEPS = 60
QAT_STEPS = 15


def _train(cfg, x, y, steps, params=None, policy=None, lr=3e-3, seed=0):
    if policy is not None:
        cfg = dataclasses.replace(cfg, precision=policy)
    if params is None:
        params = pmodel.init_params(cfg, jax.random.PRNGKey(seed))
    opt = AdamW(schedule=lambda s: lr, weight_decay=0.0)
    state = opt.init(params)
    batch = {"x": jnp.asarray(x), "y": jnp.asarray(y)}

    @jax.jit
    def step(params, state):
        (_, m), g = jax.value_and_grad(pmodel.loss_fn, has_aux=True)(
            params, cfg, batch
        )
        params, state, _ = opt.update(g, state, params)
        return params, state

    for _ in range(steps):
        params, state = step(params, state)
    return params, cfg


def _auc(cfg, params, x, y_like_scores) -> float:
    proba = np.asarray(pmodel.predict_proba(params, cfg, jnp.asarray(x)))
    if cfg.n_classes == 1:
        return pdata.auc_score(y_like_scores, proba)
    if cfg.n_classes == 2:
        return pdata.auc_score(y_like_scores, proba[:, 1])
    return pdata.multiclass_auc(y_like_scores, proba)


def run(
    n_train=384,
    n_test=512,
    models=None,
    frac_bits=None,
    train_steps=TRAIN_STEPS,
    qat_steps=QAT_STEPS,
) -> list[str]:
    models = models or MODELS
    frac_bits = frac_bits or FRAC_BITS
    rows = ["figure,model,mode,int_bits,frac_bits,auc_float,auc_quant,auc_ratio"]
    for name in models:
        cfg = configs.get_config(name)
        gen = pdata.GENERATORS[name]
        x, y = gen(n_train, seed=0)
        xt, yt = gen(n_test, seed=123)
        params, cfg_f = _train(cfg, x, y, train_steps)
        auc_float = _auc(cfg_f, params, xt, yt)

        for fb in frac_bits:
            # PTQ: the parametric policy snaps trained weights to the grid
            ptq_policy = precision_lib.get_policy(
                f"ptq_fixed<{INT_BITS + fb},{INT_BITS}>"
            )
            ptq_plan = ptq_policy.resolve(cfg.n_layers)
            qparams = precision_lib.apply_plan_to_params(params, ptq_plan)
            auc_ptq = _auc(cfg_f, qparams, xt, yt)
            rows.append(
                f"auc_vs_bits,{name},ptq,{INT_BITS},{fb},"
                f"{auc_float:.4f},{auc_ptq:.4f},{auc_ptq/auc_float:.4f}"
            )
            # QAT: short fine-tune with the fake-quant (STE) policy
            qat_policy = precision_lib.get_policy(
                f"qat_fixed<{INT_BITS + fb},{INT_BITS}>"
            )
            qat_params, cfg_q = _train(
                cfg, x, y, qat_steps, params=params, policy=qat_policy,
                lr=1e-3,
            )
            qat_eval = precision_lib.apply_plan_to_params(
                qat_params, qat_policy.resolve(cfg.n_layers)
            )
            auc_qat = _auc(cfg_q, qat_eval, xt, yt)
            rows.append(
                f"auc_vs_bits,{name},qat,{INT_BITS},{fb},"
                f"{auc_float:.4f},{auc_qat:.4f},{auc_qat/auc_float:.4f}"
            )
    return rows


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--models", nargs="*", default=None, choices=MODELS)
    ap.add_argument("--frac-bits", type=int, nargs="*", default=None)
    ap.add_argument("--train-steps", type=int, default=TRAIN_STEPS)
    ap.add_argument("--qat-steps", type=int, default=QAT_STEPS)
    ap.add_argument(
        "--smoke", action="store_true",
        help="CI smoke: 1 model x 2 bit widths, short training",
    )
    args = ap.parse_args()
    if args.smoke:
        args.models = args.models or ["gw"]
        args.frac_bits = args.frac_bits or [2, 6]
        args.train_steps = min(args.train_steps, 10)
        args.qat_steps = min(args.qat_steps, 4)
    t0 = time.time()
    for row in run(
        models=args.models,
        frac_bits=args.frac_bits,
        train_steps=args.train_steps,
        qat_steps=args.qat_steps,
    ):
        print(row)
    print(f"# auc_vs_bits done in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()

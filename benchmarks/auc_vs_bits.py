"""Paper Figs. 9-11 analogue: AUC ratio (quantized model vs float model)
vs fractional bit width, PTQ and QAT, for the three physics models.

Mirrors the paper's protocol: the metric compares the quantized model's
outputs against the FLOAT model (not ground truth) — "we are primarily
interested in the capability ... to replicate the output of the Keras
model".  Integer bits fixed at 6 (the paper's chosen setting).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core import fixed_point as fxp
from repro.core import quant
from repro.data import physics as pdata
from repro.models import physics as pmodel
from repro.optim import AdamW

INT_BITS = 6
# paper sweeps 1..11 fractional bits; we sample the same range coarsely so
# the whole benchmark stays CPU-friendly (QAT fine-tunes per point)
FRAC_BITS = [1, 2, 3, 4, 6, 8, 10]
TRAIN_STEPS = 60
QAT_STEPS = 15


def _train(cfg, x, y, steps, params=None, quant_cfg=None, lr=3e-3, seed=0):
    import dataclasses

    if quant_cfg is not None:
        cfg = dataclasses.replace(cfg, quant=quant_cfg)
    if params is None:
        params = pmodel.init_params(cfg, jax.random.PRNGKey(seed))
    opt = AdamW(schedule=lambda s: lr, weight_decay=0.0)
    state = opt.init(params)
    batch = {"x": jnp.asarray(x), "y": jnp.asarray(y)}

    @jax.jit
    def step(params, state):
        (_, m), g = jax.value_and_grad(pmodel.loss_fn, has_aux=True)(
            params, cfg, batch
        )
        params, state, _ = opt.update(g, state, params)
        return params, state

    for _ in range(steps):
        params, state = step(params, state)
    return params, cfg


def _auc(cfg, params, x, y_like_scores) -> float:
    proba = np.asarray(pmodel.predict_proba(params, cfg, jnp.asarray(x)))
    if cfg.n_classes == 1:
        return pdata.auc_score(y_like_scores, proba)
    if cfg.n_classes == 2:
        return pdata.auc_score(y_like_scores, proba[:, 1])
    return pdata.multiclass_auc(y_like_scores, proba)


def run(n_train=384, n_test=512) -> list[str]:
    rows = ["figure,model,mode,int_bits,frac_bits,auc_float,auc_quant,auc_ratio"]
    for name in ("engine_anomaly", "btagging", "gw"):
        cfg = configs.get_config(name)
        gen = pdata.GENERATORS[name]
        x, y = gen(n_train, seed=0)
        xt, yt = gen(n_test, seed=123)
        params, cfg_f = _train(cfg, x, y, TRAIN_STEPS)
        auc_float = _auc(cfg_f, params, xt, yt)

        for fb in FRAC_BITS:
            fp = fxp.ap_fixed(INT_BITS + fb, INT_BITS)
            # PTQ: snap trained weights to the grid
            qparams = quant.quantize_pytree_fixed(params, fp)
            auc_ptq = _auc(cfg_f, qparams, xt, yt)
            rows.append(
                f"auc_vs_bits,{name},ptq,{INT_BITS},{fb},"
                f"{auc_float:.4f},{auc_ptq:.4f},{auc_ptq/auc_float:.4f}"
            )
            # QAT: short fine-tune with fake-quant weights+activations
            qcfg = quant.QuantConfig(mode="qat", weight_cfg=fp, act_cfg=fp)
            qat_params, cfg_q = _train(
                cfg, x, y, QAT_STEPS, params=params, quant_cfg=qcfg, lr=1e-3
            )
            qat_eval = quant.quantize_pytree_fixed(qat_params, fp)
            auc_qat = _auc(cfg_q, qat_eval, xt, yt)
            rows.append(
                f"auc_vs_bits,{name},qat,{INT_BITS},{fb},"
                f"{auc_float:.4f},{auc_qat:.4f},{auc_qat/auc_float:.4f}"
            )
    return rows


def main():
    t0 = time.time()
    for row in run():
        print(row)
    print(f"# auc_vs_bits done in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()

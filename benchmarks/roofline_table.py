"""§Roofline table: reads the dry-run JSON cache (experiments/dryrun) and
emits per-(arch x shape x mesh) roofline rows — baseline and fused-
attention variants — plus a markdown table for EXPERIMENTS.md."""

from __future__ import annotations

import glob
import json
import os
import time

OUT_DIR = os.environ.get("REPRO_DRYRUN_DIR", "experiments/dryrun")


def load_cells(out_dir: str = OUT_DIR, mesh: str | None = None) -> list[dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        if "__" not in os.path.basename(path):
            continue
        base = os.path.basename(path)[:-5]
        if base.count("__") != 2:  # skip tagged perf-iteration files
            continue
        with open(path) as f:
            d = json.load(f)
        if mesh and d.get("mesh") != mesh:
            continue
        cells.append(d)
    return cells


def run(out_dir: str = OUT_DIR) -> list[str]:
    rows = [
        "roofline,arch,shape,mesh,status,compute_s,memory_s,collective_s,"
        "dominant,compute_s_fused,memory_s_fused,dominant_fused,"
        "useful_ratio,useful_ratio_fused,roofline_frac_fused"
    ]
    for d in load_cells(out_dir):
        if d.get("status") == "skip":
            rows.append(
                f"roofline,{d['arch']},{d['shape']},{d['mesh']},skip({d['reason']})"
                + "," * 10
            )
            continue
        if d.get("status") != "ok":
            rows.append(
                f"roofline,{d['arch']},{d['shape']},{d['mesh']},error" + "," * 10
            )
            continue
        t, tf = d["terms"], d["terms_fused"]
        # roofline fraction: compute term / bound term (how close the cell
        # is to being compute-limited at peak)
        bound = max(tf["compute_s"], tf["memory_s"], tf["collective_s"])
        frac = tf["compute_s"] / bound if bound else 0.0
        rows.append(
            f"roofline,{d['arch']},{d['shape']},{d['mesh']},ok,"
            f"{t['compute_s']:.3f},{t['memory_s']:.3f},{t['collective_s']:.3f},"
            f"{t['dominant']},{tf['compute_s']:.3f},{tf['memory_s']:.3f},"
            f"{tf['dominant']},{d['useful_ratio']:.3f},"
            f"{d['useful_ratio_fused']:.3f},{frac:.3f}"
        )
    return rows


def markdown(out_dir: str = OUT_DIR, mesh: str = "pod") -> str:
    lines = [
        "| arch | shape | compute s | memory s | coll s | dominant | "
        "6ND/HLO | fused: comp | fused: mem | fused dom | RL frac |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for d in load_cells(out_dir, mesh=mesh):
        if d.get("status") == "skip":
            lines.append(
                f"| {d['arch']} | {d['shape']} | — | — | — | SKIP: {d['reason']} | | | | | |"
            )
            continue
        if d.get("status") != "ok":
            lines.append(f"| {d['arch']} | {d['shape']} | ERROR | | | | | | | | |")
            continue
        t, tf = d["terms"], d["terms_fused"]
        bound = max(tf["compute_s"], tf["memory_s"], tf["collective_s"])
        frac = tf["compute_s"] / bound if bound else 0.0
        lines.append(
            f"| {d['arch']} | {d['shape']} | {t['compute_s']:.2f} | "
            f"{t['memory_s']:.2f} | {t['collective_s']:.2f} | {t['dominant']} | "
            f"{d['useful_ratio']:.2f} | {tf['compute_s']:.2f} | "
            f"{tf['memory_s']:.2f} | {tf['dominant']} | {frac:.2f} |"
        )
    return "\n".join(lines)


def main():
    t0 = time.time()
    for row in run():
        print(row)
    print(f"# roofline_table done in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()

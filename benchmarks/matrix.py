"""Declarative benchmark matrix: configs x policies x kv-layouts x
ablations, with per-cell result caching and a regression gate.

Every serving feature so far (paged KV, prefix cache, chunked prefill,
the cache-extending prefill program) shipped with its own one-off
benchmark invocation; nothing measured the *cross product*, so a change
that helped one cell could quietly tax another.  This runner makes the
grid explicit:

* A **cell** is ``config/policy/layout/ablation``.  Ablations switch
  one feature off against the full-featured engine:

  - ``none``       — everything on (prefix cache + preemption, chunked
    prefill, cache-extend) as the layout allows
  - ``no-prefix``  — prefix-cache page sharing off
  - ``no-paging``  — dense slabs instead of the block-table pool
    (which also forecloses sharing/preemption)
  - ``no-chunk``   — chunked prefill off (long prompts admit whole)
  - ``no-extend``  — cache-extending prefill program off (the old
    bit-exact-gated fallback)

* **Per-cell caching**: results land in ``benchmarks/.matrix_cache/``
  keyed by git rev + cell, so re-running a 12-cell matrix after an
  unrelated edit only re-measures what the rev change invalidated
  (``--no-cache`` forces fresh measurements).

* ``--record`` appends a timestamped entry (git rev + UTC date + args +
  every cell row) to the ``BENCH_matrix.json`` trajectory — append-only,
  same schema discipline as ``BENCH_serving.json``.

* ``--check`` compares fresh measurements against the *latest* recorded
  entry and exits nonzero when any shared cell's ``us_per_token``
  regressed by more than ``--tolerance`` (default 0.2 = 20%).  CI runs
  the 2-cell ``--preset smoke`` with a generous tolerance — a tripwire
  for order-of-magnitude regressions, not a microbenchmark gate.

CSV rows: ``matrix,<cell>,<us_per_token>,<derived>``.
"""

from __future__ import annotations

import dataclasses
import json
import os
import time

import numpy as np

from repro import configs
from repro.configs.base import ServeConfig
from repro.models import lm

_HERE = os.path.dirname(os.path.abspath(__file__))
CACHE_DIR = os.path.join(_HERE, ".matrix_cache")
DEFAULT_TRAJECTORY = os.path.join(os.path.dirname(_HERE), "BENCH_matrix.json")


# --------------------------------------------------------------- cells --
@dataclasses.dataclass(frozen=True)
class Cell:
    """One matrix point.  ``policy`` None means the float datapath."""

    config: str = "physics_scale"
    policy: str | None = None
    layout: str = "paged"
    ablation: str = "none"

    @property
    def key(self) -> str:
        return (
            f"{self.config}/{self.policy or 'float'}/"
            f"{self.layout}/{self.ablation}"
        )


ABLATIONS = ("none", "no-prefix", "no-paging", "no-chunk", "no-extend")

#: named cell sets.  smoke = the 2-cell CI tripwire; default = the
#: physics-scale grid both datapaths x both layouts plus every ablation
#: on the quantized paged engine (the cells the recent PRs changed).
PRESETS: dict[str, tuple[Cell, ...]] = {
    "smoke": (
        Cell("physics_scale", None, "paged", "none"),
        Cell("physics_scale", "int8_serve", "paged", "no-extend"),
    ),
    "default": (
        Cell("physics_scale", None, "dense", "none"),
        Cell("physics_scale", None, "paged", "none"),
        Cell("physics_scale", "int8_serve", "dense", "none"),
        Cell("physics_scale", "int8_serve", "paged", "none"),
        Cell("physics_scale", "int8_serve", "paged", "no-prefix"),
        Cell("physics_scale", "int8_serve", "paged", "no-paging"),
        Cell("physics_scale", "int8_serve", "paged", "no-chunk"),
        Cell("physics_scale", "int8_serve", "paged", "no-extend"),
        Cell("minicpm_2b", None, "paged", "none"),
        Cell("minicpm_2b", "int8_serve", "paged", "none"),
    ),
}


def _model_cfg(name: str):
    if name == "physics_scale":
        from benchmarks.serving_throughput import physics_scale_lm

        return physics_scale_lm()
    return configs.get_config(name.replace("_", "-"), reduced=True)


def _serve_cfg(cell: Cell, policy: str | None) -> ServeConfig:
    """Resolve a cell to engine knobs: the full feature set, minus the
    one thing its ablation switches off (layout permitting)."""
    if cell.ablation not in ABLATIONS:
        raise ValueError(
            f"unknown ablation {cell.ablation!r}; expected one of {ABLATIONS}"
        )
    layout = "dense" if cell.ablation == "no-paging" else cell.layout
    paged = layout == "paged"
    sharing = paged and cell.ablation != "no-prefix"
    return ServeConfig(
        max_batch=2,
        max_seq_len=64,
        prefill_buckets=(8, 16, 32),
        decode_steps=4,
        policy=policy,
        kv_layout=layout,
        kv_page_size=16,
        kv_prefix_cache=sharing,
        kv_preemption=sharing,
        prefill_chunk=None if cell.ablation == "no-chunk" else 8,
        cache_extend=cell.ablation != "no-extend",
    )


# ------------------------------------------------------------- measure --
def measure_cell(cell: Cell, n_requests: int = 8, max_new: int = 16,
                 seed: int = 0) -> dict:
    """Run one cell: warmup wave (compiles the program set), then a
    measured prefix-heavy wave — every feature under ablation has work
    to do (a shared preamble exercises the prefix cache, a long prompt
    exercises chunking).  Returns the cell's result record."""
    from repro.serve import Engine

    cfg = _model_cfg(cell.config)
    policy = cfg.serve_policy if cell.policy == "auto" else cell.policy
    eng = Engine(
        cfg, params_for(cell.config), _serve_cfg(cell, policy), seed=seed
    )

    preamble = list(
        np.random.default_rng(seed + 7).integers(0, cfg.vocab_size, 16)
    )

    def wave(wave_seed):
        rng = np.random.default_rng(wave_seed)
        for k in range(n_requests):
            # one long prompt per wave so chunked prefill runs
            n = 40 if k == 0 else int(rng.integers(3, 14))
            payload = list(rng.integers(0, cfg.vocab_size, n))
            eng.submit(preamble + payload, max_new_tokens=max_new)
        t0 = time.perf_counter()
        eng.generate()
        return time.perf_counter() - t0

    wave(seed)
    tokens_before = eng.telemetry["tokens_generated"]
    wall_s = wave(seed + 1)
    tel = eng.telemetry
    toks = tel["tokens_generated"] - tokens_before
    return {
        "cell": cell.key,
        "us_per_token": round(wall_s / max(toks, 1) * 1e6, 1),
        "tok_s": round(toks / max(wall_s, 1e-9), 1),
        "prefill_compiles": tel["prefill_compiles"],
        "decode_compiles": tel["decode_compiles"],
        "extend_dispatches": tel.get("extend_dispatches", 0),
        "prefill_tokens_saved": tel.get("prefill_tokens_saved", 0),
        "kv_layout": tel["kv_layout"],
    }


_PARAMS_CACHE: dict[str, object] = {}


def params_for(config: str):
    """Init params once per model config per process (cells share them)."""
    if config not in _PARAMS_CACHE:
        import jax

        _PARAMS_CACHE[config] = lm.init_params(
            _model_cfg(config), jax.random.PRNGKey(0)
        )
    return _PARAMS_CACHE[config]


# --------------------------------------------------------------- cache --
def _git_rev() -> str:
    from benchmarks.serving_throughput import _git_rev as rev

    return rev()


def _cache_path(rev: str, cell: Cell) -> str:
    return os.path.join(CACHE_DIR, rev, cell.key.replace("/", "__") + ".json")


def run_cells(cells: tuple[Cell, ...], *, use_cache: bool = True,
              verbose: bool = False) -> list[dict]:
    """Measure every cell, reading/writing the per-rev disk cache.  A
    cached cell is a measurement taken at this exact git rev — safe to
    reuse; any code change moves the rev and invalidates it."""
    rev = _git_rev()
    results = []
    for cell in cells:
        path = _cache_path(rev, cell)
        if use_cache and rev != "unknown" and os.path.exists(path):
            with open(path) as f:
                rec = json.load(f)
            rec["cached"] = True
        else:
            if verbose:
                print(f"# measuring {cell.key} ...")
            rec = measure_cell(cell)
            rec["cached"] = False
            if use_cache and rev != "unknown":
                os.makedirs(os.path.dirname(path), exist_ok=True)
                with open(path, "w") as f:
                    json.dump(rec, f, indent=2)
        results.append(rec)
    return results


# ---------------------------------------------------------- trajectory --
def load_trajectory(path: str) -> list[dict]:
    if not os.path.exists(path):
        return []
    with open(path) as f:
        doc = json.load(f)
    return [doc] if isinstance(doc, dict) else list(doc)


def record(path: str, preset: str, results: list[dict]) -> dict:
    """Append one timestamped matrix run to the trajectory at ``path``."""
    import datetime

    entry = {
        "bench": "matrix",
        "date": datetime.datetime.now(datetime.timezone.utc).isoformat(
            timespec="seconds"
        ),
        "git_rev": _git_rev(),
        "args": {"preset": preset},
        "cells": [
            {k: v for k, v in rec.items() if k != "cached"}
            for rec in results
        ],
    }
    history = load_trajectory(path)
    history.append(entry)
    with open(path, "w") as f:
        json.dump(history, f, indent=2)
        f.write("\n")
    return entry


def check(results: list[dict], baseline_entry: dict,
          tolerance: float = 0.2) -> list[str]:
    """Compare fresh cell results against a recorded entry; return one
    failure line per shared cell whose us_per_token regressed by more
    than ``tolerance`` (0.2 = 20% slower than baseline fails).  Cells
    missing on either side are skipped — the gate only judges what both
    runs measured."""
    base = {rec["cell"]: rec for rec in baseline_entry.get("cells", [])}
    failures = []
    for rec in results:
        ref = base.get(rec["cell"])
        if ref is None:
            continue
        limit = ref["us_per_token"] * (1.0 + tolerance)
        if rec["us_per_token"] > limit:
            failures.append(
                f"{rec['cell']}: {rec['us_per_token']:.1f} us/tok vs "
                f"baseline {ref['us_per_token']:.1f} "
                f"(limit {limit:.1f}, tolerance {tolerance:.0%})"
            )
    return failures


# ----------------------------------------------------------------- cli --
def _rows(results: list[dict]) -> list[str]:
    rows = ["bench,cell,us_per_token,derived"]
    for rec in results:
        derived = ";".join(
            f"{k}={v}" for k, v in rec.items()
            if k not in ("cell", "us_per_token")
        )
        rows.append(f"matrix,{rec['cell']},{rec['us_per_token']},{derived}")
    return rows


def run(preset: str = "smoke") -> list[str]:
    """benchmarks/run.py entry point: the smoke cells, uncached."""
    return _rows(run_cells(PRESETS[preset], use_cache=False))


def main():
    import argparse

    ap = argparse.ArgumentParser(
        description="serving benchmark matrix (configs x policies x "
                    "layouts x ablations) with caching + regression gate"
    )
    ap.add_argument("--preset", default="default", choices=sorted(PRESETS),
                    help="which cell set to run (smoke = 2-cell CI "
                         "tripwire, default = the full ablation grid)")
    ap.add_argument("--no-cache", action="store_true",
                    help="ignore the per-rev cell cache and re-measure")
    ap.add_argument("--record", nargs="?", const=DEFAULT_TRAJECTORY,
                    default=None, metavar="PATH",
                    help="append this run to the trajectory JSON "
                         f"(default {DEFAULT_TRAJECTORY})")
    ap.add_argument("--check", nargs="?", const=DEFAULT_TRAJECTORY,
                    default=None, metavar="PATH",
                    help="compare against the latest entry in the "
                         "trajectory JSON; exit 1 on regression")
    ap.add_argument("--tolerance", type=float, default=0.2,
                    help="allowed us_per_token regression for --check "
                         "(0.2 = 20%%; CI smoke uses a generous value — "
                         "shared-runner noise is not a regression)")
    args = ap.parse_args()

    t0 = time.time()
    results = run_cells(
        PRESETS[args.preset], use_cache=not args.no_cache, verbose=True
    )
    for row in _rows(results):
        print(row)
    if args.record:
        entry = record(args.record, args.preset, results)
        print(f"# appended run {entry['git_rev']}@{entry['date']} to "
              f"{args.record} ({len(load_trajectory(args.record))} entries)")
    if args.check:
        history = load_trajectory(args.check)
        if not history:
            raise SystemExit(f"--check: no baseline at {args.check}")
        failures = check(results, history[-1], tolerance=args.tolerance)
        if failures:
            print(f"# REGRESSION vs {history[-1].get('git_rev')}"
                  f"@{history[-1].get('date')}:")
            for line in failures:
                print(f"#   {line}")
            raise SystemExit(1)
        print(f"# check OK vs {history[-1].get('git_rev')}"
              f"@{history[-1].get('date')} "
              f"(tolerance {args.tolerance:.0%})")
    print(f"# matrix done in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()

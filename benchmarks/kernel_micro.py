"""Kernel microbenchmarks: interpret-mode wall time (CPU correctness
path) + analytic MXU-pass counts for the four Pallas kernels, vs their
jnp references."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_attention import mha
from repro.kernels.layernorm import layernorm
from repro.kernels.lut_softmax import lut_softmax
from repro.kernels.qmatmul import qmatmul


def _time(fn, *args, reps=3, **kw):
    fn(*args, **kw)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args, **kw))
    return (time.perf_counter() - t0) / reps * 1e6


def run() -> list[str]:
    rng = np.random.default_rng(0)
    rows = ["bench,kernel,variant,us_per_call,max_err_vs_ref"]

    x = jnp.asarray(rng.normal(size=(128, 256)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(256, 128)), jnp.float32)
    ref = qmatmul(x, w, use_pallas=False)
    for r in (1, 2, 4):
        t = _time(qmatmul, x, w, reuse_factor=r, interpret=True)
        err = float(jnp.max(jnp.abs(qmatmul(x, w, reuse_factor=r, interpret=True) - ref)))
        rows.append(f"kernel_micro,qmatmul,R{r},{t:.1f},{err:.2e}")

    s = jnp.asarray(rng.normal(size=(256, 64)) * 2, jnp.float32)
    ref = lut_softmax(s, use_pallas=False)
    t = _time(lut_softmax, s, use_pallas=True, interpret=True)
    err = float(jnp.max(jnp.abs(lut_softmax(s, use_pallas=True, interpret=True) - ref)))
    rows.append(f"kernel_micro,lut_softmax,default,{t:.1f},{err:.2e}")

    xn = jnp.asarray(rng.normal(size=(256, 128)), jnp.float32)
    g = jnp.ones((128,), jnp.float32)
    b = jnp.zeros((128,), jnp.float32)
    for lut_mode in (False, True):
        ref = layernorm(xn, g, b, use_lut=lut_mode, use_pallas=False)
        t = _time(layernorm, xn, g, b, use_lut=lut_mode, use_pallas=True, interpret=True)
        out = layernorm(xn, g, b, use_lut=lut_mode, use_pallas=True, interpret=True)
        err = float(jnp.max(jnp.abs(out - ref)))
        rows.append(
            f"kernel_micro,layernorm,{'lut' if lut_mode else 'exact'},{t:.1f},{err:.2e}"
        )

    from repro.kernels.ssd_scan import ssd

    xdt = jnp.asarray(rng.normal(size=(1, 128, 2, 32)) * 0.5, jnp.float32)
    a = jnp.asarray(-np.abs(rng.normal(size=(1, 128, 2))) * 0.3, jnp.float32)
    bm = jnp.asarray(rng.normal(size=(1, 128, 2, 16)), jnp.float32)
    cm = jnp.asarray(rng.normal(size=(1, 128, 2, 16)), jnp.float32)
    ref = ssd(xdt, a, bm, cm, chunk=32, use_pallas=False)
    t = _time(ssd, xdt, a, bm, cm, chunk=32, use_pallas=True, interpret=True)
    out = ssd(xdt, a, bm, cm, chunk=32, use_pallas=True, interpret=True)
    err = float(jnp.max(jnp.abs(out - ref)))
    rows.append(f"kernel_micro,ssd_scan,chunk32,{t:.1f},{err:.2e}")

    q = jnp.asarray(rng.normal(size=(1, 4, 128, 32)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 4, 128, 32)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 4, 128, 32)), jnp.float32)
    for mode in ("safe", "lut"):
        ref = mha(q, k, v, causal=True, mode=mode, use_pallas=False)
        t = _time(
            mha, q, k, v, causal=True, mode=mode, use_pallas=True,
            interpret=True, block_q=64, block_kv=64,
        )
        out = mha(q, k, v, causal=True, mode=mode, use_pallas=True,
                  interpret=True, block_q=64, block_kv=64)
        err = float(jnp.max(jnp.abs(out - ref)))
        rows.append(f"kernel_micro,flash_attention,{mode},{t:.1f},{err:.2e}")
    return rows


def main():
    t0 = time.time()
    for row in run():
        print(row)
    print(f"# kernel_micro done in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()

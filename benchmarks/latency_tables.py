"""Paper Tables II-IV analogue: latency / initiation-interval vs reuse
factor for the three physics models.

Reports the FPGA-style cycle model (core/latency_model.fpga_style_estimate,
calibrated to the paper's structure) AND the TPU roofline latency of the
same models' streaming-MHA inference (per-request, single-chip v5e terms),
showing the same monotone R trade-off on both targets.
"""

from __future__ import annotations

import time

from repro import configs
from repro.core import latency_model as lat
from repro.core import reuse

MODELS = {
    "engine_anomaly": dict(paper_r1_us=1.908, paper_r4_us=3.780),
    "btagging": dict(paper_r1_us=2.077, paper_r4_us=5.853),
    "gw": dict(paper_r1_us=3.532, paper_r4_us=9.175),
}


def tpu_latency_us(cfg, r: int) -> tuple[float, int]:
    """Single-chip roofline latency of one inference with reuse factor r.

    Returns (us, mxu_passes).  NOTE the honest hardware-adaptation finding
    (DESIGN.md): for the paper's <10k-param models, K < 128*R — the whole
    contraction fits ONE 128-lane MXU pass, so the FPGA's R trade-off
    degenerates on TPU (passes stay 1) and latency is HBM-streaming bound.
    R becomes meaningful again at LM-scale GEMMs (see resources bench).
    """
    seq, d = cfg.seq_len, cfg.d_model
    macs = cfg.n_layers * (4 * seq * d * d + 2 * seq * seq * d + 2 * seq * d * 2 * d)
    flops = 2 * macs
    hbm = 2 * (cfg.n_layers * (4 * d * d + 2 * d * 2 * d) + 2 * seq * d)
    terms = lat.roofline(flops, hbm, 0.0, int8=True)
    plan = reuse.plan_matmul(seq, d, d, reuse_factor=r)
    passes = plan.interval
    return terms.serial_s * 1e6 * passes, passes


def run() -> list[str]:
    rows = [
        "table,model,reuse,clk_ns,interval_cyc,latency_cyc,latency_us,"
        "tpu_roofline_us,tpu_mxu_passes,paper_us"
    ]
    for name, paper in MODELS.items():
        cfg = configs.get_config(name)
        for r in (1, 2, 4):
            est = lat.fpga_style_estimate(
                seq_len=cfg.seq_len, d_model=cfg.d_model,
                n_blocks=cfg.n_layers, reuse=r,
            )
            paper_us = {1: paper["paper_r1_us"], 4: paper["paper_r4_us"]}.get(r, "")
            us, passes = tpu_latency_us(cfg, r)
            rows.append(
                f"latency,{name},R{r},{est.clock_ns:.3f},{est.interval_cycles},"
                f"{est.latency_cycles},{est.latency_us:.3f},"
                f"{us:.3f},{passes},{paper_us}"
            )
    return rows


def main():
    t0 = time.time()
    for row in run():
        print(row)
    print(f"# latency_tables done in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()

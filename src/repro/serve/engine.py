"""Serving engine v2: continuous batching with bucketed prefill and
multi-token scan decode.

The paper's subject is low-latency *inference* with a bounded, pre-compiled
set of fixed-iteration datapaths (hls4ml pipelines); this engine is the
datacenter-scale counterpart and inherits that discipline:

* **Bucketed prefill** — prompts are right-padded to power-of-two length
  buckets with an explicit length mask, so the jit cache holds at most
  ``len(prefill_buckets)`` prefill programs instead of one per distinct
  prompt length.  The mask selects the true last-token logits and zeroes
  the padded tail of the freshly filled KV cache; decode-side position
  masking (``kv_pos <= pos``) keeps the pad region inert from then on.
* **Scan decode** — ``decode_steps`` tokens per host dispatch via
  ``jax.lax.scan`` over the fused decode program, with per-slot active
  masks so finished slots (eos / max-tokens / sequence cap) freeze their
  position and stop emitting mid-scan.
* **Telemetry** — tokens/s, queue wait, and prefill/decode compile
  counters exposed from ``step()``/``run()``.
* **Precision policy** — ``ServeConfig.policy`` (a ``core.precision``
  PrecisionPolicy / preset name) selects the quantized datapath: offline
  weight transforms, KV-cache dtype, LUT softmax, and any runtime
  fake-quant — all without adding jit programs beyond the float baseline.

Families whose caches are not safely right-paddable (SSM/hybrid state,
rolling sliding-window buffers) transparently fall back to exact-length
prefill through the same program, so every architecture keeps working.

Host-side state is just the slot table; all device work happens in the
per-bucket prefill programs and one decode-scan program.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ServeConfig
from repro.core import precision as precision_lib
from repro.models import lm
from repro.serve.sampling import sample

PyTree = Any

# cache leaves with a sequence axis: name -> axis from the right
_SEQ_AXIS_FROM_RIGHT = {
    "k": 2, "v": 2, "latent": 2,  # (..., cache_len, feature)
    "k_scale": 1, "v_scale": 1, "latent_scale": 1,  # (..., cache_len)
}


@dataclasses.dataclass
class Request:
    uid: int
    prompt: list[int]
    max_new_tokens: int = 16
    eos_id: int | None = None
    generated: list[int] = dataclasses.field(default_factory=list)
    submitted_at: float = 0.0
    admitted_at: float = 0.0

    @property
    def done(self) -> bool:
        if self.eos_id is not None and self.generated and self.generated[-1] == self.eos_id:
            return True
        return len(self.generated) >= self.max_new_tokens

    @property
    def queue_wait_s(self) -> float:
        return max(0.0, self.admitted_at - self.submitted_at)


@dataclasses.dataclass
class _Slot:
    active: bool = False
    request: Request | None = None
    pos: int = 0  # next position to write (== current length)
    last_token: int = 0


class ServingEngine:
    def __init__(
        self,
        cfg: ModelConfig,
        params: PyTree,
        serve_cfg: ServeConfig | None = None,
        kernel: dict | None = None,
        seed: int = 0,
    ):
        self.serve_cfg = serve_cfg or ServeConfig()
        if self.serve_cfg.decode_steps < 1:
            raise ValueError(
                f"decode_steps must be >= 1, got {self.serve_cfg.decode_steps}"
            )
        if self.serve_cfg.max_prefill_per_step < 0:
            raise ValueError(
                "max_prefill_per_step must be >= 0 (0 = fill all free slots)"
            )
        self.kernel = kernel or {}
        self.key = jax.random.PRNGKey(seed)

        # Precision: one declarative policy governs weights (offline PTQ /
        # int8 quantize-dequantize; the true int8 GEMM path is
        # kernels/qmatmul on TPU), the KV-cache dtype, the softmax kernel
        # mode, and any runtime fake-quant the model applies in-graph.
        # ServeConfig.policy wins (legacy booleans lower onto it with a
        # DeprecationWarning); otherwise the model's own policy applies.
        policy = self.serve_cfg.resolved_policy()
        if policy is not None:
            cfg = dataclasses.replace(cfg, precision=policy)
        else:
            policy = precision_lib.model_policy(cfg)
        self.cfg = cfg
        self.policy = policy
        self.plan = policy.resolve(cfg.n_layers)
        self.kernel = self.plan.kernel_defaults(self.kernel) or {}
        self.params = precision_lib.apply_plan_to_params(params, self.plan)

        if self.plan.int8_kv_cache and self.plan.kv_cache.bits != 8:
            raise NotImplementedError(
                "the KV cache implements 8-bit per-token quantization only; "
                f"policy {self.policy.name!r} asks for "
                f"{self.plan.kv_cache.bits}-bit"
            )
        sc = self.serve_cfg
        self.quant_cache = bool(
            self.plan.int8_kv_cache
            and cfg.attn_kind in ("gqa", "mla")
            and cfg.family not in ("ssm", "hybrid")
        )
        self.caches = lm.init_caches(
            cfg, sc.max_batch, sc.max_seq_len,
            dtype=jnp.float32, quantized=self.quant_cache,
        )
        self.slots = [_Slot() for _ in range(sc.max_batch)]
        self._queue: list[Request] = []
        self._finished: dict[int, Request] = {}
        self._uid = 0

        # right-padding the prompt is only sound when the cache is
        # position-addressed and decode masks by position: true for dense
        # GQA / MLA caches, false for SSM/hybrid state and for rolling
        # sliding-window buffers (padding would evict real tokens).
        rolling = (
            cfg.sliding_window is not None
            and cfg.sliding_window < sc.max_seq_len
        )
        self._bucketable = (
            cfg.attn_kind in ("gqa", "mla")
            and cfg.family not in ("ssm", "hybrid")
            and not rolling
        )
        # a bucket longer than the cache could not be inserted; drop those
        self._buckets = (
            tuple(b for b in sc.resolved_buckets() if b <= sc.max_seq_len)
            if self._bucketable
            else ()
        )

        self._decode_fn = jax.jit(self._decode_scan)
        self._prefill_fn: dict[int, Any] = {}  # jit cache per bucket length
        self.telemetry = {
            "tokens_generated": 0,
            "prompts_admitted": 0,
            "prefill_compiles": 0,
            "decode_compiles": 0,
            "queue_wait_s_total": 0.0,
            "prefill_time_s": 0.0,
            "decode_time_s": 0.0,
            "steps": 0,
        }

    # ------------------------------------------------------------- utils --
    @property
    def prefill_buckets(self) -> tuple[int, ...]:
        """Active buckets; empty for exact-length (v1-style) prefill."""
        return self._buckets

    def bucket_for(self, n: int) -> int:
        """Padded prefill length for an n-token prompt: the smallest bucket
        >= n, or n itself for unbucketable families / oversized prompts."""
        for b in self._buckets:
            if b >= n:
                return b
        return n

    # ----------------------------------------------------------- requests --
    def submit(self, prompt: list[int], max_new_tokens: int = 16,
               eos_id: int | None = None) -> int:
        if not prompt:
            raise ValueError("empty prompt")
        if len(prompt) >= self.serve_cfg.max_seq_len:
            raise ValueError(
                f"prompt length {len(prompt)} >= max_seq_len "
                f"{self.serve_cfg.max_seq_len}"
            )
        self._uid += 1
        self._queue.append(
            Request(self._uid, list(prompt), max_new_tokens, eos_id,
                    submitted_at=time.perf_counter())
        )
        return self._uid

    def result(self, uid: int) -> Request | None:
        return self._finished.get(uid)

    @property
    def has_work(self) -> bool:
        return bool(self._queue) or any(s.active for s in self.slots)

    # ------------------------------------------------------------ device --
    def _mask_cache_tail(self, filled: PyTree, length: jax.Array) -> PyTree:
        """Zero cache entries at positions >= length (the explicit bucket
        length mask).  Leaves without a sequence axis (SSM state, slot_pos)
        pass through; those families use exact-length prefill anyway."""

        def _mask_group(group):
            out = {}
            for name, leaf in group.items():
                axis_r = _SEQ_AXIS_FROM_RIGHT.get(name)
                if axis_r is None:
                    out[name] = leaf
                    continue
                axis = leaf.ndim - axis_r
                seq = jnp.arange(leaf.shape[axis])
                mask = seq < length
                mask = mask.reshape(
                    (1,) * axis + (-1,) + (1,) * (leaf.ndim - axis - 1)
                )
                out[name] = jnp.where(mask, leaf, jnp.zeros((), leaf.dtype))
            return out

        return {k: _mask_group(v) for k, v in filled.items()}

    def _prefill_bucket(self, params, tokens, length, caches, slot_idx):
        """Prefill one right-padded batch-1 prompt and insert its cache.

        ``tokens``: (1, bucket) int32, positions >= length are padding.
        ``length``: scalar int32 true prompt length (traced, so every
        prompt sharing a bucket reuses one compiled program).
        Returns (true last-token logits (1, V), updated slot caches).
        """
        cfg = self.cfg
        bucket = tokens.shape[1]
        mask = jnp.arange(bucket, dtype=jnp.int32) < length
        tokens = jnp.where(mask[None, :], tokens, 0)  # canonical pad id
        small = lm.init_caches(
            cfg, 1, self.serve_cfg.max_seq_len,
            dtype=jnp.float32, quantized=self.quant_cache,
        )
        logits, filled, _ = lm.forward(
            params, cfg, {"tokens": tokens}, mode="prefill",
            caches=small, kernel=self.kernel,
        )
        # causal attention keeps positions < length independent of the pad
        # tail; the true prompt's logits live at index length-1
        last = jax.lax.dynamic_slice_in_dim(logits, length - 1, 1, axis=1)
        filled = self._mask_cache_tail(filled, length)

        def insert(big, one):
            # batch axis is axis 1 on every stacked cache leaf
            return jax.lax.dynamic_update_index_in_dim(
                big, one[:, 0].astype(big.dtype), slot_idx, 1
            )

        new_caches = jax.tree.map(insert, caches, filled)
        return last[:, 0], new_caches

    def _decode_scan(self, params, tokens, positions, active, rem, eos,
                     caches, key):
        """Run ``decode_steps`` fused decode steps under one dispatch.

        All arrays are per-slot (B,): ``tokens`` last sampled token,
        ``positions`` next write position, ``active`` live mask, ``rem``
        generation budget left, ``eos`` per-request eos id (-1 = none).
        Inactive slots freeze (token, position); re-running a frozen
        position is idempotent for position-addressed caches and harmless
        for retired SSM slots (their state is overwritten on re-prefill).
        """
        sc = self.serve_cfg
        keys = jax.random.split(key, sc.decode_steps)

        def body(carry, k):
            tok, pos, act, budget, c = carry
            logits, new_c, _ = lm.forward(
                params, self.cfg, {"tokens": tok[:, None]}, mode="decode",
                caches=c, positions=pos, kernel=self.kernel,
            )
            nxt = sample(logits[:, -1], k, temperature=sc.temperature)
            nxt = jnp.where(act, nxt, tok)
            emitted = (nxt, act)
            budget = jnp.where(act, budget - 1, budget)
            new_pos = jnp.where(act, pos + 1, pos)
            new_act = (
                act
                & (nxt != eos)
                & (budget > 0)
                & (new_pos + 1 < sc.max_seq_len)
            )
            return (nxt, new_pos, new_act, budget, new_c), emitted

        init = (tokens, positions, active, rem, caches)
        (tok, pos, act, rem, caches), (toks_t, act_t) = jax.lax.scan(
            body, init, keys
        )
        return toks_t, act_t, pos, act, caches

    # -------------------------------------------------------------- step --
    def step(self) -> dict:
        """One engine iteration: admit waiting prompts, then scan-decode."""
        tel = self.telemetry
        tel["steps"] += 1
        stats = {"prefilled": 0, "decoded": 0}
        sc = self.serve_cfg
        # 1. admission: fill free slots with queued prompts (bucketed)
        cap = sc.max_prefill_per_step or sc.max_batch
        for idx, slot in enumerate(self.slots):
            if not self._queue or stats["prefilled"] >= cap:
                break
            if slot.active:
                continue
            req = self._queue.pop(0)
            # queue wait ends at pop: prefill execution/compile time that
            # follows is prefill_time_s, not waiting
            req.admitted_at = time.perf_counter()
            tel["queue_wait_s_total"] += req.queue_wait_s
            tel["prompts_admitted"] += 1
            n = len(req.prompt)
            bucket = self.bucket_for(n)
            toks = np.zeros((1, bucket), np.int32)
            toks[0, :n] = req.prompt
            fn = self._prefill_fn.get(bucket)
            if fn is None:
                fn = jax.jit(self._prefill_bucket)
                self._prefill_fn[bucket] = fn
                tel["prefill_compiles"] += 1
            t0 = time.perf_counter()
            logits, self.caches = fn(
                self.params, jnp.asarray(toks), jnp.int32(n),
                self.caches, idx,
            )
            self.key, sub = jax.random.split(self.key)
            nxt = int(sample(logits, sub, temperature=sc.temperature)[0])
            tel["prefill_time_s"] += time.perf_counter() - t0
            req.generated.append(nxt)
            tel["tokens_generated"] += 1
            slot.active, slot.request = True, req
            slot.pos = n  # next write position
            slot.last_token = nxt
            stats["prefilled"] += 1
            self._retire(idx)

        # 2. scan decode for all active slots
        if any(s.active for s in self.slots):
            tokens = np.asarray([s.last_token for s in self.slots], np.int32)
            positions = np.asarray(
                [s.pos if s.active else 0 for s in self.slots], np.int32
            )
            active = np.asarray([s.active for s in self.slots], bool)
            rem = np.asarray(
                [
                    max(s.request.max_new_tokens - len(s.request.generated), 0)
                    if s.active
                    else 0
                    for s in self.slots
                ],
                np.int32,
            )
            eos = np.asarray(
                [
                    s.request.eos_id
                    if s.active and s.request.eos_id is not None
                    else -1
                    for s in self.slots
                ],
                np.int32,
            )
            self.key, sub = jax.random.split(self.key)
            if tel["decode_compiles"] == 0:
                tel["decode_compiles"] = 1  # one program, fixed shapes
            t0 = time.perf_counter()
            toks_t, act_t, pos_f, act_f, self.caches = self._decode_fn(
                self.params, jnp.asarray(tokens), jnp.asarray(positions),
                jnp.asarray(active), jnp.asarray(rem), jnp.asarray(eos),
                self.caches, sub,
            )
            toks_t, act_t = np.asarray(toks_t), np.asarray(act_t)
            pos_f, act_f = np.asarray(pos_f), np.asarray(act_f)
            tel["decode_time_s"] += time.perf_counter() - t0
            for idx, slot in enumerate(self.slots):
                if not slot.active:
                    continue
                for t in range(toks_t.shape[0]):
                    if not act_t[t, idx]:
                        break
                    slot.request.generated.append(int(toks_t[t, idx]))
                    stats["decoded"] += 1
                    tel["tokens_generated"] += 1
                slot.pos = int(pos_f[idx])
                if slot.request.generated:
                    slot.last_token = slot.request.generated[-1]
                if not act_f[idx]:
                    self._finished[slot.request.uid] = slot.request
                    self.slots[idx] = _Slot()
                else:
                    self._retire(idx)
        stats.update(
            prefill_compiles=tel["prefill_compiles"],
            decode_compiles=tel["decode_compiles"],
        )
        return stats

    def _retire(self, idx: int):
        slot = self.slots[idx]
        if slot.active and (
            slot.request.done or slot.pos + 1 >= self.serve_cfg.max_seq_len
        ):
            self._finished[slot.request.uid] = slot.request
            self.slots[idx] = _Slot()

    def run(self, max_steps: int = 10_000) -> dict[int, Request]:
        t0 = time.perf_counter()
        tokens0 = self.telemetry["tokens_generated"]
        steps = 0
        while self.has_work and steps < max_steps:
            self.step()
            steps += 1
        dt = time.perf_counter() - t0
        tel = self.telemetry
        tel["run_wall_s"] = dt
        tel["tokens_per_s"] = (tel["tokens_generated"] - tokens0) / max(
            dt, 1e-9
        )
        admitted = max(tel["prompts_admitted"], 1)
        tel["queue_wait_s_mean"] = tel["queue_wait_s_total"] / admitted
        return dict(self._finished)

"""Serving engine: continuous batching over slot-structured KV caches.

The paper's subject is low-latency *inference*; this engine is its
datacenter-scale counterpart: a fixed pool of ``max_batch`` cache slots,
prompts prefilled into free slots while resident sequences keep decoding
(continuous batching / "in-flight batching"), greedy or temperature
sampling, optional int8 weights (PTQ), int8 KV cache, and the paper's LUT
softmax in the attention score path.

All device work happens in two jitted programs: ``_prefill_one`` (batch-1
prompt -> slot-cache insert) and ``_decode_all`` (one token for every
resident slot).  Host-side state is just the slot table.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ServeConfig
from repro.core import fixed_point as fxp
from repro.core import quant
from repro.models import lm
from repro.serve.sampling import sample

PyTree = Any


@dataclasses.dataclass
class Request:
    uid: int
    prompt: list[int]
    max_new_tokens: int = 16
    eos_id: int | None = None
    generated: list[int] = dataclasses.field(default_factory=list)

    @property
    def done(self) -> bool:
        if self.eos_id is not None and self.generated and self.generated[-1] == self.eos_id:
            return True
        return len(self.generated) >= self.max_new_tokens


@dataclasses.dataclass
class _Slot:
    active: bool = False
    request: Request | None = None
    pos: int = 0  # next position to write (== current length)
    last_token: int = 0


class ServingEngine:
    def __init__(
        self,
        cfg: ModelConfig,
        params: PyTree,
        serve_cfg: ServeConfig | None = None,
        kernel: dict | None = None,
        seed: int = 0,
    ):
        self.cfg = cfg
        self.serve_cfg = serve_cfg or ServeConfig()
        self.kernel = kernel or {}
        if self.serve_cfg.lut_softmax:
            self.kernel.setdefault("softmax_mode", "lut")
        self.key = jax.random.PRNGKey(seed)

        if self.serve_cfg.int8_weights:
            # PTQ int8 numerics on weights (quantize-dequantize; the true
            # int8 GEMM path is kernels/qmatmul on TPU)
            params = self._int8_params(params)
        self.params = params

        sc = self.serve_cfg
        self.quant_cache = bool(
            sc.int8_kv_cache
            and cfg.attn_kind in ("gqa", "mla")
            and cfg.family not in ("ssm", "hybrid")
        )
        self.caches = lm.init_caches(
            cfg, sc.max_batch, sc.max_seq_len,
            dtype=jnp.float32, quantized=self.quant_cache,
        )
        self.slots = [_Slot() for _ in range(sc.max_batch)]
        self._queue: list[Request] = []
        self._finished: dict[int, Request] = {}
        self._uid = 0

        self._decode_fn = jax.jit(self._decode_all)
        self._prefill_fn = {}  # jit cache per prompt length

    # ------------------------------------------------------------- utils --
    @staticmethod
    def _int8_params(params: PyTree) -> PyTree:
        def _q(leaf):
            if (
                isinstance(leaf, jax.Array)
                and jnp.issubdtype(leaf.dtype, jnp.floating)
                and leaf.ndim >= 2
            ):
                return quant.quantize_int8(leaf, axis=leaf.ndim - 1).dequantize(
                    leaf.dtype
                )
            return leaf

        return jax.tree.map(_q, params)

    # ----------------------------------------------------------- requests --
    def submit(self, prompt: list[int], max_new_tokens: int = 16,
               eos_id: int | None = None) -> int:
        self._uid += 1
        self._queue.append(
            Request(self._uid, list(prompt), max_new_tokens, eos_id)
        )
        return self._uid

    def result(self, uid: int) -> Request | None:
        return self._finished.get(uid)

    @property
    def has_work(self) -> bool:
        return bool(self._queue) or any(s.active for s in self.slots)

    # ------------------------------------------------------------ device --
    def _prefill_one(self, params, tokens, caches, slot_idx):
        """Prefill a batch-1 prompt and insert its cache into slot_idx."""
        cfg = self.cfg
        small = lm.init_caches(
            cfg, 1, self.serve_cfg.max_seq_len,
            dtype=jnp.float32, quantized=self.quant_cache,
        )
        logits, filled, _ = lm.forward(
            params, cfg, {"tokens": tokens}, mode="prefill",
            caches=small, kernel=self.kernel,
        )

        def insert(big, one):
            # batch axis is axis 1 on every stacked cache leaf
            return jax.lax.dynamic_update_index_in_dim(
                big, one[:, 0].astype(big.dtype), slot_idx, 1
            )

        new_caches = jax.tree.map(insert, caches, filled)
        return logits[:, -1], new_caches

    def _decode_all(self, params, tokens, positions, caches, key):
        logits, new_caches, _ = lm.forward(
            params, self.cfg, {"tokens": tokens}, mode="decode",
            caches=caches, positions=positions, kernel=self.kernel,
        )
        nxt = sample(
            logits[:, -1], key, temperature=self.serve_cfg.temperature
        )
        return nxt, new_caches

    # -------------------------------------------------------------- step --
    def step(self) -> dict:
        """One engine iteration: admit waiting prompts, then decode."""
        stats = {"prefilled": 0, "decoded": 0}
        # 1. admission: fill free slots with queued prompts
        for idx, slot in enumerate(self.slots):
            if not self._queue:
                break
            if slot.active:
                continue
            req = self._queue.pop(0)
            toks = jnp.asarray([req.prompt], jnp.int32)
            n = len(req.prompt)
            fn = self._prefill_fn.get(n)
            if fn is None:
                fn = jax.jit(self._prefill_one, static_argnames=())
                self._prefill_fn[n] = fn
            logits, self.caches = fn(
                self.params, toks, self.caches, idx
            )
            self.key, sub = jax.random.split(self.key)
            nxt = int(
                sample(logits, sub, temperature=self.serve_cfg.temperature)[0]
            )
            req.generated.append(nxt)
            slot.active, slot.request = True, req
            slot.pos = n  # next write position
            slot.last_token = nxt
            stats["prefilled"] += 1
            self._retire(idx)

        # 2. batched decode for all active slots
        if any(s.active for s in self.slots):
            tokens = jnp.asarray(
                [[s.last_token] for s in self.slots], jnp.int32
            )
            positions = jnp.asarray(
                [s.pos if s.active else 0 for s in self.slots], jnp.int32
            )
            self.key, sub = jax.random.split(self.key)
            nxt, self.caches = self._decode_fn(
                self.params, tokens, positions, self.caches, sub
            )
            nxt = np.asarray(nxt)
            for idx, slot in enumerate(self.slots):
                if not slot.active:
                    continue
                slot.pos += 1
                slot.last_token = int(nxt[idx])
                slot.request.generated.append(slot.last_token)
                stats["decoded"] += 1
                self._retire(idx)
        return stats

    def _retire(self, idx: int):
        slot = self.slots[idx]
        if slot.active and (
            slot.request.done or slot.pos + 1 >= self.serve_cfg.max_seq_len
        ):
            self._finished[slot.request.uid] = slot.request
            self.slots[idx] = _Slot()

    def run(self, max_steps: int = 10_000) -> dict[int, Request]:
        steps = 0
        while self.has_work and steps < max_steps:
            self.step()
            steps += 1
        return dict(self._finished)

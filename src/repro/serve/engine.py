"""Serving engine v3: continuous batching with bucketed *batched* prefill,
multi-token scan decode, and pluggable KV-cache layouts.

The paper's subject is low-latency *inference* with a bounded, pre-compiled
set of fixed-iteration datapaths (hls4ml pipelines); this engine is the
datacenter-scale counterpart and inherits that discipline:

* **Bucketed, batched prefill** — prompts are right-padded to power-of-two
  length buckets with an explicit per-row length mask, and every prompt
  sharing a bucket in one engine step rides ONE fixed-shape dispatch that
  fills up to ``max_batch`` slots at once.  The jit cache holds at most
  ``len(prefill_buckets)`` prefill programs (each at the fixed batch
  width) plus one decode program — test-enforced.
* **Scan decode** — ``decode_steps`` tokens per host dispatch via
  ``jax.lax.scan`` over the fused decode program, with per-slot active
  masks so finished slots (eos / max-tokens / sequence cap) freeze their
  position and stop emitting mid-scan.
* **KV-cache layouts** — all layout knowledge lives in
  ``serve/kv_cache.py`` behind a :class:`~repro.serve.kv_cache.CacheManager`:
  ``dense`` (per-slot slabs, the historical behavior) or ``paged``
  (block-table-indexed pages; long contexts allocate on demand, finished
  slots return pages immediately).  Both produce token-identical output.
* **Prefix-cache page sharing** (``kv_prefix_cache``, paged layout) — a
  same-prefix admission maps its leading block-table entries to pages the
  prefix index already holds (refcounted, copy-on-write on decode
  writes).  On the bit-exact datapath (float GQA, exact softmax, no
  Pallas), a hit also skips the prefill dispatch entirely: the unshared
  prompt tail is teacher-forced through the decode scan (forced steps
  write prompt KV and emit nothing), so the saved prefill FLOPs are
  real.  Elsewhere (MLA / int8-KV / LUT softmax, whose decode datapath
  is not bitwise the prefill datapath) a hit still dedupes storage: the
  full prompt is recomputed through the normal prefill program — logits
  bit-identical to dense by construction — and the insert skips the
  shared columns so shared history stays immutable.  Bit-identity is a
  statement about logits, and therefore about greedy token streams
  (test-enforced); sampled streams are equally distributed but not
  reproducible against a dense run when a skip or preemption changes
  the PRNG dispatch schedule.
* **Page-aware preemption** (``kv_preemption``, paged layout) — when the
  pool cannot cover the queue head's reservation, the youngest resident
  slot is preempted (private pages freed, request re-queued at the queue
  front with prompt + generated-so-far as a resumable prompt) instead of
  head-of-line blocking.  Enabled only on the bit-exact datapath, where
  re-prefilling previously-decoded positions reproduces the exact same
  values; other engines keep the FIFO serialization.
* **Telemetry** — tokens/s, queue wait, prefill/decode compile counters,
  and KV-cache occupancy (bytes, page utilization) from ``step()``/``run()``.
* **Precision policy** — ``ServeConfig.policy`` (a ``core.precision``
  PrecisionPolicy / preset name) selects the quantized datapath: offline
  weight transforms, KV-cache dtype (int8 per-token scales apply per page
  under the paged layout), LUT softmax, and any runtime fake-quant — all
  without adding jit programs beyond the float baseline.

Families whose caches are not position-addressed (SSM/hybrid state,
rolling sliding-window buffers) transparently fall back to exact-length
prefill and the dense layout, so every architecture keeps working.

Host-side state is just the slot table plus the page free-list; all
device work happens in the per-bucket prefill programs and one
decode-scan program.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ServeConfig
from repro.core import precision as precision_lib
from repro.models import lm
from repro.serve import kv_cache
from repro.serve.sampling import sample

PyTree = Any


@dataclasses.dataclass
class Request:
    uid: int
    prompt: list[int]
    max_new_tokens: int = 16
    eos_id: int | None = None
    generated: list[int] = dataclasses.field(default_factory=list)
    submitted_at: float = 0.0
    admitted_at: float = 0.0
    #: times this request was preempted (pages freed, re-queued to resume
    #: from prompt + generated-so-far); telemetry for the scheduler tests
    preemptions: int = 0

    @property
    def done(self) -> bool:
        if self.eos_id is not None and self.generated and self.generated[-1] == self.eos_id:
            return True
        return len(self.generated) >= self.max_new_tokens

    @property
    def resume_tokens(self) -> list[int]:
        """Effective prompt at (re-)admission: the original prompt plus
        everything generated before any preemption."""
        return self.prompt + self.generated

    @property
    def queue_wait_s(self) -> float:
        return max(0.0, self.admitted_at - self.submitted_at)


@dataclasses.dataclass
class _Slot:
    active: bool = False
    request: Request | None = None
    pos: int = 0  # next position to write (== current length)
    last_token: int = 0
    #: prompt-tail tokens still to be teacher-forced through the decode
    #: scan (prefill-skip admissions); drained decode_steps at a time
    pending: list[int] = dataclasses.field(default_factory=list)
    #: admission order stamp — preemption picks the youngest resident
    admit_seq: int = -1
    #: generated-token count at (re-)admission: a slot is only
    #: preemptable once it has emitted at least one token this
    #: residency, so every preemption cycle nets forward progress (a
    #: skip-resumed slot replaying its forced tail would otherwise be
    #: preempted before ever sampling — a livelock)
    admit_gen: int = 0


class ServingEngine:
    def __init__(
        self,
        cfg: ModelConfig,
        params: PyTree,
        serve_cfg: ServeConfig | None = None,
        kernel: dict | None = None,
        seed: int = 0,
    ):
        self.serve_cfg = serve_cfg or ServeConfig()
        if self.serve_cfg.decode_steps < 1:
            raise ValueError(
                f"decode_steps must be >= 1, got {self.serve_cfg.decode_steps}"
            )
        if self.serve_cfg.max_prefill_per_step < 0:
            raise ValueError(
                "max_prefill_per_step must be >= 0 (0 = fill all free slots)"
            )
        self.kernel = kernel or {}
        self.key = jax.random.PRNGKey(seed)

        # Precision: one declarative policy governs weights (offline PTQ /
        # int8 quantize-dequantize; the true int8 GEMM path is
        # kernels/qmatmul on TPU), the KV-cache dtype, the softmax kernel
        # mode, and any runtime fake-quant the model applies in-graph.
        # ServeConfig.policy wins; otherwise the model's own policy applies.
        if self.serve_cfg.policy is not None:
            policy = precision_lib.get_policy(self.serve_cfg.policy)
            cfg = dataclasses.replace(cfg, precision=policy)
        else:
            policy = precision_lib.model_policy(cfg)
        self.cfg = cfg
        self.policy = policy
        self.plan = policy.resolve(cfg.n_layers)
        self.kernel = self.plan.kernel_defaults(self.kernel) or {}
        self.params = precision_lib.apply_plan_to_params(params, self.plan)

        if self.plan.int8_kv_cache and self.plan.kv_cache.bits != 8:
            raise NotImplementedError(
                "the KV cache implements 8-bit per-token quantization only; "
                f"policy {self.policy.name!r} asks for "
                f"{self.plan.kv_cache.bits}-bit"
            )
        sc = self.serve_cfg
        self.quant_cache = bool(
            self.plan.int8_kv_cache
            and cfg.attn_kind in ("gqa", "mla")
            and cfg.family not in ("ssm", "hybrid")
        )
        # All layout knowledge (dense slabs vs block-table pages, specs,
        # insertion, allocation) lives in the manager.
        self.cache_mgr = kv_cache.CacheManager(
            cfg, sc, quantized=self.quant_cache, dtype=jnp.float32
        )
        self.kv_layout = self.cache_mgr.layout
        self.caches = self.cache_mgr.init_device_caches()
        self.slots = [_Slot() for _ in range(sc.max_batch)]
        self._queue: list[Request] = []
        self._finished: dict[int, Request] = {}
        self._uid = 0
        self._admit_seq = 0

        # Bit-exact datapath predicate: is a decode-path forward bitwise
        # identical to the prefill-path forward for the same token at the
        # same position?  True for float GQA with the exact softmax on the
        # jnp reference path — prefill's attention_ref and decode's
        # gather-view attend are then the same f32 math.  False for MLA
        # (~1 ulp: different einsum orders when re-materializing K/V from
        # the latent), int8 KV (prefill attends float K/V, decode attends
        # dequantized codes), and LUT softmax (decode uses exact softmax).
        # Prefill-skip (tail-via-forced-decode) and preemption-resume
        # (re-prefill of previously-decoded positions) are only enabled
        # where this holds, so token streams stay bit-identical to dense.
        self._bit_exact_resume = (
            self.kv_layout == "paged"
            and cfg.attn_kind == "gqa"
            and not self.quant_cache
            and self.kernel.get("softmax_mode", "safe") == "safe"
            and not self.kernel.get("use_pallas", False)
        )
        #: prefix hits skip the prefill dispatch (vs storage-only sharing)
        self._prefix_skip = (
            self.cache_mgr.prefix_cache and self._bit_exact_resume
        )
        #: page-aware preemption instead of FIFO head-of-line blocking
        self._preempt_enabled = (
            self.kv_layout == "paged"
            and sc.kv_preemption
            and self._bit_exact_resume
        )

        # right-padding the prompt is only sound when the cache is
        # position-addressed and decode masks by position: true for dense
        # GQA / MLA caches, false for SSM/hybrid state and for rolling
        # sliding-window buffers (padding would evict real tokens).
        self._bucketable = self.cache_mgr.position_addressed
        # a bucket longer than the cache could not be inserted; drop those
        self._buckets = (
            tuple(b for b in sc.resolved_buckets() if b <= sc.max_seq_len)
            if self._bucketable
            else ()
        )

        self._decode_fn = jax.jit(self._decode_scan)
        self._prefill_fn: dict[int, Any] = {}  # jit cache per bucket length
        self.telemetry = {
            "tokens_generated": 0,
            "prompts_admitted": 0,
            "prefill_compiles": 0,
            "prefill_dispatches": 0,
            "decode_compiles": 0,
            "queue_wait_s_total": 0.0,
            "prefill_time_s": 0.0,
            "decode_time_s": 0.0,
            "steps": 0,
            # prompt tokens never recomputed thanks to a prefix hit
            # (prefill-skip admissions only — real FLOPs saved)
            "prefill_tokens_saved": 0,
            # prompt tokens whose pages were deduped by a prefix hit on
            # the storage-only path (recomputed, but no pages written)
            "prefix_tokens_shared": 0,
            "preemptions": 0,
            **self.cache_mgr.stats().as_dict(),
        }

    # ------------------------------------------------------------- utils --
    @property
    def prefill_buckets(self) -> tuple[int, ...]:
        """Active buckets; empty for exact-length (v1-style) prefill."""
        return self._buckets

    def bucket_for(self, n: int) -> int:
        """Padded prefill length for an n-token prompt: the smallest bucket
        >= n, or n itself for unbucketable families / oversized prompts."""
        for b in self._buckets:
            if b >= n:
                return b
        return n

    def kv_stats(self) -> dict:
        """Current KV-cache occupancy (layout, bytes, page utilization)."""
        return self.cache_mgr.stats().as_dict()

    def _reserve_len(self, req: Request) -> int:
        """Worst-case sequence length for a request: decode writes reach at
        most position prompt + max_new_tokens - 1 (capped by max_seq_len)."""
        return min(
            len(req.prompt) + req.max_new_tokens, self.serve_cfg.max_seq_len
        )

    # ----------------------------------------------------------- requests --
    def submit(self, prompt: list[int], max_new_tokens: int = 16,
               eos_id: int | None = None) -> int:
        if not prompt:
            raise ValueError("empty prompt")
        if len(prompt) >= self.serve_cfg.max_seq_len:
            raise ValueError(
                f"prompt length {len(prompt)} >= max_seq_len "
                f"{self.serve_cfg.max_seq_len}"
            )
        req = Request(self._uid + 1, list(prompt), max_new_tokens, eos_id,
                      submitted_at=time.perf_counter())
        need = self.cache_mgr.pages_for(self._reserve_len(req))
        if need > self.cache_mgr.pages_capacity:
            raise ValueError(
                f"request needs {need} KV pages (prompt {len(prompt)} + "
                f"up to {max_new_tokens} new tokens) but the pool only "
                f"holds {self.cache_mgr.pages_capacity}; raise "
                "ServeConfig.kv_pages or lower max_new_tokens"
            )
        self._uid += 1
        self._queue.append(req)
        return self._uid

    def result(self, uid: int) -> Request | None:
        return self._finished.get(uid)

    @property
    def has_work(self) -> bool:
        return bool(self._queue) or any(s.active for s in self.slots)

    # ------------------------------------------------------------ device --
    def _prefill_batch(self, params, tokens, lengths, caches, slots,
                       shared=None):
        """Prefill up to ``max_batch`` same-bucket prompts in ONE dispatch.

        ``tokens``: (max_batch, bucket) int32, right-padded per row.
        ``lengths``: (max_batch,) true prompt lengths (0 for pad rows).
        ``slots``: (max_batch,) destination slot per row; the value
        ``max_batch`` marks a pad row (dropped by the dense scatter,
        routed to the trash page by the paged scatter).
        ``shared``: (max_batch,) leading prefix-cache pages per row whose
        recomputed values must not touch shared storage (their insert
        columns scatter to the trash page; 0 everywhere when the prefix
        cache is off).
        All four are traced, so every same-bucket wave reuses one
        compiled program.  Returns (per-row last-token logits (N, V),
        updated caches).
        """
        cfg = self.cfg
        nb, bucket = tokens.shape
        mask = jnp.arange(bucket, dtype=jnp.int32)[None, :] < lengths[:, None]
        tokens = jnp.where(mask, tokens, 0)  # canonical pad id
        # the model writes its natural contiguous (dense) scratch cache;
        # insert_prefill is the only layout-specific step.  Paged: the
        # scratch only needs to cover the bucket (rounded up to whole
        # pages), so the transient footprint scales with the bucket, not
        # with max_batch x max_seq_len.  Dense keeps the full-length
        # scratch: its insert scatters whole slot slabs (bit-identical
        # historical behavior, zeroed tail included).
        if self.kv_layout == "paged":
            ps = self.cache_mgr.page_size
            scratch_len = -(-bucket // ps) * ps
        else:
            scratch_len = self.serve_cfg.max_seq_len
        small = kv_cache.init_caches(
            cfg, nb, scratch_len,
            dtype=jnp.float32, quantized=self.quant_cache,
        )
        logits, filled, _ = lm.forward(
            params, cfg, {"tokens": tokens}, mode="prefill",
            caches=small, kernel=self.kernel,
        )
        # causal attention keeps positions < length independent of the pad
        # tail; each row's true logits live at index length-1
        idx = jnp.maximum(lengths - 1, 0)[:, None, None]
        last = jnp.take_along_axis(logits, idx, axis=1)[:, 0]
        filled = kv_cache.mask_cache_tail(filled, lengths)
        new_caches = self.cache_mgr.insert_prefill(
            caches, filled, slots, shared
        )
        return last, new_caches

    def _decode_scan(self, params, tokens, positions, active, rem, eos,
                     forced, n_forced, caches, key):
        """Run ``decode_steps`` fused decode steps under one dispatch.

        All arrays are per-slot (B,): ``tokens`` last sampled token,
        ``positions`` next write position, ``active`` live mask, ``rem``
        generation budget left, ``eos`` per-request eos id (-1 = none).
        Inactive slots freeze (token, position); re-running a frozen
        position is idempotent for position-addressed caches (dense slabs
        and pages alike — retired paged slots write the trash page) and
        harmless for retired SSM slots (their state is overwritten on
        re-prefill).

        ``forced``: (decode_steps, B) teacher-forced next tokens,
        ``n_forced``: (B,) how many leading steps of this dispatch force
        each slot (prefix-cache prefill-skip: the unshared prompt tail
        rides the decode program).  A forced step writes its prompt
        token's KV, overrides the sampled next token, emits nothing, and
        leaves the generation budget and eos/budget deactivation alone —
        so the first *sampled* token after the tail sees logits bitwise
        equal to the prefill path's last-position logits.  All zeros when
        nothing is forced, which reduces to the historical behavior.
        Returns (per-step next tokens, per-step emit mask, final carry
        token, final positions, final active mask, caches).
        """
        sc = self.serve_cfg
        keys = jax.random.split(key, sc.decode_steps)
        flags = (
            jnp.arange(sc.decode_steps, dtype=jnp.int32)[:, None]
            < n_forced[None, :]
        )  # (T, B)

        def body(carry, xs):
            k, forced_t, flag_t = xs
            tok, pos, act, budget, c = carry
            logits, new_c, _ = lm.forward(
                params, self.cfg, {"tokens": tok[:, None]}, mode="decode",
                caches=c, positions=pos, kernel=self.kernel,
            )
            sampled = sample(logits[:, -1], k, temperature=sc.temperature)
            nxt = jnp.where(act, jnp.where(flag_t, forced_t, sampled), tok)
            emit = act & ~flag_t
            emitted = (nxt, emit)
            budget = jnp.where(emit, budget - 1, budget)
            new_pos = jnp.where(act, pos + 1, pos)
            new_act = (
                act
                & (flag_t | ((nxt != eos) & (budget > 0)))
                & (new_pos + 1 < sc.max_seq_len)
            )
            return (nxt, new_pos, new_act, budget, new_c), emitted

        init = (tokens, positions, active, rem, caches)
        (tok, pos, act, rem, caches), (toks_t, emit_t) = jax.lax.scan(
            body, init, (keys, forced, flags)
        )
        return toks_t, emit_t, tok, pos, act, caches

    # -------------------------------------------------------------- step --
    def _try_preempt(self, free: list[int]) -> bool:
        """Preempt the youngest resident slot to unblock the queue head:
        free its pages (shared prefix pages survive via refcounts), stamp
        the preemption, and re-queue it right behind the head with
        prompt + generated-so-far as a resumable prompt.  Returns False
        when preemption is off or nothing is preemptable.

        A slot whose resume prompt no longer fits the largest configured
        prefill bucket is not preemptable: re-prefilling it would mint an
        exact-length jit program and silently blow the
        len(prefill_buckets) + 1 program budget.  Neither is a slot that
        has not emitted a token since its (re-)admission: preempting it
        would discard a residency that made no progress, and a
        skip-resumed slot still replaying its teacher-forced tail could
        be preempted every step forever (livelock)."""
        if not self._preempt_enabled:
            return False
        max_bucket = max(self._buckets) if self._buckets else None
        victims = [
            i for i, s in enumerate(self.slots)
            if s.active
            and len(s.request.generated) > s.admit_gen
            and (
                max_bucket is None
                or len(s.request.resume_tokens) <= max_bucket
            )
        ]
        if not victims:
            return False
        idx = max(victims, key=lambda i: self.slots[i].admit_seq)
        req = self.slots[idx].request
        req.preemptions += 1
        # the wait clock restarts at requeue: the next admission's queue
        # wait measures time spent waiting to resume, not time since the
        # original submission (which would double-count the residency)
        req.submitted_at = time.perf_counter()
        self.telemetry["preemptions"] += 1
        self.cache_mgr.free(idx)
        self.slots[idx] = _Slot()
        free.append(idx)
        self._queue.insert(1, req)
        return True

    def step(self) -> dict:
        """One engine iteration: admit waiting prompts (grouped by bucket,
        one dispatch per same-bucket group; prefix-hit prompts on the
        bit-exact datapath skip prefill entirely), then scan-decode."""
        tel = self.telemetry
        tel["steps"] += 1
        stats = {"prefilled": 0, "decoded": 0}
        sc = self.serve_cfg
        # 1. admission: fill free slots with queued prompts.  FIFO order;
        # when the queue head cannot get pages, either preempt the
        # youngest resident (kv_preemption on the bit-exact datapath) or
        # block the head until finished slots return pages (no
        # reordering, no starvation either way).
        cap = sc.max_prefill_per_step or sc.max_batch
        free = [i for i, s in enumerate(self.slots) if not s.active]
        admitted: list[tuple[int, Request, list[int], int]] = []
        n_admitted = 0
        while self._queue and free and n_admitted < cap:
            head = self._queue[0]
            seq = head.resume_tokens
            # reserve worst-case pages (prompt + generation budget) so
            # decode growth can never exhaust the pool mid-run; pages
            # still allocate lazily as the sequence actually grows.  A
            # prefix hit reserves only the unshared tail (+1 CoW page
            # when the first write lands inside a shared page).
            reserve_len = self._reserve_len(head)
            match = self.cache_mgr.match_prefix(seq)
            skip = bool(match) and self._prefix_skip and len(seq) > 1
            write_from = min(match.tokens, len(seq) - 1) if skip else len(seq)
            need = self.cache_mgr.admission_need(match, reserve_len, write_from)
            if not self.cache_mgr.can_reserve(need):
                if self._try_preempt(free):
                    continue  # pages (and a slot) came back; retry head
                break
            req = self._queue.pop(0)
            # queue wait ends at pop: prefill execution/compile time that
            # follows is prefill_time_s, not waiting.  A preemption-resume
            # adds its re-wait to the total but the prompt counts once.
            if req.admitted_at == 0.0:
                tel["prompts_admitted"] += 1
            req.admitted_at = time.perf_counter()
            tel["queue_wait_s_total"] += req.queue_wait_s
            n_admitted += 1
            idx = free.pop(0)
            self._admit_seq += 1
            self.slots[idx].admit_seq = self._admit_seq
            self.slots[idx].admit_gen = len(req.generated)
            shared = self.cache_mgr.admit(
                idx, seq, reserve_len,
                match=match, lazy_tail=skip, write_from=write_from,
            )
            if skip:
                # the shared pages hold every position < write_from; the
                # remaining tail rides the decode scan teacher-forced —
                # no prefill dispatch at all for this admission
                slot = self.slots[idx]
                slot.active, slot.request = True, req
                slot.pos = write_from
                slot.last_token = seq[write_from]
                slot.pending = list(seq[write_from + 1:])
                tel["prefill_tokens_saved"] += write_from
                stats["prefilled"] += 1
            else:
                tel["prefix_tokens_shared"] += match.tokens if match else 0
                admitted.append((idx, req, seq, shared))
        groups: dict[int, list[tuple[int, Request, list[int], int]]] = {}
        for idx, req, seq, shared in admitted:
            groups.setdefault(self.bucket_for(len(seq)), []).append(
                (idx, req, seq, shared)
            )
        for bucket in sorted(groups):
            self._dispatch_prefill(bucket, groups[bucket], stats)

        # 2. scan decode for all active slots
        if any(s.active for s in self.slots):
            nb = sc.max_batch
            forced = np.zeros((sc.decode_steps, nb), np.int32)
            n_forced = np.zeros((nb,), np.int32)
            for idx, slot in enumerate(self.slots):
                if slot.active:
                    nf = min(len(slot.pending), sc.decode_steps)
                    if nf:
                        forced[:nf, idx] = slot.pending[:nf]
                        n_forced[idx] = nf
                    # the scan advances at most min(decode_steps, forced
                    # tail + remaining budget) positions, so this never
                    # outgrows the pages reserved at admission; passing
                    # the write range lets the manager copy-on-write any
                    # shared page before the dispatch scatters into it
                    rem_i = max(
                        slot.request.max_new_tokens
                        - len(slot.request.generated),
                        1,
                    )
                    self.cache_mgr.ensure(
                        idx,
                        min(slot.pos + min(sc.decode_steps, nf + rem_i),
                            sc.max_seq_len),
                        write_from=slot.pos,
                    )
            self.caches = self.cache_mgr.flush_copies(self.caches)
            self.caches = self.cache_mgr.write_table(self.caches)
            tokens = np.asarray([s.last_token for s in self.slots], np.int32)
            positions = np.asarray(
                [s.pos if s.active else 0 for s in self.slots], np.int32
            )
            active = np.asarray([s.active for s in self.slots], bool)
            rem = np.asarray(
                [
                    max(s.request.max_new_tokens - len(s.request.generated), 0)
                    if s.active
                    else 0
                    for s in self.slots
                ],
                np.int32,
            )
            eos = np.asarray(
                [
                    s.request.eos_id
                    if s.active and s.request.eos_id is not None
                    else -1
                    for s in self.slots
                ],
                np.int32,
            )
            self.key, sub = jax.random.split(self.key)
            if tel["decode_compiles"] == 0:
                tel["decode_compiles"] = 1  # one program, fixed shapes
            t0 = time.perf_counter()
            toks_t, emit_t, tok_f, pos_f, act_f, self.caches = self._decode_fn(
                self.params, jnp.asarray(tokens), jnp.asarray(positions),
                jnp.asarray(active), jnp.asarray(rem), jnp.asarray(eos),
                jnp.asarray(forced), jnp.asarray(n_forced),
                self.caches, sub,
            )
            toks_t, emit_t = np.asarray(toks_t), np.asarray(emit_t)
            tok_f = np.asarray(tok_f)
            pos_f, act_f = np.asarray(pos_f), np.asarray(act_f)
            tel["decode_time_s"] += time.perf_counter() - t0
            for idx, slot in enumerate(self.slots):
                if not slot.active:
                    continue
                if slot.pending:
                    del slot.pending[:int(n_forced[idx])]
                for t in range(toks_t.shape[0]):
                    if not emit_t[t, idx]:
                        continue
                    slot.request.generated.append(int(toks_t[t, idx]))
                    stats["decoded"] += 1
                    tel["tokens_generated"] += 1
                slot.pos = int(pos_f[idx])
                slot.last_token = int(tok_f[idx])
                if self._prefix_skip:
                    # decode-completed full pages become shareable too:
                    # their content is bit-exact with a prefill of the
                    # same tokens on this datapath
                    self.cache_mgr.register_filled(
                        idx, slot.request.resume_tokens, slot.pos
                    )
                if not act_f[idx]:
                    self._finished[slot.request.uid] = slot.request
                    self.slots[idx] = _Slot()
                    self.cache_mgr.free(idx)
                else:
                    self._retire(idx)
        tel.update(self.cache_mgr.stats().as_dict())
        stats.update(
            prefill_compiles=tel["prefill_compiles"],
            decode_compiles=tel["decode_compiles"],
        )
        return stats

    def _dispatch_prefill(
        self,
        bucket: int,
        group: list[tuple[int, Request, list[int], int]],
        stats: dict,
    ):
        """One fixed-shape prefill dispatch filling every slot in ``group``
        (all prompts share ``bucket``); pad rows carry the slot sentinel
        ``max_batch`` so their writes are dropped.  Each row's ``seq`` is
        its effective prompt (original prompt + generated-so-far for a
        preempted request being resumed) and ``shared`` its count of
        prefix-cache pages the insert must not overwrite."""
        sc, tel = self.serve_cfg, self.telemetry
        nb = sc.max_batch
        toks = np.zeros((nb, bucket), np.int32)
        lengths = np.zeros((nb,), np.int32)
        slots_arr = np.full((nb,), nb, np.int32)
        shared_arr = np.zeros((nb,), np.int32)
        for row, (idx, req, seq, shared) in enumerate(group):
            n = len(seq)
            toks[row, :n] = seq
            lengths[row] = n
            slots_arr[row] = idx
            shared_arr[row] = shared
        self.caches = self.cache_mgr.write_table(self.caches)
        fn = self._prefill_fn.get(bucket)
        if fn is None:
            fn = jax.jit(self._prefill_batch)
            self._prefill_fn[bucket] = fn
            tel["prefill_compiles"] += 1
        t0 = time.perf_counter()
        last, self.caches = fn(
            self.params, jnp.asarray(toks), jnp.asarray(lengths),
            self.caches, jnp.asarray(slots_arr), jnp.asarray(shared_arr),
        )
        tel["prefill_dispatches"] += 1
        # one vectorized sample + one device->host transfer for the group
        self.key, sub = jax.random.split(self.key)
        first_tokens = np.asarray(
            sample(last[:len(group)], sub, temperature=sc.temperature)
        )
        for row, (idx, req, seq, _) in enumerate(group):
            nxt = int(first_tokens[row])
            req.generated.append(nxt)
            tel["tokens_generated"] += 1
            slot = self.slots[idx]
            slot.active, slot.request = True, req
            slot.pos = len(seq)  # next write position
            slot.last_token = nxt
            stats["prefilled"] += 1
            self._retire(idx)
        tel["prefill_time_s"] += time.perf_counter() - t0

    def _retire(self, idx: int):
        slot = self.slots[idx]
        if slot.active and (
            slot.request.done or slot.pos + 1 >= self.serve_cfg.max_seq_len
        ):
            self._finished[slot.request.uid] = slot.request
            self.slots[idx] = _Slot()
            self.cache_mgr.free(idx)

    def run(self, max_steps: int = 10_000) -> dict[int, Request]:
        t0 = time.perf_counter()
        tokens0 = self.telemetry["tokens_generated"]
        steps = 0
        while self.has_work and steps < max_steps:
            self.step()
            steps += 1
        dt = time.perf_counter() - t0
        tel = self.telemetry
        tel["run_wall_s"] = dt
        tel["tokens_per_s"] = (tel["tokens_generated"] - tokens0) / max(
            dt, 1e-9
        )
        admitted = max(tel["prompts_admitted"], 1)
        tel["queue_wait_s_mean"] = tel["queue_wait_s_total"] / admitted
        return dict(self._finished)

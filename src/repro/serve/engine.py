"""Serving engine v3: continuous batching with bucketed *batched* prefill,
multi-token scan decode, and pluggable KV-cache layouts.

The paper's subject is low-latency *inference* with a bounded, pre-compiled
set of fixed-iteration datapaths (hls4ml pipelines); this engine is the
datacenter-scale counterpart and inherits that discipline:

* **Bucketed, batched prefill** — prompts are right-padded to power-of-two
  length buckets with an explicit per-row length mask, and every prompt
  sharing a bucket in one engine step rides ONE fixed-shape dispatch that
  fills up to ``max_batch`` slots at once.  The jit cache holds at most
  ``len(prefill_buckets)`` prefill programs (each at the fixed batch
  width) plus one decode program — test-enforced.
* **Scan decode** — ``decode_steps`` tokens per host dispatch via
  ``jax.lax.scan`` over the fused decode program, with per-slot active
  masks so finished slots (eos / max-tokens / sequence cap) freeze their
  position and stop emitting mid-scan.
* **KV-cache layouts** — all layout knowledge lives in
  ``serve/kv_cache.py`` behind a :class:`~repro.serve.kv_cache.CacheManager`:
  ``dense`` (per-slot slabs, the historical behavior) or ``paged``
  (block-table-indexed pages; long contexts allocate on demand, finished
  slots return pages immediately).  Both produce token-identical output.
* **Telemetry** — tokens/s, queue wait, prefill/decode compile counters,
  and KV-cache occupancy (bytes, page utilization) from ``step()``/``run()``.
* **Precision policy** — ``ServeConfig.policy`` (a ``core.precision``
  PrecisionPolicy / preset name) selects the quantized datapath: offline
  weight transforms, KV-cache dtype (int8 per-token scales apply per page
  under the paged layout), LUT softmax, and any runtime fake-quant — all
  without adding jit programs beyond the float baseline.

Families whose caches are not position-addressed (SSM/hybrid state,
rolling sliding-window buffers) transparently fall back to exact-length
prefill and the dense layout, so every architecture keeps working.

Host-side state is just the slot table plus the page free-list; all
device work happens in the per-bucket prefill programs and one
decode-scan program.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ServeConfig
from repro.core import precision as precision_lib
from repro.models import lm
from repro.serve import kv_cache
from repro.serve.sampling import sample

PyTree = Any


@dataclasses.dataclass
class Request:
    uid: int
    prompt: list[int]
    max_new_tokens: int = 16
    eos_id: int | None = None
    generated: list[int] = dataclasses.field(default_factory=list)
    submitted_at: float = 0.0
    admitted_at: float = 0.0

    @property
    def done(self) -> bool:
        if self.eos_id is not None and self.generated and self.generated[-1] == self.eos_id:
            return True
        return len(self.generated) >= self.max_new_tokens

    @property
    def queue_wait_s(self) -> float:
        return max(0.0, self.admitted_at - self.submitted_at)


@dataclasses.dataclass
class _Slot:
    active: bool = False
    request: Request | None = None
    pos: int = 0  # next position to write (== current length)
    last_token: int = 0


class ServingEngine:
    def __init__(
        self,
        cfg: ModelConfig,
        params: PyTree,
        serve_cfg: ServeConfig | None = None,
        kernel: dict | None = None,
        seed: int = 0,
    ):
        self.serve_cfg = serve_cfg or ServeConfig()
        if self.serve_cfg.decode_steps < 1:
            raise ValueError(
                f"decode_steps must be >= 1, got {self.serve_cfg.decode_steps}"
            )
        if self.serve_cfg.max_prefill_per_step < 0:
            raise ValueError(
                "max_prefill_per_step must be >= 0 (0 = fill all free slots)"
            )
        self.kernel = kernel or {}
        self.key = jax.random.PRNGKey(seed)

        # Precision: one declarative policy governs weights (offline PTQ /
        # int8 quantize-dequantize; the true int8 GEMM path is
        # kernels/qmatmul on TPU), the KV-cache dtype, the softmax kernel
        # mode, and any runtime fake-quant the model applies in-graph.
        # ServeConfig.policy wins; otherwise the model's own policy applies.
        if self.serve_cfg.policy is not None:
            policy = precision_lib.get_policy(self.serve_cfg.policy)
            cfg = dataclasses.replace(cfg, precision=policy)
        else:
            policy = precision_lib.model_policy(cfg)
        self.cfg = cfg
        self.policy = policy
        self.plan = policy.resolve(cfg.n_layers)
        self.kernel = self.plan.kernel_defaults(self.kernel) or {}
        self.params = precision_lib.apply_plan_to_params(params, self.plan)

        if self.plan.int8_kv_cache and self.plan.kv_cache.bits != 8:
            raise NotImplementedError(
                "the KV cache implements 8-bit per-token quantization only; "
                f"policy {self.policy.name!r} asks for "
                f"{self.plan.kv_cache.bits}-bit"
            )
        sc = self.serve_cfg
        self.quant_cache = bool(
            self.plan.int8_kv_cache
            and cfg.attn_kind in ("gqa", "mla")
            and cfg.family not in ("ssm", "hybrid")
        )
        # All layout knowledge (dense slabs vs block-table pages, specs,
        # insertion, allocation) lives in the manager.
        self.cache_mgr = kv_cache.CacheManager(
            cfg, sc, quantized=self.quant_cache, dtype=jnp.float32
        )
        self.kv_layout = self.cache_mgr.layout
        self.caches = self.cache_mgr.init_device_caches()
        self.slots = [_Slot() for _ in range(sc.max_batch)]
        self._queue: list[Request] = []
        self._finished: dict[int, Request] = {}
        self._uid = 0

        # right-padding the prompt is only sound when the cache is
        # position-addressed and decode masks by position: true for dense
        # GQA / MLA caches, false for SSM/hybrid state and for rolling
        # sliding-window buffers (padding would evict real tokens).
        self._bucketable = self.cache_mgr.position_addressed
        # a bucket longer than the cache could not be inserted; drop those
        self._buckets = (
            tuple(b for b in sc.resolved_buckets() if b <= sc.max_seq_len)
            if self._bucketable
            else ()
        )

        self._decode_fn = jax.jit(self._decode_scan)
        self._prefill_fn: dict[int, Any] = {}  # jit cache per bucket length
        self.telemetry = {
            "tokens_generated": 0,
            "prompts_admitted": 0,
            "prefill_compiles": 0,
            "prefill_dispatches": 0,
            "decode_compiles": 0,
            "queue_wait_s_total": 0.0,
            "prefill_time_s": 0.0,
            "decode_time_s": 0.0,
            "steps": 0,
            **self.cache_mgr.stats().as_dict(),
        }

    # ------------------------------------------------------------- utils --
    @property
    def prefill_buckets(self) -> tuple[int, ...]:
        """Active buckets; empty for exact-length (v1-style) prefill."""
        return self._buckets

    def bucket_for(self, n: int) -> int:
        """Padded prefill length for an n-token prompt: the smallest bucket
        >= n, or n itself for unbucketable families / oversized prompts."""
        for b in self._buckets:
            if b >= n:
                return b
        return n

    def kv_stats(self) -> dict:
        """Current KV-cache occupancy (layout, bytes, page utilization)."""
        return self.cache_mgr.stats().as_dict()

    def _reserve_len(self, req: Request) -> int:
        """Worst-case sequence length for a request: decode writes reach at
        most position prompt + max_new_tokens - 1 (capped by max_seq_len)."""
        return min(
            len(req.prompt) + req.max_new_tokens, self.serve_cfg.max_seq_len
        )

    # ----------------------------------------------------------- requests --
    def submit(self, prompt: list[int], max_new_tokens: int = 16,
               eos_id: int | None = None) -> int:
        if not prompt:
            raise ValueError("empty prompt")
        if len(prompt) >= self.serve_cfg.max_seq_len:
            raise ValueError(
                f"prompt length {len(prompt)} >= max_seq_len "
                f"{self.serve_cfg.max_seq_len}"
            )
        req = Request(self._uid + 1, list(prompt), max_new_tokens, eos_id,
                      submitted_at=time.perf_counter())
        need = self.cache_mgr.pages_for(self._reserve_len(req))
        if need > self.cache_mgr.pages_capacity:
            raise ValueError(
                f"request needs {need} KV pages (prompt {len(prompt)} + "
                f"up to {max_new_tokens} new tokens) but the pool only "
                f"holds {self.cache_mgr.pages_capacity}; raise "
                "ServeConfig.kv_pages or lower max_new_tokens"
            )
        self._uid += 1
        self._queue.append(req)
        return self._uid

    def result(self, uid: int) -> Request | None:
        return self._finished.get(uid)

    @property
    def has_work(self) -> bool:
        return bool(self._queue) or any(s.active for s in self.slots)

    # ------------------------------------------------------------ device --
    def _prefill_batch(self, params, tokens, lengths, caches, slots):
        """Prefill up to ``max_batch`` same-bucket prompts in ONE dispatch.

        ``tokens``: (max_batch, bucket) int32, right-padded per row.
        ``lengths``: (max_batch,) true prompt lengths (0 for pad rows).
        ``slots``: (max_batch,) destination slot per row; the value
        ``max_batch`` marks a pad row (dropped by the dense scatter,
        routed to the trash page by the paged scatter).
        All three are traced, so every same-bucket wave reuses one
        compiled program.  Returns (per-row last-token logits (N, V),
        updated caches).
        """
        cfg = self.cfg
        nb, bucket = tokens.shape
        mask = jnp.arange(bucket, dtype=jnp.int32)[None, :] < lengths[:, None]
        tokens = jnp.where(mask, tokens, 0)  # canonical pad id
        # the model writes its natural contiguous (dense) scratch cache;
        # insert_prefill is the only layout-specific step.  Paged: the
        # scratch only needs to cover the bucket (rounded up to whole
        # pages), so the transient footprint scales with the bucket, not
        # with max_batch x max_seq_len.  Dense keeps the full-length
        # scratch: its insert scatters whole slot slabs (bit-identical
        # historical behavior, zeroed tail included).
        if self.kv_layout == "paged":
            ps = self.cache_mgr.page_size
            scratch_len = -(-bucket // ps) * ps
        else:
            scratch_len = self.serve_cfg.max_seq_len
        small = kv_cache.init_caches(
            cfg, nb, scratch_len,
            dtype=jnp.float32, quantized=self.quant_cache,
        )
        logits, filled, _ = lm.forward(
            params, cfg, {"tokens": tokens}, mode="prefill",
            caches=small, kernel=self.kernel,
        )
        # causal attention keeps positions < length independent of the pad
        # tail; each row's true logits live at index length-1
        idx = jnp.maximum(lengths - 1, 0)[:, None, None]
        last = jnp.take_along_axis(logits, idx, axis=1)[:, 0]
        filled = kv_cache.mask_cache_tail(filled, lengths)
        new_caches = self.cache_mgr.insert_prefill(caches, filled, slots)
        return last, new_caches

    def _decode_scan(self, params, tokens, positions, active, rem, eos,
                     caches, key):
        """Run ``decode_steps`` fused decode steps under one dispatch.

        All arrays are per-slot (B,): ``tokens`` last sampled token,
        ``positions`` next write position, ``active`` live mask, ``rem``
        generation budget left, ``eos`` per-request eos id (-1 = none).
        Inactive slots freeze (token, position); re-running a frozen
        position is idempotent for position-addressed caches (dense slabs
        and pages alike — retired paged slots write the trash page) and
        harmless for retired SSM slots (their state is overwritten on
        re-prefill).
        """
        sc = self.serve_cfg
        keys = jax.random.split(key, sc.decode_steps)

        def body(carry, k):
            tok, pos, act, budget, c = carry
            logits, new_c, _ = lm.forward(
                params, self.cfg, {"tokens": tok[:, None]}, mode="decode",
                caches=c, positions=pos, kernel=self.kernel,
            )
            nxt = sample(logits[:, -1], k, temperature=sc.temperature)
            nxt = jnp.where(act, nxt, tok)
            emitted = (nxt, act)
            budget = jnp.where(act, budget - 1, budget)
            new_pos = jnp.where(act, pos + 1, pos)
            new_act = (
                act
                & (nxt != eos)
                & (budget > 0)
                & (new_pos + 1 < sc.max_seq_len)
            )
            return (nxt, new_pos, new_act, budget, new_c), emitted

        init = (tokens, positions, active, rem, caches)
        (tok, pos, act, rem, caches), (toks_t, act_t) = jax.lax.scan(
            body, init, keys
        )
        return toks_t, act_t, pos, act, caches

    # -------------------------------------------------------------- step --
    def step(self) -> dict:
        """One engine iteration: admit waiting prompts (grouped by bucket,
        one dispatch per same-bucket group), then scan-decode."""
        tel = self.telemetry
        tel["steps"] += 1
        stats = {"prefilled": 0, "decoded": 0}
        sc = self.serve_cfg
        # 1. admission: fill free slots with queued prompts.  FIFO order;
        # a prompt that cannot get pages yet blocks the queue head until
        # finished slots return pages (no reordering, no starvation).
        cap = sc.max_prefill_per_step or sc.max_batch
        free = [i for i, s in enumerate(self.slots) if not s.active]
        admitted: list[tuple[int, Request]] = []
        while self._queue and free and len(admitted) < cap:
            head = self._queue[0]
            # reserve worst-case pages (prompt + generation budget) so
            # decode growth can never exhaust the pool mid-run; pages
            # still allocate lazily as the sequence actually grows
            reserve_len = self._reserve_len(head)
            if not self.cache_mgr.can_reserve(
                self.cache_mgr.pages_for(reserve_len)
            ):
                break
            req = self._queue.pop(0)
            # queue wait ends at pop: prefill execution/compile time that
            # follows is prefill_time_s, not waiting
            req.admitted_at = time.perf_counter()
            tel["queue_wait_s_total"] += req.queue_wait_s
            tel["prompts_admitted"] += 1
            idx = free.pop(0)
            self.cache_mgr.admit(idx, len(req.prompt), reserve_len)
            admitted.append((idx, req))
        groups: dict[int, list[tuple[int, Request]]] = {}
        for idx, req in admitted:
            groups.setdefault(self.bucket_for(len(req.prompt)), []).append(
                (idx, req)
            )
        for bucket in sorted(groups):
            self._dispatch_prefill(bucket, groups[bucket], stats)

        # 2. scan decode for all active slots
        if any(s.active for s in self.slots):
            for idx, slot in enumerate(self.slots):
                if slot.active:
                    # the scan advances at most min(decode_steps, remaining
                    # budget) positions, so this never outgrows the pages
                    # reserved at admission
                    rem_i = max(
                        slot.request.max_new_tokens
                        - len(slot.request.generated),
                        1,
                    )
                    self.cache_mgr.ensure(
                        idx,
                        min(slot.pos + min(sc.decode_steps, rem_i),
                            sc.max_seq_len),
                    )
            self.caches = self.cache_mgr.write_table(self.caches)
            tokens = np.asarray([s.last_token for s in self.slots], np.int32)
            positions = np.asarray(
                [s.pos if s.active else 0 for s in self.slots], np.int32
            )
            active = np.asarray([s.active for s in self.slots], bool)
            rem = np.asarray(
                [
                    max(s.request.max_new_tokens - len(s.request.generated), 0)
                    if s.active
                    else 0
                    for s in self.slots
                ],
                np.int32,
            )
            eos = np.asarray(
                [
                    s.request.eos_id
                    if s.active and s.request.eos_id is not None
                    else -1
                    for s in self.slots
                ],
                np.int32,
            )
            self.key, sub = jax.random.split(self.key)
            if tel["decode_compiles"] == 0:
                tel["decode_compiles"] = 1  # one program, fixed shapes
            t0 = time.perf_counter()
            toks_t, act_t, pos_f, act_f, self.caches = self._decode_fn(
                self.params, jnp.asarray(tokens), jnp.asarray(positions),
                jnp.asarray(active), jnp.asarray(rem), jnp.asarray(eos),
                self.caches, sub,
            )
            toks_t, act_t = np.asarray(toks_t), np.asarray(act_t)
            pos_f, act_f = np.asarray(pos_f), np.asarray(act_f)
            tel["decode_time_s"] += time.perf_counter() - t0
            for idx, slot in enumerate(self.slots):
                if not slot.active:
                    continue
                for t in range(toks_t.shape[0]):
                    if not act_t[t, idx]:
                        break
                    slot.request.generated.append(int(toks_t[t, idx]))
                    stats["decoded"] += 1
                    tel["tokens_generated"] += 1
                slot.pos = int(pos_f[idx])
                if slot.request.generated:
                    slot.last_token = slot.request.generated[-1]
                if not act_f[idx]:
                    self._finished[slot.request.uid] = slot.request
                    self.slots[idx] = _Slot()
                    self.cache_mgr.free(idx)
                else:
                    self._retire(idx)
        tel.update(self.cache_mgr.stats().as_dict())
        stats.update(
            prefill_compiles=tel["prefill_compiles"],
            decode_compiles=tel["decode_compiles"],
        )
        return stats

    def _dispatch_prefill(
        self, bucket: int, group: list[tuple[int, Request]], stats: dict
    ):
        """One fixed-shape prefill dispatch filling every slot in ``group``
        (all prompts share ``bucket``); pad rows carry the slot sentinel
        ``max_batch`` so their writes are dropped."""
        sc, tel = self.serve_cfg, self.telemetry
        nb = sc.max_batch
        toks = np.zeros((nb, bucket), np.int32)
        lengths = np.zeros((nb,), np.int32)
        slots_arr = np.full((nb,), nb, np.int32)
        for row, (idx, req) in enumerate(group):
            n = len(req.prompt)
            toks[row, :n] = req.prompt
            lengths[row] = n
            slots_arr[row] = idx
        self.caches = self.cache_mgr.write_table(self.caches)
        fn = self._prefill_fn.get(bucket)
        if fn is None:
            fn = jax.jit(self._prefill_batch)
            self._prefill_fn[bucket] = fn
            tel["prefill_compiles"] += 1
        t0 = time.perf_counter()
        last, self.caches = fn(
            self.params, jnp.asarray(toks), jnp.asarray(lengths),
            self.caches, jnp.asarray(slots_arr),
        )
        tel["prefill_dispatches"] += 1
        # one vectorized sample + one device->host transfer for the group
        self.key, sub = jax.random.split(self.key)
        first_tokens = np.asarray(
            sample(last[:len(group)], sub, temperature=sc.temperature)
        )
        for row, (idx, req) in enumerate(group):
            nxt = int(first_tokens[row])
            req.generated.append(nxt)
            tel["tokens_generated"] += 1
            slot = self.slots[idx]
            slot.active, slot.request = True, req
            slot.pos = len(req.prompt)  # next write position
            slot.last_token = nxt
            stats["prefilled"] += 1
            self._retire(idx)
        tel["prefill_time_s"] += time.perf_counter() - t0

    def _retire(self, idx: int):
        slot = self.slots[idx]
        if slot.active and (
            slot.request.done or slot.pos + 1 >= self.serve_cfg.max_seq_len
        ):
            self._finished[slot.request.uid] = slot.request
            self.slots[idx] = _Slot()
            self.cache_mgr.free(idx)

    def run(self, max_steps: int = 10_000) -> dict[int, Request]:
        t0 = time.perf_counter()
        tokens0 = self.telemetry["tokens_generated"]
        steps = 0
        while self.has_work and steps < max_steps:
            self.step()
            steps += 1
        dt = time.perf_counter() - t0
        tel = self.telemetry
        tel["run_wall_s"] = dt
        tel["tokens_per_s"] = (tel["tokens_generated"] - tokens0) / max(
            dt, 1e-9
        )
        admitted = max(tel["prompts_admitted"], 1)
        tel["queue_wait_s_mean"] = tel["queue_wait_s_total"] / admitted
        return dict(self._finished)

"""Deprecated monolithic serving facade.

The serving engine was split into three layers — scheduling policy
(``serve/scheduler.py``), device execution (``serve/executor.py``), and
the client-facing streaming API (``serve/api.py``).  This module keeps
the old ``ServingEngine`` surface alive for one release as a thin shim
over :class:`repro.serve.api.Engine`: numerics are identical (the shim
adds no logic of its own), but every construction emits a
``DeprecationWarning``.  Migrate:

    ``ServingEngine(cfg, params, sc)``   -> ``Engine(cfg, params, sc)``
    ``uid = eng.submit(p, n)``           -> ``h = eng.submit(p, max_new_tokens=n)``
    ``eng.run()``                        -> ``eng.generate()``
    (new) token streaming                -> ``for ev in eng.stream(h): ...``
    (new) cancellation                   -> ``eng.cancel(h)``

See README "Serving API" for the full migration table.
"""

from __future__ import annotations

import warnings
from typing import Any

from repro.configs.base import ModelConfig, ServeConfig
from repro.serve.api import Engine
from repro.serve.scheduler import Request  # noqa: F401  (re-export)

PyTree = Any


class ServingEngine:
    """Deprecated: use :class:`repro.serve.Engine` (``generate`` /
    ``stream``) instead.  Delegates everything to a wrapped Engine —
    same scheduler, same executor, same compiled programs, token
    streams bit-identical."""

    def __init__(
        self,
        cfg: ModelConfig,
        params: PyTree,
        serve_cfg: ServeConfig | None = None,
        kernel: dict | None = None,
        seed: int = 0,
    ):
        warnings.warn(
            "ServingEngine is deprecated and will be removed next release; "
            "use repro.serve.Engine (Engine.generate replaces run, "
            "Engine.stream adds token streaming)",
            DeprecationWarning,
            stacklevel=2,
        )
        self._engine = Engine(cfg, params, serve_cfg, kernel=kernel, seed=seed)

    # ------------------------------------------------------- old surface --
    def submit(self, prompt: list[int], max_new_tokens: int = 16,
               eos_id: int | None = None) -> int:
        return self._engine.submit(
            prompt, max_new_tokens=max_new_tokens, eos_id=eos_id
        ).uid

    def run(self, max_steps: int = 10_000) -> dict[int, Request]:
        return self._engine.generate(max_steps=max_steps)

    def step(self) -> dict:
        return self._engine.step()

    def result(self, uid: int) -> Request | None:
        return self._engine.result(uid)

    @property
    def has_work(self) -> bool:
        return self._engine.has_work

    def kv_stats(self) -> dict:
        return self._engine.kv_stats()

    def bucket_for(self, n: int) -> int:
        return self._engine.scheduler.bucket_for(n)

    @property
    def prefill_buckets(self) -> tuple[int, ...]:
        """Active buckets; empty for exact-length (v1-style) prefill."""
        return self._engine.executor.buckets

    @property
    def telemetry(self) -> dict:
        return self._engine.telemetry

    # ------------------------------------------------ legacy attributes --
    # The monolith exposed its internals; tests and tooling built on them
    # keep working against the split layers for the deprecation window.
    @property
    def cfg(self):
        return self._engine.executor.cfg

    @property
    def serve_cfg(self):
        return self._engine.serve_cfg

    @property
    def params(self):
        return self._engine.executor.params

    @property
    def policy(self):
        return self._engine.executor.policy

    @property
    def plan(self):
        return self._engine.executor.plan

    @property
    def kernel(self):
        return self._engine.executor.kernel

    @property
    def quant_cache(self):
        return self._engine.executor.quant_cache

    @property
    def cache_mgr(self):
        return self._engine.executor.cache_mgr

    @property
    def kv_layout(self):
        return self._engine.executor.kv_layout

    @property
    def caches(self):
        return self._engine.executor.caches

    @property
    def slots(self):
        return self._engine.executor.slots

    @property
    def key(self):
        return self._engine.executor.key

    @property
    def _queue(self):
        return self._engine.scheduler.queue

    @property
    def _finished(self):
        return self._engine._finished

    @property
    def _prefill_fn(self):
        return self._engine.executor._prefill_fn

    @property
    def _decode_fn(self):
        return self._engine.executor._decode_fn

    def _prefill_batch(self, *args, **kwargs):
        return self._engine.executor._prefill_batch(*args, **kwargs)

    @property
    def _bucketable(self):
        return self._engine.executor.bucketable

    @property
    def _bit_exact_resume(self):
        return self._engine.executor.bit_exact

    @property
    def _prefix_skip(self):
        return self._engine.scheduler.prefix_skip

    @property
    def _preempt_enabled(self):
        return self._engine.scheduler.preempt_enabled

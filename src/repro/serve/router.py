"""Data-parallel replica routing: N engines, one front door.

The scale-out story on top of the single-engine stack: a
:class:`ReplicaRouter` owns ``ServeConfig.replicas`` independent
:class:`~repro.serve.api.Engine` instances (each with its own scheduler,
executor, KV pool, and jit caches — len(prefill_buckets)+2 compiled
programs *per replica*) and routes each submitted request to the
least-loaded replica at admission.  Replicas never share request state,
so everything the single engine guarantees (token identity, program
budget, cancel/preemption semantics, the async pipelined loop) holds
per replica unchanged; the router only multiplexes the request
lifecycle API over them:

* :meth:`submit` — least-loaded admission: the replica with the fewest
  open requests (queued + resident) wins, ties broken by replica index,
  so a fixed submission order routes deterministically.
* :meth:`stream` / :meth:`result` / :meth:`cancel` — delegate to the
  owning replica; router handles carry router-level uids (each engine
  mints its own local uids, so TokenEvents are re-stamped on the way
  out).
* :meth:`step` — pump every replica that has work (one engine
  iteration each); :meth:`generate` runs all replicas to idle.
* :attr:`telemetry` — per-replica telemetries plus summed core
  counters, so throughput math over the fleet stays one dict away.

Pumping a single replica's stream advances only that replica — one
slow tenant cannot stall tokens for requests routed elsewhere.
"""

from __future__ import annotations

import dataclasses
from collections.abc import Iterator
from typing import Any, Callable

from repro.configs.base import ModelConfig, ServeConfig
from repro.serve.api import Engine, RequestHandle, TokenEvent
from repro.serve.sampling import SamplingParams
from repro.serve.scheduler import Request, Scheduler

PyTree = Any

#: telemetry counters summed across replicas (the fleet-level view);
#: everything else is reported per replica only
_SUMMED = (
    "tokens_generated",
    "prefill_dispatches",
    "extend_dispatches",
    "prompts_admitted",
    "preemptions",
    "deadline_requests",
    "deadline_missed",
    "deadline_dropped",
    "draft_tokens_proposed",
    "draft_tokens_accepted",
    "spec_dispatches",
    "gen_pages_shared",
)


class ReplicaRouter:
    """Front door over ``ServeConfig.replicas`` data-parallel engines.

    Construction mirrors :class:`~repro.serve.api.Engine` — same
    ``(cfg, params, serve_cfg, kernel, seed, scheduler_factory,
    clock)`` signature — and builds one engine per replica from the
    same config (each replica sees ``replicas=1``; the fan-out lives
    here).  ``params`` are shared by reference: replicas on one host
    read the same device arrays, so N replicas cost N KV pools, not N
    copies of the weights.  Every replica gets the same base ``seed``
    with its replica index folded into the dispatch key (see
    ``ModelExecutor``) — collision-free across (seed, replica) pairs,
    unlike additive ``seed + i`` offsets — so unseeded sampled
    (temperature > 0) replicas draw distinct streams; greedy decoding
    and per-request *seeded* streams are key-independent and stay
    bit-identical to a single engine.
    """

    def __init__(
        self,
        cfg: ModelConfig,
        params: PyTree,
        serve_cfg: ServeConfig | None = None,
        kernel: dict | None = None,
        seed: int = 0,
        scheduler_factory: Callable[..., Scheduler] | None = None,
        clock: Callable[[], float] | None = None,
        draft: tuple | None = None,
    ):
        sc = serve_cfg or ServeConfig()
        if sc.replicas < 1:
            raise ValueError(f"replicas must be >= 1, got {sc.replicas}")
        per_replica = dataclasses.replace(sc, replicas=1)
        self.serve_cfg = sc
        self.engines = [
            Engine(
                cfg, params, per_replica, kernel=kernel, seed=seed,
                scheduler_factory=scheduler_factory, clock=clock,
                replica=i, draft=draft,
            )
            for i in range(sc.replicas)
        ]
        self._uid = 0
        #: router uid -> (replica index, that replica's local uid)
        self._route: dict[int, tuple[int, int]] = {}

    # --------------------------------------------------------- admission --
    def _load(self, idx: int) -> int:
        """Open requests on replica ``idx``: queued + resident.  The
        admission signal — O(max_batch) per replica, no device sync."""
        eng = self.engines[idx]
        return len(eng.scheduler.queue) + sum(
            s.active for s in eng.executor.slots
        )

    def submit(
        self,
        prompt: list[int],
        params: SamplingParams | None = None,
        **kw,
    ) -> RequestHandle | list[RequestHandle]:
        """Admit to the least-loaded replica (ties -> lowest index) and
        return a router-level handle (a list of them for ``n > 1``
        fan-out — the siblings stay on one replica so their generation
        pages can share)."""
        idx = min(range(len(self.engines)), key=lambda i: (self._load(i), i))
        local = self.engines[idx].submit(prompt, params, **kw)
        locals_ = local if isinstance(local, list) else [local]
        out = []
        for lh in locals_:
            self._uid += 1
            self._route[self._uid] = (idx, lh.uid)
            out.append(RequestHandle(self._uid))
        return out if isinstance(local, list) else out[0]

    def replica_of(self, handle: RequestHandle | int) -> int:
        """Which replica a request was routed to (introspection/tests)."""
        uid = handle.uid if isinstance(handle, RequestHandle) else handle
        return self._route[uid][0]

    def _resolve(self, handle: RequestHandle | int) -> tuple[Engine, int]:
        uid = handle.uid if isinstance(handle, RequestHandle) else handle
        try:
            idx, local = self._route[uid]
        except KeyError:
            raise KeyError(f"unknown request {uid}") from None
        return self.engines[idx], local

    # --------------------------------------------------------- lifecycle --
    def cancel(self, handle: RequestHandle | int) -> bool:
        eng, local = self._resolve(handle)
        return eng.cancel(local)

    def result(self, handle: RequestHandle | int) -> Request | None:
        eng, local = self._resolve(handle)
        return eng.result(local)

    def request(self, handle: RequestHandle | int) -> Request:
        eng, local = self._resolve(handle)
        return eng.request(local)

    def finish_reason(self, handle: RequestHandle | int) -> str | None:
        eng, local = self._resolve(handle)
        return eng.finish_reason(local)

    def stream(self, handle: RequestHandle | int) -> Iterator[TokenEvent]:
        """The owning replica's event stream, re-stamped with the
        router uid.  Pumping it advances that replica only."""
        uid = handle.uid if isinstance(handle, RequestHandle) else handle
        eng, local = self._resolve(uid)
        for ev in eng.stream(local):
            yield dataclasses.replace(ev, uid=uid)

    @property
    def has_work(self) -> bool:
        return any(eng.has_work for eng in self.engines)

    # -------------------------------------------------------------- loop --
    def step(self) -> dict:
        """Pump one engine iteration on every replica that has work;
        returns summed step stats."""
        total: dict = {}
        for eng in self.engines:
            if not eng.has_work:
                continue
            for k, v in eng.step().items():
                total[k] = total.get(k, 0) + v
        return total

    def generate(
        self,
        prompts: list[list[int]] | None = None,
        params: SamplingParams | None = None,
        *,
        max_new_tokens: int = 16,
        eos_id: int | None = None,
        max_steps: int = 10_000,
    ) -> dict[int, Request]:
        """Batch convenience over the fleet: submit ``prompts`` through
        least-loaded admission, run every replica to idle, and return
        finished requests keyed by *router* uid (including requests
        submitted earlier through :meth:`submit`)."""
        if prompts is not None:
            sp = params or SamplingParams(
                max_new_tokens=max_new_tokens, eos_id=eos_id
            )
            for prompt in prompts:
                self.submit(prompt, sp)
        steps = 0
        while self.has_work and steps < max_steps:
            self.step()
            steps += 1
        out: dict[int, Request] = {}
        for uid, (idx, local) in self._route.items():
            req = self.engines[idx].result(local)
            if req is not None:
                out[uid] = req
        return out

    # --------------------------------------------------------- telemetry --
    @property
    def telemetry(self) -> dict:
        """``replicas`` (per-replica dicts, routing loads) plus fleet
        sums of the core counters."""
        per = [eng.telemetry for eng in self.engines]
        tel: dict = {
            "replicas": len(self.engines),
            "replica_telemetry": per,
            "replica_loads": [
                self._load(i) for i in range(len(self.engines))
            ],
        }
        for key in _SUMMED:
            tel[key] = sum(t.get(key, 0) for t in per)
        return tel

    def kv_stats(self) -> list[dict]:
        return [eng.kv_stats() for eng in self.engines]

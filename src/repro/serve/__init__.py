# kv_cache first: it is import-standalone, and models/attention.py reaches
# back into it (repro.serve.kv_cache) while engine -> models is importing
from repro.serve import kv_cache  # noqa: F401
from repro.serve.kv_cache import CacheManager, CacheStats, PrefixMatch  # noqa: F401
from repro.serve.scheduler import (  # noqa: F401
    Admission,
    ExecutorCaps,
    FifoScheduler,
    Request,
    ScheduleDecision,
    Scheduler,
    Slot,
)
from repro.serve.slo import DeadlineScheduler  # noqa: F401
from repro.serve.executor import (  # noqa: F401
    DraftWorker,
    InflightStep,
    ModelExecutor,
    StepOutput,
)
from repro.serve.api import Engine, RequestHandle, TokenEvent  # noqa: F401
from repro.serve.router import ReplicaRouter  # noqa: F401
from repro.serve.engine import ServingEngine  # noqa: F401  (deprecated shim)
from repro.serve.sampling import (  # noqa: F401
    SamplingParams,
    sample,
    sample_tokens,
)
from repro.serve.phases import (  # noqa: F401
    NULL_TRACER,
    NullTracer,
    OverlapTracer,
    PhaseTracer,
    make_tracer,
)
from repro.serve.workloads import (  # noqa: F401
    ArrivalEvent,
    ReplayReport,
    StepClock,
    load_trace,
    multi_tenant,
    poisson,
    replay,
    save_trace,
    synchronous,
)

# kv_cache first: it is import-standalone, and models/attention.py reaches
# back into it (repro.serve.kv_cache) while engine -> models is importing
from repro.serve import kv_cache  # noqa: F401
from repro.serve.kv_cache import CacheManager, CacheStats, PrefixMatch  # noqa: F401
from repro.serve.engine import Request, ServingEngine  # noqa: F401
from repro.serve.sampling import sample  # noqa: F401

from repro.serve.engine import Request, ServingEngine  # noqa: F401
from repro.serve.sampling import sample  # noqa: F401

"""Shared serving CLI: one flag set and one ServeConfig builder for every
serving entry point (``launch/serve.py``, ``examples/serve_lm.py``,
benchmarks).  The launchers used to re-declare the same ~12 flags each;
they now both import :func:`add_serving_args` / :func:`config_from_args`
so a new engine knob lands in every CLI by construction.
"""

from __future__ import annotations

import argparse

from repro.configs.base import ServeConfig


def resolve_policy_arg(policy: str | None, quantized: bool, cfg) -> str | None:
    """Shared --policy semantics for the serving CLIs: explicit --policy
    wins; 'auto' resolves to the arch's recommended ``cfg.serve_policy``;
    the deprecated --quantized maps to the int8_serve preset."""
    if policy == "auto":
        return cfg.serve_policy
    if policy is not None:
        return policy
    if quantized:
        return "int8_serve"
    return None


def add_serving_args(
    ap: argparse.ArgumentParser,
    *,
    max_batch: int = 4,
    max_seq: int = 128,
    max_new: int = 16,
    temperature: float = 0.0,
) -> argparse.ArgumentParser:
    """Register the engine flag set (batch/sequence shape, precision
    policy, prefill/decode knobs, KV-cache layout and sharing, chunked
    prefill, streaming).  Per-script defaults ride the keyword args."""
    ap.add_argument("--max-batch", type=int, default=max_batch)
    ap.add_argument("--max-seq", type=int, default=max_seq)
    ap.add_argument("--max-new", type=int, default=max_new)
    ap.add_argument("--temperature", type=float, default=temperature)
    ap.add_argument("--policy", default=None,
                    help="precision policy: a preset name (float, int8_serve, "
                         "paper_vu13p, ptq_fixed<W,I>, qat_fixed<W,I>) or "
                         "'auto' for the arch's recommended serve_policy")
    ap.add_argument("--quantized", action="store_true",
                    help="deprecated alias for --policy int8_serve")
    ap.add_argument("--prefill-buckets", type=int, nargs="*", default=None,
                    help="prompt-length buckets (default: powers of two; "
                         "pass with no values for exact-length v1 prefill)")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="chunked prefill: admit long prompts one "
                         "chunk-sized dispatch at a time, interleaved with "
                         "resident decode; later chunks ride the "
                         "cache-extending prefill program on every datapath "
                         "(GQA, MLA, int8-KV, LUT softmax; must not exceed "
                         "the largest bucket; requires a bucketable cache)")
    ap.add_argument("--decode-steps", type=int, default=4,
                    help="decode tokens per host dispatch (lax.scan)")
    ap.add_argument("--max-prefill-per-step", type=int, default=0,
                    help="cap on prompts admitted per step (0 = all free slots)")
    ap.add_argument("--kv-layout", default="dense",
                    choices=("dense", "paged"),
                    help="KV-cache storage layout: dense per-slot slabs or "
                         "block-table pages (serve/kv_cache.py)")
    ap.add_argument("--kv-page-size", type=int, default=16,
                    help="tokens per page (paged layout; must divide "
                         "--max-seq)")
    ap.add_argument("--kv-pages", type=int, default=None,
                    help="physical pages in the pool (default: worst case "
                         "max_batch x max_seq / page_size, + trash page)")
    ap.add_argument("--kv-prefix-cache", action="store_true",
                    help="share full prompt pages across same-prefix "
                         "requests (paged layout; copy-on-write)")
    ap.add_argument("--kv-preemption", action="store_true",
                    help="preempt the youngest resident instead of "
                         "head-of-line blocking when the page pool is "
                         "exhausted; resumes are token-exact on every "
                         "datapath (paged layout)")
    ap.add_argument("--kv-host-pages", type=int, default=0,
                    help="host-memory victim tier: pages evicted off the "
                         "prefix-cache LRU spill their rows to a host ring "
                         "of this many pages and swap back into fresh "
                         "device pages on a later prefix hit (paged layout "
                         "with --kv-prefix-cache; 0 = off)")
    ap.add_argument("--no-kv-victim-tier", action="store_true",
                    help="kill switch: keep --kv-host-pages configured but "
                         "never spill or swap (evictions discard rows, as "
                         "without a tier)")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="prepend a fixed preamble of this many tokens to "
                         "every request (prefix-cache exercise; think "
                         "repeated detector-geometry preambles)")
    ap.add_argument("--no-cache-extend", action="store_true",
                    help="disable the cache-extending prefill program "
                         "(chunked prefill / prefix-skip / preemption fall "
                         "back to bit-exact-datapath gating, as before)")
    ap.add_argument("--speculative", action="store_true",
                    help="speculative decoding: a draft model proposes "
                         "--spec-tokens greedy tokens per slot; the target "
                         "verifies the window in one cache-extending "
                         "dispatch (accept-prefix + correction; greedy "
                         "output stays bitwise identical on bit-exact "
                         "datapaths)")
    ap.add_argument("--draft", default=None,
                    help="draft model for --speculative: a config-zoo arch "
                         "name (reduced shape), or 'self'/omitted for "
                         "self-drafting with the target model")
    ap.add_argument("--spec-tokens", type=int, default=4,
                    help="draft tokens proposed per speculative step "
                         "(capped by the extend window width)")
    ap.add_argument("--stream", action="store_true",
                    help="consume requests through Engine.stream "
                         "(per-token events with TTFT) instead of the "
                         "batch Engine.generate wrapper")
    ap.add_argument("--scheduler", default="fifo",
                    choices=("fifo", "edf"),
                    help="admission policy: fifo (arrival order) or edf "
                         "(earliest-deadline-first, serve/slo.py)")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="default per-request completion budget in ms "
                         "(engine clock); advisory under fifo (misses "
                         "are counted), enforced under edf")
    ap.add_argument("--overdue", default="drop",
                    choices=("drop", "demote", "ignore"),
                    help="edf policy for a queued request whose deadline "
                         "passed: drop (finish_reason='deadline'), demote "
                         "(run behind feasible work), or ignore")
    ap.add_argument("--trace-phases", action="store_true",
                    help="per-step phase tracing (schedule/host_prep/"
                         "dispatch/device/sample) with device fencing; "
                         "p50/p95/p99 land in Engine.telemetry['phases']. "
                         "Off by default: fencing serializes dispatch")
    ap.add_argument("--phase-mode", default="fenced",
                    choices=("fenced", "overlap"),
                    help="tracer mode under --trace-phases: fenced isolates "
                         "device time by blocking each dispatch; overlap "
                         "never fences and reports device_overlap_s / "
                         "host_bubble_s / overlap_efficiency instead (use "
                         "with --async-loop)")
    ap.add_argument("--async-loop", action="store_true",
                    help="pipelined engine loop: dispatch step N+1 while "
                         "step N's decode scan runs on device; greedy "
                         "token streams stay bit-identical to the "
                         "synchronous loop (results surface one step late)")
    ap.add_argument("--shard-decode", action="store_true",
                    help="place params and KV pools with NamedSharding "
                         "over the host (data, model) mesh; the same "
                         "len(buckets)+2 programs compile against sharded "
                         "operands (single-device meshes are a no-op)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="data-parallel engines behind one ReplicaRouter "
                         "front door with least-loaded admission (each "
                         "replica owns its KV pool and jit caches)")
    return ap


def config_from_args(args: argparse.Namespace, model_cfg) -> ServeConfig:
    """Build the ServeConfig from parsed serving args (``model_cfg``
    resolves ``--policy auto`` to the arch's recommended preset)."""
    return ServeConfig(
        max_batch=args.max_batch,
        max_seq_len=args.max_seq,
        temperature=args.temperature,
        policy=resolve_policy_arg(args.policy, args.quantized, model_cfg),
        prefill_buckets=(
            None if args.prefill_buckets is None
            else tuple(args.prefill_buckets)
        ),
        prefill_chunk=args.prefill_chunk,
        decode_steps=args.decode_steps,
        max_prefill_per_step=args.max_prefill_per_step,
        kv_layout=args.kv_layout,
        kv_page_size=args.kv_page_size,
        kv_pages=args.kv_pages,
        kv_prefix_cache=args.kv_prefix_cache,
        kv_preemption=args.kv_preemption,
        kv_host_pages=getattr(args, "kv_host_pages", 0),
        kv_victim_tier=not getattr(args, "no_kv_victim_tier", False),
        cache_extend=not getattr(args, "no_cache_extend", False),
        speculative=getattr(args, "speculative", False),
        spec_tokens=getattr(args, "spec_tokens", 4),
        draft_config=getattr(args, "draft", None),
        scheduler=getattr(args, "scheduler", "fifo"),
        deadline_ms=getattr(args, "deadline_ms", None),
        overdue_policy=getattr(args, "overdue", "drop"),
        trace_phases=getattr(args, "trace_phases", False),
        phase_mode=getattr(args, "phase_mode", "fenced"),
        async_loop=getattr(args, "async_loop", False),
        shard_decode=getattr(args, "shard_decode", False),
        replicas=getattr(args, "replicas", 1),
    )

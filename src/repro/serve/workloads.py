"""Deterministic serving workloads: seeded arrival processes, trace
files, and a replay driver with SLO accounting.

Benchmarks used to submit every request up front and drain the engine —
a closed loop that hides queueing behavior entirely.  This module
replaces that with *timed* workloads:

* :func:`poisson` — seeded Poisson arrivals (exponential inter-arrival
  gaps) with seeded prompt payloads, an optional shared system-prompt
  preamble (the physics pattern: one detector-geometry prefix ahead of
  per-event payloads), and optional per-request deadlines (fixed or
  uniformly mixed — mixed urgency is what separates an EDF scheduler
  from FIFO).
* :func:`synchronous` — every request at t=0 (the legacy closed loop,
  expressed as a workload so every benchmark path goes through one
  driver).
* :func:`multi_tenant` — Poisson arrivals cycling over N distinct
  seeded tenant preambles, the warm-prefix stream whose working set is
  sized to overflow a small device page pool (the tiered-KV-cache
  exercise: tenant prefixes spill to the host victim tier between
  visits and swap back on re-arrival).
* :func:`save_trace` / :func:`load_trace` — JSONL trace files, so a
  recorded or hand-written arrival trace replays exactly
  (``{"at": .., "prompt": [..], "max_new_tokens": .., "deadline_s": ..}``
  per line).
* :class:`StepClock` — a virtual engine clock.  Arrival times and
  deadlines are *simulation* time; tests advance it a fixed amount per
  engine step, making queueing/deadline dynamics bit-reproducible
  across machines (wall-clock SLO tests are flake factories).
* :func:`replay` — the open-loop driver: submits each event when the
  engine clock passes its arrival time, pumps the engine, and returns a
  :class:`ReplayReport` with completion/deadline-miss accounting.

No jax imports here either: workloads are host-side policy inputs.
"""

from __future__ import annotations

import dataclasses
import json
import math
import time

import numpy as np


# ------------------------------------------------------------- events --
@dataclasses.dataclass(frozen=True)
class ArrivalEvent:
    """One request arrival: ``at`` seconds (engine-clock) after the
    replay starts, ``deadline_s`` relative to arrival (None = no SLO)."""

    at: float
    prompt: tuple[int, ...]
    max_new_tokens: int = 16
    deadline_s: float | None = None
    eos_id: int | None = None


def _prompt(
    rng: np.random.Generator,
    vocab_size: int,
    prompt_len: tuple[int, int],
    preamble: tuple[int, ...],
) -> tuple[int, ...]:
    lo, hi = prompt_len
    n = int(rng.integers(lo, hi + 1))
    return preamble + tuple(
        int(t) for t in rng.integers(0, vocab_size, n)
    )


def poisson(
    *,
    rate: float,
    n: int,
    vocab_size: int,
    seed: int = 0,
    prompt_len: tuple[int, int] = (4, 12),
    shared_prefix: int = 0,
    max_new_tokens: int = 16,
    deadline_s: float | tuple[float, float] | None = None,
    eos_id: int | None = None,
) -> list[ArrivalEvent]:
    """``n`` arrivals with exponential inter-arrival gaps at ``rate``
    requests per (engine-clock) second, fully determined by ``seed``.

    ``deadline_s``: None = no deadlines; a float = every request gets
    that budget from its arrival; a (lo, hi) tuple = per-request uniform
    draw — the mixed-urgency stream where deadline-aware ordering pays.
    ``shared_prefix`` > 0 prepends one seeded preamble of that many
    tokens to every prompt (prefix-cache fodder).
    """
    if rate <= 0:
        raise ValueError(f"arrival rate must be > 0, got {rate}")
    rng = np.random.default_rng(seed)
    preamble = tuple(
        int(t) for t in rng.integers(0, vocab_size, shared_prefix)
    )
    events, t = [], 0.0
    for _ in range(n):
        t += float(rng.exponential(1.0 / rate))
        if deadline_s is None:
            dl = None
        elif isinstance(deadline_s, tuple):
            dl = float(rng.uniform(*deadline_s))
        else:
            dl = float(deadline_s)
        events.append(
            ArrivalEvent(
                at=t,
                prompt=_prompt(rng, vocab_size, prompt_len, preamble),
                max_new_tokens=max_new_tokens,
                deadline_s=dl,
                eos_id=eos_id,
            )
        )
    return events


def synchronous(
    *,
    n: int,
    vocab_size: int,
    seed: int = 0,
    prompt_len: tuple[int, int] = (4, 12),
    shared_prefix: int = 0,
    max_new_tokens: int = 16,
    deadline_s: float | tuple[float, float] | None = None,
    eos_id: int | None = None,
) -> list[ArrivalEvent]:
    """The legacy closed loop as a workload: all ``n`` requests arrive at
    t=0 (same seeded prompt distribution as :func:`poisson`)."""
    events = poisson(
        rate=1.0, n=n, vocab_size=vocab_size, seed=seed,
        prompt_len=prompt_len, shared_prefix=shared_prefix,
        max_new_tokens=max_new_tokens, deadline_s=deadline_s,
        eos_id=eos_id,
    )
    return [dataclasses.replace(ev, at=0.0) for ev in events]


def multi_tenant(
    *,
    rate: float,
    n: int,
    vocab_size: int,
    tenants: int = 4,
    preamble_len: int = 24,
    seed: int = 0,
    prompt_len: tuple[int, int] = (4, 12),
    max_new_tokens: int = 16,
    deadline_s: float | tuple[float, float] | None = None,
    eos_id: int | None = None,
) -> list[ArrivalEvent]:
    """Warm-prefix multi-tenant stream: ``tenants`` distinct seeded
    preambles of ``preamble_len`` tokens each, with ``n`` Poisson
    arrivals cycling round-robin over the tenants (request *i* belongs
    to tenant ``i % tenants``), so every tenant's prefix keeps coming
    back warm.

    This is the victim-tier exercise: the warm working set is
    ``tenants * ceil(preamble_len / page_size)`` prefix pages, and a
    device pool sized *below* that forces the LRU to spill tenant
    prefixes between visits — with ``kv_host_pages`` > 0 they swap back
    from the host tier (prefill-skip on re-arrival); without a tier
    each re-arrival recomputes its preamble.  Fully determined by
    ``seed``; ``deadline_s`` follows :func:`poisson` semantics.
    """
    if rate <= 0:
        raise ValueError(f"arrival rate must be > 0, got {rate}")
    if tenants < 1:
        raise ValueError(f"need at least one tenant, got {tenants}")
    rng = np.random.default_rng(seed)
    preambles = [
        tuple(int(t) for t in rng.integers(0, vocab_size, preamble_len))
        for _ in range(tenants)
    ]
    events, t = [], 0.0
    for i in range(n):
        t += float(rng.exponential(1.0 / rate))
        if deadline_s is None:
            dl = None
        elif isinstance(deadline_s, tuple):
            dl = float(rng.uniform(*deadline_s))
        else:
            dl = float(deadline_s)
        events.append(
            ArrivalEvent(
                at=t,
                prompt=_prompt(
                    rng, vocab_size, prompt_len, preambles[i % tenants]
                ),
                max_new_tokens=max_new_tokens,
                deadline_s=dl,
                eos_id=eos_id,
            )
        )
    return events


# -------------------------------------------------------------- traces --
def save_trace(events: list[ArrivalEvent], path: str) -> None:
    """Write a workload as a JSONL trace (one event per line, sorted by
    arrival) — the interchange format for recorded or synthetic traces."""
    with open(path, "w") as f:
        for ev in sorted(events, key=lambda e: e.at):
            f.write(json.dumps({
                "at": ev.at,
                "prompt": list(ev.prompt),
                "max_new_tokens": ev.max_new_tokens,
                "deadline_s": ev.deadline_s,
                "eos_id": ev.eos_id,
            }) + "\n")


def load_trace(path: str) -> list[ArrivalEvent]:
    """Load a JSONL trace written by :func:`save_trace` (or by hand —
    only ``at`` and ``prompt`` are required per line)."""
    events = []
    with open(path) as f:
        for line_no, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            rec = json.loads(line)
            try:
                events.append(ArrivalEvent(
                    at=float(rec["at"]),
                    prompt=tuple(int(t) for t in rec["prompt"]),
                    max_new_tokens=int(rec.get("max_new_tokens", 16)),
                    deadline_s=(
                        None if rec.get("deadline_s") is None
                        else float(rec["deadline_s"])
                    ),
                    eos_id=(
                        None if rec.get("eos_id") is None
                        else int(rec["eos_id"])
                    ),
                ))
            except (KeyError, TypeError, ValueError) as e:
                raise ValueError(
                    f"{path}:{line_no}: bad trace record {rec!r}"
                ) from e
    return sorted(events, key=lambda e: e.at)


# --------------------------------------------------------------- clock --
class StepClock:
    """A virtual engine clock: ``clock()`` reads it, :meth:`advance`
    moves it.  Pass one to ``Engine(clock=...)`` and :func:`replay` to
    make arrivals, queue waits, and deadlines deterministic simulation
    time instead of wall time."""

    __slots__ = ("t",)

    def __init__(self, t0: float = 0.0):
        self.t = float(t0)

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        if dt < 0:
            raise ValueError(f"clock cannot run backwards (dt={dt})")
        self.t += dt


# -------------------------------------------------------------- replay --
@dataclasses.dataclass
class ReplayReport:
    """What a replayed workload did, per request and in aggregate.
    ``deadline_missed`` counts drops *and* late completions among the
    ``deadline_total`` requests that carried a deadline."""

    requests: int = 0
    completed: int = 0
    dropped: int = 0
    deadline_total: int = 0
    deadline_missed: int = 0
    tokens: int = 0
    #: engine-clock span of the replay (== wall seconds for a real clock)
    clock_span_s: float = 0.0
    #: real host seconds the replay loop took
    host_wall_s: float = 0.0
    per_request: list[dict] = dataclasses.field(default_factory=list)

    @property
    def miss_rate(self) -> float:
        return self.deadline_missed / max(self.deadline_total, 1)

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d.pop("per_request")
        d["miss_rate"] = self.miss_rate
        return d


def replay(
    engine,
    events: list[ArrivalEvent],
    *,
    step_cost: float | None = None,
    max_steps: int = 100_000,
) -> ReplayReport:
    """Open-loop replay: submit each event once the engine clock reaches
    its arrival time, pump :meth:`Engine.step` while there is work, and
    account completions against deadlines.

    The clock is the engine's own (``engine.clock``).  With a
    :class:`StepClock`, ``step_cost`` sets how much simulation time one
    engine step costs (None = advance by the step's measured wall time,
    keeping virtual arrivals paced by real compute), and idle gaps jump
    instantly.  With the default wall clock, arrivals pace in real time
    (idle waits sleep in 1 ms slices) and ``step_cost`` must be None.
    """
    clock = engine.clock
    virtual = hasattr(clock, "advance")
    if step_cost is not None and not virtual:
        raise ValueError(
            "step_cost only applies to a virtual engine clock (StepClock)"
        )
    pending = sorted(events, key=lambda e: e.at)
    t_start = clock()
    host0 = time.perf_counter()
    handles = []
    i = 0
    steps = 0
    while i < len(pending) or engine.has_work:
        now = clock() - t_start
        while i < len(pending) and pending[i].at <= now:
            ev = pending[i]
            i += 1
            handles.append((
                engine.submit(
                    list(ev.prompt),
                    max_new_tokens=ev.max_new_tokens,
                    eos_id=ev.eos_id,
                    deadline_s=ev.deadline_s,
                ),
                ev,
            ))
        if engine.has_work:
            if steps >= max_steps:
                raise RuntimeError(
                    f"replay exceeded max_steps={max_steps} "
                    "(engine not making progress?)"
                )
            t0 = time.perf_counter()
            engine.step()
            steps += 1
            if virtual:
                clock.advance(
                    time.perf_counter() - t0 if step_cost is None
                    else step_cost
                )
        elif i < len(pending):
            gap = pending[i].at - (clock() - t_start)
            if gap > 0:
                if virtual:
                    # ``gap`` comes from subtracting two clock readings
                    # much larger than itself (a reused clock far from
                    # zero); the residual can round below one ulp of the
                    # clock value, making advance() a no-op forever —
                    # nudge by an ulp so the arrival check must cross
                    before = clock()
                    clock.advance(gap)
                    if clock() == before:
                        clock.advance(math.ulp(before))
                else:
                    time.sleep(min(gap, 1e-3))
    report = ReplayReport(
        requests=len(handles),
        clock_span_s=clock() - t_start,
        host_wall_s=time.perf_counter() - host0,
    )
    for handle, ev in handles:
        req = engine.result(handle)
        reason = engine.finish_reason(handle)
        dropped = reason == "deadline"
        report.completed += not dropped
        report.dropped += dropped
        report.tokens += len(req.generated)
        missed = None
        if req.deadline_at is not None:
            report.deadline_total += 1
            missed = dropped or req.finished_at > req.deadline_at
            report.deadline_missed += missed
        report.per_request.append({
            "uid": req.uid,
            "arrived_at": ev.at,
            "deadline_s": ev.deadline_s,
            "finish_reason": reason,
            "tokens": len(req.generated),
            "finished_at": req.finished_at - t_start,
            "preemptions": req.preemptions,
            "missed": missed,
        })
    return report

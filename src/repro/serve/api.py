"""Client-facing serving API: submit / stream / cancel / generate.

The top layer of the Scheduler / Executor / Engine split (see
``serve/scheduler.py`` for the layering contract).  :class:`Engine`
wires a scheduling policy to a :class:`~repro.serve.executor.ModelExecutor`
and exposes the request lifecycle the batch-only ``run()`` API could
not express:

* :meth:`Engine.submit` — enqueue a prompt, get a :class:`RequestHandle`.
* :meth:`Engine.stream` — iterate :class:`TokenEvent`s as they are
  produced (time-to-first-token and inter-token latency are the event
  timestamp deltas).  Pumping any one stream advances the whole engine;
  events for other requests buffer on their own handles, so interleaved
  streams each see their full ordered token sequence.
* :meth:`Engine.cancel` — drop a queued request, or evict a resident one
  and free its KV pages immediately.
* :meth:`Engine.generate` — the batch convenience wrapper (submit
  everything, run to completion, return finished requests) that
  ``ServingEngine.run()`` callers migrate to.

The engine loop is synchronous and single-threaded by default: each
:meth:`Engine.step` asks the scheduler for an explicit
:class:`~repro.serve.scheduler.ScheduleDecision` and has the executor
apply it.  With ``ServeConfig.async_loop`` the loop is *pipelined*
(double-buffered): step N's decode scan is dispatched and left in
flight on device while the host schedules and preps step N+1; N's
results are collected — and its TokenEvents routed — one step late,
stamped with the engine clock at N's *dispatch* so virtual-clock
replay (:class:`~repro.serve.workloads.StepClock`) produces the exact
same event timeline as the synchronous loop.  Greedy token streams are
bit-identical between the two loops; the visible semantic differences
sit at the one-step-stale boundary (cancel may discard one in-flight
step's tokens, preemption defers one step — see README).  All
telemetry is merged from the two layers plus the cache manager under
:attr:`Engine.telemetry` (same key set as the historical monolith).
"""

from __future__ import annotations

import collections
import dataclasses
import time
import warnings
from collections.abc import Iterator
from typing import Any, Callable

import inspect

from repro.configs.base import ModelConfig, ServeConfig
from repro.serve.executor import InflightStep, ModelExecutor
from repro.serve.phases import make_tracer
from repro.serve.sampling import SamplingParams
from repro.serve.scheduler import FifoScheduler, Request, Scheduler
from repro.serve.slo import DeadlineScheduler

PyTree = Any

#: finish reasons stamped on the terminal TokenEvent / request
FINISH_EOS = "eos"
FINISH_LENGTH = "length"
FINISH_CANCELLED = "cancelled"
#: dropped past-deadline by the SLO scheduler (serve/slo.py); the
#: terminal event carries no token (token == NO_TOKEN)
FINISH_DEADLINE = "deadline"

#: sentinel ``TokenEvent.token`` for a tokenless terminal event (a
#: deadline drop is an answer — "this request will not be served" — not
#: a generated token)
NO_TOKEN = -1

#: ServeConfig.scheduler name -> default policy class
SCHEDULERS = {"fifo": FifoScheduler, "edf": DeadlineScheduler}


def _accepts_clock(factory: Callable) -> bool:
    """Whether a scheduler factory takes a ``clock`` keyword (built-ins
    do; pre-existing custom factories keep the 3-argument contract)."""
    try:
        params = inspect.signature(factory).parameters
    except (TypeError, ValueError):
        return False
    return "clock" in params or any(
        p.kind is inspect.Parameter.VAR_KEYWORD for p in params.values()
    )


@dataclasses.dataclass(frozen=True)
class RequestHandle:
    """Opaque ticket for a submitted request."""

    uid: int


@dataclasses.dataclass(frozen=True)
class TokenEvent:
    """One generated token, stamped when its decode/prefill dispatch
    result reached the host.  ``index`` is the token's position in the
    request's generated sequence; ``finished`` marks the request's final
    token (``finish_reason`` in {"eos", "length"}).  A cancelled request
    simply stops producing events — cancellation is not a token."""

    uid: int
    token: int
    index: int
    ts: float
    finished: bool = False
    finish_reason: str | None = None


class Engine:
    """Streaming serving engine: a scheduling policy (default
    :class:`~repro.serve.scheduler.FifoScheduler`) driving a
    :class:`~repro.serve.executor.ModelExecutor`.

    ``scheduler_factory`` swaps the policy: it is called with
    ``(serve_cfg, executor.caps, executor.cache_mgr)`` — plus
    ``clock=`` when its signature accepts one — and must return a
    :class:`~repro.serve.scheduler.Scheduler`.  Without a factory,
    ``ServeConfig.scheduler`` picks the policy ("fifo" or "edf").

    ``clock`` is the engine's time source for every wait / deadline /
    TokenEvent stamp (default ``time.perf_counter``).  Pass a
    :class:`~repro.serve.workloads.StepClock` to run queueing and SLO
    dynamics in deterministic simulation time; phase tracing
    (``ServeConfig.trace_phases``) always measures real host/device
    seconds regardless.
    """

    def __init__(
        self,
        cfg: ModelConfig,
        params: PyTree,
        serve_cfg: ServeConfig | None = None,
        kernel: dict | None = None,
        seed: int = 0,
        scheduler_factory: Callable[..., Scheduler] | None = None,
        clock: Callable[[], float] | None = None,
        replica: int = 0,
        draft: tuple[ModelConfig, PyTree] | None = None,
    ):
        sc_in = serve_cfg or ServeConfig()
        if (
            draft is None
            and sc_in.speculative
            and sc_in.draft_config not in (None, "self")
        ):
            # resolve the named draft from the config zoo (reduced =
            # CPU-sized smoke shapes); the executor rejects a draft
            # whose vocabulary differs from the target's
            import jax

            from repro.configs import get_config
            from repro.models import lm

            dcfg = get_config(sc_in.draft_config, reduced=True)
            draft = (dcfg, lm.init_params(dcfg, jax.random.PRNGKey(seed)))
        self.executor = ModelExecutor(
            cfg, params, serve_cfg, kernel=kernel, seed=seed,
            replica=replica, draft=draft,
        )
        self.serve_cfg = self.executor.serve_cfg
        self.clock = clock if clock is not None else time.perf_counter
        self._tracer = make_tracer(
            self.serve_cfg.trace_phases, self.serve_cfg.phase_ring,
            mode=self.serve_cfg.phase_mode,
        )
        if (
            self.serve_cfg.trace_phases
            and self.serve_cfg.async_loop
            and self.serve_cfg.phase_mode == "fenced"
        ):
            # the default warnings filter surfaces this once per call
            # site — enough to flag a measurement that contradicts itself
            warnings.warn(
                "trace_phases with phase_mode='fenced' fences every "
                "dispatch, serializing the async_loop pipeline it is "
                "measuring; use phase_mode='overlap' for non-destructive "
                "overlap accounting",
                UserWarning,
                stacklevel=2,
            )
        self.executor.tracer = self._tracer
        #: the dispatched-but-uncollected step (async loop double buffer)
        self._inflight: InflightStep | None = None
        if scheduler_factory is None:
            try:
                factory = SCHEDULERS[self.serve_cfg.scheduler]
            except KeyError:
                raise ValueError(
                    f"unknown ServeConfig.scheduler "
                    f"{self.serve_cfg.scheduler!r}; "
                    f"expected one of {sorted(SCHEDULERS)}"
                ) from None
        else:
            factory = scheduler_factory
        args = (self.serve_cfg, self.executor.caps, self.executor.cache_mgr)
        if _accepts_clock(factory):
            self.scheduler: Scheduler = factory(*args, clock=self.clock)
        else:  # older custom factories keep the 3-arg contract
            self.scheduler = factory(*args)
        self._uid = 0
        self._requests: dict[int, Request] = {}
        self._finished: dict[int, Request] = {}
        self._finish_reason: dict[int, str] = {}
        self._events: dict[int, collections.deque[TokenEvent]] = {}
        self._run_tel: dict[str, float] = {}
        #: SLO accounting over requests that carried a deadline —
        #: engine-level so FIFO engines report misses too (the
        #: EDF-vs-FIFO comparison needs both sides measured)
        self._slo = {
            "deadline_requests": 0,
            "deadline_missed": 0,
            "deadline_dropped": 0,
        }

    # --------------------------------------------------------- lifecycle --
    def submit(
        self,
        prompt: list[int],
        params: SamplingParams | None = None,
        *,
        max_new_tokens: int | None = None,
        eos_id: int | None = None,
        deadline_s: float | None = None,
        n: int = 1,
    ) -> RequestHandle | list[RequestHandle]:
        """Enqueue a prompt.  Per-request knobs ride a
        :class:`~repro.serve.sampling.SamplingParams` (or the keyword
        shortcuts); returns a handle for :meth:`stream` / :meth:`cancel`
        / :meth:`result`.

        ``n > 1`` fans the prompt into n independent candidates (n-best
        sampling) and returns a list of n handles.  On paged engines the
        siblings fork off the first candidate's live KV pages
        copy-on-write — prompt pages AND already-generated-into pages
        are shared until a sibling diverges — so the prompt prefills
        once, not n times.  Each sibling draws its own sampled stream: a
        seeded request's siblings get consecutive seeds (seed + i),
        unseeded siblings diverge through the engine dispatch key.

        ``deadline_s`` is the request's completion budget in seconds
        from now (engine clock); None inherits
        ``ServeConfig.deadline_ms`` when set.  Deadlines are advisory
        under FIFO (misses are counted in telemetry) and enforced by the
        EDF policy (``ServeConfig.scheduler="edf"``)."""
        if params is None:
            params = SamplingParams(
                max_new_tokens=16 if max_new_tokens is None else max_new_tokens,
                eos_id=eos_id,
            )
        elif max_new_tokens is not None or eos_id is not None:
            raise ValueError(
                "pass either SamplingParams or the keyword shortcuts, not both"
            )
        if n < 1:
            raise ValueError(f"n must be >= 1, got {n}")
        if params.temperature is not None and params.temperature < 0:
            raise ValueError(
                f"temperature must be >= 0, got {params.temperature}"
            )
        if params.top_k is not None and params.top_k < 0:
            raise ValueError(f"top_k must be >= 0, got {params.top_k}")
        if params.top_p is not None and not 0.0 < params.top_p <= 1.0:
            raise ValueError(
                f"top_p must be in (0, 1], got {params.top_p}"
            )
        if params.seed is not None and params.seed < 0:
            raise ValueError(f"seed must be >= 0, got {params.seed}")
        if not prompt:
            raise ValueError("empty prompt")
        if len(prompt) >= self.serve_cfg.max_seq_len:
            raise ValueError(
                f"prompt length {len(prompt)} >= max_seq_len "
                f"{self.serve_cfg.max_seq_len}"
            )
        if deadline_s is None and self.serve_cfg.deadline_ms is not None:
            deadline_s = self.serve_cfg.deadline_ms / 1e3
        if deadline_s is not None and deadline_s <= 0:
            raise ValueError(f"deadline_s must be > 0, got {deadline_s}")
        cache = self.executor.cache_mgr
        need = cache.pages_for(
            min(len(prompt) + params.max_new_tokens, self.serve_cfg.max_seq_len)
        )
        if need > cache.pages_capacity:
            raise ValueError(
                f"request needs {need} KV pages (prompt {len(prompt)} + "
                f"up to {params.max_new_tokens} new tokens) but the pool only "
                f"holds {cache.pages_capacity}; raise "
                "ServeConfig.kv_pages or lower max_new_tokens"
            )
        handles = []
        fork_of = None
        for i in range(n):
            now = self.clock()
            req = Request(
                self._uid + 1, list(prompt),
                params.max_new_tokens, params.eos_id,
                created_at=now, submitted_at=now,
                deadline_at=None if deadline_s is None else now + deadline_s,
            )
            req.temperature = params.temperature
            req.top_k = params.top_k
            req.top_p = params.top_p
            req.seed = (
                None if params.seed is None else params.seed + i
            )
            req.fork_of = fork_of
            self._uid += 1
            self._requests[req.uid] = req
            self._events[req.uid] = collections.deque()
            self.scheduler.enqueue(req)
            handles.append(RequestHandle(req.uid))
            if fork_of is None:
                fork_of = req.uid
        return handles if n > 1 else handles[0]

    def cancel(self, handle: RequestHandle | int) -> bool:
        """Cancel a request: a queued one is dropped before it ever
        prefills; a resident one is evicted and its KV pages return to
        the pool immediately.  Returns False when the request already
        finished (nothing to cancel)."""
        uid = handle.uid if isinstance(handle, RequestHandle) else handle
        if uid in self._finished or uid not in self._requests:
            return False
        req = self.scheduler.remove(uid)
        if req is None:
            for idx, slot in enumerate(self.executor.slots):
                if slot.active and slot.request.uid == uid:
                    req = slot.request
                    self.executor.release(idx)
                    break
        if req is None:  # not queued, not resident: raced a finish
            return False
        req.cancelled = True
        self._finished[uid] = req
        self._finish_reason[uid] = FINISH_CANCELLED
        return True

    def result(self, handle: RequestHandle | int) -> Request | None:
        """The finished request, or None while it is still queued/running."""
        uid = handle.uid if isinstance(handle, RequestHandle) else handle
        return self._finished.get(uid)

    def request(self, handle: RequestHandle | int) -> Request:
        """The live request record (queued, resident, or finished) —
        e.g. for submit timestamps while a stream is still open."""
        uid = handle.uid if isinstance(handle, RequestHandle) else handle
        return self._requests[uid]

    def finish_reason(self, handle: RequestHandle | int) -> str | None:
        uid = handle.uid if isinstance(handle, RequestHandle) else handle
        return self._finish_reason.get(uid)

    @property
    def has_work(self) -> bool:
        return (
            bool(self.scheduler.queue)
            or any(s.active for s in self.executor.slots)
            # an uncollected dispatch still owes tokens/finishes (async
            # loop drain: one extra step collects it after the queue and
            # slots empty out)
            or (self._inflight is not None and not self._inflight.empty)
        )

    # -------------------------------------------------------------- loop --
    def _route_output(self, out, ts: float) -> None:
        """Route one collected step's emissions into per-request event
        queues and finish bookkeeping, stamping everything with ``ts`` —
        the engine clock at the step's *dispatch* (== collect time for
        the synchronous loop, one step earlier under the async loop, so
        both loops produce identical virtual-clock event timelines)."""
        finished_uids = {req.uid for req in out.finished}
        reasons = {
            req.uid: (
                FINISH_EOS
                if req.eos_id is not None
                and req.generated
                and req.generated[-1] == req.eos_id
                else FINISH_LENGTH
            )
            for req in out.finished
        }
        last_index = {
            req.uid: len(req.generated) - 1 for req in out.finished
        }
        for uid, token, index in out.tokens:
            final = uid in finished_uids and index == last_index[uid]
            self._events.setdefault(uid, collections.deque()).append(TokenEvent(
                uid=uid, token=token, index=index, ts=ts,
                finished=final,
                finish_reason=reasons[uid] if final else None,
            ))
        for req in out.finished:
            req.finished_at = ts
            self._finished[req.uid] = req
            self._finish_reason[req.uid] = reasons[req.uid]
        self._account_slo(out.finished)

    def _route_dropped(self, dropped, ts: float) -> None:
        """Finish past-deadline drops: the scheduler removed them from
        its queue; they finish here with a tokenless terminal event so
        every consumer (stream / generate / result) sees an answered
        request.  Drops are a host-side decision — under the async loop
        they route at schedule time, never one step late."""
        for req in dropped:
            req.finished_at = ts
            self._finished[req.uid] = req
            self._finish_reason[req.uid] = FINISH_DEADLINE
            self._events.setdefault(req.uid, collections.deque()).append(
                TokenEvent(
                    uid=req.uid, token=NO_TOKEN, index=len(req.generated),
                    ts=ts, finished=True, finish_reason=FINISH_DEADLINE,
                )
            )
        self._account_slo(dropped)

    def _account_slo(self, reqs) -> None:
        for req in reqs:
            if req.deadline_at is None:
                continue
            self._slo["deadline_requests"] += 1
            dropped = self._finish_reason.get(req.uid) == FINISH_DEADLINE
            self._slo["deadline_dropped"] += dropped
            self._slo["deadline_missed"] += (
                dropped or req.finished_at > req.deadline_at
            )

    def step(self) -> dict:
        """One engine iteration: ``scheduler.schedule`` then
        ``executor.execute``; route the step's emissions into per-request
        event queues, finish any past-deadline drops the policy reported,
        and stamp SLO accounting.  Under ``ServeConfig.async_loop`` the
        execute splits across steps: this step dispatches its decision
        and collects the *previous* step's (see :meth:`_step_async`)."""
        if self.executor.async_loop:
            return self._step_async()
        tr = self._tracer
        tr.begin_step()
        with tr.phase("schedule"):
            decision = self.scheduler.schedule(self.executor.slots)
        out = self.executor.execute(decision)
        now = self.clock()
        self._route_output(out, now)
        self._route_dropped(decision.dropped, now)
        stats = out.stats
        stats.update(
            prefill_compiles=self.executor.tel["prefill_compiles"],
            decode_compiles=self.executor.tel["decode_compiles"],
        )
        tr.end_step()
        return stats

    def _step_async(self) -> dict:
        """One pipelined iteration: schedule and *dispatch* step N, then
        *collect* step N-1 — so N-1's decode scan runs on device under
        N's schedule/host_prep.  The stats returned (and the tokens
        routed) are N-1's: every step's results surface exactly one
        step after its dispatch, stamped with its dispatch-time clock.
        The scheduler sees host slot state that is one step stale for
        in-flight slots; staleness is safe by construction — collect
        re-checks every slot against its dispatch-time snapshot and
        ``admit_seq`` stamp, so tokens of a slot that was preempted,
        cancelled, or turned over while its dispatch was in flight are
        discarded (a preempted request regenerates them after resume),
        and EDF drops touch only queued requests."""
        tr = self._tracer
        tr.begin_step()
        with tr.phase("schedule"):
            decision = self.scheduler.schedule(self.executor.slots)
        inflight = self.executor.dispatch(decision)
        inflight.dispatched_at = self.clock()
        self._route_dropped(decision.dropped, inflight.dispatched_at)
        prev, self._inflight = self._inflight, inflight
        stats = {"prefilled": 0, "decoded": 0}
        if prev is not None:
            out = self.executor.collect(prev)
            ts = (
                prev.dispatched_at
                if prev.dispatched_at is not None
                else self.clock()
            )
            self._route_output(out, ts)
            stats = out.stats
        stats.update(
            prefill_compiles=self.executor.tel["prefill_compiles"],
            decode_compiles=self.executor.tel["decode_compiles"],
        )
        tr.end_step()
        return stats

    def stream(self, handle: RequestHandle | int) -> Iterator[TokenEvent]:
        """Yield the request's :class:`TokenEvent`s in order, pumping the
        engine as needed.  Other requests progress on the same pumps;
        their events buffer for their own streams.  The iterator ends
        after the request's final event (or silently on cancellation)."""
        uid = handle.uid if isinstance(handle, RequestHandle) else handle
        if uid not in self._requests:
            raise KeyError(f"unknown request {uid}")
        queue = self._events.get(uid, collections.deque())
        while True:
            while queue:
                yield queue.popleft()
            if uid in self._finished or not self.has_work:
                # a finished request emits no further events: release the
                # (drained) buffer so a long-lived engine stays bounded
                self._events.pop(uid, None)
                return
            self.step()

    def generate(
        self,
        prompts: list[list[int]] | None = None,
        params: SamplingParams | None = None,
        *,
        max_new_tokens: int = 16,
        eos_id: int | None = None,
        max_steps: int = 10_000,
    ) -> dict[int, Request]:
        """Batch convenience wrapper (the ``ServingEngine.run`` migration
        target): optionally submit ``prompts`` (all with the same
        sampling params), run the engine until idle, and return every
        finished request keyed by uid — including requests submitted
        earlier through :meth:`submit`.

        Buffered :class:`TokenEvent`s of requests that finished are
        discarded on return (generated tokens live on the Request):
        streams opened before this call drain normally, but the batch
        path never accumulates per-token event state across waves."""
        if prompts is not None:
            sp = params or SamplingParams(
                max_new_tokens=max_new_tokens, eos_id=eos_id
            )
            for prompt in prompts:
                self.submit(prompt, sp)
        t0 = time.perf_counter()
        tokens0 = self.executor.tel["tokens_generated"]
        steps = 0
        while self.has_work and steps < max_steps:
            self.step()
            steps += 1
        dt = time.perf_counter() - t0
        self._run_tel["run_wall_s"] = dt
        self._run_tel["tokens_per_s"] = (
            self.executor.tel["tokens_generated"] - tokens0
        ) / max(dt, 1e-9)
        admitted = max(self.scheduler.stats["prompts_admitted"], 1)
        self._run_tel["queue_wait_s_mean"] = (
            self.scheduler.stats["queue_wait_s_total"] / admitted
        )
        self._run_tel["queue_wait_created_s_mean"] = (
            self.scheduler.stats["queue_wait_created_s_total"] / admitted
        )
        # finished requests emit no further events; dropping their
        # buffers keeps a wave-after-wave batch engine O(resident), not
        # O(tokens ever generated).  Open streams hold their own deque
        # reference and still drain what was buffered before this call.
        for uid in [u for u in self._events if u in self._finished]:
            del self._events[uid]
        return dict(self._finished)

    # --------------------------------------------------------- telemetry --
    @property
    def telemetry(self) -> dict:
        """Merged view over the scheduler, executor, cache-manager, and
        run-level counters (the historical monolith's key set)."""
        tel = dict(self.executor.tel)
        tel.update(self.scheduler.stats)
        tel.update(self.executor.cache_mgr.stats().as_dict())
        tel.update(self._run_tel)
        tel.update(self._slo)
        #: per-phase latency summary ({} unless ServeConfig.trace_phases)
        tel["phases"] = self._tracer.summary()
        return tel

    def kv_stats(self) -> dict:
        return self.executor.kv_stats()

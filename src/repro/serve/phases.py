"""Per-engine-step phase tracing: where does a step's time actually go?

The paper's discipline is *accountable* latency — a 64-cycle MLP is only
meaningful inside its ~140k-cycle shell if you can say where the other
cycles went.  The serving counterpart: each engine step decomposes into

    schedule   policy: FifoScheduler/DeadlineScheduler.schedule()
    host_prep  numpy batch assembly + page-table bookkeeping
               (ensure / flush_copies / write_table) before a dispatch
    dispatch   handing the jitted program to the runtime (returns as
               soon as the computation is enqueued; first-call
               compilation also lands here)
    device     waiting for the dispatched arrays (block_until_ready)
    sample     host-side post-processing: device->host transfers,
               token sampling/routing, slot bookkeeping

:class:`PhaseTracer` accumulates per-phase seconds for the current step,
pushes the finished record into a bounded ring buffer, and summarizes
p50/p95/p99 on demand.  Isolating ``device`` requires *fencing* every
dispatch (``jax.block_until_ready``), which serializes host and device
work — so tracing is **off by default** (``ServeConfig.trace_phases``)
and the off path is :data:`NULL_TRACER`, whose methods are no-ops and
which never fences: an untraced engine runs the exact code it ran
before, test-enforced to cost no measurable throughput.

The fenced tracer *destroys the pipeline it measures*: under the
PR-8 async engine loop (``ServeConfig.async_loop``) a fence between
dispatch N and schedule N+1 is exactly the serialization the loop
exists to remove.  :class:`OverlapTracer`
(``ServeConfig.phase_mode="overlap"``) is the non-fencing alternative:
it records, per step,

    overlap    host seconds between a dispatch returning and its
               collect starting — device compute hidden under host
               work (schedule/host_prep/sample of the next step)
    collect    the residual blocking wait inside ``collect`` — host
               time the device did NOT hide (the pipeline bubble)

and its summary adds ``device_overlap_s`` (total overlap),
``host_bubble_s`` (total collect wait), and ``overlap_efficiency`` =
overlap / (overlap + bubble) — 1.0 means the loop is fully pipelined,
0.0 means it is effectively synchronous.  ``overlap`` is an upper
bound on hidden device time (the device may finish early inside the
span); ``collect`` is exact.

The tracer always stamps with ``time.perf_counter`` — real host/device
seconds — even when the engine itself runs on a virtual clock
(:class:`~repro.serve.workloads.StepClock`): phase timings are physical
measurements, arrival/deadline bookkeeping is simulation time.

This module stays importable without jax (the single
``block_until_ready`` call imports lazily), so host-side tooling can
consume recorded phase data anywhere the scheduler runs.
"""

from __future__ import annotations

import collections
import time

#: phase names in within-step order (``wall`` is the whole step)
PHASES = ("schedule", "host_prep", "dispatch", "device", "sample")


def _percentile(xs: list[float], q: float) -> float:
    """Nearest-rank percentile of a sorted list (numpy-free: the policy
    layer must not grow device deps for a summary)."""
    if not xs:
        return 0.0
    idx = min(len(xs) - 1, max(0, int(round(q / 100.0 * (len(xs) - 1)))))
    return xs[idx]


class _NullCtx:
    """Reusable no-op context manager (one shared instance, no allocation
    per phase on the untraced path)."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


class NullTracer:
    """The off switch: every hook is a no-op and :meth:`fence` never
    touches the device, so an untraced engine's hot loop is unchanged."""

    enabled = False
    _ctx = _NullCtx()
    #: record key the executor wraps collect's blocking transfer in
    #: ("sample" keeps the fenced/untraced record schema; the overlap
    #: tracer renames it "collect" — the pipeline-bubble measurement)
    collect_phase = "sample"

    def begin_step(self) -> None:
        pass

    def end_step(self) -> None:
        pass

    def phase(self, name: str) -> _NullCtx:
        return self._ctx

    def fence(self, value):
        return value

    def mark_dispatch(self) -> float:
        """Timestamp a decode dispatch's return (overlap accounting);
        the no-op tracer never reads a clock."""
        return 0.0

    def collect_begin(self, dispatched_at: float) -> None:
        """Record the dispatch->collect host span as hidden device time
        (overlap accounting); no-op here."""

    def records(self) -> list[dict]:
        return []

    def summary(self) -> dict:
        return {}


#: the shared untraced instance every executor starts with
NULL_TRACER = NullTracer()


class _PhaseCtx:
    """Context manager accumulating elapsed seconds into the tracer's
    current step record under ``name`` (re-entrant per step: repeated
    phases — one per dispatch — sum)."""

    __slots__ = ("tracer", "name", "t0")

    def __init__(self, tracer: PhaseTracer, name: str):
        self.tracer = tracer
        self.name = name

    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        cur = self.tracer._cur
        if cur is not None:
            cur[self.name] = (
                cur.get(self.name, 0.0) + time.perf_counter() - self.t0
            )
        return False


class PhaseTracer:
    """Accumulate per-step phase timings into a bounded ring buffer.

    Usage (the engine/executor wiring)::

        tracer.begin_step()
        with tracer.phase("schedule"):
            decision = scheduler.schedule(slots)
        with tracer.phase("dispatch"):
            out = jitted(...)            # returns once enqueued
        with tracer.phase("device"):
            tracer.fence(out)            # block_until_ready
        tracer.end_step()

    ``fence`` is the only device-touching call and exists so the *same*
    executor source runs fenced and unfenced: under :data:`NULL_TRACER`
    it is a pass-through.
    """

    enabled = True
    #: per-step record keys the summary reports (subclasses extend)
    _names = PHASES
    #: see NullTracer.collect_phase
    collect_phase = "sample"

    def __init__(self, ring: int = 512):
        if ring < 1:
            raise ValueError(f"phase ring must hold >= 1 record, got {ring}")
        self._ring: collections.deque[dict] = collections.deque(maxlen=ring)
        self._cur: dict | None = None
        self._t0 = 0.0
        #: dispatches fenced so far (the off-costs-nothing guard test
        #: asserts an untraced engine performs zero fences)
        self.fences = 0

    # ------------------------------------------------------------ hooks --
    def begin_step(self) -> None:
        self._cur = {}
        self._t0 = time.perf_counter()

    def end_step(self) -> None:
        if self._cur is None:
            return
        self._cur["wall"] = time.perf_counter() - self._t0
        self._ring.append(self._cur)
        self._cur = None

    def phase(self, name: str) -> _PhaseCtx:
        return _PhaseCtx(self, name)

    def fence(self, value):
        """Wait for every array in ``value`` (pytree) to be ready on
        device.  Call inside a ``phase("device")`` block, right after the
        dispatch returned, to split launch time from device time."""
        import jax  # lazy: keep the module importable host-side

        self.fences += 1
        return jax.block_until_ready(value)

    def mark_dispatch(self) -> float:
        """Timestamp a decode dispatch's return.  The fenced tracer
        already isolates device time via :meth:`fence`; the stamp is
        consumed by :class:`OverlapTracer.collect_begin`."""
        return time.perf_counter()

    def collect_begin(self, dispatched_at: float) -> None:
        """Overlap accounting hook; the fenced tracer measures device
        time by fencing instead, so this records nothing."""

    # ---------------------------------------------------------- reading --
    def records(self) -> list[dict]:
        """Completed per-step records, oldest first (bounded by the ring)."""
        return list(self._ring)

    def summary(self) -> dict:
        """Per-phase p50/p95/p99/mean in milliseconds plus totals, over
        the retained ring.  A phase absent from a step (e.g. no prefill
        that step) does not drag its percentiles toward zero: each
        phase summarizes only the steps it appeared in."""
        recs = self.records()
        out: dict = {"steps": len(recs), "ring": self._ring.maxlen}
        for name in self._names + ("wall",):
            xs = sorted(r[name] for r in recs if name in r)
            if not xs:
                continue
            total = sum(xs)
            out[name] = {
                "n": len(xs),
                "p50_ms": _percentile(xs, 50) * 1e3,
                "p95_ms": _percentile(xs, 95) * 1e3,
                "p99_ms": _percentile(xs, 99) * 1e3,
                "mean_ms": total / len(xs) * 1e3,
                "total_s": total,
            }
        if recs:
            # time the phase model did not attribute (python routing in
            # the engine loop, telemetry merges): honest accounting
            # means the residual is reported, not hidden
            walls = sum(r.get("wall", 0.0) for r in recs)
            attributed = sum(
                v for r in recs
                for k, v in r.items()
                if k != "wall"
            )
            out["unattributed_s"] = max(0.0, walls - attributed)
        return out


class OverlapTracer(PhaseTracer):
    """The non-fencing tracer for the pipelined loop: same per-phase
    accumulation as :class:`PhaseTracer`, but :meth:`fence` is a
    pass-through (device and host stay overlapped) and device time is
    accounted by *span*, not by blocking:

    * ``overlap`` — host seconds between :meth:`mark_dispatch` (a decode
      dispatch returned, device busy) and :meth:`collect_begin` (the
      host finally needs the results).  Under the async loop this span
      contains the *next* step's schedule/host_prep — exactly the work
      the pipeline hides.  Upper bound on hidden device time.
    * ``collect`` — wrapped by the executor around the blocking
      device->host conversion in ``collect()``: host time the device
      did not hide (the pipeline bubble).  Exact.

    The summary adds ``device_overlap_s`` / ``host_bubble_s`` /
    ``overlap_efficiency`` totals over the ring.
    """

    _names = PHASES + ("collect", "overlap")
    collect_phase = "collect"

    def fence(self, value):
        """Never blocks — fencing would serialize the pipeline this
        tracer exists to measure.  ``fences`` stays 0."""
        return value

    def collect_begin(self, dispatched_at: float) -> None:
        if self._cur is not None and dispatched_at > 0.0:
            span = max(0.0, time.perf_counter() - dispatched_at)
            self._cur["overlap"] = self._cur.get("overlap", 0.0) + span

    def summary(self) -> dict:
        out = super().summary()
        recs = self.records()
        overlap = sum(r.get("overlap", 0.0) for r in recs)
        bubble = sum(r.get("collect", 0.0) for r in recs)
        out["device_overlap_s"] = overlap
        out["host_bubble_s"] = bubble
        out["overlap_efficiency"] = (
            overlap / (overlap + bubble) if (overlap + bubble) > 0 else 0.0
        )
        return out


def make_tracer(
    trace: bool, ring: int = 512, mode: str = "fenced"
) -> PhaseTracer | NullTracer:
    """The ServeConfig -> tracer factory: a live tracer when tracing is
    requested (``mode`` "fenced" = :class:`PhaseTracer`, "overlap" =
    :class:`OverlapTracer`), the shared no-op otherwise."""
    if not trace:
        return NULL_TRACER
    if mode == "overlap":
        return OverlapTracer(ring=ring)
    if mode == "fenced":
        return PhaseTracer(ring=ring)
    raise ValueError(
        f"phase_mode must be 'fenced' or 'overlap', got {mode!r}"
    )

"""SLO-aware scheduling: earliest-deadline-first on the Scheduler seam.

The source paper's setting is a hard-real-time physics trigger — an
answer that arrives after its bunch-crossing window is *worthless*, not
late.  :class:`DeadlineScheduler` brings that discipline to the serving
stack as a drop-in policy for the PR-5 ``Scheduler`` protocol
(``Engine(scheduler_factory=...)`` or ``ServeConfig.scheduler="edf"``):

* **EDF admission order** — the queue is kept sorted by each request's
  absolute ``deadline_at`` (engine-clock time); requests without a
  deadline run FIFO behind every deadlined one.  Everything else —
  prefix-cache hit planning, chunked prefill, page reservation,
  preemption bookkeeping — is inherited from
  :class:`~repro.serve.scheduler.FifoScheduler` unchanged, which is the
  whole point of the scheduler/executor split: a new policy is a
  reordering, not a re-implementation.
* **Overdue policy** (``ServeConfig.overdue_policy``) for a *queued*
  request whose deadline passes before admission:

  - ``"drop"`` (default): remove it and report it — the API layer
    finishes it with ``finish_reason="deadline"`` and streams a
    terminal :class:`~repro.serve.api.TokenEvent`, so a drop is an
    answered request, and the capacity it would have burned serves
    still-feasible work instead.
  - ``"demote"``: keep it, but behind every still-feasible request.
  - ``"ignore"``: pure EDF order, no special handling (it will run,
    and be counted as a miss).

  A *resident* past-deadline request always runs to completion: its
  pages and KV content are never invalidated mid-flight, it is simply
  counted as a miss by the engine's SLO telemetry.
* **Deadline-aware preemption victims** — when the page pool blocks the
  queue head, the evicted resident is the one with the *least urgent*
  deadline (deadline-less first, then latest deadline; youngest breaks
  ties), instead of FIFO's youngest-resident rule.

This module is policy only: like ``serve/scheduler.py`` it imports no
jax and performs no device work.
"""

from __future__ import annotations

import math
from typing import TYPE_CHECKING

from repro.serve.scheduler import (
    ExecutorCaps,
    FifoScheduler,
    Request,
    ScheduleDecision,
    Slot,
)

if TYPE_CHECKING:
    from repro.configs.base import ServeConfig
    from repro.serve.kv_cache import CacheManager

#: valid ``ServeConfig.overdue_policy`` values
OVERDUE_POLICIES = ("drop", "demote", "ignore")


def _urgency(req: Request) -> float:
    """EDF sort key: absolute deadline, +inf when none (deadline-less
    requests yield to every deadlined one)."""
    return req.deadline_at if req.deadline_at is not None else math.inf


class DeadlineScheduler(FifoScheduler):
    """Earliest-deadline-first admission with a configurable past-deadline
    policy, composing with prefix caching, chunked prefill, and
    page-aware preemption through the inherited FIFO machinery."""

    def __init__(
        self,
        serve_cfg: ServeConfig,
        caps: ExecutorCaps,
        cache: CacheManager,
        clock=None,
    ):
        super().__init__(serve_cfg, caps, cache, clock=clock)
        self.overdue_policy = serve_cfg.overdue_policy
        if self.overdue_policy not in OVERDUE_POLICIES:
            raise ValueError(
                f"overdue_policy must be one of {OVERDUE_POLICIES}, "
                f"got {self.overdue_policy!r}"
            )
        #: queued requests removed past their deadline (drop policy)
        self.stats["deadline_drops"] = 0

    # ----------------------------------------------------------- policy --
    def schedule(self, slots: list[Slot]) -> ScheduleDecision:
        """Apply the overdue policy, re-sort the queue EDF, then run the
        inherited admission/preemption machinery over the reordered
        queue.  Sorting is host-side list work on O(queue) records —
        exactly the kind of policy the device layer never sees."""
        now = self.clock()
        dropped: list[Request] = []
        if self.overdue_policy == "drop" and self.queue:
            feasible = []
            for req in self.queue:
                if req.deadline_at is not None and now > req.deadline_at:
                    # never admitted this residency -> no pages held
                    # (a preempted requeue freed its pages at eviction);
                    # removing it is pure bookkeeping
                    dropped.append(req)
                    self.stats["deadline_drops"] += 1
                else:
                    feasible.append(req)
            self.queue[:] = feasible
        # stable sort: same-deadline (and deadline-less) requests keep
        # FIFO order among themselves, so EDF degrades to exactly FIFO
        # when nobody carries a deadline
        self.queue.sort(key=_urgency)
        if self.overdue_policy == "demote" and self.queue:
            fresh = [
                r for r in self.queue
                if r.deadline_at is None or now <= r.deadline_at
            ]
            overdue = [
                r for r in self.queue
                if r.deadline_at is not None and now > r.deadline_at
            ]
            self.queue[:] = fresh + overdue
        decision = super().schedule(slots)
        decision.dropped = dropped
        return decision

    def _pick_victim(self, victims: list[int], slots: list[Slot]) -> int:
        """Evict the least-urgent resident: deadline-less before
        deadlined, later deadlines before earlier ones; admit_seq
        (youngest) breaks ties — protecting urgent in-flight work is
        what makes preemption deadline-aware rather than merely
        page-aware."""
        return max(
            victims,
            key=lambda i: (_urgency(slots[i].request), slots[i].admit_seq),
        )

"""Scheduling layer of the serving stack: *policy only, no device work*.

The serving engine is split into three layers (see ``serve/api.py`` for
the client-facing one):

* **Scheduler** (this module) — decides, each engine step, which queued
  prompts are admitted into which bucket/slots, which resident slots
  decode, and which residents are preempted.  It owns the request queue
  and performs the host-side page-pool bookkeeping for its decisions
  (reservation, prefix-hit mapping, preemption frees) through the
  :class:`~repro.serve.kv_cache.CacheManager` — all numpy/list state,
  never a device dispatch.  This module must stay importable without
  jax: it contains **no jax imports and no device dispatches**
  (test-enforced), which is what makes scheduling policy auditable and
  swappable without touching compiled programs.
* **Executor** (``serve/executor.py``) — owns the jit caches, the
  CacheManager and the device cache pytree, and mechanically applies a
  :class:`ScheduleDecision` (prefill dispatches, the decode scan, slot
  bookkeeping).  It makes no policy choices.
* **Engine** (``serve/api.py``) — the client API (submit / stream /
  cancel / generate) looping ``scheduler.schedule -> executor.execute``.

The default :class:`FifoScheduler` reproduces the historical engine
behavior exactly: FIFO admission grouped by prefill bucket,
prefix-cache hit planning (prefill-skip), youngest-first page-aware
preemption, and **chunked prefill** (``ServeConfig.prefill_chunk``): a
long prompt is admitted by prefilling only its first ``prefill_chunk``
tokens through the bucketed prefill program and replaying the
remaining prompt tail incrementally, interleaved with resident decode
steps, so each step stalls residents by at most a chunk-sized dispatch
instead of a full-prompt-sized one.

Token replay picks whichever mechanism reproduces the cache's own
math on the engine's datapath, stamped per admission as
``decode_from``: positions before it ride the executor's
cache-extending prefill program (prefill-path math), positions from it
on teacher-force through the decode scan (decode-path math).
Bit-exact datapaths (float GQA, exact softmax, reference kernel) plan
``decode_from == write_from`` — the whole tail through the decode scan,
the historical behavior; every other datapath (MLA, int8 KV, LUT
softmax) replays prompt positions via cache-extend so skip / chunked /
resume stay token-identical there too.  The compiled-program set stays
at ``len(prefill_buckets)`` prefill + 1 decode programs, + 1 extend
program on the datapaths that need it (test-enforced).
"""

from __future__ import annotations

import dataclasses
import time
import warnings
from typing import TYPE_CHECKING, Protocol, runtime_checkable

if TYPE_CHECKING:  # import-time dependency kept out of the policy layer
    from repro.configs.base import ServeConfig
    from repro.serve.kv_cache import CacheManager, PrefixMatch


# ------------------------------------------------------------ requests --
@dataclasses.dataclass
class Request:
    uid: int
    prompt: list[int]
    max_new_tokens: int = 16
    eos_id: int | None = None
    generated: list[int] = dataclasses.field(default_factory=list)
    #: original submission time; never restamped — the stable anchor for
    #: client-side latency (TTFT = first TokenEvent.ts - created_at)
    created_at: float = 0.0
    #: queue-wait clock; a preemption restamps it at requeue so the next
    #: admission's wait measures time-to-resume, not time-since-submit
    submitted_at: float = 0.0
    admitted_at: float = 0.0
    #: absolute completion deadline on the engine clock (None = no SLO);
    #: the EDF policy (serve/slo.py) orders the queue by it and may drop
    #: a queued request once it passes (finish_reason="deadline")
    deadline_at: float | None = None
    #: engine-clock time the request left the system (final token
    #: emitted, or dropped past-deadline); 0.0 while live.  Deadline
    #: met iff ``finished_at <= deadline_at``.
    finished_at: float = 0.0
    #: times this request was preempted (pages freed, re-queued to resume
    #: from prompt + generated-so-far); telemetry for the scheduler tests
    preemptions: int = 0
    #: set by Engine.cancel; a cancelled request emits no further tokens
    cancelled: bool = False
    #: per-request sampling knobs (None = engine default); resolved by
    #: :func:`encode_sampling` and threaded through the compiled
    #: programs as traced per-slot arrays (see ``serve/sampling.py``)
    temperature: float | None = None
    top_k: int | None = None
    top_p: float | None = None
    seed: int | None = None
    #: uid of the primary request this n-best sibling forked from
    #: (``Engine.submit(n=...)``); admission maps the parent's pages —
    #: prompt AND generated-so-far — copy-on-write instead of
    #: re-prefilling, when the parent is still resident
    fork_of: int | None = None
    #: speculative-decoding counters for this request (tokens the draft
    #: model proposed for it / the target model accepted)
    draft_proposed: int = 0
    draft_accepted: int = 0

    @property
    def done(self) -> bool:
        if self.eos_id is not None and self.generated and self.generated[-1] == self.eos_id:
            return True
        return len(self.generated) >= self.max_new_tokens

    @property
    def resume_tokens(self) -> list[int]:
        """Effective prompt at (re-)admission: the original prompt plus
        everything generated before any preemption."""
        return self.prompt + self.generated

    @property
    def queue_wait_s(self) -> float:
        return max(0.0, self.admitted_at - self.submitted_at)


@dataclasses.dataclass
class Slot:
    """One continuous-batching slot.  Execution state (``pos``,
    ``last_token``, ``pending``) is written by the executor; the
    admission stamps (``admit_seq``, ``admit_gen``) are scheduler
    bookkeeping carried on the slot record."""

    active: bool = False
    request: Request | None = None
    pos: int = 0  # next position to write (== current length)
    last_token: int = 0
    #: prompt-tail tokens still to be teacher-forced through the decode
    #: scan (prefix-skip / chunked-prefill admissions); drained
    #: decode_steps at a time
    pending: list[int] = dataclasses.field(default_factory=list)
    #: tokens still to be replayed through the cache-extending prefill
    #: program before ``pending`` (non-bit-exact skip / chunked / resume
    #: admissions); drained extend_width at a time, and the slot does
    #: not decode until this is empty
    prefill_tail: list[int] = dataclasses.field(default_factory=list)
    #: admission order stamp — preemption picks the youngest resident
    admit_seq: int = -1
    #: a decode dispatch referencing this slot is in flight and not yet
    #: collected (async loop).  Set by the executor at dispatch, cleared
    #: at collect (unless a newer dispatch re-marked the slot first).
    #: Policies MAY preempt an in-flight slot: the executor's dispatch
    #: snapshot discards the uncollected tokens at collect, and the
    #: resume replays from the host-visible ``generated`` — greedy
    #: streams regenerate the discarded tokens bit-identically.  Under
    #: the synchronous loop dispatch/collect run back-to-back and the
    #: scheduler never observes this True.
    inflight: bool = False
    #: generated-token count at (re-)admission: a slot is only
    #: preemptable once it has emitted at least one token this
    #: residency, so every preemption cycle nets forward progress (a
    #: skip-resumed or chunked slot replaying its forced tail would
    #: otherwise be preempted before ever sampling — a livelock)
    admit_gen: int = 0


# ------------------------------------------------------------ sampling --
#: traced-array sentinels for "knob off" (see ``serve/sampling.py``)
TOPK_OFF = 0
TOPP_OFF = 1.0
SEED_OFF = -1


def encode_sampling(
    req: Request | None, default_temperature: float = 0.0
) -> tuple[float, int, float, int]:
    """Resolve a request's sampling knobs to the traced-array encoding
    ``(temperature, top_k, top_p, seed)`` consumed by the compiled
    programs: ``None`` temperature inherits the engine default, off
    knobs map to their sentinels (top_k 0, top_p 1.0, seed -1).  Pure
    host arithmetic — this module stays device-free."""
    if req is None:
        return (0.0, TOPK_OFF, TOPP_OFF, SEED_OFF)
    t = default_temperature if req.temperature is None else req.temperature
    k = TOPK_OFF if not req.top_k else int(req.top_k)
    p = TOPP_OFF if req.top_p is None else float(req.top_p)
    s = SEED_OFF if req.seed is None else int(req.seed)
    return (float(t), k, p, s)


# ------------------------------------------------------------ decisions --
#: admission modes — how the prompt's KV gets into the cache
MODE_PREFILL = "prefill"  # whole effective prompt through one bucket dispatch
MODE_SKIP = "skip"        # prefix hit: no dispatch, tail teacher-forced
MODE_CHUNKED = "chunked"  # first chunk through a bucket dispatch, tail forced
MODE_FORK = "fork"        # n-best sibling: parent pages mapped CoW, no dispatch


@dataclasses.dataclass(frozen=True)
class Admission:
    """One planned slot tenancy.  ``tokens`` is the effective prompt
    (original prompt + generated-so-far for a preemption resume);
    ``fill_len`` of it rides the prefill dispatch (0 for prefix-skip).
    The unwritten tail splits at ``decode_from``: positions in
    [``write_from``, ``decode_from``) replay through the cache-extending
    prefill program, positions >= ``decode_from`` teacher-force through
    the decode scan.  ``decode_from == write_from`` (bit-exact
    datapaths) routes the whole tail through decode — the historical
    plan."""

    slot: int
    request: Request
    tokens: tuple[int, ...]
    mode: str  # MODE_PREFILL | MODE_SKIP | MODE_CHUNKED | MODE_FORK
    bucket: int  # padded dispatch length (0 for MODE_SKIP / MODE_FORK)
    fill_len: int  # prompt tokens the prefill dispatch computes
    write_from: int  # first position written after the prefill dispatch
    decode_from: int  # first position replayed through the decode scan
    shared_pages: int  # leading covered pages mapped at admit()/fork()
    admit_seq: int
    admit_gen: int
    #: of ``shared_pages``, how many were victim-tier hits: chunks whose
    #: rows were spilled to host memory and swap back into fresh device
    #: pages at this admission (CacheManager.flush_swaps applies the
    #: copies at the executor's next dispatch).  0 everywhere the tier
    #: is off; purely observational — the executor treats swapped pages
    #: exactly like device-shared ones (their columns are already mapped
    #: and must not be re-written by a prefill scatter)
    swapped_pages: int = 0
    #: resolved (temperature, top_k, top_p, seed) traced-array encoding
    #: for this tenancy (:func:`encode_sampling`); the executor stacks
    #: these into the per-slot sampling arrays
    sampling: tuple[float, int, float, int] = (0.0, TOPK_OFF, TOPP_OFF, SEED_OFF)

    @property
    def emits_first_token(self) -> bool:
        """Whether the prefill dispatch's last-position logits sample the
        first generated token (only when the dispatch saw the whole
        prompt; a chunk's logits predict a token we already have)."""
        return self.mode == MODE_PREFILL


@dataclasses.dataclass
class ScheduleDecision:
    """Explicit per-step plan consumed by the executor: which residents
    preempt, which queued prompts prefill into which bucket/slots, and
    which slots decode.  The scheduler has already performed the
    host-side page bookkeeping (``CacheManager.admit``/``free``) for
    everything listed here; the executor performs only device work and
    slot bookkeeping."""

    #: slots whose resident was preempted (pages already freed, request
    #: already re-queued); the executor resets the slot records
    preempted: list[tuple[int, Request]] = dataclasses.field(default_factory=list)
    #: new tenancies, in admission order
    admissions: list[Admission] = dataclasses.field(default_factory=list)
    #: bucket -> same-bucket admissions riding ONE prefill dispatch,
    #: ascending bucket order (MODE_SKIP admissions never appear here)
    prefill_groups: dict[int, list[Admission]] = dataclasses.field(default_factory=dict)
    #: slots that run the decode scan this step (residents surviving
    #: preemption + this step's admissions; the executor holds back any
    #: slot still draining a prefill tail)
    decode_slots: list[int] = dataclasses.field(default_factory=list)
    #: slots with cache-extend replay work this step (non-empty
    #: ``prefill_tail`` residents + admissions planning one)
    extend_slots: list[int] = dataclasses.field(default_factory=list)
    #: register decode-completed full pages in the prefix index (only
    #: sound on the bit-exact datapath, where decode-written KV is
    #: bitwise what a prefill of the same tokens would write)
    register_decoded: bool = False
    #: queued requests the policy removed past their deadline (never
    #: admitted this residency, so no pages to free); the API layer
    #: finishes them with ``finish_reason="deadline"`` and streams a
    #: terminal event — a drop is an answered request, never a silent one
    dropped: list[Request] = dataclasses.field(default_factory=list)


@dataclasses.dataclass(frozen=True)
class ExecutorCaps:
    """Datapath capabilities the executor advertises; policies must plan
    within them (the scheduler never inspects device state directly)."""

    max_batch: int
    max_seq_len: int
    decode_steps: int
    buckets: tuple[int, ...]  # active prefill buckets (() = exact-length)
    bucketable: bool  # position-addressed cache: right-padding is sound
    paged: bool  # block-table page pool (vs dense slot slabs)
    #: decode-path forward bitwise identical to prefill-path forward
    #: (float GQA, exact softmax, reference kernel) — lets prompt
    #: positions replay through the decode scan
    bit_exact: bool
    prefix_cache: bool  # prefix index live (paged + kv_prefix_cache)
    #: cache-extending prefill program available — lets prompt positions
    #: replay with prefill-path math on any datapath, so prefill-skip,
    #: preemption-resume, and chunked prefill no longer require
    #: ``bit_exact``
    cache_extend: bool = False


@runtime_checkable
class Scheduler(Protocol):
    """Scheduling policy protocol.  ``schedule`` may query and perform
    host-side bookkeeping on the executor-owned CacheManager (admission
    reservations, preemption frees) but must never touch device state —
    every dispatch consequence must be spelled out in the returned
    :class:`ScheduleDecision`."""

    #: policy counters merged into Engine.telemetry; must at least carry
    #: ``prompts_admitted`` and ``queue_wait_s_total``
    stats: dict

    def enqueue(self, request: Request) -> None: ...

    def remove(self, uid: int) -> Request | None: ...

    @property
    def queue(self) -> list[Request]: ...

    def schedule(self, slots: list[Slot]) -> ScheduleDecision: ...


class FifoScheduler:
    """The default policy: FIFO admission bucketed by prompt length,
    prefix-cache hit planning, youngest-first page-aware preemption, and
    chunked prefill for long prompts (``ServeConfig.prefill_chunk``)."""

    def __init__(
        self,
        serve_cfg: ServeConfig,
        caps: ExecutorCaps,
        cache: CacheManager,
        clock=None,
    ):
        self.serve_cfg = serve_cfg
        self.caps = caps
        self.cache = cache
        #: the engine clock: wall time by default, a virtual clock under
        #: deterministic workload replay (serve/workloads.py StepClock) —
        #: every wait/deadline stamp in this layer reads it
        self.clock = clock if clock is not None else time.perf_counter
        self.queue: list[Request] = []
        self._admit_seq = 0
        if serve_cfg.prefill_chunk is not None and not caps.bucketable:
            raise ValueError(
                "prefill_chunk requires a bucketable (position-addressed) "
                "cache; SSM/hybrid state and rolling sliding-window "
                "buffers admit exact-length prompts only"
            )
        #: requested knobs the engine cannot honor, surfaced in telemetry
        #: (and warned once) instead of being silently swallowed
        disabled: list[str] = []

        def _disable(feature: str, reason: str) -> None:
            disabled.append(f"{feature}: {reason}")
            warnings.warn(
                f"serving knob {feature} is disabled on this engine: "
                f"{reason}",
                RuntimeWarning,
                stacklevel=4,
            )

        #: token replay on prompt positions must reproduce the cache's
        #: own math: either the decode scan is bitwise the prefill
        #: (bit_exact) or the executor offers the cache-extending
        #: prefill program (cache_extend) — this picks the mechanism
        self.extend_replay = caps.cache_extend and not caps.bit_exact
        replayable = caps.bit_exact or caps.cache_extend
        #: prefix hits skip the prefill dispatch (vs storage-only sharing)
        self.prefix_skip = caps.prefix_cache and replayable
        if serve_cfg.kv_prefix_cache and not caps.prefix_cache:
            _disable(
                "kv_prefix_cache",
                "prefix sharing needs the paged layout on a "
                "position-addressed cache (kv_layout='paged')",
            )
        elif caps.prefix_cache and not self.prefix_skip:
            _disable(
                "kv_prefix_cache (prefill-skip)",
                "hits dedup page storage only: the datapath is not "
                "bit-exact and the cache-extending prefill program is "
                "unavailable (Pallas kernel or cache_extend=False)",
            )
        #: n-best sibling admission (``Request.fork_of``): map the
        #: resident parent's pages — including generated-into ones —
        #: copy-on-write instead of re-prefilling.  Needs refcounted
        #: pages (paged layout) and a replayable datapath: the child
        #: re-processes the parent's last prompt token to sample its own
        #: first token, exactly like a full-coverage prefix-skip.
        self.fork_enabled = caps.paged and replayable
        #: page-aware preemption instead of FIFO head-of-line blocking
        self.preempt_enabled = (
            caps.paged and serve_cfg.kv_preemption and replayable
        )
        if serve_cfg.kv_preemption and not self.preempt_enabled:
            _disable(
                "kv_preemption",
                "preemption needs the paged layout and a datapath that "
                "can replay a resume's prompt (bit-exact decode or the "
                "cache-extending prefill program)",
            )
        #: chunked prefill: the chunk dispatch must reuse a bucketed
        #: program, and the prompt tail must be replayable
        self.chunk_len = (
            serve_cfg.prefill_chunk
            if (
                serve_cfg.prefill_chunk is not None
                and replayable
                and caps.buckets
            )
            else None
        )
        if serve_cfg.prefill_chunk is not None and self.chunk_len is None:
            _disable(
                "prefill_chunk",
                "chunk-tail replay needs prefill buckets and a datapath "
                "that can replay prompt positions (bit-exact decode or "
                "the cache-extending prefill program)",
            )
        if self.chunk_len is not None:
            if self.chunk_len < 1:
                raise ValueError(
                    f"prefill_chunk must be >= 1, got {self.chunk_len}"
                )
            if self.chunk_len > max(caps.buckets):
                raise ValueError(
                    f"prefill_chunk={self.chunk_len} exceeds the largest "
                    f"prefill bucket {max(caps.buckets)}; a chunk dispatch "
                    "must ride an existing bucketed program"
                )
        self.stats = {
            "prompts_admitted": 0,
            "queue_wait_s_total": 0.0,
            # created_at-anchored wait: admission minus ORIGINAL submit
            # time, summed over admissions.  Equal to queue_wait_s_total
            # until a preemption restamps submitted_at — from then on
            # this is the honest time-in-system-before-(re)admission the
            # restamped clock undercounts (includes prior residencies).
            "queue_wait_created_s_total": 0.0,
            "preemptions": 0,
            # prompt tokens never recomputed thanks to a prefix hit
            # (prefill-skip admissions only — real FLOP savings)
            "prefill_tokens_saved": 0,
            # prompt tokens whose pages were deduped by a prefix hit on
            # the storage-only path (recomputed, but no pages written)
            "prefix_tokens_shared": 0,
            # n-best siblings admitted by mapping the parent's pages CoW
            "forks": 0,
            # siblings whose parent had already left its slot, admitted
            # through a plain prefill instead (correct, just no sharing)
            "fork_fallbacks": 0,
            # requested-but-unhonorable knobs ("feature: reason")
            "disabled_features": disabled,
        }

    # ------------------------------------------------------------ queue --
    def enqueue(self, request: Request) -> None:
        self.queue.append(request)

    def remove(self, uid: int) -> Request | None:
        for i, req in enumerate(self.queue):
            if req.uid == uid:
                return self.queue.pop(i)
        return None

    def bucket_for(self, n: int) -> int:
        """Padded prefill length for an n-token prompt: the smallest bucket
        >= n, or n itself for unbucketable families / oversized prompts."""
        for b in self.caps.buckets:
            if b >= n:
                return b
        return n

    # ------------------------------------------------------- preemption --
    def _try_preempt(
        self, slots: list[Slot], free: list[int], decision: ScheduleDecision
    ) -> bool:
        """Preempt the youngest resident slot to unblock the queue head:
        free its pages (shared prefix pages survive via refcounts), stamp
        the preemption, and re-queue it right behind the head with
        prompt + generated-so-far as a resumable prompt.  Returns False
        when preemption is off or nothing is preemptable.

        A slot whose resume prompt no longer fits the largest configured
        prefill bucket is not preemptable: re-prefilling it would mint an
        exact-length jit program and silently blow the
        len(prefill_buckets) + 1 program budget.  Neither is a slot that
        has not emitted a token since its (re-)admission: preempting it
        would discard a residency that made no progress, and a
        skip-resumed slot still replaying its teacher-forced tail could
        be preempted every step forever (livelock).

        A slot with an uncollected decode dispatch in flight (async
        loop) IS preemptable: collect discards its in-flight tokens
        (executor snapshot guard) and the resume regenerates them, so
        greedy streams stay identical.  Excluding in-flight victims
        would starve preemption entirely under the pipelined loop —
        every decoding resident has a dispatch in flight at schedule
        time."""
        if not self.preempt_enabled:
            return False
        taken = {idx for idx, _ in decision.preempted}
        max_bucket = max(self.caps.buckets) if self.caps.buckets else None
        victims = [
            i for i, s in enumerate(slots)
            if s.active
            and i not in taken
            and len(s.request.generated) > s.admit_gen
            and (
                max_bucket is None
                or len(s.request.resume_tokens) <= max_bucket
            )
        ]
        if not victims:
            return False
        idx = self._pick_victim(victims, slots)
        req = slots[idx].request
        req.preemptions += 1
        # the wait clock restarts at requeue: the next admission's queue
        # wait measures time spent waiting to resume, not time since the
        # original submission (which would double-count the residency).
        # created_at never restamps — queue_wait_created_s_total keeps
        # the full time-in-system view.
        req.submitted_at = self.clock()
        self.stats["preemptions"] += 1
        self.cache.free(idx)
        decision.preempted.append((idx, req))
        free.append(idx)
        self.queue.insert(1, req)
        return True

    def _pick_victim(self, victims: list[int], slots: list[Slot]) -> int:
        """Choose which preemptable resident to evict.  FIFO preempts
        the youngest (largest admit_seq) so the head-of-line request
        displaces the least-progressed work; deadline-aware policies
        override this to protect urgent residents."""
        return max(victims, key=lambda i: slots[i].admit_seq)

    # ------------------------------------------------------------- fork --
    def _try_fork(
        self,
        head: Request,
        slots: list[Slot],
        free: list[int],
        decision: ScheduleDecision,
    ) -> str:
        """Try to admit the queue head — an n-best sibling — by mapping
        its resident parent's pages copy-on-write (generated-into pages
        included: this is what extends page sharing beyond prompts).

        Returns ``"admitted"`` on success, ``"wait"`` when the parent is
        resident but not yet covering the prompt (or pages are short and
        preemption cannot help) — the head blocks, FIFO order holds —,
        ``"retry"`` after a preemption freed pages, and ``"fallback"``
        when the parent already left its slot: the sibling then admits
        through the plain prefill path (correct, just no sharing)."""
        taken = {i for i, _ in decision.preempted}
        pidx = next(
            (
                i for i, s in enumerate(slots)
                if s.active
                and i not in taken
                and s.request is not None
                and s.request.uid == head.fork_of
            ),
            None,
        )
        if pidx is None:
            if any(
                a.request.uid == head.fork_of for a in decision.admissions
            ):
                # the parent is being admitted by THIS decision (the
                # common submit(n=...) burst): it is not in a slot yet,
                # but will be next step — wait instead of falling back
                return "wait"
            return "fallback"
        upto = len(head.prompt)
        if slots[pidx].pos < upto:
            # parent still prefilling its prompt (or its host position
            # is stale-low under the async loop): wait a step.  The
            # parent is resident and progressing, so this never wedges.
            return "wait"
        reserve_len = self._reserve_len(head)
        need = self.cache.fork_need(pidx, upto, reserve_len)
        if not self.cache.can_reserve(need):
            # preemption may evict the parent itself — the retry then
            # takes the fallback path, which is still correct
            return "retry" if self._try_preempt(slots, free, decision) else "wait"
        req = self.queue.pop(0)
        if req.admitted_at == 0.0:
            self.stats["prompts_admitted"] += 1
        req.admitted_at = self.clock()
        self.stats["queue_wait_s_total"] += req.queue_wait_s
        self.stats["queue_wait_created_s_total"] += max(
            0.0, req.admitted_at - req.created_at
        )
        idx = free.pop(0)
        self._admit_seq += 1
        shared = self.cache.fork(idx, pidx, upto, reserve_len)
        self.stats["forks"] += 1
        # every prompt position is already in the shared pages; the
        # child re-processes only the last prompt token (write_from) to
        # sample its own first token — prefill-skip mechanics with the
        # parent's live pages instead of the prefix index
        write_from = max(upto - 1, 0)
        decode_from = upto if self.extend_replay else write_from
        adm = Admission(
            slot=idx, request=req, tokens=tuple(req.prompt), mode=MODE_FORK,
            bucket=0, fill_len=0, write_from=write_from,
            decode_from=decode_from, shared_pages=shared,
            admit_seq=self._admit_seq, admit_gen=0,
            sampling=encode_sampling(req, self.serve_cfg.temperature),
        )
        decision.admissions.append(adm)
        self.stats["prefill_tokens_saved"] += write_from
        return "admitted"

    # -------------------------------------------------------- admission --
    def _reserve_len(self, req: Request) -> int:
        """Worst-case sequence length for a request: decode writes reach at
        most position prompt + max_new_tokens - 1 (capped by max_seq_len)."""
        return min(
            len(req.prompt) + req.max_new_tokens, self.serve_cfg.max_seq_len
        )

    def schedule(self, slots: list[Slot]) -> ScheduleDecision:
        """Plan one engine step.  FIFO order; when the queue head cannot
        get pages, either preempt the youngest resident (kv_preemption on
        the bit-exact datapath) or block the head until finished slots
        return pages (no reordering, no starvation either way)."""
        sc = self.serve_cfg
        # decode-written pages are only registerable in the prefix index
        # on the bit-exact datapath (elsewhere their content is decode
        # math, not what a prefill of the same tokens would write)
        decision = ScheduleDecision(
            register_decoded=self.prefix_skip and self.caps.bit_exact
        )
        cap = sc.max_prefill_per_step or sc.max_batch
        free = [i for i, s in enumerate(slots) if not s.active]
        n_admitted = 0
        while self.queue and free and n_admitted < cap:
            head = self.queue[0]
            if (
                self.fork_enabled
                and head.fork_of is not None
                and not head.generated
            ):
                outcome = self._try_fork(head, slots, free, decision)
                if outcome == "admitted":
                    n_admitted += 1
                    continue
                if outcome == "retry":
                    continue
                if outcome == "wait":
                    break
                # "fallback": parent gone for good (finished, cancelled,
                # or itself preempted) — sticky-demote the sibling to a
                # plain admission so it is planned (and counted) once
                head.fork_of = None
                self.stats["fork_fallbacks"] += 1
            seq = head.resume_tokens
            resume = bool(head.generated)
            # a preemption resume on the cache-extend path splits: the
            # prompt part replays with prefill math, the generated part
            # must replay through the decode scan (the math that wrote
            # those positions in the baseline stream)
            split = self.extend_replay and resume
            # reserve worst-case pages (prompt + generation budget) so
            # decode growth can never exhaust the pool mid-run; pages
            # still allocate lazily as the sequence actually grows.  A
            # prefix hit reserves only the unshared tail (+1 CoW page
            # when the first write lands inside a shared page).
            reserve_len = self._reserve_len(head)
            match = self.cache.match_prefix(seq)
            if match and split:
                # index pages hold prefill-path content; a split resume
                # may only share pages fully inside its original prompt
                # (host-tier hits included: keys count total coverage)
                keep = len(head.prompt) // self.cache.page_size
                if len(match.keys) > keep:
                    match = type(match)(
                        match.pages[:keep], match.keys[:keep],
                        keep * self.cache.page_size,
                    )
            skip = bool(match) and self.prefix_skip and len(seq) > 1
            # chunked prefill only applies where no prefix pages cover the
            # prompt (a hit always skips instead); a split resume without
            # a hit also admits chunked — its prefill dispatch may cover
            # at most the original prompt
            chunked = (
                not skip
                and not match
                and (
                    (self.chunk_len is not None and len(seq) > self.chunk_len)
                    or split
                )
            )
            if skip:
                write_from = min(match.tokens, len(seq) - 1)
            elif chunked:
                write_from = len(head.prompt) if split else self.chunk_len
                if self.chunk_len is not None:
                    write_from = min(write_from, self.chunk_len)
            else:
                write_from = len(seq)
            need = self.cache.admission_need(match, reserve_len, write_from)
            if not self.cache.can_reserve(need):
                if self._try_preempt(slots, free, decision):
                    continue  # pages (and a slot) came back; retry head
                break
            req = self.queue.pop(0)
            # queue wait ends at pop: prefill execution/compile time that
            # follows is prefill_time_s, not waiting.  A preemption-resume
            # adds its re-wait to the total but the prompt counts once.
            if req.admitted_at == 0.0:
                self.stats["prompts_admitted"] += 1
            req.admitted_at = self.clock()
            self.stats["queue_wait_s_total"] += req.queue_wait_s
            # the created_at-anchored companion key: for a preemption
            # resume this spans prior residencies too, so preempted
            # requests' time-in-system is never silently undercounted
            self.stats["queue_wait_created_s_total"] += max(
                0.0, req.admitted_at - req.created_at
            )
            n_admitted += 1
            idx = free.pop(0)
            self._admit_seq += 1
            shared = self.cache.admit(
                idx, seq, reserve_len,
                match=match, lazy_tail=skip or chunked,
                write_from=write_from,
                fill_len=write_from if chunked else None,
            )
            if skip:
                mode, bucket, fill_len = MODE_SKIP, 0, 0
                self.stats["prefill_tokens_saved"] += write_from
            elif chunked:
                mode = MODE_CHUNKED
                fill_len = write_from
                bucket = self.bucket_for(fill_len)
            else:
                mode = MODE_PREFILL
                fill_len = len(seq)
                bucket = self.bucket_for(fill_len)
                self.stats["prefix_tokens_shared"] += match.tokens if match else 0
            # where the unwritten tail switches from cache-extend replay
            # to decode-scan replay: everywhere on the legacy (bit-exact)
            # plan; past the original prompt for a split resume; past the
            # whole sequence for a fresh extend-path admission (the last
            # window's logits sample the first token, exactly as a
            # whole-prompt prefill dispatch would)
            if mode == MODE_PREFILL or not self.extend_replay:
                decode_from = write_from if mode != MODE_PREFILL else len(seq)
            elif resume:
                decode_from = max(write_from, len(head.prompt))
            else:
                decode_from = len(seq)
            adm = Admission(
                slot=idx, request=req, tokens=tuple(seq), mode=mode,
                bucket=bucket, fill_len=fill_len, write_from=write_from,
                decode_from=decode_from, shared_pages=shared,
                admit_seq=self._admit_seq, admit_gen=len(req.generated),
                swapped_pages=match.host_hits if match else 0,
                sampling=encode_sampling(req, sc.temperature),
            )
            decision.admissions.append(adm)
            if mode != MODE_SKIP:
                decision.prefill_groups.setdefault(bucket, []).append(adm)
        decision.prefill_groups = dict(sorted(decision.prefill_groups.items()))
        preempted = {idx for idx, _ in decision.preempted}
        decision.decode_slots = sorted(
            {i for i, s in enumerate(slots) if s.active and i not in preempted}
            | {a.slot for a in decision.admissions}
        )
        decision.extend_slots = sorted(
            {
                i for i, s in enumerate(slots)
                if s.active and s.prefill_tail and i not in preempted
            }
            | {
                a.slot for a in decision.admissions
                if a.decode_from > (
                    a.write_from
                    if a.mode in (MODE_SKIP, MODE_FORK)
                    else a.fill_len
                )
            }
        )
        return decision

"""KV-cache subsystem: one CacheManager, two storage layouts (dense, paged).

The paper's sub-2us datapath works because the memory layout is decided
once, ahead of time, and every pipeline stage addresses it with fixed
strides.  This module gives the serving engine the same discipline at
datacenter scale: all KV-cache *layout* knowledge — which leaves have a
sequence axis, how a prefilled slab is inserted into a slot, how decode
reads and writes one token — lives here, behind a small set of traced
helpers plus a host-side :class:`CacheManager`.

Two layouts share one interface:

* **dense** — the classic per-slot slab: every cache leaf carries a
  ``(batch, ..., max_seq_len, ...)`` sequence axis and slot ``i`` owns
  row ``i`` for the engine's lifetime.  Bit-identical to the historical
  engine behavior.

* **paged** — block-table-indexed pages (vLLM-style, with hls4ml's
  fixed-stride flavor: ``max_seq_len`` must be a whole number of pages).
  K/V live in a shared pool ``(num_pages, ..., page_size, ...)`` with no
  batch axis; each slot holds a ``page_table`` row of physical page ids.
  Long contexts allocate pages on demand as decode crosses page
  boundaries, and a finished slot returns its pages to the free list
  immediately.  Admission *reserves* each request's worst-case page
  count (prompt + generation budget) up front — allocation stays lazy,
  but decode growth can never exhaust the pool mid-run; when the pool
  cannot cover the queue head's reservation, admission waits FIFO until
  finished slots return pages.  Physical page 0 is a reserved *trash*
  page: unallocated table entries point at it, so masked pad writes land
  there harmlessly and are never read back (reads are masked by position
  validity).

The attention layer does not assume a contiguous sequence axis: it asks
``is_paged(cache)`` and goes through :func:`paged_decode_write` /
:func:`paged_decode_view` (gather/scatter views) when the per-layer
cache is a page pool.  Prefill always fills a *dense* scratch cache
(the model's natural contiguous write), and the engine's jitted prefill
program inserts it through :meth:`CacheManager.insert_prefill`, which
is the only layout-specific step.

Families whose state is not position-addressed (SSM/hybrid state,
rolling sliding-window buffers) cannot be paged; the manager silently
falls back to dense for them, mirroring the engine's exact-length
prefill fallback.

int8 KV policies compose: the per-token scales ride their own pools
``(num_pages, ..., page_size)``, so the precision plan's ``kv_cache``
rule applies per page exactly as it applies per slab in dense layout.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ServeConfig

PyTree = Any

#: cache leaves with a sequence axis: name -> axis index from the right
SEQ_AXIS_FROM_RIGHT = {
    "k": 2, "v": 2, "latent": 2,  # (..., cache_len, feature)
    "k_scale": 1, "v_scale": 1, "latent_scale": 1,  # (..., cache_len)
}

#: pool leaves whose page axis is followed by a head axis (page, head, off, ...)
_HEAD_MAJOR_POOLS = ("k", "v", "k_scale", "v_scale")

#: reserved physical page id: write sink for masked/pad scatters, never read
TRASH_PAGE = 0

LAYOUTS = ("dense", "paged")


# ---------------------------------------------------------------------------
# Per-layer attention cache specs (both layouts)
# ---------------------------------------------------------------------------


def attention_cache_spec(
    cfg: ModelConfig,
    batch: int,
    max_len: int,
    dtype=jnp.bfloat16,
    quantized: bool = False,
    layout: str = "dense",
    page_size: int | None = None,
    num_pages: int | None = None,
) -> dict:
    """Abstract per-layer attention cache (ShapeDtypeStruct); stacked by
    the caller.

    Dense GQA: (B, Hkv, L, D) k/v slabs.  Sliding window: rolling buffer
    of length ``window`` + slot positions.  MLA: packed latent
    (B, L, kv_lora + rope_dim).  quantized=True adds int8 codes +
    per-token f32 scales (the paper's fixed-point datapath applied to
    the KV cache, KIVI-style).

    Paged: k/v (or latent) pools keyed by physical page —
    (num_pages, Hkv, page_size, D) / (num_pages, page_size, width) —
    plus a per-slot ``page_table`` (batch, max_len // page_size) of
    physical page ids.  Scale pools mirror their data pools per page.
    """
    if layout not in LAYOUTS:
        raise ValueError(f"unknown kv layout {layout!r}; use one of {LAYOUTS}")
    if layout == "paged":
        return _paged_attention_cache_spec(
            cfg, batch, max_len, dtype, quantized, page_size, num_pages
        )
    if cfg.attn_kind == "none":
        return {}
    if cfg.attn_kind == "mla":
        m = cfg.mla
        width = m.kv_lora_rank + m.qk_rope_head_dim
        if quantized:
            return {
                "latent": jax.ShapeDtypeStruct(
                    (batch, max_len, width), jnp.int8
                ),
                "latent_scale": jax.ShapeDtypeStruct(
                    (batch, max_len), jnp.float32
                ),
            }
        return {
            "latent": jax.ShapeDtypeStruct((batch, max_len, width), dtype),
        }
    hd = cfg.resolved_head_dim
    length = max_len
    extra = {}
    if cfg.sliding_window is not None and cfg.sliding_window < max_len:
        length = cfg.sliding_window
        extra["slot_pos"] = jax.ShapeDtypeStruct((batch, length), jnp.int32)
    kv_dtype = jnp.int8 if quantized else dtype
    spec = {
        "k": jax.ShapeDtypeStruct((batch, cfg.n_kv_heads, length, hd), kv_dtype),
        "v": jax.ShapeDtypeStruct((batch, cfg.n_kv_heads, length, hd), kv_dtype),
        **extra,
    }
    if quantized:
        spec["k_scale"] = jax.ShapeDtypeStruct(
            (batch, cfg.n_kv_heads, length), jnp.float32
        )
        spec["v_scale"] = jax.ShapeDtypeStruct(
            (batch, cfg.n_kv_heads, length), jnp.float32
        )
    return spec


def _paged_attention_cache_spec(
    cfg, batch, max_len, dtype, quantized, page_size, num_pages
):
    if page_size is None or num_pages is None:
        raise ValueError("paged layout requires page_size and num_pages")
    if max_len % page_size != 0:
        raise ValueError(
            f"paged layout requires max_seq_len ({max_len}) to be a whole "
            f"number of pages (kv_page_size={page_size})"
        )
    if cfg.attn_kind not in ("gqa", "mla") or cfg.family in ("ssm", "hybrid"):
        raise ValueError(
            f"paged layout supports position-addressed GQA/MLA caches only "
            f"(got attn_kind={cfg.attn_kind!r}, family={cfg.family!r})"
        )
    if cfg.sliding_window is not None and cfg.sliding_window < max_len:
        raise ValueError(
            "paged layout does not support rolling sliding-window buffers"
        )
    pages_per_slot = max_len // page_size
    table = jax.ShapeDtypeStruct((batch, pages_per_slot), jnp.int32)
    if cfg.attn_kind == "mla":
        m = cfg.mla
        width = m.kv_lora_rank + m.qk_rope_head_dim
        spec = {
            "latent": jax.ShapeDtypeStruct(
                (num_pages, page_size, width),
                jnp.int8 if quantized else dtype,
            ),
        }
        if quantized:
            spec["latent_scale"] = jax.ShapeDtypeStruct(
                (num_pages, page_size), jnp.float32
            )
    else:
        hd = cfg.resolved_head_dim
        kv_dtype = jnp.int8 if quantized else dtype
        spec = {
            "k": jax.ShapeDtypeStruct(
                (num_pages, cfg.n_kv_heads, page_size, hd), kv_dtype
            ),
            "v": jax.ShapeDtypeStruct(
                (num_pages, cfg.n_kv_heads, page_size, hd), kv_dtype
            ),
        }
        if quantized:
            spec["k_scale"] = jax.ShapeDtypeStruct(
                (num_pages, cfg.n_kv_heads, page_size), jnp.float32
            )
            spec["v_scale"] = jax.ShapeDtypeStruct(
                (num_pages, cfg.n_kv_heads, page_size), jnp.float32
            )
    spec["page_table"] = table
    return spec


def init_attention_cache(
    cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16, **kw
):
    spec = attention_cache_spec(cfg, batch, max_len, dtype, **kw)
    return {k: _zero_leaf(k, v) for k, v in spec.items()}


def _zero_leaf(name: str, s: jax.ShapeDtypeStruct):
    if name == "page_table":
        return jnp.full(s.shape, TRASH_PAGE, jnp.int32)
    if s.dtype == jnp.int32:
        return jnp.full(s.shape, -1, jnp.int32)  # invalid slot marker
    return jnp.zeros(s.shape, s.dtype)


# ---------------------------------------------------------------------------
# Stacked model-level caches (moved from models/lm.py)
# ---------------------------------------------------------------------------


def _per_layer_cache_spec(cfg, batch, max_len, dtype, quantized, **layout_kw):
    if cfg.family in ("ssm", "hybrid"):
        from repro.models import ssm  # runtime import: no module cycle

        return ssm.mamba_cache_spec(cfg, batch, jnp.float32)
    return attention_cache_spec(
        cfg, batch, max_len, dtype, quantized=quantized, **layout_kw
    )


def abstract_caches(
    cfg: ModelConfig,
    batch: int,
    max_len: int,
    dtype=jnp.bfloat16,
    quantized: bool = False,
    layout: str = "dense",
    page_size: int | None = None,
    num_pages: int | None = None,
) -> PyTree:
    """Stacked (leading layer axis) abstract caches for a whole model."""
    layout_kw = dict(layout=layout, page_size=page_size, num_pages=num_pages)
    per_layer = _per_layer_cache_spec(
        cfg, batch, max_len, dtype, quantized, **layout_kw
    )
    stacked = {
        k: jax.ShapeDtypeStruct((cfg.n_layers,) + v.shape, v.dtype)
        for k, v in per_layer.items()
    }
    caches: dict = {"layers": stacked}
    if cfg.family == "hybrid":
        # runtime imports: no module cycle (attention -> kv_cache)
        from repro.models import blocks, lm

        shared = blocks.shared_attn_cache_spec(cfg, batch, max_len, dtype)
        n_apps = lm.n_shared_apps(cfg)
        caches["shared"] = {
            k: jax.ShapeDtypeStruct((n_apps,) + v.shape, v.dtype)
            for k, v in shared.items()
        }
    return caches


def init_caches(
    cfg: ModelConfig,
    batch: int,
    max_len: int,
    dtype=jnp.bfloat16,
    quantized: bool = False,
    **layout_kw,
) -> PyTree:
    spec = abstract_caches(cfg, batch, max_len, dtype, quantized, **layout_kw)

    def _walk(node):
        if isinstance(node, dict):
            return {k: _walk_named(k, v) for k, v in node.items()}
        return node

    def _walk_named(name, node):
        if isinstance(node, dict):
            return {k: _walk_named(k, v) for k, v in node.items()}
        return _zero_leaf(name, node)

    return _walk(spec)


def cache_logical_axes(
    cfg: ModelConfig, quantized: bool = False, layout: str = "dense"
) -> PyTree:
    """Logical axes for cache sharding (distributed/sharding.py).

    Paged pools have no batch axis — they shard over heads (TP) with the
    page axis replicated; the tiny page table shards over batch.
    """
    if layout == "paged":
        if cfg.attn_kind == "mla":
            per_layer = {"latent": ("layers", None, None, None)}
            if quantized:
                per_layer["latent_scale"] = ("layers", None, None)
        else:
            per_layer = {
                "k": ("layers", None, "kv_heads", None, None),
                "v": ("layers", None, "kv_heads", None, None),
            }
            if quantized:
                per_layer["k_scale"] = ("layers", None, "kv_heads", None)
                per_layer["v_scale"] = ("layers", None, "kv_heads", None)
        per_layer["page_table"] = ("layers", "batch", None)
        return {"layers": per_layer}
    kind = "mamba" if cfg.family in ("ssm", "hybrid") else cfg.attn_kind
    if kind == "mamba":
        per_layer = {
            "ssm_state": ("layers", "batch", "ssm_heads", None, None),
            "conv_state": ("layers", "batch", None, "inner"),
        }
    elif kind == "mla":
        per_layer = {"latent": ("layers", "batch", "cache_len", None)}
        if quantized:
            per_layer["latent_scale"] = ("layers", "batch", "cache_len")
    else:
        per_layer = {
            "k": ("layers", "batch", "kv_heads", "cache_len", None),
            "v": ("layers", "batch", "kv_heads", "cache_len", None),
        }
        if cfg.sliding_window is not None:
            per_layer["slot_pos"] = ("layers", "batch", None)
        if quantized:
            per_layer["k_scale"] = ("layers", "batch", "kv_heads", "cache_len")
            per_layer["v_scale"] = ("layers", "batch", "kv_heads", "cache_len")
    axes: dict = {"layers": per_layer}
    if cfg.family == "hybrid":
        axes["shared"] = {
            "k": ("layers", "batch", "kv_heads", "cache_len", None),
            "v": ("layers", "batch", "kv_heads", "cache_len", None),
        }
    return axes


# ---------------------------------------------------------------------------
# Traced helpers: paged decode read/write views (used by models/attention.py)
# ---------------------------------------------------------------------------


def is_paged(cache: dict | None) -> bool:
    """A per-layer cache dict is paged iff it carries a page table."""
    return cache is not None and "page_table" in cache


def _pool_page_size(name: str, pool: jax.Array) -> int:
    return pool.shape[2] if name in _HEAD_MAJOR_POOLS else pool.shape[1]


def paged_decode_write(
    cache: dict, updates: dict[str, jax.Array], positions: jax.Array
) -> dict:
    """Scatter one token per slot into its physical page.

    ``updates``: leaf name -> per-slot values with the seq axis removed
    (k/v: (B, Hkv, D); scales: (B, Hkv); latent: (B, width);
    latent_scale: (B,)).  ``positions``: (B,) global write positions.
    Retired slots have all-trash page tables, so their (frozen) writes
    land in the reserved trash page and never alias live data.
    """
    table = cache["page_table"]  # (B, pages_per_slot)
    out = dict(cache)
    for name, val in updates.items():
        pool = cache[name]
        ps = _pool_page_size(name, pool)
        phys = jnp.take_along_axis(
            table, (positions // ps)[:, None], axis=1
        )[:, 0]  # (B,)
        off = positions % ps
        if name in _HEAD_MAJOR_POOLS:
            out[name] = pool.at[phys, :, off].set(val.astype(pool.dtype))
        else:
            out[name] = pool.at[phys, off].set(val.astype(pool.dtype))
    return out


def paged_decode_view(cache: dict) -> dict[str, jax.Array]:
    """Gather each slot's pages into a contiguous logical view.

    Returns dense-shaped arrays — k/v: (B, Hkv, L, D); scales:
    (B, Hkv, L); latent: (B, L, width); latent_scale: (B, L) — where
    ``L = pages_per_slot * page_size == max_seq_len``, so downstream
    attention math is bit-identical to the dense layout (unallocated
    entries read the trash page and are masked by position validity,
    exactly like dense positions beyond the write head).
    """
    table = cache["page_table"]  # (B, pages_per_slot)
    out = {}
    for name, pool in cache.items():
        if name == "page_table":
            continue
        g = pool[table]  # (B, n_pages, ...)
        if name in _HEAD_MAJOR_POOLS:
            g = jnp.moveaxis(g, 2, 1)  # (B, Hkv, n_pages, ps[, D])
            shape = g.shape[:2] + (g.shape[2] * g.shape[3],) + g.shape[4:]
        else:
            shape = (g.shape[0], g.shape[1] * g.shape[2]) + g.shape[3:]
        out[name] = g.reshape(shape)
    return out


# ---------------------------------------------------------------------------
# Traced helpers: prefill masking + layout-specific slot insertion
# ---------------------------------------------------------------------------


def mask_cache_tail(filled: PyTree, lengths: jax.Array) -> PyTree:
    """Zero cache entries at positions >= the per-row prompt length.

    ``filled``: stacked dense caches with batch axis 1 on every leaf.
    ``lengths``: (N,) true prompt lengths (traced, so every same-bucket
    batch reuses one compiled program).  Leaves without a sequence axis
    (SSM state, slot_pos) pass through; those families use exact-length
    prefill anyway, where the mask is all-true.
    """

    def _mask_group(group):
        out = {}
        for name, leaf in group.items():
            axis_r = SEQ_AXIS_FROM_RIGHT.get(name)
            if axis_r is None:
                out[name] = leaf
                continue
            axis = leaf.ndim - axis_r
            seq = jnp.arange(leaf.shape[axis])
            seq_b = seq.reshape(
                (1,) * axis + (-1,) + (1,) * (leaf.ndim - axis - 1)
            )
            len_b = lengths.reshape((1, -1) + (1,) * (leaf.ndim - 2))
            out[name] = jnp.where(
                seq_b < len_b, leaf, jnp.zeros((), leaf.dtype)
            )
        return out

    return {k: _mask_group(v) for k, v in filled.items()}


def insert_prefill_dense(big: PyTree, filled: PyTree, slots: jax.Array):
    """Scatter freshly prefilled rows into their slots (batch axis 1 on
    every stacked leaf).  Rows whose slot index is out of range (the
    engine's padding sentinel) are dropped."""

    def ins(b, f):
        return b.at[:, slots].set(f.astype(b.dtype), mode="drop")

    return jax.tree.map(ins, big, filled)


def insert_prefill_paged(
    big: PyTree, filled: PyTree, slots: jax.Array, page_size: int
):
    """Scatter dense prefilled rows into each slot's physical pages.

    ``filled`` is the dense scratch cache the model wrote (tail-masked);
    it may be shorter than the full logical range — the engine sizes it
    to the prefill bucket rounded up to whole pages.  Its page view is
    scattered through the leading columns of the slots' page-table rows;
    later logical pages stay untouched (any stale tenant data there is
    masked by position validity until decode overwrites each position as
    it becomes valid).  Unallocated table entries — the pad tail beyond
    a prompt's allocated pages, and entire rows for padding slots —
    point at the trash page, so those writes are inert.
    """
    layers = dict(big["layers"])
    table = layers["page_table"][0]  # identical across layers: (B, n_pages)
    row_tables = jnp.take(
        table, slots, axis=0, mode="fill", fill_value=TRASH_PAGE
    )  # (N, pages_per_slot)
    for name, small in filled["layers"].items():
        pool = layers[name]
        axis = small.ndim - SEQ_AXIS_FROM_RIGHT[name]
        n_pages = small.shape[axis] // page_size
        paged_shape = (
            small.shape[:axis] + (n_pages, page_size) + small.shape[axis + 1:]
        )
        pages = jnp.moveaxis(small.reshape(paged_shape), axis, 2)
        # pool (L, P, ...), indices (N, n_pages) on axis 1
        layers[name] = pool.at[:, row_tables[:, :n_pages]].set(
            pages.astype(pool.dtype)
        )
    return {"layers": layers}


# ---------------------------------------------------------------------------
# Host-side manager
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class CacheStats:
    layout: str
    kv_bytes: int
    page_size: int
    pages_in_use: int
    pages_capacity: int
    page_allocs_total: int
    pages_in_use_peak: int

    @property
    def page_utilization(self) -> float:
        return self.pages_in_use / max(self.pages_capacity, 1)

    def as_dict(self) -> dict:
        return {
            "kv_layout": self.layout,
            "kv_bytes": self.kv_bytes,
            "kv_page_size": self.page_size,
            "pages_in_use": self.pages_in_use,
            "pages_capacity": self.pages_capacity,
            "page_utilization": self.page_utilization,
            "page_allocs_total": self.page_allocs_total,
            "pages_in_use_peak": self.pages_in_use_peak,
        }


class CacheManager:
    """Owns the KV-cache storage layout for one serving engine.

    Host-side responsibilities: building the device cache pytree,
    page allocation / reclamation per slot (paged layout), and keeping
    the device page table in sync.  Traced responsibility: inserting a
    prefilled dense slab into the big caches inside the engine's jitted
    prefill program (:meth:`insert_prefill` — static layout config only,
    so it adds no jit programs).

    Dense layout is modeled as one page of ``max_seq_len`` tokens per
    slot, statically bound to the slot — which makes the occupancy
    telemetry uniform across layouts.
    """

    def __init__(
        self,
        cfg: ModelConfig,
        serve_cfg: ServeConfig,
        quantized: bool = False,
        dtype=jnp.float32,
    ):
        self.cfg = cfg
        self.serve_cfg = serve_cfg
        self.quantized = quantized
        self.dtype = dtype
        sc = serve_cfg
        rolling = (
            cfg.sliding_window is not None
            and cfg.sliding_window < sc.max_seq_len
        )
        #: position-addressed caches can be right-padded (bucketed
        #: prefill) and paged; SSM/hybrid state and rolling buffers can't
        self.position_addressed = (
            cfg.attn_kind in ("gqa", "mla")
            and cfg.family not in ("ssm", "hybrid")
            and not rolling
        )
        requested = sc.kv_layout
        if requested not in LAYOUTS:
            raise ValueError(
                f"unknown kv_layout {requested!r}; use one of {LAYOUTS}"
            )
        self.layout = (
            "paged"
            if requested == "paged" and self.position_addressed
            else "dense"
        )
        if self.layout == "paged":
            ps = sc.kv_page_size
            if ps < 1 or sc.max_seq_len % ps != 0:
                raise ValueError(
                    f"kv_page_size={ps} must divide max_seq_len="
                    f"{sc.max_seq_len} (fixed-stride pages)"
                )
            self.page_size = ps
            self.pages_per_slot = sc.max_seq_len // ps
            auto = sc.max_batch * self.pages_per_slot + 1  # +1 trash page
            self.num_pages = auto if sc.kv_pages is None else sc.kv_pages
            if self.num_pages < 2:
                raise ValueError("kv_pages must be >= 2 (one is the trash page)")
            # page 0 is the reserved trash page; pop() allocates ascending
            self._free = list(range(self.num_pages - 1, 0, -1))
        else:
            # dense: one slot-bound "page" of max_seq_len tokens
            self.page_size = sc.max_seq_len
            self.pages_per_slot = 1
            self.num_pages = sc.max_batch
            self._free = []
        self._slot_pages: list[list[int]] = [[] for _ in range(sc.max_batch)]
        # worst-case pages promised to each resident request at admission;
        # allocation stays lazy, but admission never over-promises the pool
        self._slot_reserved: list[int] = [0] * sc.max_batch
        self._table = np.zeros(
            (sc.max_batch, self.pages_per_slot), np.int32
        )
        self._table_dirty = True
        self._allocs_total = 0
        self._peak_in_use = 0
        self.kv_bytes = sum(
            int(np.prod(leaf.shape)) * leaf.dtype.itemsize
            for leaf in jax.tree.leaves(self._abstract())
        )

    # ----------------------------------------------------------- layout --
    def _layout_kw(self) -> dict:
        if self.layout == "paged":
            return dict(
                layout="paged",
                page_size=self.page_size,
                num_pages=self.num_pages,
            )
        return dict(layout="dense")

    def _abstract(self) -> PyTree:
        return abstract_caches(
            self.cfg, self.serve_cfg.max_batch, self.serve_cfg.max_seq_len,
            dtype=self.dtype, quantized=self.quantized, **self._layout_kw(),
        )

    def init_device_caches(self) -> PyTree:
        return init_caches(
            self.cfg, self.serve_cfg.max_batch, self.serve_cfg.max_seq_len,
            dtype=self.dtype, quantized=self.quantized, **self._layout_kw(),
        )

    # ------------------------------------------------------- allocation --
    def pages_for(self, length: int) -> int:
        """Pages needed to hold ``length`` tokens (at least one)."""
        return max(1, -(-length // self.page_size))

    @property
    def pages_reserved_unallocated(self) -> int:
        """Reserved-but-not-yet-allocated pages (promised decode headroom)."""
        return sum(
            max(r - len(p), 0)
            for r, p in zip(self._slot_reserved, self._slot_pages)
        )

    def can_reserve(self, n_pages: int) -> bool:
        """Whether the pool can promise ``n_pages`` to a new request without
        eating another resident request's unallocated reservation."""
        if self.layout != "paged":
            return True  # dense slabs are slot-bound; engine gates on slots
        return len(self._free) - self.pages_reserved_unallocated >= n_pages

    def admit(self, slot: int, prompt_len: int, reserve_len: int) -> None:
        """Admit a request: reserve worst-case pages for its whole lifetime
        (``reserve_len`` = prompt + generation budget, capped at
        max_seq_len), then allocate the prompt's pages.  Reservation is a
        counter, not an allocation — pages still materialize lazily in
        :meth:`ensure` — but admission-time reservation guarantees decode
        growth can never exhaust the pool mid-run."""
        need = self.pages_for(min(reserve_len, self.serve_cfg.max_seq_len))
        if self.layout == "paged":
            if not self.can_reserve(need):
                raise RuntimeError(
                    f"cannot reserve {need} KV pages for admission; check "
                    "can_reserve() before calling admit()"
                )
            self._slot_reserved[slot] = need
        self.alloc(slot, prompt_len)

    def alloc(self, slot: int, length: int) -> None:
        """Ensure ``slot`` owns pages covering positions [0, length)."""
        self.ensure(slot, length)

    def ensure(self, slot: int, upto_len: int) -> None:
        """Grow ``slot``'s page list to cover ``upto_len`` positions —
        called before each decode dispatch so mid-scan writes never cross
        into unallocated space.  Under the engine's admission discipline
        (reservation at admit()), the pool-exhausted error below is
        unreachable; it guards direct misuse of the manager."""
        if self.layout != "paged":
            if not self._slot_pages[slot]:
                self._slot_pages[slot] = [slot]
                self._allocs_total += 1
                self._peak_in_use = max(self._peak_in_use, self.pages_in_use)
            return
        pages = self._slot_pages[slot]
        need = self.pages_for(upto_len)
        while len(pages) < need:
            if not self._free:
                raise RuntimeError(
                    f"KV page pool exhausted ({self.num_pages} pages of "
                    f"{self.page_size} tokens); raise ServeConfig.kv_pages "
                    "or admit fewer concurrent long sequences"
                )
            page = self._free.pop()
            self._table[slot, len(pages)] = page
            pages.append(page)
            self._allocs_total += 1
            self._table_dirty = True
        self._peak_in_use = max(self._peak_in_use, self.pages_in_use)

    def free(self, slot: int) -> None:
        """Return a finished slot's pages (and reservation) immediately."""
        pages = self._slot_pages[slot]
        self._slot_pages[slot] = []
        self._slot_reserved[slot] = 0
        if self.layout != "paged" or not pages:
            return
        self._free.extend(reversed(pages))
        self._table[slot, :] = TRASH_PAGE
        self._table_dirty = True

    # ------------------------------------------------------ device sync --
    def write_table(self, caches: PyTree) -> PyTree:
        """Refresh the stacked device page table from the host table
        (no-op for dense or when nothing changed since the last sync)."""
        if self.layout != "paged" or not self._table_dirty:
            return caches
        table = jnp.asarray(self._table)
        stacked = jnp.broadcast_to(
            table[None], (self.cfg.n_layers,) + table.shape
        )
        layers = dict(caches["layers"])
        layers["page_table"] = stacked
        self._table_dirty = False
        return {**caches, "layers": layers}

    # --------------------------------------------------- traced insert --
    def insert_prefill(
        self, big: PyTree, filled: PyTree, slots: jax.Array
    ) -> PyTree:
        """Insert tail-masked dense prefill rows into the big caches
        (traced inside the engine's per-bucket jitted prefill)."""
        if self.layout == "paged":
            return insert_prefill_paged(big, filled, slots, self.page_size)
        return insert_prefill_dense(big, filled, slots)

    # ---------------------------------------------------------- metrics --
    @property
    def pages_in_use(self) -> int:
        return sum(len(p) for p in self._slot_pages)

    @property
    def pages_capacity(self) -> int:
        if self.layout == "paged":
            return self.num_pages - 1  # trash page is not allocatable
        return self.serve_cfg.max_batch

    def stats(self) -> CacheStats:
        return CacheStats(
            layout=self.layout,
            kv_bytes=self.kv_bytes,
            page_size=self.page_size,
            pages_in_use=self.pages_in_use,
            pages_capacity=self.pages_capacity,
            page_allocs_total=self._allocs_total,
            pages_in_use_peak=self._peak_in_use,
        )

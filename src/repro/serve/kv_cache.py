"""KV-cache subsystem: one CacheManager, two storage layouts (dense, paged).

The paper's sub-2us datapath works because the memory layout is decided
once, ahead of time, and every pipeline stage addresses it with fixed
strides.  This module gives the serving engine the same discipline at
datacenter scale: all KV-cache *layout* knowledge — which leaves have a
sequence axis, how a prefilled slab is inserted into a slot, how decode
reads and writes one token — lives here, behind a small set of traced
helpers plus a host-side :class:`CacheManager`.

Two layouts share one interface:

* **dense** — the classic per-slot slab: every cache leaf carries a
  ``(batch, ..., max_seq_len, ...)`` sequence axis and slot ``i`` owns
  row ``i`` for the engine's lifetime.  Bit-identical to the historical
  engine behavior.

* **paged** — block-table-indexed pages (vLLM-style, with hls4ml's
  fixed-stride flavor: ``max_seq_len`` must be a whole number of pages).
  K/V live in a shared pool ``(num_pages, ..., page_size, ...)`` with no
  batch axis; each slot holds a ``page_table`` row of physical page ids.
  Long contexts allocate pages on demand as decode crosses page
  boundaries, and a finished slot returns its pages to the free list
  immediately.  Admission *reserves* each request's worst-case page
  count (prompt + generation budget) up front — allocation stays lazy,
  but decode growth can never exhaust the pool mid-run; when the pool
  cannot cover the queue head's reservation, admission waits FIFO until
  finished slots return pages.  Physical page 0 is a reserved *trash*
  page: unallocated table entries point at it, so masked pad writes land
  there harmlessly and are never read back (reads are masked by position
  validity).

The attention layer does not assume a contiguous sequence axis: it asks
``is_paged(cache)`` and goes through :func:`paged_decode_write` /
:func:`paged_decode_view` (gather/scatter views) when the per-layer
cache is a page pool.  Prefill always fills a *dense* scratch cache
(the model's natural contiguous write), and the engine's jitted prefill
program inserts it through :meth:`CacheManager.insert_prefill`, which
is the only layout-specific step.

Families whose state is not position-addressed (SSM/hybrid state,
rolling sliding-window buffers) cannot be paged; the manager silently
falls back to dense for them, mirroring the engine's exact-length
prefill fallback.

int8 KV policies compose: the per-token scales ride their own pools
``(num_pages, ..., page_size)``, so the precision plan's ``kv_cache``
rule applies per page exactly as it applies per slab in dense layout.

**Prefix-cache page sharing** (``ServeConfig.kv_prefix_cache``, paged
layout): every *full* prompt page is registered in a prefix index under a
hash chain key — ``key_i = intern(key_{i-1}, tokens[i*ps:(i+1)*ps])`` —
so a page is only ever matched when its entire causal token prefix is
identical (keys are interned exact token tuples, never lossy hashes).
A same-prefix admission maps its leading block-table entries to the
matched pages and bumps their refcounts; only the unshared tail needs
pages (and, on the bit-exact float-GQA datapath, compute).  When a
request finishes, its refcount-0 registered pages are *retained* on an
evictable LRU instead of being wiped, so repeated-prompt workloads (the
same detector-geometry preamble across a physics batch) keep hitting
after the first tenant completes; allocation evicts the LRU tail only
under pool pressure.  A decode write aimed at a page with refcount > 1
triggers copy-on-write — allocate a fresh page, copy the pool rows,
swap the writer's table entry — and a write into a registered
refcount-1 page first drops the page from the index, so shared history
is immutable and every token stream stays bit-identical to the dense
layout.  Sharing, CoW bookkeeping, and preemption are host-side
block-table operations: the jitted program set does not grow.

**Host-memory victim tier** (``ServeConfig.kv_host_pages``): a fourth
page state behind the cached LRU.  A registered page evicted under pool
pressure spills its pool rows (every pool leaf — k/v, int8 scales, MLA
latents) into a host-side numpy ring of ``kv_host_pages`` pages instead
of discarding them, keeping its prefix-index chain key alive in a
host-tier index.  ``match_prefix`` walks past device coverage into that
index; admission then allocates fresh device pages for the host-covered
chunks and queues batched host->device row copies, applied by
:meth:`CacheManager.flush_swaps` at the executor's next dispatch
(exactly like CoW copies through :meth:`CacheManager.flush_copies`) —
so a warm prefix larger than the device pool admits as a normal prefix
hit with prefill-skip instead of recomputing.  All tier movement is
host bookkeeping plus eager device copies outside every jitted program:
the compiled program budget stays len(prefill_buckets) + 2.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ServeConfig

PyTree = Any

#: cache leaves with a sequence axis: name -> axis index from the right
SEQ_AXIS_FROM_RIGHT = {
    "k": 2, "v": 2, "latent": 2,  # (..., cache_len, feature)
    "k_scale": 1, "v_scale": 1, "latent_scale": 1,  # (..., cache_len)
}

#: pool leaves whose page axis is followed by a head axis (page, head, off, ...)
_HEAD_MAJOR_POOLS = ("k", "v", "k_scale", "v_scale")

#: reserved physical page id: write sink for masked/pad scatters, never read
TRASH_PAGE = 0

LAYOUTS = ("dense", "paged")


# ---------------------------------------------------------------------------
# Per-layer attention cache specs (both layouts)
# ---------------------------------------------------------------------------


def attention_cache_spec(
    cfg: ModelConfig,
    batch: int,
    max_len: int,
    dtype=jnp.bfloat16,
    quantized: bool = False,
    layout: str = "dense",
    page_size: int | None = None,
    num_pages: int | None = None,
) -> dict:
    """Abstract per-layer attention cache (ShapeDtypeStruct); stacked by
    the caller.

    Dense GQA: (B, Hkv, L, D) k/v slabs.  Sliding window: rolling buffer
    of length ``window`` + slot positions.  MLA: packed latent
    (B, L, kv_lora + rope_dim).  quantized=True adds int8 codes +
    per-token f32 scales (the paper's fixed-point datapath applied to
    the KV cache, KIVI-style).

    Paged: k/v (or latent) pools keyed by physical page —
    (num_pages, Hkv, page_size, D) / (num_pages, page_size, width) —
    plus a per-slot ``page_table`` (batch, max_len // page_size) of
    physical page ids.  Scale pools mirror their data pools per page.
    """
    if layout not in LAYOUTS:
        raise ValueError(f"unknown kv layout {layout!r}; use one of {LAYOUTS}")
    if layout == "paged":
        return _paged_attention_cache_spec(
            cfg, batch, max_len, dtype, quantized, page_size, num_pages
        )
    if cfg.attn_kind == "none":
        return {}
    if cfg.attn_kind == "mla":
        m = cfg.mla
        width = m.kv_lora_rank + m.qk_rope_head_dim
        if quantized:
            return {
                "latent": jax.ShapeDtypeStruct(
                    (batch, max_len, width), jnp.int8
                ),
                "latent_scale": jax.ShapeDtypeStruct(
                    (batch, max_len), jnp.float32
                ),
            }
        return {
            "latent": jax.ShapeDtypeStruct((batch, max_len, width), dtype),
        }
    hd = cfg.resolved_head_dim
    length = max_len
    extra = {}
    if cfg.sliding_window is not None and cfg.sliding_window < max_len:
        length = cfg.sliding_window
        extra["slot_pos"] = jax.ShapeDtypeStruct((batch, length), jnp.int32)
    kv_dtype = jnp.int8 if quantized else dtype
    spec = {
        "k": jax.ShapeDtypeStruct((batch, cfg.n_kv_heads, length, hd), kv_dtype),
        "v": jax.ShapeDtypeStruct((batch, cfg.n_kv_heads, length, hd), kv_dtype),
        **extra,
    }
    if quantized:
        spec["k_scale"] = jax.ShapeDtypeStruct(
            (batch, cfg.n_kv_heads, length), jnp.float32
        )
        spec["v_scale"] = jax.ShapeDtypeStruct(
            (batch, cfg.n_kv_heads, length), jnp.float32
        )
    return spec


def _paged_attention_cache_spec(
    cfg, batch, max_len, dtype, quantized, page_size, num_pages
):
    if page_size is None or num_pages is None:
        raise ValueError("paged layout requires page_size and num_pages")
    if max_len % page_size != 0:
        raise ValueError(
            f"paged layout requires max_seq_len ({max_len}) to be a whole "
            f"number of pages (kv_page_size={page_size})"
        )
    if cfg.attn_kind not in ("gqa", "mla") or cfg.family in ("ssm", "hybrid"):
        raise ValueError(
            f"paged layout supports position-addressed GQA/MLA caches only "
            f"(got attn_kind={cfg.attn_kind!r}, family={cfg.family!r})"
        )
    if cfg.sliding_window is not None and cfg.sliding_window < max_len:
        raise ValueError(
            "paged layout does not support rolling sliding-window buffers"
        )
    pages_per_slot = max_len // page_size
    table = jax.ShapeDtypeStruct((batch, pages_per_slot), jnp.int32)
    if cfg.attn_kind == "mla":
        m = cfg.mla
        width = m.kv_lora_rank + m.qk_rope_head_dim
        spec = {
            "latent": jax.ShapeDtypeStruct(
                (num_pages, page_size, width),
                jnp.int8 if quantized else dtype,
            ),
        }
        if quantized:
            spec["latent_scale"] = jax.ShapeDtypeStruct(
                (num_pages, page_size), jnp.float32
            )
    else:
        hd = cfg.resolved_head_dim
        kv_dtype = jnp.int8 if quantized else dtype
        spec = {
            "k": jax.ShapeDtypeStruct(
                (num_pages, cfg.n_kv_heads, page_size, hd), kv_dtype
            ),
            "v": jax.ShapeDtypeStruct(
                (num_pages, cfg.n_kv_heads, page_size, hd), kv_dtype
            ),
        }
        if quantized:
            spec["k_scale"] = jax.ShapeDtypeStruct(
                (num_pages, cfg.n_kv_heads, page_size), jnp.float32
            )
            spec["v_scale"] = jax.ShapeDtypeStruct(
                (num_pages, cfg.n_kv_heads, page_size), jnp.float32
            )
    spec["page_table"] = table
    return spec


def init_attention_cache(
    cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16, **kw
):
    spec = attention_cache_spec(cfg, batch, max_len, dtype, **kw)
    return {k: _zero_leaf(k, v) for k, v in spec.items()}


def _zero_leaf(name: str, s: jax.ShapeDtypeStruct):
    if name == "page_table":
        return jnp.full(s.shape, TRASH_PAGE, jnp.int32)
    if s.dtype == jnp.int32:
        return jnp.full(s.shape, -1, jnp.int32)  # invalid slot marker
    return jnp.zeros(s.shape, s.dtype)


# ---------------------------------------------------------------------------
# Stacked model-level caches (moved from models/lm.py)
# ---------------------------------------------------------------------------


def _per_layer_cache_spec(cfg, batch, max_len, dtype, quantized, **layout_kw):
    if cfg.family in ("ssm", "hybrid"):
        from repro.models import ssm  # runtime import: no module cycle

        return ssm.mamba_cache_spec(cfg, batch, jnp.float32)
    return attention_cache_spec(
        cfg, batch, max_len, dtype, quantized=quantized, **layout_kw
    )


def abstract_caches(
    cfg: ModelConfig,
    batch: int,
    max_len: int,
    dtype=jnp.bfloat16,
    quantized: bool = False,
    layout: str = "dense",
    page_size: int | None = None,
    num_pages: int | None = None,
) -> PyTree:
    """Stacked (leading layer axis) abstract caches for a whole model."""
    layout_kw = dict(layout=layout, page_size=page_size, num_pages=num_pages)
    per_layer = _per_layer_cache_spec(
        cfg, batch, max_len, dtype, quantized, **layout_kw
    )
    stacked = {
        k: jax.ShapeDtypeStruct((cfg.n_layers,) + v.shape, v.dtype)
        for k, v in per_layer.items()
    }
    caches: dict = {"layers": stacked}
    if cfg.family == "hybrid":
        # runtime imports: no module cycle (attention -> kv_cache)
        from repro.models import blocks, lm

        shared = blocks.shared_attn_cache_spec(cfg, batch, max_len, dtype)
        n_apps = lm.n_shared_apps(cfg)
        caches["shared"] = {
            k: jax.ShapeDtypeStruct((n_apps,) + v.shape, v.dtype)
            for k, v in shared.items()
        }
    return caches


def init_caches(
    cfg: ModelConfig,
    batch: int,
    max_len: int,
    dtype=jnp.bfloat16,
    quantized: bool = False,
    **layout_kw,
) -> PyTree:
    spec = abstract_caches(cfg, batch, max_len, dtype, quantized, **layout_kw)

    def _walk(node):
        if isinstance(node, dict):
            return {k: _walk_named(k, v) for k, v in node.items()}
        return node

    def _walk_named(name, node):
        if isinstance(node, dict):
            return {k: _walk_named(k, v) for k, v in node.items()}
        return _zero_leaf(name, node)

    return _walk(spec)


def cache_logical_axes(
    cfg: ModelConfig, quantized: bool = False, layout: str = "dense"
) -> PyTree:
    """Logical axes for cache sharding (distributed/sharding.py).

    Paged pools have no batch axis — they shard over heads (TP) with the
    page axis replicated; the tiny page table shards over batch.
    """
    if layout == "paged":
        if cfg.attn_kind == "mla":
            per_layer = {"latent": ("layers", None, None, None)}
            if quantized:
                per_layer["latent_scale"] = ("layers", None, None)
        else:
            per_layer = {
                "k": ("layers", None, "kv_heads", None, None),
                "v": ("layers", None, "kv_heads", None, None),
            }
            if quantized:
                per_layer["k_scale"] = ("layers", None, "kv_heads", None)
                per_layer["v_scale"] = ("layers", None, "kv_heads", None)
        per_layer["page_table"] = ("layers", "batch", None)
        return {"layers": per_layer}
    kind = "mamba" if cfg.family in ("ssm", "hybrid") else cfg.attn_kind
    if kind == "mamba":
        per_layer = {
            "ssm_state": ("layers", "batch", "ssm_heads", None, None),
            "conv_state": ("layers", "batch", None, "inner"),
        }
    elif kind == "mla":
        per_layer = {"latent": ("layers", "batch", "cache_len", None)}
        if quantized:
            per_layer["latent_scale"] = ("layers", "batch", "cache_len")
    else:
        per_layer = {
            "k": ("layers", "batch", "kv_heads", "cache_len", None),
            "v": ("layers", "batch", "kv_heads", "cache_len", None),
        }
        if cfg.sliding_window is not None:
            per_layer["slot_pos"] = ("layers", "batch", None)
        if quantized:
            per_layer["k_scale"] = ("layers", "batch", "kv_heads", "cache_len")
            per_layer["v_scale"] = ("layers", "batch", "kv_heads", "cache_len")
    axes: dict = {"layers": per_layer}
    if cfg.family == "hybrid":
        axes["shared"] = {
            "k": ("layers", "batch", "kv_heads", "cache_len", None),
            "v": ("layers", "batch", "kv_heads", "cache_len", None),
        }
    return axes


# ---------------------------------------------------------------------------
# Traced helpers: paged decode read/write views (used by models/attention.py)
# ---------------------------------------------------------------------------


def is_paged(cache: dict | None) -> bool:
    """A per-layer cache dict is paged iff it carries a page table."""
    return cache is not None and "page_table" in cache


def _pool_page_size(name: str, pool: jax.Array) -> int:
    return pool.shape[2] if name in _HEAD_MAJOR_POOLS else pool.shape[1]


def paged_decode_write(
    cache: dict, updates: dict[str, jax.Array], positions: jax.Array
) -> dict:
    """Scatter one token per slot into its physical page.

    ``updates``: leaf name -> per-slot values with the seq axis removed
    (k/v: (B, Hkv, D); scales: (B, Hkv); latent: (B, width);
    latent_scale: (B,)).  ``positions``: (B,) global write positions.
    Retired slots have all-trash page tables, so their (frozen) writes
    land in the reserved trash page and never alias live data.
    """
    table = cache["page_table"]  # (B, pages_per_slot)
    out = dict(cache)
    for name, val in updates.items():
        pool = cache[name]
        ps = _pool_page_size(name, pool)
        phys = jnp.take_along_axis(
            table, (positions // ps)[:, None], axis=1
        )[:, 0]  # (B,)
        off = positions % ps
        if name in _HEAD_MAJOR_POOLS:
            out[name] = pool.at[phys, :, off].set(val.astype(pool.dtype))
        else:
            out[name] = pool.at[phys, off].set(val.astype(pool.dtype))
    return out


def dense_window_write(
    cache: dict, updates: dict[str, jax.Array], positions: jax.Array
) -> dict:
    """Scatter a token *window* per slot into a dense per-layer cache.

    The cache-extending prefill program's write primitive: where
    :func:`paged_decode_write` lands one token per slot,
    this lands a contiguous window of ``W`` tokens at arbitrary
    per-row offsets.  ``updates``: leaf name -> per-slot windows
    (k/v: (B, Hkv, W, D); scales: (B, Hkv, W); latent: (B, W, width);
    latent_scale: (B, W)).  ``positions``: (B, W) global write
    positions; masked entries carry an out-of-range sentinel
    (>= cache length) and are dropped by the scatter.
    """
    out = dict(cache)
    b = positions.shape[0]
    for name, val in updates.items():
        buf = cache[name]
        if name in _HEAD_MAJOR_POOLS:
            bi = jnp.arange(b)[:, None, None]
            hi = jnp.arange(buf.shape[1])[None, :, None]
            out[name] = buf.at[bi, hi, positions[:, None, :]].set(
                val.astype(buf.dtype), mode="drop"
            )
        else:
            out[name] = buf.at[jnp.arange(b)[:, None], positions].set(
                val.astype(buf.dtype), mode="drop"
            )
    return out


def paged_window_write(
    cache: dict, updates: dict[str, jax.Array], positions: jax.Array
) -> dict:
    """Scatter a token window per slot into physical pages.

    Same update shapes and (B, W) ``positions`` contract as
    :func:`dense_window_write`.  Each position routes through the page
    table independently, so a window may straddle page boundaries.
    Sentinel positions index past the table and are routed to the
    reserved trash page (same write-sink convention as retired slots in
    :func:`paged_decode_write`), so masked entries never alias live
    data.
    """
    table = cache["page_table"]  # (B, pages_per_slot)
    out = dict(cache)
    for name, val in updates.items():
        pool = cache[name]
        ps = _pool_page_size(name, pool)
        phys = jnp.take_along_axis(
            table, positions // ps, axis=1,
            mode="fill", fill_value=TRASH_PAGE,
        )  # (B, W)
        off = positions % ps
        if name in _HEAD_MAJOR_POOLS:
            # advanced-index axes lead the result: updates go (B, W, H[, D])
            v = jnp.moveaxis(val, 2, 1)
            out[name] = pool.at[phys, :, off].set(v.astype(pool.dtype))
        else:
            out[name] = pool.at[phys, off].set(val.astype(pool.dtype))
    return out


def paged_decode_view(cache: dict) -> dict[str, jax.Array]:
    """Gather each slot's pages into a contiguous logical view.

    Returns dense-shaped arrays — k/v: (B, Hkv, L, D); scales:
    (B, Hkv, L); latent: (B, L, width); latent_scale: (B, L) — where
    ``L = pages_per_slot * page_size == max_seq_len``, so downstream
    attention math is bit-identical to the dense layout (unallocated
    entries read the trash page and are masked by position validity,
    exactly like dense positions beyond the write head).
    """
    table = cache["page_table"]  # (B, pages_per_slot)
    out = {}
    for name, pool in cache.items():
        if name == "page_table":
            continue
        g = pool[table]  # (B, n_pages, ...)
        if name in _HEAD_MAJOR_POOLS:
            g = jnp.moveaxis(g, 2, 1)  # (B, Hkv, n_pages, ps[, D])
            shape = g.shape[:2] + (g.shape[2] * g.shape[3],) + g.shape[4:]
        else:
            shape = (g.shape[0], g.shape[1] * g.shape[2]) + g.shape[3:]
        out[name] = g.reshape(shape)
    return out


# ---------------------------------------------------------------------------
# Traced helpers: prefill masking + layout-specific slot insertion
# ---------------------------------------------------------------------------


def mask_cache_tail(filled: PyTree, lengths: jax.Array) -> PyTree:
    """Zero cache entries at positions >= the per-row prompt length.

    ``filled``: stacked dense caches with batch axis 1 on every leaf.
    ``lengths``: (N,) true prompt lengths (traced, so every same-bucket
    batch reuses one compiled program).  Leaves without a sequence axis
    (SSM state, slot_pos) pass through; those families use exact-length
    prefill anyway, where the mask is all-true.
    """

    def _mask_group(group):
        out = {}
        for name, leaf in group.items():
            axis_r = SEQ_AXIS_FROM_RIGHT.get(name)
            if axis_r is None:
                out[name] = leaf
                continue
            axis = leaf.ndim - axis_r
            seq = jnp.arange(leaf.shape[axis])
            seq_b = seq.reshape(
                (1,) * axis + (-1,) + (1,) * (leaf.ndim - axis - 1)
            )
            len_b = lengths.reshape((1, -1) + (1,) * (leaf.ndim - 2))
            out[name] = jnp.where(
                seq_b < len_b, leaf, jnp.zeros((), leaf.dtype)
            )
        return out

    return {k: _mask_group(v) for k, v in filled.items()}


def insert_prefill_dense(big: PyTree, filled: PyTree, slots: jax.Array):
    """Scatter freshly prefilled rows into their slots (batch axis 1 on
    every stacked leaf).  Rows whose slot index is out of range (the
    engine's padding sentinel) are dropped."""

    def ins(b, f):
        return b.at[:, slots].set(f.astype(b.dtype), mode="drop")

    return jax.tree.map(ins, big, filled)


def insert_prefill_paged(
    big: PyTree, filled: PyTree, slots: jax.Array, page_size: int,
    shared_pages: jax.Array | None = None,
):
    """Scatter dense prefilled rows into each slot's physical pages.

    ``filled`` is the dense scratch cache the model wrote (tail-masked);
    it may be shorter than the full logical range — the engine sizes it
    to the prefill bucket rounded up to whole pages.  Its page view is
    scattered through the leading columns of the slots' page-table rows;
    later logical pages stay untouched (any stale tenant data there is
    masked by position validity until decode overwrites each position as
    it becomes valid).  Unallocated table entries — the pad tail beyond
    a prompt's allocated pages, and entire rows for padding slots —
    point at the trash page, so those writes are inert.

    ``shared_pages``: optional (N,) per-row count of leading table
    entries that alias prefix-cache pages owned by earlier requests.
    Those columns are redirected to the trash page for this scatter, so
    the (recomputed, bit-identical) prefix values never touch shared
    storage — shared history stays immutable without copy-on-write.
    """
    layers = dict(big["layers"])
    table = layers["page_table"][0]  # identical across layers: (B, n_pages)
    row_tables = jnp.take(
        table, slots, axis=0, mode="fill", fill_value=TRASH_PAGE
    )  # (N, pages_per_slot)
    if shared_pages is not None:
        col = jnp.arange(row_tables.shape[1], dtype=jnp.int32)
        row_tables = jnp.where(
            col[None, :] < shared_pages[:, None], TRASH_PAGE, row_tables
        )
    for name, small in filled["layers"].items():
        pool = layers[name]
        axis = small.ndim - SEQ_AXIS_FROM_RIGHT[name]
        n_pages = small.shape[axis] // page_size
        paged_shape = (
            small.shape[:axis] + (n_pages, page_size) + small.shape[axis + 1:]
        )
        pages = jnp.moveaxis(small.reshape(paged_shape), axis, 2)
        # pool (L, P, ...), indices (N, n_pages) on axis 1
        layers[name] = pool.at[:, row_tables[:, :n_pages]].set(
            pages.astype(pool.dtype)
        )
    return {"layers": layers}


# ---------------------------------------------------------------------------
# Host-side manager
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class CacheStats:
    layout: str
    kv_bytes: int
    page_size: int
    pages_in_use: int
    pages_capacity: int
    page_allocs_total: int
    pages_in_use_peak: int
    pages_cached: int = 0
    prefix_queries: int = 0
    prefix_hits: int = 0
    prefix_pages_hit: int = 0
    cow_copies: int = 0
    page_evictions: int = 0
    #: pages shared by mapping a resident parent's live pages onto an
    #: n-best sibling (CacheManager.fork) — generated-into pages
    #: included, unlike prefix-index hits which only ever share fully
    #: prompt-written pages
    gen_pages_shared: int = 0
    #: victim-tier movement: pages spilled to the host ring on eviction
    #: (swap_outs), spilled pages fetched back into device pages on a
    #: later prefix hit (swap_ins), and spilled pages dropped when the
    #: host ring itself overflowed (host_evictions)
    swap_outs: int = 0
    swap_ins: int = 0
    host_evictions: int = 0
    host_pages_used: int = 0
    host_pages_capacity: int = 0
    #: host wall seconds spent in flush_swaps (device<->host row copies)
    swap_latency_s: float = 0.0

    @property
    def page_utilization(self) -> float:
        # a dense manager built with max_batch=0 (spec-only probes) or a
        # hand-rolled stats row may carry zero capacity
        if self.pages_capacity <= 0:
            return 0.0
        return self.pages_in_use / self.pages_capacity

    @property
    def prefix_hit_rate(self) -> float:
        if self.prefix_queries <= 0:
            return 0.0
        return self.prefix_hits / self.prefix_queries

    def as_dict(self) -> dict:
        return {
            "kv_layout": self.layout,
            "kv_bytes": self.kv_bytes,
            "kv_page_size": self.page_size,
            "pages_in_use": self.pages_in_use,
            "pages_capacity": self.pages_capacity,
            "page_utilization": self.page_utilization,
            "page_allocs_total": self.page_allocs_total,
            "pages_in_use_peak": self.pages_in_use_peak,
            "pages_cached": self.pages_cached,
            "prefix_queries": self.prefix_queries,
            "prefix_hits": self.prefix_hits,
            "prefix_hit_rate": self.prefix_hit_rate,
            "prefix_pages_hit": self.prefix_pages_hit,
            "cow_copies": self.cow_copies,
            "page_evictions": self.page_evictions,
            "gen_pages_shared": self.gen_pages_shared,
            "swap_outs": self.swap_outs,
            "swap_ins": self.swap_ins,
            "host_evictions": self.host_evictions,
            "host_pages_used": self.host_pages_used,
            "host_pages_capacity": self.host_pages_capacity,
            "swap_latency_s": self.swap_latency_s,
        }


@dataclasses.dataclass(frozen=True)
class PrefixMatch:
    """Longest prefix-index match for a prompt.  ``keys[i]`` is the
    interned chain key of token chunk ``i`` (all full pages); the
    leading ``len(pages)`` chunks are device-resident (``pages[i]``
    holds chunk ``i``'s KV), the remaining ``host_hits`` chunks live in
    the host victim tier and swap back in at admission.  Without a
    victim tier ``len(keys) == len(pages)``.  ``tokens`` ==
    ``len(keys) * page_size`` — total coverage across both tiers."""

    pages: tuple[int, ...] = ()
    keys: tuple[int, ...] = ()
    tokens: int = 0

    @property
    def host_hits(self) -> int:
        """Matched chunks resident only in the host victim tier."""
        return len(self.keys) - len(self.pages)

    def __bool__(self) -> bool:
        return bool(self.keys)


class CacheManager:
    """Owns the KV-cache storage layout for one serving engine.

    Host-side responsibilities: building the device cache pytree,
    page allocation / reclamation / refcounting per slot (paged layout),
    the prefix-cache index (hash-chained full prompt pages, shared
    copy-on-write), and keeping the device page table in sync.  Traced
    responsibility: inserting a prefilled dense slab into the big caches
    inside the engine's jitted prefill program (:meth:`insert_prefill` —
    static layout config only, so it adds no jit programs).

    Dense layout is modeled as one page of ``max_seq_len`` tokens per
    slot, statically bound to the slot — which makes the occupancy
    telemetry uniform across layouts.  Prefix caching degenerates to a
    no-op for dense (slot-bound slabs cannot be shared).

    Paged page lifecycle: ``free`` (no meaningful content) -> ``live``
    (refcount >= 1, owned by one or more slot tables) -> either back to
    ``free`` (unregistered content) or ``cached`` (refcount 0 but still
    registered in the prefix index, evictable LRU) when its last owner
    finishes.  The reserved trash page 0 never enters any of the three
    sets.  With a victim tier (``ServeConfig.kv_host_pages``) eviction
    off the cached LRU adds a fourth, host-side state: ``spilled`` —
    the page's rows live in the host ring under its chain key, and a
    later prefix hit swaps them back into a fresh device page
    (:meth:`flush_swaps`); the tier-LRU eviction of a spilled chain is
    the only point where warm prefix state is truly discarded.
    """

    def __init__(
        self,
        cfg: ModelConfig,
        serve_cfg: ServeConfig,
        quantized: bool = False,
        dtype=jnp.float32,
    ):
        self.cfg = cfg
        self.serve_cfg = serve_cfg
        self.quantized = quantized
        self.dtype = dtype
        sc = serve_cfg
        rolling = (
            cfg.sliding_window is not None
            and cfg.sliding_window < sc.max_seq_len
        )
        #: position-addressed caches can be right-padded (bucketed
        #: prefill) and paged; SSM/hybrid state and rolling buffers can't
        self.position_addressed = (
            cfg.attn_kind in ("gqa", "mla")
            and cfg.family not in ("ssm", "hybrid")
            and not rolling
        )
        requested = sc.kv_layout
        if requested not in LAYOUTS:
            raise ValueError(
                f"unknown kv_layout {requested!r}; use one of {LAYOUTS}"
            )
        self.layout = (
            "paged"
            if requested == "paged" and self.position_addressed
            else "dense"
        )
        if self.layout == "paged":
            ps = sc.kv_page_size
            if ps < 1 or sc.max_seq_len % ps != 0:
                raise ValueError(
                    f"kv_page_size={ps} must divide max_seq_len="
                    f"{sc.max_seq_len} (fixed-stride pages)"
                )
            self.page_size = ps
            self.pages_per_slot = sc.max_seq_len // ps
            auto = sc.max_batch * self.pages_per_slot + 1  # +1 trash page
            self.num_pages = auto if sc.kv_pages is None else sc.kv_pages
            if self.num_pages < 2:
                raise ValueError("kv_pages must be >= 2 (one is the trash page)")
            # page 0 is the reserved trash page; pop() allocates ascending
            self._free = list(range(self.num_pages - 1, 0, -1))
        else:
            # dense: one slot-bound "page" of max_seq_len tokens
            self.page_size = sc.max_seq_len
            self.pages_per_slot = 1
            self.num_pages = sc.max_batch
            self._free = []
        #: prefix-cache sharing is a paged-layout feature; dense slabs are
        #: slot-bound and the knob is silently inert there
        self.prefix_cache = bool(sc.kv_prefix_cache and self.layout == "paged")
        self._slot_pages: list[list[int]] = [[] for _ in range(sc.max_batch)]
        # worst-case pages promised to each resident request at admission;
        # allocation stays lazy, but admission never over-promises the pool
        self._slot_reserved: list[int] = [0] * sc.max_batch
        #: per-slot interned chain keys for pages [0, len(keys)) — the
        #: registration watermark, so register_filled only chunks/interns
        #: pages completed since its previous call (truncated when a
        #: write mutates a chained page, i.e. CoW / deregister-on-write)
        self._slot_keys: list[list[int]] = [[] for _ in range(sc.max_batch)]
        self._table = np.zeros(
            (sc.max_batch, self.pages_per_slot), np.int32
        )
        self._table_dirty = True
        self._allocs_total = 0
        self._peak_in_use = 0
        # --- refcounts + prefix index (paged sharing) ---
        self._page_ref = np.zeros(self.num_pages, np.int32)
        #: retained refcount-0 registered pages, insertion order == LRU
        self._cached: dict[int, None] = {}
        #: interned hash-chain keys: (parent_key, token chunk) -> key id.
        #: Keys are exact token tuples (no lossy hashing), so two distinct
        #: prefixes can never collide into the same page.  Ids come from a
        #: monotonic counter (never reused), and the table is mark-swept
        #: once it doubles past the reachable set (_maybe_gc_intern) so a
        #: long-running server does not leak an entry per page ever served.
        self._key_intern: dict[tuple[int, tuple[int, ...]], int] = {}
        self._next_key_id = 1
        self._intern_gc_floor = 1024
        self._intern_gc_at = self._intern_gc_floor
        self._prefix_index: dict[int, int] = {}  # key id -> physical page
        self._page_key: dict[int, int] = {}  # physical page -> key id
        #: device page copies scheduled by copy-on-write, flushed by the
        #: engine (flush_copies) before the next decode dispatch
        self._pending_copies: list[tuple[int, int]] = []
        self._cow_copies = 0
        self._evictions = 0
        self._gen_pages_shared = 0
        self._prefix_queries = 0
        self._prefix_hits = 0
        self._prefix_pages_hit = 0
        # --- host-memory victim tier (kv_host_pages) ---
        #: tier on: registered pages evicted off the device LRU spill
        #: their pool rows into host numpy rings instead of vanishing
        self.victim_tier = bool(
            self.prefix_cache
            and getattr(sc, "kv_victim_tier", True)
            and getattr(sc, "kv_host_pages", 0) > 0
        )
        self.host_pages = sc.kv_host_pages if self.victim_tier else 0
        #: per-pool host rings, (n_layers, host_pages, per-page dims...)
        #: mirroring every device pool leaf (k/v, scales, latents — never
        #: the page table)
        self._host_pool: dict[str, np.ndarray] = {}
        if self.victim_tier:
            for name, leaf in self._abstract()["layers"].items():
                if name == "page_table":
                    continue
                self._host_pool[name] = np.zeros(
                    (leaf.shape[0], self.host_pages) + leaf.shape[2:],
                    leaf.dtype,
                )
        self._host_free: list[int] = list(range(self.host_pages - 1, -1, -1))
        #: chain key -> host ring slot, insertion order == tier LRU
        self._host_index: dict[int, int] = {}
        self._host_key: dict[int, int] = {}  # host slot -> chain key
        #: host-tier keys the current admit() must not evict while it
        #: allocates their swap-in device pages (a fetch's own device
        #: allocation can evict a cached page, whose spill could
        #: otherwise recycle a host slot the same admission still needs)
        self._host_pins: set[int] = set()
        #: queued device->host row copies (evictions of warm pages) and
        #: host->device copies (prefix hits on spilled chains), both
        #: applied by flush_swaps at the executor's next dispatch
        self._pending_spills: list[tuple[int, int]] = []  # (page, host slot)
        self._pending_swap_ins: list[tuple[int, int]] = []  # (host slot, page)
        #: device page -> (host slot, chain key) for unflushed swap-ins,
        #: so eviction/free of the target page can cancel the copy and
        #: restore the key to the host tier (the rows never left it)
        self._swap_in_by_page: dict[int, tuple[int, int]] = {}
        self._swap_ins = 0
        self._swap_outs = 0
        self._host_evictions = 0
        self._swap_latency_s = 0.0
        self.kv_bytes = sum(
            int(np.prod(leaf.shape)) * leaf.dtype.itemsize
            for leaf in jax.tree.leaves(self._abstract())
        )
        #: NamedSharding for the page_table leaf (set by the executor
        #: when shard_decode is on).  write_table rebuilds the table
        #: from host numpy each sync; without re-placing it onto the
        #: mesh the rebuilt leaf would arrive with default (single
        #: device) sharding and re-key the decode jit cache — a second
        #: compiled decode program, blowing the budget.
        self.table_sharding = None

    # ----------------------------------------------------------- layout --
    def _layout_kw(self) -> dict:
        if self.layout == "paged":
            return dict(
                layout="paged",
                page_size=self.page_size,
                num_pages=self.num_pages,
            )
        return dict(layout="dense")

    def _abstract(self) -> PyTree:
        return abstract_caches(
            self.cfg, self.serve_cfg.max_batch, self.serve_cfg.max_seq_len,
            dtype=self.dtype, quantized=self.quantized, **self._layout_kw(),
        )

    def init_device_caches(self) -> PyTree:
        return init_caches(
            self.cfg, self.serve_cfg.max_batch, self.serve_cfg.max_seq_len,
            dtype=self.dtype, quantized=self.quantized, **self._layout_kw(),
        )

    def device_shardings(self, rules) -> PyTree:
        """NamedSharding tree matching :meth:`init_device_caches` for the
        given :class:`~repro.distributed.sharding.ShardingRules` — the
        executor device_puts the live caches onto it when
        ``ServeConfig.shard_decode`` is on, and stores the page_table
        leaf in :attr:`table_sharding` so :meth:`write_table` rebuilds
        land on the same placement."""
        from repro.distributed.sharding import cache_shardings

        return cache_shardings(
            rules, self.cfg, self.serve_cfg.max_batch,
            self.serve_cfg.max_seq_len, quantized=self.quantized,
            **self._layout_kw(),
        )

    # ------------------------------------------------------- allocation --
    def pages_for(self, length: int) -> int:
        """Pages needed to hold ``length`` tokens (at least one)."""
        return max(1, -(-length // self.page_size))

    @property
    def pages_reserved_unallocated(self) -> int:
        """Reserved-but-not-yet-allocated pages (promised decode headroom)."""
        return sum(
            max(r - len(p), 0)
            for r, p in zip(self._slot_reserved, self._slot_pages)
        )

    def can_reserve(self, n_pages: int) -> bool:
        """Whether the pool can promise ``n_pages`` to a new request without
        eating another resident request's unallocated reservation.  Cached
        (refcount-0 retained) pages count as available: allocation evicts
        them LRU under pressure."""
        if self.layout != "paged":
            return True  # dense slabs are slot-bound; engine gates on slots
        avail = len(self._free) + len(self._cached)
        return avail - self.pages_reserved_unallocated >= n_pages

    def _take_page(self) -> int | None:
        """Pop a free page, evicting the LRU cached page when the free list
        is empty.  With a victim tier, the evicted page's rows spill to
        the host ring (its chain key stays fetchable) instead of being
        discarded.  Returns None when the pool is truly exhausted."""
        if self._free:
            return self._free.pop()
        if self._cached:
            page = next(iter(self._cached))
            del self._cached[page]
            self._spill(page)
            self._evictions += 1
            return page
        return None

    def _spill(self, page: int) -> None:
        """Deregister an evicted page; with a victim tier, move its chain
        key into the host index and queue the device->host row copy for
        :meth:`flush_swaps` (the copy must land before the page's new
        owner writes it — guaranteed because every dispatch flushes
        swaps at host_prep, ahead of its device program).  Degenerates
        to plain deregistration when the tier is off or the host ring
        has no evictable slot left."""
        key = self._page_key.pop(page, None)
        if key is not None and self._prefix_index.get(key) == page:
            del self._prefix_index[key]
        if not self.victim_tier or key is None:
            return
        if page in self._swap_in_by_page:
            # the page's content is itself an unflushed swap-in: the
            # chain rows never left the host ring, so cancel the copy
            # and re-register the key on its still-valid host slot
            self._cancel_swap_in(page)
            return
        host = self._host_take()
        if host is None:
            return  # ring exhausted (all pinned/absent): classic discard
        self._host_index[key] = host
        self._host_key[host] = key
        self._pending_spills.append((page, host))
        self._swap_outs += 1

    def _host_take(self) -> int | None:
        """Pop a free host ring slot, evicting the tier-LRU chain (for
        real — its rows are gone) when the ring is full.  Keys pinned by
        an in-progress admission are never victims."""
        if self._host_free:
            return self._host_free.pop()
        victim = next(
            (k for k in self._host_index if k not in self._host_pins), None
        )
        if victim is None:
            return None
        host = self._host_index.pop(victim)
        del self._host_key[host]
        # a spill aimed at the recycled slot that never flushed is
        # superseded by the new tenant's rows — drop it
        self._pending_spills = [
            (p, h) for p, h in self._pending_spills if h != host
        ]
        self._host_evictions += 1
        return host

    def _cancel_swap_in(self, page: int) -> None:
        """Cancel the unflushed host->device copy aimed at ``page``
        (the page is being evicted or freed before any dispatch flushed
        it) and restore its chain key onto the host slot, whose rows are
        still intact."""
        host, key = self._swap_in_by_page.pop(page)
        self._pending_swap_ins = [
            (h, p) for h, p in self._pending_swap_ins if p != page
        ]
        if key not in self._host_index and key not in self._prefix_index:
            self._host_index[key] = host
            self._host_key[host] = key
        elif host not in self._host_key:
            self._host_free.append(host)

    def _fetch_host(self, key: int) -> int:
        """Swap one spilled chain page back: allocate a fresh device
        page, queue the host->device row copy for :meth:`flush_swaps`,
        and re-register the key on the device page (the host slot frees
        once the copy lands).  Callers have already counted this
        allocation in :meth:`admission_need`."""
        host = self._host_index.pop(key)
        del self._host_key[host]
        page = self._take_page()
        if page is None:
            # unreachable under the admission discipline (the fetch was
            # charged to admission_need); restore the host entry and
            # fail loudly rather than corrupt the chain
            self._host_index[key] = host
            self._host_key[host] = key
            raise RuntimeError(
                "KV page pool exhausted during victim-tier swap-in; "
                "check can_reserve(admission_need(...)) before admit()"
            )
        self._pending_swap_ins.append((host, page))
        self._swap_in_by_page[page] = (host, key)
        self._prefix_index[key] = page
        self._page_key[page] = key
        self._swap_ins += 1
        self._allocs_total += 1
        return page

    def _deregister(self, page: int) -> None:
        key = self._page_key.pop(page, None)
        if key is None:
            return
        if self._prefix_index.get(key) == page:
            del self._prefix_index[key]
        entry = self._swap_in_by_page.get(page)
        if (
            entry is not None
            and entry[1] == key
            and key not in self._host_index
        ):
            # deregistered by a mid-tenancy write before its swap-in
            # flushed: the device copy is about to diverge, but the host
            # ring still holds the chain's original rows — keep the key
            # fetchable there (the pending copy still runs: positions
            # below the write still need the swapped content)
            host = entry[0]
            self._host_index[key] = host
            self._host_key[host] = key

    def _intern_key(self, parent: int, chunk: tuple[int, ...]) -> int:
        key = self._key_intern.get((parent, chunk))
        if key is None:
            key = self._next_key_id
            self._next_key_id += 1
            self._key_intern[(parent, chunk)] = key
            self._maybe_gc_intern()
        return key

    def _maybe_gc_intern(self) -> None:
        """Mark-sweep the chain-key intern table once it doubles past its
        last post-sweep size: keep only keys reachable (via parent links)
        from a registered page or a resident slot's chain watermark.
        Without this, every full page of every request ever served leaves
        an entry behind — an unbounded host-memory leak on long-running
        engines.  Dropped prefixes simply re-intern under fresh ids (the
        monotonic counter guarantees no id is ever reused)."""
        if len(self._key_intern) <= self._intern_gc_at:
            return
        parent_of = {
            kid: parent for (parent, _), kid in self._key_intern.items()
        }
        live: set[int] = set()
        roots = list(self._prefix_index)
        for keys in self._slot_keys:
            roots.extend(keys)
        for key in roots:
            while key and key not in live:
                live.add(key)
                key = parent_of.get(key, 0)
        self._key_intern = {
            pk: kid for pk, kid in self._key_intern.items() if kid in live
        }
        self._intern_gc_at = max(
            self._intern_gc_floor, 2 * len(self._key_intern)
        )

    # ----------------------------------------------------- prefix cache --
    def match_prefix(self, tokens: list[int]) -> PrefixMatch:
        """Longest run of leading *full* prompt pages already present in
        the prefix index — device-resident pages first, then (victim
        tier) chain keys whose rows live in the host ring and will swap
        back in at admission.  The device run must stay leading (shared
        pages sit at identical table columns in every owner), so the
        walk ends at the first chunk found in neither tier, or at a
        device-resident chunk that follows a host hit.  Pure lookup —
        hit/query telemetry is counted at :meth:`admit` so admission
        retries don't inflate the rate."""
        if not self.prefix_cache:
            return PrefixMatch()
        parent = 0
        pages: list[int] = []
        keys: list[int] = []
        for i in range(len(tokens) // self.page_size):
            chunk = tuple(tokens[i * self.page_size:(i + 1) * self.page_size])
            key = self._key_intern.get((parent, chunk))
            if key is None:
                break
            page = self._prefix_index.get(key)
            if page is not None and len(keys) == len(pages):
                pages.append(page)
            elif key not in self._host_index:
                break
            keys.append(key)
            parent = key
        return PrefixMatch(
            tuple(pages), tuple(keys), len(keys) * self.page_size
        )

    def _tail_need(
        self, match: PrefixMatch | None, reserve_len: int, write_from: int
    ) -> int:
        """Pages this admission will still have to allocate beyond its
        shared coverage (device-matched plus swapped-in chunks): the
        uncovered tail, plus one copy-on-write headroom page when the
        first decode write lands inside a covered page (a full-coverage
        prefix hit)."""
        total = self.pages_for(min(reserve_len, self.serve_cfg.max_seq_len))
        shared = len(match.keys) if match else 0
        headroom = 1 if match and write_from < match.tokens else 0
        return max(total - shared, 0) + headroom

    def _revived(self, match: PrefixMatch | None) -> int:
        """Matched pages currently on the cached LRU (refcount 0): mapping
        them revives them, removing them from the evictable pool, so the
        admission check must count them against availability even though
        they are not fresh allocations."""
        if not match:
            return 0
        return sum(1 for p in match.pages if self._page_ref[p] == 0)

    def admission_need(
        self, match: PrefixMatch | None, reserve_len: int, write_from: int
    ) -> int:
        """Pages the pool must have available (free + evictable-cached,
        net of other residents' unallocated reservations) to admit this
        request: its uncovered tail's worst case, plus any cached
        matched pages its admission revives, plus one fresh device page
        per host-tier hit (each swapped-in chunk lands in a new device
        page)."""
        if self.layout != "paged":
            return 0
        return (
            self._tail_need(match, reserve_len, write_from)
            + self._revived(match)
            + (match.host_hits if match else 0)
        )

    def admit(
        self,
        slot: int,
        tokens: list[int],
        reserve_len: int,
        match: PrefixMatch | None = None,
        lazy_tail: bool = False,
        write_from: int | None = None,
        fill_len: int | None = None,
    ) -> int:
        """Admit a request: map any prefix-cache hit onto the slot's
        leading table entries (refcount++, reviving retained pages; a
        host-tier continuation allocates fresh device pages and queues
        their swap-in row copies for :meth:`flush_swaps`), reserve
        worst-case pages for the uncovered remainder
        (``reserve_len`` = prompt + generation budget, capped at
        max_seq_len), then allocate — and register in the prefix index —
        the prompt's own pages.  ``lazy_tail=True`` skips the prompt-tail
        allocation (the engine's prefill-skip path fills the tail through
        decode writes, so :meth:`ensure` allocates it lazily like any
        decode growth); ``fill_len`` (chunked prefill) allocates and
        registers only the leading ``fill_len`` positions now — the
        prefill dispatch writes exactly those — leaving the rest lazy.
        Returns the number of covered leading pages (device-shared plus
        swapped-in).

        Reservation is a counter, not an allocation — but admission-time
        reservation guarantees decode growth (including at most one
        copy-on-write allocation) can never exhaust the pool mid-run."""
        if write_from is None:
            write_from = len(tokens)
        if self.layout != "paged":
            self.alloc(slot, len(tokens))
            return 0
        if self.prefix_cache:
            self._prefix_queries += 1
        shared = list(match.pages) if match else []
        swapped = match.host_hits if match else 0
        need = self.admission_need(match, reserve_len, write_from)
        if not self.can_reserve(need):
            raise RuntimeError(
                f"cannot reserve {need} KV pages for admission; check "
                "can_reserve() before calling admit()"
            )
        tail_need = self._tail_need(match, reserve_len, write_from)
        if shared or swapped:
            self._prefix_hits += 1
            self._prefix_pages_hit += len(shared) + swapped
            pages = self._slot_pages[slot]
            for col, page in enumerate(shared):
                if self._page_ref[page] == 0:  # revive a retained page
                    del self._cached[page]
                self._page_ref[page] += 1
                self._table[slot, col] = page
                pages.append(page)
            if swapped:
                # host-tier continuation: each spilled chunk swaps back
                # into a fresh device page.  Pin the remaining host keys
                # while fetching — a fetch's own device allocation can
                # spill an evicted page, and that spill must never
                # recycle a host slot this same admission still needs.
                host_keys = match.keys[len(shared):]
                self._host_pins = set(host_keys)
                try:
                    for col, key in enumerate(host_keys, start=len(shared)):
                        self._host_pins.discard(key)
                        page = self._fetch_host(key)
                        self._page_ref[page] = 1
                        self._table[slot, col] = page
                        pages.append(page)
                finally:
                    self._host_pins = set()
            self._slot_keys[slot] = list(match.keys)
            self._table_dirty = True
        self._slot_reserved[slot] = len(shared) + swapped + tail_need
        if not lazy_tail:
            self.ensure(slot, len(tokens))
            self.register_filled(slot, tokens, len(tokens))
        elif fill_len:
            # chunked prefill: the dispatch fills [0, fill_len); its full
            # pages are registerable like any prefilled page (causal
            # attention makes their content independent of the suffix)
            self.ensure(slot, fill_len)
            self.register_filled(slot, tokens, fill_len)
        self._peak_in_use = max(self._peak_in_use, self.pages_in_use)
        return len(shared) + swapped

    def fork_need(
        self, parent_slot: int, upto_len: int, reserve_len: int
    ) -> int:
        """Pages a fork admission must be able to reserve: the worst-case
        tail beyond the shared coverage, plus one copy-on-write headroom
        page — the child's first write always lands inside the last
        shared page (it re-processes the parent's final prompt token)."""
        if self.layout != "paged":
            return 0
        shared = min(
            -(-upto_len // self.page_size), len(self._slot_pages[parent_slot])
        )
        total = self.pages_for(min(reserve_len, self.serve_cfg.max_seq_len))
        return max(total - shared, 0) + (1 if shared else 0)

    def fork(
        self, slot: int, parent_slot: int, upto_len: int, reserve_len: int
    ) -> int:
        """Map the parent's pages covering positions [0, ``upto_len``)
        onto ``slot`` with a refcount bump each — the n-best
        generation-page sharing path (``Engine.submit(n=...)``).

        Unlike a prefix-index hit, which only ever shares fully
        prompt-written pages, this shares the parent's *live* pages,
        including the page the parent is actively generating into; the
        child's own writes split off private copies through the ordinary
        copy-on-write machinery in :meth:`ensure`.  The shared chain-key
        watermark transfers too (the child's tokens match the parent's
        on every shared page), so registration stays incremental.
        Returns the number of shared pages."""
        if self.layout != "paged":
            raise RuntimeError("fork() requires the paged layout")
        need = self.fork_need(parent_slot, upto_len, reserve_len)
        if not self.can_reserve(need):
            raise RuntimeError(
                f"cannot reserve {need} KV pages for fork; check "
                "can_reserve(fork_need()) before calling fork()"
            )
        parent_pages = self._slot_pages[parent_slot]
        n = min(-(-upto_len // self.page_size), len(parent_pages))
        pages = self._slot_pages[slot]
        assert not pages, f"fork target slot {slot} already holds pages"
        for col in range(n):
            page = parent_pages[col]
            self._page_ref[page] += 1
            self._table[slot, col] = page
            pages.append(page)
        self._table_dirty = True
        self._slot_keys[slot] = list(self._slot_keys[parent_slot][:n])
        self._slot_reserved[slot] = n + need
        self._gen_pages_shared += n
        self._peak_in_use = max(self._peak_in_use, self.pages_in_use)
        return n

    def register_filled(
        self, slot: int, tokens: list[int], upto_len: int
    ) -> None:
        """Register ``slot``'s fully-written pages (positions
        [0, upto_len), token ids ``tokens``) in the prefix index so later
        same-prefix admissions can share them.  Idempotent; pages already
        registered (shared prefix pages) and keys already served by
        another live page are left untouched.  Incremental: the slot's
        chain-key watermark (``_slot_keys``) means each page is chunked
        and interned once per residency, not once per decode dispatch."""
        if not self.prefix_cache:
            return
        pages = self._slot_pages[slot]
        keys = self._slot_keys[slot]
        parent = keys[-1] if keys else 0
        for i in range(len(keys), min(upto_len // self.page_size, len(pages))):
            chunk = tuple(tokens[i * self.page_size:(i + 1) * self.page_size])
            parent = self._intern_key(parent, chunk)
            keys.append(parent)
            page = pages[i]
            if page in self._page_key or parent in self._prefix_index:
                continue
            self._prefix_index[parent] = page
            self._page_key[page] = parent

    def alloc(self, slot: int, length: int) -> None:
        """Ensure ``slot`` owns pages covering positions [0, length)."""
        self.ensure(slot, length)

    def ensure(
        self, slot: int, upto_len: int, write_from: int | None = None
    ) -> None:
        """Grow ``slot``'s page list to cover ``upto_len`` positions —
        called before each decode dispatch so mid-scan writes never cross
        into unallocated space.  When ``write_from`` is given, pages
        overlapping the write range [write_from, upto_len) are made
        privately writable first: a shared page (refcount > 1) is
        copy-on-write replaced (fresh page, device copy scheduled for
        :meth:`flush_copies`, table entry swapped), and a registered
        sole-owner page is dropped from the prefix index, so shared
        history is immutable.  Under the engine's admission discipline
        (reservation at admit()), the pool-exhausted error below is
        unreachable; it guards direct misuse of the manager."""
        if self.layout != "paged":
            if not self._slot_pages[slot]:
                self._slot_pages[slot] = [slot]
                self._allocs_total += 1
                self._peak_in_use = max(self._peak_in_use, self.pages_in_use)
            return
        pages = self._slot_pages[slot]
        need = self.pages_for(upto_len)
        while len(pages) < need:
            page = self._take_page()
            if page is None:
                raise RuntimeError(
                    f"KV page pool exhausted ({self.num_pages} pages of "
                    f"{self.page_size} tokens); raise ServeConfig.kv_pages "
                    "or admit fewer concurrent long sequences"
                )
            self._table[slot, len(pages)] = page
            pages.append(page)
            self._page_ref[page] = 1
            self._allocs_total += 1
            self._table_dirty = True
        if write_from is not None and upto_len > write_from:
            first = write_from // self.page_size
            last = (upto_len - 1) // self.page_size
            for col in range(first, min(last + 1, len(pages))):
                page = pages[col]
                if self._page_ref[page] > 1:
                    fresh = self._take_page()
                    if fresh is None:
                        raise RuntimeError(
                            "KV page pool exhausted during copy-on-write; "
                            "raise ServeConfig.kv_pages"
                        )
                    self._pending_copies.append((page, fresh))
                    self._page_ref[page] -= 1
                    self._page_ref[fresh] = 1
                    pages[col] = fresh
                    self._table[slot, col] = fresh
                    self._table_dirty = True
                    self._cow_copies += 1
                    self._allocs_total += 1
                    # the CoW headroom reserved at admission is now spent
                    self._slot_reserved[slot] = max(
                        self._slot_reserved[slot] - 1, len(pages)
                    )
                    # the chunk content diverges from the chained key
                    del self._slot_keys[slot][col:]
                elif page in self._page_key:
                    # sole owner about to mutate a registered page: the
                    # index must never serve stale content
                    self._deregister(page)
                    del self._slot_keys[slot][col:]
        self._peak_in_use = max(self._peak_in_use, self.pages_in_use)

    def free(self, slot: int) -> None:
        """Drop a finished (or preempted) slot's references immediately.
        A page whose refcount falls to zero returns to the free list —
        unless it is registered in the prefix index and prefix caching is
        on, in which case it is retained on the evictable LRU so repeated
        prompts keep hitting."""
        pages = self._slot_pages[slot]
        self._slot_pages[slot] = []
        self._slot_reserved[slot] = 0
        self._slot_keys[slot] = []
        if self.layout != "paged" or not pages:
            return
        freed: set[int] = set()
        for page in reversed(pages):
            self._page_ref[page] -= 1
            if self._page_ref[page] > 0:
                continue
            if self.prefix_cache and page in self._page_key:
                self._cached[page] = None
            else:
                self._free.append(page)
                freed.add(page)
        if freed and self._pending_copies:
            # drop queued CoW copies whose destination just returned to
            # the free list: the copy's content died with this tenancy,
            # and flushing it later would corrupt whichever unrelated
            # request reuses the page (the prefill dispatch syncs the
            # table without flushing CoW copies, so a stale copy could
            # land AFTER the page's next tenant prefilled into it)
            self._pending_copies = [
                (s, d) for s, d in self._pending_copies if d not in freed
            ]
        if freed and self._swap_in_by_page:
            # likewise cancel unflushed swap-ins aimed at freed pages —
            # the chain key (and its rows) stay fetchable in the host
            # ring, and no stale copy targets the page's next tenant
            for page in freed:
                if page in self._swap_in_by_page:
                    self._cancel_swap_in(page)
        self._table[slot, :] = TRASH_PAGE
        self._table_dirty = True

    # ------------------------------------------------------ device sync --
    def flush_copies(self, caches: PyTree) -> PyTree:
        """Apply scheduled copy-on-write page copies to the device pools.

        Host-side eager scatter of whole pool rows — it runs outside the
        engine's jitted prefill/decode programs, so the compiled program
        budget is untouched.  Must run before the decode dispatch that
        writes the copied pages (the engine calls it right after the
        per-slot :meth:`ensure` pass)."""
        if self.layout != "paged" or not self._pending_copies:
            return caches
        src = jnp.asarray([s for s, _ in self._pending_copies], jnp.int32)
        dst = jnp.asarray([d for _, d in self._pending_copies], jnp.int32)
        self._pending_copies.clear()
        layers = dict(caches["layers"])
        for name, pool in layers.items():
            if name == "page_table":
                continue
            layers[name] = pool.at[:, dst].set(pool[:, src])
        return {**caches, "layers": layers}

    def flush_swaps(self, caches: PyTree) -> PyTree:
        """Apply queued victim-tier page movement to the device pools:
        spills (evicted-but-warm device rows -> host ring) first, then
        swap-ins (host rows -> freshly allocated device pages), so a
        chain that spilled and re-matched before any dispatch moves
        device -> host -> device in one flush.  Host-side eager batched
        copies outside every jitted program — like
        :meth:`flush_copies`, the compiled program budget is untouched.
        The executor runs it at the top of every dispatch host_prep,
        BEFORE ``flush_copies``: a CoW destination may be a just-evicted
        page whose rows must reach the host ring before the copy
        overwrites them."""
        if self.layout != "paged" or not (
            self._pending_spills or self._pending_swap_ins
        ):
            return caches
        t0 = time.perf_counter()
        layers = dict(caches["layers"])
        if self._pending_spills:
            # one row per host slot — a later queue entry supersedes an
            # earlier one aimed at the same recycled slot
            by_host = {h: p for p, h in self._pending_spills}
            self._pending_spills.clear()
            hosts = list(by_host)
            pages = jnp.asarray([by_host[h] for h in hosts], jnp.int32)
            for name, pool in layers.items():
                if name == "page_table":
                    continue
                self._host_pool[name][:, hosts] = np.asarray(pool[:, pages])
        if self._pending_swap_ins:
            hosts = [h for h, _ in self._pending_swap_ins]
            dst = jnp.asarray(
                [p for _, p in self._pending_swap_ins], jnp.int32
            )
            for name, pool in layers.items():
                if name == "page_table":
                    continue
                rows = jnp.asarray(self._host_pool[name][:, hosts])
                layers[name] = pool.at[:, dst].set(rows.astype(pool.dtype))
            for host, page in self._pending_swap_ins:
                self._swap_in_by_page.pop(page, None)
                # a slot whose key was restored mid-flight (deregistered
                # target page) keeps holding the chain's rows; every
                # other slot returns to the ring's free list
                if host not in self._host_key:
                    self._host_free.append(host)
            self._pending_swap_ins.clear()
        self._swap_latency_s += time.perf_counter() - t0
        return {**caches, "layers": layers}

    def write_table(self, caches: PyTree) -> PyTree:
        """Refresh the stacked device page table from the host table
        (no-op for dense or when nothing changed since the last sync)."""
        if self.layout != "paged" or not self._table_dirty:
            return caches
        # .copy() is load-bearing: the CPU backend zero-copies aligned
        # numpy buffers, so the device table would otherwise alias the
        # live host table — an in-flight dispatch (async engine loop)
        # could then observe ``ensure``/``free`` mutations made while
        # its program is still running
        table = jnp.asarray(self._table.copy())
        stacked = jnp.broadcast_to(
            table[None], (self.cfg.n_layers,) + table.shape
        )
        if self.table_sharding is not None:
            stacked = jax.device_put(stacked, self.table_sharding)
        layers = dict(caches["layers"])
        layers["page_table"] = stacked
        self._table_dirty = False
        return {**caches, "layers": layers}

    # --------------------------------------------------- traced insert --
    def insert_prefill(
        self,
        big: PyTree,
        filled: PyTree,
        slots: jax.Array,
        shared_pages: jax.Array | None = None,
    ) -> PyTree:
        """Insert tail-masked dense prefill rows into the big caches
        (traced inside the engine's per-bucket jitted prefill).
        ``shared_pages``: per-row count of leading prefix-cache pages
        whose (recomputed, bit-identical) values must not be re-written
        — their columns scatter to the trash page instead."""
        if self.layout == "paged":
            return insert_prefill_paged(
                big, filled, slots, self.page_size, shared_pages
            )
        return insert_prefill_dense(big, filled, slots)

    # ---------------------------------------------------------- metrics --
    @property
    def pages_in_use(self) -> int:
        """Distinct live pages (a shared page counts once)."""
        if self.layout == "paged":
            return int((self._page_ref > 0).sum())
        return sum(len(p) for p in self._slot_pages)

    @property
    def pages_capacity(self) -> int:
        if self.layout == "paged":
            return self.num_pages - 1  # trash page is not allocatable
        return self.serve_cfg.max_batch

    def stats(self) -> CacheStats:
        return CacheStats(
            layout=self.layout,
            kv_bytes=self.kv_bytes,
            page_size=self.page_size,
            pages_in_use=self.pages_in_use,
            pages_capacity=self.pages_capacity,
            page_allocs_total=self._allocs_total,
            pages_in_use_peak=self._peak_in_use,
            pages_cached=len(self._cached),
            prefix_queries=self._prefix_queries,
            prefix_hits=self._prefix_hits,
            prefix_pages_hit=self._prefix_pages_hit,
            cow_copies=self._cow_copies,
            page_evictions=self._evictions,
            gen_pages_shared=self._gen_pages_shared,
            swap_outs=self._swap_outs,
            swap_ins=self._swap_ins,
            host_evictions=self._host_evictions,
            host_pages_used=self.host_pages - len(self._host_free),
            host_pages_capacity=self.host_pages,
            swap_latency_s=self._swap_latency_s,
        )

    # ------------------------------------------------------- invariants --
    def check_invariants(self) -> None:
        """Assert the paged pool's structural invariants; raises
        AssertionError with a descriptive message on any violation.  Used
        by the property-based trace tests after every operation; cheap
        enough (O(pages + table)) to call in debugging sessions too."""
        if self.layout != "paged":
            return
        ref = self._page_ref
        assert ref[TRASH_PAGE] == 0, "trash page acquired a refcount"
        assert TRASH_PAGE not in self._free, "trash page on the free list"
        assert TRASH_PAGE not in self._cached, "trash page retained as cached"
        assert TRASH_PAGE not in self._page_key, "trash page registered"
        live = {p for p in range(self.num_pages) if ref[p] > 0}
        free_set, cached_set = set(self._free), set(self._cached)
        assert len(free_set) == len(self._free), "free list holds duplicates"
        assert not (free_set & cached_set), "page both free and cached"
        assert not (free_set & live), "live page on the free list"
        assert not (cached_set & live), "live page retained as cached"
        universe = free_set | cached_set | live
        expected = set(range(self.num_pages)) - {TRASH_PAGE}
        assert universe == expected, (
            f"page leak/double-free: missing={sorted(expected - universe)} "
            f"extra={sorted(universe - expected)}"
        )
        # refcount conservation: every reference is a slot table entry
        counts = np.zeros(self.num_pages, np.int64)
        for slot, pages in enumerate(self._slot_pages):
            for col, page in enumerate(pages):
                assert page != TRASH_PAGE, f"slot {slot} maps the trash page"
                assert self._table[slot, col] == page, (
                    f"table desync at slot {slot} col {col}"
                )
                counts[page] += 1
            for col in range(len(pages), self.pages_per_slot):
                assert self._table[slot, col] == TRASH_PAGE, (
                    f"stale table entry at slot {slot} col {col}"
                )
        assert np.array_equal(counts, ref), (
            f"refcount drift: table refs {counts.nonzero()[0].tolist()} vs "
            f"refcounts {ref.nonzero()[0].tolist()}"
        )
        assert self.pages_in_use == len(live) == len(
            {p for pages in self._slot_pages for p in pages}
        ), "pages_in_use != distinct live table entries"
        for page in self._cached:
            assert page in self._page_key, "cached page lost its index key"
        for key, page in self._prefix_index.items():
            assert self._page_key.get(page) == key, (
                f"index/page key desync for page {page}"
            )
            assert page in live or page in cached_set, (
                f"prefix index maps a freed page {page}"
            )
        for page in self._page_key:
            assert page in live or page in cached_set, (
                f"registered page {page} is neither live nor cached"
            )
        for slot, (reserved, pages) in enumerate(
            zip(self._slot_reserved, self._slot_pages)
        ):
            assert reserved >= len(pages) or reserved == 0, (
                f"slot {slot} holds more pages than it reserved"
            )
            assert len(self._slot_keys[slot]) <= len(pages), (
                f"slot {slot} chain-key watermark outran its page list"
            )
        # a page shared by several slots sits at the SAME table column in
        # every owner: both sharing paths (prefix-index hits and n-best
        # forks) map leading runs of pages, so a shared page's tokens
        # occupy identical global positions in every mapping — the paged
        # gather's position arithmetic depends on this.  Covers
        # generation-page refcounts: a forked generated-into page obeys
        # the same rule until copy-on-write splits it.
        col_of: dict[int, int] = {}
        for slot, pages in enumerate(self._slot_pages):
            for col, page in enumerate(pages):
                seen = col_of.setdefault(page, col)
                assert seen == col, (
                    f"shared page {page} mapped at column {seen} and at "
                    f"column {col} (slot {slot})"
                )
        # --- host victim tier: the ring is its own page universe ---
        assert len(self._host_index) == len(self._host_key), (
            "host index/reverse-map size mismatch"
        )
        for key, host in self._host_index.items():
            assert 0 <= host < self.host_pages, (
                f"host slot {host} outside the ring"
            )
            assert self._host_key.get(host) == key, (
                f"host index/slot key desync for slot {host}"
            )
            assert key not in self._prefix_index, (
                f"chain key {key} served by both tiers"
            )
        host_free = set(self._host_free)
        assert len(host_free) == len(self._host_free), (
            "host free list holds duplicates"
        )
        held = set(self._host_key)
        transit = {h for h, _ in self._pending_swap_ins}
        assert not (host_free & held), "host slot both free and indexed"
        assert not (host_free & transit), (
            "host slot freed while its swap-in is still pending"
        )
        assert host_free | held | transit == set(range(self.host_pages)), (
            "host slot leak/double-free"
        )
        assert {p for _, p in self._pending_swap_ins} == set(
            self._swap_in_by_page
        ), "pending swap-in queue and its page map desync"
        for page, (host, _key) in self._swap_in_by_page.items():
            assert ref[page] > 0 or page in self._cached, (
                f"pending swap-in targets page {page}, neither live nor cached"
            )
        for page, host in self._pending_spills:
            assert host in self._host_key, (
                f"pending spill targets unindexed host slot {host}"
            )

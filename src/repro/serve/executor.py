"""Execution layer of the serving stack: *device work only, no policy*.

:class:`ModelExecutor` owns everything that touches the accelerator —
the precision plan applied to the params, the per-bucket prefill jit
cache and the single decode-scan program, the
:class:`~repro.serve.kv_cache.CacheManager` with its device cache
pytree, and the slot table (execution state: write positions, carry
tokens, pending teacher-forced tails).  It consumes an explicit
:class:`~repro.serve.scheduler.ScheduleDecision` and mechanically
applies it: reset preempted slots, activate admissions, run one
fixed-shape prefill dispatch per bucket group, run the decode scan,
retire finished slots.  Every *choice* (who is admitted where, who
preempts, what chunks) was already made by the scheduler; the executor
never inspects the queue and never makes a policy decision.

The compiled-program discipline: at most ``len(prefill_buckets)``
prefill programs (each at the fixed ``max_batch`` width) plus one
decode program plus — on datapaths that need it — one cache-extending
prefill program, test-enforced on the real jit caches.  The extend
program runs the prefill-path forward over a fixed-width token window
against the already-populated caches, so prefill-skip tails, chunk
tails, and preemption-resume prompts can be replayed with the same
math that produced the cache even when the decode scan is not bitwise
the prefill (MLA latent caches, int8 KV, LUT softmax).
"""

from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ServeConfig
from repro.core import precision as precision_lib
from repro.models import lm
from repro.serve import kv_cache
from repro.serve.phases import NULL_TRACER
from repro.serve.sampling import sample_tokens
from repro.serve.scheduler import (
    MODE_FORK,
    MODE_SKIP,
    Admission,
    ExecutorCaps,
    Request,
    ScheduleDecision,
    Slot,
    encode_sampling,
)

PyTree = Any


@dataclasses.dataclass
class StepOutput:
    """What one executed decision produced, for the API layer to route:
    ``tokens`` are (uid, token, index-in-generated) in emission order,
    ``finished``/``preempted`` the requests that left their slots."""

    stats: dict
    tokens: list[tuple[int, int, int]] = dataclasses.field(default_factory=list)
    finished: list[Request] = dataclasses.field(default_factory=list)
    preempted: list[Request] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class InflightStep:
    """A dispatched-but-uncollected engine step (the async loop's
    double-buffer token).  ``out`` already holds everything the
    internally-synchronous prefill/extend paths produced (they sample on
    host); the decode scan's results are still on device in ``dev``
    until :meth:`ModelExecutor.collect` blocks on them.

    ``snapshot`` pins each decode slot's Request at dispatch time so a
    mid-flight ``cancel`` (or any slot turnover) is detected at collect:
    a slot whose request changed identity — or was cancelled — has its
    in-flight tokens discarded rather than routed to a dead stream."""

    out: StepOutput
    decision: ScheduleDecision
    #: slots the decode dispatch covered (sorted), () = no decode ran
    decode_set: tuple[int, ...] = ()
    #: device arrays (toks_t, emit_t, tok_f, pos_f, act_f, rem_f) from
    #: the decode scan; None when no decode ran
    dev: Any = None
    #: slot index -> Request resident there when the scan was dispatched
    snapshot: dict[int, Request] = dataclasses.field(default_factory=dict)
    #: slot index -> the slot's admission stamp at dispatch; catches the
    #: turnover the Request identity check cannot — the SAME request
    #: preempted mid-flight and re-admitted into the SAME slot (its
    #: resume replay was planned from pre-dispatch ``generated``, so the
    #: in-flight tokens must still be discarded)
    admit_seqs: dict[int, int] = dataclasses.field(default_factory=dict)
    #: per-slot forced-token counts the dispatch consumed (host array)
    n_forced: Any = None
    #: monotone dispatch stamp: collect only clears a slot's inflight
    #: mark when no newer dispatch has re-marked it (the pipelined loop
    #: dispatches N+1 before collecting N, often over the same slots)
    seq: int = 0
    #: tracer stamp of the decode dispatch's return (overlap accounting)
    t_dispatch: float = 0.0
    #: perf_counter at decode dispatch start (decode_time_s accounting)
    t0: float = 0.0
    #: engine-clock stamp set by the Engine right after dispatch; token
    #: events and finished_at for this step carry it, keeping virtual-
    #: clock replay deterministic one step late (StepClock contract)
    dispatched_at: float | None = None

    @property
    def empty(self) -> bool:
        """Nothing to collect and nothing to route (idle dispatch)."""
        return (
            self.dev is None
            and not self.out.tokens
            and not self.out.finished
            and not self.out.preempted
        )


class DraftWorker:
    """The draft side of speculative decoding: a (small) model with its
    own dense float KV cache that greedily proposes ``spec_k`` tokens per
    resident slot in one scan dispatch.

    Program discipline mirrors the target executor's: at most
    ``len(buckets)`` draft prefill programs (used to resync a slot's
    draft cache from its token history after any host-side turnover)
    plus ONE propose-scan program, all at fixed ``(max_batch, ...)``
    shapes.  The draft cache is always dense float32 at
    ``max_batch x max_seq_len`` — the draft never pages and never
    quantizes, so its decode math is its own prefill math and resyncs
    are cheap and exact.

    ``pos[i]``/``tok[i]`` track which (position, carry token) the draft
    cache row i is synced to; ``-1`` means unsynced (the target
    executor's ``_host_dirty`` hook invalidates on every slot turnover).
    """

    def __init__(self, cfg, params, serve_cfg, buckets, spec_k):
        self.cfg = cfg
        self.params = params
        self.sc = serve_cfg
        self.buckets = tuple(buckets)
        self.spec_k = int(spec_k)
        nb = serve_cfg.max_batch
        self.caches = kv_cache.init_caches(
            cfg, nb, serve_cfg.max_seq_len, dtype=jnp.float32,
            quantized=False,
        )
        self.pos = [-1] * nb
        self.tok = [0] * nb
        self._prefill_fn: dict[int, Any] = {}
        self._propose_fn = jax.jit(self._propose_scan)

    def bucket_for(self, n: int) -> int | None:
        """Smallest draft prefill bucket covering ``n`` history tokens
        (None when the history outgrew every bucket — the slot simply
        decodes without speculation)."""
        for b in self.buckets:
            if b >= n:
                return b
        return None

    def _prefill_batch(self, params, tokens, lengths, caches, slots):
        """Rebuild draft cache rows from token histories in one bucketed
        dispatch (same row conventions as the target's prefill: pad rows
        carry length 0 and slot ``max_batch``, dropped by the dense
        scatter)."""
        nb, bucket = tokens.shape
        mask = jnp.arange(bucket, dtype=jnp.int32)[None, :] < lengths[:, None]
        tokens = jnp.where(mask, tokens, 0)
        small = kv_cache.init_caches(
            self.cfg, nb, self.sc.max_seq_len, dtype=jnp.float32,
            quantized=False,
        )
        _, filled, _ = lm.forward(
            params, self.cfg, {"tokens": tokens}, mode="prefill",
            caches=small,
        )
        filled = kv_cache.mask_cache_tail(filled, lengths)
        return kv_cache.insert_prefill_dense(caches, filled, slots)

    def _propose_scan(self, params, tokens, positions, active, caches):
        """Propose ``spec_k`` greedy tokens per active row in one scan.
        Row i processes its carry token at ``positions[i]`` (writing its
        KV) and argmaxes the next, exactly like the target decode scan
        minus sampling and emission bookkeeping.  Inactive rows freeze;
        their repeated same-position writes are idempotent."""
        def body(carry, _):
            tok, pos, c = carry
            logits, new_c, _ = lm.forward(
                params, self.cfg, {"tokens": tok[:, None]}, mode="decode",
                caches=c, positions=pos,
            )
            nxt = jnp.where(
                active,
                jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32),
                tok,
            )
            new_pos = jnp.where(active, pos + 1, pos)
            return (nxt, new_pos, new_c), nxt

        (tok, pos, caches), toks_t = jax.lax.scan(
            body, (tokens, positions, caches), None, length=self.spec_k
        )
        return toks_t, caches

    def sync(self, need: list[tuple[int, list[int]]], tel: dict) -> None:
        """Resync draft cache rows from their token histories, grouped by
        the smallest covering bucket.  ``need`` rows were pre-filtered to
        fit a bucket; empty histories just mark synced (nothing to
        write)."""
        groups: dict[int, list[tuple[int, list[int]]]] = {}
        for i, hist in need:
            if not hist:
                continue
            groups.setdefault(self.bucket_for(len(hist)), []).append((i, hist))
        nb = self.sc.max_batch
        for bucket in sorted(groups):
            grp = groups[bucket]
            toks = np.zeros((nb, bucket), np.int32)
            lengths = np.zeros((nb,), np.int32)
            slot_arr = np.full((nb,), nb, np.int32)
            for row, (i, hist) in enumerate(grp):
                toks[row, : len(hist)] = hist
                lengths[row] = len(hist)
                slot_arr[row] = i
            fn = self._prefill_fn.get(bucket)
            if fn is None:
                fn = jax.jit(self._prefill_batch)
                self._prefill_fn[bucket] = fn
                tel["draft_prefill_compiles"] = (
                    tel.get("draft_prefill_compiles", 0) + 1
                )
            self.caches = fn(
                self.params, jnp.asarray(toks), jnp.asarray(lengths),
                self.caches, jnp.asarray(slot_arr),
            )


class ModelExecutor:
    def __init__(
        self,
        cfg: ModelConfig,
        params: PyTree,
        serve_cfg: ServeConfig | None = None,
        kernel: dict | None = None,
        seed: int = 0,
        replica: int = 0,
        draft: tuple[ModelConfig, PyTree] | None = None,
    ):
        self.serve_cfg = serve_cfg or ServeConfig()
        if self.serve_cfg.decode_steps < 1:
            raise ValueError(
                f"decode_steps must be >= 1, got {self.serve_cfg.decode_steps}"
            )
        if self.serve_cfg.max_prefill_per_step < 0:
            raise ValueError(
                "max_prefill_per_step must be >= 0 (0 = fill all free slots)"
            )
        self.kernel = kernel or {}
        self.key = jax.random.PRNGKey(seed)
        # Replica salt: fold the replica index into the dispatch key so a
        # router's replicas draw distinct unseeded sampled streams even
        # when handed the same base seed.  fold_in (not seed + replica)
        # keeps (seed, replica) pairs collision-free.  Per-request seeded
        # streams are position-keyed and replica-independent by design,
        # so the salt leaves them untouched.
        self.replica = int(replica)
        if self.replica:
            self.key = jax.random.fold_in(self.key, self.replica)

        # Precision: one declarative policy governs weights (offline PTQ /
        # int8 quantize-dequantize; the true int8 GEMM path is
        # kernels/qmatmul on TPU), the KV-cache dtype, the softmax kernel
        # mode, and any runtime fake-quant the model applies in-graph.
        # ServeConfig.policy wins; otherwise the model's own policy applies.
        if self.serve_cfg.policy is not None:
            policy = precision_lib.get_policy(self.serve_cfg.policy)
            cfg = dataclasses.replace(cfg, precision=policy)
        else:
            policy = precision_lib.model_policy(cfg)
        self.cfg = cfg
        self.policy = policy
        self.plan = policy.resolve(cfg.n_layers)
        self.kernel = self.plan.kernel_defaults(self.kernel) or {}
        self.params = precision_lib.apply_plan_to_params(params, self.plan)

        if self.plan.int8_kv_cache and self.plan.kv_cache.bits != 8:
            raise NotImplementedError(
                "the KV cache implements 8-bit per-token quantization only; "
                f"policy {self.policy.name!r} asks for "
                f"{self.plan.kv_cache.bits}-bit"
            )
        sc = self.serve_cfg
        self.quant_cache = bool(
            self.plan.int8_kv_cache
            and cfg.attn_kind in ("gqa", "mla")
            and cfg.family not in ("ssm", "hybrid")
        )
        # All layout knowledge (dense slabs vs block-table pages, specs,
        # insertion, allocation) lives in the manager.
        self.cache_mgr = kv_cache.CacheManager(
            cfg, sc, quantized=self.quant_cache, dtype=jnp.float32
        )
        self.kv_layout = self.cache_mgr.layout
        self.caches = self.cache_mgr.init_device_caches()
        self.slots = [Slot() for _ in range(sc.max_batch)]
        #: pipelined loop (ServeConfig.async_loop): dispatch and collect
        #: interleave across steps, and the decode carry stays on device
        self.async_loop = bool(sc.async_loop)

        # Mesh-sharded decode: place params and the KV pools with
        # NamedSharding over a (data, model) host mesh so every
        # prefill/extend/decode program compiles against sharding-
        # annotated operands.  Committed input shardings propagate
        # through the existing jitted programs — no new programs, so the
        # len(buckets)+2 budget holds (test-enforced).  The page table
        # keeps its sharding across host-side rebuilds via the manager's
        # ``table_sharding`` hook (a fresh uncommitted table would
        # otherwise re-key the jit cache and mint a second decode
        # program mid-run).
        self.mesh = None
        self.sharding_rules = None
        self._cache_out_sh = None
        self._rep_sh = None
        if sc.shard_decode:
            from repro.distributed import sharding as sharding_lib
            from repro.launch.mesh import make_host_mesh

            self.mesh = make_host_mesh()
            rules = sharding_lib.ShardingRules(self.mesh)
            self.sharding_rules = rules
            self.params = jax.device_put(
                self.params, sharding_lib.param_shardings(rules, cfg, lm)
            )
            cache_sh = self.cache_mgr.device_shardings(rules)
            self.caches = jax.device_put(self.caches, cache_sh)
            table_sh = cache_sh.get("layers", {}).get("page_table")
            if table_sh is not None:
                self.cache_mgr.table_sharding = table_sh
            # pinning every program's cache outputs to the SAME shardings
            # the pools were placed with (and the small per-slot arrays to
            # a replicated placement) is what keeps the jit caches at one
            # program each: left to GSPMD, a program's chosen output
            # sharding (e.g. the page table partitioned over 'model') can
            # differ from the host-side placement, and the next dispatch
            # would re-key on the flip-flopping operand sharding
            self._cache_out_sh = cache_sh
            self._rep_sh = jax.sharding.NamedSharding(
                self.mesh, jax.sharding.PartitionSpec()
            )

        # Device-resident decode carry (async loop): the scan's final
        # (token, position, active, budget) per slot, kept on device so
        # consecutive decode dispatches chain without a host round-trip.
        # ``_carry_valid[i]`` False means host state is authoritative for
        # slot i (fresh admission / extend handoff / preemption / release
        # since the last dispatch); the merge happens outside jit, so the
        # decode program's signature — and the jit budget — is unchanged.
        self._carry = None
        self._carry_valid = np.zeros((sc.max_batch,), bool)
        #: dispatch stamping for the Slot.inflight protocol (see
        #: InflightStep.seq)
        self._dispatch_seq = 0
        self._slot_dispatch = [-1] * sc.max_batch
        #: conservative per-slot upper bound on the next write position
        #: while a dispatch is in flight (drives page ensure() when the
        #: true position is still on device)
        self._pos_ub = [0] * sc.max_batch

        # Bit-exact datapath predicate: is a decode-path forward bitwise
        # identical to the prefill-path forward for the same token at the
        # same position?  True for float GQA with the exact softmax on the
        # jnp reference path — prefill's attention_ref and decode's
        # gather-view attend are then the same f32 math.  False for MLA
        # (~1 ulp: different einsum orders when re-materializing K/V from
        # the latent), int8 KV (prefill attends float K/V, decode attends
        # dequantized codes), and LUT softmax (decode uses exact softmax).
        # The scheduler gates prefill-skip, preemption-resume, and chunked
        # prefill on this capability so token streams stay bit-identical
        # to dense.
        self.bit_exact = (
            cfg.attn_kind == "gqa"
            and not self.quant_cache
            and self.kernel.get("softmax_mode", "safe") == "safe"
            and not self.kernel.get("use_pallas", False)
        )

        # right-padding the prompt is only sound when the cache is
        # position-addressed and decode masks by position: true for dense
        # GQA / MLA caches, false for SSM/hybrid state and for rolling
        # sliding-window buffers (padding would evict real tokens).
        self.bucketable = self.cache_mgr.position_addressed
        # a bucket longer than the cache could not be inserted; drop those
        self.buckets = (
            tuple(b for b in sc.resolved_buckets() if b <= sc.max_seq_len)
            if self.bucketable
            else ()
        )

        # Cache-extending prefill program: ONE extra jitted program at a
        # fixed (max_batch, extend_width) shape.  Replayed tokens go
        # through the prefill-path forward against the populated caches,
        # which is what lets the scheduler plan prefill-skip / chunked /
        # preemption-resume admissions on datapaths where the decode
        # scan is NOT bitwise the prefill.  The window attend mirrors
        # the jnp reference path, so engines on the Pallas kernel keep
        # the legacy bit-exact gating (their prefill math is the
        # streaming kernel, not the reference).
        self.extend_width = (
            (sc.prefill_chunk or max(self.buckets)) if self.buckets else 0
        )
        self.cache_extend = bool(
            sc.cache_extend
            and self.bucketable
            and self.extend_width > 0
            and not self.kernel.get("use_pallas", False)
        )
        rep, csh = self._rep_sh, self._cache_out_sh
        self._decode_fn = jax.jit(
            self._decode_scan,
            out_shardings=(rep, rep, rep, rep, rep, rep, csh),
        ) if self.mesh is not None else jax.jit(self._decode_scan)
        self._prefill_fn: dict[int, Any] = {}  # jit cache per bucket length
        if not self.cache_extend:
            self._extend_fn = None
        elif self.mesh is not None:
            self._extend_fn = jax.jit(
                self._extend_batch, out_shardings=(rep, csh)
            )
        else:
            self._extend_fn = jax.jit(self._extend_batch)
        # Step-phase tracer (serve/phases.py), assigned by the Engine when
        # ServeConfig.trace_phases is on.  The default NULL_TRACER is a
        # shared no-op whose fence() never touches the device, so the
        # untraced hot loop is byte-for-byte the historical one.
        self.tracer = NULL_TRACER
        self.tel = {
            "tokens_generated": 0,
            "prefill_compiles": 0,
            "prefill_dispatches": 0,
            "decode_compiles": 0,
            "extend_compiles": 0,
            "extend_dispatches": 0,
            "prefill_time_s": 0.0,
            "decode_time_s": 0.0,
            "extend_time_s": 0.0,
            "draft_tokens_proposed": 0,
            "draft_tokens_accepted": 0,
            "spec_dispatches": 0,
            "spec_time_s": 0.0,
            "steps": 0,
        }

        # Speculative decoding: a draft model proposes spec_tokens greedy
        # tokens per resident decoding slot; the target verifies the whole
        # window in ONE cache-extending dispatch and accepts the longest
        # matching prefix plus a correction token.  The verify path IS the
        # extend program (no new target program — the len(buckets)+2
        # budget holds); rejected draft tokens rewind through the same
        # position-idempotent window-write machinery that extend replay
        # uses, which is why the feature is gated to cache_extend
        # datapaths.  The draft worker owns its own bounded program set
        # (at most len(buckets) draft prefills + 1 propose scan).
        self.draft: DraftWorker | None = None
        self.spec_k = 0
        if sc.speculative and not self.cache_extend:
            warnings.warn(
                "speculative decoding disabled: it verifies drafts through "
                "the cache-extending prefill program, which this datapath "
                "does not support (cache_extend off, unbucketable cache, "
                "or the Pallas prefill kernel)",
                RuntimeWarning,
                stacklevel=3,
            )
        elif sc.speculative:
            dcfg, dparams = draft if draft is not None else (
                self.cfg, self.params
            )
            if dcfg.vocab_size != cfg.vocab_size:
                raise ValueError(
                    "draft model must share the target vocabulary: "
                    f"draft {dcfg.vocab_size} vs target {cfg.vocab_size}"
                )
            self.spec_k = max(1, min(int(sc.spec_tokens), self.extend_width))
            self.draft = DraftWorker(
                dcfg, dparams, sc, self.buckets, self.spec_k
            )

    # ------------------------------------------------------------- view --
    @property
    def caps(self) -> ExecutorCaps:
        """Capabilities schedulers plan against (policy never inspects
        device state directly)."""
        return ExecutorCaps(
            max_batch=self.serve_cfg.max_batch,
            max_seq_len=self.serve_cfg.max_seq_len,
            decode_steps=self.serve_cfg.decode_steps,
            buckets=self.buckets,
            bucketable=self.bucketable,
            paged=self.kv_layout == "paged",
            bit_exact=self.bit_exact,
            prefix_cache=self.cache_mgr.prefix_cache,
            cache_extend=self.cache_extend,
        )

    def kv_stats(self) -> dict:
        """Current KV-cache occupancy (layout, bytes, page utilization)."""
        return self.cache_mgr.stats().as_dict()

    # ------------------------------------------------------------ device --
    def _prefill_batch(self, params, tokens, lengths, caches, slots,
                       shared=None):
        """Prefill up to ``max_batch`` same-bucket prompts in ONE dispatch.

        ``tokens``: (max_batch, bucket) int32, right-padded per row.
        ``lengths``: (max_batch,) true prompt lengths (0 for pad rows).
        ``slots``: (max_batch,) destination slot per row; the value
        ``max_batch`` marks a pad row (dropped by the dense scatter,
        routed to the trash page by the paged scatter).
        ``shared``: (max_batch,) leading prefix-cache pages per row whose
        recomputed values must not touch shared storage (their insert
        columns scatter to the trash page; 0 everywhere when the prefix
        cache is off).
        All four are traced, so every same-bucket wave reuses one
        compiled program.  Returns (per-row last-token logits (N, V),
        updated caches).
        """
        cfg = self.cfg
        nb, bucket = tokens.shape
        mask = jnp.arange(bucket, dtype=jnp.int32)[None, :] < lengths[:, None]
        tokens = jnp.where(mask, tokens, 0)  # canonical pad id
        # the model writes its natural contiguous (dense) scratch cache;
        # insert_prefill is the only layout-specific step.  Paged: the
        # scratch only needs to cover the bucket (rounded up to whole
        # pages), so the transient footprint scales with the bucket, not
        # with max_batch x max_seq_len.  Dense keeps the full-length
        # scratch: its insert scatters whole slot slabs (bit-identical
        # historical behavior, zeroed tail included).
        if self.kv_layout == "paged":
            ps = self.cache_mgr.page_size
            scratch_len = -(-bucket // ps) * ps
        else:
            scratch_len = self.serve_cfg.max_seq_len
        small = kv_cache.init_caches(
            cfg, nb, scratch_len,
            dtype=jnp.float32, quantized=self.quant_cache,
        )
        logits, filled, _ = lm.forward(
            params, cfg, {"tokens": tokens}, mode="prefill",
            caches=small, kernel=self.kernel,
        )
        # causal attention keeps positions < length independent of the pad
        # tail; each row's true logits live at index length-1
        idx = jnp.maximum(lengths - 1, 0)[:, None, None]
        last = jnp.take_along_axis(logits, idx, axis=1)[:, 0]
        filled = kv_cache.mask_cache_tail(filled, lengths)
        new_caches = self.cache_mgr.insert_prefill(
            caches, filled, slots, shared
        )
        return last, new_caches

    def _extend_batch(self, params, tokens, win_len, starts, caches):
        """Extend resident slots' caches by one token window each in ONE
        fixed-shape dispatch (the cache-extending prefill program).

        ``tokens``: (max_batch, extend_width) int32, right-padded per
        row.  ``win_len``: (max_batch,) valid tokens per row (0 = idle
        row).  ``starts``: (max_batch,) each row's first write position.
        Row i is slot i — the same full-batch convention as the decode
        scan, so no slot gather is needed.  The forward runs in
        ``extend`` mode: window tokens are written at global positions
        ``starts + [0, W)`` through the dense/paged scatter and attended
        with prefill-path math against history + window, making the new
        cache entries and logits bitwise what a whole-prompt prefill
        would have produced at those positions.  Masked entries carry
        the ``max_seq_len`` sentinel position (dropped / trash-paged).
        Returns (full per-window logits (max_batch, W, V), updated
        caches) — tail replay selects each row's last valid position
        eagerly on host, while speculative verification consumes every
        window position's logits, so ONE program serves both.
        """
        cfg = self.cfg
        nb, w = tokens.shape
        mask = jnp.arange(w, dtype=jnp.int32)[None, :] < win_len[:, None]
        tokens = jnp.where(mask, tokens, 0)  # canonical pad id
        positions = starts[:, None] + jnp.arange(w, dtype=jnp.int32)[None, :]
        positions = jnp.where(mask, positions, self.serve_cfg.max_seq_len)
        logits, new_caches, _ = lm.forward(
            params, cfg, {"tokens": tokens}, mode="extend",
            caches=caches, positions=positions, kernel=self.kernel,
        )
        return logits, new_caches

    def _decode_scan(self, params, tokens, positions, active, rem, eos,
                     temp, top_k, top_p, seed, forced, n_forced, caches,
                     key):
        """Run ``decode_steps`` fused decode steps under one dispatch.

        All arrays are per-slot (B,): ``tokens`` last sampled token,
        ``positions`` next write position, ``active`` live mask, ``rem``
        generation budget left, ``eos`` per-request eos id (-1 = none).
        ``temp``/``top_k``/``top_p``/``seed`` are the stacked per-request
        sampling knobs (scheduler-stamped sentinels when absent — see
        ``encode_sampling``); they ride the dispatch exactly like ``eos``
        so a mixed greedy/sampled batch shares this one program.
        Inactive slots freeze (token, position); re-running a frozen
        position is idempotent for position-addressed caches (dense slabs
        and pages alike — retired paged slots write the trash page) and
        harmless for retired SSM slots (their state is overwritten on
        re-prefill).

        ``forced``: (decode_steps, B) teacher-forced next tokens,
        ``n_forced``: (B,) how many leading steps of this dispatch force
        each slot (prefix-cache prefill-skip and chunked prefill: the
        unprefilled prompt tail rides the decode program).  A forced step
        writes its prompt token's KV, overrides the sampled next token,
        emits nothing, and leaves the generation budget and eos/budget
        deactivation alone — so the first *sampled* token after the tail
        sees logits bitwise equal to the prefill path's last-position
        logits.  All zeros when nothing is forced, which reduces to the
        historical behavior.
        Returns (per-step next tokens, per-step emit mask, final carry
        token, final positions, final active mask, final budget,
        caches).
        """
        sc = self.serve_cfg
        keys = jax.random.split(key, sc.decode_steps)
        flags = (
            jnp.arange(sc.decode_steps, dtype=jnp.int32)[:, None]
            < n_forced[None, :]
        )  # (T, B)

        def body(carry, xs):
            k, forced_t, flag_t = xs
            tok, pos, act, budget, c = carry
            logits, new_c, _ = lm.forward(
                params, self.cfg, {"tokens": tok[:, None]}, mode="decode",
                caches=c, positions=pos, kernel=self.kernel,
            )
            sampled = sample_tokens(
                logits[:, -1], k, temperature=temp, top_k=top_k,
                top_p=top_p, seed=seed, positions=pos,
            )
            nxt = jnp.where(act, jnp.where(flag_t, forced_t, sampled), tok)
            emit = act & ~flag_t
            emitted = (nxt, emit)
            budget = jnp.where(emit, budget - 1, budget)
            new_pos = jnp.where(act, pos + 1, pos)
            new_act = (
                act
                & (flag_t | ((nxt != eos) & (budget > 0)))
                & (new_pos + 1 < sc.max_seq_len)
            )
            return (nxt, new_pos, new_act, budget, new_c), emitted

        init = (tokens, positions, active, rem, caches)
        (tok, pos, act, rem, caches), (toks_t, emit_t) = jax.lax.scan(
            body, init, (keys, forced, flags)
        )
        # the final budget rides along so the async loop's device carry
        # can chain dispatches without reading ``generated`` on host
        return toks_t, emit_t, tok, pos, act, rem, caches

    # ----------------------------------------------------------- execute --
    def execute(self, decision: ScheduleDecision) -> StepOutput:
        """Apply one :class:`ScheduleDecision` synchronously:
        :meth:`dispatch` then :meth:`collect`, back to back.  The legacy
        engine loop — byte-identical op order to the historical
        monolithic ``execute`` (the async loop interleaves the two
        halves across steps instead)."""
        return self.collect(self.dispatch(decision))

    def dispatch(self, decision: ScheduleDecision) -> InflightStep:
        """The non-blocking half: reset preempted slots, activate
        admissions (prefix-skip slots immediately, prefill / chunked
        slots through their bucket dispatches), drain cache-extend
        windows, then *enqueue* the decode scan and return without
        waiting for it.  Prefill and extend stay internally synchronous
        (their first tokens must be sampled host-side either way); the
        decode scan — the steady-state hot path — is what pipelines.
        The scheduler already performed the host-side page bookkeeping;
        nothing here chooses anything."""
        tel = self.tel
        tel["steps"] += 1
        out = StepOutput(stats={"prefilled": 0, "decoded": 0})
        for idx, req in decision.preempted:
            # pages were freed by the scheduler; drop the execution state
            self.slots[idx] = Slot()
            self._host_dirty(idx)
            out.preempted.append(req)
        for adm in decision.admissions:
            slot = self.slots[adm.slot]
            slot.admit_seq = adm.admit_seq
            slot.admit_gen = adm.admit_gen
            if adm.mode in (MODE_SKIP, MODE_FORK):
                # MODE_SKIP: the shared pages hold every position <
                # write_from; no prompt-prefill dispatch at all — the
                # remaining tail replays per the admission's split.
                # MODE_FORK is mechanically identical: the n-best child
                # entered owning refcounted views of its parent's pages
                # (prompt AND generated-into), so only the prompt's last
                # token replays; the child's first diverging write
                # copy-on-writes it off the shared last page
                slot.active, slot.request = True, adm.request
                slot.pos = adm.write_from
                self._activate_tail(slot, adm, adm.write_from)
                self._host_dirty(adm.slot)
                out.stats["prefilled"] += 1
        for bucket, group in decision.prefill_groups.items():
            self._dispatch_prefill(bucket, group, out)
        self._dispatch_extend(decision, out)
        spec_served = self._dispatch_speculative(decision, out)
        return self._dispatch_decode(decision, out, exclude=spec_served)

    def collect(self, inflight: InflightStep) -> StepOutput:
        """The blocking half: transfer the decode scan's results to host
        (the only point the loop waits on the device), route emitted
        tokens into each slot's request, update slot execution state
        from the device carry, and retire finished slots.  Under the
        async loop this runs one step late — while the *next* decision's
        dispatch is already in flight — so each slot is re-checked
        against the dispatch-time ``snapshot``: a cancelled or
        turned-over slot's in-flight tokens are discarded, never routed
        to a dead stream."""
        out = inflight.out
        if inflight.dev is None:
            return out
        tel, tr = self.tel, self.tracer
        decision = inflight.decision
        tr.collect_begin(inflight.t_dispatch)
        with tr.phase(tr.collect_phase):
            toks_t, emit_t, tok_f, pos_f, act_f, rem_f = (
                np.asarray(x) for x in inflight.dev
            )
        tel["decode_time_s"] += time.perf_counter() - inflight.t0
        with tr.phase("sample"):
            for idx in inflight.decode_set:
                slot = self.slots[idx]
                req = inflight.snapshot.get(idx)
                if (
                    req is None
                    or req.cancelled
                    or not slot.active
                    or slot.request is not req
                    or slot.admit_seq != inflight.admit_seqs.get(idx, -2)
                ):
                    # mid-flight cancel, preemption, or slot turnover
                    # (including the same request re-admitted into the
                    # same slot — the admit_seq stamp): the tokens this
                    # dispatch produced for the slot are dropped at the
                    # one-step-stale boundary; pages were already freed
                    # by release()/preempt, and a preempted request
                    # regenerates the discarded tokens after resume
                    continue
                for t in range(toks_t.shape[0]):
                    if not emit_t[t, idx]:
                        continue
                    req.generated.append(int(toks_t[t, idx]))
                    out.stats["decoded"] += 1
                    tel["tokens_generated"] += 1
                    out.tokens.append((
                        req.uid, int(toks_t[t, idx]),
                        len(req.generated) - 1,
                    ))
                slot.pos = int(pos_f[idx])
                slot.last_token = int(tok_f[idx])
                if decision.register_decoded:
                    # decode-completed full pages become shareable too:
                    # their content is bit-exact with a prefill of the
                    # same tokens on this datapath
                    self.cache_mgr.register_filled(
                        idx, req.resume_tokens, slot.pos
                    )
                if not act_f[idx]:
                    out.finished.append(req)
                    self.slots[idx] = Slot()
                    self.cache_mgr.free(idx)
                    self._host_dirty(idx)
                else:
                    self._retire(idx, out)
        # clear in-flight marks (also for skipped/cancelled slots) —
        # unless a newer dispatch already re-marked the slot (the async
        # loop dispatches N+1 before collecting N, often over the same
        # slots); the marks tell policies which residents have an
        # uncollected dispatch (preempting one discards its tokens)
        for idx in inflight.decode_set:
            if self._slot_dispatch[idx] == inflight.seq:
                self.slots[idx].inflight = False
        return out

    def _activate_tail(self, slot: Slot, adm: Admission, start: int) -> None:
        """Split an admission's unwritten token tail per its
        ``decode_from`` stamp: positions in [start, decode_from) replay
        through the cache-extending prefill program, positions from
        ``decode_from`` on teacher-force through the decode scan.  With
        ``decode_from == start`` (the bit-exact datapaths' plan) the
        whole tail rides the decode scan and the carry token is primed
        immediately — the historical behavior, byte for byte."""
        tail = list(adm.tokens[start:adm.decode_from])
        pend = list(adm.tokens[adm.decode_from:])
        if tail:
            slot.prefill_tail = tail
            slot.pending = pend
        else:
            slot.last_token = pend[0]
            slot.pending = pend[1:]

    def release(self, idx: int) -> None:
        """Immediately free a resident slot's pages and execution state
        (request cancellation); safe on inactive slots.  An in-flight
        dispatch covering the slot keeps writing through its captured
        page table — device program order guarantees those writes land
        before any later dispatch reuses the freed pages, and the next
        table sync points the row at the trash page."""
        self.cache_mgr.free(idx)
        self.slots[idx] = Slot()
        self._host_dirty(idx)

    def _host_dirty(self, idx: int) -> None:
        """Mark host slot state authoritative for ``idx``: the device
        carry must not override it at the next decode dispatch (fresh
        admission, extend handoff, preemption, release, retire).  The
        same turnovers invalidate the slot's draft-cache sync stamp: the
        draft worker re-prefills the row from its token history before
        speculating for it again."""
        self._carry_valid[idx] = False
        self._pos_ub[idx] = self.slots[idx].pos
        if self.draft is not None:
            self.draft.pos[idx] = -1

    def _reserve_cap(self, req: Request) -> int:
        """The admission-time worst-case length reservation for ``req``
        (scheduler ``_reserve_len``): the hard cap for conservative page
        ``ensure`` while the true position is still on device."""
        return min(
            len(req.prompt) + req.max_new_tokens, self.serve_cfg.max_seq_len
        )

    def _dispatch_prefill(
        self, bucket: int, group: list[Admission], out: StepOutput
    ):
        """One fixed-shape prefill dispatch filling every slot in ``group``
        (all rows share ``bucket``); pad rows carry the slot sentinel
        ``max_batch`` so their writes are dropped.  Each row's dispatched
        tokens are its effective prompt (original prompt + generated-so-far
        for a preempted request being resumed) truncated to ``fill_len``
        (the whole prompt for MODE_PREFILL, the first chunk for
        MODE_CHUNKED) and ``shared_pages`` its count of prefix-cache pages
        the insert must not overwrite.  Only MODE_PREFILL rows sample a
        first token from the dispatch's last-position logits; a chunk's
        logits predict a prompt token the request already has, so chunked
        rows activate with their teacher-forced tail instead."""
        sc, tel, tr = self.serve_cfg, self.tel, self.tracer
        nb = sc.max_batch
        with tr.phase("host_prep"):
            toks = np.zeros((nb, bucket), np.int32)
            lengths = np.zeros((nb,), np.int32)
            slots_arr = np.full((nb,), nb, np.int32)
            shared_arr = np.zeros((nb,), np.int32)
            for row, adm in enumerate(group):
                n = adm.fill_len
                toks[row, :n] = adm.tokens[:n]
                lengths[row] = n
                slots_arr[row] = adm.slot
                shared_arr[row] = adm.shared_pages
            # victim-tier movement queued by this step's admissions must
            # land before the prefill program runs: spills drain the
            # rows of pages the scatter is about to overwrite, swap-ins
            # fill the covered columns the scatter redirects to trash
            self.caches = self.cache_mgr.flush_swaps(self.caches)
            self.caches = self.cache_mgr.write_table(self.caches)
        fn = self._prefill_fn.get(bucket)
        if fn is None:
            if self.mesh is not None:
                fn = jax.jit(
                    self._prefill_batch,
                    out_shardings=(self._rep_sh, self._cache_out_sh),
                )
            else:
                fn = jax.jit(self._prefill_batch)
            self._prefill_fn[bucket] = fn
            tel["prefill_compiles"] += 1
        t0 = time.perf_counter()
        with tr.phase("dispatch"):
            last, self.caches = fn(
                self.params, jnp.asarray(toks), jnp.asarray(lengths),
                self.caches, jnp.asarray(slots_arr), jnp.asarray(shared_arr),
            )
        with tr.phase("device"):
            tr.fence((last, self.caches))
        tel["prefill_dispatches"] += 1
        # one vectorized sample + one device->host transfer for the group;
        # the admission carries the scheduler-stamped per-request knobs
        self.key, sub = jax.random.split(self.key)
        with tr.phase("sample"):
            knobs = [adm.sampling for adm in group]
            first_tokens = np.asarray(
                sample_tokens(
                    last[:len(group)], sub,
                    temperature=jnp.asarray(
                        [s[0] for s in knobs], jnp.float32
                    ),
                    top_k=jnp.asarray([s[1] for s in knobs], jnp.int32),
                    top_p=jnp.asarray([s[2] for s in knobs], jnp.float32),
                    seed=jnp.asarray([s[3] for s in knobs], jnp.int32),
                    positions=jnp.asarray(
                        [len(adm.tokens) - 1 for adm in group], jnp.int32
                    ),
                )
            )
            for row, adm in enumerate(group):
                slot = self.slots[adm.slot]
                slot.active, slot.request = True, adm.request
                self._host_dirty(adm.slot)
                if adm.emits_first_token:
                    nxt = int(first_tokens[row])
                    adm.request.generated.append(nxt)
                    tel["tokens_generated"] += 1
                    out.tokens.append(
                        (adm.request.uid, nxt, len(adm.request.generated) - 1)
                    )
                    slot.pos = len(adm.tokens)  # next write position
                    slot.last_token = nxt
                else:  # MODE_CHUNKED: tail replays per the admission split
                    slot.pos = adm.fill_len
                    self._activate_tail(slot, adm, adm.fill_len)
                out.stats["prefilled"] += 1
                self._retire(adm.slot, out)
        tel["prefill_time_s"] += time.perf_counter() - t0

    def _dispatch_extend(self, decision: ScheduleDecision, out: StepOutput):
        """ONE fixed-shape dispatch draining every listed slot's prefill
        tail by up to ``extend_width`` tokens through the cache-extending
        prefill program.  A slot whose tail fully drains either hands off
        to its teacher-forced pending (preemption resume: the generated
        part replays through the decode math that originally wrote it) or
        samples its first token from the window's last-position logits —
        exactly the logits a whole-prompt prefill would have produced."""
        work = [
            i for i in decision.extend_slots
            if self.slots[i].active and self.slots[i].prefill_tail
        ]
        if not work:
            return
        sc, tel, tr = self.serve_cfg, self.tel, self.tracer
        nb, w = sc.max_batch, self.extend_width
        with tr.phase("host_prep"):
            toks = np.zeros((nb, w), np.int32)
            lens = np.zeros((nb,), np.int32)
            starts = np.zeros((nb,), np.int32)
            for i in work:
                slot = self.slots[i]
                n = min(len(slot.prefill_tail), w)
                toks[i, :n] = slot.prefill_tail[:n]
                lens[i] = n
                starts[i] = slot.pos
                # grow pages over the write range; shared pages
                # overlapping it are copy-on-write replaced pre-scatter
                self.cache_mgr.ensure(i, slot.pos + n, write_from=slot.pos)
            # swaps before CoW copies: a CoW destination can be a
            # just-evicted page whose rows must spill first
            self.caches = self.cache_mgr.flush_swaps(self.caches)
            self.caches = self.cache_mgr.flush_copies(self.caches)
            self.caches = self.cache_mgr.write_table(self.caches)
        if tel["extend_compiles"] == 0:
            tel["extend_compiles"] = 1  # one program, fixed shapes
        t0 = time.perf_counter()
        with tr.phase("dispatch"):
            logits, self.caches = self._extend_fn(
                self.params, jnp.asarray(toks), jnp.asarray(lens),
                jnp.asarray(starts), self.caches,
            )
        with tr.phase("device"):
            tr.fence((logits, self.caches))
        tel["extend_dispatches"] += 1
        self.key, sub = jax.random.split(self.key)
        with tr.phase("sample"):
            # each row's true logits live at its window's last valid
            # position (selected eagerly — the program returns the full
            # window so speculative verification can reuse it)
            idx = np.maximum(lens - 1, 0)
            last = jnp.take_along_axis(
                logits, jnp.asarray(idx)[:, None, None], axis=1
            )[:, 0]
            knobs = [
                encode_sampling(
                    self.slots[i].request if i in work else None,
                    sc.temperature,
                )
                for i in range(nb)
            ]
            first_tokens = np.asarray(
                sample_tokens(
                    last, sub,
                    temperature=jnp.asarray(
                        [s[0] for s in knobs], jnp.float32
                    ),
                    top_k=jnp.asarray([s[1] for s in knobs], jnp.int32),
                    top_p=jnp.asarray([s[2] for s in knobs], jnp.float32),
                    seed=jnp.asarray([s[3] for s in knobs], jnp.int32),
                    positions=jnp.asarray(starts + idx, jnp.int32),
                )
            )
            for i in work:
                slot = self.slots[i]
                n = int(lens[i])
                del slot.prefill_tail[:n]
                slot.pos += n
                self._host_dirty(i)
                if slot.prefill_tail:
                    continue  # another window next step
                if slot.pending:
                    # resume handoff: the generated part teacher-forces
                    # through the decode scan from here
                    slot.last_token = slot.pending.pop(0)
                else:
                    nxt = int(first_tokens[i])
                    slot.request.generated.append(nxt)
                    tel["tokens_generated"] += 1
                    out.tokens.append(
                        (slot.request.uid, nxt,
                         len(slot.request.generated) - 1)
                    )
                    slot.last_token = nxt
                # window-written full pages hold prefill-path content —
                # as shareable as a bucket dispatch's, on every datapath
                self.cache_mgr.register_filled(
                    i, slot.request.resume_tokens, slot.pos
                )
                self._retire(i, out)
        tel["extend_time_s"] += time.perf_counter() - t0

    def _dispatch_speculative(
        self, decision: ScheduleDecision, out: StepOutput
    ) -> set[int]:
        """Advance eligible decode slots by up to ``spec_k + 1`` tokens in
        one draft-propose + one target-verify dispatch; returns the slots
        served (the decode scan skips them this step).

        The draft greedily proposes ``spec_k`` tokens per slot.  The
        target verifies the whole window [carry, d1..d_{k-1}] through the
        cache-extending prefill program at starts = pos: the window's
        logits at offset j are exactly what the decode scan would have
        produced for position pos+j, so sampling them with the same
        per-request knobs and position-keyed PRNG yields the target's own
        token s_j.  The accepted prefix is the run of j with s_j == d_j;
        one correction token (the target's sample at the first mismatch)
        always ships, so a fully-rejected draft still nets one token —
        greedy speculative output is bitwise the non-speculative stream
        on bit-exact datapaths (test-enforced).  Rejected window
        positions hold stale KV that the next window/decode write
        overwrites — the same position-idempotence extend replay relies
        on, which is why speculation is gated to cache_extend datapaths.

        Host emission replicates the decode scan's deactivation rules
        exactly: emit eos then stop, stop at budget zero, stop when the
        next write position would reach max_seq_len.  Served slots are
        marked host-dirty (the async carry never covers them), then the
        draft sync stamp is advanced — the accepted prefix was written to
        the draft cache during proposal, so steady-state speculation
        needs no draft resync at all.
        """
        if self.draft is None:
            return set()
        sc, tel, tr = self.serve_cfg, self.tel, self.tracer
        k, nb = self.spec_k, sc.max_batch
        cand: list[int] = []
        for i in sorted(set(decision.decode_slots)):
            slot = self.slots[i]
            if not slot.active or slot.prefill_tail or slot.pending:
                continue
            if slot.request.cancelled:
                continue
            if self.async_loop and self._carry_valid[i]:
                continue  # the device carry owns this slot's truth
            if slot.request.max_new_tokens <= len(slot.request.generated):
                continue
            if slot.pos + k > sc.max_seq_len - 1:
                continue  # near the cap: plain decode finishes it
            cand.append(i)
        if not cand:
            return set()
        t0 = time.perf_counter()
        with tr.phase("host_prep"):
            # resync draft cache rows whose (pos, carry) drifted from the
            # target's — any admission/extend/preempt/release turnover
            # invalidated them via _host_dirty
            need: list[tuple[int, list[int]]] = []
            fit: list[int] = []
            for i in cand:
                slot = self.slots[i]
                if (
                    self.draft.pos[i] == slot.pos
                    and self.draft.tok[i] == slot.last_token
                ):
                    fit.append(i)
                    continue
                hist = list(slot.request.resume_tokens[: slot.pos])
                if hist and self.draft.bucket_for(len(hist)) is None:
                    continue  # history outgrew the draft buckets
                need.append((i, hist))
                fit.append(i)
            cand = fit
            if not cand:
                tel["spec_time_s"] += time.perf_counter() - t0
                return set()
            self.draft.sync(need, tel)
            for i, _ in need:
                self.draft.pos[i] = self.slots[i].pos
                self.draft.tok[i] = self.slots[i].last_token
            # propose: one draft scan over the full batch
            d_tok = np.zeros((nb,), np.int32)
            d_pos = np.zeros((nb,), np.int32)
            d_act = np.zeros((nb,), bool)
            for i in cand:
                d_tok[i] = self.slots[i].last_token
                d_pos[i] = self.slots[i].pos
                d_act[i] = True
        with tr.phase("dispatch"):
            toks_t, self.draft.caches = self.draft._propose_fn(
                self.draft.params, jnp.asarray(d_tok), jnp.asarray(d_pos),
                jnp.asarray(d_act), self.draft.caches,
            )
        props = np.asarray(toks_t)  # (k, nb)
        with tr.phase("host_prep"):
            # verify: ONE extend dispatch over [carry, d1..d_{k-1}]
            vt = np.zeros((nb, self.extend_width), np.int32)
            vl = np.zeros((nb,), np.int32)
            vs = np.zeros((nb,), np.int32)
            for i in cand:
                slot = self.slots[i]
                vt[i, 0] = slot.last_token
                vt[i, 1:k] = props[: k - 1, i]
                vl[i] = k
                vs[i] = slot.pos
                self.cache_mgr.ensure(i, slot.pos + k, write_from=slot.pos)
            self.caches = self.cache_mgr.flush_swaps(self.caches)
            self.caches = self.cache_mgr.flush_copies(self.caches)
            self.caches = self.cache_mgr.write_table(self.caches)
        with tr.phase("dispatch"):
            logits, self.caches = self._extend_fn(
                self.params, jnp.asarray(vt), jnp.asarray(vl),
                jnp.asarray(vs), self.caches,
            )
        with tr.phase("device"):
            tr.fence((logits, self.caches))
        tel["spec_dispatches"] += 1
        self.key, sub = jax.random.split(self.key)
        with tr.phase("sample"):
            knobs = [
                encode_sampling(
                    self.slots[i].request if i in cand else None,
                    sc.temperature,
                )
                for i in range(nb)
            ]
            temp = jnp.asarray([s[0] for s in knobs], jnp.float32)
            top_k = jnp.asarray([s[1] for s in knobs], jnp.int32)
            top_p = jnp.asarray([s[2] for s in knobs], jnp.float32)
            seedv = jnp.asarray([s[3] for s in knobs], jnp.int32)
            samp = np.stack([
                np.asarray(
                    sample_tokens(
                        logits[:, t], jax.random.fold_in(sub, t),
                        temperature=temp, top_k=top_k, top_p=top_p,
                        seed=seedv,
                        positions=jnp.asarray(d_pos + t, jnp.int32),
                    )
                )
                for t in range(k)
            ])  # (k, nb): the target's own token at each window offset
            served: set[int] = set()
            for i in cand:
                slot = self.slots[i]
                req = slot.request
                d = [int(props[t, i]) for t in range(k)]
                s = [int(samp[t, i]) for t in range(k)]
                m = 0
                while m < k and s[m] == d[m]:
                    m += 1
                emitted = d[:m] + ([] if m == k else [s[m]])
                req.draft_proposed += k
                req.draft_accepted += m
                tel["draft_tokens_proposed"] += k
                tel["draft_tokens_accepted"] += m
                base = slot.pos
                n_emit = 0
                for nxt in emitted:
                    req.generated.append(nxt)
                    out.stats["decoded"] += 1
                    tel["tokens_generated"] += 1
                    out.tokens.append((req.uid, nxt, len(req.generated) - 1))
                    n_emit += 1
                    if (
                        (req.eos_id is not None and nxt == req.eos_id)
                        or len(req.generated) >= req.max_new_tokens
                        or base + n_emit + 1 >= sc.max_seq_len
                    ):
                        break
                slot.pos = base + n_emit
                slot.last_token = emitted[n_emit - 1]
                self._host_dirty(i)
                # accepted positions were written to the draft cache
                # during proposal, so the draft is synced by construction
                self.draft.pos[i] = slot.pos
                self.draft.tok[i] = slot.last_token
                self.cache_mgr.register_filled(
                    i, req.resume_tokens, slot.pos
                )
                self._retire(i, out)
                served.add(i)
        tel["spec_time_s"] += time.perf_counter() - t0
        return served

    def _dispatch_decode(
        self,
        decision: ScheduleDecision,
        out: StepOutput,
        exclude: frozenset[int] | set[int] = frozenset(),
    ) -> InflightStep:
        """Enqueue the decode scan for the decision's decode slots
        (per-slot active masks; slots outside the decision freeze for
        this dispatch; a slot still draining a prefill tail is not ready
        to decode) and return the :class:`InflightStep` without waiting.

        Synchronous mode builds every scan input from host slot state —
        the historical op order, byte for byte.  Async mode merges the
        device carry over the host arrays (outside jit: the program
        signature is unchanged) for slots whose last dispatch has not
        been collected yet, so consecutive decode dispatches chain
        entirely on device; page ``ensure`` then works on a conservative
        position upper bound (stale-low ``write_from``, +decode_steps
        upper), which can only over-cover the true write range — extra
        pages stay within the admission-time worst-case reservation."""
        sc, tel, tr = self.serve_cfg, self.tel, self.tracer
        decode_set = {
            i for i in decision.decode_slots
            if self.slots[i].active
            and not self.slots[i].prefill_tail
            and i not in exclude  # already advanced speculatively
        }
        if not decode_set:
            return InflightStep(out=out, decision=decision)
        nb = sc.max_batch
        use_carry = self.async_loop and self._carry is not None
        with tr.phase("host_prep"):
            forced = np.zeros((sc.decode_steps, nb), np.int32)
            n_forced = np.zeros((nb,), np.int32)
            for idx in sorted(decode_set):
                slot = self.slots[idx]
                nf = min(len(slot.pending), sc.decode_steps)
                if nf:
                    forced[:nf, idx] = slot.pending[:nf]
                    n_forced[idx] = nf
                    # consumed by THIS dispatch: trimming here (not at
                    # collect) keeps the next dispatch's forced window
                    # correct even before this one is collected
                    del slot.pending[:nf]
                if use_carry and self._carry_valid[idx]:
                    # true position still on device: ensure against the
                    # conservative upper bound; write_from = the stale
                    # host pos (a lower bound) so CoW covers the range
                    base = self._pos_ub[idx]
                    upto = min(
                        base + sc.decode_steps,
                        self._reserve_cap(slot.request),
                    )
                    self._pos_ub[idx] = upto
                    self.cache_mgr.ensure(idx, upto, write_from=slot.pos)
                    continue
                # the scan advances at most min(decode_steps, forced
                # tail + remaining budget) positions, so this never
                # outgrows the pages reserved at admission; passing
                # the write range lets the manager copy-on-write any
                # shared page before the dispatch scatters into it
                rem_i = max(
                    slot.request.max_new_tokens - len(slot.request.generated),
                    1,
                )
                self.cache_mgr.ensure(
                    idx,
                    min(slot.pos + min(sc.decode_steps, nf + rem_i),
                        sc.max_seq_len),
                    write_from=slot.pos,
                )
                if self.async_loop:
                    self._pos_ub[idx] = min(
                        slot.pos + min(sc.decode_steps, nf + rem_i),
                        sc.max_seq_len,
                    )
            self.caches = self.cache_mgr.flush_swaps(self.caches)
            self.caches = self.cache_mgr.flush_copies(self.caches)
            self.caches = self.cache_mgr.write_table(self.caches)
            tokens = np.asarray([s.last_token for s in self.slots], np.int32)
            positions = np.asarray(
                [s.pos if s.active else 0 for s in self.slots], np.int32
            )
            active = np.asarray(
                [
                    s.active and i in decode_set
                    for i, s in enumerate(self.slots)
                ],
                bool,
            )
            rem = np.asarray(
                [
                    max(s.request.max_new_tokens - len(s.request.generated), 0)
                    if s.active and i in decode_set
                    else 0
                    for i, s in enumerate(self.slots)
                ],
                np.int32,
            )
            eos = np.asarray(
                [
                    s.request.eos_id
                    if s.active and s.request.eos_id is not None
                    else -1
                    for s in self.slots
                ],
                np.int32,
            )
            # stacked per-request sampling knobs, built next to eos from
            # the same host slot state; a carried (uncollected) slot's
            # request cannot change mid-residency, so rebuilding from
            # host is sound under the async merge too
            knobs = [
                encode_sampling(
                    self.slots[i].request
                    if self.slots[i].active and i in decode_set
                    else None,
                    sc.temperature,
                )
                for i in range(nb)
            ]
            temp = np.asarray([s[0] for s in knobs], np.float32)
            top_k = np.asarray([s[1] for s in knobs], np.int32)
            top_p = np.asarray([s[2] for s in knobs], np.float32)
            seedv = np.asarray([s[3] for s in knobs], np.int32)
            if use_carry:
                # merge: device truth for uncollected slots, host truth
                # where an admission/extend/release made host fresh.
                # Plain (B,)-element ops outside jit — no new compiled
                # engine programs.
                # .copy() is load-bearing: device_put on the CPU backend
                # zero-copies an aligned numpy buffer, so handing the
                # live mask to jax would alias it — the asynchronously
                # dispatched merge could then read the ``[:] = True``
                # reset below (or a later ``_host_dirty``) instead of
                # the merge-time values, silently resurrecting a stale
                # device carry for a just-turned-over slot
                v = jnp.asarray(self._carry_valid.copy())
                c_tok, c_pos, c_act, c_rem = self._carry
                tok_in = jnp.where(v, c_tok, tokens)
                pos_in = jnp.where(v, c_pos, positions)
                act_in = jnp.asarray(active) & jnp.where(v, c_act, True)
                rem_in = jnp.where(v, c_rem, rem)
            else:
                tok_in, pos_in = jnp.asarray(tokens), jnp.asarray(positions)
                act_in, rem_in = jnp.asarray(active), jnp.asarray(rem)
            if self.mesh is not None:
                # commit the per-slot operands to one replicated placement
                # on every dispatch: an uncommitted host array (first
                # step) and a committed carry-merge result would
                # otherwise key two decode programs
                tok_in, pos_in, act_in, rem_in = (
                    jax.device_put(x, self._rep_sh)
                    for x in (tok_in, pos_in, act_in, rem_in)
                )
        self.key, sub = jax.random.split(self.key)
        if tel["decode_compiles"] == 0:
            tel["decode_compiles"] = 1  # one program, fixed shapes
        t0 = time.perf_counter()
        with tr.phase("dispatch"):
            toks_t, emit_t, tok_f, pos_f, act_f, rem_f, self.caches = (
                self._decode_fn(
                    self.params, tok_in, pos_in,
                    act_in, rem_in, jnp.asarray(eos),
                    jnp.asarray(temp), jnp.asarray(top_k),
                    jnp.asarray(top_p), jnp.asarray(seedv),
                    jnp.asarray(forced), jnp.asarray(n_forced),
                    self.caches, sub,
                )
            )
        with tr.phase("device"):
            tr.fence((toks_t, emit_t, tok_f, pos_f, act_f, self.caches))
        if self.async_loop:
            # every batch row's scan output reflects the merged (device
            # or fresh-host) input, so the whole carry is valid until
            # the next host-side slot mutation
            self._carry = (tok_f, pos_f, act_f, rem_f)
            self._carry_valid[:] = True
        snapshot = {i: self.slots[i].request for i in decode_set}
        admit_seqs = {i: self.slots[i].admit_seq for i in decode_set}
        self._dispatch_seq += 1
        for i in decode_set:
            self.slots[i].inflight = True
            self._slot_dispatch[i] = self._dispatch_seq
        return InflightStep(
            out=out, decision=decision, decode_set=tuple(sorted(decode_set)),
            dev=(toks_t, emit_t, tok_f, pos_f, act_f, rem_f),
            snapshot=snapshot, admit_seqs=admit_seqs, n_forced=n_forced,
            seq=self._dispatch_seq, t_dispatch=tr.mark_dispatch(), t0=t0,
        )

    def _retire(self, idx: int, out: StepOutput):
        slot = self.slots[idx]
        if slot.active and (
            slot.request.done or slot.pos + 1 >= self.serve_cfg.max_seq_len
        ):
            out.finished.append(slot.request)
            self._finish_slot(idx)

    def _finish_slot(self, idx: int):
        self.slots[idx] = Slot()
        self.cache_mgr.free(idx)
        self._host_dirty(idx)

"""Token sampling for the serving engine."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def sample(
    logits: jax.Array,  # (B, V)
    key: jax.Array,
    *,
    temperature: float = 0.0,
    top_k: int | None = None,
) -> jax.Array:
    """Greedy when temperature == 0, else (top-k) temperature sampling."""
    if temperature == 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    scaled = logits / temperature
    if top_k is not None:
        vals, _ = jax.lax.top_k(scaled, top_k)
        cut = vals[..., -1:]
        scaled = jnp.where(scaled < cut, -1e30, scaled)
    return jax.random.categorical(key, scaled, axis=-1).astype(jnp.int32)

"""Token sampling for the serving engine."""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request generation knobs for :meth:`repro.serve.Engine.submit`.

    Temperature / top-k stay engine-level (``ServeConfig.temperature``):
    they are baked into the single compiled decode program, and a
    per-request temperature would either mint extra programs or force a
    traced greedy/sampled select — both against the bounded-program
    discipline this stack inherits from the paper's fixed datapaths.
    """

    max_new_tokens: int = 16
    eos_id: int | None = None


def sample(
    logits: jax.Array,  # (B, V)
    key: jax.Array,
    *,
    temperature: float = 0.0,
    top_k: int | None = None,
) -> jax.Array:
    """Greedy when temperature == 0, else (top-k) temperature sampling."""
    if temperature == 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    scaled = logits / temperature
    if top_k is not None:
        vals, _ = jax.lax.top_k(scaled, top_k)
        cut = vals[..., -1:]
        scaled = jnp.where(scaled < cut, -1e30, scaled)
    return jax.random.categorical(key, scaled, axis=-1).astype(jnp.int32)

"""Token sampling for the serving engine.

Sampling knobs are *per-request* traced quantities.  The decode scan,
the bucketed prefill programs, and the cache-extending prefill program
all receive stacked per-slot ``(B,)`` arrays of (temperature, top-k,
top-p, seed) next to the per-slot ``eos`` array, so a batch can mix a
greedy request with a temperature-1.0 top-p request without minting a
second compiled program: greedy is a traced ``where``-select on
``temperature > 0``, never a Python branch.  The jit budget
(len(prefill_buckets) + 2 programs) is unchanged and test-enforced.

Traced encodings (host ``None`` -> array sentinel, see
:func:`repro.serve.scheduler.encode_sampling`):

* ``temperature <= 0``  -> greedy (argmax)
* ``top_k <= 0``        -> top-k off
* ``top_p >= 1``        -> top-p off
* ``seed < 0``          -> stream derived from the engine dispatch key
  (schedule-dependent, replica-salted)

A non-negative per-request ``seed`` pins the stream *by position*: row
``i`` draws with ``fold_in(PRNGKey(seed_i), position_i)`` where
``position_i`` is the global position of the token being processed.
Because the key depends only on (seed, position) — not on batch
composition, slot index, dispatch boundaries, or which program
(prefill / extend / decode scan) processes the token — a seeded
request's sampled stream is identical whether it runs alone or inside
a mixed-temperature batch, across prefix-skip, chunked prefill,
preemption-resume, and the async loop.  That schedule independence is
what the per-slot token-identity tests pin down.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request generation knobs for :meth:`repro.serve.Engine.submit`.

    ``temperature`` / ``top_k`` / ``top_p`` / ``seed`` ride the compiled
    programs as traced per-slot arrays (see module docstring), so every
    combination shares the one decode program.  ``None`` means "engine
    default": ``ServeConfig.temperature`` for temperature, *off* for
    top-k / top-p, and the engine's replica-salted dispatch key for the
    seed.  ``temperature=0.0`` is greedy decoding regardless of the
    other knobs.
    """

    max_new_tokens: int = 16
    eos_id: int | None = None
    #: softmax temperature; None = ServeConfig.temperature, 0.0 = greedy
    temperature: float | None = None
    #: keep only the k highest logits (tie-inclusive); None/0 = off
    top_k: int | None = None
    #: nucleus sampling mass in (0, 1]; None/1.0 = off
    top_p: float | None = None
    #: pins the sampled stream per (seed, position) — schedule- and
    #: replica-independent; None = engine dispatch key
    seed: int | None = None


def _mask_top_k(scaled: jax.Array, top_k: jax.Array) -> jax.Array:
    """Mask all but each row's ``top_k`` highest logits to the dtype
    minimum.  ``top_k`` is per-row traced; ``<= 0`` disables the mask.
    Tie-inclusive: values equal to the k-th largest all survive."""
    v = scaled.shape[-1]
    k = jnp.where(top_k > 0, top_k, v)
    desc = jnp.sort(scaled, axis=-1)[:, ::-1]
    kth = jnp.take_along_axis(desc, jnp.clip(k - 1, 0, v - 1)[:, None], axis=-1)
    return jnp.where(scaled < kth, jnp.finfo(scaled.dtype).min, scaled)


def _mask_top_p(scaled: jax.Array, top_p: jax.Array) -> jax.Array:
    """Nucleus mask: keep each row's smallest set of tokens whose
    probability mass reaches ``top_p`` (the top token always survives).
    ``top_p`` is per-row traced; ``>= 1`` disables the mask."""
    desc = jnp.sort(scaled, axis=-1)[:, ::-1]
    probs = jax.nn.softmax(desc, axis=-1)
    cum = jnp.cumsum(probs, axis=-1)
    keep = (cum - probs) < top_p[:, None]
    thresh = jnp.min(jnp.where(keep, desc, jnp.inf), axis=-1, keepdims=True)
    masked = jnp.where(scaled < thresh, jnp.finfo(scaled.dtype).min, scaled)
    return jnp.where(top_p[:, None] >= 1.0, scaled, masked)


def _row_keys(
    key: jax.Array, seed: jax.Array, positions: jax.Array
) -> jax.Array:
    """One PRNG key per batch row.  Seeded rows (``seed >= 0``) fold the
    processed token's global position into ``PRNGKey(seed)`` — the
    stream depends only on (seed, position).  Unseeded rows fold the row
    index into the engine's per-dispatch ``key``."""
    rows = jnp.arange(seed.shape[0], dtype=jnp.uint32)

    def one(s, p, r):
        pinned = jax.random.fold_in(
            jax.random.PRNGKey(jnp.maximum(s, 0).astype(jnp.uint32)), p
        )
        shared = jax.random.fold_in(key, r)
        return jnp.where(s >= 0, pinned, shared)

    return jax.vmap(one)(seed, positions, rows)


def sample_tokens(
    logits: jax.Array,  # (B, V)
    key: jax.Array,
    *,
    temperature: jax.Array,  # (B,) float32; <= 0 = greedy
    top_k: jax.Array,        # (B,) int32;   <= 0 = off
    top_p: jax.Array,        # (B,) float32; >= 1 = off
    seed: jax.Array,         # (B,) int32;   <  0 = engine key
    positions: jax.Array,    # (B,) int32 position of the processed token
) -> jax.Array:
    """Per-slot sampling with traced knob arrays — greedy and sampled
    rows coexist in one dispatch via a ``where``-select, so one compiled
    program serves every knob combination."""
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    scaled = logits / jnp.where(temperature > 0, temperature, 1.0)[:, None]
    scaled = _mask_top_k(scaled, top_k)
    scaled = _mask_top_p(scaled, top_p)
    keys = _row_keys(key, seed, positions)
    sampled = jax.vmap(jax.random.categorical)(keys, scaled).astype(jnp.int32)
    return jnp.where(temperature > 0, sampled, greedy)


def sample(
    logits: jax.Array,  # (B, V)
    key: jax.Array,
    *,
    temperature: float = 0.0,
    top_k: int | None = None,
) -> jax.Array:
    """Greedy when temperature == 0, else (top-k) temperature sampling.

    The scalar-knob path: one temperature / top-k for the whole batch,
    one key.  The serving programs use :func:`sample_tokens`; this stays
    for direct callers and as the reference the per-slot path reduces to
    when every row carries the same knobs."""
    if temperature == 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    scaled = logits / temperature
    if top_k is not None:
        vals, _ = jax.lax.top_k(scaled, top_k)
        cut = vals[..., -1:]
        # dtype-aware sentinel: a hardcoded -1e30 overflows/flushes under
        # low-precision logits and corrupts the masked distribution
        scaled = jnp.where(scaled < cut, jnp.finfo(scaled.dtype).min, scaled)
    return jax.random.categorical(key, scaled, axis=-1).astype(jnp.int32)

"""Deterministic synthetic LM token stream — shard-aware, restart-exact.

Generates a stationary Markov-ish token process with learnable structure
(next token depends on previous token through a fixed random permutation
plus noise), so small LMs show a clearly decreasing loss.  Batches are
addressed by (step, shard) so any host can regenerate any shard of any
step — the property the fault-tolerance layer relies on for exact restarts.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class SyntheticLMConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    structure: float = 0.8  # prob of following the deterministic successor


class SyntheticLM:
    def __init__(self, cfg: SyntheticLMConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        self.successor = rng.permutation(cfg.vocab_size)

    def batch(self, step: int, shard: int = 0, n_shards: int = 1) -> dict:
        """Batch shard for (step, shard) — pure function of its arguments."""
        cfg = self.cfg
        assert cfg.global_batch % n_shards == 0
        local = cfg.global_batch // n_shards
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 65_537 + shard
        )
        toks = np.empty((local, cfg.seq_len), np.int32)
        toks[:, 0] = rng.integers(0, cfg.vocab_size, local)
        follow = rng.random((local, cfg.seq_len)) < cfg.structure
        noise = rng.integers(0, cfg.vocab_size, (local, cfg.seq_len))
        for t in range(1, cfg.seq_len):
            succ = self.successor[toks[:, t - 1]]
            toks[:, t] = np.where(follow[:, t], succ, noise[:, t])
        return {"tokens": toks}


def make_batch_fn(vocab_size, seq_len, global_batch, seed=0):
    ds = SyntheticLM(SyntheticLMConfig(vocab_size, seq_len, global_batch, seed))
    return ds.batch

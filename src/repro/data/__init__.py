from repro.data.loader import PrefetchLoader  # noqa: F401
from repro.data.physics import (  # noqa: F401
    GENERATORS,
    auc_score,
    btagging_data,
    engine_anomaly_data,
    gw_data,
    multiclass_auc,
)
from repro.data.synthetic import SyntheticLM, SyntheticLMConfig  # noqa: F401

"""Synthetic physics datasets mirroring the paper's three benchmarks.

The real datasets (FordA/UCR, CMS open data, LIGO O3a) are not available
offline; these generators produce statistically similar, *learnable*
classification problems with the exact input shapes of paper Table I, so
the QAT/PTQ fidelity pipeline (AUC-ratio-vs-bits, Figs. 9-11) runs
end-to-end.  All generators are seeded and deterministic.

  engine  : 1-ch time series (seq 50); anomalies inject harmonic distortion
            + noise bursts into an engine-like periodic signal.
  btagging: 15 "tracks" x 6 features; b-jets have displaced-vertex-like
            shifts in impact-parameter features (the paper's Sec. V-B
            physics), light jets are prompt.
  gw      : 2-ch strain (seq 100); signals are sine-Gaussian chirps
            injected on colored noise, as in the paper's O3a setup.
"""

from __future__ import annotations

import numpy as np


def engine_anomaly_data(n: int, seed: int = 0, seq_len: int = 50):
    rng = np.random.default_rng(seed)
    t = np.linspace(0, 4 * np.pi, seq_len)
    y = rng.integers(0, 2, n)
    freq = rng.uniform(0.8, 1.2, (n, 1))
    phase = rng.uniform(0, 2 * np.pi, (n, 1))
    base = np.sin(freq * t[None, :] + phase)
    base += 0.3 * np.sin(3 * freq * t[None, :] + phase)
    noise = 0.25 * rng.standard_normal((n, seq_len))
    # anomaly: 2nd-harmonic distortion + localized burst
    distort = 0.55 * np.sin(2 * freq * t[None, :] + phase * 1.7)
    burst_pos = rng.integers(5, seq_len - 10, n)
    burst = np.zeros((n, seq_len))
    for i in range(n):
        if y[i]:
            burst[i, burst_pos[i] : burst_pos[i] + 6] += rng.normal(
                0, 0.8, 6
            )
    x = base + noise + y[:, None] * distort + burst
    x = (x - x.mean(axis=1, keepdims=True)) / (x.std(axis=1, keepdims=True) + 1e-6)
    return x[..., None].astype(np.float32), y.astype(np.int32)


def btagging_data(n: int, seed: int = 0, seq_len: int = 15, n_feat: int = 6):
    rng = np.random.default_rng(seed)
    y = rng.integers(0, 3, n)  # 0 light, 1 c, 2 b
    # per-track features ~ (pt, eta, phi, d0, z0, quality)
    x = rng.standard_normal((n, seq_len, n_feat)).astype(np.float32)
    x[..., 0] = np.abs(rng.standard_normal((n, seq_len))) * 2 + 0.5  # pt
    # displaced-vertex signature: heavy flavours shift impact parameters of
    # their leading tracks, with b > c (longer lifetime)
    lifetime = np.where(y == 2, 1.0, np.where(y == 1, 0.45, 0.0))
    n_displ = rng.integers(2, 6, n)
    for i in range(n):
        k = n_displ[i]
        x[i, :k, 3] += lifetime[i] * np.abs(rng.standard_normal(k)) * 2.2
        x[i, :k, 4] += lifetime[i] * np.abs(rng.standard_normal(k)) * 1.4
    return x, y.astype(np.int32)


def gw_data(n: int, seed: int = 0, seq_len: int = 100, n_ch: int = 2):
    rng = np.random.default_rng(seed)
    y = rng.integers(0, 2, n)
    t = np.linspace(-1, 1, seq_len)
    # colored background noise (smoothed white noise + lines)
    white = rng.standard_normal((n, n_ch, seq_len))
    kernel = np.exp(-0.5 * (np.arange(-4, 5) / 1.8) ** 2)
    kernel /= kernel.sum()
    noise = np.apply_along_axis(
        lambda m: np.convolve(m, kernel, mode="same"), -1, white
    )
    # sine-Gaussian injections (paper Sec. V-C) with random Q/f0/t0
    f0 = rng.uniform(4, 12, (n, 1, 1))
    q = rng.uniform(3, 9, (n, 1, 1))
    t0 = rng.uniform(-0.4, 0.4, (n, 1, 1))
    amp = rng.uniform(0.6, 1.4, (n, 1, 1))
    sg = amp * np.exp(-((t - t0) ** 2) * q) * np.sin(
        2 * np.pi * f0 * (t - t0)
    )
    x = noise + y[:, None, None] * sg
    x = x.transpose(0, 2, 1)  # (n, seq, ch)
    x = (x - x.mean(axis=1, keepdims=True)) / (x.std(axis=1, keepdims=True) + 1e-6)
    return x.astype(np.float32), y.astype(np.int32)


GENERATORS = {
    "engine_anomaly": engine_anomaly_data,
    "btagging": btagging_data,
    "gw": gw_data,
}


def _average_ranks(x: np.ndarray) -> np.ndarray:
    """1-based ranks with ties averaged (Mann-Whitney midranks)."""
    order = np.argsort(x, kind="stable")
    ranks = np.empty(len(x), np.float64)
    i = 0
    xs = x[order]
    while i < len(x):
        j = i
        while j + 1 < len(x) and xs[j + 1] == xs[i]:
            j += 1
        ranks[order[i : j + 1]] = (i + j) / 2 + 1
        i = j + 1
    return ranks


def auc_score(y_true: np.ndarray, scores: np.ndarray) -> float:
    """Binary ROC AUC via the Mann-Whitney rank statistic (midranks for
    ties; no sklearn offline)."""
    y_true = np.asarray(y_true)
    scores = np.asarray(scores, np.float64)
    pos_mask = y_true == 1
    n_pos = int(pos_mask.sum())
    n_neg = len(y_true) - n_pos
    if n_pos == 0 or n_neg == 0:
        return float("nan")
    ranks = _average_ranks(scores)
    r_pos = ranks[pos_mask].sum()
    return float((r_pos - n_pos * (n_pos + 1) / 2) / (n_pos * n_neg))


def multiclass_auc(y_true: np.ndarray, probs: np.ndarray) -> float:
    """Macro one-vs-rest AUC (b-tagging has 3 classes)."""
    aucs = []
    for c in range(probs.shape[-1]):
        aucs.append(auc_score((y_true == c).astype(int), probs[:, c]))
    return float(np.nanmean(aucs))

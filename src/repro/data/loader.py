"""Host data loader: background prefetch + sharded device_put.

The loader wraps a pure ``batch_fn(step, shard, n_shards) -> dict`` (see
synthetic.py) with a prefetch thread and places each global batch with the
mesh batch sharding, so the train loop overlaps host-side generation with
device compute.  Restart-exactness: state is just the step counter.
"""

from __future__ import annotations

import queue
import threading
from typing import Callable

import jax
import numpy as np


class PrefetchLoader:
    def __init__(
        self,
        batch_fn: Callable[[int, int, int], dict],
        sharding=None,
        start_step: int = 0,
        prefetch: int = 2,
    ):
        self.batch_fn = batch_fn
        self.sharding = sharding
        self.step = start_step
        self.prefetch = prefetch
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self.step
        while not self._stop.is_set():
            batch = self.batch_fn(step, 0, 1)
            if self.sharding is not None:
                batch = {
                    k: jax.device_put(v, self.sharding) for k, v in batch.items()
                }
            try:
                self._q.put((step, batch), timeout=1.0)
                step += 1
            except queue.Full:
                continue

    def __next__(self):
        step, batch = self._q.get()
        self.step = step + 1
        return batch

    def close(self):
        self._stop.set()
        # drain so the worker can exit
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)

"""The paper's 5-stage LayerNorm (Sec. IV-C), module-level API.

Stages: (1) mean, (2) deviation-from-mean, (3) variance, (4) normalize via a
1/sqrt(var) LUT, (5) gamma * x_hat + beta.

The Pallas fused kernel lives in ``kernels/layernorm``; this module is the
framework-facing API and jnp fallback.  RMSNorm (used by most assigned LM
architectures) shares stage 3-5 with the mean fixed at zero.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import lut


def layernorm_paper(
    x: jax.Array,
    gamma: jax.Array,
    beta: jax.Array,
    *,
    eps: float = 1e-5,
    use_lut: bool = False,
    axis: int = -1,
) -> jax.Array:
    """Paper's staged dataflow.  Note the paper's formula has no epsilon —
    fixed-point arithmetic bounds 1/sqrt via the LUT domain instead; for the
    float path we keep a small eps for parity with standard LayerNorm."""
    k = x.shape[axis]
    mean = jnp.sum(x, axis=axis, keepdims=True) / k  # stage 1
    dm = x - mean  # stage 2
    var = jnp.sum(dm * dm, axis=axis, keepdims=True) / k  # stage 3
    if use_lut:  # stage 4: 1/sqrt LUT
        inv_std = lut.lut_rsqrt(var)
    else:
        inv_std = jax.lax.rsqrt(var + eps)
    x_hat = dm * inv_std
    return x_hat * gamma + beta  # stage 5


def rmsnorm(
    x: jax.Array,
    gamma: jax.Array,
    *,
    eps: float = 1e-6,
    use_lut: bool = False,
    axis: int = -1,
) -> jax.Array:
    """RMSNorm via the same staged structure (mean fixed at 0)."""
    k = x.shape[axis]
    ms = jnp.sum(x * x, axis=axis, keepdims=True) / k
    if use_lut:
        inv_rms = lut.lut_rsqrt(ms)
    else:
        inv_rms = jax.lax.rsqrt(ms + eps)
    return x * inv_rms * gamma


def norm(
    x: jax.Array,
    params: dict,
    *,
    kind: str = "layernorm",
    eps: float = 1e-5,
    use_lut: bool = False,
) -> jax.Array:
    """Framework entry point; ``params`` holds 'scale' (+ 'bias' for LN)."""
    if kind == "layernorm":
        return layernorm_paper(
            x, params["scale"], params["bias"], eps=eps, use_lut=use_lut
        )
    if kind == "rmsnorm":
        return rmsnorm(x, params["scale"], eps=eps, use_lut=use_lut)
    if kind == "none":
        return x
    raise ValueError(f"unknown norm kind: {kind}")

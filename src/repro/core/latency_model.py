"""Latency estimation (paper Tables II-IV), for both FPGA-style cycle
models and TPU roofline models.

The paper reports, per (model x reuse x quantization): clock period,
initiation interval (cycles), latency (cycles), latency (us).  Without
Vivado we reproduce the *model* behind those tables:

  latency_cycles = pipeline_depth + (rows - 1) * interval
  interval       = base_interval * R      (paper: II grows ~linearly in R)
  clock_ns       = f(precision)           (paper: wider datapath -> slower clk)

and for the TPU target we derive latency from the three-term roofline over
compiled HLO (see ``repro/roofline``).  Both appear in ``benchmarks/``.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    """Roofline constants.  Defaults: TPU v5e (per chip)."""

    name: str = "tpu-v5e"
    peak_flops: float = 197e12  # bf16 FLOP/s
    hbm_bw: float = 819e9  # bytes/s
    ici_bw: float = 50e9  # bytes/s per link
    ici_links: int = 4  # 2D torus: 2 axes x 2 directions
    vmem_bytes: int = 128 * 1024 * 1024
    hbm_bytes: int = 16 * 1024 * 1024 * 1024

    # int8 path: MXU does int8 at >= bf16 rate on v5e; keep equal (conservative).
    peak_int8_ops: float = 394e12


TPU_V5E = HardwareSpec()


@dataclasses.dataclass(frozen=True)
class RooflineTerms:
    """The three §Roofline terms, in seconds (per device)."""

    compute_s: float
    memory_s: float
    collective_s: float

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def overlap_s(self) -> float:
        """Perfect-overlap latency lower bound = max of the three."""
        return self.bound_s

    @property
    def serial_s(self) -> float:
        """No-overlap upper bound = sum of the three."""
        return self.compute_s + self.memory_s + self.collective_s


def roofline(
    flops_per_device: float,
    hbm_bytes_per_device: float,
    collective_bytes_per_device: float,
    hw: HardwareSpec = TPU_V5E,
    *,
    int8: bool = False,
) -> RooflineTerms:
    peak = hw.peak_int8_ops if int8 else hw.peak_flops
    return RooflineTerms(
        compute_s=flops_per_device / peak,
        memory_s=hbm_bytes_per_device / hw.hbm_bw,
        collective_s=collective_bytes_per_device / (hw.ici_bw * hw.ici_links),
    )


# ---------------------------------------------------------------------------
# FPGA-style cycle model (Tables II-IV reproduction)
# ---------------------------------------------------------------------------

# Clock periods measured by the paper (ns) as a function of reuse factor —
# R=1 designs close timing slower (7.4/6.6 ns), R>=2 tighten to ~4.4-6.2 ns.
_PAPER_CLOCKS_NS = {1: 6.86, 2: 5.60, 4: 4.60}  # mean of Tables II-IV


@dataclasses.dataclass(frozen=True)
class FpgaLatencyEstimate:
    reuse: int
    clock_ns: float
    interval_cycles: int
    latency_cycles: int

    @property
    def latency_us(self) -> float:
        return self.latency_cycles * self.clock_ns / 1e3


def fpga_style_estimate(
    *,
    seq_len: int,
    d_model: int,
    n_blocks: int,
    n_heads: int = 4,
    reuse: int = 1,
    clock_ns: float | None = None,
) -> FpgaLatencyEstimate:
    """Analytic cycle model matching the structure of paper Tables II-IV.

    Each transformer block contributes a 4-stage MHA pipeline + FFN:
      - stage interval grows linearly with R (DSP time multiplexing),
      - pipeline depth ~ stages * fill, latency ~ depth + seq * II.
    Calibrated so that the engine model (seq 50, d 16, 3 blocks) lands near
    the paper's R1 = 257 cycles / II 119, and preserves the paper's
    monotonic trends (II ~ R, latency ~ R) exactly.
    """
    if clock_ns is None:
        clock_ns = _PAPER_CLOCKS_NS.get(reuse, 4.6)
    # per-row work in one block: QKV proj + QK^T + AV + out proj + FFN
    row_macs = d_model * d_model * 4 + seq_len * d_model * 2 + d_model * d_model * 8
    # R multiplies the per-row initiation interval; base interval is the
    # rows-per-cycle streaming rate of the fully parallel design.
    base_interval = max(1, round(seq_len * 0.75))
    interval = base_interval + (reuse - 1) * seq_len * 2
    fill_depth = n_blocks * (4 * 12) + row_macs // max(d_model * d_model, 1)
    latency = fill_depth + interval + reuse * seq_len * n_blocks
    return FpgaLatencyEstimate(
        reuse=reuse,
        clock_ns=clock_ns,
        interval_cycles=interval,
        latency_cycles=latency,
    )


def tpu_latency_us(terms: RooflineTerms) -> tuple[float, float]:
    """(lower bound, upper bound) latency in us from roofline terms."""
    return terms.overlap_s * 1e6, terms.serial_s * 1e6

"""Bounded-domain lookup-table function approximation (paper Sec. IV-B/C).

hls4ml evaluates exp / 1/x / 1/sqrt(x) with BRAM lookup tables.  The TPU has
no BRAM, but it has an MXU: a table read is a one-hot row-select, i.e. a
``(rows, T) @ (T,)`` matmul.  ``kernels/lut_softmax`` uses exactly that
inside Pallas; this module owns table *construction* and the pure-jnp
reference lookup (``jnp.take``) used by ref oracles and the fidelity path.

Tables are built over a bounded input domain — valid because the paper's
datapath is fixed point (``ap_fixed<W,I>`` bounds every tensor), which is
also why the paper's softmax needs no max-subtraction.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class LutSpec:
    """A sampled function table over [lo, hi] with ``size`` entries.

    ``spacing``: 'linear' mirrors the FPGA's fixed-point BRAM indexing
    (uniform steps of the ap_fixed grid).  'log' is the TPU adaptation for
    reciprocal-like functions on the float datapath: fixed-point linear
    steps are relatively fine near zero, but a float-valued denominator
    spans octaves — log-indexing keeps the RELATIVE error uniform
    (~ln2 * octave_step / 2), which linear spacing cannot.
    """

    name: str
    lo: float
    hi: float
    size: int
    spacing: str = "linear"  # linear | log

    @property
    def step(self) -> float:
        if self.spacing == "log":
            return (np.log2(self.hi) - np.log2(self.lo)) / (self.size - 1)
        return (self.hi - self.lo) / (self.size - 1)


def build_table(spec: LutSpec, fn: Callable[[np.ndarray], np.ndarray]) -> jax.Array:
    if spec.spacing == "log":
        xs = np.logspace(
            np.log2(spec.lo), np.log2(spec.hi), spec.size, base=2.0,
            dtype=np.float64,
        )
    else:
        xs = np.linspace(spec.lo, spec.hi, spec.size, dtype=np.float64)
    return jnp.asarray(fn(xs), dtype=jnp.float32)


def lut_index(x: jax.Array, spec: LutSpec) -> jax.Array:
    """Nearest-entry index with saturation (AP_SAT analogue).

    Pure-jnp expression (also valid inside Pallas kernel bodies)."""
    if spec.spacing == "log":
        xl = jnp.log2(jnp.maximum(x, 1e-30))
        idx = jnp.round((xl - np.log2(spec.lo)) / spec.step)
    else:
        idx = jnp.round((x - spec.lo) / spec.step)
    return jnp.clip(idx, 0, spec.size - 1).astype(jnp.int32)


def lut_lookup(x: jax.Array, table: jax.Array, spec: LutSpec) -> jax.Array:
    """Reference lookup (gather).  Kernels use the one-hot-matmul form."""
    return jnp.take(table, lut_index(x, spec), axis=0)


def lut_lookup_onehot(x: jax.Array, table: jax.Array, spec: LutSpec) -> jax.Array:
    """MXU-native lookup: one_hot(idx) @ table.

    This is the TPU translation of a BRAM read — it runs on the systolic
    array and is what the Pallas kernels emit.  Bit-identical to
    ``lut_lookup`` (both select exactly one table row).
    """
    idx = lut_index(x, spec)
    onehot = jax.nn.one_hot(idx, spec.size, dtype=table.dtype)
    return onehot @ table


# --- standard tables used by the paper's three layers ----------------------

# exp over the (scaled) attention-score domain.  hls4ml default table range
# is [-8, 8) with 1024 entries; exp saturates hard below -8 anyway.
# Linear spacing == the paper's fixed-point BRAM indexing.
EXP_SPEC = LutSpec("exp", lo=-8.0, hi=8.0, size=1024)

# 1/x over the softmax-denominator domain.  Log-indexed (see LutSpec): the
# denominator of a CAUSAL row can be as small as e^{-8} (one masked-in
# term) and as large as 512k * e^8 for the long-context cells — 45 octaves
# that a linear fixed-point table cannot cover with uniform relative error.
INV_SPEC = LutSpec("inv", lo=2.0 ** -12, hi=2.0 ** 33, size=4096, spacing="log")

# 1/sqrt(var) for layernorm; same octave-spanning argument.
RSQRT_SPEC = LutSpec("rsqrt", lo=2.0 ** -20, hi=2.0 ** 12, size=4096, spacing="log")


def exp_table() -> jax.Array:
    return build_table(EXP_SPEC, np.exp)


def inv_table() -> jax.Array:
    return build_table(INV_SPEC, lambda x: 1.0 / x)


def rsqrt_table() -> jax.Array:
    return build_table(RSQRT_SPEC, lambda x: 1.0 / np.sqrt(x))


def lut_exp(x: jax.Array) -> jax.Array:
    return lut_lookup(x, exp_table(), EXP_SPEC)


def lut_inv(x: jax.Array) -> jax.Array:
    return lut_lookup(x, inv_table(), INV_SPEC)


def lut_rsqrt(x: jax.Array) -> jax.Array:
    return lut_lookup(x, rsqrt_table(), RSQRT_SPEC)


def lut_max_abs_error(spec: LutSpec, fn: Callable[[np.ndarray], np.ndarray]) -> float:
    """Worst-case interpolation error of nearest-entry lookup on the grid
    midpoints — used by property tests to bound LUT softmax error."""
    if spec.spacing == "log":
        grid = np.logspace(
            np.log2(spec.lo), np.log2(spec.hi), spec.size, base=2.0
        )
        xs = np.sqrt(grid[:-1] * grid[1:])  # geometric midpoints
        idx = np.clip(
            np.round((np.log2(xs) - np.log2(spec.lo)) / spec.step),
            0, spec.size - 1,
        ).astype(int)
    else:
        xs = np.linspace(spec.lo, spec.hi - spec.step, spec.size - 1) + spec.step / 2
        idx = np.clip(
            np.round((xs - spec.lo) / spec.step), 0, spec.size - 1
        ).astype(int)
    table = np.asarray(build_table(spec, fn))
    return float(np.max(np.abs(table[idx] - fn(xs))))

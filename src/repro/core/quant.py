"""Quantization engine: QAT fake-quant, PTQ calibration, int8 tensors.

Two paths, mirroring the paper's split:

* **Fidelity path** — ``ap_fixed`` fake-quant (``core.fixed_point``) applied
  to weights/activations during training (QAT, straight-through estimator)
  or after training (PTQ).  Arbitrary bit widths; used for the
  AUC-ratio-vs-bits sweeps (paper Figs. 9-11).

* **Performance path** — symmetric int8 with per-tensor or per-channel
  scales and int32 accumulation, feeding ``kernels/qmatmul`` (the MXU
  analogue of the paper's DSP fixed-point datapath).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core import fixed_point as fxp

PyTree = Any


# --------------------------------------------------------------------------
# int8 symmetric quantization (performance path)
# --------------------------------------------------------------------------

@dataclasses.dataclass
class QTensor:
    """A quantized tensor: int codes + float scale.

    ``values``: int8 (or int16) codes.
    ``scale``: per-tensor scalar or per-axis vector such that
    ``dequant = values * scale`` broadcast along ``axis``.
    """

    values: jax.Array
    scale: jax.Array
    axis: int | None = None  # channel axis of per-channel scale, None = per-tensor

    @property
    def shape(self):
        return self.values.shape

    def dequantize(self, dtype=jnp.float32) -> jax.Array:
        scale = self.scale
        if self.axis is not None:
            bshape = [1] * self.values.ndim
            bshape[self.axis] = self.values.shape[self.axis]
            scale = scale.reshape(bshape)
        return self.values.astype(dtype) * scale.astype(dtype)


jax.tree_util.register_pytree_node(
    QTensor,
    lambda q: ((q.values, q.scale), q.axis),
    lambda axis, leaves: QTensor(leaves[0], leaves[1], axis),
)


def quantize_int8(
    x: jax.Array, axis: int | None = None, bits: int = 8
) -> QTensor:
    """Symmetric linear quantization to ``bits`` (default int8)."""
    qmax = 2 ** (bits - 1) - 1
    if axis is None:
        amax = jnp.max(jnp.abs(x))
    else:
        reduce_axes = tuple(i for i in range(x.ndim) if i != axis)
        amax = jnp.max(jnp.abs(x), axis=reduce_axes)
    scale = jnp.maximum(amax, 1e-8) / qmax
    if axis is None:
        codes = jnp.round(x / scale)
    else:
        bshape = [1] * x.ndim
        bshape[axis] = x.shape[axis]
        codes = jnp.round(x / scale.reshape(bshape))
    dtype = jnp.int8 if bits <= 8 else jnp.int16
    codes = jnp.clip(codes, -qmax - 1, qmax).astype(dtype)
    return QTensor(codes, scale.astype(jnp.float32), axis)


def fake_quant_int8(x: jax.Array, axis: int | None = None, bits: int = 8) -> jax.Array:
    """Quantize-dequantize with STE gradient (int8 QAT)."""
    q = quantize_int8(jax.lax.stop_gradient(x), axis=axis, bits=bits)
    deq = q.dequantize(x.dtype)
    return x + jax.lax.stop_gradient(deq - x)


# --------------------------------------------------------------------------
# PTQ calibration (fidelity + performance paths)
# --------------------------------------------------------------------------

@dataclasses.dataclass
class CalibrationStats:
    """Running activation statistics collected over calibration batches."""

    amax: float = 0.0
    amin: float = 0.0
    n: int = 0

    def update(self, x: jax.Array) -> "CalibrationStats":
        return CalibrationStats(
            amax=max(self.amax, float(jnp.max(x))),
            amin=min(self.amin, float(jnp.min(x))),
            n=self.n + 1,
        )

    def required_int_bits(self) -> int:
        """Smallest signed integer width covering the observed range."""
        bound = max(abs(self.amax), abs(self.amin), 1e-8)
        import math

        return max(1, math.ceil(math.log2(bound) + 1e-12) + 1)


class PTQCalibrator:
    """Collects per-name activation stats and emits FixedPointConfigs.

    Usage::

        calib = PTQCalibrator(frac_bits=8)
        for batch in data: model_apply(params, batch, observer=calib)
        cfgs = calib.configs()
    """

    def __init__(self, frac_bits: int, max_int_bits: int = fxp.ACCUM_INT_BITS):
        self.frac_bits = frac_bits
        self.max_int_bits = max_int_bits
        self.stats: dict[str, CalibrationStats] = {}

    def observe(self, name: str, x: jax.Array) -> jax.Array:
        self.stats[name] = self.stats.get(name, CalibrationStats()).update(x)
        return x

    def configs(self) -> dict[str, fxp.FixedPointConfig]:
        out = {}
        for name, st in self.stats.items():
            int_bits = min(st.required_int_bits(), self.max_int_bits)
            out[name] = fxp.ap_fixed(int_bits + self.frac_bits, int_bits)
        return out


# --------------------------------------------------------------------------
# Model-level quantization transforms
# --------------------------------------------------------------------------

def quantize_pytree_fixed(params: PyTree, cfg: fxp.FixedPointConfig) -> PyTree:
    """PTQ: snap every float leaf onto the ap_fixed grid (fidelity path)."""

    def _q(leaf):
        if jnp.issubdtype(leaf.dtype, jnp.floating):
            return fxp.quantize(leaf, cfg)
        return leaf

    return jax.tree.map(_q, params)


def fake_quant_pytree(params: PyTree, cfg: fxp.FixedPointConfig) -> PyTree:
    """QAT: fake-quant every float leaf with STE gradients."""

    def _q(leaf):
        if jnp.issubdtype(leaf.dtype, jnp.floating):
            return fxp.quantize_ste(leaf, cfg)
        return leaf

    return jax.tree.map(_q, params)


def quantize_pytree_int8(params: PyTree, axis: int | None = 0) -> PyTree:
    """Performance path: every float matrix leaf -> QTensor (per-channel).

    1-D leaves (biases, norm scales) stay float — the paper also keeps
    accumulator/bias precision higher than the datapath.
    """

    def _q(leaf):
        if (
            isinstance(leaf, jax.Array)
            and jnp.issubdtype(leaf.dtype, jnp.floating)
            and leaf.ndim >= 2
        ):
            ch_axis = (leaf.ndim - 1) if axis is not None else None
            return quantize_int8(leaf, axis=ch_axis)
        return leaf

    return jax.tree.map(_q, params)


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """Framework-level quantization selection (configs/*.py reference this).

    .. deprecated::
        New code should declare precision through
        ``core.precision.PrecisionPolicy`` (``ModelConfig.precision`` /
        ``ServeConfig.policy``).  A model-level QuantConfig is lowered
        onto an equivalent policy via ``core.precision.from_quant_config``
        so the policy engine is the single source of truth; the
        ``int8_weights / int8_kv_cache / lut_softmax`` booleans here are
        read only by that lowering.  ``maybe_fake_quant_*`` remain as the
        runtime execution hooks that policy-derived configs also use.
    """

    mode: str = "none"  # none | ptq | qat | int8
    weight_cfg: fxp.FixedPointConfig | None = None
    act_cfg: fxp.FixedPointConfig | None = None
    accum_cfg: fxp.FixedPointConfig = fxp.ACCUM_CONFIG
    int8_weights: bool = False
    int8_kv_cache: bool = False
    lut_softmax: bool = False

    def maybe_fake_quant_act(self, x: jax.Array) -> jax.Array:
        if self.mode == "qat" and self.act_cfg is not None:
            return fxp.quantize_ste(x, self.act_cfg)
        return x

    def maybe_fake_quant_weight(self, w: jax.Array) -> jax.Array:
        if self.mode == "qat" and self.weight_cfg is not None:
            return fxp.quantize_ste(w, self.weight_cfg)
        return w


def sweep_frac_bits(
    apply_fn: Callable[[PyTree, jax.Array], jax.Array],
    params: PyTree,
    x: jax.Array,
    int_bits: int,
    frac_bits_list: list[int],
) -> dict[int, jax.Array]:
    """PTQ bit-width sweep helper used by the Fig. 9-11 benchmark."""
    out = {}
    for fb in frac_bits_list:
        cfg = fxp.ap_fixed(int_bits + fb, int_bits)
        qparams = quantize_pytree_fixed(params, cfg)
        out[fb] = apply_fn(qparams, x)
    return out

"""The paper's primary contribution, TPU-native.

Low-latency quantized transformer inference per *Low Latency Transformer
Inference on FPGAs for Physics Applications with hls4ml* (2024):

* ``fixed_point``   — ap_fixed<W,I> semantics (fidelity path, QAT STE)
* ``quant``         — QAT/PTQ engine + int8 tensors (performance path)
* ``precision``     — declarative per-layer PrecisionPolicy API (hls4ml-style)
* ``lut``           — bounded-domain table approximation (exp, 1/x, 1/sqrt)
* ``softmax``       — the restructured 3-stage softmax (Sec. IV-B)
* ``layernorm``     — the staged LayerNorm (Sec. IV-C)
* ``streaming_mha`` — the 4-stage MHA pipeline (Sec. IV-A), kernel-backed
* ``reuse``         — reuse-factor R -> kernel schedule mapping (Sec. VI-B)
* ``latency_model`` — latency/resource estimation (Tables II-IV analogue)
"""

from repro.core import (  # noqa: F401
    fixed_point,
    latency_model,
    layernorm,
    lut,
    precision,
    quant,
    reuse,
    softmax,
)

"""ap_fixed<W, I> semantics in JAX — the paper's numeric substrate.

hls4ml represents every tensor as ``ap_fixed<W, I>``: W total bits (incl.
sign), I integer bits (incl. sign), F = W - I fractional bits.  Step size is
``2**-F``; the representable range is ``[-2**(I-1), 2**(I-1) - 2**-F]``.

On TPU there is no arbitrary-width fixed point, so this module provides the
*fidelity* path: bit-exact ap_fixed simulation on float carriers, used for

  * the AUC-ratio-vs-fractional-bits sweeps (paper Figs. 9-11),
  * QAT fake-quantization (straight-through estimator),
  * deriving int8 scales for the *performance* path (``kernels/qmatmul``).

The paper fixes the accumulator at 10 integer bits (incl. sign) and sweeps
fractional bits; ``ACCUM_INT_BITS`` mirrors that default.
"""

from __future__ import annotations

import dataclasses
from typing import Literal

import jax
import jax.numpy as jnp

# Paper, Sec. VI-A: "We set this [accumulator integer width] as a larger
# fixed number, 10 bits including the sign bit".
ACCUM_INT_BITS = 10

RoundMode = Literal["nearest", "floor"]
OverflowMode = Literal["saturate", "wrap"]


@dataclasses.dataclass(frozen=True)
class FixedPointConfig:
    """``ap_fixed<total_bits, int_bits>`` (both include the sign bit)."""

    total_bits: int
    int_bits: int
    signed: bool = True
    round_mode: RoundMode = "nearest"
    overflow_mode: OverflowMode = "saturate"

    def __post_init__(self):
        if self.total_bits < 1:
            raise ValueError(f"total_bits must be >= 1, got {self.total_bits}")
        if self.int_bits > self.total_bits:
            raise ValueError(
                f"int_bits ({self.int_bits}) cannot exceed total_bits "
                f"({self.total_bits})"
            )

    @property
    def frac_bits(self) -> int:
        return self.total_bits - self.int_bits

    @property
    def step(self) -> float:
        return 2.0 ** (-self.frac_bits)

    @property
    def max_value(self) -> float:
        if self.signed:
            return 2.0 ** (self.int_bits - 1) - self.step
        return 2.0 ** self.int_bits - self.step

    @property
    def min_value(self) -> float:
        if self.signed:
            return -(2.0 ** (self.int_bits - 1))
        return 0.0

    @property
    def n_levels(self) -> int:
        return 2 ** self.total_bits

    def with_frac_bits(self, frac_bits: int) -> "FixedPointConfig":
        return dataclasses.replace(
            self, total_bits=self.int_bits + frac_bits
        )

    def __str__(self) -> str:  # pragma: no cover - repr sugar
        kind = "ap_fixed" if self.signed else "ap_ufixed"
        return f"{kind}<{self.total_bits},{self.int_bits}>"


def quantize(x: jax.Array, cfg: FixedPointConfig) -> jax.Array:
    """Round ``x`` onto the ap_fixed grid (returns a float carrier).

    Matches Vivado HLS AP_RND (round-half-away-from-zero is what
    ``jnp.round`` does for .5 ties at representable floats; hls4ml's default
    AP_TRN is the "floor" mode) and AP_SAT saturation.
    """
    scaled = x / cfg.step
    if cfg.round_mode == "nearest":
        q = jnp.round(scaled)
    else:
        q = jnp.floor(scaled)
    if cfg.overflow_mode == "saturate":
        lo = cfg.min_value / cfg.step
        hi = cfg.max_value / cfg.step
        q = jnp.clip(q, lo, hi)
    else:  # wrap (two's complement)
        n = float(cfg.n_levels)
        lo = cfg.min_value / cfg.step
        q = jnp.mod(q - lo, n) + lo
    return q * jnp.asarray(cfg.step, dtype=x.dtype)


def quantize_ste(x: jax.Array, cfg: FixedPointConfig) -> jax.Array:
    """Fake-quantize with a straight-through-estimator gradient (QAT).

    Forward: ``quantize(x)``.  Backward: identity inside the representable
    range, zero outside (clipped STE), per QKeras ``quantized_bits``.
    """
    clipped = jnp.clip(x, cfg.min_value, cfg.max_value)
    return clipped + jax.lax.stop_gradient(quantize(x, cfg) - clipped)


def to_int(x: jax.Array, cfg: FixedPointConfig, dtype=jnp.int32) -> jax.Array:
    """Integer codes of the fixed-point representation (perf-path bridge)."""
    q = quantize(x, cfg)
    return jnp.round(q / cfg.step).astype(dtype)


def from_int(codes: jax.Array, cfg: FixedPointConfig, dtype=jnp.float32) -> jax.Array:
    return codes.astype(dtype) * jnp.asarray(cfg.step, dtype=dtype)


def quantization_error_bound(cfg: FixedPointConfig) -> float:
    """Max |x - quantize(x)| for in-range x (used by property tests)."""
    if cfg.round_mode == "nearest":
        return cfg.step / 2.0
    return cfg.step


# Common configs used throughout the repo / benchmarks.
def ap_fixed(total_bits: int, int_bits: int, **kw) -> FixedPointConfig:
    return FixedPointConfig(total_bits=total_bits, int_bits=int_bits, **kw)


# The paper's per-model optima (Sec. VI-A): engine 6 frac bits (PTQ & QAT),
# b-tagging 10 (PTQ) / 6 (QAT), GW 6 (PTQ & QAT); 6 integer bits.
PAPER_OPTIMAL = {
    "engine_anomaly": {"ptq": ap_fixed(12, 6), "qat": ap_fixed(12, 6)},
    "btagging": {"ptq": ap_fixed(16, 6), "qat": ap_fixed(12, 6)},
    "gw": {"ptq": ap_fixed(12, 6), "qat": ap_fixed(12, 6)},
}

ACCUM_CONFIG = ap_fixed(ACCUM_INT_BITS + 8, ACCUM_INT_BITS)

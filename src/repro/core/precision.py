"""Declarative per-layer precision policies — the hls4ml analogue.

The paper's central mechanism is hls4ml's *per-layer* fixed-point
configuration: every tensor in the dataflow graph is assigned an
``ap_fixed<W, I>`` (or LUT / integer) precision, and the latency/accuracy
wins come from choosing those widths per layer.  This module is the
repo-wide equivalent: a :class:`PrecisionPolicy` is an ordered list of
pattern-based rules over named tensor-class paths, resolved once per model
into a concrete :class:`PrecisionPlan` that the model, kernel, serving and
benchmark layers all consume.

Tensor-class paths (the address space rules match against)::

    layers.{i}.weights          per-layer parameter tensors
    layers.{i}.activations      per-layer activation fake-quant
    layers.{i}.attn.softmax     per-layer attention softmax datapath
    layers.{i}.norm             per-layer normalization datapath
    embed.weights / embed.activations       embedding + input frontends
    logits.weights / logits.activations     lm_head / classifier heads
    shared.weights / shared.activations     hybrid shared-attention block
    norm.weights                final-norm parameters
    kv_cache                    serving KV cache storage
    accum                       matmul accumulator

Patterns are ``fnmatch`` globs (hls4ml-style: ``*`` crosses dots), e.g.
``("layers.*.attn.softmax", lut8())`` or ``("*.weights", int8())``.
Rules are applied in order with **last match wins**; unmatched slots
default to float.

Named presets (``get_policy``): ``float``, ``int8_serve``,
``paper_vu13p``, and the parametric ``ptq_fixed<W,I>`` /
``qat_fixed<W,I>`` families.

The legacy model-level knobs (``QuantConfig.mode/weight_cfg/act_cfg``
and its booleans) lower onto this API via :func:`from_quant_config`, so
there is exactly one source of truth for precision selection.  (The
old ``ServeConfig`` boolean triple and its deprecation shim were removed
once their cycle elapsed; serving code passes ``policy=`` directly.)
"""

from __future__ import annotations

import dataclasses
import fnmatch
import functools
import re
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import fixed_point as fxp
from repro.core import quant as quant_lib

PyTree = Any

TENSOR_CLASSES = (
    "weights", "activations", "kv_cache", "softmax", "norm", "logits", "accum"
)

_KINDS = ("float", "fixed", "int8", "lut")


# ---------------------------------------------------------------------------
# Precision values
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Precision:
    """The precision assigned to one tensor-class slot.

    kind:
      ``float``  — native float carrier (no transform).
      ``fixed``  — ap_fixed<total_bits, int_bits>; ``method`` picks how
                   parameters are treated: ``ptq`` snaps them offline,
                   ``qat`` additionally fake-quantizes (STE) at runtime.
                   On activations, fixed always means runtime fake-quant.
      ``int8``   — symmetric integer codes + scales (``bits`` wide,
                   per-channel or per-tensor).
      ``lut``    — the paper's bounded-domain table datapath (softmax /
                   norm kernels); ``bits`` is the table address width.
    """

    kind: str = "float"
    total_bits: int | None = None
    int_bits: int | None = None
    method: str = "ptq"  # fixed parameters: ptq (snap) | qat (snap + STE)
    per_channel: bool = True  # int8 scale granularity
    bits: int = 8  # int8 code width / lut address width

    def __post_init__(self):
        if self.kind not in _KINDS:
            raise ValueError(f"unknown precision kind {self.kind!r}")
        if self.kind == "fixed":
            if self.total_bits is None or self.int_bits is None:
                raise ValueError("fixed precision requires total_bits/int_bits")
            if self.method not in ("ptq", "qat"):
                raise ValueError(f"unknown fixed method {self.method!r}")
            # validates bit widths
            fxp.ap_fixed(self.total_bits, self.int_bits)

    @property
    def is_float(self) -> bool:
        return self.kind == "float"

    def fixed_cfg(self) -> fxp.FixedPointConfig | None:
        if self.kind != "fixed":
            return None
        return fxp.ap_fixed(self.total_bits, self.int_bits)

    def to_dict(self) -> dict:
        d: dict = {"kind": self.kind}
        if self.kind == "fixed":
            d.update(
                total_bits=self.total_bits,
                int_bits=self.int_bits,
                method=self.method,
            )
        elif self.kind == "int8":
            d.update(per_channel=self.per_channel, bits=self.bits)
        elif self.kind == "lut":
            d.update(bits=self.bits)
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "Precision":
        return cls(**d)

    def __str__(self) -> str:
        if self.kind == "fixed":
            return f"{self.method}_fixed<{self.total_bits},{self.int_bits}>"
        if self.kind == "int8":
            gran = "perchannel" if self.per_channel else "pertensor"
            return f"int{self.bits}_{gran}"
        if self.kind == "lut":
            return f"lut{self.bits}"
        return "float"


FLOAT = Precision("float")


def fixed(total_bits: int, int_bits: int, method: str = "ptq") -> Precision:
    return Precision(
        "fixed", total_bits=total_bits, int_bits=int_bits, method=method
    )


def int8(per_channel: bool = True, bits: int = 8) -> Precision:
    return Precision("int8", per_channel=per_channel, bits=bits)


def int8_perchannel() -> Precision:
    return int8(per_channel=True)


def lut8(bits: int = 8) -> Precision:
    return Precision("lut", bits=bits)


_FIXED_RE = re.compile(r"^(ptq|qat)_fixed<(\d+)\s*,\s*(\d+)>$")


def parse_precision(s: str) -> Precision:
    """Parse a precision literal: ``float``, ``int8``, ``int8_pertensor``,
    ``lut8``, ``ptq_fixed<12,6>``, ``qat_fixed<12,6>``."""
    if s == "float":
        return FLOAT
    if s in ("int8", "int8_perchannel"):
        return int8(per_channel=True)
    if s == "int8_pertensor":
        return int8(per_channel=False)
    if s == "lut8":
        return lut8()
    m = _FIXED_RE.match(s)
    if m:
        return fixed(int(m.group(2)), int(m.group(3)), method=m.group(1))
    raise ValueError(f"cannot parse precision literal {s!r}")


# ---------------------------------------------------------------------------
# Rules and policies
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Rule:
    """One pattern -> precision assignment (last matching rule wins)."""

    pattern: str
    precision: Precision

    def matches(self, path: str) -> bool:
        return fnmatch.fnmatchcase(path, self.pattern)

    def to_dict(self) -> dict:
        return {"pattern": self.pattern, "precision": self.precision.to_dict()}

    @classmethod
    def from_dict(cls, d: dict) -> "Rule":
        return cls(d["pattern"], Precision.from_dict(d["precision"]))


# what each slot class is allowed to resolve to
_SLOT_KINDS = {
    "weights": ("float", "fixed", "int8"),
    "activations": ("float", "fixed"),
    "softmax": ("float", "lut"),
    "norm": ("float", "fixed", "lut"),
    "kv_cache": ("float", "int8"),
    "accum": ("float", "fixed"),
}


@dataclasses.dataclass(frozen=True)
class SlotPlan:
    """Resolved (weights, activations) pair for one dense site group."""

    weights: Precision = FLOAT
    activations: Precision = FLOAT


@dataclasses.dataclass(frozen=True)
class LayerPlan(SlotPlan):
    """Per-layer resolution: dense sites + softmax + norm datapaths."""

    softmax: Precision = FLOAT
    norm: Precision = FLOAT


@dataclasses.dataclass(frozen=True)
class PrecisionPolicy:
    """Ordered pattern-based precision rules; resolve() per model."""

    name: str
    rules: tuple[Rule, ...] = ()

    def _lookup(self, path: str, slot_class: str) -> Precision:
        hit = FLOAT
        for rule in self.rules:
            if rule.matches(path):
                hit = rule.precision
        if hit.kind not in _SLOT_KINDS[slot_class]:
            raise ValueError(
                f"policy {self.name!r}: precision {hit} is not valid for "
                f"{path} (class {slot_class!r} accepts {_SLOT_KINDS[slot_class]})"
            )
        return hit

    def _slot(self, prefix: str) -> SlotPlan:
        return SlotPlan(
            weights=self._lookup(f"{prefix}.weights", "weights"),
            activations=self._lookup(f"{prefix}.activations", "activations"),
        )

    def resolve(self, model) -> "PrecisionPlan":
        """Resolve into a concrete per-layer plan.

        ``model``: an int layer count or anything with ``.n_layers``.
        """
        n_layers = getattr(model, "n_layers", model)
        layers = tuple(
            LayerPlan(
                weights=self._lookup(f"layers.{i}.weights", "weights"),
                activations=self._lookup(
                    f"layers.{i}.activations", "activations"
                ),
                softmax=self._lookup(f"layers.{i}.attn.softmax", "softmax"),
                norm=self._lookup(f"layers.{i}.norm", "norm"),
            )
            for i in range(n_layers)
        )
        return PrecisionPlan(
            policy=self,
            layers=layers,
            embed=self._slot("embed"),
            logits=self._slot("logits"),
            shared=self._slot("shared"),
            final_norm=self._lookup("norm.weights", "weights"),
            kv_cache=self._lookup("kv_cache", "kv_cache"),
            accum=self._lookup("accum", "accum"),
        )

    def to_dict(self) -> dict:
        return {
            "name": self.name,
            "rules": [r.to_dict() for r in self.rules],
        }

    @classmethod
    def from_dict(cls, d: dict) -> "PrecisionPolicy":
        return cls(d["name"], tuple(Rule.from_dict(r) for r in d["rules"]))


# ---------------------------------------------------------------------------
# Resolved plans
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PrecisionPlan:
    """One policy resolved against one model: the concrete per-layer map
    every consumer (models, kernels, serving engine, benchmarks) reads."""

    policy: PrecisionPolicy
    layers: tuple[LayerPlan, ...]
    embed: SlotPlan
    logits: SlotPlan
    shared: SlotPlan
    final_norm: Precision
    kv_cache: Precision
    accum: Precision

    # ----------------------------------------------- engine lowering --
    @property
    def int8_weights(self) -> bool:
        return any(
            s.weights.kind == "int8"
            for s in (*self.layers, self.embed, self.logits, self.shared)
        )

    @property
    def int8_kv_cache(self) -> bool:
        return self.kv_cache.kind == "int8"

    @property
    def lut_softmax(self) -> bool:
        return self.softmax_mode() == "lut"

    def softmax_mode(self) -> str:
        """Kernel softmax mode.  The fused attention kernel is compiled
        once for the whole scan-over-layers body, so softmax precision
        must resolve uniformly across layers."""
        kinds = {lp.softmax.kind for lp in self.layers} or {"float"}
        if len(kinds) > 1:
            raise ValueError(
                f"policy {self.policy.name!r}: per-layer mixed softmax "
                "precision is not supported by the fused kernel path; use "
                "a uniform softmax rule (e.g. 'layers.*.attn.softmax')"
            )
        return "lut" if kinds == {"lut"} else "safe"

    def norm_mode(self) -> str:
        """Normalization datapath: float, lut (the paper's staged 1/sqrt
        LUT), or fixed (kernel-level output snapping).  Like softmax, the
        norm runs inside the single scan-over-layers body, so it must
        resolve uniformly across layers."""
        kinds = {lp.norm.kind for lp in self.layers} or {"float"}
        if len(kinds) > 1:
            raise ValueError(
                f"policy {self.policy.name!r}: per-layer mixed norm "
                "precision is not supported by the scan-over-layers path; "
                "use a uniform norm rule (e.g. 'layers.*.norm')"
            )
        return next(iter(kinds)) if kinds != {"float"} else "float"

    def kernel_defaults(self, kernel: dict | None) -> dict | None:
        """Fill policy-driven kernel knobs (explicit kernel dict wins)."""
        if self.softmax_mode() == "lut":
            kernel = dict(kernel or {})
            kernel.setdefault("softmax_mode", "lut")
        if self.norm_mode() == "lut":
            kernel = dict(kernel or {})
            kernel.setdefault("norm_lut", True)
        return kernel

    # ------------------------------------------------- runtime hooks --
    def _accum_cfg(self) -> fxp.FixedPointConfig:
        return self.accum.fixed_cfg() or fxp.ACCUM_CONFIG

    def quant_for(self, slot: SlotPlan) -> quant_lib.QuantConfig:
        """QuantConfig-compatible runtime hook for one dense site group.

        Only runtime (in-graph) transforms appear here: QAT weight STE and
        activation fake-quant.  PTQ snapping and int8 weight quantization
        are parameter transforms (``apply_plan_to_params``)."""
        w, a = slot.weights, slot.activations
        weight_cfg = (
            w.fixed_cfg() if w.kind == "fixed" and w.method == "qat" else None
        )
        act_cfg = a.fixed_cfg() if a.kind == "fixed" else None
        mode = "qat" if (weight_cfg is not None or act_cfg is not None) else "none"
        return quant_lib.QuantConfig(
            mode=mode,
            weight_cfg=weight_cfg,
            act_cfg=act_cfg,
            accum_cfg=self._accum_cfg(),
        )

    def embed_quant(self) -> quant_lib.QuantConfig:
        return self.quant_for(self.embed)

    def logits_quant(self) -> quant_lib.QuantConfig:
        return self.quant_for(self.logits)

    def shared_quant(self) -> quant_lib.QuantConfig:
        return self.quant_for(self.shared)

    def quant_for_layer(self, i: int) -> quant_lib.QuantConfig:
        return self.quant_for(self.layers[i])

    def uniform_layer_quant(self) -> quant_lib.QuantConfig | None:
        """The single runtime hook shared by all layers, or None when the
        plan is layer-heterogeneous (use ``layer_quant_arrays`` then)."""
        if all(
            (lp.weights, lp.activations)
            == (self.layers[0].weights, self.layers[0].activations)
            for lp in self.layers
        ):
            return self.quant_for_layer(0)
        return None

    def layer_quant_arrays(self) -> "LayerQuantArrays":
        """Stacked (n_layers,) fake-quant parameters for scan-over-layers.

        Heterogeneous per-layer fixed-point runs through ONE traced scan
        body: the step/bound scalars ride the scan xs, with step == 0
        meaning passthrough (float layers).  This keeps the bounded-
        compile discipline — per-layer precision adds no jit programs."""

        def row(slot_prec: Precision, runtime: bool):
            cfg = slot_prec.fixed_cfg() if runtime else None
            if cfg is None:
                return 0.0, 0.0, 0.0
            return cfg.step, cfg.min_value, cfg.max_value

        w_rows = [
            row(
                lp.weights,
                lp.weights.kind == "fixed" and lp.weights.method == "qat",
            )
            for lp in self.layers
        ]
        a_rows = [
            row(lp.activations, lp.activations.kind == "fixed")
            for lp in self.layers
        ]

        def col(rows, j):
            return jnp.asarray([r[j] for r in rows], jnp.float32)

        return LayerQuantArrays(
            w_step=col(w_rows, 0), w_lo=col(w_rows, 1), w_hi=col(w_rows, 2),
            a_step=col(a_rows, 0), a_lo=col(a_rows, 1), a_hi=col(a_rows, 2),
        )

    # -------------------------------------------------- param transform --
    @property
    def transforms_params(self) -> bool:
        slots = (
            [lp.weights for lp in self.layers]
            + [self.embed.weights, self.logits.weights, self.shared.weights,
               self.final_norm]
        )
        return any(p.kind in ("fixed", "int8") for p in slots)

    def to_dict(self) -> dict:
        return self.policy.to_dict()


# ---------------------------------------------------------------------------
# Heterogeneous per-layer runtime hook (rides scan xs)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class LayerQuantArrays:
    """QuantConfig-compatible fake-quant hook with traced parameters.

    All fields are f32 scalars inside the scan body ((n_layers,) stacked
    outside); ``step == 0`` disables that transform."""

    w_step: jax.Array
    w_lo: jax.Array
    w_hi: jax.Array
    a_step: jax.Array
    a_lo: jax.Array
    a_hi: jax.Array

    def maybe_fake_quant_weight(self, w: jax.Array) -> jax.Array:
        return _fake_quant_traced(w, self.w_step, self.w_lo, self.w_hi)

    def maybe_fake_quant_act(self, x: jax.Array) -> jax.Array:
        return _fake_quant_traced(x, self.a_step, self.a_lo, self.a_hi)


jax.tree_util.register_pytree_node(
    LayerQuantArrays,
    lambda q: ((q.w_step, q.w_lo, q.w_hi, q.a_step, q.a_lo, q.a_hi), None),
    lambda _, leaves: LayerQuantArrays(*leaves),
)


def _fake_quant_traced(x, step, lo, hi):
    """ap_fixed STE fake-quant with traced step/bounds (0-step = identity).

    Matches ``fixed_point.quantize_ste`` (round-to-nearest, saturate,
    clipped-STE gradient) when step > 0."""
    on = step > 0
    step = step.astype(x.dtype)
    lo = lo.astype(x.dtype)
    hi = hi.astype(x.dtype)
    safe = jnp.where(on, step, jnp.ones_like(step))
    q = jnp.clip(jnp.round(x / safe), lo / safe, hi / safe) * safe
    clipped = jnp.where(on, jnp.clip(x, lo, hi), x)
    q = jnp.where(on, q, x)
    return clipped + jax.lax.stop_gradient(q - clipped)


# ---------------------------------------------------------------------------
# Parameter-tree application (PTQ snap / int8 quantize-dequantize)
# ---------------------------------------------------------------------------

# top-level param-tree keys -> slot path prefix
_PARAM_SLOT_ALIASES = {
    "embed": "embed",
    "frontend_proj": "embed",
    "input_proj": "embed",
    "pos_embed": "embed",
    "lm_head": "logits",
    "head1": "logits",
    "head2": "logits",
    "final_norm": "norm",
    "shared_attn": "shared",
}


def _apply_precision_leaf(x, prec: Precision):
    if not (
        isinstance(x, jax.Array) and jnp.issubdtype(x.dtype, jnp.floating)
    ):
        return x
    if prec.kind == "fixed":
        return fxp.quantize(x, prec.fixed_cfg())
    if prec.kind == "int8":
        if x.ndim < 2:
            # biases / norm scales stay float — the paper also keeps
            # accumulator/bias precision above the datapath
            return x
        axis = x.ndim - 1 if prec.per_channel else None
        return quant_lib.quantize_int8(x, axis=axis, bits=prec.bits).dequantize(
            x.dtype
        )
    return x


def _apply_precision_tree(tree, prec: Precision):
    if prec.kind not in ("fixed", "int8"):
        return tree
    return jax.tree.map(lambda leaf: _apply_precision_leaf(leaf, prec), tree)


def apply_plan_to_params(params: PyTree, plan: PrecisionPlan) -> PyTree:
    """Offline parameter transform: snap fixed-point weights onto their
    ap_fixed grids and quantize-dequantize int8 weights, per the plan.

    The ``blocks`` subtree is stacked (leading layer axis) and supports a
    layer-heterogeneous plan; every other top-level key maps onto one
    global slot (embed / logits / norm / shared)."""
    if not plan.transforms_params:
        return params
    n_layers = len(plan.layers)
    w_precs = [lp.weights for lp in plan.layers]
    uniform = all(p == w_precs[0] for p in w_precs)
    out = {}
    for key, sub in params.items():
        if key == "blocks":
            if uniform and w_precs[0].kind == "float":
                out[key] = sub
            elif uniform and w_precs[0].kind == "fixed":
                # fixed snapping is elementwise — whole-stack application
                # equals per-layer application
                out[key] = _apply_precision_tree(sub, w_precs[0])
            else:
                # int8 (and heterogeneous) plans go per layer so a bias
                # stacked to (n_layers, d) is still seen as 1-D and stays
                # float, matching the per-layer ndim rule
                def _per_layer(leaf):
                    if not (
                        isinstance(leaf, jax.Array)
                        and jnp.issubdtype(leaf.dtype, jnp.floating)
                    ):
                        return leaf
                    assert leaf.shape[0] == n_layers, (leaf.shape, n_layers)
                    return jnp.stack(
                        [
                            _apply_precision_leaf(leaf[i], w_precs[i])
                            for i in range(n_layers)
                        ]
                    )

                out[key] = jax.tree.map(_per_layer, sub)
        else:
            prefix = _PARAM_SLOT_ALIASES.get(key, key)
            prec = plan.policy._lookup(f"{prefix}.weights", "weights")
            out[key] = _apply_precision_tree(sub, prec)
    return out


# ---------------------------------------------------------------------------
# Presets
# ---------------------------------------------------------------------------


def _preset_float() -> PrecisionPolicy:
    return PrecisionPolicy("float", ())


def _preset_int8_serve() -> PrecisionPolicy:
    """The serving performance path: int8 per-channel weights, int8
    per-token KV cache, LUT softmax — what the legacy ``--quantized``
    triple of booleans used to enable."""
    return PrecisionPolicy(
        "int8_serve",
        (
            Rule("*.weights", int8(per_channel=True)),
            Rule("kv_cache", int8(per_channel=False)),
            Rule("*.softmax", lut8()),
        ),
    )


def _preset_paper_vu13p() -> PrecisionPolicy:
    """The paper's VU13P configuration (Sec. VI-A): ap_fixed<12,6> weights
    and activations, LUT softmax/normalization datapaths, and the fixed
    10-integer-bit accumulator."""
    return PrecisionPolicy(
        "paper_vu13p",
        (
            Rule("*.weights", fixed(12, 6, method="ptq")),
            Rule("*.activations", fixed(12, 6)),
            Rule("layers.*.attn.softmax", lut8()),
            Rule("layers.*.norm", lut8()),
            Rule("accum", fixed(fxp.ACCUM_INT_BITS + 8, fxp.ACCUM_INT_BITS)),
        ),
    )


PRESETS = {
    "float": _preset_float,
    "int8_serve": _preset_int8_serve,
    "paper_vu13p": _preset_paper_vu13p,
}


def get_policy(name: "str | PrecisionPolicy") -> PrecisionPolicy:
    """Look up a named preset, parse a parametric ``{ptq,qat}_fixed<W,I>``
    family name, or pass a policy through unchanged."""
    if isinstance(name, PrecisionPolicy):
        return name
    if name in PRESETS:
        return PRESETS[name]()
    m = _FIXED_RE.match(name)
    if m:
        method, w, i = m.group(1), int(m.group(2)), int(m.group(3))
        rules: tuple[Rule, ...]
        if method == "ptq":
            rules = (Rule("*.weights", fixed(w, i, method="ptq")),)
        else:
            rules = (
                Rule("*.weights", fixed(w, i, method="qat")),
                Rule("*.activations", fixed(w, i)),
            )
        return PrecisionPolicy(name, rules)
    raise KeyError(
        f"unknown precision policy {name!r}; presets: {sorted(PRESETS)} "
        "or parametric 'ptq_fixed<W,I>' / 'qat_fixed<W,I>'"
    )


def policy_names() -> list[str]:
    return sorted(PRESETS)


# ---------------------------------------------------------------------------
# Legacy lowering (model-level QuantConfig)
# ---------------------------------------------------------------------------


def from_quant_config(qc: quant_lib.QuantConfig) -> PrecisionPolicy | None:
    """Lower a legacy QuantConfig onto an equivalent policy (None when the
    config selects nothing)."""
    rules = []
    if qc.mode in ("ptq", "qat") and qc.weight_cfg is not None:
        rules.append(
            Rule(
                "*.weights",
                fixed(
                    qc.weight_cfg.total_bits,
                    qc.weight_cfg.int_bits,
                    method="qat" if qc.mode == "qat" else "ptq",
                ),
            )
        )
    if qc.mode == "qat" and qc.act_cfg is not None:
        rules.append(
            Rule(
                "*.activations",
                fixed(qc.act_cfg.total_bits, qc.act_cfg.int_bits),
            )
        )
    if qc.int8_weights:
        rules.append(Rule("*.weights", int8(per_channel=True)))
    if qc.int8_kv_cache:
        rules.append(Rule("kv_cache", int8(per_channel=False)))
    if qc.lut_softmax:
        rules.append(Rule("*.softmax", lut8()))
    if qc.accum_cfg != fxp.ACCUM_CONFIG:
        rules.append(
            Rule(
                "accum",
                fixed(qc.accum_cfg.total_bits, qc.accum_cfg.int_bits),
            )
        )
    if not rules:
        return None
    return PrecisionPolicy("legacy_quant_config", tuple(rules))


# ---------------------------------------------------------------------------
# Model-level resolution (ModelConfig.precision with QuantConfig fallback)
# ---------------------------------------------------------------------------


def model_policy(cfg) -> PrecisionPolicy:
    """The policy governing a model: its explicit ``cfg.precision``, else
    the legacy ``cfg.quant`` lowered, else float."""
    explicit = getattr(cfg, "precision", None)
    if explicit is not None:
        return get_policy(explicit)
    legacy = from_quant_config(cfg.quant)
    return legacy if legacy is not None else _preset_float()


@functools.lru_cache(maxsize=512)
def _resolve_cached(policy: PrecisionPolicy, n_layers: int) -> PrecisionPlan:
    return policy.resolve(n_layers)


def resolve_model_plan(cfg) -> PrecisionPlan:
    """Resolve a ModelConfig's governing policy once (cached — resolution
    happens at trace time on every forward)."""
    return _resolve_cached(model_policy(cfg), cfg.n_layers)

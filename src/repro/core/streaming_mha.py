"""The paper's 4-stage streaming MHA pipeline (Sec. IV-A), module API.

Stage 1: Q/K/V linear projections     -> kernels/qmatmul (int8) or jnp
Stage 2: Q K^T, scale, softmax        -> fused into kernels/flash_attention
Stage 3: scores x V                   -> (same fused kernel)
Stage 4: concat heads + out projection-> kernels/qmatmul (int8) or jnp

On the FPGA the stages communicate through FIFOs; on TPU stages 2+3 fuse
into one VMEM-resident kernel and stages 1/4 are independent GEMM kernels —
the HBM->VMEM grid pipeline provides the producer/consumer overlap.

This module is the *paper-faithful inference path* used by the serving
engine for quantized models and by the physics-model benchmarks.  The
training path lives in ``models/attention.py`` (differentiable, shardable).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import quant
from repro.kernels.flash_attention import mha as fused_attention
from repro.kernels.qmatmul.ops import qmatmul_prequantized


@dataclasses.dataclass
class StreamingMHAParams:
    """Quantized weights for one MHA layer (performance path)."""

    wq: quant.QTensor  # (d_model, n_heads * d_head)
    wk: quant.QTensor
    wv: quant.QTensor
    wo: quant.QTensor  # (n_heads * d_head, d_model)
    bq: jax.Array | None = None
    bk: jax.Array | None = None
    bv: jax.Array | None = None
    bo: jax.Array | None = None


def quantize_mha_params(
    wq, wk, wv, wo, bq=None, bk=None, bv=None, bo=None
) -> StreamingMHAParams:
    return StreamingMHAParams(
        wq=quant.quantize_int8(wq, axis=1),
        wk=quant.quantize_int8(wk, axis=1),
        wv=quant.quantize_int8(wv, axis=1),
        wo=quant.quantize_int8(wo, axis=1),
        bq=bq, bk=bk, bv=bv, bo=bo,
    )


def streaming_mha(
    x: jax.Array,  # (batch, seq, d_model)
    params: StreamingMHAParams,
    *,
    n_heads: int,
    causal: bool = False,
    window: int | None = None,
    softmax_mode: str = "lut",  # the paper's default datapath
    use_pallas_attention: bool = False,
    interpret: bool = True,
) -> jax.Array:
    b, s, d_model = x.shape
    d_head = params.wq.shape[1] // n_heads

    def _proj(inp: jax.Array, w: quant.QTensor, bias) -> jax.Array:
        # Stage 1/4 GEMM: per-row activation quant + prequantized weights.
        flat = inp.reshape(b * s, -1)
        xq = quant.quantize_int8(flat, axis=0)
        out = qmatmul_prequantized(xq, w)
        if bias is not None:
            out = out + bias
        return out

    # ---- Stage 1: linear projections (row-streamed on FPGA) --------------
    q = _proj(x, params.wq, params.bq).reshape(b, s, n_heads, d_head)
    k = _proj(x, params.wk, params.bk).reshape(b, s, n_heads, d_head)
    v = _proj(x, params.wv, params.bv).reshape(b, s, n_heads, d_head)

    # ---- Stages 2+3: fused scores/softmax/weighted-sum -------------------
    q = q.transpose(0, 2, 1, 3)  # (b, h, s, d)
    k = k.transpose(0, 2, 1, 3)
    v = v.transpose(0, 2, 1, 3)
    o = fused_attention(
        q, k, v,
        causal=causal, window=window, mode=softmax_mode,
        use_pallas=use_pallas_attention, interpret=interpret,
    )

    # ---- Stage 4: concat heads + output projection ------------------------
    o = o.transpose(0, 2, 1, 3).reshape(b, s, n_heads * d_head)
    out = _proj(o, params.wo, params.bo)
    return out.reshape(b, s, -1)


def streaming_mha_float_ref(
    x: jax.Array,
    wq, wk, wv, wo,
    *,
    n_heads: int,
    causal: bool = False,
    window: int | None = None,
) -> jax.Array:
    """Float oracle of the whole 4-stage pipeline (tests/benchmarks)."""
    b, s, _ = x.shape
    d_head = wq.shape[1] // n_heads
    q = (x @ wq).reshape(b, s, n_heads, d_head).transpose(0, 2, 1, 3)
    k = (x @ wk).reshape(b, s, n_heads, d_head).transpose(0, 2, 1, 3)
    v = (x @ wv).reshape(b, s, n_heads, d_head).transpose(0, 2, 1, 3)
    o = fused_attention(q, k, v, causal=causal, window=window, mode="safe",
                        use_pallas=False)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, n_heads * d_head)
    return o @ wo

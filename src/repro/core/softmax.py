"""The paper's restructured 3-stage softmax (Sec. IV-B), module-level API.

Original hls4ml form (k^2 exponent evaluations):
    S_i = ( sum_j exp(z_j - z_i) )^{-1}

Paper's restructured form (k evaluations):
    S_i = exp(z_i) * ( sum_j exp(z_j) )^{-1}

computed in 3 pipeline stages:
  1. element-wise exp via LUT,
  2. sum + inversion via LUT (once per row),
  3. element-wise multiply.

There is deliberately *no max subtraction*: in the paper's fixed-point
datapath the score domain is bounded, so exp never overflows.  We keep that
behaviour for the quantized path (scores are clipped to the LUT domain,
which is exactly what ap_fixed saturation does), and provide the numerically
safe variant for the float path.

The Pallas kernel version lives in ``kernels/lut_softmax``; this module is
the framework-facing API and jnp fallback.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import lut


def softmax_paper_exact(x: jax.Array, axis: int = -1) -> jax.Array:
    """Paper's 3-stage dataflow with exact exp/reciprocal (no max-sub)."""
    e = jnp.exp(x)  # stage 1
    inv = 1.0 / jnp.sum(e, axis=axis, keepdims=True)  # stage 2
    return e * inv  # stage 3


def softmax_lut(x: jax.Array, axis: int = -1) -> jax.Array:
    """Paper's 3-stage softmax with LUT exp + LUT inversion.

    Inputs outside the exp-LUT domain saturate (ap_fixed AP_SAT analogue).
    """
    if axis != -1:
        x = jnp.moveaxis(x, axis, -1)
    e = lut.lut_exp(x)  # stage 1: exp LUT
    s = jnp.sum(e, axis=-1, keepdims=True)
    inv = lut.lut_inv(s)  # stage 2: inversion LUT
    out = e * inv  # stage 3: multiply
    if axis != -1:
        out = jnp.moveaxis(out, -1, axis)
    return out


def softmax_legacy_hls4ml(x: jax.Array, axis: int = -1) -> jax.Array:
    """The ORIGINAL hls4ml softmax the paper replaced — k^2 exponent terms.

    Implemented as the baseline the paper compares against (kept for the
    benchmark that reproduces the k vs k^2 operation-count argument).
    """
    # S_i = (sum_j exp(z_j - z_i))^{-1}
    diff = jnp.expand_dims(x, -2) - jnp.expand_dims(x, -1)  # [..., i, j]
    if axis != -1:
        raise NotImplementedError("legacy softmax only supports axis=-1")
    return 1.0 / jnp.sum(jnp.exp(diff), axis=-1)


def softmax_safe(x: jax.Array, axis: int = -1) -> jax.Array:
    """Float-path softmax with max subtraction (jax.nn.softmax semantics)."""
    return jax.nn.softmax(x, axis=axis)


def softmax(x: jax.Array, axis: int = -1, mode: str = "safe") -> jax.Array:
    """Framework entry point.  ``mode``: safe | paper | lut | legacy."""
    if mode == "safe":
        return softmax_safe(x, axis)
    if mode == "paper":
        return softmax_paper_exact(x, axis)
    if mode == "lut":
        return softmax_lut(x, axis)
    if mode == "legacy":
        return softmax_legacy_hls4ml(x, axis)
    raise ValueError(f"unknown softmax mode: {mode}")


def op_count(k: int, mode: str) -> int:
    """Exponent-evaluation count — the paper's k vs k^2 argument."""
    return k * k if mode == "legacy" else k

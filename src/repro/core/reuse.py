"""Reuse factor (paper Sec. VI-B) mapped to TPU kernel scheduling.

On the FPGA, reuse factor ``R`` = multiplications time-multiplexed onto one
DSP: R=1 is fully parallel (max DSPs, min latency), larger R trades compute
resources for initiation interval / latency, and drives BRAM-vs-register
array partitioning.

TPU translation: the MXU is the (fixed-size) DSP array, VMEM is the
register/BRAM budget.  ``R`` becomes the *sequentialization factor* of a
kernel's contraction dimension:

  * ``R = 1``  -> contraction dim loaded whole per output tile: one MXU
    streaming pass, maximum VMEM working set ("fully partitioned").
  * ``R = r``  -> contraction dim split into ``r`` sequential grid steps:
    the live working set shrinks ~r-fold ("BRAM-banked"), while the number
    of sequential passes — the initiation-interval analogue — grows r-fold.

``plan_matmul`` computes the concrete BlockSpec block shapes used by
``kernels/qmatmul`` / ``kernels/flash_attention``; ``resource_estimate``
reports the VMEM bytes ("resource") and pass count ("interval") that the
latency/resource benchmarks sweep, reproducing the structure of the paper's
Tables II-IV and Figs. 12-14.
"""

from __future__ import annotations

import dataclasses
import enum
import math


class Strategy(enum.Enum):
    """hls4ml synthesis strategy (Sec. VI-B).

    LATENCY: fully pipelined, output every cycle -> widest block shapes.
    RESOURCE: time-multiplex hardware across stages -> reuse-factor loop.
    """

    LATENCY = "latency"
    RESOURCE = "resource"


# TPU v5e-aligned tile granularities.
MXU_DIM = 128
LANE = 128
SUBLANE = 8
VMEM_BYTES = 128 * 1024 * 1024  # ~128 MiB VMEM per core on v5e


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class MatmulPlan:
    """Block plan for an (M,K) @ (K,N) kernel under a reuse factor."""

    block_m: int
    block_n: int
    block_k: int
    grid_m: int
    grid_n: int
    grid_k: int  # == reuse factor (sequential contraction passes)
    vmem_bytes: int

    @property
    def interval(self) -> int:
        """Sequential passes per output tile — the II analogue."""
        return self.grid_k


def plan_matmul(
    m: int,
    k: int,
    n: int,
    *,
    reuse_factor: int = 1,
    strategy: Strategy = Strategy.LATENCY,
    bytes_per_elem: int = 1,  # int8 datapath by default
    accum_bytes: int = 4,  # int32/float32 accumulator
    max_block_m: int = 512,
) -> MatmulPlan:
    """Translate (shape, R, strategy) into Pallas block shapes.

    R divides the contraction dim K into R sequential chunks.  Under the
    RESOURCE strategy, output tiles are narrowed first (time-multiplexing
    the MXU across output columns) before the contraction is split.
    """
    if reuse_factor < 1:
        raise ValueError(f"reuse_factor must be >= 1, got {reuse_factor}")
    m_pad = _round_up(max(m, 1), SUBLANE)
    k_pad = _round_up(max(k, 1), LANE)
    n_pad = _round_up(max(n, 1), LANE)

    block_m = min(m_pad, max_block_m)
    if strategy is Strategy.LATENCY:
        block_n = n_pad
    else:
        # resource strategy: one MXU-wide column stripe at a time
        block_n = min(n_pad, MXU_DIM)

    # reuse factor: split K into R sequential chunks (>= one lane each)
    grid_k = min(reuse_factor, max(1, k_pad // LANE))
    block_k = _round_up(k_pad // grid_k, LANE)
    grid_k = math.ceil(k_pad / block_k)

    vmem = (
        block_m * block_k * bytes_per_elem  # lhs tile
        + block_k * block_n * bytes_per_elem  # rhs tile
        + block_m * block_n * accum_bytes  # accumulator
    )
    # shrink block_m until the working set fits VMEM (with double buffering)
    while vmem * 2 > VMEM_BYTES and block_m > SUBLANE:
        block_m //= 2
        vmem = (
            block_m * block_k * bytes_per_elem
            + block_k * block_n * bytes_per_elem
            + block_m * block_n * accum_bytes
        )

    return MatmulPlan(
        block_m=block_m,
        block_n=block_n,
        block_k=block_k,
        grid_m=math.ceil(m_pad / block_m),
        grid_n=math.ceil(n_pad / block_n),
        grid_k=grid_k,
        vmem_bytes=vmem,
    )


@dataclasses.dataclass(frozen=True)
class ResourceEstimate:
    """The paper's resource/latency axes, TPU units.

    ``macs``        - multiply-accumulates (DSP-op analogue)
    ``vmem_bytes``  - live fast-memory working set (register/BRAM analogue)
    ``passes``      - sequential MXU passes (latency cycles analogue)
    ``interval``    - passes per new output tile (initiation interval)
    """

    macs: int
    vmem_bytes: int
    passes: int
    interval: int


def resource_estimate(plan: MatmulPlan) -> ResourceEstimate:
    total_passes = plan.grid_m * plan.grid_n * plan.grid_k
    macs = (
        plan.block_m
        * plan.block_n
        * plan.block_k
        * plan.grid_m
        * plan.grid_n
        * plan.grid_k
    )
    return ResourceEstimate(
        macs=macs,
        vmem_bytes=plan.vmem_bytes,
        passes=total_passes,
        interval=plan.interval,
    )

"""The paper's three benchmark models (Sec. V / Table I).

Small encoder transformers over continuous time-series inputs:

  engine_anomaly : seq 50 x 1,  3 blocks, d=16,  2-class softmax, NO norm
  btagging       : seq 15 x 6,  3 blocks, d=64,  3-class softmax
  gw             : seq 100 x 2, 2 blocks, d=32,  1-logit sigmoid, layernorm

Structure per the paper: input projection -> learned positional embedding ->
N transformer blocks (MHA + FFN, residual connections; the engine model
"forgoes the normalization layer") -> pooling -> two dense layers -> output.

These run through the same precision machinery as the big LMs
(``cfg.precision`` PrecisionPolicy with the legacy ``cfg.quant`` shim;
offline PTQ/int8 via ``core.precision.apply_plan_to_params``) and feed
the AUC-ratio-vs-bits policy-grid benchmark (paper Figs. 9-11).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import precision as precision_lib
from repro.models import blocks, layers
from repro.models import params as params_lib
from repro.models.params import ArraySpec


def param_spec(cfg: ModelConfig, dtype=jnp.float32):
    d = cfg.d_model
    spec = {
        "input_proj": layers.dense_spec(
            cfg.input_vec_size, d, axes=(None, "embed"), bias=True, dtype=dtype
        ),
        "pos_embed": ArraySpec(
            (cfg.seq_len, d), dtype, (None, "embed"), "normal", init_scale=0.02
        ),
        "blocks": params_lib.stack_spec(
            blocks.block_spec(cfg, dtype), cfg.n_layers
        ),
        "head1": layers.dense_spec(d, d, axes=("embed", "mlp"), bias=True, dtype=dtype),
        "head2": layers.dense_spec(
            d, cfg.n_classes, axes=("mlp", None), bias=True, dtype=dtype
        ),
    }
    if cfg.norm_kind != "none":
        spec["final_norm"] = layers.norm_spec(d, cfg.norm_kind, dtype)
    return spec


def init_params(cfg: ModelConfig, key: jax.Array, dtype=jnp.float32):
    return params_lib.init_params(param_spec(cfg, dtype), key)


def forward(
    params, cfg: ModelConfig, x: jax.Array, *, kernel: dict | None = None
) -> jax.Array:
    """x: (batch, seq_len, input_vec_size) -> logits (batch, n_classes)."""
    plan = precision_lib.resolve_model_plan(cfg)
    kernel = plan.kernel_defaults(kernel)
    h = layers.dense(params["input_proj"], x, plan.embed_quant())
    h = h + params["pos_embed"]
    positions = jnp.arange(cfg.seq_len, dtype=jnp.int32)

    uniform_quant = plan.uniform_layer_quant()
    layer_quants = (
        None if uniform_quant is not None else plan.layer_quant_arrays()
    )

    def body(carry, xs):
        hh = carry
        bparams, *rest = xs
        lquant = rest[0] if rest else uniform_quant
        hh, _, _ = blocks.block_apply(
            bparams, cfg, hh, positions, mode="train", cache=None,
            kernel=kernel, quant=lquant,
        )
        return hh, None

    xs = (params["blocks"],)
    if layer_quants is not None:
        xs = xs + (layer_quants,)
    h, _ = jax.lax.scan(body, h, xs)
    if cfg.norm_kind != "none":
        h = layers.norm(
            params["final_norm"], h, cfg.norm_kind, cfg.norm_eps,
            use_lut=(kernel or {}).get("norm_lut", False),
        )
    h = jnp.mean(h, axis=1)  # pool over time
    qc_head = plan.logits_quant()
    h = jax.nn.relu(layers.dense(params["head1"], h, qc_head))
    return layers.dense(params["head2"], h, qc_head)


def predict_proba(params, cfg: ModelConfig, x: jax.Array, **kw) -> jax.Array:
    """Probability of the positive / per-class probabilities (AUC input)."""
    logits = forward(params, cfg, x, **kw)
    if cfg.n_classes == 1:
        return jax.nn.sigmoid(logits[..., 0])
    return jax.nn.softmax(logits, axis=-1)


def loss_fn(params, cfg: ModelConfig, batch: dict, **kw):
    logits = forward(params, cfg, batch["x"], **kw)
    y = batch["y"]
    if cfg.n_classes == 1:
        logit = logits[..., 0]
        loss = jnp.mean(
            jnp.maximum(logit, 0) - logit * y + jnp.log1p(jnp.exp(-jnp.abs(logit)))
        )
        acc = jnp.mean((logit > 0) == (y > 0.5))
    else:
        logp = jax.nn.log_softmax(logits, axis=-1)
        loss = -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=-1))
        acc = jnp.mean(jnp.argmax(logits, -1) == y)
    return loss, {"loss": loss, "accuracy": acc}

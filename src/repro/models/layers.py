"""Shared primitive layers: dense (quantizable), norms, rope, embeddings."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import layernorm as ln_core
from repro.models.params import ArraySpec

# ---------------------------------------------------------------------------
# dense
# ---------------------------------------------------------------------------


def dense_spec(
    d_in: int,
    d_out: int,
    *,
    axes=("embed", "mlp"),
    bias: bool = False,
    dtype=jnp.float32,
    init: str = "fan_in",
):
    spec = {"kernel": ArraySpec((d_in, d_out), dtype, tuple(axes), init)}
    if bias:
        spec["bias"] = ArraySpec((d_out,), dtype, (axes[1],), "zeros")
    return spec


def dense(params, x: jax.Array, quant_cfg=None) -> jax.Array:
    """x @ kernel (+ bias), with optional QAT fake-quant hooks."""
    w = params["kernel"]
    if quant_cfg is not None:
        w = quant_cfg.maybe_fake_quant_weight(w)
        x = quant_cfg.maybe_fake_quant_act(x)
    y = jnp.einsum("...i,io->...o", x, w)
    if "bias" in params:
        y = y + params["bias"]
    return y


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def norm_spec(d: int, kind: str, dtype=jnp.float32):
    if kind == "none":
        return {}
    spec = {"scale": ArraySpec((d,), dtype, ("embed",), "ones")}
    if kind == "layernorm":
        spec["bias"] = ArraySpec((d,), dtype, ("embed",), "zeros")
    return spec


def norm(
    params, x: jax.Array, kind: str, eps: float = 1e-5,
    use_lut: bool = False,
) -> jax.Array:
    """``use_lut`` selects the paper's staged 1/sqrt-LUT datapath
    (Sec. IV-C) — enabled by a PrecisionPlan norm rule via the kernel
    dict (``norm_lut``)."""
    if kind == "none":
        return x
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        out = ln_core.rmsnorm(
            xf, params["scale"].astype(jnp.float32), eps=eps, use_lut=use_lut
        )
    elif kind == "layernorm":
        out = ln_core.layernorm_paper(
            xf,
            params["scale"].astype(jnp.float32),
            params["bias"].astype(jnp.float32),
            eps=eps,
            use_lut=use_lut,
        )
    else:
        raise ValueError(f"unknown norm kind {kind}")
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary position embedding
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def apply_rope(
    x: jax.Array,  # (..., seq, head_dim)
    positions: jax.Array,  # (..., seq) or (seq,)
    theta: float = 10000.0,
) -> jax.Array:
    head_dim = x.shape[-1]
    freqs = rope_freqs(head_dim, theta)  # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    cos = jnp.cos(angles)
    sin = jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# activations & embedding
# ---------------------------------------------------------------------------


def activation(x: jax.Array, kind: str) -> jax.Array:
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu":
        return jax.nn.gelu(x)
    if kind == "relu":
        return jax.nn.relu(x)
    raise ValueError(f"unknown activation {kind}")


def embedding_spec(vocab: int, d: int, dtype=jnp.float32):
    return {
        "table": ArraySpec(
            (vocab, d), dtype, ("vocab", "embed"), "embed", init_scale=0.02
        )
    }


def embed(params, tokens: jax.Array) -> jax.Array:
    return jnp.take(params["table"], tokens, axis=0)


def unembed(params, x: jax.Array) -> jax.Array:
    """Tied unembedding: x @ table.T"""
    return jnp.einsum("...d,vd->...v", x, params["table"])

"""Model orchestrator: causal LMs, encoders, VLMs — scan-over-layers,
prefill/decode with stacked caches, MoE aux accumulation, hybrid
shared-attention, modality-stub frontends.

Entry points
------------
``param_spec / init_params / abstract_params``  — parameter trees
``forward(params, cfg, batch, mode=...)``       — logits (+caches, aux)
``loss_fn``                                     — scalar loss + metrics
``init_caches / abstract_caches``               — stacked KV/SSM caches
``input_specs(cfg, shape)``                     — ShapeDtypeStruct inputs
                                                   for dry-run cells
"""

from __future__ import annotations

import functools
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core import precision as precision_lib
from repro.models import blocks, layers
from repro.models import params as params_lib
from repro.serve import kv_cache as kv_cache_lib

PyTree = Any


# ---------------------------------------------------------------------------
# Parameter trees
# ---------------------------------------------------------------------------


def n_shared_apps(cfg: ModelConfig) -> int:
    if cfg.family != "hybrid":
        return 0
    return math.ceil(cfg.n_layers / cfg.hybrid.attn_every)


def resolve_dtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def param_spec(cfg: ModelConfig, dtype=None) -> PyTree:
    dtype = resolve_dtype(cfg) if dtype is None else dtype
    d = cfg.d_model
    spec: dict = {}
    if cfg.frontend != "audio":
        spec["embed"] = layers.embedding_spec(cfg.padded_vocab_size, d, dtype)
    if cfg.frontend is not None:
        fd = cfg.frontend_dim or d
        spec["frontend_proj"] = layers.dense_spec(
            fd, d, axes=("frontend", "embed"), dtype=dtype
        )
    spec["blocks"] = params_lib.stack_spec(
        blocks.block_spec(cfg, dtype), cfg.n_layers
    )
    if cfg.family == "hybrid":
        spec["shared_attn"] = blocks.shared_attn_spec(cfg, dtype)
    spec["final_norm"] = layers.norm_spec(d, cfg.norm_kind, dtype)
    if not cfg.tie_embeddings:
        spec["lm_head"] = layers.dense_spec(
            d, cfg.padded_vocab_size, axes=("embed", "vocab"), dtype=dtype
        )
    return spec


def init_params(cfg: ModelConfig, key: jax.Array, dtype=None) -> PyTree:
    return params_lib.init_params(param_spec(cfg, dtype), key)


def abstract_params(cfg: ModelConfig, dtype=None) -> PyTree:
    return params_lib.abstract_params(param_spec(cfg, dtype))


def count_params(cfg: ModelConfig) -> int:
    return params_lib.count_params(param_spec(cfg))


# ---------------------------------------------------------------------------
# Caches — layout knowledge (dense slabs vs block-table pages, sequence-axis
# maps, logical sharding axes) lives in repro.serve.kv_cache; these aliases
# keep the historical lm-module entry points working.  All three accept the
# layout kwargs (layout= / page_size= / num_pages=) the manager passes.
# ---------------------------------------------------------------------------

abstract_caches = kv_cache_lib.abstract_caches
init_caches = kv_cache_lib.init_caches
cache_logical_axes = kv_cache_lib.cache_logical_axes


# ---------------------------------------------------------------------------
# Embedding frontends
# ---------------------------------------------------------------------------


def _embed_inputs(params, cfg: ModelConfig, batch: dict, mode: str, quant=None):
    """Returns (h, text_offset).  ``batch`` keys by family:

    LM: tokens (b, s).  VLM: patches (b, n_img, fd) + tokens (b, s_text)
    (decode: tokens only).  Audio: frames (b, s, fd).
    """
    qc = cfg.quant if quant is None else quant
    if cfg.frontend == "audio":
        h = layers.dense(params["frontend_proj"], batch["frames"], qc)
        return h, 0
    tok_emb = None
    if "tokens" in batch:
        tok_emb = layers.embed(params["embed"], batch["tokens"]) * cfg.emb_scale
    if cfg.frontend == "patch" and "patches" in batch and mode != "decode":
        patch_emb = layers.dense(
            params["frontend_proj"], batch["patches"], qc
        )
        if tok_emb is not None:
            h = jnp.concatenate([patch_emb, tok_emb], axis=1)
        else:
            h = patch_emb
        return h, patch_emb.shape[1]
    return tok_emb, 0


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _aux_init(cfg: ModelConfig):
    if cfg.moe is None:
        return {}
    return {
        "moe_aux_loss": jnp.float32(0.0),
        "moe_z_loss": jnp.float32(0.0),
        "moe_dropped_frac": jnp.float32(0.0),
    }


def _tree_index(tree, idx):
    return jax.tree.map(
        lambda a: jax.lax.dynamic_index_in_dim(a, idx, 0, keepdims=False), tree
    )


def _tree_update(tree, upd, idx):
    return jax.tree.map(
        lambda a, u: jax.lax.dynamic_update_index_in_dim(
            a, u.astype(a.dtype), idx, 0
        ),
        tree,
        upd,
    )


def _constrain_acts(x: jax.Array, kernel: dict | None) -> jax.Array:
    """Activation sharding constraint at block boundaries.

    Without this, XLA's propagation can resolve the FSDP-weight /
    DP-activation conflict by REPLICATING the batch (observed on the 256-
    chip dry-run: full-batch f32 buffers in the backward while body) —
    the constraint pins activations to (batch over data axes).
    """
    sh = (kernel or {}).get("act_sharding")
    if sh is None:
        return x
    return jax.lax.with_sharding_constraint(x, sh)


def _run_blocks(
    params,
    cfg: ModelConfig,
    h: jax.Array,
    positions: jax.Array,
    *,
    mode: str,
    caches: PyTree | None,
    kernel: dict | None,
    remat: str = "none",
    plan: precision_lib.PrecisionPlan | None = None,
):
    h = _constrain_acts(h, kernel)
    x_embed = h
    layer_caches = caches["layers"] if caches is not None else None
    shared_cache = caches.get("shared") if caches is not None else None
    aux0 = _aux_init(cfg)

    plan = plan if plan is not None else precision_lib.resolve_model_plan(cfg)
    # Homogeneous plans keep the legacy single-QuantConfig trace; per-layer
    # heterogeneous plans ride the scan xs as stacked step/bound scalars
    # (one traced body either way — no extra jit programs).
    uniform_quant = plan.uniform_layer_quant()
    shared_quant = plan.shared_quant() if cfg.family == "hybrid" else None
    layer_quants = (
        None if uniform_quant is not None else plan.layer_quant_arrays()
    )

    def body(carry, xs):
        x, shared_c, aux = carry
        bparams, lcache, idx, *rest = xs
        lquant = rest[0] if rest else uniform_quant
        if cfg.family == "hybrid":
            is_attn = (idx % cfg.hybrid.attn_every) == 0
            app_idx = idx // cfg.hybrid.attn_every

            def do_attn(op):
                x_in, sc = op
                c = _tree_index(sc, app_idx) if sc is not None else None
                x_out, new_c = blocks.shared_attn_apply(
                    params["shared_attn"], cfg, x_in, x_embed, positions,
                    mode=mode, cache=c, kernel=kernel, quant=shared_quant,
                )
                sc_out = (
                    _tree_update(sc, new_c, app_idx) if sc is not None else sc
                )
                return x_out, sc_out

            x, shared_c = jax.lax.cond(
                is_attn, do_attn, lambda op: op, (x, shared_c)
            )
        x, new_lcache, l_aux = blocks.block_apply(
            bparams, cfg, x, positions, mode=mode, cache=lcache,
            kernel=kernel, quant=lquant,
        )
        x = _constrain_acts(x, kernel)
        aux = {k: aux[k] + l_aux.get(k, 0.0) for k in aux}
        return (x, shared_c, aux), new_lcache

    if remat == "full":
        body = jax.checkpoint(body, prevent_cse=False)
    elif remat == "minimal":
        body = jax.checkpoint(
            body,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
            prevent_cse=False,
        )

    xs = (params["blocks"], layer_caches, jnp.arange(cfg.n_layers))
    if layer_quants is not None:
        xs = xs + (layer_quants,)
    (x, shared_cache, aux), new_layer_caches = jax.lax.scan(
        body, (h, shared_cache, aux0), xs
    )
    new_caches = None
    if caches is not None:
        new_caches = {"layers": new_layer_caches}
        if cfg.family == "hybrid":
            new_caches["shared"] = shared_cache
    return x, new_caches, aux


def forward(
    params,
    cfg: ModelConfig,
    batch: dict,
    *,
    mode: str = "train",
    caches: PyTree | None = None,
    positions: jax.Array | None = None,
    kernel: dict | None = None,
    remat: str = "none",
):
    """Returns (logits, new_caches, aux).

    positions: (S,) for train/prefill (defaults to arange), (B,) global
    positions of the new token for decode.
    """
    plan = precision_lib.resolve_model_plan(cfg)
    kernel = plan.kernel_defaults(kernel)
    h, text_offset = _embed_inputs(
        params, cfg, batch, mode, quant=plan.embed_quant()
    )
    if positions is None:
        if mode in ("decode", "extend"):
            raise ValueError(
                f"{mode} requires explicit per-sequence positions"
            )
        positions = jnp.arange(h.shape[1], dtype=jnp.int32)
    x, new_caches, aux = _run_blocks(
        params, cfg, h, positions,
        mode=mode, caches=caches, kernel=kernel, remat=remat, plan=plan,
    )
    x = layers.norm(
        params["final_norm"], x, cfg.norm_kind, cfg.norm_eps,
        use_lut=(kernel or {}).get("norm_lut", False),
    )
    if cfg.tie_embeddings:
        logits = layers.unembed(params["embed"], x)
    else:
        logits = layers.dense(params["lm_head"], x, plan.logits_quant())
    logits = logits * cfg.logit_scale
    # mask vocab padding
    pad = cfg.padded_vocab_size - cfg.vocab_size
    if pad > 0:
        mask = jnp.arange(cfg.padded_vocab_size) < cfg.vocab_size
        logits = jnp.where(mask, logits, -1e9)
    aux["text_offset"] = text_offset
    return logits, new_caches, aux


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------


def _cross_entropy(logits, labels, mask, tp_safe: bool = False):
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    if tp_safe:
        # TP-aware label gather: an einsum against a one-hot partitions
        # cleanly over a vocab-sharded logits axis (becomes a local dot +
        # psum), whereas take_along_axis makes XLA all-gather the logits.
        onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logp.dtype)
        ll = jnp.einsum("...v,...v->...", logp, onehot)
    else:
        ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    loss = -jnp.sum(ll * mask) / denom
    acc = jnp.sum((jnp.argmax(logits, -1) == labels) * mask) / denom
    return loss, acc


def loss_fn(
    params,
    cfg: ModelConfig,
    batch: dict,
    *,
    kernel: dict | None = None,
    remat: str = "none",
):
    logits, _, aux = forward(
        params, cfg, batch, mode="train", kernel=kernel, remat=remat
    )
    tp_safe = bool((kernel or {}).get("tp_loss", False))
    if cfg.is_encoder:
        labels = batch["labels"]
        mask = batch.get("loss_mask", jnp.ones_like(labels, jnp.float32))
        loss, acc = _cross_entropy(logits, labels, mask, tp_safe)
    else:
        off = aux.pop("text_offset", 0)
        tokens = batch["tokens"]
        text_logits = logits[:, off:]
        pred = text_logits[:, :-1]
        labels = tokens[:, 1:]
        mask = batch.get(
            "loss_mask", jnp.ones_like(tokens, jnp.float32)
        )[:, 1:]
        loss, acc = _cross_entropy(pred, labels, mask, tp_safe)
    total = loss
    metrics = {"ce_loss": loss, "accuracy": acc}
    for k in ("moe_aux_loss", "moe_z_loss"):
        if k in aux:
            total = total + aux[k]
            metrics[k] = aux[k]
    if "moe_dropped_frac" in aux:
        metrics["moe_dropped_frac"] = aux["moe_dropped_frac"] / cfg.n_layers
    metrics["loss"] = total
    return total, metrics


# ---------------------------------------------------------------------------
# Serving entry points
# ---------------------------------------------------------------------------


def prefill(
    params,
    cfg: ModelConfig,
    batch: dict,
    caches: PyTree,
    *,
    kernel: dict | None = None,
):
    """Run the prompt through the model, filling caches.

    Returns (last-position logits (B, V), caches)."""
    logits, new_caches, _ = forward(
        params, cfg, batch, mode="prefill", caches=caches, kernel=kernel
    )
    return logits[:, -1], new_caches


def decode_step(
    params,
    cfg: ModelConfig,
    tokens: jax.Array,  # (B, 1)
    positions: jax.Array,  # (B,) global position of the new token
    caches: PyTree,
    *,
    kernel: dict | None = None,
):
    logits, new_caches, _ = forward(
        params, cfg, {"tokens": tokens}, mode="decode",
        caches=caches, positions=positions, kernel=kernel,
    )
    return logits[:, -1], new_caches


# ---------------------------------------------------------------------------
# Dry-run input specs (ShapeDtypeStruct stand-ins, no allocation)
# ---------------------------------------------------------------------------


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Model inputs for one (arch x shape) dry-run cell."""
    b, s = shape.global_batch, shape.seq_len
    tok = jax.ShapeDtypeStruct((b, s), jnp.int32)
    if shape.kind == "decode":
        return {
            "tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32),
            "positions": jax.ShapeDtypeStruct((b,), jnp.int32),
        }
    if cfg.frontend == "audio":
        fd = cfg.frontend_dim or cfg.d_model
        specs = {"frames": jax.ShapeDtypeStruct((b, s, fd), jnp.float32)}
        if shape.kind == "train":
            specs["labels"] = tok
        return specs
    if cfg.frontend == "patch":
        fd = cfg.frontend_dim or cfg.d_model
        n_img = cfg.n_frontend_tokens
        return {
            "patches": jax.ShapeDtypeStruct((b, n_img, fd), jnp.float32),
            "tokens": jax.ShapeDtypeStruct((b, s - n_img), jnp.int32),
        }
    return {"tokens": tok}

"""Transformer / MoE / Mamba / hybrid blocks (pre-norm residual).

MiniCPM-style muP scaling is supported via ``cfg.residual_scale`` (each
residual branch is scaled — "scale_depth / sqrt(n_layers)").
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention, layers, mlp, moe, ssm


def block_kind(cfg: ModelConfig, layer_idx: int | None = None) -> str:
    if cfg.family in ("ssm", "hybrid"):
        return "mamba"
    if cfg.moe is not None:
        return "moe"
    return "dense"


def block_spec(cfg: ModelConfig, dtype=jnp.float32):
    kind = block_kind(cfg)
    if kind == "mamba":
        return {
            "ln1": layers.norm_spec(cfg.d_model, cfg.norm_kind, dtype),
            "mamba": ssm.mamba_spec(cfg, dtype),
        }
    spec = {
        "ln1": layers.norm_spec(cfg.d_model, cfg.norm_kind, dtype),
        "attn": attention.attention_spec(cfg, dtype),
        "ln2": layers.norm_spec(cfg.d_model, cfg.norm_kind, dtype),
    }
    if kind == "moe":
        spec["ffn"] = moe.moe_spec(cfg, dtype)
    else:
        spec["ffn"] = mlp.mlp_spec(cfg, dtype)
    return spec


def block_apply(
    params,
    cfg: ModelConfig,
    x: jax.Array,
    positions: jax.Array,
    *,
    mode: str = "train",
    cache=None,
    kernel: dict | None = None,
    quant=None,  # per-layer runtime hook from the precision plan
):
    """Returns (x, new_cache, aux)."""
    kind = block_kind(cfg)
    rs = cfg.residual_scale
    norm_lut = (kernel or {}).get("norm_lut", False)
    aux = {}
    if kind == "mamba":
        h = layers.norm(
            params["ln1"], x, cfg.norm_kind, cfg.norm_eps, use_lut=norm_lut
        )
        out, new_cache = ssm.mamba_apply(
            params["mamba"], cfg, h, mode=mode, cache=cache, quant=quant
        )
        x = x + rs * out
        return x, new_cache, aux

    h = layers.norm(
        params["ln1"], x, cfg.norm_kind, cfg.norm_eps, use_lut=norm_lut
    )
    attn_out, new_cache = attention.attention_apply(
        params["attn"], cfg, h, positions, mode=mode, cache=cache,
        kernel=kernel, quant=quant,
    )
    x = x + rs * attn_out
    h = layers.norm(
        params["ln2"], x, cfg.norm_kind, cfg.norm_eps, use_lut=norm_lut
    )
    if kind == "moe":
        ffn_out, aux = moe.moe_apply(params["ffn"], cfg, h)
    else:
        ffn_out = mlp.mlp_apply(params["ffn"], cfg, h, quant=quant)
    x = x + rs * ffn_out
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# Zamba2-style shared attention block (hybrid family)
# ---------------------------------------------------------------------------


def shared_attn_cfg(cfg: ModelConfig) -> ModelConfig:
    """Derived attention config for the shared block: it attends in the
    concat(x, x_embed) space (width 2*d_model) and projects back to d."""
    width = 2 * cfg.d_model if cfg.hybrid.concat_residual else cfg.d_model
    return dataclasses.replace(
        cfg,
        attn_kind="gqa",
        head_dim=width // cfg.n_heads,
        sliding_window=None,
        ssm=None,
    )


def shared_attn_spec(cfg: ModelConfig, dtype=jnp.float32):
    """Zamba2 shared block: a full transformer block (attention + MLP) in
    the concat(x, x_embed) width-W space, followed by a W->d down-projector.
    Weights are shared across all applications (every ``attn_every`` layers);
    each application has its own KV cache."""
    acfg = shared_attn_cfg(cfg)
    w = 2 * cfg.d_model if cfg.hybrid.concat_residual else cfg.d_model
    hd = acfg.resolved_head_dim
    wcfg = dataclasses.replace(acfg, d_model=w)
    return {
        "ln1": layers.norm_spec(w, cfg.norm_kind, dtype),
        "attn": {
            "wq": layers.dense_spec(w, cfg.n_heads * hd, axes=("embed", "heads"), dtype=dtype),
            "wk": layers.dense_spec(w, cfg.n_kv_heads * hd, axes=("embed", "kv_heads"), dtype=dtype),
            "wv": layers.dense_spec(w, cfg.n_kv_heads * hd, axes=("embed", "kv_heads"), dtype=dtype),
            "wo": layers.dense_spec(cfg.n_heads * hd, w, axes=("heads", "embed"), dtype=dtype),
        },
        "ln2": layers.norm_spec(w, cfg.norm_kind, dtype),
        "mlp": mlp.mlp_spec(wcfg, dtype),
        "out_proj": layers.dense_spec(w, cfg.d_model, axes=("mlp", "embed"), dtype=dtype),
    }


def shared_attn_cache_spec(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    # hybrid shared-attention caches are always dense: the hybrid family is
    # not position-addressed end-to-end, so the paged layout never applies
    return attention.cache_spec(shared_attn_cfg(cfg), batch, max_len, dtype)


def shared_attn_apply(
    params,
    cfg: ModelConfig,
    x: jax.Array,
    x_embed: jax.Array,
    positions: jax.Array,
    *,
    mode: str = "train",
    cache=None,
    kernel: dict | None = None,
    quant=None,  # shared-block runtime hook from the precision plan
):
    acfg = shared_attn_cfg(cfg)
    wcfg = dataclasses.replace(acfg, d_model=2 * cfg.d_model)
    qc = cfg.quant if quant is None else quant
    norm_lut = (kernel or {}).get("norm_lut", False)
    h = (
        jnp.concatenate([x, x_embed], axis=-1)
        if cfg.hybrid.concat_residual
        else x
    )
    a = layers.norm(
        params["ln1"], h, cfg.norm_kind, cfg.norm_eps, use_lut=norm_lut
    )
    a, new_cache = attention.gqa_apply(
        params["attn"], acfg, a, positions, mode=mode, cache=cache,
        kernel=kernel, quant=quant,
    )
    h = h + a
    m = layers.norm(
        params["ln2"], h, cfg.norm_kind, cfg.norm_eps, use_lut=norm_lut
    )
    h = h + mlp.mlp_apply(params["mlp"], wcfg, m, quant=quant)
    out = layers.dense(params["out_proj"], h, qc)
    return x + cfg.residual_scale * out, new_cache

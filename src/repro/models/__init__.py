"""Architecture zoo: composable model definitions over the param-spec
system (see params.py), covering dense / MoE / SSM / hybrid / VLM / audio
families for the 10 assigned architectures plus the paper's three physics
models (models/physics.py)."""

from repro.models import (  # noqa: F401
    attention,
    blocks,
    layers,
    lm,
    mlp,
    moe,
    params,
    ssm,
)

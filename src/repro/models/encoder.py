"""Encoder-only backbone (hubert-xlarge) — thin wrapper over models.lm.

The audio frontend is a stub per the assignment: ``input_specs`` provides
precomputed frame embeddings (the wav2vec2-style conv feature extractor is
out of scope); ``frontend_proj`` maps them into d_model.  Training uses a
HuBERT-style masked-unit prediction objective over ``vocab_size`` units
(labels supplied by the data pipeline).

Encoder models have no decode step (bidirectional attention, no KV cache) —
``decode_32k``/``long_500k`` dry-run cells are skipped for this family.
"""

from __future__ import annotations

from repro.models.lm import (  # noqa: F401
    abstract_params,
    count_params,
    forward,
    init_params,
    input_specs,
    loss_fn,
    param_spec,
)

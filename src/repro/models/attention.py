"""Attention variants: GQA/MHA/MQA, MLA (latent KV), sliding-window — with
prefill/decode KV caches (dense, rolling-buffer, latent, paged,
int8-quantized).

All functions are pure; caches are pytrees (dicts of arrays) so they stack
under scan-over-layers and shard under pjit.  Cache *layout* knowledge
(dense slabs vs block-table pages, sequence-axis maps, specs) lives in
``repro.serve.kv_cache``; decode reads/writes go through that module's
gather/scatter views instead of assuming a contiguous sequence axis.
The fused streaming-attention kernel (``kernels/flash_attention``) is the
TPU target for the score path; the jnp reference path
(``use_pallas=False``) is used on CPU hosts/tests.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import quant
from repro.core import softmax as sm
from repro.kernels.flash_attention import mha as fused_mha
from repro.kernels.flash_attention.ref import NEG_INF
from repro.models import layers
from repro.models.params import ArraySpec
from repro.serve import kv_cache as kv_cache_lib

Cache = dict[str, Any]


# ---------------------------------------------------------------------------
# Param specs
# ---------------------------------------------------------------------------


def gqa_spec(cfg: ModelConfig, dtype=jnp.float32):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    h, hkv = cfg.n_heads, cfg.n_kv_heads
    spec = {
        "wq": layers.dense_spec(d, h * hd, axes=("embed", "heads"), bias=cfg.attn_bias, dtype=dtype),
        "wk": layers.dense_spec(d, hkv * hd, axes=("embed", "kv_heads"), bias=cfg.attn_bias, dtype=dtype),
        "wv": layers.dense_spec(d, hkv * hd, axes=("embed", "kv_heads"), bias=cfg.attn_bias, dtype=dtype),
        "wo": layers.dense_spec(h * hd, d, axes=("heads", "embed"), bias=cfg.attn_bias, dtype=dtype),
    }
    return spec


def mla_spec(cfg: ModelConfig, dtype=jnp.float32):
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "wq_a": layers.dense_spec(d, m.q_lora_rank, axes=("embed", "q_lora"), dtype=dtype),
        "q_norm": layers.norm_spec(m.q_lora_rank, "rmsnorm", dtype),
        "wq_b": layers.dense_spec(m.q_lora_rank, h * qk, axes=("q_lora", "heads"), dtype=dtype),
        "wkv_a": layers.dense_spec(
            d, m.kv_lora_rank + m.qk_rope_head_dim, axes=("embed", "kv_lora"), dtype=dtype
        ),
        "kv_norm": layers.norm_spec(m.kv_lora_rank, "rmsnorm", dtype),
        "wk_b": layers.dense_spec(
            m.kv_lora_rank, h * m.qk_nope_head_dim, axes=("kv_lora", "heads"), dtype=dtype
        ),
        "wv_b": layers.dense_spec(
            m.kv_lora_rank, h * m.v_head_dim, axes=("kv_lora", "heads"), dtype=dtype
        ),
        "wo": layers.dense_spec(h * m.v_head_dim, d, axes=("heads", "embed"), dtype=dtype),
    }


def attention_spec(cfg: ModelConfig, dtype=jnp.float32):
    if cfg.attn_kind == "mla":
        return mla_spec(cfg, dtype)
    return gqa_spec(cfg, dtype)


# ---------------------------------------------------------------------------
# KV caches
# ---------------------------------------------------------------------------


# Layout-aware cache specs live in repro.serve.kv_cache; these aliases
# keep the historical attention-module entry points working.
cache_spec = kv_cache_lib.attention_cache_spec
init_cache = kv_cache_lib.init_attention_cache


# ---------------------------------------------------------------------------
# GQA apply
# ---------------------------------------------------------------------------


def _split_heads(x, n_heads, head_dim):
    b, s, _ = x.shape
    return x.reshape(b, s, n_heads, head_dim).transpose(0, 2, 1, 3)


def _merge_heads(x):
    b, h, s, d = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, s, h * d)


def gqa_apply(
    params,
    cfg: ModelConfig,
    x: jax.Array,  # (B, S, D)
    positions: jax.Array,  # (S,) global positions
    *,
    mode: str = "train",  # train | prefill | extend | decode
    cache: Cache | None = None,
    kernel: dict | None = None,
    quant=None,  # per-layer runtime hook from the precision plan
) -> tuple[jax.Array, Cache | None]:
    kernel = kernel or {}
    qc = cfg.quant if quant is None else quant
    hd = cfg.resolved_head_dim
    b, s, _ = x.shape

    q = layers.dense(params["wq"], x, qc)
    k = layers.dense(params["wk"], x, qc)
    v = layers.dense(params["wv"], x, qc)
    q = _split_heads(q, cfg.n_heads, hd)
    k = _split_heads(k, cfg.n_kv_heads, hd)
    v = _split_heads(v, cfg.n_kv_heads, hd)
    # positions: (S,) shared across batch for train/prefill, (B,) per-sequence
    # global positions for decode, (B, S) per-row windows for cache-extend
    # (continuous batching).
    if cfg.use_rope:
        if mode == "decode":
            rope_pos = positions[:, None, None]
        elif mode == "extend":
            rope_pos = positions[:, None, :]
        else:
            rope_pos = positions
        q = layers.apply_rope(q, rope_pos, cfg.rope_theta)
        k = layers.apply_rope(k, rope_pos, cfg.rope_theta)

    new_cache = cache
    window = cfg.sliding_window
    rolling = (
        cache is not None
        and window is not None
        and "slot_pos" in (cache or {})
    )
    quantized = cache is not None and "k_scale" in cache

    if quantized:
        k_store, k_sc = _kv_quantize(k)
        v_store, v_sc = _kv_quantize(v)
    elif cache is not None:
        k_store = k.astype(cache["k"].dtype)
        v_store = v.astype(cache["v"].dtype)
        k_sc = v_sc = None

    if mode in ("train",) or cache is None:
        out = fused_mha(
            q, k, v,
            causal=not cfg.is_encoder,
            window=window,
            mode=kernel.get("softmax_mode", "safe"),
            use_pallas=kernel.get("use_pallas", False),
            interpret=kernel.get("interpret", True),
        )
    elif mode == "prefill":
        if kv_cache_lib.is_paged(cache):
            raise ValueError(
                "prefill fills a dense scratch cache; insert it into paged "
                "storage via serve.kv_cache.CacheManager.insert_prefill"
            )
        if rolling:
            w = window

            def tail3(t, fill=0):  # (b,h,s,...) -> last w along axis 2
                if s >= w:
                    return t[:, :, -w:]
                pad = [(0, 0)] * t.ndim
                pad[2] = (0, w - s)
                return jnp.pad(t, pad, constant_values=fill)

            pos_tail = (
                positions[-w:]
                if s >= w
                else jnp.pad(positions, (0, w - s), constant_values=-1)
            )
            # invalid (padded) entries get slot index w -> dropped by scatter
            slots = jnp.where(pos_tail >= 0, pos_tail % w, w)

            def scatter3(buf, t):
                return jnp.zeros_like(buf).at[:, :, slots].set(t, mode="drop")

            new_cache = {
                "k": scatter3(cache["k"], tail3(k_store)),
                "v": scatter3(cache["v"], tail3(v_store)),
            }
            slot_pos = (
                jnp.full((w,), -1, jnp.int32)
                .at[slots]
                .set(pos_tail, mode="drop")
            )
            # per-sequence slot positions (all rows identical after prefill)
            new_cache["slot_pos"] = jnp.broadcast_to(slot_pos, (b, w))
            if quantized:
                new_cache["k_scale"] = scatter3(cache["k_scale"], tail3(k_sc))
                new_cache["v_scale"] = scatter3(cache["v_scale"], tail3(v_sc))
        else:
            new_cache = {
                "k": jax.lax.dynamic_update_slice(
                    cache["k"], k_store, (0, 0, 0, 0)
                ),
                "v": jax.lax.dynamic_update_slice(
                    cache["v"], v_store, (0, 0, 0, 0)
                ),
            }
            if quantized:
                new_cache["k_scale"] = jax.lax.dynamic_update_slice(
                    cache["k_scale"], k_sc, (0, 0, 0)
                )
                new_cache["v_scale"] = jax.lax.dynamic_update_slice(
                    cache["v_scale"], v_sc, (0, 0, 0)
                )
        if quantized:
            # attend the cache's own representation (int8 roundtrip):
            # prefill scores the exact values decode and cache-extend
            # will read back, so replaying any of these positions later
            # reproduces the same bits
            k_att = k_store.astype(jnp.float32) * k_sc[..., None]
            v_att = v_store.astype(jnp.float32) * v_sc[..., None]
        else:
            k_att, v_att = k, v
        out = fused_mha(
            q, k_att, v_att,
            causal=True,
            window=window,
            mode=kernel.get("softmax_mode", "safe"),
            use_pallas=kernel.get("use_pallas", False),
            interpret=kernel.get("interpret", True),
        )
    elif mode == "extend":
        # cache-extending prefill: W window tokens per row written at
        # per-row global positions (B, W) through the layout scatter,
        # then attended with the prefill-path math against the full
        # logical view (history + window) — so the window's activations
        # and cache entries are bitwise what a whole-prompt prefill
        # would have produced at the same positions.  Masked window
        # entries carry an out-of-range sentinel position: dropped by
        # the dense scatter, routed to the trash page by the paged one,
        # and masked out of every window row's reduction.
        if rolling:
            raise ValueError(
                "cache-extend requires a position-addressed cache; "
                "rolling sliding-window buffers prefill exact-length"
            )
        upd = {"k": k_store, "v": v_store}
        if quantized:
            upd["k_scale"], upd["v_scale"] = k_sc, v_sc
        if kv_cache_lib.is_paged(cache):
            new_cache = kv_cache_lib.paged_window_write(cache, upd, positions)
            view = kv_cache_lib.paged_decode_view(new_cache)
        else:
            new_cache = kv_cache_lib.dense_window_write(cache, upd, positions)
            view = new_cache
        kv_pos = jnp.arange(view["k"].shape[2])
        mask = kv_pos[None, None, :] <= positions[:, :, None]  # (B, W, L)
        if window is not None:
            mask = mask & (positions[:, :, None] - kv_pos[None, None, :] < window)
        out = _window_attend(
            q, view["k"], view["v"], mask,
            softmax_mode=kernel.get("softmax_mode", "safe"),
            k_scale=view.get("k_scale"),
            v_scale=view.get("v_scale"),
        )
    else:  # decode: s == 1, attend over cache; positions is (B,) per-seq
        pos = positions  # (B,)
        if kv_cache_lib.is_paged(cache):
            # layout-provided scatter (one token into its physical page)
            # and gather (pages -> contiguous logical view): the math
            # below is then bit-identical to the dense slab path.
            upd = {"k": k_store[:, :, 0], "v": v_store[:, :, 0]}
            if quantized:
                upd["k_scale"] = k_sc[:, :, 0]
                upd["v_scale"] = v_sc[:, :, 0]
            new_cache = kv_cache_lib.paged_decode_write(cache, upd, pos)
            view = kv_cache_lib.paged_decode_view(new_cache)
        else:
            bi = jnp.arange(b)[:, None]
            hi = jnp.arange(cfg.n_kv_heads)[None, :]
            slot = pos % window if rolling else pos  # (B,)
            new_cache = {
                "k": cache["k"].at[bi, hi, slot[:, None]].set(k_store[:, :, 0]),
                "v": cache["v"].at[bi, hi, slot[:, None]].set(v_store[:, :, 0]),
            }
            if quantized:
                new_cache["k_scale"] = cache["k_scale"].at[
                    bi, hi, slot[:, None]
                ].set(k_sc[:, :, 0])
                new_cache["v_scale"] = cache["v_scale"].at[
                    bi, hi, slot[:, None]
                ].set(v_sc[:, :, 0])
            if rolling:
                new_cache["slot_pos"] = cache["slot_pos"].at[
                    jnp.arange(b), slot
                ].set(pos)
            view = new_cache
        if rolling:
            slot_pos = new_cache["slot_pos"]
            valid = (
                (slot_pos >= 0)
                & (slot_pos <= pos[:, None])
                & (slot_pos > pos[:, None] - window)
            )  # (B, w)
        else:
            kv_pos = jnp.arange(view["k"].shape[2])
            valid = kv_pos[None, :] <= pos[:, None]  # (B, L)
        out = _decode_attend(
            q,
            view["k"],
            view["v"],
            valid,
            k_scale=view.get("k_scale"),
            v_scale=view.get("v_scale"),
        )

    out = _merge_heads(out)
    out = layers.dense(params["wo"], out, qc)
    return out, new_cache


def _kv_quantize(x: jax.Array):
    """(b, h, s, d) -> (int8 codes, f32 scales (b, h, s)). Per-token-head
    symmetric int8 — the paper's fixed-point datapath applied to the KV
    cache (4x memory/bandwidth vs bf16)."""
    scale = jnp.maximum(jnp.max(jnp.abs(x), axis=-1), 1e-8) / 127.0
    codes = jnp.clip(jnp.round(x / scale[..., None]), -128, 127).astype(jnp.int8)
    return codes, scale.astype(jnp.float32)


def _window_attend(
    q: jax.Array,  # (B, Hq, W, Dq)
    k: jax.Array,  # (B, Hkv, L, Dk) float or int8 codes
    v: jax.Array,  # (B, Hkv, L, Dv)
    mask: jax.Array,  # (B, W, L) bool: window row i attends kv position j
    *,
    softmax_mode: str = "safe",
    k_scale: jax.Array | None = None,  # (B, Hkv, L) when k is int8
    v_scale: jax.Array | None = None,
) -> jax.Array:
    """Window attention over a cache-backed view with *prefill-path*
    math.

    The cache-extending prefill program's attend: a window of W query
    rows against the full logical cache (history + the just-written
    window), under an explicit per-row mask.  Mirrors the jnp reference
    path (``fused_mha`` with ``use_pallas=False`` ->
    ``kernels.flash_attention.ref.attention_ref``) operation for
    operation — KV heads repeated across query groups, one scaled
    einsum, masked scores at NEG_INF (safe) or zero weight (lut) — so
    window rows produce bitwise the activations a whole-prompt prefill
    would have at the same positions.  Masked columns are a suffix of
    the reduction axis and contribute exactly +0.0, which keeps the
    reduction bitwise stable across cache lengths (the same property
    the decode path already relies on).
    """
    b, hq, w, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    if k_scale is not None:
        kf = kf * k_scale[..., None]
    if v_scale is not None:
        vf = vf * v_scale[..., None]
    if group > 1:
        kf = jnp.repeat(kf, group, axis=1)
        vf = jnp.repeat(vf, group, axis=1)
    qf = q.astype(jnp.float32)
    scale = 1.0 / (d ** 0.5)
    m = mask[:, None]  # (B, 1, W, L) broadcast over heads
    with jax.named_scope("attnvol"):
        s = jnp.einsum("...qd,...kd->...qk", qf, kf) * scale
        if softmax_mode == "safe":
            s = jnp.where(m, s, NEG_INF)
            p = jax.nn.softmax(s, axis=-1)
        else:  # paper's LUT softmax, masked entries contribute zero weight
            e = sm.lut.lut_exp(s)
            e = jnp.where(m, e, 0.0)
            denom = jnp.sum(e, axis=-1, keepdims=True)
            p = e * sm.lut.lut_inv(denom)
        out = jnp.einsum("...qk,...kd->...qd", p, vf)
    return out.astype(q.dtype)


def _decode_attend(
    q: jax.Array,  # (B, Hq, 1, D)
    k: jax.Array,  # (B, Hkv, L, D) float or int8 codes
    v: jax.Array,
    valid: jax.Array,  # (B, L) bool
    k_scale: jax.Array | None = None,  # (B, Hkv, L) when k is int8
    v_scale: jax.Array | None = None,
) -> jax.Array:
    """Single-token attention over a (possibly int8-quantized) cache."""
    b, hq, s, d = q.shape
    assert s == 1, "decode attention expects a single query position"
    hkv = k.shape[1]
    group = hq // hkv
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    if k_scale is not None:
        kf = kf * k_scale[..., None]
    if v_scale is not None:
        vf = vf * v_scale[..., None]
    qf = q.astype(jnp.float32).reshape(b, hkv, group * s, d)
    with jax.named_scope("attnvol"):
        scores = jnp.einsum("bhqd,bhkd->bhqk", qf, kf)
        scores = scores / (d ** 0.5)
        scores = jnp.where(valid[:, None, None, :], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bhqk,bhkd->bhqd", probs, vf)
    return out.reshape(b, hq, s, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# MLA apply
# ---------------------------------------------------------------------------


def mla_apply(
    params,
    cfg: ModelConfig,
    x: jax.Array,
    positions: jax.Array,
    *,
    mode: str = "train",
    cache: Cache | None = None,
    kernel: dict | None = None,
    quant=None,  # per-layer runtime hook from the precision plan
    absorb: bool = False,
) -> tuple[jax.Array, Cache | None]:
    """Multi-head latent attention (DeepSeek-V2 / MiniCPM3).

    Paper-faithful baseline materializes per-head K/V from the latent
    (mirrors the FPGA design streaming full K/V); ``absorb=True`` is the
    beyond-paper decode optimization (absorbs wk_b/wv_b into the query/out
    projections so decode attends directly against the latent cache).
    """
    kernel = kernel or {}
    absorb = kernel.get("mla_absorb", absorb)
    m = cfg.mla
    qc = cfg.quant if quant is None else quant
    b, s, _ = x.shape
    h = cfg.n_heads
    nope, rope_d, vd = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    qk = nope + rope_d

    if mode == "decode":
        rope_pos = positions[:, None, None]  # (B,) -> (B, 1, 1)
    elif mode == "extend":
        rope_pos = positions[:, None, :]  # (B, W) -> (B, 1, W)
    else:
        rope_pos = positions  # (S,)

    # --- query path ---
    cq = layers.dense(params["wq_a"], x, qc)
    cq = layers.norm(params["q_norm"], cq, "rmsnorm", cfg.norm_eps)
    q = layers.dense(params["wq_b"], cq, qc).reshape(b, s, h, qk)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = layers.apply_rope(
        q_rope.transpose(0, 2, 1, 3), rope_pos, cfg.rope_theta
    )  # (b, h, s, rope_d)
    q_nope = q_nope.transpose(0, 2, 1, 3)  # (b, h, s, nope)

    # --- latent KV path ---
    kv_a = layers.dense(params["wkv_a"], x, qc)
    ckv, k_rope = kv_a[..., : m.kv_lora_rank], kv_a[..., m.kv_lora_rank :]
    ckv = layers.norm(params["kv_norm"], ckv, "rmsnorm", cfg.norm_eps)
    k_rope = layers.apply_rope(
        k_rope[:, None], rope_pos, cfg.rope_theta
    )[:, 0]  # (b, s, rope_d), shared across heads

    latent = jnp.concatenate([ckv, k_rope], axis=-1)  # (b, s, r + rope_d)

    new_cache = cache
    quantized = cache is not None and "latent_scale" in cache
    if cache is not None:
        cache_dtype = cache["latent"].dtype
        if quantized:
            # per-token symmetric int8 over the latent width
            l_scale = jnp.maximum(jnp.max(jnp.abs(latent), axis=-1), 1e-8) / 127.0
            l_store = jnp.clip(
                jnp.round(latent / l_scale[..., None]), -128, 127
            ).astype(jnp.int8)
        else:
            l_store, l_scale = latent.astype(cache_dtype), None
        if mode == "prefill":
            if kv_cache_lib.is_paged(cache):
                raise ValueError(
                    "prefill fills a dense scratch cache; insert it into "
                    "paged storage via serve.kv_cache.CacheManager"
                    ".insert_prefill"
                )
            new_latent = jax.lax.dynamic_update_slice(
                cache["latent"], l_store, (0, 0, 0)
            )
            new_cache = {"latent": new_latent}
            if quantized:
                new_cache["latent_scale"] = jax.lax.dynamic_update_slice(
                    cache["latent_scale"], l_scale.astype(jnp.float32), (0, 0)
                )
        elif mode == "extend":  # window scatter at (B, W) positions
            upd = {"latent": l_store}
            if quantized:
                upd["latent_scale"] = l_scale.astype(jnp.float32)
            write = (
                kv_cache_lib.paged_window_write
                if kv_cache_lib.is_paged(cache)
                else kv_cache_lib.dense_window_write
            )
            new_cache = write(cache, upd, positions)
        elif kv_cache_lib.is_paged(cache):  # paged decode: page scatter
            upd = {"latent": l_store[:, 0]}
            if quantized:
                upd["latent_scale"] = l_scale[:, 0].astype(jnp.float32)
            new_cache = kv_cache_lib.paged_decode_write(cache, upd, positions)
        else:  # dense decode: positions is (B,)
            new_latent = cache["latent"].at[jnp.arange(b), positions].set(
                l_store[:, 0]
            )
            new_cache = {"latent": new_latent}
            if quantized:
                new_cache["latent_scale"] = cache["latent_scale"].at[
                    jnp.arange(b), positions
                ].set(l_scale[:, 0].astype(jnp.float32))

    if mode == "decode" and cache is not None:
        pos = positions  # (B,)
        view = (
            kv_cache_lib.paged_decode_view(new_cache)
            if kv_cache_lib.is_paged(new_cache)
            else new_cache
        )
        lat = view["latent"].astype(jnp.float32)  # (b, L, r+rope_d)
        if quantized:
            lat = lat * view["latent_scale"][..., None]
        ckv_all, krope_all = lat[..., : m.kv_lora_rank], lat[..., m.kv_lora_rank :]
        valid = jnp.arange(lat.shape[1])[None, :] <= pos[:, None]  # (B, L)
        scale = 1.0 / (qk ** 0.5)
        if absorb:
            # beyond-paper: q_nope' = q_nope @ wk_b^T (per head) -> latent dim
            wk_b = params["wk_b"]["kernel"].reshape(m.kv_lora_rank, h, nope)
            q_lat = jnp.einsum("bhsn,rhn->bhsr", q_nope, wk_b)
            with jax.named_scope("attnvol"):
                scores = (
                    jnp.einsum("bhsr,bLr->bhsL", q_lat, ckv_all)
                    + jnp.einsum("bhsd,bLd->bhsL", q_rope, krope_all)
                ) * scale
                scores = jnp.where(valid[:, None, None, :], scores, -1e30)
                probs = jax.nn.softmax(scores, axis=-1)
                o_lat = jnp.einsum("bhsL,bLr->bhsr", probs, ckv_all)
            wv_b = params["wv_b"]["kernel"].reshape(m.kv_lora_rank, h, vd)
            out = jnp.einsum("bhsr,rhv->bhsv", o_lat, wv_b)
        else:
            # paper-faithful: materialize per-head K/V from the latent
            k_nope = layers.dense(params["wk_b"], ckv_all, qc).reshape(
                b, -1, h, nope
            )
            vv = layers.dense(params["wv_b"], ckv_all, qc).reshape(b, -1, h, vd)
            with jax.named_scope("attnvol"):
                scores = (
                    jnp.einsum("bhsn,bLhn->bhsL", q_nope, k_nope)
                    + jnp.einsum("bhsd,bLd->bhsL", q_rope, krope_all)
                ) * scale
                scores = jnp.where(valid[:, None, None, :], scores, -1e30)
                probs = jax.nn.softmax(scores, axis=-1)
                out = jnp.einsum("bhsL,bLhv->bhsv", probs, vv)
        out = out.transpose(0, 2, 1, 3).reshape(b, s, h * vd)
        out = out.astype(x.dtype)  # decode math runs f32; restore carry dtype
    elif mode == "extend" and cache is not None:
        # cache-extending prefill: attend the window rows with the
        # prefill-path math against the full latent view (history + the
        # just-written window), materializing per-head K/V from the
        # latent exactly as the whole-prompt prefill does — so window
        # activations and cache entries are bitwise what that prefill
        # would have produced at the same positions.
        view = (
            kv_cache_lib.paged_decode_view(new_cache)
            if kv_cache_lib.is_paged(new_cache)
            else new_cache
        )
        lat = view["latent"].astype(jnp.float32)  # (b, L, r+rope_d)
        if quantized:
            lat = lat * view["latent_scale"][..., None]
        ckv_all = lat[..., : m.kv_lora_rank]
        krope_all = lat[..., m.kv_lora_rank :]
        L = lat.shape[1]
        k_nope = layers.dense(params["wk_b"], ckv_all, qc).reshape(
            b, L, h, nope
        )
        vv = layers.dense(params["wv_b"], ckv_all, qc).reshape(b, L, h, vd)
        k_full = jnp.concatenate(
            [
                k_nope.transpose(0, 2, 1, 3),
                jnp.broadcast_to(krope_all[:, None], (b, h, L, rope_d)),
            ],
            axis=-1,
        )
        q_full = jnp.concatenate([q_nope, q_rope], axis=-1)  # (b, h, W, qk)
        kv_pos = jnp.arange(L)
        mask = kv_pos[None, None, :] <= positions[:, :, None]  # (B, W, L)
        out = _window_attend(
            q_full, k_full, vv.transpose(0, 2, 1, 3), mask,
            softmax_mode=kernel.get("softmax_mode", "safe"),
        )
        out = _merge_heads(out)
    else:
        # train / prefill: materialize K/V (paper-faithful streaming form)
        if quantized and mode == "prefill":
            # attend the cache's own representation (int8 roundtrip), so
            # replaying these positions via decode or cache-extend reads
            # back exactly the values prefill scored
            lat_att = l_store.astype(jnp.float32) * l_scale[..., None]
            ckv_att = lat_att[..., : m.kv_lora_rank]
            krope_att = lat_att[..., m.kv_lora_rank :]
        else:
            ckv_att, krope_att = ckv, k_rope
        k_nope = layers.dense(params["wk_b"], ckv_att, qc).reshape(b, s, h, nope)
        vv = layers.dense(params["wv_b"], ckv_att, qc).reshape(b, s, h, vd)
        k_full = jnp.concatenate(
            [
                k_nope.transpose(0, 2, 1, 3),
                jnp.broadcast_to(krope_att[:, None], (b, h, s, rope_d)),
            ],
            axis=-1,
        )
        q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
        # pad v head dim up to qk dim for the fused kernel, slice after
        v_heads = vv.transpose(0, 2, 1, 3)
        pad = qk - vd
        if pad > 0:
            v_heads = jnp.pad(v_heads, ((0, 0), (0, 0), (0, 0), (0, pad)))
        out = fused_mha(
            q_full, k_full, v_heads,
            causal=not cfg.is_encoder,
            mode=kernel.get("softmax_mode", "safe"),
            use_pallas=kernel.get("use_pallas", False),
            interpret=kernel.get("interpret", True),
        )[..., :vd]
        out = _merge_heads(out)

    out = layers.dense(params["wo"], out, qc)
    return out, new_cache


def attention_apply(params, cfg, x, positions, **kw):
    if cfg.attn_kind == "mla":
        return mla_apply(params, cfg, x, positions, **kw)
    return gqa_apply(params, cfg, x, positions, **kw)

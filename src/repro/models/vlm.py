"""VLM backbone (internvl2-1b) — thin wrapper over models.lm.

The vision frontend is a stub per the assignment: ``input_specs`` provides
precomputed InternViT patch embeddings (``frontend_dim=1024``); the
``frontend_proj`` MLP projector maps them into the LM embedding space, where
they are prepended to the text tokens.  Decode operates on text tokens with
the image prefix resident in the KV cache from prefill.
"""

from __future__ import annotations

from repro.models.lm import (  # noqa: F401
    abstract_params,
    count_params,
    decode_step,
    forward,
    init_params,
    input_specs,
    loss_fn,
    param_spec,
    prefill,
)

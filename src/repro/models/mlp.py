"""Feed-forward layers: standard and gated (GLU) MLPs."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers


def mlp_spec(cfg: ModelConfig, dtype=jnp.float32, d_ff: int | None = None):
    d = cfg.d_model
    ff = d_ff if d_ff is not None else cfg.d_ff
    spec = {
        "w_up": layers.dense_spec(d, ff, axes=("embed", "mlp"), bias=cfg.mlp_bias, dtype=dtype),
        "w_down": layers.dense_spec(ff, d, axes=("mlp", "embed"), bias=cfg.mlp_bias, dtype=dtype),
    }
    if cfg.gated_mlp:
        spec["w_gate"] = layers.dense_spec(
            d, ff, axes=("embed", "mlp"), bias=cfg.mlp_bias, dtype=dtype
        )
    return spec


def mlp_apply(
    params, cfg: ModelConfig, x: jax.Array, quant=None
) -> jax.Array:
    qc = cfg.quant if quant is None else quant
    up = layers.dense(params["w_up"], x, qc)
    if cfg.gated_mlp:
        gate = layers.dense(params["w_gate"], x, qc)
        h = layers.activation(gate, cfg.act) * up
    else:
        h = layers.activation(up, cfg.act)
    return layers.dense(params["w_down"], h, qc)

"""Mamba2 / SSD (state-space duality, arXiv:2405.21060) in pure JAX.

Implements the chunked SSD algorithm for train/prefill (quadratic within
Q-sized chunks + linear inter-chunk state recurrence) and the O(1) single
-token state update for decode.  A naive step-by-step recurrence is kept as
the test oracle (``ssd_naive_ref``).

Paper-technique note (DESIGN.md §Arch-applicability): Mamba2 has no softmax
attention, so the LUT-softmax/streaming-MHA parts of the paper do not apply
here; quantized projections and the staged RMSNorm do.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers
from repro.models.params import ArraySpec


# ---------------------------------------------------------------------------
# Param spec
# ---------------------------------------------------------------------------


def mamba_spec(cfg: ModelConfig, dtype=jnp.float32):
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    h = s.n_heads(d)
    conv_dim = di + 2 * s.n_groups * s.state_dim
    d_in_proj = 2 * di + 2 * s.n_groups * s.state_dim + h
    return {
        "in_proj": layers.dense_spec(d, d_in_proj, axes=("embed", "inner"), dtype=dtype),
        "conv_w": ArraySpec((s.conv_width, conv_dim), dtype, (None, "inner"), "fan_in"),
        "conv_b": ArraySpec((conv_dim,), dtype, ("inner",), "zeros"),
        "A_log": ArraySpec((h,), jnp.float32, ("ssm_heads",), "zeros"),
        "dt_bias": ArraySpec((h,), jnp.float32, ("ssm_heads",), "zeros"),
        "D": ArraySpec((h,), jnp.float32, ("ssm_heads",), "ones"),
        "gate_norm": layers.norm_spec(di, "rmsnorm", dtype),
        "out_proj": layers.dense_spec(di, d, axes=("inner", "embed"), dtype=dtype),
    }


# ---------------------------------------------------------------------------
# SSD core
# ---------------------------------------------------------------------------


def _segsum(a: jax.Array) -> jax.Array:
    """(..., q) -> (..., q, q) with [i, j] = sum_{m=j+1..i} a_m (i>=j)."""
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    q = a.shape[-1]
    mask = jnp.arange(q)[:, None] >= jnp.arange(q)[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(
    xdt: jax.Array,  # (b, l, h, p) inputs pre-multiplied by dt
    a: jax.Array,  # (b, l, h) log-decay = dt * A  (A < 0)
    bmat: jax.Array,  # (b, l, h, n) per-head B
    cmat: jax.Array,  # (b, l, h, n) per-head C
    *,
    chunk: int,
    initial_state: jax.Array | None = None,  # (b, h, p, n)
) -> tuple[jax.Array, jax.Array]:
    """Chunked SSD scan. Returns (y (b,l,h,p), final_state (b,h,p,n))."""
    b, l, h, p = xdt.shape
    n = bmat.shape[-1]
    assert l % chunk == 0, (l, chunk)
    nc = l // chunk

    def tochunks(t):
        return t.reshape(b, nc, chunk, *t.shape[2:])

    xc = tochunks(xdt)  # (b,c,q,h,p)
    ac = tochunks(a).transpose(0, 3, 1, 2)  # (b,h,c,q)
    bc = tochunks(bmat)  # (b,c,q,h,n)
    cc = tochunks(cmat)  # (b,c,q,h,n)

    a_cumsum = jnp.cumsum(ac, axis=-1)  # (b,h,c,q)

    # 1. intra-chunk (quadratic, the "attention-like" term)
    el = jnp.exp(_segsum(ac))  # (b,h,c,q,q)
    scores = jnp.einsum("bcqhn,bckhn->bhcqk", cc, bc)
    y_diag = jnp.einsum("bhcqk,bckhp->bcqhp", scores * el, xc)

    # 2. chunk states (what each chunk contributes to the running state)
    decay_states = jnp.exp(a_cumsum[..., -1:] - a_cumsum)  # (b,h,c,q)
    states = jnp.einsum("bckhn,bhck,bckhp->bchpn", bc, decay_states, xc)

    # 3. inter-chunk recurrence (linear scan over chunk states)
    if initial_state is None:
        initial_state = jnp.zeros((b, h, p, n), xdt.dtype)
    a_last = a_cumsum[..., -1]  # (b,h,c)
    a_pad = jnp.pad(a_last, ((0, 0), (0, 0), (1, 0)))  # (b,h,c+1)
    decay_chunk = jnp.exp(_segsum(a_pad))  # (b,h,c+1,c+1)
    all_states = jnp.concatenate([initial_state[:, None], states], axis=1)
    new_states = jnp.einsum("bhzc,bchpn->bzhpn", decay_chunk, all_states)
    states_in, final_state = new_states[:, :-1], new_states[:, -1]

    # 4. state -> output within each chunk
    state_decay = jnp.exp(a_cumsum)  # (b,h,c,q)
    y_off = jnp.einsum("bcqhn,bchpn,bhcq->bcqhp", cc, states_in, state_decay)

    y = (y_diag + y_off).reshape(b, l, h, p)
    return y, final_state


def ssd_step(
    state: jax.Array,  # (b, h, p, n)
    x: jax.Array,  # (b, h, p) single token (NOT pre-multiplied by dt)
    dt: jax.Array,  # (b, h)
    a_log_decay: jax.Array,  # (b, h) = dt * A
    bvec: jax.Array,  # (b, h, n)
    cvec: jax.Array,  # (b, h, n)
) -> tuple[jax.Array, jax.Array]:
    """O(1) decode update: h' = exp(dt*A) h + dt * x  B^T ;  y = C . h'."""
    da = jnp.exp(a_log_decay)[..., None, None]  # (b,h,1,1)
    upd = jnp.einsum("bhp,bhn->bhpn", x * dt[..., None], bvec)
    new_state = state * da + upd
    y = jnp.einsum("bhpn,bhn->bhp", new_state, cvec)
    return y, new_state


def ssd_naive_ref(
    xdt: jax.Array,  # (b, l, h, p)
    a: jax.Array,  # (b, l, h)
    bmat: jax.Array,  # (b, l, h, n)
    cmat: jax.Array,  # (b, l, h, n)
    initial_state: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Step-by-step recurrence oracle for tests."""
    b, l, h, p = xdt.shape
    n = bmat.shape[-1]
    state = (
        jnp.zeros((b, h, p, n), xdt.dtype)
        if initial_state is None
        else initial_state
    )

    def step(state, inp):
        x_t, a_t, b_t, c_t = inp
        da = jnp.exp(a_t)[..., None, None]
        state = state * da + jnp.einsum("bhp,bhn->bhpn", x_t, b_t)
        y = jnp.einsum("bhpn,bhn->bhp", state, c_t)
        return state, y

    xs = (
        xdt.transpose(1, 0, 2, 3),
        a.transpose(1, 0, 2),
        bmat.transpose(1, 0, 2, 3),
        cmat.transpose(1, 0, 2, 3),
    )
    final, ys = jax.lax.scan(step, state, xs)
    return ys.transpose(1, 0, 2, 3), final


# ---------------------------------------------------------------------------
# Full Mamba2 block
# ---------------------------------------------------------------------------


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv1d: x (b, l, c), w (width, c)."""
    width = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        xp,
        w[:, None, :],  # (width, 1, c) HIO for depthwise
        window_strides=(1,),
        padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=x.shape[-1],
    )
    return out + b


def mamba_cache_spec(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    h = s.n_heads(d)
    conv_dim = di + 2 * s.n_groups * s.state_dim
    return {
        "ssm_state": jax.ShapeDtypeStruct((batch, h, s.head_dim, s.state_dim), dtype),
        "conv_state": jax.ShapeDtypeStruct((batch, s.conv_width - 1, conv_dim), dtype),
    }


def mamba_init_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> dict:
    return {
        k: jnp.zeros(v.shape, v.dtype)
        for k, v in mamba_cache_spec(cfg, batch, dtype).items()
    }


def _split_proj(cfg: ModelConfig, zxbcdt: jax.Array):
    s = cfg.ssm
    di = s.d_inner(cfg.d_model)
    gn = s.n_groups * s.state_dim
    h = s.n_heads(cfg.d_model)
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di : di + di + 2 * gn]
    dt = zxbcdt[..., di + di + 2 * gn :]
    assert dt.shape[-1] == h, (dt.shape, h)
    return z, xbc, dt


def _expand_groups(t: jax.Array, h: int, g: int) -> jax.Array:
    """(b, l, g*n) -> (b, l, h, n) broadcasting groups across heads."""
    b, l, _ = t.shape
    n = t.shape[-1] // g
    t = t.reshape(b, l, g, n)
    return jnp.repeat(t, h // g, axis=2)


def mamba_apply(
    params,
    cfg: ModelConfig,
    x: jax.Array,  # (b, l, d)
    *,
    mode: str = "train",
    cache: dict | None = None,
    quant=None,  # per-layer runtime hook from the precision plan
) -> tuple[jax.Array, dict | None]:
    s = cfg.ssm
    qc = cfg.quant if quant is None else quant
    b, l, d = x.shape
    di = s.d_inner(d)
    h = s.n_heads(d)
    p = s.head_dim
    g = s.n_groups
    n = s.state_dim

    zxbcdt = layers.dense(params["in_proj"], x, qc)
    z, xbc, dt = _split_proj(cfg, zxbcdt)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (b,l,h)
    a_neg = -jnp.exp(params["A_log"])  # (h,) negative decay rates

    new_cache = cache
    if mode == "decode" and cache is not None:
        # conv via rolling window
        window = jnp.concatenate([cache["conv_state"], xbc.astype(jnp.float32)], axis=1)
        conv_out = (
            jnp.einsum("bwc,wc->bc", window, params["conv_w"]) + params["conv_b"]
        )[:, None]
        new_conv_state = window[:, 1:].astype(cache["conv_state"].dtype)
        xbc_c = jax.nn.silu(conv_out)
        x_in = xbc_c[..., :di].reshape(b, 1, h, p)[:, 0]
        bmat = _expand_groups(xbc_c[..., di : di + g * n], h, g)[:, 0]
        cmat = _expand_groups(xbc_c[..., di + g * n :], h, g)[:, 0]
        dt0 = dt[:, 0]
        y, new_state = ssd_step(
            cache["ssm_state"].astype(jnp.float32),
            x_in.astype(jnp.float32),
            dt0,
            dt0 * a_neg,
            bmat.astype(jnp.float32),
            cmat.astype(jnp.float32),
        )
        y = y + x_in.astype(jnp.float32) * params["D"][:, None]
        y = y.reshape(b, 1, di).astype(x.dtype)
        new_cache = {
            "ssm_state": new_state.astype(cache["ssm_state"].dtype),
            "conv_state": new_conv_state,
        }
    else:
        xbc_c = jax.nn.silu(_causal_conv(xbc, params["conv_w"], params["conv_b"]))
        x_in = xbc_c[..., :di].reshape(b, l, h, p)
        bmat = _expand_groups(xbc_c[..., di : di + g * n], h, g)
        cmat = _expand_groups(xbc_c[..., di + g * n :], h, g)
        xdt = x_in.astype(jnp.float32) * dt[..., None]
        a = dt * a_neg  # (b,l,h)
        y, final_state = ssd_chunked(
            xdt, a, bmat.astype(jnp.float32), cmat.astype(jnp.float32),
            chunk=min(s.chunk_size, l),
        )
        y = y + x_in.astype(jnp.float32) * params["D"].reshape(1, 1, h, 1)
        y = y.reshape(b, l, di).astype(x.dtype)
        if cache is not None:  # prefill: hand the final state to decode
            width = s.conv_width
            tail = xbc[:, -(width - 1) :].astype(jnp.float32)
            if l < width - 1:
                tail = jnp.pad(tail, ((0, 0), (width - 1 - l, 0), (0, 0)))
            new_cache = {
                "ssm_state": final_state.astype(cache["ssm_state"].dtype),
                "conv_state": tail.astype(cache["conv_state"].dtype),
            }

    # gated output: RMSNorm(y * silu(z)) -> out_proj
    y = y * jax.nn.silu(z)
    y = layers.norm(params["gate_norm"], y, "rmsnorm", cfg.norm_eps)
    return layers.dense(params["out_proj"], y, qc), new_cache

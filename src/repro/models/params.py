"""Parameter-spec system: one source of truth for init, abstract shapes,
and sharding.

Each layer declares a *spec tree*: a nested dict whose leaves are
``ArraySpec(shape, dtype, logical_axes, init)``.  From a spec tree we derive

* ``init_params``      — real parameters (deterministic per-leaf RNG),
* ``abstract_params``  — ``jax.ShapeDtypeStruct`` tree (dry-run: no alloc),
* ``logical_axes``     — pytree of logical-axis tuples, consumed by
  ``distributed/sharding.py`` to produce ``NamedSharding`` trees.

Scan-over-layers is expressed by ``stack_spec(spec, n)``, which prepends a
``layers`` axis to every leaf.
"""

from __future__ import annotations

import dataclasses
import zlib
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

SpecTree = Any  # nested dict[str, ArraySpec | SpecTree]


@dataclasses.dataclass(frozen=True)
class ArraySpec:
    shape: tuple[int, ...]
    dtype: Any = jnp.float32
    logical_axes: tuple[str | None, ...] = ()
    init: str = "fan_in"  # fan_in | normal | zeros | ones | embed | small
    init_scale: float | None = None

    def __post_init__(self):
        if self.logical_axes and len(self.logical_axes) != len(self.shape):
            raise ValueError(
                f"logical_axes {self.logical_axes} rank != shape {self.shape}"
            )


def _leaf_init(spec: ArraySpec, key: jax.Array) -> jax.Array:
    shape, dtype = spec.shape, spec.dtype
    if spec.init == "zeros":
        return jnp.zeros(shape, dtype)
    if spec.init == "ones":
        return jnp.ones(shape, dtype)
    if spec.init == "embed":
        scale = spec.init_scale or 1.0
        return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)
    if spec.init == "normal":
        scale = spec.init_scale or 0.02
        return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)
    if spec.init == "small":
        scale = spec.init_scale or 1e-3
        return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)
    # fan_in: truncated-normal-ish with 1/sqrt(fan_in); fan-in = prod of all
    # axes but the last (works for stacked (layers, in, out) leaves too).
    fan_in = int(np.prod(shape[:-1])) if len(shape) > 1 else shape[0]
    if len(shape) >= 3:  # stacked (layers, in, out): fan-in is axis -2
        fan_in = shape[-2]
    scale = spec.init_scale or (1.0 / max(fan_in, 1)) ** 0.5
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def _iter_leaves(spec: SpecTree, path=()):
    if isinstance(spec, ArraySpec):
        yield path, spec
        return
    for name in sorted(spec):
        yield from _iter_leaves(spec[name], path + (name,))


def _map_leaves(fn: Callable[[tuple, ArraySpec], Any], spec: SpecTree, path=()):
    if isinstance(spec, ArraySpec):
        return fn(path, spec)
    return {
        name: _map_leaves(fn, child, path + (name,))
        for name, child in spec.items()
    }


def init_params(spec: SpecTree, key: jax.Array) -> Any:
    """Deterministic init: each leaf's key is fold_in(crc32(path)).

    crc32, not Python ``hash()``: string hashes are salted per process
    (PYTHONHASHSEED), which made "the same seed" produce different
    parameters in every interpreter — and turned threshold-based quality
    tests and the serving examples nondeterministic across runs."""

    def _init(path, leaf_spec):
        h = np.uint32(zlib.crc32("/".join(path).encode()))
        return _leaf_init(leaf_spec, jax.random.fold_in(key, h))

    return _map_leaves(_init, spec)


def abstract_params(spec: SpecTree) -> Any:
    return _map_leaves(
        lambda _, s: jax.ShapeDtypeStruct(s.shape, s.dtype), spec
    )


def logical_axes(spec: SpecTree) -> Any:
    return _map_leaves(lambda _, s: s.logical_axes, spec)


def count_params(spec: SpecTree) -> int:
    return sum(int(np.prod(s.shape)) for _, s in _iter_leaves(spec))


def stack_spec(spec: SpecTree, n: int) -> SpecTree:
    """Prepend a ``layers`` axis to every leaf (scan-over-layers params)."""

    def _stack(_, s: ArraySpec) -> ArraySpec:
        axes = ("layers",) + tuple(s.logical_axes) if s.logical_axes else (
            ("layers",) + (None,) * len(s.shape)
        )
        return ArraySpec(
            shape=(n,) + s.shape,
            dtype=s.dtype,
            logical_axes=axes,
            init=s.init,
            init_scale=s.init_scale,
        )

    return _map_leaves(_stack, spec)


def cast_floats(tree: Any, dtype) -> Any:
    def _cast(x):
        if isinstance(x, jax.Array) and jnp.issubdtype(x.dtype, jnp.floating):
            return x.astype(dtype)
        return x

    return jax.tree.map(_cast, tree)

"""Mixture-of-experts with sort-based capacity dispatch (GShard/MaxText
"dropping" strategy) — static shapes, expert-parallel shardable.

Dispatch: top-k routing -> stable sort by expert id -> position-in-expert
rank -> scatter into (E, C, d) expert batches (overflow tokens dropped,
matching capacity-factor semantics) -> per-expert GEMMs (einsum over the
stacked expert weights, EP-shardable over the 'experts' logical axis) ->
weighted scatter back.

The router softmax uses the paper's restructured 3-stage form
(``core/softmax.softmax_paper_exact``) — one of the places the paper's
technique lands in a modern architecture (DESIGN.md §Arch-applicability).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import softmax as sm
from repro.models import layers
from repro.models.params import ArraySpec


def moe_spec(cfg: ModelConfig, dtype=jnp.float32):
    d = cfg.d_model
    e = cfg.moe.n_experts
    ff = cfg.moe.d_expert
    spec = {
        "router": layers.dense_spec(d, e, axes=("embed", "experts"), dtype=dtype),
        "w_up": ArraySpec((e, d, ff), dtype, ("experts", "embed", "mlp"), "fan_in"),
        "w_down": ArraySpec((e, ff, d), dtype, ("experts", "mlp", "embed"), "fan_in"),
    }
    if cfg.gated_mlp:
        spec["w_gate"] = ArraySpec(
            (e, d, ff), dtype, ("experts", "embed", "mlp"), "fan_in"
        )
    return spec


def moe_apply(
    params, cfg: ModelConfig, x: jax.Array
) -> tuple[jax.Array, dict]:
    """Returns (output, aux) where aux carries router losses/metrics."""
    mcfg = cfg.moe
    b, s, d = x.shape
    t = b * s
    e, k = mcfg.n_experts, mcfg.top_k
    flat = x.reshape(t, d)

    # ---- routing ----------------------------------------------------------
    logits = layers.dense(params["router"], flat.astype(jnp.float32), None)
    probs = sm.softmax_paper_exact(logits, axis=-1)  # paper's 3-stage form
    gate_vals, expert_ids = jax.lax.top_k(probs, k)  # (t, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9
    )

    # aux losses (Switch-style load balance + router z-loss)
    me = jnp.mean(probs, axis=0)  # mean prob per expert
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(expert_ids, e), axis=1), axis=0
    )  # fraction routed
    aux_loss = e * jnp.sum(me * ce) * mcfg.router_aux_weight
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2) * mcfg.router_z_weight

    # ---- sort-based dispatch ---------------------------------------------
    capacity = int(max(1, round(t * k / e * mcfg.capacity_factor)))
    flat_expert = expert_ids.reshape(t * k)
    flat_gate = gate_vals.reshape(t * k)
    flat_token = jnp.repeat(jnp.arange(t), k)

    order = jnp.argsort(flat_expert, stable=True)
    sorted_expert = flat_expert[order]
    sorted_token = flat_token[order]
    sorted_gate = flat_gate[order]

    # rank of each entry within its expert segment
    counts = jnp.bincount(flat_expert, length=e)  # (e,)
    seg_start = jnp.cumsum(counts) - counts  # exclusive prefix
    rank = jnp.arange(t * k) - seg_start[sorted_expert]
    keep = rank < capacity
    dropped = jnp.sum(~keep)

    # scatter tokens into (e, capacity, d); overflow -> dropped
    slot = jnp.where(keep, sorted_expert * capacity + rank, e * capacity)
    expert_in = jnp.zeros((e * capacity, d), x.dtype).at[slot].set(
        flat[sorted_token], mode="drop"
    )
    expert_in = expert_in.reshape(e, capacity, d)

    # ---- expert compute (EP: 'experts' axis shardable) --------------------
    up = jnp.einsum("ecd,edf->ecf", expert_in, params["w_up"])
    if cfg.gated_mlp:
        gate = jnp.einsum("ecd,edf->ecf", expert_in, params["w_gate"])
        h = layers.activation(gate, cfg.act) * up
    else:
        h = layers.activation(up, cfg.act)
    expert_out = jnp.einsum("ecf,efd->ecd", h, params["w_down"])

    # ---- weighted combine --------------------------------------------------
    gathered = expert_out.reshape(e * capacity, d)
    # value for each kept (token, slot) entry
    vals = jnp.where(
        keep[:, None], gathered[jnp.clip(slot, 0, e * capacity - 1)], 0.0
    )
    out = jnp.zeros((t, d), x.dtype).at[sorted_token].add(
        vals * sorted_gate[:, None].astype(x.dtype)
    )

    aux = {
        "moe_aux_loss": aux_loss,
        "moe_z_loss": z_loss,
        "moe_dropped_frac": dropped / (t * k),
    }
    return out.reshape(b, s, d), aux

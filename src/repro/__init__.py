"""repro: multi-pod JAX framework for low-latency quantized transformer
inference, reproducing and extending *Low Latency Transformer Inference on
FPGAs for Physics Applications with hls4ml* (2024) on TPU.

Layers: ``core`` (the paper's technique), ``kernels`` (Pallas TPU),
``models`` (architecture zoo), ``data``/``optim``/``train``/``serve``/
``checkpoint``/``distributed`` (substrates), ``configs`` (architectures),
``launch`` (mesh/dryrun/drivers), ``roofline`` (perf analysis).
"""

__version__ = "1.0.0"

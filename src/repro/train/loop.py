"""Training loop with checkpoint/restart, preemption handling, straggler
detection and heartbeat — the fault-tolerant driver for launch/train.py.

Restart-exactness contract: (data step <- state step) and a deterministic
``batch_fn`` mean a run killed at any point resumes bit-identically from
the latest checkpoint (tested in tests/test_fault_tolerance.py).
"""

from __future__ import annotations

import dataclasses
import logging
import os
import time
from typing import Any, Callable

import jax
import numpy as np

from repro.checkpoint import Checkpointer
from repro.configs.base import ModelConfig, TrainConfig
from repro.optim import AdamW, make_schedule
from repro.train import step as step_lib
from repro.train.fault_tolerance import (
    FailureInjector,
    Heartbeat,
    PreemptionHandler,
    StepTimer,
)

log = logging.getLogger("repro.train")


@dataclasses.dataclass
class LoopResult:
    final_step: int
    metrics_history: list[dict]
    stragglers: list[tuple[int, float, float]]
    stopped_early: bool


def run_training(
    cfg: ModelConfig,
    train_cfg: TrainConfig,
    batch_fn: Callable[[int, int, int], dict],
    *,
    workdir: str,
    mesh=None,
    rules=None,
    kernel: dict | None = None,
    remat: str = "none",
    preemption: PreemptionHandler | None = None,
    failure_injector: FailureInjector | None = None,
    log_every: int = 10,
) -> LoopResult:
    os.makedirs(workdir, exist_ok=True)
    optimizer = AdamW(
        schedule=make_schedule(train_cfg),
        b1=train_cfg.b1,
        b2=train_cfg.b2,
        eps=train_cfg.eps,
        weight_decay=train_cfg.weight_decay,
        grad_clip=train_cfg.grad_clip,
    )
    ckpt = Checkpointer(
        os.path.join(workdir, "checkpoints"), keep=train_cfg.keep_checkpoints
    )
    update = step_lib.make_train_step(
        cfg, optimizer, mesh=mesh, rules=rules, kernel=kernel, remat=remat
    )

    # ---- restore or init -------------------------------------------------
    key = jax.random.PRNGKey(train_cfg.seed)
    state = step_lib.make_train_state(cfg, optimizer, key)
    start_step = 0
    if ckpt.latest_step() is not None:
        shardings = None
        if mesh is not None and rules is not None:
            abstract = step_lib.abstract_train_state(cfg, optimizer)
            axes = step_lib.train_state_logical_axes(cfg)
            shardings = rules.tree_shardings(abstract, axes)
        state = ckpt.restore(state, shardings=shardings)
        start_step = int(np.asarray(state["opt"]["step"]))
        log.info("restored checkpoint at step %d", start_step)

    preemption = preemption or PreemptionHandler(signals=())
    timer = StepTimer()
    hb = Heartbeat(os.path.join(workdir, "heartbeat")).start()
    history: list[dict] = []
    stopped_early = False

    try:
        step = start_step
        while step < train_cfg.total_steps:
            if preemption.should_stop:
                log.warning("preemption requested: checkpointing at %d", step)
                ckpt.save(step, state, blocking=True)
                stopped_early = True
                break
            batch = {
                k: jax.numpy.asarray(v)
                for k, v in batch_fn(step, 0, 1).items()
            }
            timer.start()
            if failure_injector is not None:
                failure_injector.maybe_fail(step)
            state, metrics = update(state, batch)
            jax.block_until_ready(metrics["loss"])
            dt, straggler = timer.stop()
            step += 1
            if straggler:
                log.warning("straggler step %d: %.3fs", step, dt)
            if step % log_every == 0 or step == train_cfg.total_steps:
                m = {k: float(np.asarray(v)) for k, v in metrics.items()}
                m["step"] = step
                m["step_time_s"] = dt
                history.append(m)
                log.info(
                    "step %d loss %.4f lr %.2e (%.3fs)",
                    step, m.get("loss", float("nan")), m.get("lr", 0), dt,
                )
            if step % train_cfg.checkpoint_every == 0:
                ckpt.save(step, state)
        else:
            ckpt.save(train_cfg.total_steps, state, blocking=True)
        ckpt.wait()
    finally:
        hb.stop()

    return LoopResult(
        final_step=step,
        metrics_history=history,
        stragglers=timer.straggler_events,
        stopped_early=stopped_early,
    )

from repro.train.fault_tolerance import (  # noqa: F401
    FailureInjector,
    Heartbeat,
    PreemptionHandler,
    StepTimer,
)
from repro.train.loop import LoopResult, run_training  # noqa: F401
from repro.train.step import (  # noqa: F401
    abstract_train_state,
    make_train_state,
    make_train_step,
    train_state_logical_axes,
    train_step,
)

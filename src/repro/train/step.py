"""Train-step factory: loss -> grads -> optimizer, pjit-shardable.

``make_train_step`` builds the jitted update; with a mesh + ShardingRules
the step is fully sharded (params/opt-state per the logical rules,
batch over the data axes) and buffers are donated.  This same factory is
what the dry-run lowers for the ``train_4k`` cells.
"""

from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import lm
from repro.models import params as params_lib
from repro.optim.adamw import AdamW

PyTree = Any


def make_train_state(cfg: ModelConfig, optimizer: AdamW, key) -> dict:
    params = lm.init_params(cfg, key)
    return {"params": params, "opt": optimizer.init(params)}


def abstract_train_state(cfg: ModelConfig, optimizer: AdamW) -> dict:
    ap = lm.abstract_params(cfg)
    return {"params": ap, "opt": optimizer.abstract_state(ap)}


def train_state_logical_axes(cfg: ModelConfig) -> dict:
    spec = lm.param_spec(cfg)
    axes = params_lib.logical_axes(spec)
    return {
        "params": axes,
        "opt": {"step": (), "mu": axes, "nu": axes},
    }


def make_loss_fn(
    cfg: ModelConfig,
    *,
    kernel: dict | None = None,
    remat: str = "none",
    loss_impl: Callable = lm.loss_fn,
):
    def _loss(params, batch):
        return loss_impl(params, cfg, batch, kernel=kernel, remat=remat)

    return _loss


def train_step(
    state: dict,
    batch: dict,
    *,
    cfg: ModelConfig,
    optimizer: AdamW,
    kernel: dict | None = None,
    remat: str = "none",
    grad_accum: int = 1,
):
    """One synchronous update. Pure; jit/pjit-able; donate-friendly.

    ``grad_accum > 1`` scans over microbatches (batch axis split), summing
    gradients before the optimizer — the standard lever for fitting large
    per-device token counts in HBM (activation live-set / grad_accum).
    """
    loss_fn = make_loss_fn(cfg, kernel=kernel, remat=remat)
    if grad_accum <= 1:
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            state["params"], batch
        )
    else:
        micro = jax.tree.map(
            lambda x: x.reshape((grad_accum, x.shape[0] // grad_accum) + x.shape[1:]),
            batch,
        )

        def body(acc, mb):
            (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(
                state["params"], mb
            )
            m = {k: m[k] for k in acc["m"]}  # fixed metric subset
            acc = jax.tree.map(jnp.add, acc, {"g": g, "m": m})
            return acc, None

        zero = {
            "g": jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state["params"]
            ),
            "m": {
                k: jnp.float32(0.0)
                for k in ("loss", "ce_loss", "accuracy")
            },
        }
        acc, _ = jax.lax.scan(body, zero, micro)
        grads = jax.tree.map(lambda g: g / grad_accum, acc["g"])
        metrics = {k: v / grad_accum for k, v in acc["m"].items()}
    new_params, new_opt, opt_metrics = optimizer.update(
        grads, state["opt"], state["params"]
    )
    metrics = dict(metrics)
    metrics.update(opt_metrics)
    return {"params": new_params, "opt": new_opt}, metrics


def make_train_step(
    cfg: ModelConfig,
    optimizer: AdamW,
    *,
    mesh=None,
    rules=None,
    kernel: dict | None = None,
    remat: str = "none",
    donate: bool = True,
):
    """jit-compiled train step; sharded when (mesh, rules) are given."""
    fn = functools.partial(
        train_step, cfg=cfg, optimizer=optimizer, kernel=kernel, remat=remat
    )
    if mesh is None or rules is None:
        return jax.jit(fn, donate_argnums=(0,) if donate else ())

    abstract = abstract_train_state(cfg, optimizer)
    axes = train_state_logical_axes(cfg)
    state_sh = rules.tree_shardings(abstract, axes)
    batch_sh = rules.batch_sharding(2)
    return jax.jit(
        fn,
        in_shardings=(state_sh, {"tokens": batch_sh}),
        out_shardings=(state_sh, None),
        donate_argnums=(0,) if donate else (),
    )

"""Fault-tolerance machinery for long multi-pod runs.

On a synchronous-SPMD JAX cluster, fault tolerance decomposes into:

* **preemption / failure**  -> checkpoint + restart (possibly elastic, on a
  different device count) — ``PreemptionHandler`` + ``Checkpointer``.
* **straggler mitigation**  -> detection (``StepTimer``) + operator policy
  (alerting, hot-spare swap, or elastic down-scale).  In synchronous SPMD a
  straggler stalls the collective, so detection + restart-without-it is the
  mitigation; we implement the detector and the restart path, and unit-test
  both with simulated clocks.
* **liveness**              -> ``Heartbeat`` file, consumed by an external
  supervisor (k8s/GCE health checks) to reschedule dead workers.
"""

from __future__ import annotations

import os
import signal
import threading
import time
from collections import deque
from typing import Callable


class PreemptionHandler:
    """Converts SIGTERM/SIGINT into a cooperative 'please checkpoint' flag.

    The train loop polls ``should_stop`` each step and writes a final
    checkpoint before exiting — the standard TPU-preemption dance.
    """

    def __init__(self, signals=(signal.SIGTERM,)):
        self._stop = threading.Event()
        self._prev = {}
        for sig in signals:
            try:
                self._prev[sig] = signal.signal(sig, self._handle)
            except ValueError:  # not main thread (tests)
                pass

    def _handle(self, signum, frame):
        self._stop.set()

    def request_stop(self):  # programmatic (tests / simulated failures)
        self._stop.set()

    @property
    def should_stop(self) -> bool:
        return self._stop.is_set()


class StepTimer:
    """Straggler detector: flags steps slower than ``threshold`` x the
    rolling median.  ``clock`` injectable for tests."""

    def __init__(self, window: int = 32, threshold: float = 2.5, clock=time.monotonic):
        self.window = window
        self.threshold = threshold
        self.clock = clock
        self.durations: deque[float] = deque(maxlen=window)
        self._t0: float | None = None
        self.straggler_events: list[tuple[int, float, float]] = []
        self.step_idx = 0

    def start(self):
        self._t0 = self.clock()

    def stop(self) -> tuple[float, bool]:
        """Returns (duration, is_straggler)."""
        assert self._t0 is not None, "start() not called"
        dt = self.clock() - self._t0
        self._t0 = None
        is_straggler = False
        if len(self.durations) >= max(4, self.window // 4):
            med = sorted(self.durations)[len(self.durations) // 2]
            if dt > self.threshold * med:
                is_straggler = True
                self.straggler_events.append((self.step_idx, dt, med))
        self.durations.append(dt)
        self.step_idx += 1
        return dt, is_straggler


class Heartbeat:
    """Background thread touching a liveness file every ``interval`` s."""

    def __init__(self, path: str, interval: float = 10.0):
        self.path = path
        self.interval = interval
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)

    def start(self):
        self._thread.start()
        return self

    def _run(self):
        while not self._stop.is_set():
            with open(self.path, "w") as f:
                f.write(str(time.time()))
            self._stop.wait(self.interval)

    def stop(self):
        self._stop.set()
        self._thread.join(timeout=2.0)
        if os.path.exists(self.path):
            os.unlink(self.path)

    @staticmethod
    def is_alive(path: str, timeout: float = 30.0) -> bool:
        try:
            with open(path) as f:
                return time.time() - float(f.read()) < timeout
        except (OSError, ValueError):
            return False


class FailureInjector:
    """Deterministic failure injection for integration tests: raises at a
    chosen step, letting tests exercise checkpoint-restart-resume."""

    def __init__(self, fail_at_step: int | None = None):
        self.fail_at_step = fail_at_step

    def maybe_fail(self, step: int):
        if self.fail_at_step is not None and step == self.fail_at_step:
            raise RuntimeError(f"injected failure at step {step}")

from repro.roofline.analysis import (  # noqa: F401
    CellAnalysis,
    analyze_cell,
    attention_flops,
    model_flops,
)
from repro.roofline.hlo_parser import total_cost, type_bytes  # noqa: F401

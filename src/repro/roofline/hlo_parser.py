"""HLO-text cost model: per-computation FLOPs / HBM bytes / collective
bytes with while-loop trip-count multiplication.

Why this exists: ``compiled.cost_analysis()`` visits each ``while`` body
ONCE — for scan-over-layers models it under-counts FLOPs and bytes by a
factor of n_layers (verified empirically in this repo; see DESIGN.md).
This parser walks the optimized HLO, prices dots/convs per computation,
and multiplies while-body costs by the trip count recovered from the loop
condition's comparison constant (falling back to a caller default).

This is the "profile" of the dry-run methodology: no wall clock exists on
CPU, so the lowered module IS the measurement.
"""

from __future__ import annotations

import dataclasses
import math
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")

COLLECTIVE_OPS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# ops whose operands+result approximate HBM traffic (post-fusion HLO)
_MEM_OPS_SKIP = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}


def type_bytes(type_str: str) -> int:
    """Total bytes of an HLO type string (handles tuples)."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _first_shape_dims(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",")] if m.group(2) else []


@dataclasses.dataclass
class Instr:
    name: str
    result_type: str
    op: str
    operands: list[str]
    attrs: str
    raw_operands: str = ""


@dataclasses.dataclass
class Computation:
    name: str
    instrs: dict[str, Instr] = dataclasses.field(default_factory=dict)
    order: list[str] = dataclasses.field(default_factory=list)


_COMP_START = re.compile(
    r"^(ENTRY\s+)?%?([\w\.\-]+)(?:\.clone)?\s*\(.*\)\s*->\s*.*\{\s*$"
)
# `  %name = TYPE op-name(operands), attrs`  (TYPE may be a tuple)
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(\(.*?\)|\S+)\s+([\w\-]+)\((.*?)\)(.*)$"
)
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")


def parse_module(text: str) -> tuple[dict[str, Computation], str | None]:
    comps: dict[str, Computation] = {}
    entry: str | None = None
    cur: Computation | None = None
    for line in text.splitlines():
        if line.rstrip().endswith("{") and ("->" in line or line.startswith("ENTRY")):
            m = _COMP_START.match(line.strip())
            if m:
                name = m.group(2)
                cur = Computation(name)
                comps[name] = cur
                if m.group(1):
                    entry = name
                continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, rtype, op, operand_str, attrs = m.groups()
        operands = _OPERAND_RE.findall(operand_str)
        instr = Instr(name, rtype, op, operands, attrs, operand_str)
        cur.instrs[name] = instr
        cur.order.append(name)
    return comps, entry


def _dot_flops(comp: Computation, instr: Instr) -> float:
    result_elems = 1
    for d in _first_shape_dims(instr.result_type):
        result_elems *= d
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", instr.attrs)
    cdims = [int(x) for x in m.group(1).split(",")] if m and m.group(1) else []
    lhs = comp.instrs.get(instr.operands[0]) if instr.operands else None
    csize = 1
    if lhs is not None:
        dims = _first_shape_dims(lhs.result_type)
        for c in cdims:
            if c < len(dims):
                csize *= dims[c]
    return 2.0 * result_elems * csize


def _conv_flops(comp: Computation, instr: Instr) -> float:
    result_elems = 1
    rdims = _first_shape_dims(instr.result_type)
    for d in rdims:
        result_elems *= d
    kernel = comp.instrs.get(instr.operands[1]) if len(instr.operands) > 1 else None
    if kernel is None:
        return 2.0 * result_elems
    kdims = _first_shape_dims(kernel.result_type)
    kelems = 1
    for d in kdims:
        kelems *= d
    # flops = 2 * result * (kernel elems / out_channels); out_channels is
    # the last kernel dim under the default (.., I, O) kernel layout
    out_ch = kdims[-1] if kdims else 1
    return 2.0 * result_elems * (kelems / max(out_ch, 1))


@dataclasses.dataclass
class CompCost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    # attention-volume subset (instructions tagged with the ``attnvol``
    # named_scope in models/attention.py) — priced separately so the
    # analysis can swap the XLA-fallback attention for the fused Pallas
    # kernel's cost model (§Perf fused-attention step)
    attn_flops: float = 0.0
    attn_hbm_bytes: float = 0.0
    coll_bytes: dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float)
    )
    # (instr, body_comp, cond_comp) for while; branch list for conditional
    whiles: list[tuple[str, str, str]] = dataclasses.field(default_factory=list)
    conditionals: list[list[str]] = dataclasses.field(default_factory=list)
    fusions: list[str] = dataclasses.field(default_factory=list)


def _attr_computations(attrs: str, key: str) -> list[str]:
    m = re.search(key + r"=%([\w\.\-]+)", attrs)
    return [m.group(1)] if m else []


_SLICE_OPS = {"dynamic-slice", "dynamic-update-slice", "gather", "slice"}


def _operand_read_bytes(
    comps: dict[str, Computation], comp: Computation, ins: Instr
) -> float:
    """Bytes read for one instruction's operands, slice-aware.

    A fusion whose operand is only dynamic-sliced inside reads the slice,
    not the whole buffer (loop-carried stacked activations would otherwise
    be charged n_layers times over).  Same for top-level slice ops.
    """
    if ins.op in _SLICE_OPS:
        # read = result (ds/gather/slice); dus: read + write the update
        if ins.op == "dynamic-update-slice" and len(ins.operands) > 1:
            upd = comp.instrs.get(ins.operands[1])
            return 2.0 * type_bytes(upd.result_type) if upd else 0.0
        return type_bytes(ins.result_type)

    called = None
    if ins.op == "fusion":
        m = re.search(r"calls=%([\w\.\-]+)", ins.attrs)
        called = comps.get(m.group(1)) if m else None

    total = 0.0
    for idx, o in enumerate(ins.operands):
        src = comp.instrs.get(o)
        if src is None or src.op == "tuple":
            continue
        full = type_bytes(src.result_type)
        if called is not None:
            sliced = _fusion_param_read(called, idx)
            if sliced is not None:
                total += min(sliced, full)
                continue
        total += full
    return total


def _fusion_param_read(called: Computation, param_idx: int) -> float | None:
    """If parameter ``param_idx`` of a fused computation is consumed only
    by slice ops, return the sliced read size; else None (full read)."""
    pname = None
    for iname in called.order:
        ins = called.instrs[iname]
        if ins.op == "parameter" and ins.raw_operands.strip() == str(param_idx):
            pname = iname
            break
    if pname is None:
        return None
    uses = [
        called.instrs[i]
        for i in called.order
        if pname in called.instrs[i].operands
    ]
    if not uses:
        return 0.0
    read = 0.0
    for u in uses:
        if u.op not in _SLICE_OPS:
            return None
        if u.op == "dynamic-update-slice" and len(u.operands) > 1:
            upd = called.instrs.get(u.operands[1])
            read += type_bytes(upd.result_type) if upd else 0.0
        else:
            read += type_bytes(u.result_type)
    return read


def direct_costs(comps: dict[str, Computation]) -> dict[str, CompCost]:
    out: dict[str, CompCost] = {}
    for cname, comp in comps.items():
        cost = CompCost()
        for iname in comp.order:
            ins = comp.instrs[iname]
            op = ins.op
            tagged_attn = "attnvol" in ins.attrs
            if op == "dot":
                f = _dot_flops(comp, ins)
                cost.flops += f
                if tagged_attn:
                    cost.attn_flops += f
            elif op == "convolution":
                cost.flops += _conv_flops(comp, ins)
            elif op in COLLECTIVE_OPS:
                cost.coll_bytes[op] += type_bytes(ins.result_type)
            elif op == "while":
                body = _attr_computations(ins.attrs, "body")
                cond = _attr_computations(ins.attrs, "condition")
                if body and cond:
                    cost.whiles.append((iname, body[0], cond[0]))
            elif op == "conditional":
                branches = re.search(
                    r"branch_computations=\{([^}]*)\}", ins.attrs
                )
                names = []
                if branches:
                    names = _OPERAND_RE.findall(branches.group(1))
                else:
                    names = _attr_computations(
                        ins.attrs, "true_computation"
                    ) + _attr_computations(ins.attrs, "false_computation")
                if names:
                    cost.conditionals.append(names)
            elif op == "fusion":
                called = _attr_computations(ins.attrs, "calls")
                if called:
                    cost.fusions.append(called[0])
            if op not in _MEM_OPS_SKIP and op not in ("while", "conditional"):
                nbytes = type_bytes(ins.result_type)
                if op == "dynamic-update-slice":
                    # in-place DUS writes only the update region
                    nbytes = 0.0
                nbytes += _operand_read_bytes(comps, comp, ins)
                cost.hbm_bytes += nbytes
                if tagged_attn:
                    cost.attn_hbm_bytes += nbytes
        out[cname] = cost
    return out


def _while_trip_count(
    comps: dict[str, Computation], cond_name: str, default: int
) -> int:
    """Recover the trip count from the loop condition's comparison
    constant (scan loops compare an induction var against n).

    The value is the *operand string* of the constant's defining line
    (``%n = s32[] constant(6)`` parses op='constant', raw_operands='6').
    """
    cond = comps.get(cond_name)
    if cond is None:
        return default
    # The comparison may be a bare `compare` or wrapped in a kLoop fusion
    # (`ROOT %wrapped_compare = pred[] fusion(%gte, %const)` after SPMD),
    # so collect integer constants referenced by ANY instruction of this
    # (tiny) condition computation.
    consts = []
    for iname in cond.order:
        ins = cond.instrs[iname]
        if ins.op in ("compare", "fusion"):
            for o in ins.operands:
                src = cond.instrs.get(o)
                if src is not None and src.op == "constant":
                    m = re.fullmatch(r"\s*(-?\d+)\s*", src.raw_operands)
                    if m:
                        consts.append(int(m.group(1)))
    consts = [c for c in consts if c > 0]
    if consts:
        # a scan condition has exactly one compare; with several constants
        # the smallest positive one is the safe (under-)estimate
        return min(consts)
    return default


@dataclasses.dataclass
class ModuleCost:
    flops: float
    hbm_bytes: float
    coll_bytes: dict[str, float]
    trip_counts: list[int]
    attn_flops: float = 0.0
    attn_hbm_bytes: float = 0.0


def total_cost(
    text: str, *, default_trip_count: int = 1
) -> ModuleCost:
    """Price the whole module, multiplying while bodies by trip counts and
    charging conditionals at their most expensive branch."""
    comps, entry = parse_module(text)
    direct = direct_costs(comps)
    memo: dict[str, tuple] = {}
    trips: list[int] = []
    ZERO = (0.0, 0.0, {}, 0.0, 0.0)

    def total(cname: str, depth=0) -> tuple:
        if cname in memo:
            return memo[cname]
        if depth > 64 or cname not in direct:
            return ZERO
        c = direct[cname]
        flops, hbm = c.flops, c.hbm_bytes
        af, ah = c.attn_flops, c.attn_hbm_bytes
        coll = defaultdict(float, c.coll_bytes)
        for fusion_comp in c.fusions:
            f, _, _, faf, _ = total(fusion_comp, depth + 1)
            flops += f  # fused internals: flops only (no HBM round trip)
            af += faf
        for _, body, cond in c.whiles:
            trip = _while_trip_count(comps, cond, default_trip_count)
            trips.append(trip)
            for sub in (body, cond):
                sf, sh, sc, saf, sah = total(sub, depth + 1)
                flops += trip * sf
                hbm += trip * sh
                af += trip * saf
                ah += trip * sah
                for k, v in sc.items():
                    coll[k] += trip * v
        for branches in c.conditionals:
            best = max(
                (total(b, depth + 1) for b in branches),
                key=lambda t: t[0] + t[1],
                default=ZERO,
            )
            flops += best[0]
            hbm += best[1]
            af += best[3]
            ah += best[4]
            for k, v in best[2].items():
                coll[k] += v
        memo[cname] = (flops, hbm, dict(coll), af, ah)
        return memo[cname]

    if entry is None:
        return ModuleCost(0.0, 0.0, {}, [])
    f, h, c, af, ah = total(entry)
    return ModuleCost(f, h, dict(c), trips, af, ah)

"""§Roofline: three-term analysis of a compiled dry-run cell.

    compute_s    = HLO_FLOPs_per_device   / peak_FLOP/s
    memory_s     = HLO_bytes_per_device   / HBM_bw
    collective_s = coll_bytes_per_device  / (ICI links x link_bw)

HLO_FLOPs/bytes come from the trip-count-aware HLO parser
(``hlo_parser.total_cost``) because XLA's ``cost_analysis`` visits scan
bodies once (verified; see hlo_parser docstring).  The compiled module is
the per-device SPMD program, so costs are already per-device.

Two variants are reported per cell:

* **baseline** — the module exactly as XLA lowered it (attention volume
  materialized in HBM, as any non-fused deployment would run it);
* **fused-attention** — the ``attnvol``-tagged volume re-priced as the
  fused streaming Pallas kernel (``kernels/flash_attention``): causal/
  window-aware FLOPs, and HBM traffic = q/k/v/out (+ cache reads) only.
  This is the paper's stage-2+3 fusion applied at datacenter scale and is
  the first entry of every §Perf hillclimb.

MODEL_FLOPS uses the 6ND rule (6 x params x tokens for training; 2ND for
a forward-only pass) with N = active params for MoE.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.latency_model import TPU_V5E, HardwareSpec, RooflineTerms, roofline
from repro.roofline.hlo_parser import ModuleCost, total_cost


# ---------------------------------------------------------------------------
# analytic attention-kernel cost model (the fused Pallas kernel)
# ---------------------------------------------------------------------------


def _attn_geometry(cfg: ModelConfig):
    """(layers_with_attention, n_heads, qk_head_dim, v_head_dim, kv_heads)."""
    if cfg.attn_kind == "none":
        return 0, 0, 0, 0, 0
    if cfg.family == "hybrid":
        n_apps = math.ceil(cfg.n_layers / cfg.hybrid.attn_every)
        width = 2 * cfg.d_model if cfg.hybrid.concat_residual else cfg.d_model
        hd = width // cfg.n_heads
        return n_apps, cfg.n_heads, hd, hd, cfg.n_kv_heads
    if cfg.attn_kind == "mla" and cfg.mla is not None:
        qk = cfg.mla.qk_nope_head_dim + cfg.mla.qk_rope_head_dim
        return cfg.n_layers, cfg.n_heads, qk, cfg.mla.v_head_dim, cfg.n_heads
    hd = cfg.resolved_head_dim
    return cfg.n_layers, cfg.n_heads, hd, hd, cfg.n_kv_heads


def attention_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Global fused-kernel attention FLOPs: 2*(QK^T) + 2*(PV) per position
    pair, causal-halved, window-clipped; x3 for training (fwd+bwd)."""
    layers, h, qk_hd, v_hd, _ = _attn_geometry(cfg)
    if layers == 0:
        return 0.0
    b, l = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        ctx = min(l, cfg.sliding_window or l)
        per_layer = 2.0 * b * ctx * h * (qk_hd + v_hd)
        return per_layer * layers
    if cfg.sliding_window is not None and cfg.sliding_window < l:
        pairs = l * cfg.sliding_window  # each query sees <= window keys
    else:
        pairs = l * l / 2.0  # causal
        if cfg.is_encoder:
            pairs = l * l
    per_layer = 2.0 * b * pairs * h * (qk_hd + v_hd)
    mult = 3.0 if shape.kind == "train" else 1.0
    return per_layer * mult * layers


def attention_io_bytes(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Global HBM traffic of the fused kernel: q/k/v/out streamed once
    (train: ~3x for fwd+bwd), plus cache reads for decode."""
    layers, h, qk_hd, v_hd, hkv = _attn_geometry(cfg)
    if layers == 0:
        return 0.0
    b, l = shape.global_batch, shape.seq_len
    bpe = 2.0  # bf16 activations
    if shape.kind == "decode":
        ctx = min(l, cfg.sliding_window or l)
        if cfg.attn_kind == "mla" and cfg.mla is not None:
            cache = b * ctx * (cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim)
        else:
            cache = 2.0 * b * hkv * ctx * qk_hd
        per_layer = cache * bpe + b * h * (qk_hd + v_hd) * bpe
        return per_layer * layers
    qo = 2.0 * b * l * h * max(qk_hd, v_hd)
    kv = 2.0 * b * l * hkv * qk_hd
    mult = 3.0 if shape.kind == "train" else 1.0
    return (qo + kv) * bpe * mult * layers


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """6ND (train) / 2ND (prefill) / 2ND per token (decode)."""
    n_active = cfg.active_param_count_estimate()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch


# ---------------------------------------------------------------------------
# cell analysis
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class CellAnalysis:
    arch: str
    shape: str
    mesh: str
    n_devices: int
    # baseline (module as lowered)
    flops: float
    hbm_bytes: float
    coll_bytes: dict[str, float]
    terms: RooflineTerms
    # fused-attention variant (attnvol re-priced as the Pallas kernel)
    flops_fused: float
    hbm_bytes_fused: float
    terms_fused: RooflineTerms
    attn_flops_hlo: float
    attn_hbm_hlo: float
    model_flops_global: float
    useful_ratio: float  # MODEL_FLOPS / (HLO_FLOPs x devices), baseline
    useful_ratio_fused: float
    memory_stats: dict[str, int]
    trip_counts: list[int]

    @property
    def dominant(self) -> str:
        return self.terms.dominant

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        for key, t in (("terms", self.terms), ("terms_fused", self.terms_fused)):
            d[key] = {
                "compute_s": t.compute_s,
                "memory_s": t.memory_s,
                "collective_s": t.collective_s,
                "dominant": t.dominant,
            }
        return d


def analyze_cell(
    *,
    arch: str,
    shape_cfg: ShapeConfig,
    cfg: ModelConfig,
    mesh_name: str,
    n_devices: int,
    compiled,
    hw: HardwareSpec = TPU_V5E,
) -> CellAnalysis:
    text = compiled.as_text()
    mc: ModuleCost = total_cost(text, default_trip_count=cfg.n_layers)
    coll_total = sum(mc.coll_bytes.values())
    terms = roofline(mc.flops, mc.hbm_bytes, coll_total, hw)

    # fused-attention re-pricing (per-device shares of global kernel cost)
    attn_f_model = attention_flops(cfg, shape_cfg) / max(n_devices, 1)
    attn_io_model = attention_io_bytes(cfg, shape_cfg) / max(n_devices, 1)
    flops_fused = mc.flops - mc.attn_flops + attn_f_model
    hbm_fused = max(mc.hbm_bytes - mc.attn_hbm_bytes, 0.0) + attn_io_model
    terms_fused = roofline(flops_fused, hbm_fused, coll_total, hw)

    try:
        ms = compiled.memory_analysis()
        memory_stats = {
            "argument_bytes": int(ms.argument_size_in_bytes),
            "output_bytes": int(ms.output_size_in_bytes),
            "temp_bytes": int(ms.temp_size_in_bytes),
            "alias_bytes": int(ms.alias_size_in_bytes),
        }
    except Exception:  # pragma: no cover - backend-dependent
        memory_stats = {}

    mf = model_flops(cfg, shape_cfg) + attention_flops(cfg, shape_cfg)
    total_hlo = mc.flops * n_devices
    total_fused = flops_fused * n_devices
    return CellAnalysis(
        arch=arch,
        shape=shape_cfg.name,
        mesh=mesh_name,
        n_devices=n_devices,
        flops=mc.flops,
        hbm_bytes=mc.hbm_bytes,
        coll_bytes=dict(mc.coll_bytes),
        terms=terms,
        flops_fused=flops_fused,
        hbm_bytes_fused=hbm_fused,
        terms_fused=terms_fused,
        attn_flops_hlo=mc.attn_flops,
        attn_hbm_hlo=mc.attn_hbm_bytes,
        model_flops_global=mf,
        useful_ratio=(mf / total_hlo) if total_hlo else 0.0,
        useful_ratio_fused=(mf / total_fused) if total_fused else 0.0,
        memory_stats=memory_stats,
        trip_counts=mc.trip_counts,
    )

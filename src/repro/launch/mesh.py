"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module never touches jax device state — required for the dry-run's
``XLA_FLAGS`` ordering and for smoke tests that must see 1 device.
"""

from __future__ import annotations

import jax


def make_mesh(shape, axes):
    """Version-tolerant ``jax.make_mesh``.

    ``jax.sharding.AxisType`` (and the ``axis_types`` kwarg) only exist on
    newer JAX lines; on older ones every axis is implicitly Auto, which is
    exactly what we want — so pass it only when available.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(
            shape, axes, axis_types=(axis_type.Auto,) * len(axes)
        )
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
    Multi-pod: (pod=2, data=16, model=16) = 512 chips across DCN."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(model: int | None = None):
    """Small mesh over whatever devices the host actually has (tests,
    examples).  Uses (data, model) with model defaulting to 1."""
    n = len(jax.devices())
    model = model or 1
    assert n % model == 0, (n, model)
    return make_mesh((n // model, model), ("data", "model"))


def mesh_axis_names(mesh) -> tuple[str, ...]:
    return tuple(mesh.axis_names)


def data_axes(mesh) -> tuple[str, ...]:
    """Axes that shard the batch: ('pod','data') when pod exists."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))

"""Launchers: mesh construction, the multi-pod dry-run, and the train /
serve drivers.  NOTE: ``repro.launch.dryrun`` sets XLA_FLAGS at import —
import it only in dedicated processes."""

from repro.launch.mesh import make_host_mesh, make_production_mesh  # noqa: F401

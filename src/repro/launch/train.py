"""Cluster training launcher.

Single-host: runs the fault-tolerant loop directly.  Multi-host (real TPU
pods): each worker calls ``jax.distributed.initialize()`` (env-driven on
Cloud TPU), builds the production mesh, and runs the same loop — the
checkpointer and data pipeline are already per-process sharded.

    PYTHONPATH=src python -m repro.launch.train --arch minicpm-2b --steps 100
"""

from __future__ import annotations

import argparse
import logging

import jax

from repro import configs
from repro.configs.base import ParallelismConfig, TrainConfig
from repro.data.synthetic import SyntheticLM, SyntheticLMConfig
from repro.distributed.sharding import ShardingRules
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.train import run_training


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--full-config", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--workdir", default="/tmp/repro_launch_train")
    ap.add_argument("--multihost", action="store_true",
                    help="initialize jax.distributed and use the production mesh")
    ap.add_argument("--schedule", default=None)
    args = ap.parse_args()

    logging.basicConfig(level=logging.INFO)
    if args.multihost:
        jax.distributed.initialize()
        mesh = make_production_mesh()
    else:
        mesh = None

    cfg = configs.get_config(args.arch, reduced=not args.full_config)
    schedule = args.schedule or (
        "wsd" if cfg.name.startswith("minicpm") else "cosine"
    )
    tc = TrainConfig(
        total_steps=args.steps,
        warmup_steps=max(5, args.steps // 20),
        schedule=schedule,
        checkpoint_every=max(25, args.steps // 4),
    )
    ds = SyntheticLM(SyntheticLMConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq, global_batch=args.batch
    ))
    rules = ShardingRules(mesh=mesh, plan=ParallelismConfig()) if mesh else None
    result = run_training(
        cfg, tc, ds.batch, workdir=args.workdir, mesh=mesh, rules=rules
    )
    print(f"done at step {result.final_step}; "
          f"last loss {result.metrics_history[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()

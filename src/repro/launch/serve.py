"""Serving launcher: continuous-batching engine with the paper's
quantized datapath, fed from a simple request file or synthetic load.

    PYTHONPATH=src python -m repro.launch.serve --arch granite-8b --quantized

All engine flags come from the shared serving CLI (serve/cli.py);
``--stream`` switches from the batch ``Engine.generate`` wrapper to
per-token ``Engine.stream`` consumption and reports time-to-first-token.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.models import lm
from repro.serve import Engine, ReplicaRouter
from repro.serve.cli import (  # noqa: F401  (resolve_policy_arg re-export)
    add_serving_args,
    config_from_args,
    resolve_policy_arg,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--full-config", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    add_serving_args(ap, max_batch=4, max_seq=128, max_new=16,
                     temperature=0.0)
    args = ap.parse_args()

    cfg = configs.get_config(args.arch, reduced=not args.full_config)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    serve_cfg = config_from_args(args, cfg)
    # replicas > 1: the same request-lifecycle API, fronted by the
    # least-loaded data-parallel router (serve/router.py)
    eng = (
        ReplicaRouter(cfg, params, serve_cfg)
        if serve_cfg.replicas > 1
        else Engine(cfg, params, serve_cfg)
    )
    rng = np.random.default_rng(0)
    preamble = list(rng.integers(0, cfg.vocab_size, args.shared_prefix))
    handles = [
        eng.submit(
            preamble
            + list(rng.integers(0, cfg.vocab_size, rng.integers(4, 16))),
            max_new_tokens=args.max_new,
        )
        for _ in range(args.requests)
    ]
    t0 = time.perf_counter()
    if args.stream:
        ttfts, toks = [], 0
        for h in handles:
            events = list(eng.stream(h))
            toks += len(events)
            if events:  # a request can legally finish with zero tokens
                # created_at, not submitted_at: preemption restamps the
                # latter for queue-wait accounting
                ttfts.append(events[0].ts - eng.request(h).created_at)
        dt = time.perf_counter() - t0
        print(f"{len(handles)} requests streamed, {toks} tokens in {dt:.2f}s "
              f"({toks/dt:.1f} tok/s) | "
              f"ttft p50 {np.percentile(ttfts, 50)*1e3:.1f} ms / "
              f"p95 {np.percentile(ttfts, 95)*1e3:.1f} ms"
              if ttfts else
              f"{len(handles)} requests streamed, {toks} tokens in {dt:.2f}s")
    else:
        results = eng.generate()
        dt = time.perf_counter() - t0
        toks = sum(len(results[h.uid].generated) for h in handles)
        print(f"{len(handles)} requests, {toks} tokens in {dt:.2f}s "
              f"({toks/dt:.1f} tok/s host throughput)")
    if isinstance(eng, ReplicaRouter):
        fleet = eng.telemetry
        print(f"router: {fleet['replicas']} replicas | "
              f"{fleet['tokens_generated']} tokens total | "
              "per-replica admitted "
              f"{[t['prompts_admitted'] for t in fleet['replica_telemetry']]}")
        eng = eng.engines[0]  # detailed prints: the first replica's view
    tel = eng.telemetry
    queue_wait_ms = (
        tel["queue_wait_s_total"] / max(tel["prompts_admitted"], 1) * 1e3
    )
    mode = "async (pipelined)" if eng.serve_cfg.async_loop else "sync"
    print(f"engine loop: {mode}"
          + (" | mesh-sharded decode" if eng.serve_cfg.shard_decode else ""))
    print(f"telemetry: policy={eng.executor.policy.name} | "
          f"queue wait mean {queue_wait_ms:.1f} ms | "
          f"{tel['prefill_compiles']} prefill programs "
          f"(buckets={eng.executor.buckets or 'exact'}"
          f"{f', chunk={args.prefill_chunk}' if args.prefill_chunk else ''}), "
          f"{tel['decode_compiles']} decode program "
          f"(decode_steps={eng.serve_cfg.decode_steps})")
    print(f"kv cache: layout={tel['kv_layout']} "
          f"{tel['kv_bytes'] / 2**20:.2f} MiB | "
          f"pages {tel['pages_in_use']}/{tel['pages_capacity']} in use "
          f"(peak {tel['pages_in_use_peak']}, "
          f"page_size={tel['kv_page_size']})")
    if args.kv_prefix_cache or args.kv_preemption:
        print(f"prefix cache: hit rate {tel['prefix_hit_rate']:.2f} "
              f"({tel['prefix_hits']}/{tel['prefix_queries']}) | "
              f"prefill tokens saved {tel['prefill_tokens_saved']} "
              f"(+{tel['prefix_tokens_shared']} shared-storage) | "
              f"{tel['pages_cached']} pages retained, "
              f"{tel['cow_copies']} CoW copies, "
              f"{tel['page_evictions']} evictions | "
              f"{tel['preemptions']} preemptions")
    if getattr(args, "kv_host_pages", 0):
        print(f"victim tier: {tel['swap_outs']} spills / "
              f"{tel['swap_ins']} swap-ins | "
              f"host pages {tel['host_pages_used']}/"
              f"{tel['host_pages_capacity']} "
              f"({tel['host_evictions']} tier evictions) | "
              f"swap time {tel['swap_latency_s']*1e3:.1f} ms")
    if args.scheduler == "edf" or args.deadline_ms is not None:
        print(f"slo: scheduler={args.scheduler} | "
              f"{tel['deadline_requests']} deadlined requests, "
              f"{tel['deadline_missed']} missed "
              f"({tel['deadline_dropped']} dropped)")
    if tel["phases"]:
        print("phases (ms): " + " | ".join(
            f"{name} p50 {s['p50_ms']:.2f} / p95 {s['p95_ms']:.2f}"
            for name, s in tel["phases"].items() if isinstance(s, dict)
        ))
        if "overlap_efficiency" in tel["phases"]:
            ph = tel["phases"]
            print(f"overlap: device hidden {ph['device_overlap_s']:.3f}s | "
                  f"host bubble {ph['host_bubble_s']:.3f}s | "
                  f"efficiency {ph['overlap_efficiency']:.3f}")


if __name__ == "__main__":
    main()

"""Serving launcher: continuous-batching engine with the paper's
quantized datapath, fed from a simple request file or synthetic load.

    PYTHONPATH=src python -m repro.launch.serve --arch granite-8b --quantized
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.configs.base import ServeConfig
from repro.models import lm
from repro.serve import ServingEngine


def resolve_policy_arg(policy: str | None, quantized: bool, cfg) -> str | None:
    """Shared --policy semantics for the serving CLIs: explicit --policy
    wins; 'auto' resolves to the arch's recommended ``cfg.serve_policy``;
    the deprecated --quantized maps to the int8_serve preset."""
    if policy == "auto":
        return cfg.serve_policy
    if policy is not None:
        return policy
    if quantized:
        return "int8_serve"
    return None


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--full-config", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--policy", default=None,
                    help="precision policy: a preset name (float, int8_serve, "
                         "paper_vu13p, ptq_fixed<W,I>, qat_fixed<W,I>) or "
                         "'auto' for the arch's recommended serve_policy")
    ap.add_argument("--quantized", action="store_true",
                    help="deprecated alias for --policy int8_serve")
    ap.add_argument("--prefill-buckets", type=int, nargs="*", default=None,
                    help="prompt-length buckets (default: powers of two; "
                         "pass with no values for exact-length v1 prefill)")
    ap.add_argument("--decode-steps", type=int, default=4,
                    help="decode tokens per host dispatch (lax.scan)")
    ap.add_argument("--max-prefill-per-step", type=int, default=0,
                    help="cap on prompts admitted per step (0 = all free slots)")
    ap.add_argument("--kv-layout", default="dense",
                    choices=("dense", "paged"),
                    help="KV-cache storage layout: dense per-slot slabs or "
                         "block-table pages (serve/kv_cache.py)")
    ap.add_argument("--kv-page-size", type=int, default=16,
                    help="tokens per page (paged layout; must divide "
                         "--max-seq)")
    ap.add_argument("--kv-pages", type=int, default=None,
                    help="physical pages in the pool (default: worst case "
                         "max_batch x max_seq / page_size, + trash page)")
    ap.add_argument("--kv-prefix-cache", action="store_true",
                    help="share full prompt pages across same-prefix "
                         "requests (paged layout; copy-on-write)")
    ap.add_argument("--kv-preemption", action="store_true",
                    help="preempt the youngest resident instead of "
                         "head-of-line blocking when the page pool is "
                         "exhausted (paged layout, bit-exact datapath)")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="prepend a fixed preamble of this many tokens to "
                         "every request (prefix-cache exercise; think "
                         "repeated detector-geometry preambles)")
    args = ap.parse_args()

    cfg = configs.get_config(args.arch, reduced=not args.full_config)
    policy = resolve_policy_arg(args.policy, args.quantized, cfg)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(
        cfg, params,
        ServeConfig(
            max_batch=args.max_batch, max_seq_len=args.max_seq,
            temperature=args.temperature,
            policy=policy,
            prefill_buckets=(
                None if args.prefill_buckets is None
                else tuple(args.prefill_buckets)
            ),
            decode_steps=args.decode_steps,
            max_prefill_per_step=args.max_prefill_per_step,
            kv_layout=args.kv_layout,
            kv_page_size=args.kv_page_size,
            kv_pages=args.kv_pages,
            kv_prefix_cache=args.kv_prefix_cache,
            kv_preemption=args.kv_preemption,
        ),
    )
    rng = np.random.default_rng(0)
    preamble = list(rng.integers(0, cfg.vocab_size, args.shared_prefix))
    uids = [
        eng.submit(
            preamble
            + list(rng.integers(0, cfg.vocab_size, rng.integers(4, 16))),
            max_new_tokens=args.max_new,
        )
        for _ in range(args.requests)
    ]
    t0 = time.perf_counter()
    results = eng.run()
    dt = time.perf_counter() - t0
    toks = sum(len(results[u].generated) for u in uids)
    print(f"{len(uids)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s host throughput)")
    tel = eng.telemetry
    print(f"telemetry: {tel['tokens_per_s']:.1f} tok/s | "
          f"policy={eng.policy.name} | "
          f"queue wait mean {tel['queue_wait_s_mean']*1e3:.1f} ms | "
          f"{tel['prefill_compiles']} prefill programs "
          f"(buckets={eng.prefill_buckets or 'exact'}), "
          f"{tel['decode_compiles']} decode program "
          f"(decode_steps={eng.serve_cfg.decode_steps})")
    print(f"kv cache: layout={tel['kv_layout']} "
          f"{tel['kv_bytes'] / 2**20:.2f} MiB | "
          f"pages {tel['pages_in_use']}/{tel['pages_capacity']} in use "
          f"(peak {tel['pages_in_use_peak']}, "
          f"page_size={tel['kv_page_size']})")
    if args.kv_prefix_cache or args.kv_preemption:
        print(f"prefix cache: hit rate {tel['prefix_hit_rate']:.2f} "
              f"({tel['prefix_hits']}/{tel['prefix_queries']}) | "
              f"prefill tokens saved {tel['prefill_tokens_saved']} "
              f"(+{tel['prefix_tokens_shared']} shared-storage) | "
              f"{tel['pages_cached']} pages retained, "
              f"{tel['cow_copies']} CoW copies, "
              f"{tel['page_evictions']} evictions | "
              f"{tel['preemptions']} preemptions")


if __name__ == "__main__":
    main()

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell on 512 placeholder host devices, record memory analysis, cost
analysis and collective schedule for §Dry-run / §Roofline.

The two lines above MUST stay the first statements in this file — jax
locks the device count at first init, and smoke tests/benches must not
inherit them (they import repro.* directly, never this module).

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun --arch granite-8b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all            # 40 cells x 2 meshes
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh pod # single-pod only

Results are cached as JSON under --out (default experiments/dryrun); a
cell is recompiled only with --force.
"""

import argparse
import dataclasses
import functools
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro import configs
from repro.configs.base import SHAPES, ModelConfig, ParallelismConfig, ShapeConfig
from repro.distributed.sharding import ShardingRules
from repro.launch import mesh as mesh_lib
from repro.launch.mesh import make_production_mesh
from repro.models import lm
from repro.models import params as params_lib
from repro.optim import AdamW
from repro.roofline.analysis import analyze_cell
from repro.train import step as step_lib

MESHES = {
    "pod": dict(multi_pod=False),
    "multipod": dict(multi_pod=True),
}


def make_mesh(name: str):
    if name in MESHES:
        return make_production_mesh(**MESHES[name])
    if name == "pod2":  # head-aligned small TP: 128-way data x 2-way model
        return mesh_lib.make_mesh(
            (128, 2), ("data", "model")
        )
    if name == "pod8":  # alternate aspect ratio: 32-way data x 8-way model
        return mesh_lib.make_mesh(
            (32, 8), ("data", "model")
        )
    if name == "pod32":  # 8-way data x 32-way model
        return mesh_lib.make_mesh(
            (8, 32), ("data", "model")
        )
    if name == "tiny":  # tests: 2x2 from the same 512-device pool
        return mesh_lib.make_mesh(
            (2, 2), ("data", "model")
        )
    if name == "tinypod":
        return mesh_lib.make_mesh(
            (2, 2, 2), ("pod", "data", "model")
        )
    raise KeyError(name)


def plan_for(cfg: ModelConfig, shape: ShapeConfig) -> ParallelismConfig:
    """Default parallelism plan per cell kind (the baseline the §Perf
    hillclimb starts from)."""
    remat = "minimal" if shape.kind == "train" else "none"
    # long-context cells shard the sequence/cache dim (SP)
    sp = shape.seq_len >= 32768 and shape.kind != "train"
    return ParallelismConfig(sp=sp, remat=remat)


# ---------------------------------------------------------------------------
# per-kind lowering
# ---------------------------------------------------------------------------


def _kernel_cfg(cfg, shape, mesh, rules, kernel=None):
    """Default kernel dict for dry-run lowering: pins activation sharding
    (batch over data axes; seq over 'model' under SP for long contexts)."""
    kernel = dict(kernel or {})
    sharded_dims = {1: "seq"} if (rules.plan.sp and shape.kind != "decode") else None
    kernel.setdefault(
        "act_sharding",
        rules.batch_sharding(
            3, sharded_dims,
            shape=(shape.global_batch, shape.seq_len, cfg.d_model),
        ),
    )
    return kernel


def lower_train(cfg, shape, mesh, rules, kernel=None):
    kernel = _kernel_cfg(cfg, shape, mesh, rules, kernel)
    optimizer = AdamW(schedule=lambda s: 3e-4)
    abstract = step_lib.abstract_train_state(cfg, optimizer)
    axes = step_lib.train_state_logical_axes(cfg)
    state_sh = rules.tree_shardings(abstract, axes)
    specs = lm.input_specs(cfg, shape)
    batch_sh = {
        k: rules.batch_sharding(len(v.shape), shape=v.shape)
        for k, v in specs.items()
    }
    fn = functools.partial(
        step_lib.train_step,
        cfg=cfg,
        optimizer=optimizer,
        kernel=kernel,
        remat=rules.plan.remat,
        grad_accum=rules.plan.grad_accum,
    )
    jitted = jax.jit(
        fn,
        in_shardings=(state_sh, batch_sh),
        out_shardings=(state_sh, None),
        donate_argnums=(0,),
    )
    with mesh:
        return jitted.lower(abstract, specs)


def lower_prefill(cfg, shape, mesh, rules, kernel=None):
    kernel = _kernel_cfg(cfg, shape, mesh, rules, kernel)
    aparams = lm.abstract_params(cfg)
    axes = params_lib.logical_axes(lm.param_spec(cfg))
    params_sh = rules.tree_shardings(aparams, axes)
    acaches = lm.abstract_caches(cfg, shape.global_batch, shape.seq_len)
    cache_axes = lm.cache_logical_axes(cfg)
    cache_sh = _cache_shardings(rules, acaches, cache_axes, cfg)
    specs = lm.input_specs(cfg, shape)
    batch_sh = {
        k: rules.batch_sharding(len(v.shape), shape=v.shape)
        for k, v in specs.items()
    }

    def fn(params, batch, caches):
        logits, new_caches, _ = lm.forward(
            params, cfg, batch, mode="prefill", caches=caches, kernel=kernel
        )
        return logits[:, -1], new_caches

    jitted = jax.jit(
        fn,
        in_shardings=(params_sh, batch_sh, cache_sh),
        out_shardings=(None, cache_sh),
        donate_argnums=(2,),
    )
    with mesh:
        return jitted.lower(aparams, specs, acaches)


def lower_decode(cfg, shape, mesh, rules, kernel=None, quantized=False):
    kernel = _kernel_cfg(cfg, shape, mesh, rules, kernel)
    aparams = lm.abstract_params(cfg)
    axes = params_lib.logical_axes(lm.param_spec(cfg))
    params_sh = rules.tree_shardings(aparams, axes)
    acaches = lm.abstract_caches(
        cfg, shape.global_batch, shape.seq_len, quantized=quantized
    )
    cache_axes = lm.cache_logical_axes(cfg, quantized=quantized)
    cache_sh = _cache_shardings(rules, acaches, cache_axes, cfg)
    specs = lm.input_specs(cfg, shape)
    batch_sh = {
        "tokens": rules.batch_sharding(2, shape=specs["tokens"].shape),
        "positions": rules.batch_sharding(1, shape=specs["positions"].shape),
    }

    def fn(params, tokens, positions, caches):
        return lm.decode_step(
            params, cfg, tokens, positions, caches, kernel=kernel
        )

    jitted = jax.jit(
        fn,
        in_shardings=(
            params_sh, batch_sh["tokens"], batch_sh["positions"], cache_sh,
        ),
        out_shardings=(None, cache_sh),
        donate_argnums=(3,),
    )
    with mesh:
        return jitted.lower(
            aparams, specs["tokens"], specs["positions"], acaches
        )


def _cache_shardings(rules, acaches, cache_axes, cfg):
    def walk(abs_node, axes_node):
        if isinstance(abs_node, jax.ShapeDtypeStruct):
            return rules.sharding_for(tuple(axes_node), abs_node.shape)
        return {k: walk(abs_node[k], axes_node[k]) for k in abs_node}

    sh = {}
    for key in acaches:
        axes_key = "layers" if key == "shared" else key
        # hybrid 'shared' uses the same per-entry axes as dense kv caches
        node_axes = cache_axes.get(key) or cache_axes["layers"]
        sh[key] = walk(acaches[key], node_axes)
    return sh


LOWER = {"train": lower_train, "prefill": lower_prefill, "decode": lower_decode}


# ---------------------------------------------------------------------------
# cell runner
# ---------------------------------------------------------------------------


def run_cell(
    arch: str,
    shape_name: str,
    mesh_name: str,
    *,
    out_dir: str,
    force: bool = False,
    reduced: bool = False,
    plan: ParallelismConfig | None = None,
    tag: str = "",
    kernel: dict | None = None,
    cfg_transform=None,
    overrides: dict | None = None,
    quantized_cache: bool = False,
) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    fname = f"{arch}__{shape_name}__{mesh_name}{('__' + tag) if tag else ''}.json"
    path = os.path.join(out_dir, fname)
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)

    ok, reason = configs.cell_status(arch, shape_name)
    if not ok:
        result = {
            "arch": arch, "shape": shape_name, "mesh": mesh_name,
            "status": "skip", "reason": reason,
        }
        with open(path, "w") as f:
            json.dump(result, f, indent=2)
        return result

    cfg = configs.get_config(arch, reduced=reduced)
    if cfg_transform is not None:
        cfg = cfg_transform(cfg)
    shape = SHAPES[shape_name]
    if reduced:
        shape = dataclasses.replace(
            shape, seq_len=min(shape.seq_len, 128),
            global_batch=min(shape.global_batch, 8),
        )
    mesh = make_mesh(mesh_name)
    plan = plan or plan_for(cfg, shape)
    rules = ShardingRules(mesh=mesh, plan=plan, overrides=overrides or {})
    t0 = time.time()
    try:
        kw = {"quantized": True} if (quantized_cache and shape.kind == "decode") else {}
        lowered = LOWER[shape.kind](cfg, shape, mesh, rules, kernel=kernel, **kw)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
        analysis = analyze_cell(
            arch=arch,
            shape_cfg=shape,
            cfg=cfg,
            mesh_name=mesh_name,
            n_devices=mesh.size,
            compiled=compiled,
        )
        result = analysis.to_json()
        result.update(
            status="ok",
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            fallbacks=rules.fallbacks,
            plan=dataclasses.asdict(plan),
            params=cfg.param_count_estimate(),
            active_params=cfg.active_param_count_estimate(),
        )
        print(
            f"[dryrun] {arch} x {shape_name} x {mesh_name}: OK "
            f"(lower {t_lower:.1f}s compile {t_compile:.1f}s, "
            f"dominant={analysis.dominant})",
            flush=True,
        )
    except Exception as e:  # noqa: BLE001 - record and continue
        result = {
            "arch": arch, "shape": shape_name, "mesh": mesh_name,
            "status": "error", "error": f"{type(e).__name__}: {e}",
            "traceback": traceback.format_exc()[-4000:],
        }
        print(f"[dryrun] {arch} x {shape_name} x {mesh_name}: FAILED {e}",
              flush=True)
    with open(path, "w") as f:
        json.dump(result, f, indent=2, default=str)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both",
                    choices=["pod", "multipod", "both", "tiny", "tinypod", "pod2", "pod8", "pod32"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--reduced", action="store_true", help="reduced configs (tests)")
    args = ap.parse_args()

    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]
    cells: list[tuple[str, str]] = []
    if args.all:
        cells = [(a, s) for a, s, _, _ in configs.dryrun_cells()]
    else:
        archs = [args.arch] if args.arch else configs.ARCH_NAMES
        shapes = [args.shape] if args.shape else list(SHAPES)
        cells = [(a, s) for a in archs for s in shapes]

    n_ok = n_skip = n_err = 0
    for arch, shape in cells:
        for mesh_name in meshes:
            r = run_cell(
                arch, shape, mesh_name,
                out_dir=args.out, force=args.force, reduced=args.reduced,
            )
            st = r.get("status")
            n_ok += st == "ok"
            n_skip += st == "skip"
            n_err += st == "error"
    print(f"[dryrun] done: {n_ok} ok, {n_skip} skipped, {n_err} errors")
    return 0 if n_err == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())

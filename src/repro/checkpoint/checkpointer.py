"""Sharded, asynchronous, integrity-checked checkpointing.

Layout (one directory per step)::

    <dir>/step_000200.tmp/...      (atomic: renamed on completion)
    <dir>/step_000200/
        proc_00000.npz             per-process array shards
        META                       msgpack: step, keys, crc32s, mesh shape

Features required at 1000+-node scale, implemented here and unit-tested:

* **async**  — saves run on a background thread (training continues).
* **atomic** — write to ``.tmp`` then rename; readers never see partials.
* **integrity** — crc32 per array, verified on restore.
* **keep-k** — old steps garbage-collected after a successful save.
* **elastic restore** — arrays are loaded to host then ``device_put`` with
  the *caller's current* shardings, so a job restarted on a different mesh
  shape (scale up/down) resumes from the same checkpoint.

On a real multi-host cluster each process saves only its addressable
shards; in this single-process environment proc_00000 holds everything,
but the layout, metadata and restore path are process-count-agnostic.
"""

from __future__ import annotations

import os
import re
import shutil
import threading
import zlib
from typing import Any

import jax
import ml_dtypes
import msgpack
import numpy as np

PyTree = Any

_STEP_RE = re.compile(r"^step_(\d+)$")

# npz cannot store extended dtypes (bfloat16, fp8); store a bit-view and
# the original dtype name in META.
_EXT_DTYPES = {
    "bfloat16": (ml_dtypes.bfloat16, np.uint16),
    "float8_e4m3fn": (ml_dtypes.float8_e4m3fn, np.uint8),
    "float8_e5m2": (ml_dtypes.float8_e5m2, np.uint8),
}


def _encode_array(x: np.ndarray) -> tuple[np.ndarray, str]:
    name = x.dtype.name
    if name in _EXT_DTYPES:
        return x.view(_EXT_DTYPES[name][1]), name
    return x, name


def _decode_array(x: np.ndarray, name: str) -> np.ndarray:
    if name in _EXT_DTYPES:
        return x.view(_EXT_DTYPES[name][0])
    return x


def _flatten_with_paths(tree: PyTree) -> dict[str, Any]:
    flat = {}

    def walk(node, path):
        if isinstance(node, dict):
            for k in sorted(node):
                walk(node[k], path + (str(k),))
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                walk(v, path + (str(i),))
        else:
            flat["/".join(path)] = node

    walk(tree, ())
    return flat


def _unflatten_like(template: PyTree, flat: dict[str, Any]) -> PyTree:
    def walk(node, path):
        if isinstance(node, dict):
            return {k: walk(node[k], path + (str(k),)) for k in node}
        if isinstance(node, (list, tuple)):
            seq = [walk(v, path + (str(i),)) for i, v in enumerate(node)]
            return type(node)(seq)
        return flat["/".join(path)]

    return walk(template, ())


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None
        self._error: list[BaseException] = []

    # ------------------------------------------------------------- save --
    def save(self, step: int, tree: PyTree, blocking: bool = False):
        """Snapshot to host memory synchronously, write to disk async."""
        flat = _flatten_with_paths(tree)
        host = {k: np.asarray(v) for k, v in flat.items()}
        self.wait()  # one outstanding save at a time
        self._thread = threading.Thread(
            target=self._write, args=(step, host), daemon=True
        )
        self._thread.start()
        if blocking:
            self.wait()

    def _write(self, step: int, host: dict[str, np.ndarray]):
        try:
            final = os.path.join(self.directory, f"step_{step:08d}")
            tmp = final + ".tmp"
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            encoded, dtypes = {}, {}
            for k, v in host.items():
                encoded[k], dtypes[k] = _encode_array(v)
            np.savez(os.path.join(tmp, "proc_00000.npz"), **encoded)
            meta = {
                "step": step,
                "keys": list(host),
                "dtypes": dtypes,
                "crc32": {
                    k: zlib.crc32(np.ascontiguousarray(v).tobytes())
                    for k, v in encoded.items()
                },
                "nprocs": 1,
            }
            with open(os.path.join(tmp, "META"), "wb") as f:
                f.write(msgpack.packb(meta))
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._gc()
        except BaseException as e:  # surfaced by wait()
            self._error.append(e)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error:
            raise self._error.pop()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"))

    # ---------------------------------------------------------- restore --
    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.directory):
            m = _STEP_RE.match(name)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(
        self,
        template: PyTree,
        step: int | None = None,
        shardings: PyTree | None = None,
    ) -> PyTree:
        """Load a checkpoint into the structure of ``template``.

        ``shardings`` (same structure) re-shards on the *current* mesh —
        the elastic-restart path: the saved mesh shape is irrelevant.
        """
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        path = os.path.join(self.directory, f"step_{step:08d}")
        with open(os.path.join(path, "META"), "rb") as f:
            meta = msgpack.unpackb(f.read())
        with np.load(os.path.join(path, "proc_00000.npz")) as z:
            host = {k: z[k] for k in z.files}
        for k, crc in meta["crc32"].items():
            actual = zlib.crc32(np.ascontiguousarray(host[k]).tobytes())
            if actual != crc:
                raise IOError(f"checkpoint corruption in {k} @ step {step}")
        dtypes = meta.get("dtypes", {})
        host = {k: _decode_array(v, dtypes.get(k, v.dtype.name)) for k, v in host.items()}
        flat_shardings = (
            _flatten_with_paths(shardings) if shardings is not None else {}
        )
        placed = {}
        for k, v in host.items():
            sh = flat_shardings.get(k)
            placed[k] = jax.device_put(v, sh) if sh is not None else jax.numpy.asarray(v)
        return _unflatten_like(template, placed)

"""Version-tolerant shims over the Pallas TPU API.

The ``compiler_params`` container class has been renamed across JAX
releases (``pltpu.TPUCompilerParams`` on 0.4.3x, ``pltpu.CompilerParams``
on newer/older lines, a plain dict on the oldest ones).  Every kernel in
this package routes through :func:`tpu_compiler_params` so the rename is
absorbed in exactly one place.
"""

from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu


def tpu_compiler_params(*, dimension_semantics: tuple[str, ...], **kwargs):
    """Build the Pallas TPU ``compiler_params`` object for this JAX version.

    Accepts the keyword arguments of the underlying params class
    (``dimension_semantics`` is the only one our kernels use) and returns
    whichever container the installed JAX expects.
    """
    cls = getattr(pltpu, "CompilerParams", None) or getattr(
        pltpu, "TPUCompilerParams", None
    )
    if cls is not None:
        return cls(dimension_semantics=dimension_semantics, **kwargs)
    # very old JAX: pallas_call accepted a {"mosaic": {...}} mapping
    return {"mosaic": {"dimension_semantics": dimension_semantics, **kwargs}}

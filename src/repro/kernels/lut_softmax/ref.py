"""Pure-jnp oracle for the LUT softmax kernel (gather-based lookup)."""

from __future__ import annotations

import jax

from repro.core import lut


def lut_softmax_ref(x: jax.Array) -> jax.Array:
    """Bit-identical reference: same tables, gather instead of MXU one-hot."""
    import jax.numpy as jnp

    e = lut.lut_lookup(x.astype(jnp.float32), lut.exp_table(), lut.EXP_SPEC)
    s = jnp.sum(e, axis=-1, keepdims=True)
    inv = lut.lut_lookup(s, lut.inv_table(), lut.INV_SPEC)
    return (e * inv).astype(x.dtype)


def softmax_exact_ref(x: jax.Array) -> jax.Array:
    """Float oracle (what the LUT approximates)."""
    import jax.numpy as jnp

    return jax.nn.softmax(x.astype(jnp.float32), axis=-1).astype(x.dtype)

from repro.kernels.lut_softmax.lut_softmax import lut_softmax_pallas
from repro.kernels.lut_softmax.ops import lut_softmax
from repro.kernels.lut_softmax.ref import lut_softmax_ref, softmax_exact_ref

__all__ = [
    "lut_softmax",
    "lut_softmax_pallas",
    "lut_softmax_ref",
    "softmax_exact_ref",
]

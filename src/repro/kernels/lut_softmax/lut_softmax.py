"""Paper's 3-stage restructured softmax as a Pallas TPU kernel (Sec. IV-B).

    S_i = exp(z_i) * ( sum_j exp(z_j) )^{-1}

Stage 1: element-wise exp via lookup table.
Stage 2: row sum + reciprocal via lookup table (once per row).
Stage 3: element-wise multiply.

LUT realization on TPU: a BRAM read becomes a one-hot row-select executed on
the MXU — ``one_hot(idx, T) @ table`` — the natural systolic translation of
a table lookup (see DESIGN.md hardware-adaptation table).  No max
subtraction, exactly as in the paper: the fixed-point input domain is
bounded and the index computation saturates (AP_SAT).

Grid: one dimension over row-blocks; each block holds ``(block_rows, K)`` in
VMEM and produces its output in a single pass (latency strategy: II = 1
row-block per grid step).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import tpu_compiler_params

from repro.core import lut


def _lut_gather_mxu(x, table_ref, spec):
    """one_hot(lut_index(x)) @ table — MXU-native LUT read.

    Index arithmetic comes from ``core.lut.lut_index`` (pure jnp, valid in
    kernel bodies): linear for the paper's fixed-point exp table, log-
    spaced for the reciprocal family (see LutSpec docstring).
    """
    idx = lut.lut_index(x, spec)
    flat = idx.reshape(-1)
    onehot = (
        flat[:, None] == jax.lax.iota(jnp.int32, spec.size)[None, :]
    ).astype(table_ref.dtype)
    vals = jax.lax.dot_general(
        onehot,
        table_ref[...],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    return vals.reshape(x.shape)


def _lut_softmax_kernel(x_ref, exp_tab_ref, inv_tab_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    # Stage 1: exp LUT (element-wise).
    e = _lut_gather_mxu(x, exp_tab_ref, lut.EXP_SPEC)
    # Stage 2: row sum, then inversion LUT (once per row).
    s = jnp.sum(e, axis=-1, keepdims=True)
    inv = _lut_gather_mxu(s, inv_tab_ref, lut.INV_SPEC)
    # Stage 3: element-wise multiply.
    o_ref[...] = (e * inv).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def lut_softmax_pallas(
    x: jax.Array,  # (R, K) scores
    exp_table: jax.Array,  # (T_exp, 1)
    inv_table: jax.Array,  # (T_inv, 1)
    *,
    block_rows: int = 64,
    interpret: bool = False,
) -> jax.Array:
    rows, k = x.shape
    assert rows % block_rows == 0, (rows, block_rows)
    grid = (rows // block_rows,)
    return pl.pallas_call(
        _lut_softmax_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, k), lambda i: (i, 0)),
            pl.BlockSpec(exp_table.shape, lambda i: (0, 0)),
            pl.BlockSpec(inv_table.shape, lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, k), x.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
        name="lut_softmax",
    )(x, exp_table, inv_table)

"""jit'd public wrapper for the LUT softmax kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import fixed_point as fxp
from repro.core import lut
from repro.kernels.lut_softmax.lut_softmax import lut_softmax_pallas
from repro.kernels.lut_softmax.ref import lut_softmax_ref


def _snap_output(out: jax.Array, precision) -> jax.Array:
    """Emit on an ap_fixed grid when a fixed output precision is given
    (the hardware datapath hands fixed-point rows to the next stage)."""
    if precision is None or getattr(precision, "kind", None) != "fixed":
        return out
    return fxp.quantize(out, precision.fixed_cfg())


@functools.partial(
    jax.jit, static_argnames=("use_pallas", "interpret", "precision")
)
def lut_softmax(
    x: jax.Array,
    *,
    use_pallas: bool = True,
    interpret: bool = True,
    precision=None,  # core.precision.Precision (fixed): output grid
) -> jax.Array:
    """Softmax over the last axis via the paper's 3-stage LUT dataflow.

    Accepts any leading batch shape; rows are padded to the block size.
    Fully-padded rows produce garbage that is sliced away.
    """
    if not use_pallas:
        return _snap_output(lut_softmax_ref(x), precision)

    *lead, k = x.shape
    rows = 1
    for d in lead:
        rows *= d
    x2 = x.reshape(rows, k)
    block_rows = min(64, rows) if rows % 64 != 0 else 64
    while rows % block_rows != 0:
        block_rows -= 1
    exp_tab = lut.exp_table().reshape(-1, 1)
    inv_tab = lut.inv_table().reshape(-1, 1)
    out = lut_softmax_pallas(
        x2, exp_tab, inv_tab, block_rows=block_rows, interpret=interpret
    )
    return _snap_output(out.reshape(*lead, k), precision)

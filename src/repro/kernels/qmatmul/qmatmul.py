"""int8 x int8 -> int32 GEMM Pallas kernel with dequantizing epilogue.

TPU adaptation of the paper's fixed-point DSP datapath (Sec. VI-A): weights
and activations are int8 codes (ap_fixed<8,I> after scale folding), products
accumulate in int32 (the paper's wide accumulator), and the epilogue applies
``x_scale * w_scale`` (+ optional bias) to produce float output.

Grid: ``(grid_m, grid_n, grid_k)`` with the contraction dim innermost and
*sequential* — ``grid_k`` IS the paper's reuse factor: R=1 streams the whole
K per output tile (fully parallel), R>1 time-multiplexes the MXU over R
chunks with an R-fold smaller VMEM working set (see ``core/reuse.py``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import tpu_compiler_params


def _qmatmul_kernel(x_ref, w_ref, xs_ref, ws_ref, o_ref, acc_ref):
    """One (block_m, block_n) output tile; revisited across grid_k steps."""
    k_idx = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(k_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # int8 x int8 -> int32 on the MXU (paper: DSP multiply, wide accumulate).
    acc_ref[...] += jax.lax.dot_general(
        x_ref[...],
        w_ref[...],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )

    @pl.when(k_idx == nk - 1)
    def _epilogue():
        # dequant: per-row activation scale x per-col weight scale.
        scale = xs_ref[...] * ws_ref[...]  # (block_m,1)*(1,block_n)
        o_ref[...] = (acc_ref[...].astype(jnp.float32) * scale).astype(
            o_ref.dtype
        )


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "interpret", "out_dtype"),
)
def qmatmul_pallas(
    x: jax.Array,  # (M, K) int8
    w: jax.Array,  # (K, N) int8
    x_scale: jax.Array,  # (M, 1) f32 per-row
    w_scale: jax.Array,  # (1, N) f32 per-col
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    out_dtype=jnp.float32,
    interpret: bool = False,
) -> jax.Array:
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0, (
        f"shapes ({m},{k},{n}) must divide blocks "
        f"({block_m},{block_k},{block_n}); pad in ops.py"
    )
    grid = (m // block_m, n // block_n, k // block_k)
    return pl.pallas_call(
        _qmatmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((block_m, 1), lambda i, j, kk: (i, 0)),
            pl.BlockSpec((1, block_n), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.int32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="qmatmul_int8",
    )(x, w, x_scale, w_scale)

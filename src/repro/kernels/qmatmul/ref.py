"""Pure-jnp oracle for the int8 GEMM kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def qmatmul_ref(
    x: jax.Array,  # (M, K) int8
    w: jax.Array,  # (K, N) int8
    x_scale: jax.Array,  # (M, 1) f32
    w_scale: jax.Array,  # (1, N) f32
    out_dtype=jnp.float32,
) -> jax.Array:
    acc = jax.lax.dot_general(
        x,
        w,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    return (acc.astype(jnp.float32) * (x_scale * w_scale)).astype(out_dtype)

from repro.kernels.qmatmul.ops import qmatmul, qmatmul_prequantized
from repro.kernels.qmatmul.qmatmul import qmatmul_pallas
from repro.kernels.qmatmul.ref import qmatmul_ref

__all__ = ["qmatmul", "qmatmul_prequantized", "qmatmul_pallas", "qmatmul_ref"]

"""jit'd public wrapper for the int8 GEMM kernel.

Handles quantization of float inputs, padding to block multiples, the
reuse-factor -> block_k mapping, and falls back to the jnp reference on
hosts where Pallas interpret mode is not wanted (the wrapper is what the
models call; kernels are the TPU target).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import quant, reuse
from repro.kernels.qmatmul.qmatmul import qmatmul_pallas
from repro.kernels.qmatmul.ref import qmatmul_ref


def _pad_to(x: jax.Array, m0: int, m1: int) -> jax.Array:
    p0 = (-x.shape[0]) % m0
    p1 = (-x.shape[1]) % m1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)))
    return x


@functools.partial(
    jax.jit,
    static_argnames=(
        "reuse_factor", "strategy", "use_pallas", "interpret", "precision"
    ),
)
def qmatmul(
    x: jax.Array,  # (M, K) float
    w: jax.Array,  # (K, N) float
    *,
    reuse_factor: int = 1,
    strategy: reuse.Strategy = reuse.Strategy.LATENCY,
    use_pallas: bool = True,
    interpret: bool = True,
    precision=None,  # core.precision.Precision (int8 kind): bits/granularity
) -> jax.Array:
    """Quantize x (per-row) and w (per-col) to int8 and multiply.

    The paper's reuse factor R maps to grid_k sequential contraction chunks
    (``core/reuse.plan_matmul``).  ``precision`` threads a PrecisionPlan
    weights entry into the quantizer: ``bits`` selects the code width and
    ``per_channel=False`` collapses to per-tensor scales.
    """
    bits = 8
    per_channel = True
    if precision is not None:
        if precision.kind != "int8":
            raise ValueError(
                f"qmatmul expects an int8 precision, got {precision}"
            )
        bits = precision.bits
        per_channel = precision.per_channel
    m, k = x.shape
    _, n = w.shape
    xq = quant.quantize_int8(
        x, axis=0 if per_channel else None, bits=bits
    )  # per-row scales
    wq = quant.quantize_int8(
        w, axis=1 if per_channel else None, bits=bits
    )  # per-col scales
    x_scale = jnp.broadcast_to(xq.scale.reshape(-1, 1), (m, 1))
    w_scale = jnp.broadcast_to(wq.scale.reshape(1, -1), (1, n))

    if not use_pallas:
        return qmatmul_ref(xq.values, wq.values, x_scale, w_scale)

    plan = reuse.plan_matmul(
        m, k, n, reuse_factor=reuse_factor, strategy=strategy, bytes_per_elem=1
    )
    xv = _pad_to(xq.values, plan.block_m, plan.block_k)
    wv = _pad_to(wq.values, plan.block_k, plan.block_n)
    xs = _pad_to(x_scale, plan.block_m, 1)
    ws = _pad_to(w_scale, 1, plan.block_n)
    out = qmatmul_pallas(
        xv,
        wv,
        xs,
        ws,
        block_m=plan.block_m,
        block_n=plan.block_n,
        block_k=plan.block_k,
        interpret=interpret,
    )
    return out[:m, :n]


def qmatmul_prequantized(
    xq: quant.QTensor, wq: quant.QTensor, out_dtype=jnp.float32
) -> jax.Array:
    """Reference path for already-quantized tensors (serving engine)."""
    m = xq.values.shape[0]
    n = wq.values.shape[1]
    xs = (
        xq.scale.reshape(m, 1)
        if xq.axis is not None
        else jnp.full((m, 1), xq.scale)
    )
    ws = (
        wq.scale.reshape(1, n)
        if wq.axis is not None
        else jnp.full((1, n), wq.scale)
    )
    return qmatmul_ref(xq.values, wq.values, xs, ws, out_dtype)

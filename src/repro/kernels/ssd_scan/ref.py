"""Oracle for the SSD scan kernel: the pure-jnp chunked implementation and
the step-by-step recurrence from models/ssm."""

from __future__ import annotations

import jax

from repro.models.ssm import ssd_chunked, ssd_naive_ref


def ssd_scan_ref(xdt, a, bmat, cmat, *, chunk: int = 64):
    """(BH, L, ...) layout -> (BH, L, P), via models.ssm.ssd_chunked
    (which is itself validated against the naive recurrence)."""
    # models.ssm uses (b, l, h, p); fold BH into b with h=1
    import jax.numpy as jnp

    x4 = xdt[:, :, None, :]
    a3 = a[..., 0][:, :, None]
    b4 = bmat[:, :, None, :]
    c4 = cmat[:, :, None, :]
    y, _ = ssd_chunked(x4, a3, b4, c4, chunk=chunk)
    return y[:, :, 0, :]


def ssd_scan_naive(xdt, a, bmat, cmat):
    x4 = xdt[:, :, None, :]
    a3 = a[..., 0][:, :, None]
    b4 = bmat[:, :, None, :]
    c4 = cmat[:, :, None, :]
    y, _ = ssd_naive_ref(x4, a3, b4, c4)
    return y[:, :, 0, :]

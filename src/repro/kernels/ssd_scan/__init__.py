from repro.kernels.ssd_scan.ops import ssd
from repro.kernels.ssd_scan.ref import ssd_scan_naive, ssd_scan_ref
from repro.kernels.ssd_scan.ssd_scan import ssd_scan_pallas

__all__ = ["ssd", "ssd_scan_pallas", "ssd_scan_ref", "ssd_scan_naive"]

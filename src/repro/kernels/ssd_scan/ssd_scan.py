"""Mamba2 SSD chunked scan as a Pallas TPU kernel (arXiv:2405.21060).

The SSD duality splits the selective-state recurrence into an intra-chunk
quadratic part (two MXU matmuls masked by the decay matrix L) and an
inter-chunk linear state pass — structurally the same producer/consumer
pipeline as the paper's streaming MHA: chunk tensors stream HBM->VMEM
while the (P, N) running state lives in VMEM scratch across the
sequential chunk dimension (the FIFO/persistent-register analogue).

Grid: ``(batch*heads, n_chunks)`` — heads parallel, chunks sequential.
MXU-friendly construction: the in-chunk cumulative sums are computed as a
lower-triangular-ones matmul (``tril @ a``) instead of a scan, so every
heavy op is a dot.

VMEM working set per step: q*(P + 2N) inputs + q^2 decay/score tiles +
(P, N) state — for the assigned configs (q=64, P=64, N<=128) well under
1 MiB, leaving the double-buffered pipeline full depth.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import tpu_compiler_params


def _ssd_chunk_kernel(xdt_ref, a_ref, b_ref, c_ref, y_ref, state_ref):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    xdt = xdt_ref[0].astype(jnp.float32)  # (q, p)
    a = a_ref[0].astype(jnp.float32)  # (q, 1) log-decay per step
    bm = b_ref[0].astype(jnp.float32)  # (q, n)
    cm = c_ref[0].astype(jnp.float32)  # (q, n)
    q = xdt.shape[0]

    # inclusive cumulative sum via lower-tri ones matmul (MXU, not scan)
    ii = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    tril_incl = (ii >= jj).astype(jnp.float32)
    cs = jax.lax.dot_general(
        tril_incl, a, dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (q, 1), cs_i = sum_{m<=i} a_m

    # decay matrix L[i,j] = exp(cs_i - cs_j) for i >= j (sum over j+1..i)
    seg = cs - cs.reshape(1, q)  # [i, j] = cs_i - cs_j
    el = jnp.where(ii >= jj, jnp.exp(seg), 0.0)

    # intra-chunk: (C B^T ⊙ L) @ xdt
    scores = jax.lax.dot_general(
        cm, bm, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    y = jax.lax.dot_general(
        scores * el, xdt, dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    # inter-chunk: contribution of the carried state, decayed into chunk
    state = state_ref[...]  # (p, n)
    y_off = jax.lax.dot_general(
        cm, state, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (q, p)
    y = y + y_off * jnp.exp(cs)

    # state update: decay to chunk end + sum of B-weighted inputs
    decay_to_end = jnp.exp(cs[-1] - cs)  # (q, 1)
    upd = jax.lax.dot_general(
        xdt * decay_to_end, bm, dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (p, n)
    state_ref[...] = state * jnp.exp(cs[-1]) + upd
    y_ref[0] = y.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_pallas(
    xdt: jax.Array,  # (BH, L, P) inputs pre-multiplied by dt
    a: jax.Array,  # (BH, L, 1) log-decay
    bmat: jax.Array,  # (BH, L, N)
    cmat: jax.Array,  # (BH, L, N)
    *,
    chunk: int = 64,
    interpret: bool = False,
) -> jax.Array:
    bh, l, p = xdt.shape
    n = bmat.shape[-1]
    assert l % chunk == 0, (l, chunk)
    grid = (bh, l // chunk)
    return pl.pallas_call(
        _ssd_chunk_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, p), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, n), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, n), lambda b, c: (b, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, p), lambda b, c: (b, c, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, l, p), xdt.dtype),
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="ssd_chunked_scan",
    )(xdt, a, bmat, cmat)

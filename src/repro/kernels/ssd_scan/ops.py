"""jit'd public wrapper for the SSD scan kernel: (b, l, h, p)-layout entry
point used by models/ssm when the Pallas path is selected."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.ref import ssd_scan_ref
from repro.kernels.ssd_scan.ssd_scan import ssd_scan_pallas


@functools.partial(jax.jit, static_argnames=("chunk", "use_pallas", "interpret"))
def ssd(
    xdt: jax.Array,  # (b, l, h, p)
    a: jax.Array,  # (b, l, h)
    bmat: jax.Array,  # (b, l, h, n)
    cmat: jax.Array,  # (b, l, h, n)
    *,
    chunk: int = 64,
    use_pallas: bool = True,
    interpret: bool = True,
) -> jax.Array:
    b, l, h, p = xdt.shape
    n = bmat.shape[-1]
    chunk = min(chunk, l)

    def fold(t):
        return t.transpose(0, 2, 1, 3).reshape(b * h, l, t.shape[-1])

    xf = fold(xdt)
    af = fold(a[..., None])
    bf = fold(bmat)
    cf = fold(cmat)
    if use_pallas:
        out = ssd_scan_pallas(xf, af, bf, cf, chunk=chunk, interpret=interpret)
    else:
        out = ssd_scan_ref(xf, af, bf, cf, chunk=chunk)
    return out.reshape(b, h, l, p).transpose(0, 2, 1, 3)

"""Pallas TPU kernels for the paper's compute hot spots.

The paper optimizes four compute layers on the FPGA (quantized GEMMs, the
restructured softmax, the staged LayerNorm, and the streaming MHA stages);
each maps to one kernel subpackage here, plus ``ssd_scan`` for the Mamba2
hot spot of the assigned ssm/hybrid archs.  Each ships ``<name>.py``
(pl.pallas_call + BlockSpec), ``ops.py`` (jit'd public wrapper) and
``ref.py`` (pure-jnp oracle), validated in interpret mode on CPU against
its oracle across shape/dtype sweeps (tests/test_kernels_*.py).
"""

"""Paper's 5-stage LayerNorm as a fused Pallas TPU kernel (Sec. IV-C).

Stages (all fused in one VMEM-resident pass per row-block):
  1. mean = sum(x)/k
  2. dm   = x - mean
  3. var  = sum(dm^2)/k
  4. x_hat = dm * rsqrt(var)      (optionally via the 1/sqrt LUT)
  5. out  = gamma * x_hat + beta

The FPGA version streams one time step per cycle through five pipeline
registers; the TPU version processes a block of rows per grid step with the
whole feature dim resident in VMEM — the HBM->VMEM grid pipeline plays the
role of the FIFO chain.  RMSNorm mode fixes the mean at zero (stages 3-5),
covering the RMSNorm used by most assigned LM architectures.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import tpu_compiler_params

from repro.core import lut


def _make_kernel(use_lut: bool, rms: bool, eps: float):
    def _kernel(x_ref, gamma_ref, beta_ref, rsqrt_tab_ref, o_ref):
        x = x_ref[...].astype(jnp.float32)
        k = x.shape[-1]
        if rms:
            dm = x  # stage 1-2 skipped: mean fixed at 0
        else:
            mean = jnp.sum(x, axis=-1, keepdims=True) / k  # stage 1
            dm = x - mean  # stage 2
        var = jnp.sum(dm * dm, axis=-1, keepdims=True) / k  # stage 3
        if use_lut:  # stage 4 via LUT (one-hot MXU read)
            spec = lut.RSQRT_SPEC
            idx = lut.lut_index(var, spec)
            onehot = (
                idx.reshape(-1)[:, None]
                == jax.lax.iota(jnp.int32, spec.size)[None, :]
            ).astype(jnp.float32)
            inv_std = jax.lax.dot_general(
                onehot,
                rsqrt_tab_ref[...],
                dimension_numbers=(((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            ).reshape(var.shape)
        else:
            inv_std = jax.lax.rsqrt(var + eps)
        x_hat = dm * inv_std
        out = x_hat * gamma_ref[...]  # stage 5
        if not rms:
            out = out + beta_ref[...]
        o_ref[...] = out.astype(o_ref.dtype)

    return _kernel


@functools.partial(
    jax.jit,
    static_argnames=("block_rows", "use_lut", "rms", "eps", "interpret"),
)
def layernorm_pallas(
    x: jax.Array,  # (R, K)
    gamma: jax.Array,  # (1, K)
    beta: jax.Array,  # (1, K)
    rsqrt_table: jax.Array,  # (T, 1)
    *,
    block_rows: int = 64,
    use_lut: bool = False,
    rms: bool = False,
    eps: float = 1e-5,
    interpret: bool = False,
) -> jax.Array:
    rows, k = x.shape
    assert rows % block_rows == 0, (rows, block_rows)
    grid = (rows // block_rows,)
    return pl.pallas_call(
        _make_kernel(use_lut, rms, eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, k), lambda i: (i, 0)),
            pl.BlockSpec((1, k), lambda i: (0, 0)),
            pl.BlockSpec((1, k), lambda i: (0, 0)),
            pl.BlockSpec(rsqrt_table.shape, lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, k), x.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
        name="layernorm_staged",
    )(x, gamma, beta, rsqrt_table)

"""Pure-jnp oracle for the staged LayerNorm kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import layernorm as ln_core


def layernorm_ref(
    x: jax.Array,
    gamma: jax.Array,
    beta: jax.Array,
    *,
    use_lut: bool = False,
    rms: bool = False,
    eps: float = 1e-5,
) -> jax.Array:
    xf = x.astype(jnp.float32)
    if rms:
        out = ln_core.rmsnorm(xf, gamma.reshape(-1), eps=eps, use_lut=use_lut)
    else:
        out = ln_core.layernorm_paper(
            xf, gamma.reshape(-1), beta.reshape(-1), eps=eps, use_lut=use_lut
        )
    return out.astype(x.dtype)

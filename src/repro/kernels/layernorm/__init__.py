from repro.kernels.layernorm.layernorm import layernorm_pallas
from repro.kernels.layernorm.ops import layernorm
from repro.kernels.layernorm.ref import layernorm_ref

__all__ = ["layernorm", "layernorm_pallas", "layernorm_ref"]

"""jit'd public wrapper for the staged LayerNorm kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import fixed_point as fxp
from repro.core import lut
from repro.kernels.layernorm.layernorm import layernorm_pallas
from repro.kernels.layernorm.ref import layernorm_ref


def _snap_output(out: jax.Array, precision) -> jax.Array:
    """Emit on an ap_fixed grid when a fixed output precision is given
    (paper Sec. IV-C: the staged norm feeds a fixed-point datapath)."""
    if precision is None or getattr(precision, "kind", None) != "fixed":
        return out
    return fxp.quantize(out, precision.fixed_cfg())


@functools.partial(
    jax.jit,
    static_argnames=(
        "use_lut", "rms", "eps", "use_pallas", "interpret", "precision"
    ),
)
def layernorm(
    x: jax.Array,  # (..., K)
    gamma: jax.Array,  # (K,)
    beta: jax.Array | None = None,  # (K,) or None for RMSNorm
    *,
    use_lut: bool = False,
    rms: bool = False,
    eps: float = 1e-5,
    use_pallas: bool = True,
    interpret: bool = True,
    precision=None,  # core.precision.Precision (fixed): output grid
) -> jax.Array:
    k = x.shape[-1]
    if beta is None:
        beta = jnp.zeros((k,), dtype=jnp.float32)
    if not use_pallas:
        return _snap_output(
            layernorm_ref(x, gamma, beta, use_lut=use_lut, rms=rms, eps=eps),
            precision,
        )
    *lead, _ = x.shape
    rows = 1
    for d in lead:
        rows *= d
    x2 = x.reshape(rows, k)
    block_rows = 64 if rows % 64 == 0 else 1
    out = layernorm_pallas(
        x2,
        gamma.reshape(1, k).astype(jnp.float32),
        beta.reshape(1, k).astype(jnp.float32),
        lut.rsqrt_table().reshape(-1, 1),
        block_rows=block_rows,
        use_lut=use_lut,
        rms=rms,
        eps=eps,
        interpret=interpret,
    )
    return _snap_output(out.reshape(*lead, k), precision)

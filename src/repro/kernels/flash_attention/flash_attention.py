"""Fused streaming attention Pallas kernel — stages 2+3 of the paper's MHA
pipeline (Sec. IV-A), adapted to the TPU memory hierarchy.

FPGA original: Q rows stream out of FIFOs against a register-resident K
(stage 2: scores + softmax), scores stream against a register-resident V
(stage 3: weighted sum) — the k x k score matrix never exists in slow
memory.  TPU adaptation: Q row-blocks stream through the grid while K/V
*blocks* are pinned in VMEM; scores live only in VREG/VMEM scratch; the
HBM->VMEM double-buffered grid pipeline is the FIFO chain.

Two softmax modes, matching ``core/softmax``:

* ``safe``  — online max/sum (flash) for the float path.
* ``lut``   — the paper's no-max-subtraction 3-stage LUT softmax over the
  bounded fixed-point score domain: running *sum* only, exp via the
  one-hot-MXU table read.  Numerically valid because scores are clipped to
  the exp-table domain, exactly like ap_fixed saturation on the FPGA.

Masking: none / causal / sliding-window (starcoder2) via block-level
index arithmetic.

Grid: ``(batch*heads, q_blocks, kv_blocks)`` with kv innermost sequential;
scratch (m, l, acc) persists across the kv dimension — the reuse-factor
analogue for attention is the kv_block count per q tile.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import tpu_compiler_params

from repro.core import lut

NEG_INF = -1e30


def _make_kernel(
    *,
    scale: float,
    causal: bool,
    window: int | None,
    mode: str,
    block_q: int,
    block_kv: int,
    kv_len: int,
):
    def _kernel(
        q_ref, k_ref, v_ref, exp_tab_ref, inv_tab_ref, o_ref, m_ref, l_ref, acc_ref
    ):
        kv_idx = pl.program_id(2)
        n_kv = pl.num_programs(2)
        q_idx = pl.program_id(1)

        @pl.when(kv_idx == 0)
        def _init():
            m_ref[...] = jnp.full_like(m_ref, NEG_INF)
            l_ref[...] = jnp.zeros_like(l_ref)
            acc_ref[...] = jnp.zeros_like(acc_ref)

        q = q_ref[0].astype(jnp.float32)  # (block_q, d)
        k = k_ref[0].astype(jnp.float32)  # (block_kv, d)
        v = v_ref[0].astype(jnp.float32)  # (block_kv, d)

        # stage 2a: scores = Q K^T * 1/sqrt(d_k)  (pre-computed constant)
        s = jax.lax.dot_general(
            q, k, dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale  # (block_q, block_kv)

        # positional mask
        q_pos = q_idx * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_kv), 0
        )
        k_pos = kv_idx * block_kv + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_kv), 1
        )
        mask = k_pos < kv_len  # padding mask
        if causal:
            mask = mask & (k_pos <= q_pos)
        if window is not None:
            mask = mask & (q_pos - k_pos < window)

        if mode == "safe":
            s = jnp.where(mask, s, NEG_INF)
            # stage 2b: online softmax (running max/sum)
            m_prev = m_ref[...]  # (block_q, 1)
            m_cur = jnp.max(s, axis=-1, keepdims=True)
            m_new = jnp.maximum(m_prev, m_cur)
            alpha = jnp.exp(m_prev - m_new)
            p = jnp.exp(s - m_new)
            l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
            m_ref[...] = m_new
            # stage 3: weighted sum with V
            acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
                p, v, dimension_numbers=(((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            )
        else:  # paper's LUT mode: bounded domain, no max subtraction
            spec = lut.EXP_SPEC
            idx = lut.lut_index(s, spec)
            onehot = (
                idx.reshape(-1)[:, None]
                == jax.lax.iota(jnp.int32, spec.size)[None, :]
            ).astype(jnp.float32)
            p = jax.lax.dot_general(
                onehot, exp_tab_ref[...],
                dimension_numbers=(((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            ).reshape(s.shape)
            p = jnp.where(mask, p, 0.0)
            l_ref[...] += jnp.sum(p, axis=-1, keepdims=True)
            acc_ref[...] += jax.lax.dot_general(
                p, v, dimension_numbers=(((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            )

        @pl.when(kv_idx == n_kv - 1)
        def _epilogue():
            l = l_ref[...]
            if mode == "safe":
                inv = jnp.where(l > 0, 1.0 / jnp.maximum(l, 1e-30), 0.0)
            else:
                # paper stage 2: denominator reciprocal via the inversion LUT
                ispec = lut.INV_SPEC
                iidx = lut.lut_index(l, ispec)
                ioneh = (
                    iidx.reshape(-1)[:, None]
                    == jax.lax.iota(jnp.int32, ispec.size)[None, :]
                ).astype(jnp.float32)
                inv = jax.lax.dot_general(
                    ioneh, inv_tab_ref[...],
                    dimension_numbers=(((1,), (0,)), ((), ())),
                    preferred_element_type=jnp.float32,
                ).reshape(l.shape)
                inv = jnp.where(l > 0, inv, 0.0)
            o_ref[0] = (acc_ref[...] * inv).astype(o_ref.dtype)

    return _kernel


@functools.partial(
    jax.jit,
    static_argnames=(
        "scale", "causal", "window", "mode", "block_q", "block_kv",
        "kv_len", "interpret",
    ),
)
def flash_attention_pallas(
    q: jax.Array,  # (BH, Lq, D)
    k: jax.Array,  # (BH, Lkv, D)
    v: jax.Array,  # (BH, Lkv, D)
    *,
    scale: float,
    causal: bool = False,
    window: int | None = None,
    mode: str = "safe",
    block_q: int = 128,
    block_kv: int = 128,
    kv_len: int | None = None,  # true (unpadded) kv length
    interpret: bool = False,
) -> jax.Array:
    bh, lq, d = q.shape
    _, lkv, _ = k.shape
    kv_len = lkv if kv_len is None else kv_len
    block_q = min(block_q, lq)
    block_kv = min(block_kv, lkv)
    assert lq % block_q == 0 and lkv % block_kv == 0, (lq, lkv, block_q, block_kv)
    grid = (bh, lq // block_q, lkv // block_kv)
    exp_tab = lut.exp_table().reshape(-1, 1)
    inv_tab = lut.inv_table().reshape(-1, 1)
    kernel = _make_kernel(
        scale=scale, causal=causal, window=window, mode=mode,
        block_q=block_q, block_kv=block_kv, kv_len=kv_len,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_kv, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_kv, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec(exp_tab.shape, lambda b, i, j: (0, 0)),
            pl.BlockSpec(inv_tab.shape, lambda b, i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, lq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),  # running max m
            pltpu.VMEM((block_q, 1), jnp.float32),  # running sum l
            pltpu.VMEM((block_q, d), jnp.float32),  # output accumulator
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name=f"flash_attention_{mode}",
    )(q, k, v, exp_tab, inv_tab)

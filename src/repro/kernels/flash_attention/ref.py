"""Pure-jnp oracle for the fused streaming attention kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import softmax as sm

NEG_INF = -1e30


def attention_ref(
    q: jax.Array,  # (..., Lq, D) — any leading batch/head dims
    k: jax.Array,  # (..., Lkv, D)
    v: jax.Array,  # (..., Lkv, D)
    *,
    scale: float,
    causal: bool = False,
    window: int | None = None,
    mode: str = "safe",
    kv_len: int | None = None,
) -> jax.Array:
    qf, kf, vf = (t.astype(jnp.float32) for t in (q, k, v))
    # ``attnvol`` named_scope: tags the O(L^2) attention volume in HLO
    # metadata so the roofline parser can price it separately (the fused
    # Pallas kernel replaces exactly this volume on TPU).
    with jax.named_scope("attnvol"):
        s = jnp.einsum("...qd,...kd->...qk", qf, kf) * scale
        lq, lkv = s.shape[-2], s.shape[-1]
        kv_len = lkv if kv_len is None else kv_len
        q_pos = jnp.arange(lq)[:, None]
        k_pos = jnp.arange(lkv)[None, :]
        mask = k_pos < kv_len
        if causal:
            mask = mask & (k_pos <= q_pos)
        if window is not None:
            mask = mask & (q_pos - k_pos < window)

        if mode == "safe":
            s = jnp.where(mask, s, NEG_INF)
            p = jax.nn.softmax(s, axis=-1)
        else:  # paper's LUT softmax, masked entries contribute zero weight
            e = sm.lut.lut_exp(s)
            e = jnp.where(mask, e, 0.0)
            denom = jnp.sum(e, axis=-1, keepdims=True)
            p = e * sm.lut.lut_inv(denom)
        out = jnp.einsum("...qk,...kd->...qd", p, vf)
    return out.astype(q.dtype)

from repro.kernels.flash_attention.flash_attention import flash_attention_pallas
from repro.kernels.flash_attention.ops import mha
from repro.kernels.flash_attention.ref import attention_ref

__all__ = ["mha", "flash_attention_pallas", "attention_ref"]

"""jit'd public wrapper for the fused streaming attention kernel.

Accepts (batch, heads, len, d) tensors with GQA head-group broadcasting,
pads lengths to block multiples, and dispatches to the Pallas kernel or the
jnp reference.  This is the single attention entry point the model zoo uses
(``models/attention.py``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention_pallas
from repro.kernels.flash_attention.ref import attention_ref


def _pad_len(x: jax.Array, axis: int, mult: int) -> jax.Array:
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal", "window", "mode", "use_pallas", "interpret",
        "block_q", "block_kv",
    ),
)
def mha(
    q: jax.Array,  # (B, Hq, Lq, D)
    k: jax.Array,  # (B, Hkv, Lkv, D)
    v: jax.Array,  # (B, Hkv, Lkv, D)
    *,
    causal: bool = False,
    window: int | None = None,
    mode: str = "safe",
    use_pallas: bool = False,
    interpret: bool = True,
    block_q: int = 128,
    block_kv: int = 128,
) -> jax.Array:
    b, hq, lq, d = q.shape
    _, hkv, lkv, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    scale = 1.0 / (d ** 0.5)

    # GQA: broadcast kv heads across the query-head groups.
    if group > 1:
        k = jnp.repeat(k, group, axis=1)
        v = jnp.repeat(v, group, axis=1)

    if not use_pallas:
        # 4D path (no batch*head flatten): keeps head/batch shardings
        # intact under pjit — the flatten-reshape forces an involuntary
        # SPMD rematerialization on production meshes.
        return attention_ref(
            q, k, v, scale=scale, causal=causal, window=window, mode=mode
        )

    qf = q.reshape(b * hq, lq, d)
    kf = k.reshape(b * hq, lkv, d)
    vf = v.reshape(b * hq, lkv, d)

    bq = min(block_q, lq)
    bkv = min(block_kv, lkv)
    qp = _pad_len(qf, 1, bq)
    kp = _pad_len(kf, 1, bkv)
    vp = _pad_len(vf, 1, bkv)
    out = flash_attention_pallas(
        qp, kp, vp,
        scale=scale, causal=causal, window=window, mode=mode,
        block_q=bq, block_kv=bkv, kv_len=lkv, interpret=interpret,
    )
    return out[:, :lq].reshape(b, hq, lq, d)

"""AdamW in pure JAX (no optax dependency), pytree-native.

Optimizer state mirrors the parameter tree (fp32 moments) and therefore
shards with the same rules as FSDP parameters (distributed/sharding.py).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    schedule: Callable[[jax.Array], jax.Array]
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float | None = 1.0

    def init(self, params: PyTree) -> dict:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {
            "step": jnp.zeros((), jnp.int32),
            "mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
        }

    def update(
        self, grads: PyTree, state: dict, params: PyTree
    ) -> tuple[PyTree, dict, dict]:
        """Returns (new_params, new_state, metrics)."""
        step = state["step"] + 1
        gnorm = global_norm(grads)
        if self.grad_clip is not None:
            scale = jnp.minimum(1.0, self.grad_clip / (gnorm + 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
        b1, b2 = self.b1, self.b2
        mu = jax.tree.map(
            lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
            state["mu"], grads,
        )
        nu = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state["nu"], grads,
        )
        lr = self.schedule(step)
        c1 = 1 - b1 ** step.astype(jnp.float32)
        c2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(p, m, v):
            mhat = m / c1
            vhat = v / c2
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            delta = delta + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

        new_params = jax.tree.map(upd, params, mu, nu)
        new_state = {"step": step, "mu": mu, "nu": nu}
        return new_params, new_state, {"lr": lr, "grad_norm": gnorm}

    def abstract_state(self, abstract_params: PyTree) -> dict:
        f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
        return {
            "step": jax.ShapeDtypeStruct((), jnp.int32),
            "mu": jax.tree.map(f32, abstract_params),
            "nu": jax.tree.map(f32, abstract_params),
        }


def global_norm(tree: PyTree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)
    )

from repro.optim.adamw import AdamW, global_norm  # noqa: F401
from repro.optim.schedules import (  # noqa: F401
    cosine_schedule,
    linear_schedule,
    make_schedule,
    wsd_schedule,
)

"""LR schedules: linear warmup + {cosine, WSD, linear} decay.

WSD (Warmup-Stable-Decay) is the MiniCPM schedule (arXiv:2404.06395):
constant LR through the stable phase, then a short exponential-style decay
over the final ``decay_fraction`` of training.
"""

from __future__ import annotations

import jax.numpy as jnp


def warmup(step, warmup_steps):
    return jnp.minimum(1.0, (step + 1) / jnp.maximum(warmup_steps, 1))


def cosine_schedule(step, *, base_lr, warmup_steps, total_steps, min_ratio=0.1):
    w = warmup(step, warmup_steps)
    t = jnp.clip(
        (step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0
    )
    cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return base_lr * w * cos


def wsd_schedule(
    step, *, base_lr, warmup_steps, total_steps, decay_fraction=0.1, min_ratio=0.01
):
    """Warmup -> Stable (constant) -> Decay (MiniCPM; exponential-like)."""
    w = warmup(step, warmup_steps)
    decay_steps = jnp.maximum(total_steps * decay_fraction, 1)
    decay_start = total_steps - decay_steps
    in_decay = step >= decay_start
    t = jnp.clip((step - decay_start) / decay_steps, 0.0, 1.0)
    decay = jnp.power(min_ratio, t)  # min_ratio**t: 1 -> min_ratio
    return base_lr * w * jnp.where(in_decay, decay, 1.0)


def linear_schedule(step, *, base_lr, warmup_steps, total_steps, min_ratio=0.0):
    w = warmup(step, warmup_steps)
    t = jnp.clip(
        (step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0
    )
    return base_lr * w * (1 - (1 - min_ratio) * t)


def make_schedule(train_cfg):
    kind = train_cfg.schedule
    kw = dict(
        base_lr=train_cfg.learning_rate,
        warmup_steps=train_cfg.warmup_steps,
        total_steps=train_cfg.total_steps,
    )
    if kind == "cosine":
        return lambda s: cosine_schedule(s, **kw)
    if kind == "wsd":
        return lambda s: wsd_schedule(
            s, decay_fraction=train_cfg.decay_fraction, **kw
        )
    if kind == "linear":
        return lambda s: linear_schedule(s, **kw)
    raise ValueError(f"unknown schedule {kind}")

"""minicpm-2b [dense] — 40L d2304 36H (kv=36, MHA) d_ff=5760 vocab=122753.

arXiv:2404.06395 — llama-like arch with muP scaling (scale_emb=12,
scale_depth=1.4, dim_model_base=256); trained with the WSD schedule
(implemented in optim/schedules.py and selected by this arch's TrainConfig).
"""

import dataclasses

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="minicpm-2b",
        family="dense",
        n_layers=40,
        d_model=2304,
        n_heads=36,
        n_kv_heads=36,
        d_ff=5760,
        vocab_size=122753,
        attn_kind="gqa",
        norm_kind="rmsnorm",
        act="silu",
        gated_mlp=True,
        rope_theta=10000.0,
        tie_embeddings=True,
        emb_scale=12.0,
        residual_scale=1.4 / (40 ** 0.5),
        logit_scale=256.0 / 2304.0,
        serve_policy="int8_serve",
    )


def reduced_config() -> ModelConfig:
    return dataclasses.replace(
        config(),
        name="minicpm-2b-reduced",
        n_layers=2,
        d_model=48,
        n_heads=4,
        n_kv_heads=4,
        d_ff=96,
        vocab_size=128,
        residual_scale=1.4 / (2 ** 0.5),
        logit_scale=1.0,
        emb_scale=1.0,
    )
